"""Per-kernel validation: shape/dtype sweeps + hypothesis properties,
always against the pure-jnp ref.py oracle (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import LDAConfig, MiniBatch
from repro.core.pobp import dense_sweep
from repro.core.sync import LocalReducer
from repro.kernels.bp_update.kernel import bp_update_tokens, token_tile
from repro.kernels.bp_update.ops import dense_sweep_pallas
from repro.kernels.bp_update.ref import bp_update_tokens_ref
from repro.kernels.power_pack import ops as pp_ops
from repro.kernels.power_pack.ref import pack_rows_ref, scatter_add_rows_ref


def _rand_inputs(key, T, K, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c = jax.random.randint(k1, (T, 1), 0, 4).astype(dtype)
    mu = jax.nn.softmax(jax.random.normal(k2, (T, K)), -1).astype(dtype)
    th = (jax.random.uniform(k3, (T, K)) * 5).astype(dtype)
    ph = (jax.random.uniform(k4, (T, K)) * 5).astype(dtype)
    pt = jnp.sum(ph, 0, keepdims=True) + 1.0
    return c, mu, th, ph, pt


# ------------------------------------------------------------ bp_update

@pytest.mark.parametrize("T,K", [(8, 128), (64, 128), (256, 256), (40, 384),
                                 (512, 1024), (16, 2048)])
def test_bp_update_shape_sweep(T, K):
    c, mu, th, ph, pt = _rand_inputs(jax.random.PRNGKey(T * K), T, K)
    kw = dict(alpha=0.1, beta=0.01, wbeta=1.2)
    m1, r1 = bp_update_tokens(c, mu, th, ph, pt, **kw)
    m2, r2 = bp_update_tokens_ref(c, mu, th, ph, pt, **kw)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-5, atol=1e-6)
    # normalized output
    np.testing.assert_allclose(np.asarray(jnp.sum(m1, -1)), 1.0, atol=1e-5)


def test_bp_update_dtype_bf16():
    c, mu, th, ph, pt = _rand_inputs(jax.random.PRNGKey(0), 32, 128,
                                     dtype=jnp.bfloat16)
    kw = dict(alpha=0.1, beta=0.01, wbeta=1.2)
    m1, r1 = bp_update_tokens(c, mu, th, ph, pt, **kw)
    m2, r2 = bp_update_tokens_ref(c, mu, th, ph, pt, **kw)
    np.testing.assert_allclose(np.asarray(m1, dtype=np.float32),
                               np.asarray(m2, dtype=np.float32),
                               rtol=2e-2, atol=2e-2)


def test_token_tile_fits_vmem():
    for K in (128, 512, 2048, 4096, 10240):
        tt = token_tile(K)
        assert tt % 8 == 0 and tt >= 8
        assert 5 * tt * K * 4 <= 16 * 1024 * 1024  # hard VMEM budget


def test_dense_sweep_pallas_matches_jnp_sweep():
    """ops.py wrapper (gathers + kernel + scatter) vs core.pobp.dense_sweep."""
    key = jax.random.PRNGKey(3)
    cfg = LDAConfig(vocab_size=90, num_topics=16)
    D, L = 12, 20
    wid = jax.random.randint(key, (D, L), 0, cfg.vocab_size).astype(jnp.int32)
    cnt = jax.random.randint(key, (D, L), 0, 3).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    mu = jax.nn.softmax(jax.random.normal(key, (D, L, cfg.num_topics)), -1)
    phi = jax.random.uniform(key, (cfg.vocab_size, cfg.num_topics)) * 3
    phi_tot = jnp.sum(phi, 0)
    m1, r1 = dense_sweep_pallas(batch, mu, phi, phi_tot, cfg)
    m2, r2 = dense_sweep(batch, mu, phi, phi_tot, cfg, LocalReducer())
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ power_pack

@pytest.mark.parametrize("W,K,P,Pk", [(64, 32, 8, 4), (128, 256, 16, 50),
                                      (500, 96, 50, 10), (32, 130, 4, 130)])
def test_power_pack_shape_sweep(W, K, P, Pk):
    rng = np.random.default_rng(W + K)
    mat = jnp.asarray(rng.normal(size=(W, K)).astype(np.float32))
    sel_w = jnp.asarray(rng.choice(W, P, replace=False).astype(np.int32))
    sel_k = jnp.asarray(np.stack([rng.choice(K, Pk, replace=False)
                                  for _ in range(P)]).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(P, Pk)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(pp_ops.pack_rows(mat, sel_w, sel_k)),
                               np.asarray(pack_rows_ref(mat, sel_w, sel_k)),
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(pp_ops.scatter_add_rows(mat, sel_w, sel_k, vals)),
        np.asarray(scatter_add_rows_ref(mat, sel_w, sel_k, vals)),
        rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 20), st.data())
def test_power_pack_property_roundtrip(W, K, data):
    """hypothesis: pack(scatter(zeros, idx, vals)) == vals for any valid idx."""
    P = data.draw(st.integers(1, W))
    Pk = data.draw(st.integers(1, K))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    sel_w = jnp.asarray(rng.choice(W, P, replace=False).astype(np.int32))
    sel_k = jnp.asarray(np.stack([rng.choice(K, Pk, replace=False)
                                  for _ in range(P)]).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(P, Pk)).astype(np.float32))
    zero = jnp.zeros((W, K), jnp.float32)
    scattered = pp_ops.scatter_add_rows(zero, sel_w, sel_k, vals)
    back = pp_ops.pack_rows(scattered, sel_w, sel_k)
    np.testing.assert_allclose(np.asarray(back), np.asarray(vals), rtol=1e-6,
                               atol=1e-6)
    # total mass conserved
    np.testing.assert_allclose(float(jnp.sum(scattered)), float(jnp.sum(vals)),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.sampled_from([128, 256, 384]), st.data())
def test_bp_update_property_normalized_and_positive(T, K, data):
    """hypothesis: output is a prob. dist. and residual >= 0, any T/K/counts."""
    seed = data.draw(st.integers(0, 2**31))
    c, mu, th, ph, pt = _rand_inputs(jax.random.PRNGKey(seed), T, K)
    m1, r1 = bp_update_tokens(c, mu, th, ph, pt, alpha=0.05, beta=0.02, wbeta=2.0)
    assert not np.any(np.isnan(np.asarray(m1)))
    np.testing.assert_allclose(np.asarray(jnp.sum(m1, -1)), 1.0, atol=1e-4)
    assert np.all(np.asarray(r1) >= 0)
