"""Adaptive sweep dispatch (ISSUE 5): formulation parity (packed /
dense-layout / carry megakernel / oracle) over the FULL selective
iteration, CommMeter byte invariance across policies, and compile-count
staticness of the trace-time dispatch.  Hypothesis coverage lives in
test_sweep_policy_properties.py; this file runs without hypothesis."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, MiniBatch, make_sim_minibatch_fn
from repro.core import power as pw
from repro.core.pobp import (_selective_sweep_carry_pallas,
                             _selective_sweep_dense_layout,
                             _selective_sweep_packed, init_train_state,
                             make_train_step, selective_sweep_tokens)
from repro.core.residuals import token_scatter_wk, token_topic_segment_sum
from repro.core.sweep_dispatch import (DEFAULT_COEFFS, dense_layout_cost,
                                       packed_cost, resolve_sweep_policy)
from repro.core.sync import LocalReducer
from repro.kernels.power_sweep.ops import power_sweep_carry
from repro.kernels.power_sweep.ref import power_sweep_carry_ref


def _iteration_state(key, cfg, D=10, L=16, live_w=None):
    """Random mid-loop state honoring the invariants the sweeps assume
    (theta == einsum(c, mu); batch words < live_w when capacity-laddered)."""
    ks = jax.random.split(key, 4)
    hi = cfg.vocab_size if live_w is None else live_w
    wid = jax.random.randint(ks[0], (D, L), 0, hi).astype(jnp.int32)
    cnt = jax.random.randint(ks[1], (D, L), 0, 3).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    mu = jax.nn.softmax(jax.random.normal(ks[2], (D, L, cfg.num_topics)), -1)
    theta = jnp.einsum("dl,dlk->dk", cnt, mu)
    phi = token_scatter_wk(wid, cnt[..., None] * mu, cfg.vocab_size)
    if live_w is not None:
        # guard rows [live_w, W) stay exactly zero (DESIGN.md §12)
        phi = jnp.where(jnp.arange(cfg.vocab_size)[:, None] < live_w, phi,
                        0.0)
    return batch, mu, theta, phi, jnp.sum(phi, 0)


def _selection(key, cfg, P, Pk, live_w=None):
    r = jax.random.uniform(key, (cfg.vocab_size, cfg.num_topics))
    r_w = jnp.sum(r, 1)
    if live_w is None:
        sel_w = pw.select_power_words(r_w, P)
    else:
        sel_w = pw.select_power_words_live(r_w, P, live_w, cfg.lambda_w)
    return sel_w, pw.select_power_topics(r, sel_w, Pk)


def _run_all_formulations(cfg, batch, mu, theta, phi, phi_tot, sel_w, sel_k,
                          wbeta=None):
    lay = batch.token_layout()
    mu_t = mu.reshape(-1, cfg.num_topics)
    outs = {}
    for name, fn in (("packed", _selective_sweep_packed),
                     ("dense_layout", _selective_sweep_dense_layout),
                     ("carry_kernel", _selective_sweep_carry_pallas)):
        outs[name] = fn(lay, mu_t, theta, phi, phi_tot, sel_w, sel_k, cfg,
                        wbeta=wbeta)
    return outs


@pytest.mark.parametrize("live_w", [None, 23])
def test_formulation_parity_full_iteration(live_w):
    """mu, theta and the packed delta/residual agree across the packed,
    dense-layout and carry-megakernel formulations — including live-W
    guard rows (dead selection slots transmit exact zeros)."""
    cfg = LDAConfig(vocab_size=40, num_topics=12, lambda_w=0.2,
                    lambda_k_abs=5)
    P, Pk = cfg.num_power_words, cfg.num_power_topics
    batch, mu, theta, phi, phi_tot = _iteration_state(
        jax.random.PRNGKey(0), cfg, live_w=live_w)
    sel_w, sel_k = _selection(jax.random.PRNGKey(1), cfg, P, Pk,
                              live_w=live_w)
    wbeta = None if live_w is None else jnp.float32(live_w * cfg.beta)
    outs = _run_all_formulations(cfg, batch, mu, theta, phi, phi_tot,
                                 sel_w, sel_k, wbeta=wbeta)
    ref = outs.pop("packed")
    for name, got in outs.items():
        for a, b, what in zip(ref, got, ("mu", "theta", "d_pack", "r_pack")):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-5,
                err_msg=f"{name}/{what}")
    # the O(T*Pk) segment-sum theta oracle: every formulation's theta move
    # must equal the per-token selected deltas scattered at (doc, topic)
    lay = batch.token_layout()
    p_tok = pw.token_power_rows(lay.word_ids, sel_w, cfg.vocab_size)
    k_tok = jnp.take(sel_k, jnp.where(p_tok < P, p_tok, 0), axis=0)
    mu_t = mu.reshape(-1, cfg.num_topics)
    d_sel = jnp.take_along_axis(ref[0] - mu_t, k_tok, axis=1)
    want_dtheta = token_topic_segment_sum(lay.doc_ids, k_tok,
                                          lay.counts * d_sel,
                                          lay.num_docs, cfg.num_topics)
    np.testing.assert_allclose(np.asarray(ref[1] - theta),
                               np.asarray(want_dtheta), rtol=2e-5,
                               atol=1e-5)
    if live_w is not None:
        # dead selection slots (sel_w rows pointing at the guard row)
        # carry exactly zero packed payload in every formulation
        dead = np.asarray(sel_w) == live_w
        assert dead.any()
        for name, got in {"packed": ref, **outs}.items():
            np.testing.assert_array_equal(
                np.asarray(got[2])[dead], 0.0, err_msg=name)
            np.testing.assert_array_equal(
                np.asarray(got[3])[dead], 0.0, err_msg=name)


def test_carry_kernel_matches_oracle():
    """ops.power_sweep_carry (padding included) vs the pure-jnp oracle,
    both kernel modes."""
    rng = np.random.default_rng(7)
    T, K, P, D = 50, 12, 8, 6
    p_tok = jnp.asarray(rng.integers(0, P + 1, T).astype(np.int32))
    doc_ids = jnp.asarray(rng.integers(0, D, T).astype(np.int32))
    c = jnp.asarray(rng.integers(0, 4, (T, 1)).astype(np.float32))
    mu = jax.nn.softmax(jnp.asarray(rng.normal(size=(T, K)),
                                    dtype=jnp.float32), -1)
    theta = jnp.asarray(rng.uniform(0, 5, (D, K)).astype(np.float32))
    phi_tot = jnp.asarray(rng.uniform(1, 9, (K,)).astype(np.float32))
    mask = jnp.asarray((rng.uniform(size=(P + 1, K)) < 0.4)
                       .astype(np.float32)).at[P].set(0.0)
    phi_rows = (jnp.asarray(rng.uniform(0, 5, (P + 1, K))
                            .astype(np.float32)) * mask)
    for update_phi in (True, False):
        kw = dict(alpha=0.1, beta=0.01 if update_phi else 0.0,
                  wbeta=0.4 if update_phi else 1.0, update_phi=update_phi)
        pt = phi_tot if update_phi else jnp.zeros_like(phi_tot)
        got = power_sweep_carry(p_tok, doc_ids, c, mu, theta, pt,
                                phi_rows, mask, **kw)
        want = power_sweep_carry_ref(p_tok, doc_ids, c, mu, theta, pt,
                                     phi_rows, mask, **kw)
        if not update_phi:
            # mode-dead packed outputs come back truncated, not computed
            assert got[2].shape == (0, K) and got[3].shape == (0, K)
            got, want = (got[0], got[1], got[4]), (want[0], want[1], want[4])
            names = ("mu", "theta_delta", "rdoc")
        else:
            names = ("mu", "theta_delta", "d_rows", "r_rows", "rdoc")
        for g, w, what in zip(got, want, names):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{update_phi}/{what}")


def test_segment_sum_theta_oracle():
    """token_topic_segment_sum == the dense-delta theta contraction."""
    rng = np.random.default_rng(3)
    T, Pk, D, K = 64, 4, 5, 10
    doc_ids = jnp.asarray(rng.integers(0, D, T).astype(np.int32))
    k_tok = jnp.asarray(rng.integers(0, K, (T, Pk)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(T, Pk)).astype(np.float32))
    got = token_topic_segment_sum(doc_ids, k_tok, vals, D, K)
    want = np.zeros((D, K), np.float32)
    for t in range(T):
        for j in range(Pk):
            want[int(doc_ids[t]), int(k_tok[t, j])] += float(vals[t, j])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_comm_bytes_invariant_across_policies():
    """Eq. 6 sync bytes are identical whichever formulation computes the
    packed buffers (the acceptance pin: compute layout never changes the
    communication bill)."""
    W, K = 60, 16
    wid = jax.random.randint(jax.random.PRNGKey(5), (12, 14), 0, W)
    cnt = jax.random.randint(jax.random.PRNGKey(6), (12, 14), 0, 3)
    bytes_by_policy, mean_r = {}, {}
    for policy in ("packed", "dense_layout"):
        cfg = LDAConfig(vocab_size=W, num_topics=K, lambda_w=0.2,
                        lambda_k_abs=4, inner_iters=6, residual_tol=1e-9,
                        sweep_policy=policy)
        fn, meter = make_sim_minibatch_fn(cfg, 2, "power")
        out = fn(wid.reshape(2, 6, 14).astype(jnp.int32),
                 cnt.reshape(2, 6, 14).astype(jnp.float32),
                 jnp.zeros((W, K)), jax.random.PRNGKey(1), jnp.float32(1.0))
        jax.block_until_ready(out[0])
        bytes_by_policy[policy] = dict(meter.bytes_by_phase)
        mean_r[policy] = float(np.asarray(out[2]).reshape(-1)[0])
    assert bytes_by_policy["packed"] == bytes_by_policy["dense_layout"]
    assert abs(mean_r["packed"] - mean_r["dense_layout"]) <= 1e-6


def test_dispatch_is_static_no_retrace():
    """The trace-time policy resolution never retraces across mini-batches
    of the same shape: one compile however many batches run, and the
    resolver is deterministic per shape within a process."""
    cfg = LDAConfig(vocab_size=50, num_topics=8, lambda_w=0.2,
                    lambda_k_abs=4, inner_iters=4, residual_tol=1e-9,
                    sweep_policy="auto")
    step, _ = make_train_step(cfg, num_shards=1)
    state = init_train_state(cfg, seed=0)
    key = jax.random.PRNGKey(3)
    for m in range(4):
        k1, k2, key = jax.random.split(key, 3)
        wid = jax.random.randint(k1, (6, 12), 0, cfg.vocab_size)
        cnt = jax.random.randint(k2, (6, 12), 0, 3).astype(jnp.float32)
        state, _ = step(state, wid.astype(jnp.int32), cnt)
    assert step._cache_size() == 1
    first = resolve_sweep_policy(cfg, 6 * 12, 8, 4, 10)
    for _ in range(5):
        assert resolve_sweep_policy(cfg, 6 * 12, 8, 4, 10) == first


def test_resolve_policy_contract():
    cfg = LDAConfig(vocab_size=50, num_topics=8, sweep_policy="packed")
    assert resolve_sweep_policy(cfg, 1000, 8, 4, 5) == "packed"
    cfg = dataclasses.replace(cfg, sweep_policy="dense_layout")
    assert resolve_sweep_policy(cfg, 1000, 8, 4, 5) == "dense_layout"
    cfg = dataclasses.replace(cfg, sweep_policy="auto", impl="pallas")
    # the pallas backend's auto resolution is the carry megakernel
    assert resolve_sweep_policy(cfg, 1000, 8, 4, 5) == "dense_layout"
    cfg = dataclasses.replace(cfg, sweep_policy="bogus")
    with pytest.raises(ValueError):
        resolve_sweep_policy(cfg, 1000, 8, 4, 5)


def test_cost_model_prefers_packed_at_small_pk():
    """Whatever the measured rates, the analytic model must keep the
    asymptotics: the chain term makes packed lose as Pk -> K and win as
    Pk -> 1 (evaluated on the committed fallback coefficients so the test
    is machine-independent)."""
    c = DEFAULT_COEFFS
    T, K, P = 17280, 64, 40
    assert (packed_cost(T, K, 2, P, 8_000_000, c)
            < dense_layout_cost(T, K, 2, P, c))
    assert (packed_cost(T, K, K, P, 8_000_000, c)
            > dense_layout_cost(T, K, K, P, c))


def test_policy_dispatch_equivalence_end_to_end():
    """pobp_minibatch trajectories agree across forced policies (the
    dispatcher can pick either without changing results)."""
    W, K = 60, 16
    wid = jax.random.randint(jax.random.PRNGKey(8), (10, 14), 0, W)
    cnt = jax.random.randint(jax.random.PRNGKey(9), (10, 14), 0, 3)
    outs = {}
    for policy in ("packed", "dense_layout"):
        cfg = LDAConfig(vocab_size=W, num_topics=K, lambda_w=0.2,
                        lambda_k_abs=6, inner_iters=6, residual_tol=1e-9,
                        sweep_policy=policy)
        fn, _ = make_sim_minibatch_fn(cfg, 1, "power")
        outs[policy] = fn(wid.astype(jnp.int32), cnt.astype(jnp.float32),
                          jnp.zeros((W, K)), jax.random.PRNGKey(1),
                          jnp.float32(1.0))
    assert int(outs["packed"][1]) == int(outs["dense_layout"][1])
    for a, b in zip(outs["packed"], outs["dense_layout"]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=1e-5)
