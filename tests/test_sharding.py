"""Sharding policy unit tests (no multi-device runtime needed: specs are
pure metadata) + data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import param_specs, spec_for, validate_specs
from repro.models import registry


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def _specs_for(arch):
    cfg = get_config(arch).reduced() if False else get_config(arch)
    mod = registry.build(cfg)
    params_s = jax.eval_shape(lambda k: mod.init(k, cfg),
                              jax.random.PRNGKey(0))
    return params_s, param_specs(params_s)


def test_dense_arch_specs():
    params_s, specs = _specs_for("granite-3-2b")
    assert specs["embed"] == P("model", "data")
    # scanned stack: leading layer dim unsharded
    assert specs["stack"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["stack"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["stack"]["mlp"]["wi"] == P(None, "data", "model")
    assert specs["stack"]["ln1"]["w"] == P(None, None)


def test_moe_arch_specs_expert_parallel():
    params_s, specs = _specs_for("olmoe-1b-7b")
    # experts sharded over the model axis (EP), d_model FSDP
    assert specs["stack"]["moe"]["wi"] == P(None, "model", "data", None)
    assert specs["stack"]["moe"]["wo"] == P(None, "model", None, "data")
    assert specs["stack"]["moe"]["wr"] == P(None, "data", None)


def test_mla_specs():
    params_s, specs = _specs_for("deepseek-v2-lite-16b")
    st = specs["stack"]["attn"]
    assert st["wdkv"] == P(None, "data", None)
    assert st["wuk"] == P(None, None, "model")


def test_validate_drops_nondivisible_axes():
    specs = {"w": P("data", "model")}
    tree = {"w": jax.ShapeDtypeStruct((17, 32), jnp.float32)}
    fixed = validate_specs(specs, tree, FakeMesh())
    assert fixed["w"] == P(None, "model")   # 17 % 16 != 0 -> dropped
    tree2 = {"w": jax.ShapeDtypeStruct((32, 32), jnp.float32)}
    assert validate_specs(specs, tree2, FakeMesh())["w"] == P("data", "model")


def test_every_arch_every_param_divisible_after_validation():
    """After validation, every still-sharded dim divides the axis size —
    i.e., the dry-run can never hit the pjit divisibility error."""
    mesh = FakeMesh()
    for arch in ("granite-3-2b", "qwen2-72b", "mamba2-780m", "zamba2-2.7b",
                 "seamless-m4t-medium"):
        params_s, specs = _specs_for(arch)
        fixed = validate_specs(specs, params_s, mesh)

        def check(path, spec, leaf):
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert leaf.shape[i] % size == 0, (arch, path, spec,
                                                   leaf.shape)

        jax.tree_util.tree_map_with_path(
            lambda p, s, l: check(p, s, l), fixed, params_s)


def test_vocab_padding_divisible():
    for arch in ("granite-3-2b", "mamba2-780m", "olmoe-1b-7b",
                 "seamless-m4t-medium"):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_lm_data_deterministic_cursor():
    from repro.data.lm_data import batch_at
    a = batch_at(0, 7, 4, 16, 100)
    b = batch_at(0, 7, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = batch_at(0, 8, 4, 16, 100)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
