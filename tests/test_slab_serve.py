"""Continuous-batching slab serving (DESIGN.md §16): in-flight admission,
theta parity with run-to-convergence, per-retired-doc byte billing,
hot-swap fencing under queued load, and the per-tenant theta cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import LDAConfig
from repro.data.batching import slab_refill, truncate_doc
from repro.data.synthetic import lda_corpus
from repro.serve import OOVTrigger, SlabEngine, ThetaCache, doc_digest

W, K = 200, 16
CFG = LDAConfig(vocab_size=W, num_topics=K, alpha=0.1, beta=0.01)


@pytest.fixture(scope="module")
def trained():
    docs, _, phi_true = lda_corpus(0, 64, W, K, doc_len_mean=30)
    # converged stand-in statistic: the true topics at plausible counts
    phi_acc = jnp.asarray(phi_true.T) * 200.0
    return docs, phi_acc


def _mixed_docs(seed, n, w_hi=W, lo=4, hi=90):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        L = int(rng.integers(lo, hi))
        ids = rng.choice(w_hi, size=min(L, w_hi), replace=False)
        cnt = np.maximum(rng.poisson(1.5, len(ids)), 1)
        out.append((ids.astype(np.int32), cnt.astype(np.float32)))
    return out


# ------------------------------------------------------------- host side


def test_slab_refill_packs_truncates_and_pads():
    docs = [(np.arange(3, dtype=np.int32), np.ones(3, np.float32)),
            (np.arange(10, dtype=np.int32),
             np.arange(10, dtype=np.float32))]
    wid, cnt, slot, taken = slab_refill(docs, [5, 2], capacity=4,
                                        slot_len=8, pad_slot=16)
    assert wid.shape == (4, 8) and cnt.shape == (4, 8)
    assert taken == 2
    assert slot.tolist() == [5, 2, 16, 16]      # unused lanes -> pad_slot
    assert cnt[0, :3].tolist() == [1, 1, 1] and cnt[0, 3:].sum() == 0
    # over-long doc keeps its top-count 8 of 10 tokens
    keep_ids, keep_cnt = truncate_doc(docs[1][0], docs[1][1], 8)
    assert sorted(keep_ids.tolist()) == sorted(wid[1].tolist())
    assert cnt[1].sum() == keep_cnt.sum() == float(np.arange(2, 10).sum())


def test_oov_trigger_emits_hot_batches():
    tr = OOVTrigger(rate_threshold=0.1, min_docs=3, batch_keys=2)
    tr.observe([900, 901], [5.0, 1.0], 10.0)
    tr.observe([900], [4.0], 10.0)
    assert tr.emitted == 0                      # min_docs not reached
    tr.observe([], [], 10.0)
    assert tr.emitted == 1                      # 10/30 tokens OOV >= 0.1
    (batch,) = tr.take()
    keys, cnts = batch[0]
    assert keys.tolist() == [900, 901]          # hottest first, capped at 2
    assert cnts.tolist() == [9.0, 1.0]
    assert tr.take() == []                      # window reset
    tr.observe([5], [0.1], 100.0)
    tr.observe([5], [0.1], 100.0)
    tr.observe([5], [0.1], 100.0)
    assert tr.emitted == 1                      # under threshold: no emit


# ------------------------------------------------------- the slab engine


def test_slab_serves_all_with_one_compile_and_refill(trained):
    """More documents than slots: retirement/refill keeps ONE compiled
    step while every request is served with a normalized theta."""
    _, phi_acc = trained
    docs = _mixed_docs(3, 40)
    eng = SlabEngine(phi_acc, CFG, slots=8, slot_len=96, seed=1)
    ids = [eng.submit(d) for d in docs]
    res = eng.drain()
    assert sorted(r.req_id for r in res) == sorted(ids)
    th = np.stack([r.theta for r in res])
    np.testing.assert_allclose(th.sum(axis=1), 1.0, atol=1e-4)
    s = eng.stats()
    assert s["compiles"] == 1
    assert s["served"] == len(docs)
    assert 0 < s["slot_occupancy"] <= 1.0
    assert s["steps"] > len(docs) // 8          # refilled mid-flight


def test_slab_truncates_overlong_documents(trained):
    _, phi_acc = trained
    eng = SlabEngine(phi_acc, CFG, slots=4, slot_len=16, seed=1)
    long_doc = (np.arange(64, dtype=np.int32),
                np.linspace(1, 4, 64).astype(np.float32))
    eng.submit(long_doc)
    (r,) = eng.drain()
    assert r.iters > 0 and abs(float(np.sum(r.theta)) - 1.0) < 1e-4


def test_slab_theta_within_tol_of_run_to_convergence(trained):
    """The §16 serving guarantee, pinned: a slot that retires on the
    geometric-tail residual bound serves a theta within residual_tol
    (per-doc L1) of folding the same document to convergence."""
    _, phi_acc = trained
    docs, _, _ = lda_corpus(7, 6, W, K, doc_len_mean=30)
    tol = 2e-2
    kw = dict(slots=8, slot_len=64, fold_iters=100, seed=5)
    early = SlabEngine(phi_acc, CFG, residual_tol=tol, **kw)
    full = SlabEngine(phi_acc, CFG, residual_tol=1e-9, **kw)
    for d in docs:                 # <= slots docs: identical per-step keys
        early.submit(d)
        full.submit(d)
    re = {r.req_id: r for r in early.drain()}
    rf = {r.req_id: r for r in full.drain()}
    for rid in re:
        assert re[rid].iters < rf[rid].iters
        l1 = float(np.abs(re[rid].theta - rf[rid].theta).sum())
        assert l1 <= tol, (rid, l1)


def test_slab_swap_under_queued_load_versions_and_no_torn_phi(trained):
    """Satellite: swap_phi with requests queued AND in flight.  Every
    pre-swap request retires under the admitting generation's stamp and
    phi; post-swap submissions carry the new stamp.  No request is lost
    or served twice."""
    _, phi_acc = trained
    docs = _mixed_docs(11, 24)
    eng = SlabEngine(phi_acc, CFG, slots=4, slot_len=96, seed=2)
    pre = [eng.submit(d) for d in docs[:16]]
    eng.step()                       # some in flight, some still queued
    eng.step()
    assert eng.in_flight() > 0
    phi2 = np.asarray(phi_acc) * 0.5 + 1.0
    eng.swap_phi(phi2)
    assert eng.in_flight() == 0      # fence: pumped dry before install
    post = [eng.submit(d) for d in docs[16:]]
    res = {r.req_id: r for r in eng.drain() + eng.poll()}
    assert sorted(res) == sorted(pre + post)
    assert all(res[i].phi_version == 0 for i in pre)
    assert all(res[i].phi_version == 1 for i in post)
    # same-capacity swap reuses the compiled step
    assert eng.stats()["compiles"] == 1


def test_slab_sharded_billing_per_retired_document(trained):
    """Satellite: requests share a slab step, so sync bytes are billed
    per retired document (its own iteration count), not per batch —
    and the sharded slab serves the same theta as the unsharded one."""
    _, phi_acc = trained
    docs, _, _ = lda_corpus(9, 6, W, K, doc_len_mean=25)
    kw = dict(slots=8, slot_len=48, fold_iters=60, residual_tol=1e-2,
              seed=3)
    solo = SlabEngine(phi_acc, CFG, **kw)
    shard = SlabEngine(phi_acc, CFG, topic_shards=4, **kw)
    for d in docs:
        solo.submit(d)
        shard.submit(d)
    rs = {r.req_id: r for r in solo.drain()}
    rh = {r.req_id: r for r in shard.drain()}
    for rid in rs:
        np.testing.assert_allclose(rs[rid].theta, rh[rid].theta,
                                   atol=1e-5)
        assert rs[rid].comm_bytes == 0.0          # local reducer: no wire
        assert rh[rid].comm_bytes > 0.0
    # per-document bills scale with the document's OWN iters
    by_iters = sorted((r.iters, r.comm_bytes) for r in rh.values())
    for (i1, b1), (i2, b2) in zip(by_iters, by_iters[1:]):
        if i2 > i1:
            assert b2 > b1
    # totals reconcile: stats' per-request mean matches the results
    s = shard.stats()
    total = sum(r.comm_bytes for r in rh.values())
    assert s["per_request_bytes"] == pytest.approx(total / len(rh))


# ------------------------------------------------------------ theta cache


def test_theta_cache_hit_matches_fold_in_and_version_invalidates(trained):
    """Satellite: a cache hit returns the exact theta the fold-in
    produced; a phi_version bump turns hits into misses (no stale theta
    is ever served across a swap)."""
    _, phi_acc = trained
    doc = _mixed_docs(21, 1)[0]
    eng = SlabEngine(phi_acc, CFG, slots=4, slot_len=96, seed=4,
                     theta_cache=8)
    eng.submit(doc, tenant="a")
    (cold,) = eng.drain()
    assert not cold.cached
    eng.submit(doc, tenant="a")
    (hit,) = eng.drain()
    assert hit.cached and hit.iters == 0
    np.testing.assert_array_equal(hit.theta, cold.theta)
    # another tenant's identical content is a separate key
    eng.submit(doc, tenant="b")
    (other,) = eng.drain()
    assert not other.cached
    # swap invalidates: same submission re-folds under the new phi
    eng.swap_phi(np.asarray(phi_acc)[:, ::-1].copy())
    eng.submit(doc, tenant="a")
    (after,) = eng.drain()
    assert not after.cached and after.phi_version == 1
    assert float(np.abs(after.theta - cold.theta).sum()) > 1e-3
    st = eng.cache.stats()
    assert st["stale_evictions"] >= 1


def test_theta_cache_warm_mode_fewer_sweeps_within_tol(trained):
    """Satellite: warm mode still folds in (fresh phi-consistent theta)
    but restarts from the cached posterior — fewer sweeps, same answer
    within the residual tolerance."""
    _, phi_acc = trained
    docs, _, _ = lda_corpus(13, 4, W, K, doc_len_mean=30)
    tol = 1e-2
    eng = SlabEngine(phi_acc, CFG, slots=4, slot_len=64, seed=6,
                     residual_tol=tol, fold_iters=100,
                     theta_cache=ThetaCache(16), cache_mode="warm")
    for d in docs:
        eng.submit(d)
    cold = {r.req_id: r for r in eng.drain()}
    ids = {}
    for d in docs:
        ids[eng.submit(d)] = d
    warm = {r.req_id: r for r in eng.drain()}
    cold_list = sorted(cold.values(), key=lambda r: r.req_id)
    warm_list = sorted(warm.values(), key=lambda r: r.req_id)
    assert all(not r.cached for r in warm_list)   # warm mode still folds
    for c, w in zip(cold_list, warm_list):
        assert w.iters <= c.iters
        assert float(np.abs(w.theta - c.theta).sum()) <= 2 * tol
    s = eng.stats()
    assert s["warm_starts"] == len(docs)
    assert s["warm_fold_iters"] < s["cold_fold_iters"]


def test_doc_digest_is_content_keyed():
    a = (np.array([1, 2, 3]), np.array([1.0, 2.0, 1.0]))
    assert doc_digest(*a) == doc_digest(np.array([1, 2, 3]),
                                        np.array([1.0, 2.0, 1.0]))
    assert doc_digest(*a) != doc_digest(np.array([1, 2, 4]),
                                        np.array([1.0, 2.0, 1.0]))
    assert doc_digest(*a) != doc_digest(np.array([1, 2, 3]),
                                        np.array([1.0, 2.0, 2.0]))


# ---------------------------------------------------- serve -> train loop


def test_slab_oov_admission_feeds_retrain_batches(trained):
    """OOV tokens route through the guard row (finite theta, counted in
    oov_rate) and the trigger turns sustained OOV pressure into
    admission batches of raw external keys."""
    _, phi_acc = trained
    eng = SlabEngine(phi_acc, CFG, slots=4, slot_len=32, seed=8,
                     oov_trigger=OOVTrigger(rate_threshold=0.05,
                                            min_docs=2, batch_keys=4))
    hot = np.array([W + 7, W + 9], np.int32)
    for _ in range(4):
        eng.submit((np.concatenate([hot, np.arange(5, dtype=np.int32)]),
                    np.ones(7, np.float32)))
    res = eng.drain()
    assert all(r.oov_tokens == 2.0 for r in res)
    assert all(np.isfinite(r.theta).all() for r in res)
    assert eng.stats()["oov_rate"] == pytest.approx(2 / 7)
    batches = eng.take_retrain_batches()
    assert batches and eng.stats()["retrain_batches"] >= 1
    keys, cnts = batches[0][0]
    assert set(keys.tolist()) == {W + 7, W + 9}


# ------------------------------------------------------------ CLI report


def test_serve_cli_slab_report_json(tmp_path, trained):
    """Satellite: --report-json writes the latency/goodput/oov report;
    the slab path with open-loop load, swap and SLO check end-to-end."""
    import json

    from repro.dist import checkpoint as ckpt
    from repro.launch import serve as serve_mod

    _, phi_acc = trained
    ckpt.save(str(tmp_path), 1,
              {"state": {"phi_acc": phi_acc,
                         "m": jnp.asarray(1, jnp.int32),
                         "rng": jax.random.PRNGKey(0)}},
              extra={"next_m": 1, "run": {"vocab": W, "topics": K}})
    rep = tmp_path / "report.json"
    serve_mod.main(["--mode", "lda", "--ckpt-dir", str(tmp_path),
                    "--requests", "24", "--slots", "8",
                    "--qps", "400", "--swap-at", "0.5",
                    "--slo-ms", "5000", "--theta-cache", "16",
                    "--report-json", str(rep)])
    r = json.loads(rep.read_text())
    assert r["admission"] == "slab"
    assert r["requests"] == 24
    assert r["slo_met"] is True
    assert r["stats"]["served"] == 24
    assert r["stats"]["phi_version"] == 1
    assert r["goodput_docs_per_s"] > 0
