"""Dynamic-vocabulary runtime (ISSUE 4): VocabMap determinism, the W
capacity ladder, live-W-masked POBP parity, growth-parity of the driver
(grown-across-rungs == fresh-at-final-rung), crash-resume across a growth
event, elastic W-reshard on restore, live-W byte accounting, and OOV
serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LDAConfig, grow_state, init_train_state,
                        make_train_step, perplexity)
from repro.data import docs_to_padded, lda_corpus
from repro.data.vocab import VocabMap, next_capacity

W, K = 120, 8


# ------------------------------------------------------------- vocab layer

def test_vocab_map_append_only_and_roundtrip():
    v = VocabMap()
    assert v.admit("cat") == 0 and v.admit("dog") == 1
    assert v.admit("cat") == 0                      # re-admission is a no-op
    assert v.lookup("dog") == 1 and v.lookup("fox") is None
    rows = v.rows(["dog", "fox", "cat"], admit=True)
    np.testing.assert_array_equal(rows, [1, 2, 0])  # first-seen order
    assert v.keys_upto(2) == ["cat", "dog"]
    again = VocabMap.from_state(v.to_state())
    assert again.lookup("fox") == 2 and len(again) == 3
    # lookup-only mode routes unknowns to the oov row, vocabulary frozen
    np.testing.assert_array_equal(
        again.rows(["cat", "wolf"], admit=False, oov_row=99), [0, 99])
    assert len(again) == 3
    with pytest.raises(ValueError):
        VocabMap(["a", "a"])


def test_vocab_map_deterministic_across_runs():
    """Two consumers of the same doc sequence build identical maps — the
    property growth parity and crash-resume replay stand on."""
    docs, _, _ = lda_corpus(0, 16, W, K, doc_len_mean=30)
    ext = [(ids + 1000, cnt) for ids, cnt in docs]   # external-id space
    a, b = VocabMap(), VocabMap()
    mapped_a = a.map_docs(ext)
    mapped_b = b.map_docs(ext)
    assert a.to_state() == b.to_state()
    for (ia, ca), (ib, cb) in zip(mapped_a, mapped_b):
        np.testing.assert_array_equal(ia, ib)


def test_next_capacity_ladder():
    assert next_capacity(0) == 64
    assert next_capacity(63) == 64
    assert next_capacity(64) == 128            # strictly greater: guard row
    assert next_capacity(64, current_cap=64) == 128
    assert next_capacity(500, current_cap=128) == 512
    assert next_capacity(10, min_cap=20, multiple=8) == 24
    with pytest.raises(ValueError):
        next_capacity(10, growth=1.0)


# -------------------------------------------------- live-W core semantics

@pytest.fixture(scope="module")
def corpus_batch():
    docs, _, _ = lda_corpus(0, 32, W, K, doc_len_mean=30)
    return docs_to_padded(docs)


@pytest.mark.parametrize("sync_mode", ["power", "dense"])
def test_live_w_step_matches_fixed_w_step(corpus_batch, sync_mode):
    """A capacity-laddered step (W_cap > live) with live_w == W must agree
    with the legacy fixed-W step on the live rows, leave guard rows at
    exactly zero, and report the same mean_r (lambda_w chosen so the
    legacy round() and the live floor() power-word counts coincide)."""
    b = corpus_batch
    kw = dict(num_topics=K, lambda_w=0.25, lambda_k_abs=4, inner_iters=6,
              residual_tol=1e-9)
    cfg_fix = LDAConfig(vocab_size=W, **kw)
    cfg_dyn = LDAConfig(vocab_size=next_capacity(W), **kw)
    step_f, _ = make_train_step(cfg_fix, 1, sync_mode, donate=False)
    step_d, _ = make_train_step(cfg_dyn, 1, sync_mode, donate=False)
    s_f, d_f = step_f(init_train_state(cfg_fix, 0), b.word_ids, b.counts)
    s_d, d_d = step_d(init_train_state(cfg_dyn, 0), b.word_ids, b.counts,
                      jnp.asarray(W, jnp.int32))
    assert int(d_f["iters"]) == int(d_d["iters"])
    np.testing.assert_allclose(float(d_f["mean_r"]), float(d_d["mean_r"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s_d.phi_acc[:W]),
                               np.asarray(s_f.phi_acc), rtol=1e-5, atol=1e-6)
    assert float(jnp.abs(s_d.phi_acc[W:]).max()) == 0.0


def test_grow_state_pads_guard_rows(corpus_batch):
    cfg = LDAConfig(vocab_size=64, num_topics=K)
    s = init_train_state(cfg, 0)
    g = grow_state(s, 128)
    assert g.phi_acc.shape == (128, K)
    assert int(g.m) == int(s.m)
    np.testing.assert_array_equal(np.asarray(g.rng), np.asarray(s.rng))
    assert grow_state(g, 128) is g                 # same rung: no-op
    with pytest.raises(ValueError):
        grow_state(g, 64)                          # no eviction/compaction


def test_normalize_phi_live_masks_guard_rows():
    """Guard rows get the beta-prior mass and stay out of the denominator;
    live_w == W reduces to the legacy formula exactly."""
    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.gamma(1.0, size=(20, 4)).astype(np.float32))
    beta = 0.01
    legacy = perplexity.normalize_phi(phi, beta)
    full = perplexity.normalize_phi(phi, beta, live_w=20)
    np.testing.assert_allclose(np.asarray(full), np.asarray(legacy),
                               rtol=1e-6)
    live = 12
    masked = perplexity.normalize_phi(phi, beta, live_w=live)
    denom = np.asarray(phi[:live] + beta).sum(axis=0)
    np.testing.assert_allclose(np.asarray(masked[:live]),
                               np.asarray(phi[:live] + beta) / denom,
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(masked[live:]),
        np.broadcast_to(beta / denom, (phi.shape[0] - live, phi.shape[1])),
        rtol=1e-5)
    # live columns normalize to 1 over the live vocabulary
    np.testing.assert_allclose(np.asarray(masked[:live]).sum(axis=0), 1.0,
                               atol=1e-5)


def test_comm_meter_bills_live_w(corpus_batch):
    """W-proportional payloads scale to live W in the live accounting —
    per-minibatch sync bytes follow the vocabulary, not the rung."""
    b = corpus_batch
    cap = 512
    cfg = LDAConfig(vocab_size=cap, num_topics=K, lambda_w=0.25,
                    lambda_k_abs=4, inner_iters=6, residual_tol=1e-9)
    step, meter = make_train_step(cfg, 2, donate=False)
    D, L = b.word_ids.shape
    wid = b.word_ids.reshape(2, D // 2, L)
    cnt = b.counts.reshape(2, D // 2, L)
    _, diag = step(init_train_state(cfg, 0), wid, cnt,
                   jnp.asarray(W, jnp.int32))
    by_cap = meter.bytes_by_phase
    by_live = meter.bytes_by_phase_at(W)
    # dense phase (full phi + full r) scales exactly with live rows
    assert by_live["dense"] == by_cap["dense"] * W // cap
    # packed power buffers scale with W through P = lambda_w * W
    assert by_live["power"] == by_cap["power"] * W // cap
    # scalar token-count psum is W-independent
    assert by_live["tokens"] == by_cap["tokens"]
    iters = int(diag["iters"])
    assert meter.per_minibatch_bytes(iters, live_w=W) < \
        meter.per_minibatch_bytes(iters)


# ------------------------------------------------------- driver + parity

def _dyn_args(**over):
    from repro.launch.lda_train import default_args
    base = dict(dynamic_vocab=True, minibatches=6, docs_per_batch=16,
                shards=2, vocab=48, vocab_growth_per_batch=24, w_cap_min=64,
                w_growth=2.0, topics=K, lambda_k=4, inner_iters=4, tol=1e-9,
                log_every=0, eval_every=0, len_buckets="16,32",
                doc_len_means="10,20,30", seed=3)
    base.update(over)
    return default_args(**base)


@pytest.fixture(scope="module")
def grown_run():
    from repro.launch.lda_train import train_loop
    return train_loop(_dyn_args())


def test_growth_parity_with_fresh_run_at_final_rung(grown_run):
    """ACCEPTANCE (ISSUE 4): a stream that grows W across >= 2 ladder
    rungs produces the same mean_r trajectory and per-word phi rows (on
    the shared vocab) as a fresh run started at the final rung — the
    trajectory depends only on live_w, never on the capacity."""
    from repro.launch.lda_train import train_loop

    assert len(grown_run["growth_events"]) >= 2, grown_run["growth_events"]
    fresh = train_loop(_dyn_args(w_cap_min=grown_run["w_cap"]))
    assert fresh["growth_events"] == []
    assert fresh["live_w"] == grown_run["live_w"]
    assert fresh["vocab_keys"] == grown_run["vocab_keys"]
    np.testing.assert_allclose(fresh["mean_r"], grown_run["mean_r"],
                               rtol=1e-6, atol=1e-9)
    lw = grown_run["live_w"]
    np.testing.assert_allclose(fresh["phi_acc"][:lw],
                               grown_run["phi_acc"][:lw],
                               rtol=1e-6, atol=1e-7)
    # everything above live W is guard rows in both runs
    assert np.abs(grown_run["phi_acc"][lw:]).max() == 0.0


def test_crash_resume_across_growth_event(tmp_path, grown_run):
    """ACCEPTANCE (ISSUE 4): a --crash-at rerun spanning a growth event
    reproduces the uninterrupted grown trajectory (vocab table + capacity
    rung + carry all round-trip through the checkpoint-fenced growth)."""
    from repro.launch.lda_train import train_loop

    ckdir = str(tmp_path / "ck")
    # crash after batch 6 of 6: both growth events (m=1, m=4 rungs) and a
    # regular checkpoint (every 2) land before the failure
    with pytest.raises(SystemExit):
        train_loop(_dyn_args(ckpt_dir=ckdir, ckpt_every=2, crash_at=6))
    resumed = train_loop(_dyn_args(ckpt_dir=ckdir, ckpt_every=2, crash_at=6))
    assert resumed["first_m"] > 0
    np.testing.assert_allclose(resumed["mean_r"],
                               grown_run["mean_r"][resumed["first_m"]:],
                               rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(resumed["phi_acc"], grown_run["phi_acc"],
                               rtol=1e-6, atol=1e-7)
    assert resumed["w_cap"] == grown_run["w_cap"]
    assert resumed["vocab_keys"] == grown_run["vocab_keys"]


def test_vocab_mapped_stream_yields_live_snapshots():
    from repro.data import vocab_mapped_minibatch_stream

    docs, _, _ = lda_corpus(1, 24, W, K, doc_len_mean=20)
    ext = [(ids + 7000, cnt) for ids, cnt in docs]
    v = VocabMap()
    lives = []
    for mb, live in vocab_mapped_minibatch_stream(ext, v, 8,
                                                  len_buckets=(16, 32)):
        lives.append(live)
        assert int(mb.word_ids.max()) < live
    assert lives == sorted(lives)                  # monotone admission
    assert lives[-1] == len(v)


# ------------------------------------------------- elastic W-reshard

def test_restore_grows_phi_rows_across_rungs(tmp_path):
    from repro.dist import checkpoint as ckpt

    rng = np.random.default_rng(0)
    phi = rng.normal(size=(64, K)).astype(np.float32)
    state = {"state": {"phi_acc": jnp.asarray(phi),
                       "m": jnp.asarray(5, jnp.int32),
                       "rng": jax.random.PRNGKey(0)}}
    ckpt.save(str(tmp_path), 5, state,
              extra={"next_m": 5, "dyn": {"w_cap": 64, "live_w": 50,
                                          "vocab_keys": list(range(50))}})

    extra, step = ckpt.peek_extra(str(tmp_path))
    assert step == 5 and extra["dyn"]["w_cap"] == 64

    # restore into a larger rung: rows pad with zeros (guard rows)
    tmpl = {"state": {"phi_acc": jnp.zeros((128, K)),
                      "m": jnp.asarray(0, jnp.int32),
                      "rng": jax.random.PRNGKey(0)}}
    trees, _, _ = ckpt.restore_latest(str(tmp_path), tmpl,
                                      grow_rows=("phi_acc",))
    got = np.asarray(trees["state"]["phi_acc"])
    np.testing.assert_array_equal(got[:64], phi)
    assert np.abs(got[64:]).max() == 0.0
    # without the grow marker the strict shape contract still holds
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_latest(str(tmp_path), tmpl)
    # shrinking is never allowed
    small = {"state": {"phi_acc": jnp.zeros((32, K)),
                       "m": jnp.asarray(0, jnp.int32),
                       "rng": jax.random.PRNGKey(0)}}
    with pytest.raises(ValueError):
        ckpt.restore_latest(str(tmp_path), small, grow_rows=("phi_acc",))

    # the single-leaf serving load resizes too
    arr, _, _ = ckpt.restore_phi(str(tmp_path), w_cap=256)
    assert arr.shape == (256, K)
    np.testing.assert_array_equal(np.asarray(arr[:64]), phi)
    with pytest.raises(ValueError, match="shrink"):
        ckpt.restore_phi(str(tmp_path), w_cap=32)


def test_phi_serving_spec_valid_under_growth():
    """The serving spec never shards W, so any capacity rung — including
    the engine's appended +1 guard row (odd W) — resolves cleanly."""
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import phi_serving_spec

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    for rows in (64, 128, 129):                    # 129: capacity + guard
        assert phi_serving_spec(mesh, jnp.zeros((rows, K))) == \
            P(None, "model")


# ------------------------------------------------------------ OOV serving

def test_engine_serves_oov_words_finite_theta(grown_run):
    """ACCEPTANCE (ISSUE 4): a request containing OOV words returns finite
    theta with the OOV rate reported — never an exception."""
    from repro.serve import FoldInEngine

    lw, cap = grown_run["live_w"], grown_run["w_cap"]
    cfg = LDAConfig(vocab_size=cap, num_topics=K)
    eng = FoldInEngine(jnp.asarray(grown_run["phi_acc"]), cfg,
                       len_buckets=(16,), batch_docs=2, fold_iters=8,
                       live_words=lw, warmup=False)
    assert eng._oov_row == lw
    eng.submit((np.asarray([0, 3, lw + 5, cap + 999]),
                np.asarray([1.0, 2.0, 1.0, 1.0], np.float32)))
    eng.submit((np.asarray([1, 2]), np.ones(2, np.float32)))
    res = sorted(eng.drain(), key=lambda r: r.req_id)
    for r in res:
        assert np.all(np.isfinite(r.theta))
        np.testing.assert_allclose(r.theta.sum(), 1.0, atol=1e-5)
    assert res[0].oov_tokens == 2.0 and res[1].oov_tokens == 0.0
    s = eng.stats()
    assert s["live_words"] == lw
    np.testing.assert_allclose(s["oov_rate"], 2.0 / 7.0, rtol=1e-6)
    # a checkpoint fenced before any admission has nothing to serve from:
    # live_words=0 must be rejected loudly, not treated as "all rows live"
    with pytest.raises(ValueError, match="live_words"):
        FoldInEngine(jnp.asarray(grown_run["phi_acc"]), cfg,
                     len_buckets=(16,), live_words=0, warmup=False)


def test_engine_from_dynamic_checkpoint_picks_up_vocab(tmp_path, grown_run):
    """from_checkpoint reads the dyn manifest: capacity geometry from phi,
    live size + vocab table for external-key admission."""
    from repro.dist import checkpoint as ckpt
    from repro.serve import FoldInEngine

    lw = grown_run["live_w"]
    ckpt.save(str(tmp_path), 9,
              {"state": {"phi_acc": jnp.asarray(grown_run["phi_acc"]),
                         "m": jnp.asarray(9, jnp.int32),
                         "rng": jax.random.PRNGKey(0)}},
              extra={"next_m": 9, "run": {"impl": "jnp"},
                     "dyn": {"w_cap": grown_run["w_cap"], "live_w": lw,
                             "vocab_keys": grown_run["vocab_keys"]}})
    eng = FoldInEngine.from_checkpoint(str(tmp_path), len_buckets=(16,),
                                       batch_docs=2, fold_iters=6,
                                       warmup=False)
    assert eng.cfg.vocab_size == grown_run["w_cap"]
    assert eng.live_words == lw and eng._vocab is not None
    known = grown_run["vocab_keys"][:3]
    eng.submit((np.asarray(known + [10 ** 9]), np.ones(4, np.float32)))
    (r,) = eng.drain()
    assert np.all(np.isfinite(r.theta)) and r.oov_tokens == 1.0
    assert eng.stats()["oov_rate"] == 0.25


def test_legacy_engine_clamps_out_of_range_ids(trained_phi=None):
    """Even without a vocab table or live_words, an id >= W must fold in
    through the appended guard row instead of corrupting a gather."""
    from repro.serve import FoldInEngine

    docs, _, true_phi = lda_corpus(0, 8, W, K, doc_len_mean=20)
    phi_acc = jnp.asarray(true_phi.T) * 100.0
    eng = FoldInEngine(phi_acc, LDAConfig(vocab_size=W, num_topics=K),
                       len_buckets=(16,), batch_docs=1, fold_iters=6,
                       warmup=False)
    assert eng.live_words == W and eng.cfg.vocab_size == W
    eng.submit((np.asarray([0, 1, W + 50]), np.ones(3, np.float32)))
    (r,) = eng.drain()
    assert np.all(np.isfinite(r.theta)) and r.oov_tokens == 1.0
    assert eng.stats()["oov_rate"] == pytest.approx(1.0 / 3.0)
