"""Pull-based power-slice parameter server (ISSUE 8, DESIGN.md §15):
row sharding, per-link push/pull byte accounting, bounded-staleness
semantics, S=0 equivalence with the allreduce backend, and PS
crash-resume through the server-synced checkpoint manifest.  Chaos
hardening (ISSUE 10, DESIGN.md §17): sequence-number push idempotence,
out-of-order commit monotonicity, diagnostic pull timeouts, and the
shard crash/restart/replay state machine."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.paramserver import (JaxDistributedTransport, ParamServer,
                                    PSClient, RowShards,
                                    ServerUnavailableError, SimTransport,
                                    sliced_sum, touched_rows_of)
from repro.launch.lda_train import default_args, train_loop


# ------------------------------------------------------------ row sharding

def test_row_shards_cover_balance_and_split():
    rs = RowShards(10, 3)
    assert rs.ranges == [(0, 4), (4, 7), (7, 10)]
    assert [rs.owner(r) for r in (0, 3, 4, 9)] == [0, 0, 1, 2]
    split = rs.split(np.array([0, 5, 6, 9]))
    assert sorted(split) == [0, 1, 2]
    assert split[1].tolist() == [5, 6]
    # servers a touched set does not address never appear
    assert sorted(rs.split(np.array([8, 9]))) == [2]
    with pytest.raises(ValueError):
        rs.owner(10)
    with pytest.raises(ValueError):
        RowShards(0, 3)


def test_touched_rows_of_ignores_padding_slots():
    wid = np.array([[1, 5, 0], [5, 2, 0]])
    cnt = np.array([[1.0, 1.0, 0.0], [2.0, 1.0, 0.0]])
    np.testing.assert_array_equal(touched_rows_of(wid, cnt), [1, 2, 5])
    # stacked [N, Dl, L] layout flattens the same way; a live word at
    # row 0 counts, zero-count slots never do
    wid3 = np.array([[[0, 3]], [[3, 7]]])
    cnt3 = np.array([[[2.0, 1.0]], [[1.0, 0.0]]])
    np.testing.assert_array_equal(touched_rows_of(wid3, cnt3), [0, 3])


# ------------------------------------------------------- server + transport

def test_server_push_pull_roundtrip_and_version_gate():
    server = ParamServer(np.zeros((8, 3), np.float32), num_servers=2)
    t = SimTransport(server)
    rows = np.array([1, 5])
    delta = np.arange(6, dtype=np.float32).reshape(2, 3)
    t.push_batch(1, rows, delta).result()
    vals, ver = t.pull(rows, min_version=1).result()
    np.testing.assert_array_equal(vals, delta)
    assert ver == 1 and server.committed == 1
    # a pull demanding a version no push ever committed times out loudly
    with pytest.raises(TimeoutError):
        server.serve_pull(0, np.array([1]), min_version=5, timeout=0.05)
    # cross-shard addressing is a hard error, not silent corruption
    with pytest.raises(ValueError):
        server.apply_push(0, np.array([7]), np.ones((1, 3), np.float32))
    t.close()


def test_transport_bills_per_link_in_both_directions():
    server = ParamServer(np.zeros((8, 4), np.float32), num_servers=2)
    t = SimTransport(server)
    rows = np.array([0, 1, 6])          # 2 rows on s0, 1 row on s1
    t.push_batch(1, rows, np.ones((3, 4), np.float32)).result()
    t.pull(rows, 1).result()
    per_row = 4 * 4 + 4                 # K float32 values + int32 row id
    assert t.pushed_bytes == [2 * per_row, per_row]
    assert t.pulled_bytes == [2 * per_row, per_row]
    assert t.total_bytes == 2 * 3 * per_row
    by = t.bytes_by_link()
    assert by["push:s0"] == 2 * per_row and by["pull:s1"] == per_row
    t.close()


def test_bf16_wire_halves_value_bytes_and_round_trips():
    server = ParamServer(np.zeros((4, 4), np.float32))
    t = SimTransport(server, wire_dtype=jnp.bfloat16)
    v = 1.337
    t.push_batch(1, np.array([2]),
                 np.full((1, 4), v, np.float32)).result()
    assert t.pushed_bytes[0] == 4 * 2 + 4       # values at bf16 width
    vals, _ = t.pull(np.array([2]), 1).result()
    want = np.float32(np.asarray(v, jnp.bfloat16))
    np.testing.assert_array_equal(vals, np.full((1, 4), want))
    t.close()


def test_duplicate_push_is_idempotent():
    """A (client_id, seq) tag applies at most once per shard lifetime:
    a duplicated delivery (ChaosTransport dup, or a retry racing its
    original) never double-counts the delta."""
    server = ParamServer(np.zeros((4, 2), np.float32))
    rows = np.array([1])
    delta = np.full((1, 2), 3.0, np.float32)
    assert server.apply_push(0, rows, delta, client_id="w0", seq=0)
    assert not server.apply_push(0, rows, delta, client_id="w0", seq=0)
    server.commit(1)
    vals, _ = server.serve_pull(0, rows, min_version=1)
    np.testing.assert_array_equal(vals, delta)     # applied ONCE
    assert server.duplicates_dropped == 1
    # a different client's seq 0 is a different tag — both apply
    assert server.apply_push(0, rows, delta, client_id="w1", seq=0)
    # untagged pushes (legacy/positional callers) are never deduped
    assert server.apply_push(0, rows, delta)
    assert server.apply_push(0, rows, delta)


def test_out_of_order_delta_commit_is_monotonic():
    """Deltas may land out of version order (retries reorder the wire);
    the committed watermark is monotonic and the summed statistic is
    order-independent."""
    server = ParamServer(np.zeros((4, 2), np.float32))
    rows = np.array([2])
    # version 2's delta arrives before version 1's
    server.apply_push(0, rows, np.full((1, 2), 2.0, np.float32),
                      client_id="w0", seq=1)
    server.commit(2)
    server.apply_push(0, rows, np.full((1, 2), 1.0, np.float32),
                      client_id="w0", seq=0)
    server.commit(1)                               # stale: must not regress
    assert server.committed == 2
    vals, ver = server.serve_pull(0, rows, min_version=2)
    np.testing.assert_array_equal(vals, [[3.0, 3.0]])
    assert ver == 2


def test_pull_timeout_names_shard_rows_and_version():
    """The satellite contract: a timed-out pull says WHICH shard, WHICH
    row range and WHICH version it was waiting for — not a bare wait
    failure."""
    server = ParamServer(np.zeros((8, 2), np.float32), num_servers=2,
                         pull_timeout=0.05)
    with pytest.raises(TimeoutError, match=r"server shard 1.*rows \[4, 8\)"
                                           r".*>= 7"):
        server.serve_pull(1, np.array([5]), min_version=7)  # default timeout


def test_crash_restart_replay_state_machine():
    """crash() loses the shard's rows + dedup memory; restart() reloads
    the last synced snapshot and fences pulls until mark_recovered()."""
    server = ParamServer(np.zeros((4, 2), np.float32), pull_timeout=0.05)
    rows = np.array([0])
    server.apply_push(0, rows, np.ones((1, 2), np.float32),
                      client_id="w0", seq=0)
    server.commit(1)
    server.mark_synced()                           # fence: version 1 durable
    server.apply_push(0, rows, np.ones((1, 2), np.float32),
                      client_id="w0", seq=1)       # post-fence delta
    server.commit(2)
    server.crash(0)
    with pytest.raises(ServerUnavailableError, match="shard 0"):
        server.apply_push(0, rows, np.ones((1, 2), np.float32))
    # a pull against a down shard fails FAST (no timeout burn)
    with pytest.raises(ServerUnavailableError):
        server.serve_pull(0, rows, min_version=1)
    server.restart(0)
    assert server.needs_replay() == frozenset({0})
    # fenced: the shard holds only the synced snapshot until replay
    with pytest.raises(TimeoutError, match="replay"):
        server.serve_pull(0, rows, min_version=2)
    # the replay fence also rejects ORDINARY pushes (retryable): an
    # in-flight retry landing before the replayed backlog would re-sum
    # the rows in a different order (float add is not associative)
    with pytest.raises(ServerUnavailableError, match="replaying"):
        server.apply_push(0, rows, np.ones((1, 2), np.float32),
                          client_id="w0", seq=1)
    # client replays its retained post-fence delta — dedup memory died
    # with the shard, so the replayed (w0, 1) tag applies exactly once
    assert server.apply_push(0, rows, np.ones((1, 2), np.float32),
                             client_id="w0", seq=1, replay=True)
    server.mark_recovered(0)
    vals, _ = server.serve_pull(0, rows, min_version=2)
    np.testing.assert_array_equal(vals, [[2.0, 2.0]])
    events = [e["event"] for e in server.recovery_log]
    assert events == ["crash", "restart", "recovered"]


def test_jax_distributed_transport_refuses_uninitialized():
    # the multi-host slot must fail loudly rather than silently running
    # in-process while claiming to be a cluster
    with pytest.raises(RuntimeError, match="jax.distributed"):
        JaxDistributedTransport(2)


# ----------------------------------------------------------------- client

def test_client_s0_round_trip_is_barriered():
    server = ParamServer(np.zeros((6, 2), np.float32))
    client = PSClient(SimTransport(server), staleness=0)
    rows = np.array([0, 3])
    phi = client.begin_batch(1, rows, jnp.zeros((6, 2)))
    phi_new = phi.at[jnp.asarray(rows)].add(1.0)
    client.end_batch(1, phi_new, rows)          # S=0: blocks until commit
    assert server.committed == 1
    phi2 = client.begin_batch(2, rows, phi_new)
    np.testing.assert_array_equal(np.asarray(phi2)[rows],
                                  np.asarray(phi_new)[rows])
    client.flush()
    client.transport.close()


def test_client_staleness_bounds_pending_and_serves_stale_pulls():
    server = ParamServer(np.zeros((6, 2), np.float32))
    client = PSClient(SimTransport(server), staleness=1)
    rows = np.array([1, 4])
    phi = client.begin_batch(1, rows, jnp.zeros((6, 2)))
    # S=1: batch 2's prefetch needs committed >= 0 — served although
    # batch 1's push has not even been issued yet (bounded staleness)
    client.prefetch(2, rows)
    phi = client.begin_batch(2, rows, phi)      # must not block
    client.end_batch(2, phi.at[jnp.asarray(rows)].add(2.0), rows)
    client.flush()
    # the push was never lost: the server holds it after the drain
    vals, _ = server.serve_pull(0, np.array([1]), min_version=2)
    np.testing.assert_array_equal(vals, [[2.0, 2.0]])
    assert client.mean_touched_rows == 2.0
    client.transport.close()
    with pytest.raises(ValueError):
        PSClient(SimTransport(ParamServer(np.zeros((2, 2), np.float32))),
                 staleness=-1)


def test_sliced_sum_is_bitexact_with_dense_sum():
    rng = np.random.default_rng(0)
    w_cap, k, n = 12, 3, 3
    deltas, touched = [], []
    for _ in range(n):
        rows = np.sort(rng.choice(w_cap, size=4, replace=False))
        d = np.zeros((w_cap, k), np.float32)
        d[rows] = rng.normal(size=(4, k)).astype(np.float32)
        deltas.append(d)
        touched.append(rows)
    dense = deltas[0] + deltas[1] + deltas[2]   # same per-row add order
    np.testing.assert_array_equal(sliced_sum(deltas, touched, w_cap), dense)


# ------------------------------------------------------ driver integration

def _common(**kw):
    base = dict(minibatches=6, docs_per_batch=16, vocab=200, topics=8,
                lambda_k=4, inner_iters=5, log_every=0, shards=2, seed=11)
    base.update(kw)
    return base


def test_ps_backend_matches_allreduce_at_s0():
    """The acceptance pin: --backend ps --staleness 0 reproduces the
    allreduce trajectory (drift <= 1e-6) and reports touched-row wire
    bytes."""
    ar = train_loop(default_args(**_common(), backend="sim"))
    ps = train_loop(default_args(**_common(), backend="ps", staleness=0,
                                 ps_servers=3))
    np.testing.assert_allclose(ps["mean_r"], ar["mean_r"], atol=1e-6)
    np.testing.assert_allclose(ps["phi_acc"], ar["phi_acc"],
                               rtol=1e-6, atol=1e-5)
    assert ps["ps_wire_bytes"] > 0
    assert 0 < ps["mean_touched_rows"] <= 200
    # measured wire == the touched-row byte model, exactly: each of the
    # push and pull legs ships touched * (K * 4 + 4) bytes per batch, so
    # the total is 2 * (K*4 + 4) * sum(touched) = 2 * (K*4+4) * mean * n
    n, k = len(ps["mean_r"]), _common()["topics"]
    assert ps["ps_wire_bytes"] == pytest.approx(
        2 * (k * 4 + 4) * ps["mean_touched_rows"] * n)
    # push/pull phase split present in the trace-time model
    assert any(p.endswith(".push") for p in ps["bytes_by_phase"])
    assert any(p.endswith(".pull") for p in ps["bytes_by_phase"])


def test_ps_staleness_converges():
    ps2 = train_loop(default_args(**_common(), backend="ps", staleness=2,
                                  ps_servers=3))
    assert np.isfinite(ps2["ppl"])
    assert np.isfinite(ps2["mean_r"]).all()
    assert ps2["staleness"] == 2


def test_ps_crash_resume_matches_uninterrupted(tmp_path):
    kw = _common(minibatches=8, backend="ps", staleness=0, ps_servers=3,
                 ckpt_dir=str(tmp_path), ckpt_every=3)
    with pytest.raises(SystemExit):
        train_loop(default_args(**kw, crash_at=5))
    res = train_loop(default_args(**kw))
    base = train_loop(default_args(**_common(minibatches=8, backend="ps",
                                             staleness=0, ps_servers=3)))
    assert res["first_m"] == 3
    np.testing.assert_allclose(res["mean_r"], base["mean_r"][3:], atol=1e-6)
    # the manifest carries the server-side state at the fence
    from repro.dist import checkpoint as ckpt
    extra, _ = ckpt.peek_extra(str(tmp_path))
    assert extra["ps"]["num_servers"] == 3
    assert extra["ps"]["staleness"] == 0
    assert len(extra["ps"]["ranges"]) == 3


def test_ps_resume_rejects_mismatched_staleness(tmp_path):
    kw = _common(minibatches=6, backend="ps", ps_servers=3,
                 ckpt_dir=str(tmp_path), ckpt_every=2)
    train_loop(default_args(**kw, staleness=0))
    kw["minibatches"] = 10
    with pytest.raises(ValueError, match="staleness"):
        train_loop(default_args(**kw, staleness=2))


def test_ps_rejects_decay():
    with pytest.raises(ValueError, match="decay"):
        train_loop(default_args(**_common(), backend="ps",
                                decay="64,0.6"))
