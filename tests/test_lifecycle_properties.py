"""Hypothesis property coverage for the lifecycle compaction remap
(ISSUE 7, DESIGN.md §14): the remap is a pure, deterministic function of
the admission sequence + keep mask — same stream, same fence decisions,
same row assignment — and survivors always form an order-preserving
dense prefix whose freed rows are reused before the ladder grows."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import lifecycle
from repro.data.vocab import VocabMap

STREAM = st.lists(
    st.lists(st.integers(0, 60), min_size=1, max_size=12),
    min_size=1, max_size=8)


def _replay(batches, masks):
    """One consumer: admit batch m at step m, compact after each batch
    with the corresponding keep mask (padded/truncated to live)."""
    v = VocabMap()
    remaps = []
    for m, (batch, mask) in enumerate(zip(batches, masks)):
        v.rows(batch, admit=True, step=m)
        keep = (list(mask) + [True] * len(v))[:len(v)]
        remaps.append(v.compact(keep).tolist())
    return v, remaps


@settings(max_examples=40, deadline=None)
@given(batches=STREAM, data=st.data())
def test_same_stream_same_fences_same_rows(batches, data):
    """ACCEPTANCE (ISSUE 7): two consumers of the same batch sequence
    with the same fence decisions produce identical remaps, identical
    key->row tables, and identical touched vectors — the property
    crash-resume across a compaction fence stands on."""
    masks = [data.draw(st.lists(st.booleans(), max_size=80),
                       label=f"keep[{m}]")
             for m in range(len(batches))]
    va, ra = _replay(batches, masks)
    vb, rb = _replay(batches, masks)
    assert ra == rb
    assert va.to_state() == vb.to_state()
    assert va.touched_upto(len(va)) == vb.touched_upto(len(vb))


@settings(max_examples=40, deadline=None)
@given(keys=st.lists(st.integers(0, 200), min_size=1, max_size=40,
                     unique=True),
       data=st.data())
def test_compact_remap_is_order_preserving_dense_prefix(keys, data):
    keep = data.draw(st.lists(st.booleans(), min_size=len(keys),
                              max_size=len(keys)), label="keep")
    v = VocabMap(keys)
    remap = v.compact(keep)

    survivors = [i for i, b in enumerate(keep) if b]
    # survivors land on 0..n-1 in their original relative order
    assert [remap[i] for i in survivors] == list(range(len(survivors)))
    assert all(remap[i] == -1 for i in range(len(keys)) if not keep[i])
    assert v.to_state() == [keys[i] for i in survivors]
    # post-compaction lookup agrees with the remap; dead keys are gone
    for i, k in enumerate(keys):
        assert v.lookup(k) == (remap[i] if keep[i] else None)
    # freed rows are reused before any new row is minted
    fresh = 1000
    assert v.admit(fresh) == len(survivors)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 24), data=st.data())
def test_apply_row_remap_agrees_with_host_oracle(n, data):
    import jax
    import jax.numpy as jnp

    from repro.core.types import LDATrainState

    keep = data.draw(st.lists(st.booleans(), min_size=n, max_size=n),
                     label="keep")
    K, W = 4, n + 4                                    # a few guard rows
    rng = np.random.default_rng(n)
    phi = rng.gamma(1.0, size=(W, K)).astype(np.float32)
    remap = VocabMap(list(range(n))).compact(keep)
    out = lifecycle.apply_row_remap(
        LDATrainState(phi_acc=jnp.asarray(phi),
                      m=jnp.asarray(0, jnp.int32),
                      rng=jax.random.PRNGKey(0)), remap)
    oracle = np.zeros_like(phi)
    for i, r in enumerate(remap):
        if r >= 0:
            oracle[r] = phi[i]
    np.testing.assert_array_equal(np.asarray(out.phi_acc), oracle)
