"""Hypothesis property coverage for the selective-sweep formulations
(ISSUE 5): megakernel / jnp / oracle parity for the FULL iteration —
mu carry, theta, packed delta/residual — across random (K, Pk, T),
including live-W guard rows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import LDAConfig, MiniBatch
from repro.core import power as pw
from repro.core.pobp import (_selective_sweep_carry_pallas,
                             _selective_sweep_dense_layout,
                             _selective_sweep_packed)
from repro.core.residuals import token_scatter_wk


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    shape=st.sampled_from([(6, 8), (10, 16), (4, 40)]),   # (D, L)
    K=st.sampled_from([4, 12, 24]),
    pk_frac=st.sampled_from([1, 3, 100]),                 # Pk = min(K, .)
    live=st.sampled_from([None, 0.6]),                    # live_w / W
)
def test_full_iteration_parity_property(seed, shape, K, pk_frac, live):
    D, L = shape
    W = 48
    cfg = LDAConfig(vocab_size=W, num_topics=K, lambda_w=0.25,
                    lambda_k_abs=min(K, pk_frac))
    P, Pk = cfg.num_power_words, cfg.num_power_topics
    live_w = None if live is None else max(2, int(live * W))
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    hi = W if live_w is None else live_w
    wid = jax.random.randint(ks[0], (D, L), 0, hi).astype(jnp.int32)
    cnt = jax.random.randint(ks[1], (D, L), 0, 3).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    mu = jax.nn.softmax(jax.random.normal(ks[2], (D, L, K)), -1)
    theta = jnp.einsum("dl,dlk->dk", cnt, mu)
    phi = token_scatter_wk(wid, cnt[..., None] * mu, W)
    phi_tot = jnp.sum(phi, 0)
    r = jax.random.uniform(ks[3], (W, K))
    r_w = jnp.sum(r, 1)
    if live_w is None:
        sel_w, wbeta = pw.select_power_words(r_w, P), None
    else:
        sel_w = pw.select_power_words_live(r_w, P, live_w, cfg.lambda_w)
        wbeta = jnp.float32(live_w * cfg.beta)
    sel_k = pw.select_power_topics(r, sel_w, Pk)

    lay = batch.token_layout()
    mu_t = mu.reshape(-1, K)
    outs = {
        name: fn(lay, mu_t, theta, phi, phi_tot, sel_w, sel_k, cfg,
                 wbeta=wbeta)
        for name, fn in (("packed", _selective_sweep_packed),
                         ("dense_layout", _selective_sweep_dense_layout),
                         ("carry_kernel", _selective_sweep_carry_pallas))}

    ref = outs.pop("packed")
    # cross-formulation parity on every output of the iteration
    for name, got in outs.items():
        for a, b, what in zip(ref, got, ("mu", "theta", "d_pack", "r_pack")):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5,
                err_msg=f"{name}/{what}")
    # iteration invariants, every formulation: message mass conserved,
    # theta consistent with the updated carry, packed residual dominates
    # the signed delta, guard/dead rows transmit exact zeros
    for name, (mu_new, theta_new, d_pack, r_pack) in {
            "packed": ref, **outs}.items():
        np.testing.assert_allclose(np.asarray(jnp.sum(mu_new, -1)), 1.0,
                                   atol=1e-4, err_msg=name)
        np.testing.assert_allclose(
            np.asarray(theta_new),
            np.asarray(jnp.einsum("dl,dlk->dk", cnt,
                                  lay.to_batch_major(mu_new))),
            rtol=1e-4, atol=1e-4, err_msg=name)
        assert float(jnp.sum(r_pack)) >= abs(float(jnp.sum(d_pack))) - 1e-5
        if live_w is not None:
            dead = np.asarray(sel_w) == live_w
            np.testing.assert_array_equal(np.asarray(d_pack)[dead], 0.0,
                                          err_msg=name)
