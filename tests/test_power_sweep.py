"""Token-major power sweep: kernel-vs-ref parity, seed-semantics parity,
algorithm invariants, and the layout round-trip.  No hypothesis dependency —
this file keeps kernel coverage where property tests are skipped."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, MiniBatch, make_sim_minibatch_fn
from repro.core.pobp import (dense_sweep, selective_sweep,
                             selective_sweep_tokens,
                             selective_sweep_tokens_pallas)
from repro.core.residuals import token_scatter_wk
from repro.core.sync import LocalReducer
from repro.core import power as pw
from repro.kernels.power_sweep.ops import power_sweep
from repro.kernels.power_sweep.ref import power_sweep_tokens_ref


def _state(key, cfg, D=8, L=14):
    ks = jax.random.split(key, 4)
    wid = jax.random.randint(ks[0], (D, L), 0, cfg.vocab_size).astype(jnp.int32)
    cnt = jax.random.randint(ks[1], (D, L), 0, 3).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    mu = jax.nn.softmax(jax.random.normal(ks[2], (D, L, cfg.num_topics)), -1)
    theta = jnp.einsum("dl,dlk->dk", cnt, mu)
    phi = jax.random.uniform(ks[3], (cfg.vocab_size, cfg.num_topics)) * 5
    return batch, mu, theta, phi, jnp.sum(phi, 0)


def _selection(key, cfg, P, Pk):
    r = jax.random.uniform(key, (cfg.vocab_size, cfg.num_topics))
    sel_w = pw.select_power_words(jnp.sum(r, 1), P)
    sel_k = pw.select_power_topics(r, sel_w, Pk)
    return sel_w, sel_k


# ------------------------------------------------------- kernel vs oracle

@pytest.mark.parametrize("T,P,Pk", [(50, 8, 3), (256, 40, 50), (40, 16, 130),
                                    (8, 1, 1), (512, 64, 8)])
def test_power_sweep_kernel_matches_ref(T, P, Pk):
    rng = np.random.default_rng(T * P + Pk)
    p_tok = jnp.asarray(rng.integers(0, P + 1, T).astype(np.int32))
    c = jnp.asarray(rng.integers(0, 4, (T, 1)).astype(np.float32))
    mu_sel = jnp.asarray(rng.uniform(0.01, 1, (T, Pk)).astype(np.float32))
    th = jnp.asarray(rng.uniform(0, 5, (T, Pk)).astype(np.float32))
    pt = jnp.asarray(rng.uniform(1, 9, (T, Pk)).astype(np.float32))
    phip = jnp.asarray(rng.uniform(0, 5, (P, Pk)).astype(np.float32))
    kw = dict(alpha=0.1, beta=0.01, wbeta=0.4)
    mu1, d1, r1 = power_sweep(p_tok, c, mu_sel, th, pt, phip, **kw)
    phip1 = jnp.concatenate([phip, jnp.zeros((1, Pk))], 0)
    mu2, d2, r2 = power_sweep_tokens_ref(p_tok, c, mu_sel, th, pt, phip1,
                                         n_pow=P, **kw)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2[:P]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2[:P]),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(r1) >= 0)


# ------------------------------------------- token-major vs seed semantics

CFG = LDAConfig(vocab_size=40, num_topics=10, lambda_w=0.2, lambda_k_abs=3)


def test_token_sweep_matches_seed_selective_sweep():
    batch, mu, theta, phi, phi_tot = _state(jax.random.PRNGKey(0), CFG)
    sel_w, sel_k = _selection(jax.random.PRNGKey(1), CFG, 8, 3)
    m1, t1, d1, r1 = selective_sweep(batch, mu, theta, phi, phi_tot,
                                     sel_w, sel_k, CFG)
    lay = batch.token_layout()
    m2, t2, d2, r2 = selective_sweep_tokens(
        lay, mu.reshape(-1, CFG.num_topics), theta, phi, phi_tot,
        sel_w, sel_k, CFG)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(lay.to_batch_major(m2)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                               rtol=1e-5, atol=1e-6)


def test_pallas_sweep_matches_jnp_token_sweep():
    batch, mu, theta, phi, phi_tot = _state(jax.random.PRNGKey(2), CFG)
    sel_w, sel_k = _selection(jax.random.PRNGKey(3), CFG, 8, 3)
    lay = batch.token_layout()
    mu_t = mu.reshape(-1, CFG.num_topics)
    outs1 = selective_sweep_tokens(lay, mu_t, theta, phi, phi_tot,
                                   sel_w, sel_k, CFG)
    outs2 = selective_sweep_tokens_pallas(lay, mu_t, theta, phi, phi_tot,
                                          sel_w, sel_k, CFG)
    for a, b in zip(outs1, outs2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_token_sweep_invariants():
    """Mass conservation, untouched non-power entries, packed-delta
    consistency with the [W, K] token scatter restriction."""
    batch, mu, theta, phi, phi_tot = _state(jax.random.PRNGKey(4), CFG)
    sel_w, sel_k = _selection(jax.random.PRNGKey(5), CFG, 8, 3)
    lay = batch.token_layout()
    mu_t = mu.reshape(-1, CFG.num_topics)
    m2, t2, d2, r2 = selective_sweep_tokens(lay, mu_t, theta, phi, phi_tot,
                                            sel_w, sel_k, CFG)
    # sum_k mu == 1 stays invariant (mass-conserving renormalization)
    np.testing.assert_allclose(np.asarray(jnp.sum(m2, -1)), 1.0, atol=1e-5)
    # non-power tokens bit-identical
    in_power = np.isin(np.asarray(lay.word_ids), np.asarray(sel_w))
    np.testing.assert_array_equal(np.asarray(m2)[~in_power],
                                  np.asarray(mu_t)[~in_power])
    # unselected topic coords untouched even for power tokens
    unsel = np.setdiff1d(np.arange(CFG.num_topics), np.asarray(sel_k))
    np.testing.assert_array_equal(np.asarray(m2)[:, unsel],
                                  np.asarray(mu_t)[:, unsel])
    # theta consistent with the updated messages
    np.testing.assert_allclose(
        np.asarray(t2),
        np.asarray(jnp.einsum("dl,dlk->dk", batch.counts,
                              lay.to_batch_major(m2))), rtol=1e-5, atol=1e-5)
    # packed deltas == the [W, K] token scatter restricted to (sel_w, sel_k)
    d_tok = lay.to_batch_major(m2 - mu_t) * batch.counts[..., None]
    d_wk = token_scatter_wk(batch.word_ids, d_tok, CFG.vocab_size)
    np.testing.assert_allclose(np.asarray(pw.pack_rows(d_wk, sel_w, sel_k)),
                               np.asarray(d2), rtol=1e-4, atol=1e-5)
    # residual pack dominates the signed delta pack
    assert float(jnp.sum(r2)) >= abs(float(jnp.sum(d2))) - 1e-6


def test_token_layout_round_trip():
    batch, mu, *_ = _state(jax.random.PRNGKey(6), CFG, D=5, L=9)
    lay = batch.token_layout()
    assert lay.num_slots == 5 * 9
    np.testing.assert_array_equal(
        np.asarray(lay.word_ids.reshape(5, 9)), np.asarray(batch.word_ids))
    np.testing.assert_array_equal(
        np.asarray(lay.counts.reshape(5, 9)), np.asarray(batch.counts))
    np.testing.assert_array_equal(np.asarray(lay.doc_ids.reshape(5, 9)),
                                  np.tile(np.arange(5)[:, None], (1, 9)))
    mu_t = mu.reshape(-1, CFG.num_topics)
    np.testing.assert_array_equal(np.asarray(lay.to_batch_major(mu_t)),
                                  np.asarray(mu))


# ------------------------------------------------------------- end to end

def test_pobp_minibatch_pallas_matches_jnp():
    W, K = 60, 16
    cfgj = LDAConfig(vocab_size=W, num_topics=K, lambda_w=0.2, lambda_k_abs=4,
                     inner_iters=6, residual_tol=1e-9)
    cfgp = dataclasses.replace(cfgj, impl="pallas")
    wid = jax.random.randint(jax.random.PRNGKey(5), (10, 14), 0, W)
    cnt = jax.random.randint(jax.random.PRNGKey(6), (10, 14), 0, 3)
    outs = {}
    for name, c_ in (("jnp", cfgj), ("pallas", cfgp)):
        fn, _ = make_sim_minibatch_fn(c_, 1, "power")
        outs[name] = fn(wid.astype(jnp.int32), cnt.astype(jnp.float32),
                        jnp.zeros((W, K)), jax.random.PRNGKey(1),
                        jnp.float32(1.0))
    assert int(outs["jnp"][1]) == int(outs["pallas"][1])  # same iter count
    for a, b in zip(outs["jnp"], outs["pallas"]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=1e-5)


def test_dense_sweep_pallas_matches_jnp_sweep():
    """bp_update coverage without hypothesis (cf. tests/test_kernels.py)."""
    key = jax.random.PRNGKey(3)
    cfg = LDAConfig(vocab_size=90, num_topics=16)
    from repro.kernels.bp_update.ops import dense_sweep_pallas
    batch, mu, theta, phi, phi_tot = _state(key, cfg, D=12, L=20)
    m1, r1 = dense_sweep_pallas(batch, mu, phi, phi_tot, cfg,
                                batch.token_layout())
    m2, r2 = dense_sweep(batch, mu, phi, phi_tot, cfg, LocalReducer())
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-4,
                               atol=1e-4)


def test_token_loop_trajectory_matches_seed_loop():
    """mean_r trajectories of the production token-major loop and a
    faithfully reconstructed seed [D, L, K] loop agree to <= 1e-5.

    sweep_policy is pinned to 'packed': this test over-iterates a tiny
    random batch far past convergence (tol=1e-9), a regime where the
    selective update eventually blows up numerically (the seed does too,
    identically) — the chain formulation tracks the seed bit-closely
    through it, while dense_layout only tracks to float associativity
    (its sane-regime parity is pinned in tests/test_sweep_policy.py)."""
    cfg = LDAConfig(vocab_size=80, num_topics=12, lambda_w=0.15,
                    lambda_k_abs=4, inner_iters=6, residual_tol=1e-9,
                    sweep_policy="packed")
    W, K = cfg.vocab_size, cfg.num_topics
    P, Pk = cfg.num_power_words, cfg.num_power_topics
    key = jax.random.PRNGKey(9)
    wid = jax.random.randint(key, (16, 18), 0, W).astype(jnp.int32)
    cnt = jax.random.randint(jax.random.PRNGKey(10), (16, 18), 0, 3
                             ).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    total = jnp.sum(cnt)

    # shared dense phase (lines 3-10)
    u0 = jax.random.uniform(jax.random.PRNGKey(1), (16, 18, K),
                            minval=0.01, maxval=1.0)
    mu0 = u0 / jnp.sum(u0, -1, keepdims=True)
    phi_eff = token_scatter_wk(wid, cnt[..., None] * mu0, W)
    phi_tot = jnp.sum(phi_eff, 0)
    mu1, r_glob = dense_sweep(batch, mu0, phi_eff, phi_tot, cfg,
                              LocalReducer())
    theta = jnp.einsum("dl,dlk->dk", cnt, mu1)
    r_w = jnp.sum(r_glob, 1)

    def seed_iter(mu, theta, phi_eff, phi_tot, r_glob, r_w):
        sel_w = pw.select_power_words(r_w, P)
        sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
        mu, theta, d, r = selective_sweep(batch, mu, theta, phi_eff,
                                          phi_tot, sel_w, sel_k, cfg)
        phi_eff = pw.scatter_add_rows(phi_eff, sel_w, sel_k, d)
        phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d)
        r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r)
        return mu, theta, phi_eff, phi_tot, r_glob, jnp.sum(r_glob, 1)

    from repro.core.residuals import mean_residual, packed_rw_delta
    lay = batch.token_layout()

    def token_iter(mu_t, theta, phi_eff, phi_tot, r_glob, r_w):
        sel_w = pw.select_power_words(r_w, P)
        sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
        mu_t, theta, d, r = selective_sweep_tokens(
            lay, mu_t, theta, phi_eff, phi_tot, sel_w, sel_k, cfg)
        rw_d = packed_rw_delta(r_glob, sel_w, sel_k, r)
        phi_eff = pw.scatter_add_rows(phi_eff, sel_w, sel_k, d)
        phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d)
        r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r)
        return mu_t, theta, phi_eff, phi_tot, r_glob, r_w.at[sel_w].add(rw_d)

    s_seed = (mu1, theta, phi_eff, phi_tot, r_glob, r_w)
    s_tok = (mu1.reshape(-1, K), theta, phi_eff, phi_tot, r_glob, r_w)
    for _ in range(5):
        s_seed = seed_iter(*s_seed)
        s_tok = token_iter(*s_tok)
        a = float(mean_residual(s_seed[-1], total))
        b = float(mean_residual(s_tok[-1], total))
        assert abs(a - b) <= 1e-5, (a, b)
