"""PowerSync (the paper's technique generalized to gradient sync):
correctness, error feedback, byte reduction, end-to-end convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sync import CommMeter, MeshReducer
from repro.optim.powersync import (PowerSyncConfig, dense_sync_tree,
                                   powersync_tree, residual_init)


def _run_sim(fn, n_shards, *args):
    """vmap(axis_name='dp') so lax.psum matches mesh semantics."""
    return jax.vmap(fn, axis_name="dp", in_axes=0)(*args)


def test_lambda_one_equals_dense_sync():
    """With lambda_rows=lambda_cols=1 PowerSync IS the dense all-reduce."""
    meter = CommMeter()
    red = MeshReducer("dp", meter=meter)
    cfg = PowerSyncConfig(lambda_rows=1.0, lambda_cols=1.0, min_dense_size=1)
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (4, 16, 8))     # 4 shards
    r = jnp.zeros_like(g)

    def one(gs, rs):
        synced, res = powersync_tree({"w": gs}, {"w": rs}, red, cfg, 4)
        return synced["w"], res["w"]

    synced, res = _run_sim(one, 4, g, r)
    want = jnp.broadcast_to(jnp.mean(g, 0, keepdims=True), g.shape)
    np.testing.assert_allclose(np.asarray(synced), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res), 0.0, atol=1e-7)


def test_error_feedback_conserves_mass():
    """transmitted + residual == grad + residual_prev, per shard."""
    red = MeshReducer("dp")
    cfg = PowerSyncConfig(lambda_rows=0.25, lambda_cols=0.5, min_dense_size=1)
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, (2, 8, 8))
    r0 = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8)) * 0.1

    def one(gs, rs):
        synced, res = powersync_tree({"w": gs}, {"w": rs}, red, cfg, 2)
        return synced["w"], res["w"]

    synced, res = _run_sim(one, 2, g, r0)
    acc = np.asarray(g) + np.asarray(r0)
    # selected coords: residual zeroed; unselected: residual == acc
    res = np.asarray(res)
    sent_mask = res == 0.0
    np.testing.assert_allclose(res[~sent_mask], acc[~sent_mask], rtol=1e-5)
    # synced mean contains exactly the sum of per-shard sent entries / N
    sy = np.asarray(synced)[0]
    sel = np.asarray(sent_mask[0])
    np.testing.assert_allclose(sy[sel], acc[:, sel].mean(0) if False
                               else (acc[0][sel] + acc[1][sel]) / 2,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sy[~sel], 0.0, atol=1e-6)


def test_selection_identical_across_shards():
    """Shards must transmit identical coordinates (index-free collectives)."""
    red = MeshReducer("dp")
    cfg = PowerSyncConfig(lambda_rows=0.25, lambda_cols=0.25, min_dense_size=1)
    g = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 16))

    def one(gs):
        synced, res = powersync_tree({"w": gs}, {"w": jnp.zeros_like(gs)},
                                     red, cfg, 4)
        return res["w"] == 0.0     # the sent mask

    masks = np.asarray(_run_sim(one, 4, g))
    for n in range(1, 4):
        np.testing.assert_array_equal(masks[0], masks[n])


def test_bytes_reduction_matches_lambdas():
    meter = CommMeter()
    red = MeshReducer("dp", meter=meter)
    rows, cols = 64, 32
    cfg = PowerSyncConfig(lambda_rows=0.25, lambda_cols=0.5, min_dense_size=1)
    g = jax.random.normal(jax.random.PRNGKey(4), (2, rows, cols))

    def one(gs):
        return powersync_tree({"w": gs}, {"w": jnp.zeros_like(gs)}, red,
                              cfg, 2)[0]["w"]

    _run_sim(one, 2, g)
    payload = meter.phase_bytes("powersync_payload")
    dense = rows * cols * 4
    assert payload == int(0.25 * rows) * int(0.5 * cols) * 4
    assert payload < 0.2 * dense
    # norm side-channel is small: rows + cols floats
    assert meter.phase_bytes("powersync_norms") == (rows + cols) * 4


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 40), st.integers(4, 40), st.integers(1, 4))
def test_powersync_eventual_transmission(rows, cols, seed):
    """Dynamic re-selection (paper Fig. 3): a constant gradient's mass at ANY
    coordinate is eventually transmitted — residual cannot grow unboundedly."""
    red = MeshReducer("dp")
    cfg = PowerSyncConfig(lambda_rows=0.3, lambda_cols=0.5, min_dense_size=1)
    # bounded magnitude ratio (<=3x): eventual transmission then needs only
    # O(ratio / lambda) rounds; unbounded ratios converge too (linear
    # residual growth always wins) but need unbounded rounds.
    g = jax.random.uniform(jax.random.PRNGKey(seed), (1, rows, cols),
                           minval=0.5, maxval=1.5)

    def one(gs, rs):
        synced, res = powersync_tree({"w": gs}, {"w": rs}, red, cfg, 1)
        return synced["w"], res["w"]

    r = jnp.zeros((1, rows, cols))
    sent_total = np.zeros((rows, cols), np.float32)
    for _ in range(30):
        synced, r = _run_sim(one, 1, g, r)
        sent_total += np.asarray(synced[0])
    # every coordinate got transmitted at least once over 30 rounds
    assert np.all(sent_total > 0), (sent_total == 0).sum()


def test_training_converges_with_powersync():
    """End-to-end: tiny LM trained with PowerSync reaches a loss close to
    dense sync (error feedback keeps the optimizer unbiased over time)."""
    from repro.launch.train import main as train_main
    losses_p, meter_p = train_main([
        "--arch", "smollm-360m", "--reduced", "--steps", "40", "--batch",
        "8", "--seq", "32", "--shards", "2", "--sync", "power",
        "--log-every", "100"])
    losses_d, meter_d = train_main([
        "--arch", "smollm-360m", "--reduced", "--steps", "40", "--batch",
        "8", "--seq", "32", "--shards", "2", "--sync", "dense",
        "--log-every", "100"])
    assert losses_p[-1] < losses_p[0] - 0.3          # it learns
    assert losses_p[-1] < losses_d[-1] + 0.6         # close to dense
    payload = meter_p.phase_bytes("powersync_payload")
    dense = meter_d.phase_bytes("dense_grads")
    assert payload < 0.25 * dense, (payload, dense)  # >4x comm reduction
