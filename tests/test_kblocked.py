"""K-blocked carry megakernel (DESIGN.md §13): parity vs the oracle and
the full-K one-pass kernel, the shared VMEM tile chooser's edge shapes,
and the kblock-aware dispatch/serving contracts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, MiniBatch
from repro.core.sweep_dispatch import (_resolve_cached, carry_vmem_fit,
                                       resolve_sweep_policy)
from repro.kernels.power_sweep import kernel as K_
from repro.kernels.power_sweep.ops import (power_sweep_carry,
                                           power_sweep_carry_kblocked)
from repro.kernels.power_sweep.ref import power_sweep_carry_kblocked_ref


def _carry_inputs(seed, T, D, K, P, update_phi=True):
    rng = np.random.default_rng(seed)
    p_tok = jnp.asarray(rng.integers(0, P + 1, T).astype(np.int32))
    doc_ids = jnp.asarray(rng.integers(0, D, T).astype(np.int32))
    c = jnp.asarray(rng.integers(0, 4, (T, 1)).astype(np.float32))
    mu = rng.uniform(0.01, 1, (T, K)).astype(np.float32)
    mu = jnp.asarray(mu / mu.sum(-1, keepdims=True))
    theta = jnp.asarray(rng.uniform(0, 5, (D, K)).astype(np.float32))
    pt = jnp.asarray(rng.uniform(1, 9, (K,)).astype(np.float32))
    phi = rng.uniform(0, 5, (P + 1, K)).astype(np.float32)
    phi[-1] = 0.0
    if update_phi:
        mask = (rng.uniform(0, 1, (P + 1, K)) < 0.5).astype(np.float32)
        mask[-1] = 0.0
        kw = dict(alpha=0.1, beta=0.01, wbeta=0.4, update_phi=True)
    else:
        # serving contract: pre-normalized phi, beta == 0, implicit mask
        mask = np.ones((P + 1, K), np.float32)
        mask[-1] = 0.0
        phi = phi / np.maximum(phi.sum(0, keepdims=True), 1e-30)
        pt = jnp.ones((K,), jnp.float32)
        kw = dict(alpha=0.1, beta=0.0, wbeta=1.0, update_phi=False)
    phi = phi * mask
    return (p_tok, doc_ids, c, mu, theta, pt, jnp.asarray(phi),
            jnp.asarray(mask)), kw


# --------------------------------------------- kblocked vs oracle / full-K

@pytest.mark.parametrize("T,D,K,P,update_phi", [
    (48, 8, 256, 12, True),      # 2 K-blocks (kb=128)
    (48, 8, 256, 12, False),     # serving mode, 2 K-blocks
    (24, 4, 384, 6, True),       # 3 K-blocks, TT hits the floor of 8
    (64, 16, 200, 10, True),     # K not lane-aligned: ops pads 200 -> 256
    (40, 8, 130, 5, False),      # serving, padded 130 -> 256? no: 130->256
])
def test_kblocked_matches_ref_and_fullk(T, D, K, P, update_phi):
    args, kw = _carry_inputs(T * K + P, T, D, K, P, update_phi)
    outs_kb = power_sweep_carry(*args, kblocked=True, kb=128, **kw)
    outs_fk = power_sweep_carry(*args, **kw)
    outs_rf = power_sweep_carry_kblocked_ref(*args, **kw)
    n_keep = P if update_phi else 0
    ref = (outs_rf[0], outs_rf[1], outs_rf[2][:n_keep], outs_rf[3][:n_keep],
           outs_rf[4] if not update_phi else jnp.zeros((D,)))
    for a, b, c in zip(outs_kb, outs_fk, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-5)


def test_kblocked_auto_block_width_under_tiny_budget():
    """kb=None picks the width from the budget; a tiny budget forces the
    narrowest block and the outputs stay exact."""
    T, D, K, P = 32, 8, 512, 8
    args, kw = _carry_inputs(7, T, D, K, P, update_phi=True)
    outs_kb = power_sweep_carry(*args, kblocked=True,
                                vmem_budget_bytes=600_000, **kw)
    outs_fk = power_sweep_carry(*args, **kw)
    for a, b in zip(outs_kb, outs_fk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-5)


def test_kblocked_single_block_routes_to_fullk():
    """KB >= K degenerates to the one-pass kernel (same compiled program),
    so outputs are bit-identical to the full-K call."""
    T, D, K, P = 24, 4, 128, 6
    args, kw = _carry_inputs(11, T, D, K, P, update_phi=True)
    outs_kb = power_sweep_carry_kblocked(*args, kb=128, **kw)
    outs_fk = power_sweep_carry(*args, **kw)
    for a, b in zip(outs_kb, outs_fk):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------- tile chooser contract

def test_pow2_tile_bounds_and_monotone():
    budget = K_.vmem_budget()
    assert K_._pow2_tile(0, 4, budget) == 512          # capped at 512
    assert K_._pow2_tile(budget + 1, 4, budget) == 8   # floored at 8
    prev = 512
    for per_tok in (64, 1024, 65536, 2**22):
        tt = K_._pow2_tile(0, per_tok, budget)
        assert 8 <= tt <= prev and tt & (tt - 1) == 0  # power of two
        prev = tt


def test_fit_token_tile_clamps_and_raises():
    assert K_.fit_token_tile(24, 512) == 8     # 24 % 16 != 0 -> floor 8
    assert K_.fit_token_tile(64, 512) == 64
    assert K_.fit_token_tile(96, 64) == 32
    with pytest.raises(ValueError):
        K_.fit_token_tile(12, 512)             # T not a multiple of 8


def test_vmem_budget_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_VMEM_BUDGET_BYTES", raising=False)
    assert K_.vmem_budget() == K_.DEFAULT_VMEM_BUDGET
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "777")
    assert K_.vmem_budget() == 777
    assert K_.vmem_budget(1234) == 1234        # override beats env


def test_kblock_width_ladder():
    # huge budget: widest candidate dividing K
    assert K_.kblock_width(1024, 48, 224, 10**9) == 512
    # tiny budget: falls through to the narrowest divisor
    assert K_.kblock_width(1024, 48, 224, 100_000) == 128
    # K=256 cannot take 512
    assert K_.kblock_width(256, 48, 224, 10**9) == 256
    with pytest.raises(ValueError):
        K_.kblock_width(200, 48, 224)          # K must be lane-padded


def test_both_tile_choosers_share_the_budget(monkeypatch):
    """Satellite: one budget source for the packed chooser and the carry
    chooser — shrinking it via env shrinks both tiles."""
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "200000")
    small_pk = K_.token_tile(128, 48)
    small_ca = K_.carry_token_tile(128, 48, 224)
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", str(K_.DEFAULT_VMEM_BUDGET))
    assert K_.token_tile(128, 48) > small_pk
    assert K_.carry_token_tile(128, 48, 224) > small_ca


# -------------------------------------------------------- dispatch policy

def test_kblocked_policy_on_jnp_is_dense_layout():
    cfg = LDAConfig(vocab_size=100, num_topics=16, sweep_policy="kblocked")
    assert resolve_sweep_policy(cfg, 1000, 16, 8, 5) == "dense_layout"
    cfgp = dataclasses.replace(cfg, impl="pallas")
    assert resolve_sweep_policy(cfgp, 1000, 16, 8, 5) == "kblocked"


def test_pallas_auto_flips_on_vmem_fit():
    """auto -> dense_layout while the full-K carry fits, kblocked beyond;
    the flip is driven purely by the budget."""
    big, small = 10**9, 500_000
    assert carry_vmem_fit(1024, 48, 224, big)
    assert not carry_vmem_fit(1024, 48, 224, small)
    kw = dict(T=4096, K=1024, Pk=16, P=48, crossover=8_000_000,
              impl="pallas", n_docs=224)
    assert _resolve_cached("auto", budget=big, **kw) == "dense_layout"
    assert _resolve_cached("auto", budget=small, **kw) == "kblocked"


def test_cfg_budget_reaches_dispatch():
    cfg = LDAConfig(vocab_size=100, num_topics=1024, impl="pallas",
                    sweep_policy="auto", vmem_budget_bytes=500_000)
    assert resolve_sweep_policy(cfg, 4096, 1024, 16, 48,
                                n_docs=224) == "kblocked"
    cfg2 = dataclasses.replace(cfg, vmem_budget_bytes=None)
    assert resolve_sweep_policy(cfg2, 4096, 1024, 16, 48,
                                n_docs=224) == "dense_layout"


# ------------------------------------------------- end-to-end parity paths

def test_selective_sweep_kblocked_matches_dense_layout():
    """Training inner loop: the kblocked policy computes the dense-layout
    answer (same math, different tiling)."""
    from repro.core.pobp import (selective_sweep_tokens,
                                 selective_sweep_tokens_pallas)
    from repro.core import power as pw

    cfg = LDAConfig(vocab_size=40, num_topics=10, lambda_w=0.2,
                    lambda_k_abs=3, impl="pallas", sweep_policy="kblocked")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    D, L = 8, 14
    wid = jax.random.randint(ks[0], (D, L), 0, cfg.vocab_size).astype(jnp.int32)
    cnt = jax.random.randint(ks[1], (D, L), 0, 3).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    mu = jax.nn.softmax(jax.random.normal(ks[2], (D, L, cfg.num_topics)), -1)
    theta = jnp.einsum("dl,dlk->dk", cnt, mu)
    phi = jax.random.uniform(ks[3], (cfg.vocab_size, cfg.num_topics)) * 5
    r = jax.random.uniform(ks[4], (cfg.vocab_size, cfg.num_topics))
    sel_w = pw.select_power_words(jnp.sum(r, 1), 8)
    sel_k = pw.select_power_topics(r, sel_w, 3)
    lay = batch.token_layout()
    mu_t = mu.reshape(-1, cfg.num_topics)
    outs_ref = selective_sweep_tokens(lay, mu_t, theta, phi, jnp.sum(phi, 0),
                                      sel_w, sel_k, cfg)
    outs_kb = selective_sweep_tokens_pallas(lay, mu_t, theta, phi,
                                            jnp.sum(phi, 0), sel_w, sel_k,
                                            cfg)
    for a, b in zip(outs_ref, outs_kb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_serving_foldin_kblocked_matches_auto():
    """Fixed-phi fold-in: pinning kblocked serves the same theta as the
    default policy (the whole-vocabulary guard row is the n_guard)."""
    from repro.core.infer import fold_in_tokens

    W, K, D, L = 60, 16, 6, 12
    key = jax.random.PRNGKey(3)
    phi = jax.random.uniform(jax.random.PRNGKey(4), (W, K)) + 0.1
    phi = phi / jnp.sum(phi, 0, keepdims=True)
    wid = jax.random.randint(key, (D, L), 0, W).astype(jnp.int32)
    cnt = jax.random.randint(jax.random.PRNGKey(5), (D, L), 0, 3
                             ).astype(jnp.float32)
    batch = MiniBatch(wid, cnt)
    cfg_a = LDAConfig(vocab_size=W, num_topics=K, impl="pallas",
                      sweep_policy="auto")
    cfg_k = dataclasses.replace(cfg_a, sweep_policy="kblocked")
    ra = fold_in_tokens(jax.random.PRNGKey(7), batch, phi, cfg_a, iters=8)
    rk = fold_in_tokens(jax.random.PRNGKey(7), batch, phi, cfg_k, iters=8)
    np.testing.assert_allclose(np.asarray(ra.theta), np.asarray(rk.theta),
                               rtol=1e-5, atol=1e-6)
