"""Production streaming driver regressions (ISSUE 2): comm-meter retrace
idempotence + analytic Eq. 5/6 match, shape-bucketed streaming parity with
a bounded compile count, crash-resume trajectory, prefetch thread
lifecycle, and the power_sync_bytes itemsize fix."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LDAConfig, MiniBatch, init_train_state,
                        make_train_step, run_stream)
from repro.core.sync import dense_sync_bytes, power_sync_bytes
from repro.data import (bucketed_minibatch_stream, docs_to_padded, lda_corpus,
                        minibatch_stream, sharded_minibatch_stream)

W, K = 120, 8
CFG = LDAConfig(vocab_size=W, num_topics=K, lambda_w=0.25, lambda_k_abs=4,
                inner_iters=6, residual_tol=1e-9)


@pytest.fixture(scope="module")
def docs():
    d, _, _ = lda_corpus(0, 64, W, K, doc_len_mean=40)
    return d


# ------------------------------------------------------- comm meter (Eq. 5/6)

def _stream_with_lengths(docs, lengths, num_shards=2):
    chunk = docs[:32]
    for L in lengths:
        b = docs_to_padded(chunk, max_len=L)
        D, Lp = b.word_ids.shape
        yield MiniBatch(
            word_ids=b.word_ids.reshape(num_shards, D // num_shards, Lp),
            counts=b.counts.reshape(num_shards, D // num_shards, Lp))


@pytest.mark.parametrize("mode", ["power", "dense"])
def test_meter_bytes_invariant_under_retrace(docs, mode):
    """A variable-L stream retraces the step; the byte meter must report the
    same per-mini-batch payload as an identical fixed-L stream (the seed
    meter double-counted every psum on retrace: 7680 vs 3840)."""
    _, _, m_fixed = run_stream(_stream_with_lengths(docs, [8, 8, 8]), CFG,
                               num_shards=2, sync_mode=mode)
    _, _, m_var = run_stream(_stream_with_lengths(docs, [8, 16, 8]), CFG,
                             num_shards=2, sync_mode=mode)
    assert m_fixed.bytes_by_phase == m_var.bytes_by_phase
    # dense phase (Fig. 4 lines 9-10): full phi + full r, Eq. 5 payloads
    assert m_var.phase_bytes("dense") == 2 * dense_sync_bytes(W, K)
    if mode == "power":
        P, Pk = CFG.num_power_words, CFG.num_power_topics
        # per power-loop iteration: packed phi + packed r (Eq. 6; the r_w
        # term of power_sync_bytes travels on the model axis, which the
        # simulation's LocalReducer never records)
        assert m_var.phase_bytes("power") == (
            power_sync_bytes(P, Pk, W) - W * 4)
    else:
        assert m_var.phase_bytes("dense_loop") == 2 * dense_sync_bytes(W, K)


def test_per_minibatch_bytes_formula(docs):
    """dense + (iters-1) * sparse (the documented mini-batch total)."""
    _, hist, meter = run_stream(_stream_with_lengths(docs, [8]), CFG,
                                num_shards=2, sync_mode="power")
    iters = hist[0]["iters"]
    by = meter.bytes_by_phase
    once = by["dense"] + by["tokens"]
    assert meter.per_minibatch_bytes(iters) == once + (iters - 1) * by["power"]


def test_per_minibatch_bytes_bills_model_loop_phases_per_iteration():
    """Loop-body model-axis psums carry distinct '*_loop' phase names so
    the dense + (iters-1)*sparse split stays correct on topic-sharded
    meshes (the outer 'model_rw' is once-per-batch, the in-body
    'model_rw_loop' is per-iteration)."""
    from repro.core.sync import CommMeter, MeshReducer

    meter = CommMeter()
    red = MeshReducer("s", meter=meter)

    def shard(x):
        r = red.psum(x, "model_rw", compress=False)        # once per batch
        def body(c):
            # 0.25: the 2-shard psum doubles c, so the carry must shrink
            # by more than 2x per iteration for the loop to terminate
            return red.psum(c, "model_rw_loop", compress=False) * 0.25
        return jax.lax.while_loop(lambda c: jnp.sum(c) > 1e-3, body, r)

    jax.jit(lambda x: jax.vmap(shard, axis_name="s")(x))(jnp.ones((2, 8)))
    assert meter.per_minibatch_bytes(5) == 8 * 4 + 4 * (8 * 4)


def test_meter_max_merges_shape_variant_retraces():
    """Shape-DEPENDENT payloads (e.g. the L-dependent model_norm psum on a
    topic-sharded mesh) across bucket retraces must report what the worst
    single mini-batch pays — not the sum over every bucket variant."""
    from repro.core.sync import CommMeter, MeshReducer

    meter = CommMeter()
    red = MeshReducer("s", meter=meter)

    def fn(x):
        return jax.vmap(lambda y: red.psum(y, "model_norm", compress=False),
                        axis_name="s")(x)

    jit_fn = jax.jit(fn)
    jit_fn(jnp.ones((2, 8)))
    jit_fn(jnp.ones((2, 8)))      # cache hit: no new trace
    jit_fn(jnp.ones((2, 16)))     # bucket retrace: bigger payload
    assert meter.phase_bytes("model_norm") == 16 * 4  # max, not 8*4 + 16*4


def test_make_len_buckets_rejects_non_growing_ladder():
    from repro.data import make_len_buckets

    assert make_len_buckets(50) == (8, 16, 32, 64)
    with pytest.raises(ValueError):
        make_len_buckets(64, growth=1.0)


def test_power_sync_bytes_threads_itemsize():
    """Eq. 6 payloads for sync_dtype=bfloat16: the packed terms honor
    itemsize while the r_w term defaults to float32 width (the repo's
    residual psums are compress=False), overridable via rw_itemsize."""
    P, Pk, Wv = 10, 4, 100
    assert power_sync_bytes(P, Pk, Wv) == 2 * P * Pk * 4 + Wv * 4
    assert power_sync_bytes(P, Pk, Wv, itemsize=2) == 2 * P * Pk * 2 + Wv * 4
    assert power_sync_bytes(P, Pk, Wv, itemsize=2, rw_itemsize=2) == (
        2 * P * Pk * 2 + Wv * 2)


# ------------------------------------------------- shape-bucketed streaming

def _variable_length_corpus():
    """Sequential chunks with very different document lengths, so a
    16-doc mini-batch stream crosses several natural padded shapes."""
    out = []
    for seed, mean in ((1, 10), (2, 30), (3, 55), (4, 12)):
        d, _, _ = lda_corpus(seed, 16, W, K, doc_len_mean=mean)
        out.extend(d)
    return out


def test_bucketed_stream_matches_unbucketed_with_bounded_compiles():
    """Bucketing pads L up to a fixed ladder: phi_acc must agree with the
    natural-shape stream (cfg.init_pad_len makes the random init
    L-invariant; padding slots carry zero counts) while the step compiles
    at most once per bucket instead of once per shape."""
    docs = _variable_length_corpus()
    buckets = (16, 32, 64)
    cfg = LDAConfig(vocab_size=W, num_topics=K, lambda_w=0.25, lambda_k_abs=4,
                    inner_iters=4, residual_tol=0.0, init_pad_len=buckets[-1])

    phi_ref, hist_ref, _ = run_stream(
        sharded_minibatch_stream(docs, 16, num_shards=2), cfg,
        num_shards=2, seed=7)

    step, _ = make_train_step(cfg, num_shards=2)
    state = init_train_state(cfg, seed=7)
    traj = []
    for batch in bucketed_minibatch_stream(docs, 16, num_shards=2,
                                           len_buckets=buckets):
        state, diag = step(state, batch.word_ids, batch.counts)
        traj.append(float(diag["mean_r"]))

    assert step._cache_size() <= len(buckets)
    np.testing.assert_allclose(np.asarray(state.phi_acc),
                               np.asarray(phi_ref), rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(traj, [h["mean_r"] for h in hist_ref],
                               rtol=1e-4, atol=1e-7)


# --------------------------------------------------------- crash-resume

def _driver_args(ckpt_dir=None, **over):
    from repro.launch.lda_train import default_args
    base = dict(minibatches=8, docs_per_batch=16, shards=2, vocab=W, topics=K,
                lambda_k=4, inner_iters=4, tol=1e-9, log_every=0,
                eval_every=0, doc_len_means="10,20,30", len_buckets="16,32",
                ckpt_every=3, seed=3, ckpt_dir=ckpt_dir)
    base.update(over)
    return default_args(**base)


def test_crash_resume_reproduces_trajectory(tmp_path):
    """--crash-at N + rerun must continue from the latest checkpoint and
    reproduce the uninterrupted mean_r trajectory (full state — phi_acc,
    m, RNG, stream cursor — round-trips through repro.dist.checkpoint)."""
    from repro.launch.lda_train import train_loop

    full = train_loop(_driver_args())

    ckdir = str(tmp_path / "ck")
    with pytest.raises(SystemExit):
        train_loop(_driver_args(ckpt_dir=ckdir, crash_at=5))
    # rerun the SAME command: the simulated failure must not re-fire on a
    # resumed run, so this completes
    resumed = train_loop(_driver_args(ckpt_dir=ckdir, crash_at=5))

    assert resumed["first_m"] == 3          # resumed at the m=3 checkpoint
    np.testing.assert_allclose(resumed["mean_r"],
                               full["mean_r"][resumed["first_m"]:],
                               rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(resumed["phi_acc"], full["phi_acc"],
                               rtol=1e-6, atol=1e-7)


def test_resume_rejects_mismatched_flags(tmp_path):
    """A checkpoint written under one (seed, sync) must not be silently
    spliced into a run with different flags."""
    from repro.launch.lda_train import train_loop

    ckdir = str(tmp_path / "ck")
    train_loop(_driver_args(ckpt_dir=ckdir, minibatches=3, ckpt_every=3))
    with pytest.raises(ValueError, match="seed"):
        train_loop(_driver_args(ckpt_dir=ckdir, minibatches=6, seed=99))


# ------------------------------------------------------ prefetch lifecycle

def _alive_prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name == "repro-prefetch" and t.is_alive()]


def test_prefetch_thread_exits_when_stream_abandoned(docs):
    """A consumer that abandons the generator early (crashed driver,
    cancelled request) must not leak the worker: the seed blocked forever
    on q.put with an unreachable t.join."""
    gen = minibatch_stream(docs, 4, prefetch=1)
    next(gen)
    assert _alive_prefetch_threads(), "worker should be running mid-stream"
    gen.close()                      # delivers GeneratorExit
    deadline = time.time() + 5.0
    while _alive_prefetch_threads() and time.time() < deadline:
        time.sleep(0.02)
    assert not _alive_prefetch_threads(), "prefetch worker leaked"


def test_prefetch_stream_still_yields_everything(docs):
    n_direct = sum(1 for _ in minibatch_stream(docs, 8, prefetch=0))
    n_prefetch = sum(1 for _ in minibatch_stream(docs, 8, prefetch=3))
    assert n_direct == n_prefetch == -(-len(docs) // 8)


def test_prefetch_worker_exception_propagates():
    bad = [(None, None)]  # len(None) inside docs_to_padded -> TypeError
    with pytest.raises(TypeError):
        list(minibatch_stream(bad, 1, prefetch=2))


# ------------------------------------------------- shard_map production path

def test_driver_shard_map_backend_smoke():
    """The driver's --backend shard_map executes the SAME per-shard body the
    dryrun cell compiles (make_mesh_shard_fn) on a real (forced-host) mesh.
    Subprocess: the device count must be locked before first jax import."""
    import os
    import subprocess
    import sys

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.lda_train",
         "--backend", "shard_map", "--mesh-shape", "4,2",
         "--minibatches", "2", "--docs-per-batch", "16", "--vocab", "64",
         "--topics", "8", "--lambda-k", "4", "--inner-iters", "3",
         "--log-every", "1", "--no-warmup-buckets"],
        capture_output=True, text=True, timeout=300,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[done] 2 minibatches" in out.stdout
    # topic-sharded phases must appear (model-axis psums are real here),
    # including the per-iteration loop phase billed by per_minibatch_bytes
    assert "model_norm" in out.stdout and "model_rw" in out.stdout
    assert "model_rw_loop" in out.stdout


# ------------------------------------------ PS billing model (DESIGN.md §15)

def test_ps_reducer_splits_w_rows_payloads_into_push_pull_legs():
    """Under the parameter server every W-proportional payload crosses the
    wire twice — delta push + slice pull — and both legs stay w_rows-marked
    so touched-granularity billing (`bytes_by_phase_at`) scales them."""
    from repro.core.sync import CommMeter, LocalReducer, PSReducer

    meter = CommMeter()
    red = PSReducer(LocalReducer(meter=meter))
    assert red.meter is meter                       # inherited from inner
    x = jnp.ones((W, K), jnp.float32)
    out = red.psum(x, "power", compress=False, w_rows=W)
    np.testing.assert_array_equal(out, x)           # single worker: identity
    by = meter.bytes_by_phase
    assert by == {"power.push": W * K * 4, "power.pull": W * K * 4}
    # touched-row billing: pass the measured touched count as live_w
    touched = meter.bytes_by_phase_at(30)
    assert touched["power.push"] == touched["power.pull"] == 30 * K * 4
    # bf16 wire override halves both legs and round-trips the dtype
    out16 = red.psum(x, "dense_loop", dtype=jnp.bfloat16, w_rows=W)
    assert out16.dtype == jnp.float32
    assert meter.phase_bytes("dense_loop.push") == W * K * 2
    assert meter.phase_bytes("dense_loop.pull") == W * K * 2
    # per-topic payloads never live on row-sharded servers; with a single
    # worker (LocalReducer inner) they need no communication at all
    red.psum(jnp.ones((K,)), "model_norm", compress=False)
    assert "model_norm" not in meter.bytes_by_phase


def test_ps_reducer_bills_worker_allreduce_and_dedups_retraces():
    """With several workers (Mesh inner) non-row payloads still need a
    worker all-reduce and bill unchanged; push/pull legs dedup across
    plain retraces and max-merge across shape-bucket variants exactly
    like the allreduce phases they replace."""
    from repro.core.sync import CommMeter, MeshReducer, PSReducer

    red = PSReducer(MeshReducer("s"))
    meter = red.meter

    def run(L):
        def shard(x, y):
            a = red.psum(x, "power", compress=False, w_rows=W)
            b = red.psum(y, "model_norm", compress=False)
            return a, b
        return jax.jit(lambda x, y: jax.vmap(shard, axis_name="s")(x, y))(
            jnp.ones((2, W, K)), jnp.ones((2, L)))

    a, b = run(8)
    np.testing.assert_array_equal(np.asarray(a)[0], np.full((W, K), 2.0))
    np.testing.assert_array_equal(np.asarray(b)[0], np.full((8,), 2.0))
    run(8)                                          # plain retrace: no-op
    run(16)                                         # shape bucket: max-merge
    by = meter.bytes_by_phase
    assert by["power.push"] == by["power.pull"] == W * K * 4
    assert by["model_norm"] == 16 * 4


def test_per_minibatch_bytes_counts_push_pull_legs_as_loop_phases():
    """The power loop's push/pull legs are per-inner-iteration payloads:
    dense + (iters-1) * sparse must bill them (iters-1) times while the
    once-per-batch dense legs bill once."""
    from repro.core.sync import CommMeter, PSReducer, SimReducer

    meter = CommMeter()
    red = PSReducer(SimReducer(meter=meter))
    x = jnp.ones((2, 10, K), jnp.float32)           # leading N=2 shard axis
    out = red.psum(x, "power", compress=False, w_rows=10)
    np.testing.assert_array_equal(np.asarray(out)[0], np.asarray(out)[1])
    red.psum(jnp.ones((2, W, K)), "dense", compress=False, w_rows=W)
    leg_loop, leg_once = 2 * 10 * K * 4, 2 * W * K * 4
    assert meter.per_minibatch_bytes(4) == 2 * leg_once + 3 * 2 * leg_loop


def test_touched_power_sync_bytes_caps_rows_and_threads_itemsize():
    """Touched-W Eq. 6: the packed exchange covers at most min(P, touched)
    rows and the residual leg shrinks to the touched rows."""
    from repro.core.sync import power_sync_bytes, touched_power_sync_bytes

    P, Pk = 50, 8
    assert touched_power_sync_bytes(P, Pk, 20) == 2 * 20 * Pk * 4 + 20 * 4
    # more touched rows than power slots: packed legs cap at P
    assert touched_power_sync_bytes(P, Pk, 90) == 2 * P * Pk * 4 + 90 * 4
    # touching the whole vocabulary degenerates to the dense-W Eq. 6 model
    assert touched_power_sync_bytes(P, Pk, W) == power_sync_bytes(P, Pk, W)
    # compressed payload width threads through the packed legs only
    assert (touched_power_sync_bytes(P, Pk, 20, itemsize=2)
            == 2 * 20 * Pk * 2 + 20 * 4)
