"""Property suite for the sliced PS exchange (ISSUE 8, DESIGN.md §15).

The parameter-server mode's S=0 equivalence with the allreduce backend
rests on one algebraic fact: when every shard's dense delta is zero off
its own touched rows (true by construction for POBP's token-scatter
payloads), summing per-shard TOUCHED-ROW SLICES at the row-sharded
servers reproduces the dense allreduce ``psum`` BIT-EXACTLY — per row,
the same floats add in the same order; rows no shard touched contribute
exactly zero.  These properties pin that fact under

  - arbitrary shard counts, touched sets, and value magnitudes,
  - live-W guard rows (rows >= live_w are structurally zero on every
    shard — the §12 capacity-ladder invariant), and
  - the bf16 sync_dtype wire cast from PR 6 (the cast is applied
    per-shard-payload on both paths, so equality survives compression).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.dist.paramserver import RowShards, sliced_sum


@st.composite
def shard_payloads(draw):
    """(deltas [N, W, K], touched per shard, live_w): dense per-shard
    payloads that are zero off their touched rows and zero on guard
    rows — the exact structure pobp's token-scatter deltas have."""
    w = draw(st.integers(3, 24))
    k = draw(st.integers(1, 6))
    n = draw(st.integers(1, 4))
    live_w = draw(st.integers(1, w))
    deltas, touched = [], []
    for s in range(n):
        n_rows = draw(st.integers(0, live_w))
        rows = np.sort(np.asarray(
            draw(st.lists(st.integers(0, live_w - 1), min_size=n_rows,
                          max_size=n_rows, unique=True)), np.int64))
        d = np.zeros((w, k), np.float32)
        if rows.size:
            vals = draw(st.lists(
                st.floats(-1e4, 1e4, width=32, allow_nan=False),
                min_size=int(rows.size) * k, max_size=int(rows.size) * k))
            d[rows] = np.asarray(vals, np.float32).reshape(rows.size, k)
        deltas.append(d)
        touched.append(rows)
    return deltas, touched, w, live_w


@given(shard_payloads())
@settings(max_examples=60, deadline=None)
def test_union_of_touched_slices_equals_dense_psum(payload):
    """Sliced exchange == dense allreduce, bit for bit, at S=0."""
    deltas, touched, w, live_w = payload
    # the allreduce oracle: lax.psum over a named vmap axis — the exact
    # collective MeshReducer issues in the sim/mesh backends
    stacked = jnp.asarray(np.stack(deltas))
    dense = np.asarray(jax.vmap(lambda d: jax.lax.psum(d, "shards"),
                                axis_name="shards")(stacked))[0]
    ps = sliced_sum(deltas, touched, w)
    np.testing.assert_array_equal(ps, dense)
    # guard rows (>= live_w) stayed identically zero on both paths
    assert not ps[live_w:].any()


@given(shard_payloads())
@settings(max_examples=40, deadline=None)
def test_sliced_psum_survives_bf16_wire_cast(payload):
    """The PR 6 compressed-sync path: each shard's payload crosses the
    wire at bf16 and is upcast before the add.  Applying the SAME cast
    round-trip per shard payload keeps sliced == dense bit-exact — the
    cast commutes with the slicing, not with the sum."""
    deltas, touched, w, live_w = payload
    cast = [np.asarray(jnp.asarray(d).astype(jnp.bfloat16)
                       .astype(jnp.float32)) for d in deltas]
    dense = cast[0].copy()
    for d in cast[1:]:
        dense = dense + d
    np.testing.assert_array_equal(sliced_sum(cast, touched, w), dense)
    assert not sliced_sum(cast, touched, w)[live_w:].any()


@given(st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_row_shards_partition_is_exact(w, n):
    """Every row has exactly one owner; ranges are balanced to one row."""
    rs = RowShards(w, n)
    sizes = [hi - lo for lo, hi in rs.ranges]
    assert sum(sizes) == w
    assert max(sizes) - min(sizes) <= 1
    all_rows = np.arange(w)
    split = rs.split(all_rows)
    covered = np.sort(np.concatenate([v for v in split.values()]))
    np.testing.assert_array_equal(covered, all_rows)
    for s, rows in split.items():
        lo, hi = rs.ranges[s]
        assert ((rows >= lo) & (rows < hi)).all()
