"""Chaos hardening (ISSUE 10, DESIGN.md §17): deterministic fault
injection, PS retry/failover, elastic worker membership, and the slab's
graceful degradation.

The load-bearing claim: at ``--staleness 0`` the committed phi under ANY
eventually-delivering fault schedule (drops, duplicates, delays,
partitions, one crash/restart) is BIT-EXACT with the clean run — every
push applies exactly once (sequence-number idempotence) in the same
version order, and a restarted shard rebuilds from the synced snapshot
plus the client's retained-delta replay (same floats, same add order).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.dist.faults import (ChaosTransport, FaultInjectedError,
                               FaultPlan, _decision_bits)
from repro.dist.paramserver import ParamServer, PSClient, SimTransport
from repro.launch.lda_train import default_args, train_loop


# -------------------------------------------------------------- FaultPlan

def test_fault_plan_decisions_are_pure_and_seeded():
    a = FaultPlan(seed=3, drop_push=0.5, dup_push=0.5, delay_prob=0.5,
                  delay_s=0.1)
    b = FaultPlan(seed=3, drop_push=0.5, dup_push=0.5, delay_prob=0.5,
                  delay_s=0.1)
    fates_a = [a.decide("push", i) for i in range(64)]
    assert fates_a == [b.decide("push", i) for i in range(64)]
    # a different seed reshuffles fates; push and pull draws are distinct
    c = FaultPlan(seed=4, drop_push=0.5)
    assert any(a.decide("push", i).drop != c.decide("push", i).drop
               for i in range(64))
    assert not np.array_equal(_decision_bits(3, "push", 7),
                              _decision_bits(3, "pull", 7))
    # a retry is a NEW op index: some dropped op's successor survives
    assert any(f.drop for f in fates_a) and any(not f.drop for f in fates_a)


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="drop_push"):
        FaultPlan(drop_push=1.0)            # total drop = no eventual delivery
    with pytest.raises(ValueError, match="together"):
        FaultPlan(crash_server=1)
    with pytest.raises(ValueError, match="partition"):
        FaultPlan(partitions=(("push", 5, 2),))
    with pytest.raises(ValueError, match="SERVER@PUSHOP"):
        FaultPlan.parse_crash("nonsense")
    assert FaultPlan.parse_crash("1@6") == (1, 6)
    assert FaultPlan.parse_crash("") == (None, None)
    assert not FaultPlan().active
    assert FaultPlan(drop_pull=0.1).active


def test_partition_window_drops_every_op_inside():
    plan = FaultPlan(partitions=(("push", 2, 5),))
    assert [plan.decide("push", i).drop for i in range(7)] == \
        [False, False, True, True, True, False, False]
    assert not plan.decide("pull", 3).drop


# -------------------------------------------- transport-level parity

def _run_workload(transport, server, *, n_batches=8, w=12, k=3, seed=0,
                  sync_at=(), staleness=0, client_id="w0"):
    """A tiny deterministic push/pull workload; returns (client, phi)."""
    rng = np.random.default_rng(seed)
    phi = jnp.zeros((w, k))
    client = PSClient(transport, staleness=staleness, client_id=client_id,
                      retry_deadline_s=10.0, backoff0_s=1e-4,
                      backoff_max_s=2e-3)
    for m in range(1, n_batches + 1):
        rows = np.sort(rng.choice(w, size=4, replace=False))
        phi = client.begin_batch(m, rows, phi)
        delta = rng.normal(size=(4, k)).astype(np.float32)
        phi = phi.at[jnp.asarray(rows)].add(jnp.asarray(delta))
        client.end_batch(m, phi, rows)
        if m in sync_at:
            client.flush()
            server.mark_synced()
            client.mark_durable()
    client.flush()
    return client


def _committed_phi(plan=None, **kw):
    server = ParamServer(np.zeros((12, 3), np.float32), num_servers=3,
                         pull_timeout=5.0)
    inner = SimTransport(server)
    transport = inner if plan is None else ChaosTransport(inner, plan)
    client = _run_workload(transport, server, **kw)
    phi, version = server.snapshot()
    stats = client.stats()
    transport.close()
    return phi, version, stats, server, transport


def test_drops_retry_to_bitexact_parity():
    clean, v0, _, _, _ = _committed_phi()
    plan = FaultPlan(seed=7, drop_push=0.4, drop_pull=0.4)
    chaos, v1, stats, server, _ = _committed_phi(plan=plan)
    assert v1 == v0
    np.testing.assert_array_equal(chaos, clean)
    assert stats["retries"] > 0
    assert server.duplicates_dropped == 0   # a dropped push never arrived


def test_duplicates_are_deduped_bitexact():
    clean, _, _, _, _ = _committed_phi()
    plan = FaultPlan(seed=1, dup_push=1.0)  # EVERY push delivered twice
    chaos, _, _, server, t = _committed_phi(plan=plan)
    np.testing.assert_array_equal(chaos, clean)
    # a duplicated push dedups once per shard it addressed, so the
    # shard-level counter is at least the op-level event count
    assert server.duplicates_dropped >= t.event_counts()["duplicate"] > 0


def test_crash_restart_replay_reaches_bitexact_parity():
    clean, v0, _, _, _ = _committed_phi(sync_at=(4,))
    plan = FaultPlan(seed=2, drop_push=0.25, dup_push=0.25,
                     crash_server=1, crash_at_push=6)
    chaos, v1, stats, server, t = _committed_phi(plan=plan, sync_at=(4,))
    assert v1 == v0
    np.testing.assert_array_equal(chaos, clean)
    assert stats["recoveries"] >= 1 and stats["replayed_pushes"] > 0
    events = [e["event"] for e in server.recovery_log]
    assert events[:2] == ["crash", "restart"] and "recovered" in events
    counts = t.event_counts()
    assert counts["crash"] == 1 and counts["restart"] == 1


def test_partitioned_client_retries_through_the_window():
    clean, _, _, _, _ = _committed_phi()
    plan = FaultPlan(partitions=(("push", 1, 4), ("pull", 2, 5)))
    chaos, _, stats, _, _ = _committed_phi(plan=plan)
    np.testing.assert_array_equal(chaos, clean)
    assert stats["retries"] > 0


def test_retry_deadline_raises_a_named_timeout():
    server = ParamServer(np.zeros((6, 2), np.float32), pull_timeout=0.2)
    # a permanent partition: every push fails until the deadline
    plan = FaultPlan(partitions=(("push", 0, 10**9),))
    t = ChaosTransport(SimTransport(server), plan)
    client = PSClient(t, staleness=0, client_id="w9",
                      retry_deadline_s=0.05, backoff0_s=1e-3,
                      backoff_max_s=1e-2)
    rows = np.array([1])
    phi = client.begin_batch(1, rows, jnp.zeros((6, 2)))
    with pytest.raises(TimeoutError, match="w9"):
        client.end_batch(1, phi.at[jnp.asarray(rows)].add(1.0), rows)
        client.flush()
    t.close()


def test_retry_wire_bytes_are_billed_on_top_of_clean():
    clean_t_bytes = _committed_phi()[4].total_bytes
    # drops die at the injection boundary (the payload never reaches a
    # server), so the SERVER-side wire matches clean and the retry cost
    # shows up in the client's host-side retry meter instead
    plan = FaultPlan(seed=7, drop_push=0.4, drop_pull=0.4)
    _, _, stats, _, t = _committed_phi(plan=plan)
    assert stats["retry_wire_bytes"] > 0
    assert t.total_bytes == clean_t_bytes
    # duplicates DO reach the servers: measured wire exceeds clean
    _, _, _, _, t2 = _committed_phi(plan=FaultPlan(seed=1, dup_push=1.0))
    assert t2.total_bytes > clean_t_bytes


# ----------------------------------------- eventual-delivery property

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                    # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    @settings(max_examples=10, deadline=None)
    @given(drop=st.floats(0.0, 0.6), dup=st.floats(0.0, 1.0),
           seed=st.integers(0, 1000),
           crash=st.sampled_from([None, (0, 3), (2, 5)]))
    def test_any_eventually_delivering_schedule_is_bitexact(drop, dup,
                                                            seed, crash):
        """The §17 pin as a property: any (drop < 1, dup, crash/restart)
        schedule commits the SAME phi as the clean run at S=0."""
        clean, v0, _, _, _ = _committed_phi(n_batches=5, sync_at=(2,))
        plan = FaultPlan(seed=seed, drop_push=drop, drop_pull=drop,
                         dup_push=dup,
                         crash_server=None if crash is None else crash[0],
                         crash_at_push=None if crash is None else crash[1])
        chaos, v1, _, _, _ = _committed_phi(plan=plan, n_batches=5,
                                            sync_at=(2,))
        assert v1 == v0
        np.testing.assert_array_equal(chaos, clean)


# ------------------------------------------------ driver integration

def _common(**kw):
    base = dict(minibatches=8, docs_per_batch=16, vocab=200, topics=8,
                lambda_k=4, inner_iters=5, log_every=0, shards=2, seed=11,
                backend="ps", staleness=0, ps_servers=3)
    base.update(kw)
    return base


def test_driver_rejects_chaos_without_ps_backend():
    with pytest.raises(ValueError, match="backend ps"):
        train_loop(default_args(**_common(backend="sim"), chaos_drop=0.1))
    with pytest.raises(ValueError, match="staleness 0"):
        train_loop(default_args(**_common(staleness=2),
                                elastic_events="join:w1@2"))
    # server-crash recovery replays ONE client's retained log, so the
    # driver refuses crash schedules with multiple/elastic workers
    with pytest.raises(ValueError, match="single"):
        train_loop(default_args(**_common(), chaos_crash="1@6",
                                elastic_workers="w0,w1"))


@pytest.mark.chaos
def test_driver_chaos_run_is_bitexact_with_clean_ps():
    """The acceptance pin: a seeded ChaosTransport schedule with drops,
    duplicates and one server crash/restart reaches bit-exact phi parity
    with the clean PS run at --staleness 0."""
    clean = train_loop(default_args(**_common()))
    chaos = train_loop(default_args(**_common(), chaos_seed=5,
                                    chaos_drop=0.3, chaos_dup=0.3,
                                    chaos_crash="1@6",
                                    chaos_restart_after=2))
    np.testing.assert_array_equal(np.asarray(chaos["phi_acc"]),
                                  np.asarray(clean["phi_acc"]))
    np.testing.assert_array_equal(chaos["mean_r"], clean["mean_r"])
    assert chaos["ps_retries"] > 0
    assert chaos["chaos_events"].get("drop", 0) > 0
    assert chaos["chaos_events"].get("crash", 0) == 1
    assert [e["event"] for e in chaos["ps_recovery_log"]].count(
        "recovered") >= 1


@pytest.mark.chaos
def test_driver_elastic_membership_is_bitexact_with_clean_ps():
    """Workers join/leave mid-stream and one crashes right after its
    batch: the survivor replays the un-pushed segment, and the committed
    trajectory matches the static single-worker run exactly (S=0: the
    same deltas commit in the same order, whoever pushes them)."""
    kw = _common(minibatches=12)
    clean = train_loop(default_args(**kw))
    elastic = train_loop(default_args(
        **kw, elastic_workers="w0,w1",
        elastic_events="join:w2@3,leave:w0@6,crash:w2@9"))
    np.testing.assert_array_equal(np.asarray(elastic["phi_acc"]),
                                  np.asarray(clean["phi_acc"]))
    np.testing.assert_array_equal(elastic["mean_r"], clean["mean_r"])
    # w0 left, w2 crashed: only w1 is still an active member at the end
    assert elastic["ps_workers"] == ["w1"]
    kinds = [e["event"] for e in elastic["elastic_log"]]
    assert kinds.count("join") == 1 and kinds.count("leave") == 1
    assert kinds.count("crash") == 1
    crash = next(e for e in elastic["elastic_log"] if e["event"] == "crash")
    assert crash["worker"] == "w2"


# ------------------------------------------------ slab degradation

def _tiny_engine(**kw):
    from repro.core.types import LDAConfig
    from repro.serve import SlabEngine

    cfg = LDAConfig(vocab_size=32, num_topics=4, alpha=0.1, beta=0.01)
    phi = np.abs(np.random.default_rng(0).normal(
        size=(32, 4))).astype(np.float32) + 0.1
    return SlabEngine(phi, cfg, slots=4, slot_len=8, sweeps_per_step=2,
                      fold_iters=8, residual_tol=1e-9, warmup=True, **kw)


def test_slab_sheds_typed_result_when_slo_blown():
    from repro.serve import Shed

    eng = _tiny_engine(admission_slo_s=1e-9)
    rng = np.random.default_rng(1)
    doc = lambda: (rng.integers(0, 32, size=6).astype(np.int32),
                   np.ones(6, np.float32))
    # cold engine (no measured step yet) always admits
    assert isinstance(eng.submit(doc()), int)
    eng.step()
    sheds = []
    for _ in range(12):
        out = eng.submit(doc())
        if isinstance(out, Shed):
            sheds.append(out)
        eng.step()
    assert sheds, "an impossible SLO must shed under sustained load"
    s = sheds[0]
    assert s.est_wait_s > s.slo_s == pytest.approx(1e-9)
    eng.drain()
    st = eng.stats()
    assert st["shed"] == len(sheds) and 0 < st["shed_frac"] < 1
    # served results never include sheds
    assert st["served"] + st["shed"] == 13


def test_slab_without_slo_never_sheds():
    eng = _tiny_engine()
    rng = np.random.default_rng(2)
    for _ in range(10):
        assert isinstance(eng.submit(
            (rng.integers(0, 32, size=6).astype(np.int32),
             np.ones(6, np.float32))), int)
    res = eng.drain()
    assert len(res) == 10 and all(r.error is None for r in res)
    assert eng.stats()["shed"] == 0


def test_slab_quarantines_nonfinite_input():
    eng = _tiny_engine()
    bad = (np.arange(4, dtype=np.int32),
           np.array([1.0, np.nan, 1.0, np.inf], np.float32))
    rid = eng.submit(bad)
    res = eng.poll()
    assert len(res) == 1 and res[0].req_id == rid
    assert res[0].error == "nonfinite_input"
    # the quarantine theta is the finite flat prior, not garbage
    assert np.isfinite(res[0].theta).all()
    assert eng.stats()["quarantined"] == 1
    # the slab stays healthy: a normal doc still serves cleanly
    eng.submit((np.arange(4, dtype=np.int32), np.ones(4, np.float32)))
    ok = eng.drain()
    assert len(ok) == 1 and ok[0].error is None


def test_slab_quarantines_nonfinite_theta_and_skips_cache():
    from repro.core.types import LDAConfig
    from repro.serve import SlabEngine

    cfg = LDAConfig(vocab_size=16, num_topics=4, alpha=0.1, beta=0.01)
    phi = np.full((16, 4), 0.5, np.float32)
    phi[3] = np.nan                       # one poisoned phi row
    eng = SlabEngine(phi, cfg, slots=2, slot_len=4, sweeps_per_step=2,
                     fold_iters=4, residual_tol=1e-9, warmup=False,
                     theta_cache=8)
    doc = (np.array([3, 5], np.int32), np.ones(2, np.float32))
    eng.submit(doc, tenant="t")
    res = eng.drain()
    assert len(res) == 1
    assert res[0].error == "nonfinite_theta"
    assert eng.stats()["quarantined"] == 1
    # the poisoned theta never entered the cache: a repeat request is a
    # miss, not a cached NaN serve
    eng.submit(doc, tenant="t")
    res2 = eng.drain()
    assert res2[0].cached is False


# ------------------------------------------------ prefetch shutdown

def test_prefetch_worker_error_warns_when_masked_by_shutdown():
    from repro.data.batching import prefetched

    def gen_factory():
        yield 1
        raise RuntimeError("boom in worker")

    it = prefetched(gen_factory, prefetch=2)
    assert next(it) == 1
    with pytest.warns(RuntimeWarning, match="masked by consumer shutdown"):
        it.close()                        # GeneratorExit path


def test_prefetch_worker_error_raises_when_fully_consumed():
    from repro.data.batching import prefetched

    def gen_factory():
        yield 1
        raise RuntimeError("boom in worker")

    it = prefetched(gen_factory, prefetch=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="boom in worker"):
        list(it)
