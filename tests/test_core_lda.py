"""Core correctness: POBP vs oracles, algorithm invariants, paper claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, MiniBatch, make_sim_minibatch_fn, run_stream
from repro.core import perplexity, power, ref
from repro.core.pobp import selective_sweep
from repro.core.sync import dense_sync_bytes, power_sync_bytes
from repro.data import (docs_to_padded, lda_corpus, minibatch_stream,
                        sharded_minibatch_stream, train_test_split_counts)

CFG = LDAConfig(vocab_size=120, num_topics=8, lambda_w=0.3, lambda_k_abs=4,
                inner_iters=8, residual_tol=1e-6)


def small_corpus(seed=0, docs=64, W=120, K=8):
    d, stats, true_phi = lda_corpus(seed, docs, W, K, doc_len_mean=50)
    return d, true_phi


@pytest.fixture(scope="module")
def corpus():
    return small_corpus()


# ------------------------------------------------------------------ oracles

def test_pobp_n1_dense_equals_batch_bp_oracle(corpus):
    """N=1, M=1, dense mode must match the pure-jnp batch BP oracle exactly
    (paper §3.2: 'If N=1, POBP reduces to OBP'; 'If M=1 ... batch BP')."""
    docs, _ = corpus
    batch = docs_to_padded(docs)
    cfg = CFG
    key = jax.random.PRNGKey(7)

    fn, _ = make_sim_minibatch_fn(cfg, num_shards=1, sync_mode="dense")
    phi_new, iters, mean_r, mu, theta = fn(
        batch.word_ids, batch.counts,
        jnp.zeros((cfg.vocab_size, cfg.num_topics)), key, jnp.float32(1.0))

    mu_ref, phi_ref, theta_ref, _ = ref.batch_bp(key, batch, cfg,
                                                 iters=int(iters))
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref),
                               rtol=2e-5, atol=2e-6)
    # oracle stores phi as [K, W]; POBP uses [W, K]
    np.testing.assert_allclose(np.asarray(phi_new), np.asarray(phi_ref).T,
                               rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_ref),
                               rtol=2e-5, atol=2e-4)


def test_pobp_shards_agree_on_global_state(corpus):
    """Every data shard must end a mini-batch with an identical phi_acc —
    the synchronized-global-matrix invariant of Eq. (4)."""
    docs, _ = corpus
    stream = sharded_minibatch_stream(docs, 32, num_shards=4)
    fn, _ = make_sim_minibatch_fn(CFG, num_shards=4, sync_mode="power")
    batch = next(iter(stream))
    phi_new, *_ = fn(batch.word_ids, batch.counts,
                     jnp.zeros((CFG.vocab_size, CFG.num_topics)),
                     jax.random.PRNGKey(0), jnp.float32(1.0))
    assert phi_new.shape[0] == 4
    for n in range(1, 4):
        np.testing.assert_allclose(np.asarray(phi_new[0]),
                                   np.asarray(phi_new[n]), rtol=1e-6, atol=1e-6)


def test_dense_vs_power_converge_to_similar_perplexity(corpus):
    """The paper's core accuracy claim: sparse power sync (Eq. 6) must not
    cost much accuracy vs dense sync (Eq. 4) at lambda_w ~ 0.3."""
    docs, _ = corpus
    train, test = train_test_split_counts(docs, 0)
    cfg = LDAConfig(vocab_size=120, num_topics=8, lambda_w=0.3, lambda_k_abs=6,
                    inner_iters=15, residual_tol=0.01)
    out = {}
    for mode in ("dense", "power"):
        phi, _, _ = run_stream(sharded_minibatch_stream(train, 32, 4), cfg,
                               num_shards=4, sync_mode=mode, seed=3)
        out[mode] = perplexity.evaluate(jax.random.PRNGKey(5), phi,
                                        docs_to_padded(train),
                                        docs_to_padded(test), cfg)
    assert out["power"] < 1.30 * out["dense"], out


# ------------------------------------------------------------- invariants

def test_selective_sweep_preserves_normalization_and_untouched_entries():
    key = jax.random.PRNGKey(0)
    cfg = LDAConfig(vocab_size=40, num_topics=10, lambda_w=0.2, lambda_k_abs=3)
    D, L = 6, 12
    wid = jax.random.randint(key, (D, L), 0, cfg.vocab_size).astype(jnp.int32)
    cnt = jnp.ones((D, L), jnp.float32)
    batch = MiniBatch(wid, cnt)
    mu = jax.nn.softmax(jax.random.normal(key, (D, L, cfg.num_topics)), -1)
    theta = jnp.einsum("dl,dlk->dk", cnt, mu)
    phi = jax.random.uniform(key, (cfg.vocab_size, cfg.num_topics)) * 5
    phi_tot = jnp.sum(phi, 0)
    sel_w = jnp.asarray([3, 17, 29, 5, 11, 22, 8, 0], jnp.int32)
    sel_k = jnp.tile(jnp.asarray([[1, 4, 7]], jnp.int32), (8, 1))

    mu2, theta2, dpack, rpack = selective_sweep(batch, mu, theta, phi, phi_tot,
                                                sel_w, sel_k, cfg)
    # normalization is conserved
    np.testing.assert_allclose(np.asarray(jnp.sum(mu2, -1)), 1.0, atol=1e-5)
    # non-power tokens untouched
    in_power = np.isin(np.asarray(wid), np.asarray(sel_w))
    np.testing.assert_array_equal(np.asarray(mu2)[~in_power],
                                  np.asarray(mu)[~in_power])
    # unselected topic coords untouched even for power tokens
    unsel = np.setdiff1d(np.arange(cfg.num_topics), np.asarray(sel_k[0]))
    np.testing.assert_array_equal(np.asarray(mu2)[..., unsel],
                                  np.asarray(mu)[..., unsel])
    # theta consistent with messages
    np.testing.assert_allclose(np.asarray(theta2),
                               np.asarray(jnp.einsum("dl,dlk->dk", cnt, mu2)),
                               rtol=1e-5, atol=1e-5)
    # residual pack is the |delta| scatter
    assert float(jnp.sum(rpack)) >= float(jnp.abs(jnp.sum(dpack)))


def test_two_step_selection_matches_numpy():
    key = jax.random.PRNGKey(1)
    r = jax.random.uniform(key, (50, 16))
    r_w = jnp.sum(r, 1)
    sel_w = power.select_power_words(r_w, 10)
    np_top = np.argsort(-np.asarray(r_w))[:10]
    assert set(np.asarray(sel_w).tolist()) == set(np_top.tolist())
    sel_k = power.select_power_topics(r, sel_w, 4)
    for i, w in enumerate(np.asarray(sel_w)):
        expect = set(np.argsort(-np.asarray(r)[w])[:4].tolist())
        assert set(np.asarray(sel_k)[i].tolist()) == expect


def test_pack_scatter_roundtrip():
    key = jax.random.PRNGKey(2)
    mat = jax.random.normal(key, (30, 12))
    sel_w = jnp.asarray([4, 9, 0, 22], jnp.int32)
    sel_k = jnp.asarray([[0, 3], [1, 2], [5, 7], [10, 11]], jnp.int32)
    packed = power.pack_rows(mat, sel_w, sel_k)
    again = power.pack_rows(power.scatter_set_rows(jnp.zeros_like(mat), sel_w,
                                                   sel_k, packed), sel_w, sel_k)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(again))
    added = power.scatter_add_rows(mat, sel_w, sel_k, packed)
    np.testing.assert_allclose(np.asarray(power.pack_rows(added, sel_w, sel_k)),
                               np.asarray(packed) * 2, rtol=1e-6)


def test_tokens_from_batch_matches_loop_reference(corpus):
    """The np.repeat vectorization of gibbs.tokens_from_batch must emit
    token arrays identical (order included) to the per-token double loop
    it replaced — the setup bottleneck of the accuracy benchmark."""
    from repro.core.gibbs import tokens_from_batch

    def reference(batch):
        wid = np.asarray(batch.word_ids)
        cnt = np.asarray(batch.counts).astype(np.int64)
        docs, words = [], []
        for d in range(wid.shape[0]):
            for l in range(wid.shape[1]):
                c = int(cnt[d, l])
                if c > 0:
                    docs.extend([d] * c)
                    words.extend([int(wid[d, l])] * c)
        return np.asarray(docs, np.int32), np.asarray(words, np.int32)

    docs, _ = corpus
    for batch in (docs_to_padded(docs),
                  docs_to_padded(docs[:3], max_len=8),
                  MiniBatch(jnp.zeros((2, 4), jnp.int32),
                            jnp.zeros((2, 4), jnp.float32))):
        got_d, got_w = tokens_from_batch(batch)
        ref_d, ref_w = reference(batch)
        np.testing.assert_array_equal(got_d, ref_d)
        np.testing.assert_array_equal(got_w, ref_w)
        assert got_d.dtype == np.int32 and got_w.dtype == np.int32


# ----------------------------------------------------- communication claims

def test_comm_bytes_follow_eq5_and_eq6(corpus):
    """The byte meter must reproduce the paper's complexity expressions."""
    docs, _ = corpus
    cfg = LDAConfig(vocab_size=120, num_topics=8, lambda_w=0.25, lambda_k_abs=4,
                    inner_iters=6, residual_tol=1e-9)
    stream = sharded_minibatch_stream(docs, 32, 4)
    fn, meter = make_sim_minibatch_fn(cfg, 4, "power")
    b = next(iter(stream))
    fn(b.word_ids, b.counts, jnp.zeros((120, 8)), jax.random.PRNGKey(0),
       jnp.float32(1.0))
    P, Pk = cfg.num_power_words, cfg.num_power_topics
    # per power-loop iteration: packed phi + packed r  (r_w sync is model-axis)
    assert meter.phase_bytes("power") == 2 * P * Pk * 4
    # dense phase: full phi + full r once (Fig. 4 lines 9-10)
    assert meter.phase_bytes("dense") == 2 * 120 * 8 * 4
    assert power_sync_bytes(P, Pk, 120) < dense_sync_bytes(120, 8)


def test_bf16_sync_halves_bytes(corpus):
    docs, _ = corpus
    cfg = CFG
    stream = sharded_minibatch_stream(docs, 32, 4)
    fn, meter = make_sim_minibatch_fn(cfg, 4, "power", sync_dtype=jnp.bfloat16)
    b = next(iter(stream))
    fn(b.word_ids, b.counts, jnp.zeros((cfg.vocab_size, cfg.num_topics)),
       jax.random.PRNGKey(0), jnp.float32(1.0))
    P, Pk = cfg.num_power_words, cfg.num_power_topics
    assert meter.phase_bytes("power") == 2 * P * Pk * 2  # half of fp32


# ------------------------------------------------------------ end-to-end

def test_learning_recovers_topics_beats_random(corpus):
    docs, true_phi = corpus
    train, test = train_test_split_counts(docs, 0)
    cfg = LDAConfig(vocab_size=120, num_topics=8, lambda_w=0.3, lambda_k_abs=6,
                    inner_iters=15, residual_tol=0.01)
    phi, hist, _ = run_stream(sharded_minibatch_stream(train, 32, 4), cfg,
                              num_shards=4, sync_mode="power", seed=11)
    key = jax.random.PRNGKey(5)
    ppl = perplexity.evaluate(key, phi, docs_to_padded(train),
                              docs_to_padded(test), cfg)
    ppl_rand = perplexity.evaluate(key, jnp.zeros_like(phi),
                                   docs_to_padded(train), docs_to_padded(test),
                                   cfg)
    assert ppl < 0.6 * ppl_rand, (ppl, ppl_rand)
    assert not np.isnan(ppl)


def test_residual_decreases_within_minibatch(corpus):
    """Fig. 5: the residual is a convergence signal — it must decrease."""
    docs, _ = corpus
    batch = docs_to_padded(docs)
    cfg = LDAConfig(vocab_size=120, num_topics=8, inner_iters=10,
                    residual_tol=1e-9)
    _, _, _, trace = ref.batch_bp(jax.random.PRNGKey(0), batch, cfg, iters=60)
    tr = np.asarray(trace)
    # early iterations may oscillate while topics differentiate; by iter 60
    # the residual must be far below its early level (Fig. 5 shape).
    assert tr[-1] < tr[1] * 0.1, tr[::5]
