"""Stream-lifecycle runtime (ISSUE 7, DESIGN.md §14): Robbins-Monro
decay on the phi fold-back, checkpoint-fenced dead-row compaction +
capacity shrink, topic recycling, the manifest-versioned row-remap
restore, crash-resume across a compaction fence, and version-stamped
phi hot-swap in the serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, init_train_state, lifecycle
from repro.core.pobp import _decay_factor
from repro.core.types import LDATrainState
from repro.data.vocab import VocabMap

K = 8


def _args(**over):
    from repro.launch.lda_train import default_args
    base = dict(dynamic_vocab=True, drift_mode="slide", minibatches=9,
                docs_per_batch=16, shards=2, vocab=64,
                vocab_growth_per_batch=8, w_cap_min=64, w_growth=2.0,
                topics=K, lambda_k=4, inner_iters=4, tol=1e-9,
                log_every=0, eval_every=0, len_buckets="16,32",
                doc_len_means="10,20,30", seed=3)
    base.update(over)
    return default_args(**base)


# --------------------------------------------------------- decay schedule

def test_decay_factor_schedule():
    """rho_m = (tau0 + m)^-kappa; the fold-back retains 1 - rho_m.
    kappa=0 returns None — the STATIC disable that keeps the legacy
    fold-back expression (and its lowering) bit-exact."""
    cfg0 = LDAConfig(vocab_size=32, num_topics=K, decay_tau0=1.0,
                     decay_kappa=0.0)
    assert _decay_factor(cfg0, jnp.asarray(7, jnp.int32)) is None

    cfg = LDAConfig(vocab_size=32, num_topics=K, decay_tau0=4.0,
                    decay_kappa=0.5)
    for m in (1, 5, 40):
        got = float(_decay_factor(cfg, jnp.asarray(m, jnp.int32)))
        np.testing.assert_allclose(got, 1.0 - (4.0 + m) ** -0.5, rtol=1e-6)
    # early stream forgets aggressively, late stream barely
    assert float(_decay_factor(cfg, jnp.asarray(1, jnp.int32))) < \
        float(_decay_factor(cfg, jnp.asarray(100, jnp.int32)))


def test_kappa_zero_compact_zero_is_bit_exact():
    """ACCEPTANCE (ISSUE 7): --decay 1,0 --compact-every 0 must be
    BIT-exact with the plain accumulator driver — same mean_r floats,
    same phi_acc bits: kappa=0 compiles the pre-lifecycle step (no decay
    operand in the jaxpr at all)."""
    from repro.launch.lda_train import train_loop

    plain = train_loop(_args(minibatches=6))
    gated = train_loop(_args(minibatches=6, decay="1,0", compact_every=0))
    assert gated["mean_r"] == plain["mean_r"]          # exact, not allclose
    np.testing.assert_array_equal(gated["phi_acc"], plain["phi_acc"])
    assert gated["live_w"] == plain["live_w"]
    assert gated["vocab_version"] == plain["vocab_version"] == 0


def test_decay_fades_retired_row_mass():
    """On a sliding stream, RM decay shrinks the statistic of retired
    (no-longer-occurring) words relative to the plain accumulator —
    the signal the dead-row test needs to ever fire."""
    from repro.launch.lda_train import train_loop

    plain = train_loop(_args())
    decayed = train_loop(_args(decay="1,0.5"))
    # rows 0..7 are the first-admitted words, retired early by the slide
    old_plain = plain["phi_acc"][:8].sum()
    old_decay = decayed["phi_acc"][:8].sum()
    assert old_decay < 0.5 * old_plain, (old_decay, old_plain)


# --------------------------------------------------- resize + row remap

def test_resize_state_grow_shrink_and_fence():
    cfg = LDAConfig(vocab_size=64, num_topics=K)
    s = init_train_state(cfg, 0)
    g = lifecycle.resize_state(s, 128)
    assert g.phi_acc.shape == (128, K)
    assert lifecycle.resize_state(g, 128) is g         # same rung: no-op
    with pytest.raises(ValueError, match="shrink"):
        lifecycle.resize_state(g, 64)                  # no fence proof
    with pytest.raises(ValueError, match="strictly above"):
        lifecycle.resize_state(g, 64, live_w=64)       # guard-row invariant
    back = lifecycle.resize_state(g, 72, live_w=60)
    assert back.phi_acc.shape == (72, K)
    assert back.phi_acc.dtype == s.phi_acc.dtype
    assert int(back.m) == int(s.m)
    np.testing.assert_array_equal(np.asarray(back.rng), np.asarray(s.rng))


def test_apply_row_remap_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    W = 24
    phi = rng.gamma(1.0, size=(W, K)).astype(np.float32)
    s = LDATrainState(phi_acc=jnp.asarray(phi),
                      m=jnp.asarray(3, jnp.int32), rng=jax.random.PRNGKey(0))
    keep = rng.random(16) > 0.4                        # rows 16.. kept
    v = VocabMap(list(range(16)))
    remap = v.compact(keep)
    out = lifecycle.apply_row_remap(s, remap)

    oracle = np.zeros_like(phi)
    for i, r in enumerate(remap):
        if r >= 0:
            oracle[r] = phi[i]
    np.testing.assert_array_equal(np.asarray(out.phi_acc), oracle)
    # vacated tail + dead rows are zero guard rows again
    n_live = int((remap >= 0).sum())
    assert np.abs(np.asarray(out.phi_acc)[n_live:]).max() == 0.0
    with pytest.raises(ValueError, match="remap covers"):
        lifecycle.apply_row_remap(s, np.zeros(W + 1, np.int32))


def test_dead_rows_needs_both_signals():
    """Idle alone is resting; low-mass alone is a rare-but-live word —
    only the conjunction reclaims."""
    mass = np.asarray([0.1, 9.0, 0.1, 9.0])
    touched = np.asarray([0, 0, 9, 9])
    got = lifecycle.dead_rows(mass, touched, step=10, min_idle=5,
                              mass_floor=1.0)
    np.testing.assert_array_equal(got, [True, False, False, False])


# ------------------------------------------------------- vocab compaction

def test_vocab_compact_remap_and_touched_roundtrip():
    v = VocabMap()
    for m, key in enumerate(["a", "b", "c", "d", "e"]):
        v.admit(key, step=m)
    assert v.touched_upto(5) == [0, 1, 2, 3, 4]
    v.admit("b", step=9)                               # max-merge re-touch
    assert v.touched_upto(5)[1] == 9

    remap = v.compact([True, False, True])             # rows 3.. auto-kept
    np.testing.assert_array_equal(remap, [0, -1, 1, 2, 3])
    assert v.to_state() == ["a", "c", "d", "e"]
    assert v.touched_upto(4) == [0, 2, 3, 4]
    # freed rows return to the pool: next admission reuses them densely
    assert v.admit("f", step=5) == 4
    assert v.lookup("b") is None

    # the (keys, touched) manifest payload round-trips
    again = VocabMap.from_state(v.to_state(), touched=v.touched_upto(len(v)))
    assert again.to_state() == v.to_state()
    assert again.touched_upto(len(again)) == v.touched_upto(len(v))


def test_vocab_compact_is_deterministic():
    a, b = VocabMap(list("abcdef")), VocabMap(list("abcdef"))
    keep = [True, False, False, True, True, False]
    np.testing.assert_array_equal(a.compact(keep), b.compact(keep))
    assert a.to_state() == b.to_state() == ["a", "d", "e"]


# --------------------------------------------- checkpoint row-remap restore

def test_compact_then_restore_equals_restore_then_compact(tmp_path):
    """ACCEPTANCE (ISSUE 7): the manifest row-remap restore commutes with
    device-side compaction — restoring a pre-compaction checkpoint
    through ``row_remaps`` lands on exactly the state the fenced
    compaction produced."""
    from repro.dist import checkpoint as ckpt

    rng = np.random.default_rng(1)
    phi = rng.gamma(1.0, size=(64, K)).astype(np.float32)
    s = LDATrainState(phi_acc=jnp.asarray(phi),
                      m=jnp.asarray(4, jnp.int32), rng=jax.random.PRNGKey(2))
    v = VocabMap(list(range(40)))
    keep = rng.random(40) > 0.3
    remap = v.compact(keep)

    # compact-then-(save+restore)
    compacted = lifecycle.apply_row_remap(s, remap)
    d1 = str(tmp_path / "post")
    ckpt.save(d1, 4, {"state": {"phi_acc": compacted.phi_acc}})
    tmpl = {"state": {"phi_acc": jnp.zeros((64, K))}}
    post, _, _ = ckpt.restore_latest(d1, tmpl)

    # (save-pre-compaction)-then-restore-with-remap
    d2 = str(tmp_path / "pre")
    ckpt.save(d2, 4, {"state": {"phi_acc": s.phi_acc}},
              extra={"dyn": {"row_remap": [int(r) for r in remap]}})
    extra, _ = ckpt.peek_extra(d2)
    pre, _, _ = ckpt.restore_latest(
        d2, tmpl, row_remaps={"phi_acc": extra["dyn"]["row_remap"]})

    np.testing.assert_array_equal(np.asarray(post["state"]["phi_acc"]),
                                  np.asarray(pre["state"]["phi_acc"]))

    # the remap path may also drop a rung in the same restore
    small = {"state": {"phi_acc": jnp.zeros((48, K))}}
    shrunk, _, _ = ckpt.restore_latest(
        d2, small, row_remaps={"phi_acc": extra["dyn"]["row_remap"]})
    np.testing.assert_array_equal(
        np.asarray(shrunk["state"]["phi_acc"]),
        np.asarray(compacted.phi_acc)[:48])
    # without the remap a shrinking restore is still refused loudly
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_latest(d2, small, grow_rows=("phi_acc",))

    # single-leaf serving restore takes the same remap; without it the
    # refusal names the fenced remap path
    arr, _, _ = ckpt.restore_phi(d2, w_cap=48,
                                 row_remap=extra["dyn"]["row_remap"])
    np.testing.assert_array_equal(np.asarray(arr),
                                  np.asarray(compacted.phi_acc)[:48])
    with pytest.raises(ValueError, match="shrink"):
        ckpt.restore_phi(d2, w_cap=48)


# ----------------------------------------------------------- topic recycle

def test_recycle_topics_reseeds_dead_columns_deterministically():
    rng = np.random.default_rng(3)
    W, live = 40, 32
    phi = rng.gamma(1.0, size=(W, K)).astype(np.float32) + 0.5
    phi[:live, 2] = 1e-9                               # a faded theme
    dead = lifecycle.dead_topics(phi, live, tol=0.01)
    np.testing.assert_array_equal(dead, [2])

    out1, rec1 = lifecycle.recycle_topics(phi, live, tol=0.01)
    out2, rec2 = lifecycle.recycle_topics(phi, live, tol=0.01)
    assert rec1 == rec2 == [2]
    np.testing.assert_array_equal(out1, out2)          # pure function
    # the reseed is seed_frac x the residual mass of the top-residual rows
    live_rows = phi[:live].astype(np.float32)
    residual = live_rows.sum(1) - live_rows.max(1)
    top = np.argsort(-residual, kind="stable")[:max(8, live // 20)]
    np.testing.assert_allclose(out1[top, 2], 0.1 * residual[top], rtol=1e-6)
    # untouched columns are bit-identical; nothing dead -> same object
    keep = [k for k in range(K) if k != 2]
    np.testing.assert_array_equal(out1[:, keep], phi[:, keep])
    same, rec = lifecycle.recycle_topics(out1, live, tol=1e-9)
    assert rec == [] and same is out1


# ------------------------------------------------------- driver lifecycle

def test_driver_compaction_bounds_occupancy():
    """ACCEPTANCE (ISSUE 7): on a sliding stream the lifecycle run holds
    live_w bounded while the plain dynamic driver grows monotonically."""
    from repro.launch.lda_train import train_loop

    base = train_loop(_args(minibatches=12))
    life = train_loop(_args(minibatches=12, decay="1,0.3", compact_every=3,
                            compact_min_idle=2, compact_mass_tol=60.0))
    assert len(life["compaction_events"]) >= 3
    assert life["vocab_version"] == len(life["compaction_events"])
    assert life["live_w"] < base["live_w"]
    # occupancy stabilizes: the post-fence trace stops growing
    tail = [t["live_w"] for t in life["occupancy_trace"][-3:]]
    assert max(tail) - min(tail) <= 2 * 8               # +- one drift step
    assert len(life["vocab_keys"]) == life["live_w"]


def test_crash_resume_across_compaction_fence(tmp_path):
    """ACCEPTANCE (ISSUE 7): a --crash-at rerun that replays THROUGH a
    compaction fence reproduces the uninterrupted run exactly — phi,
    mean_r suffix, live vocabulary, and the vocab version stamp all
    round-trip through the manifest row-remap."""
    from repro.launch.lda_train import train_loop

    kw = dict(minibatches=9, decay="1,0.3", compact_every=3,
              compact_min_idle=2, compact_mass_tol=60.0)
    full = train_loop(_args(**kw))
    assert len(full["compaction_events"]) == 3

    ckdir = str(tmp_path / "ck")
    with pytest.raises(SystemExit):
        train_loop(_args(ckpt_dir=ckdir, ckpt_every=2, crash_at=8, **kw))
    resumed = train_loop(_args(ckpt_dir=ckdir, ckpt_every=2, crash_at=8,
                               **kw))
    assert resumed["first_m"] > 0
    np.testing.assert_allclose(resumed["mean_r"],
                               full["mean_r"][resumed["first_m"]:],
                               rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(resumed["phi_acc"], full["phi_acc"],
                               rtol=1e-6, atol=1e-7)
    assert resumed["live_w"] == full["live_w"]
    assert resumed["vocab_keys"] == full["vocab_keys"]
    assert resumed["vocab_version"] == full["vocab_version"]


# ------------------------------------------------------- serving hot-swap

def test_engine_swap_phi_versions_and_occupancy():
    """swap_phi installs a remapped (phi, vocab) pair without tearing:
    queued work drains under the old version first, results are stamped
    with the phi generation that served them, and a same-capacity swap
    never recompiles."""
    from repro.serve import FoldInEngine

    rng = np.random.default_rng(0)
    cap, lw = 64, 40
    phi = jnp.asarray(rng.gamma(1.0, size=(cap, K)).astype(np.float32))
    cfg = LDAConfig(vocab_size=cap, num_topics=K)
    v0 = VocabMap(list(range(1000, 1000 + lw)))
    eng = FoldInEngine(phi, cfg, len_buckets=(16,), batch_docs=2,
                       fold_iters=6, live_words=lw, vocab=v0, warmup=False)
    assert eng.phi_version == 0
    s = eng.stats()
    assert s["w_cap"] == cap and s["phi_version"] == 0
    np.testing.assert_allclose(s["occupancy"], lw / cap)

    eng.submit((np.asarray([1000, 1001]), np.ones(2, np.float32)))

    # a fenced compaction produced a denser phi + a remapped vocab
    keep = np.ones(lw, bool)
    keep[::4] = False
    v1 = VocabMap(list(range(1000, 1000 + lw)))
    remap = v1.compact(keep)
    s0 = LDATrainState(phi_acc=phi, m=jnp.asarray(0, jnp.int32),
                       rng=jax.random.PRNGKey(0))
    phi1 = lifecycle.apply_row_remap(s0, remap).phi_acc
    eng.swap_phi(phi1, live_words=len(v1), vocab=v1)

    assert eng.phi_version == 1
    assert eng.live_words == len(v1)
    eng.submit((np.asarray([1001, 1002]), np.ones(2, np.float32)))
    res = sorted(eng.drain(), key=lambda r: r.req_id)
    # the pre-swap submission was flushed under version 0
    assert [r.phi_version for r in res] == [0, 1]
    for r in res:
        assert np.all(np.isfinite(r.theta))
    # same serving capacity: the jitted fold-in is reused, not recompiled
    assert eng.stats()["compiles"] <= len(eng.len_buckets)
    assert eng.stats()["phi_version"] == 1
    # evicted key 1000 now folds through the OOV row instead of its old row
    eng.submit((np.asarray([1000]), np.ones(1, np.float32)))
    (r,) = eng.drain()
    assert r.oov_tokens == 1.0 and r.phi_version == 1


def test_slab_engine_swap_phi_versions_and_vocab_remap():
    """The same fenced compaction hot-swap against the continuous-batching
    slab (DESIGN.md §16): queued work pumps dry under the admitting
    generation, results carry the generation stamp, a remapped vocab
    routes evicted keys to the OOV row, and the single slab step shape
    never recompiles on a same-capacity swap."""
    from repro.serve import SlabEngine

    rng = np.random.default_rng(0)
    cap, lw = 64, 40
    phi = jnp.asarray(rng.gamma(1.0, size=(cap, K)).astype(np.float32))
    cfg = LDAConfig(vocab_size=cap, num_topics=K)
    v0 = VocabMap(list(range(1000, 1000 + lw)))
    eng = SlabEngine(phi, cfg, slots=4, slot_len=16, fold_iters=6,
                     live_words=lw, vocab=v0)
    assert eng.phi_version == 0
    np.testing.assert_allclose(eng.stats()["occupancy"], lw / cap)

    eng.submit((np.asarray([1000, 1001]), np.ones(2, np.float32)))

    keep = np.ones(lw, bool)
    keep[::4] = False
    v1 = VocabMap(list(range(1000, 1000 + lw)))
    remap = v1.compact(keep)
    s0 = LDATrainState(phi_acc=phi, m=jnp.asarray(0, jnp.int32),
                       rng=jax.random.PRNGKey(0))
    phi1 = lifecycle.apply_row_remap(s0, remap).phi_acc
    eng.swap_phi(phi1, live_words=len(v1), vocab=v1)

    assert eng.phi_version == 1
    assert eng.live_words == len(v1)
    assert eng.in_flight() == 0          # the swap pumped the slab dry
    eng.submit((np.asarray([1001, 1002]), np.ones(2, np.float32)))
    res = sorted(eng.drain() + eng.poll(), key=lambda r: r.req_id)
    assert [r.phi_version for r in res] == [0, 1]
    for r in res:
        assert np.all(np.isfinite(r.theta))
    # one slab geometry, one compile — swaps never add shapes
    assert eng.stats()["compiles"] == 1
    eng.submit((np.asarray([1000]), np.ones(1, np.float32)))
    (r,) = eng.drain()
    assert r.oov_tokens == 1.0 and r.phi_version == 1
