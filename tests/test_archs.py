"""Per-architecture smoke tests (reduced configs, CPU) + family-level
correctness: SSD chunk invariance, chunked-vs-recurrent agreement, and
prefill -> decode logits continuity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, input_specs, cell_supported
from repro.configs.base import SMOKE_SHAPES, SSMSpec, ShapeSpec
from repro.models import registry
from repro.models import ssm as ssm_mod
from repro.models.common import NULL_CTX


def make_batch(cfg, B, S, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)
             .astype(jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(k, (B, S, cfg.d_model),
                                            jnp.float32).astype(jnp.bfloat16)
    return batch


def run_forward(mod, params, cfg, batch, mode):
    if cfg.family == "audio":
        return mod.forward(params, batch["tokens"], batch["frames"], cfg,
                           mode=mode)
    return mod.forward(params, batch["tokens"], cfg,
                       image_embeds=batch.get("image_embeds"), mode=mode)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    """Reduced config: one forward/train step, output shapes + no NaNs."""
    cfg = get_config(arch_id).reduced()
    mod = registry.build(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: mod.loss_fn(p, batch, cfg)))(params)
    assert np.isfinite(float(loss)), arch_id
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch_id):
    """Prefill cache structure == cache_zeros structure; decode step runs."""
    cfg = get_config(arch_id).reduced()
    mod = registry.build(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, caches, _ = jax.jit(
        lambda p, b: run_forward(mod, p, cfg, b, "prefill"))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    cz = registry.cache_zeros(cfg, B, S)
    assert jax.tree.structure(caches) == jax.tree.structure(cz)
    for got, want in zip(jax.tree.leaves(caches), jax.tree.leaves(cz)):
        assert got.shape == want.shape, (arch_id, got.shape, want.shape)
    lg, new_caches = jax.jit(
        lambda p, t, c, pos: mod.decode_step(p, t, c, pos, cfg))(
        params, batch["tokens"][:, :1], cz, jnp.int32(3))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any()), arch_id


@pytest.mark.parametrize("arch_id", ["granite-3-2b", "deepseek-v2-lite-16b",
                                     "mamba2-780m", "zamba2-2.7b",
                                     "seamless-m4t-medium"])
def test_prefill_then_decode_matches_full_forward(arch_id):
    """logits(decode token S | prefill cache of S) == logits from a full
    forward over S+1 tokens — the KV-cache/state correctness invariant."""
    cfg = get_config(arch_id).reduced()
    if cfg.moe is not None:
        # capacity-based MoE drops depend on sequence-level congestion, so a
        # 1-token decode can differ from teacher forcing; disable drops to
        # test the cache path itself.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mod = registry.build(cfg)
    params = mod.init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 16
    full = make_batch(cfg, B, S + 1, key=3)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :S]
    if "frames" in pre:
        pre["frames"] = full["frames"][:, : S + 1]  # encoder memory fixed

    logits_full, _, _ = jax.jit(
        lambda p, b: run_forward(mod, p, cfg, b, "prefill"))(params, full)
    _, caches, _ = jax.jit(
        lambda p, b: run_forward(mod, p, cfg, b, "prefill"))(params, pre)

    # grow the cache capacity to S+1 along the sequence axis
    target = registry.cache_zeros(cfg, B, S + 1)
    if cfg.family == "audio":  # cross memory spans S+1 frames already
        caches["stack"]["mem_kv"] = target["stack"]["mem_kv"]
        mem, _, _ = None, None, None
        from repro.models import encdec
        memory = encdec.encode(params, full["frames"], cfg)
        # recompute cross k/v on the full memory for exactness
        def cross_kv(lp):
            k = jnp.einsum("bmd,dh->bmh", memory, lp["cross"]["xattn"]["wk"])
            v = jnp.einsum("bmd,dh->bmh", memory, lp["cross"]["xattn"]["wv"])
            H, hd = cfg.n_heads, cfg.hd
            return {"mk": k.reshape(B, -1, H, hd), "mv": v.reshape(B, -1, H, hd)}
        caches["stack"]["mem_kv"] = jax.vmap(cross_kv)(params["dec"])

    def grow(got, want):
        if got.shape == want.shape:
            return got
        pads = [(0, w - g) for g, w in zip(got.shape, want.shape)]
        return jnp.pad(got, pads)

    caches = jax.tree.map(grow, caches, target)
    lg, _ = jax.jit(lambda p, t, c, pos: mod.decode_step(p, t, c, pos, cfg))(
        params, full["tokens"][:, S:S + 1], caches, jnp.int32(S))
    a = np.asarray(lg[:, 0].astype(jnp.float32))
    b = np.asarray(logits_full[:, S].astype(jnp.float32))
    # bf16 compute: compare top-1 agreement + value closeness
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.15)
    assert np.mean(np.argmax(a, -1) == np.argmax(b, -1)) >= 0.5


def test_ssd_chunk_size_invariance():
    """SSD output must not depend on the chunk length (fp32)."""
    base = get_config("mamba2-780m").reduced()
    B, T = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, base.d_model),
                          jnp.float32)
    outs = []
    for chunk in (8, 16, 32):
        cfg = dataclasses.replace(base, ssm=dataclasses.replace(base.ssm,
                                                                chunk=chunk))
        p = ssm_mod.ssm_params(jax.random.PRNGKey(1), cfg)
        p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
        y, _ = ssm_mod.ssm_apply(p, x, cfg=cfg, ctx=NULL_CTX)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_recurrent_decode():
    """Chunked SSD == step-by-step recurrence (the duality, fp32)."""
    cfg = get_config("mamba2-780m").reduced()
    B, T = 1, 24
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    p = ssm_mod.ssm_params(jax.random.PRNGKey(3), cfg)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    y_chunked, _ = ssm_mod.ssm_apply(p, x, cfg=cfg, ctx=NULL_CTX)

    d_inner, H, conv_ch = ssm_mod.ssm_dims(cfg)
    state = {"h": jnp.zeros((B, H, cfg.ssm.state, cfg.ssm.headdim), jnp.float32),
             "conv": jnp.zeros((B, cfg.ssm.conv_width - 1, conv_ch),
                               jnp.float32)}
    ys = []
    for t in range(T):
        y_t, state = ssm_mod.ssm_decode_step(p, x[:, t:t + 1], state, cfg=cfg,
                                             ctx=NULL_CTX)
        ys.append(np.asarray(y_t[:, 0]))
    y_seq = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), y_seq, rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_cell_support_matrix(arch_id):
    """long_500k only for SSM/hybrid; every other cell is supported."""
    assert cell_supported(arch_id, "train_4k")
    assert cell_supported(arch_id, "prefill_32k")
    assert cell_supported(arch_id, "decode_32k")
    expect_long = arch_id in ("mamba2-780m", "zamba2-2.7b")
    assert cell_supported(arch_id, "long_500k") == expect_long


def test_moe_scatter_combine_matches_gather():
    """The §Perf 'scatter' combine path is numerically the baseline path."""
    from repro.models import moe as moe_mod
    cfg = get_config("olmoe-1b-7b").reduced()
    p = moe_mod.moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y1, _ = moe_mod.moe_apply(p, x, cfg=cfg, ctx=NULL_CTX)
    cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                            combine="scatter"))
    y2, _ = moe_mod.moe_apply(p, x, cfg=cfg2, ctx=NULL_CTX)
    np.testing.assert_allclose(np.asarray(y1, dtype=np.float32),
                               np.asarray(y2, dtype=np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_manual_ep_matches_local():
    """The shard_map manual-EP path (1x1 mesh degenerate) must equal the
    plain path — validates dispatch slicing, psum combine, shared experts."""
    import jax.numpy as jnp
    from repro.models import moe as moe_mod
    from repro.models.common import ShardingCtx
    for arch in ("olmoe-1b-7b", "deepseek-v2-lite-16b"):
        cfg = get_config(arch).reduced()
        p = moe_mod.moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        y1, a1 = moe_mod.moe_apply(p, x, cfg=cfg, ctx=NULL_CTX)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ctx = ShardingCtx(active=True, batch=("data",), model="model",
                          mesh=mesh)
        with mesh:
            y2, a2 = jax.jit(
                lambda p_, x_: moe_mod.moe_apply(p_, x_, cfg=cfg, ctx=ctx)
            )(p, x)
        np.testing.assert_allclose(np.asarray(y1, dtype=np.float32),
                                   np.asarray(y2, dtype=np.float32),
                                   rtol=2e-2, atol=2e-2, err_msg=arch)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-4)


def test_moe_capacity_and_aux():
    """MoE: overflow drops, combine weights normalized, aux finite."""
    from repro.models import moe as moe_mod
    cfg = get_config("olmoe-1b-7b").reduced()
    p = moe_mod.moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, aux = moe_mod.moe_apply(p, x, cfg=cfg, ctx=NULL_CTX)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0
    assert not bool(jnp.isnan(y.astype(jnp.float32)).any())
