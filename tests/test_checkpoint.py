"""Fault tolerance: checkpoint roundtrip, crash/restart determinism,
elastic restore, atomicity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import checkpoint as ckpt


def make_tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 4)),
            "nest": {"b": jax.random.normal(k2, (3,)).astype(jnp.bfloat16),
                     "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 10, {"params": tree},
              extra={"next_step": 10, "m": 3})
    out, extra, step = ckpt.restore(str(tmp_path), 10, {"params": tree})
    assert step == 10 and extra == {"next_step": 10, "m": 3}
    for a, b in zip(jax.tree.leaves(out["params"]), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    tree = make_tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"t": tree}, extra={"next_step": s})
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3  # retention window


def test_shape_mismatch_rejected(tmp_path):
    tree = make_tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    bad = {"t": {"a": jnp.zeros((9, 4)), "nest": tree["nest"]}}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_dtype_mismatch_rejected(tmp_path):
    tree = make_tree(jax.random.PRNGKey(4))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    bad = {"t": {"a": tree["a"],
                 "nest": {"b": tree["nest"]["b"].astype(jnp.float32),
                          "step": tree["nest"]["step"]}}}
    with pytest.raises(ValueError, match="dtype mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_crash_restart_resumes_identically(tmp_path):
    """Train 30 steps straight vs train-with-crash-at-20 + restart: the
    final losses must match exactly (data cursor + RNG + residuals saved)."""
    from repro.launch.train import main as train_main
    args = ["--arch", "smollm-360m", "--reduced", "--steps", "30",
            "--batch", "4", "--seq", "16", "--shards", "2", "--sync",
            "power", "--ckpt-every", "10", "--log-every", "100"]
    ref_losses, _ = train_main(args)

    d = str(tmp_path / "ck")
    with pytest.raises(SystemExit):
        train_main(args + ["--ckpt-dir", d, "--crash-at", "20"])
    resumed, _ = train_main(args + ["--ckpt-dir", d])
    # resumed covers steps 20..29; compare against the tail of the clean run
    np.testing.assert_allclose(resumed[-5:], ref_losses[-5:], rtol=2e-4,
                               atol=2e-4)


def test_elastic_restore_via_device_put(tmp_path):
    """Restore with explicit shardings (the remesh path) — single device
    here, but exercises the device_put branch end-to-end."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (16, 8))}
    ckpt.save(str(tmp_path), 2, {"params": tree})
    sh = jax.tree.map(lambda _: jax.devices()[0], tree)
    out, _, _ = ckpt.restore(str(tmp_path), 2, {"params": tree},
                             shardings={"params": sh})
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["w"]))
