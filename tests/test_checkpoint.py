"""Fault tolerance: checkpoint roundtrip, crash/restart determinism,
elastic restore, atomicity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import checkpoint as ckpt


def make_tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 4)),
            "nest": {"b": jax.random.normal(k2, (3,)).astype(jnp.bfloat16),
                     "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 10, {"params": tree},
              extra={"next_step": 10, "m": 3})
    out, extra, step = ckpt.restore(str(tmp_path), 10, {"params": tree})
    assert step == 10 and extra == {"next_step": 10, "m": 3}
    for a, b in zip(jax.tree.leaves(out["params"]), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    tree = make_tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"t": tree}, extra={"next_step": s})
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3  # retention window


def test_shape_mismatch_rejected(tmp_path):
    tree = make_tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    bad = {"t": {"a": jnp.zeros((9, 4)), "nest": tree["nest"]}}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_dtype_mismatch_rejected(tmp_path):
    tree = make_tree(jax.random.PRNGKey(4))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    bad = {"t": {"a": tree["a"],
                 "nest": {"b": tree["nest"]["b"].astype(jnp.float32),
                          "step": tree["nest"]["step"]}}}
    with pytest.raises(ValueError, match="dtype mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_crash_restart_resumes_identically(tmp_path):
    """Train 30 steps straight vs train-with-crash-at-20 + restart: the
    final losses must match exactly (data cursor + RNG + residuals saved)."""
    from repro.launch.train import main as train_main
    args = ["--arch", "smollm-360m", "--reduced", "--steps", "30",
            "--batch", "4", "--seq", "16", "--shards", "2", "--sync",
            "power", "--ckpt-every", "10", "--log-every", "100"]
    ref_losses, _ = train_main(args)

    d = str(tmp_path / "ck")
    with pytest.raises(SystemExit):
        train_main(args + ["--ckpt-dir", d, "--crash-at", "20"])
    resumed, _ = train_main(args + ["--ckpt-dir", d])
    # resumed covers steps 20..29; compare against the tail of the clean run
    np.testing.assert_allclose(resumed[-5:], ref_losses[-5:], rtol=2e-4,
                               atol=2e-4)


def test_elastic_restore_via_device_put(tmp_path):
    """Restore with explicit shardings (the remesh path) — single device
    here, but exercises the device_put branch end-to-end."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (16, 8))}
    ckpt.save(str(tmp_path), 2, {"params": tree})
    sh = jax.tree.map(lambda _: jax.devices()[0], tree)
    out, _, _ = ckpt.restore(str(tmp_path), 2, {"params": tree},
                             shardings={"params": sh})
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["w"]))


# ------------------------------------------------- torn-write robustness (§17)

def test_restore_latest_falls_back_past_torn_newest(tmp_path):
    """Garbage written over the newest retained data.npz (a torn write
    below the atomic rename) warns loudly and restores the PREVIOUS
    retained step instead of crashing the resume."""
    tree = make_tree(jax.random.PRNGKey(5))
    for s in (1, 2, 3):
        ckpt.save(str(tmp_path), s, {"t": tree}, extra={"next_step": s})
    with open(tmp_path / "step_0000003" / "data.npz", "wb") as f:
        f.write(b"\x00garbage, not a zip\xff" * 7)
    assert ckpt.verify_step(str(tmp_path), 3) is not None
    assert ckpt.verify_step(str(tmp_path), 2) is None
    with pytest.warns(RuntimeWarning, match="corrupt"):
        got = ckpt.restore_latest(str(tmp_path), {"t": tree})
    assert got is not None
    out, extra, step = got
    assert step == 2 and extra == {"next_step": 2}
    np.testing.assert_array_equal(np.asarray(out["t"]["a"]),
                                  np.asarray(tree["a"]))


def test_restore_latest_detects_truncated_leaf_bytes(tmp_path):
    """A data.npz that still opens as a zip but whose leaf bytes disagree
    with the manifest (truncation) is corruption, not a template error."""
    tree = make_tree(jax.random.PRNGKey(6))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    ckpt.save(str(tmp_path), 2, {"t": tree})
    trunc = {f"leaf_{i}": np.zeros(1, np.uint8) for i in range(3)}
    np.savez(str(tmp_path / "step_0000002" / "data.npz"), **trunc)
    bad = ckpt.verify_step(str(tmp_path), 2)
    assert bad is not None and "torn write" in bad
    with pytest.warns(RuntimeWarning, match="falling back"):
        _, _, step = ckpt.restore_latest(str(tmp_path), {"t": tree})
    assert step == 1


def test_restore_latest_all_corrupt_returns_none(tmp_path):
    tree = make_tree(jax.random.PRNGKey(7))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    with open(tmp_path / "step_0000001" / "manifest.json", "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert ckpt.restore_latest(str(tmp_path), {"t": tree}) is None


def test_template_mismatch_on_intact_step_still_raises(tmp_path):
    """Fallback is for CORRUPTION only: a caller-side template bug on an
    intact checkpoint must raise, never silently restore an older step."""
    tree = make_tree(jax.random.PRNGKey(8))
    ckpt.save(str(tmp_path), 1, {"t": tree})
    ckpt.save(str(tmp_path), 2, {"t": tree})
    bad = {"t": {"a": jnp.zeros((9, 4)), "nest": tree["nest"]}}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_latest(str(tmp_path), bad)


def test_peek_extra_skips_unreadable_newest_manifest(tmp_path):
    tree = make_tree(jax.random.PRNGKey(9))
    ckpt.save(str(tmp_path), 1, {"t": tree}, extra={"next_step": 1})
    ckpt.save(str(tmp_path), 2, {"t": tree}, extra={"next_step": 2})
    with open(tmp_path / "step_0000002" / "manifest.json", "w") as f:
        f.write("{broken")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        extra, step = ckpt.peek_extra(str(tmp_path))
    assert step == 1 and extra == {"next_step": 1}
