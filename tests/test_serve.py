"""Fold-in serving engine (ISSUE 3): the token-major inference core vs the
dense oracle, the early-exit theta guarantee, a pure-numpy perplexity
oracle, engine admission/latency/accounting, the checkpoint-to-serve path,
and the LocalReducer sync_dtype cast satellite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import infer, perplexity
from repro.core.types import LDAConfig, MiniBatch
from repro.data import docs_to_padded, lda_corpus, train_test_split_counts

W, K = 150, 16
CFG = LDAConfig(vocab_size=W, num_topics=K)


@pytest.fixture(scope="module")
def trained():
    """A converged-ish phi (the true topics as sufficient statistics) plus
    held-in/held-out documents drawn from it."""
    docs, _, true_phi = lda_corpus(0, 48, W, K, doc_len_mean=40)
    phi_acc = jnp.asarray(true_phi.T) * 200.0          # [W, K] statistic
    phi_norm = perplexity.normalize_phi(phi_acc, CFG.beta)
    return docs, phi_acc, phi_norm


# ------------------------------------------------ fold-in core vs oracle

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_token_major_fold_in_matches_dense_reference(trained, impl):
    """fold_in_tokens (tol=0: fixed sweeps) must match the seed's dense
    [D, L, K] scan on random corpora — same key, same init, same theta."""
    docs, _, phi_norm = trained
    for seed in (1, 2):
        d, _, _ = lda_corpus(seed, 24, W, K, doc_len_mean=30)
        b = docs_to_padded(d)
        key = jax.random.PRNGKey(seed)
        ref = infer.fold_in_dense_reference(key, b, phi_norm, CFG, iters=12)
        res = infer.fold_in_tokens(key, b, phi_norm, CFG, iters=12,
                                   residual_tol=0.0, impl=impl)
        assert int(res.iters) == 12
        np.testing.assert_allclose(np.asarray(res.theta), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_perplexity_fold_in_theta_routes_through_infer(trained):
    """The eval wrapper is the same program as the inference core."""
    docs, _, phi_norm = trained
    b = docs_to_padded(docs[:16])
    key = jax.random.PRNGKey(9)
    via_wrapper = perplexity.fold_in_theta(key, b, phi_norm, CFG, iters=10)
    direct = infer.fold_in_tokens(key, b, phi_norm, CFG, iters=10).theta
    np.testing.assert_array_equal(np.asarray(via_wrapper),
                                  np.asarray(direct))


def test_early_exit_never_changes_theta_beyond_tol(trained):
    """A document freezes once its per-token residual drops below
    residual_tol; the theta it serves may differ from the run-to-the-end
    theta by at most residual_tol (per-document L1)."""
    docs, _, phi_norm = trained
    b = docs_to_padded(docs[:32])
    key = jax.random.PRNGKey(4)
    tol = 0.02
    full = infer.fold_in_tokens(key, b, phi_norm, CFG, iters=40,
                                residual_tol=0.0)
    early = infer.fold_in_tokens(key, b, phi_norm, CFG, iters=40,
                                 residual_tol=tol)
    assert int(early.iters) < int(full.iters)
    per_doc_l1 = np.abs(np.asarray(early.theta)
                        - np.asarray(full.theta)).sum(axis=1)
    assert per_doc_l1.max() <= tol, per_doc_l1.max()


def test_predictive_perplexity_matches_numpy_oracle(trained):
    docs, _, phi_norm = trained
    train, test = train_test_split_counts(docs, 0)
    tr_b, te_b = docs_to_padded(train), docs_to_padded(test)
    key = jax.random.PRNGKey(5)
    theta = perplexity.fold_in_theta(key, tr_b, phi_norm, CFG, iters=20)
    got = float(perplexity.predictive_perplexity(theta, phi_norm, te_b))

    th, ph = np.asarray(theta), np.asarray(phi_norm)
    wid, cnt = np.asarray(te_b.word_ids), np.asarray(te_b.counts)
    logp_sum, n = 0.0, 0.0
    for d in range(wid.shape[0]):
        for l in range(wid.shape[1]):
            c = cnt[d, l]
            if c > 0:
                p = float(th[d] @ ph[wid[d, l]])
                logp_sum += c * np.log(max(p, 1e-30))
                n += c
    expect = float(np.exp(-logp_sum / max(n, 1.0)))
    np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_topic_sharded_fold_in_matches_unsharded(trained):
    """The model-axis simulation (psum'd renormalization, K-invariant init)
    reproduces the unsharded mixture and meters the per-iteration psums."""
    docs, _, phi_norm = trained
    b = docs_to_padded(docs[:16])
    key = jax.random.PRNGKey(6)
    base = infer.fold_in_tokens(key, b, phi_norm, CFG, iters=10).theta
    step, meter = infer.make_fold_in_step(CFG, fold_iters=10,
                                          topic_shards=4, donate=False)
    theta, iters, _ = step(infer.split_topic_shards(phi_norm, 4), key,
                           b.word_ids, b.counts)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(base),
                               rtol=1e-4, atol=1e-6)
    by = meter.bytes_by_phase
    D, L = b.word_ids.shape
    # the per-iteration renorm psum is the [T, 1] norm vector
    assert by["model_norm_loop"] == D * L * 4
    assert by["model_rw_loop"] == D * 4
    assert meter.per_minibatch_bytes(int(iters)) == (
        sum(v for p, v in by.items() if not p.endswith("_loop"))
        + (int(iters) - 1) * (D * L * 4 + D * 4))


# ------------------------------------------------------------ the engine

def _submit_all(engine, docs):
    for d in docs:
        engine.submit(d)
    return engine.drain()


def test_engine_results_match_direct_fold_in(trained):
    """Bucketed admission + async dispatch must not change the math: each
    batch's theta equals a direct fold_in_tokens call on the same padded
    batch (the engine is a scheduler, not a second implementation)."""
    from repro.serve import FoldInEngine

    docs, phi_acc, phi_norm = trained
    short = [(ids[:10], cnt[:10]) for ids, cnt in docs[:8]]
    eng = FoldInEngine(phi_acc, CFG, len_buckets=(16, 32), batch_docs=4,
                       fold_iters=15, residual_tol=0.0, seed=11,
                       warmup=False)
    results = _submit_all(eng, short)
    assert len(results) == 8 and sorted(r.req_id for r in results) == \
        list(range(8))

    key = jax.random.PRNGKey(11)
    for batch_no in range(2):
        key, sub = jax.random.split(key)
        mb = docs_to_padded(short[batch_no * 4:(batch_no + 1) * 4],
                            max_len=16)
        # eng.cfg carries the engine's init_pad_len (largest bucket)
        want = infer.fold_in_tokens(sub, mb, phi_norm, eng.cfg, iters=15,
                                    residual_tol=0.0).theta
        got = np.stack([r.theta for r in results[batch_no * 4:
                                                 (batch_no + 1) * 4]])
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5,
                                   atol=1e-6)


def test_engine_bucketed_admission_and_partial_flush(trained):
    """Requests land in ladder buckets; a partial bucket only dispatches on
    drain (padded with empty docs, D constant) and compiles stay bounded by
    the bucket count."""
    from repro.serve import FoldInEngine

    docs, phi_acc, _ = trained
    eng = FoldInEngine(phi_acc, CFG, len_buckets=(16, 32, 64), batch_docs=8,
                       fold_iters=5, warmup=False)
    sizes = [5, 20, 50, 9, 30, 3]          # -> buckets 16, 32, 64
    for n in sizes:
        for _ in range(3):
            ids = np.arange(1, n + 1, dtype=np.int32) % W
            eng.submit((ids, np.ones(n, np.float32)))
    assert eng._dispatches == 1            # one bucket filled (32: 9 subs)
    res = eng.drain()
    assert len(res) == 3 * len(sizes)
    assert {r.bucket for r in res} == {16, 32, 64}
    s = eng.stats()
    assert s["served"] == 18 and s["dispatches"] == 4
    assert 0 < s["compiles"] <= 3
    assert np.isfinite(s["latency_p50_s"]) and np.isfinite(s["docs_per_s"])
    assert s["latency_p99_s"] >= s["latency_p50_s"]
    # every mixture is a distribution
    th = np.stack([r.theta for r in res])
    np.testing.assert_allclose(th.sum(axis=1), 1.0, atol=1e-5)


def test_engine_theta_invariant_to_bucket_ladder(trained):
    """The driver's L-invariant init carries over to serving: the same
    document returns the same theta whichever ladder admitted it (the
    engine draws the init at the largest bucket and slices)."""
    from repro.serve import FoldInEngine

    docs, phi_acc, _ = trained
    doc = (docs[0][0][:10], docs[0][1][:10])       # lands in bucket 16 / 64
    thetas = []
    for ladder in ((16, 64), (64,)):
        eng = FoldInEngine(phi_acc, CFG, len_buckets=ladder, batch_docs=1,
                           fold_iters=10, residual_tol=0.0, seed=5,
                           warmup=False)
        eng.submit(doc)
        (res,) = eng.drain()
        thetas.append(res.theta)
    np.testing.assert_allclose(thetas[0], thetas[1], rtol=1e-5, atol=1e-6)


def test_engine_sharded_phi_bytes_accounted(trained):
    """Serving a topic-sharded phi meters the per-iteration model psums and
    reports per-request bytes."""
    from repro.serve import FoldInEngine

    docs, phi_acc, _ = trained
    eng = FoldInEngine(phi_acc, CFG, len_buckets=(32,), batch_docs=8,
                       topic_shards=4, fold_iters=8, residual_tol=0.0,
                       warmup=False)
    _submit_all(eng, docs[:8])
    s = eng.stats()
    assert s["bytes_by_phase"].get("model_norm_loop", 0) == 8 * 32 * 4
    assert s["per_request_bytes"] > 0


def test_engine_meter_lifecycle_across_requests_and_reset(trained):
    """CommMeter lifecycle at the engine layer (guards the PR 2
    retrace-dedup fix): per-request bytes are identical after one batch
    and after many — repeated dispatches of an already-compiled shape are
    cache hits, not retraces, so they must not inflate the totals — and
    ``reset()`` clears the byte ledger without touching latency stats,
    with only genuinely new shapes re-recording afterwards."""
    from repro.serve import FoldInEngine

    docs, phi_acc, _ = trained
    eng = FoldInEngine(phi_acc, CFG, len_buckets=(32, 64), batch_docs=4,
                       topic_shards=4, fold_iters=8, residual_tol=0.0,
                       warmup=False)
    _submit_all(eng, docs[:4])
    first = eng.stats()
    assert first["per_request_bytes"] > 0
    for _ in range(4):                     # 16 more requests, same bucket
        _submit_all(eng, docs[:4])
    many = eng.stats()
    assert many["served"] == 20
    assert many["bytes_by_phase"] == first["bytes_by_phase"]
    assert many["per_request_bytes"] == pytest.approx(
        first["per_request_bytes"])

    eng.meter.reset()
    _submit_all(eng, docs[:4])             # cache hit: no trace, no bytes
    after = eng.stats()
    assert after["bytes_by_phase"] == {}
    assert after["served"] == 24           # serving stats keep accumulating
    assert np.isfinite(after["latency_p50_s"])
    # a NEW bucket shape compiles -> exactly that section's bytes reappear
    long_doc = (np.arange(40, dtype=np.int32) % W, np.ones(40, np.float32))
    _submit_all(eng, [long_doc])
    rebuilt = eng.stats()["bytes_by_phase"]
    assert rebuilt and set(rebuilt) == set(first["bytes_by_phase"])
    # the 64-bucket renorm payload is 2x the 32-bucket one ([T, 1] norm)
    assert rebuilt["model_norm_loop"] == 2 * first["bytes_by_phase"][
        "model_norm_loop"]


def test_engine_checkpoint_roundtrip(tmp_path, trained):
    """Checkpoint-to-serve: a driver-style checkpoint (state tree + run
    signature) serves without any training carry; restore_phi rejects
    missing/ambiguous leaves."""
    from repro.dist import checkpoint as ckpt
    from repro.serve import FoldInEngine

    docs, phi_acc, _ = trained
    state = {"state": {"phi_acc": phi_acc, "m": jnp.asarray(7, jnp.int32),
                       "rng": jax.random.PRNGKey(0)}}
    ckpt.save(str(tmp_path), 7, state,
              extra={"next_m": 7, "run": {"vocab": W, "topics": K}})

    phi, extra, step = ckpt.restore_phi(str(tmp_path))
    assert step == 7 and extra["run"]["topics"] == K
    np.testing.assert_array_equal(np.asarray(phi), np.asarray(phi_acc))
    with pytest.raises(ValueError, match="0 leaves"):
        ckpt.restore_phi(str(tmp_path), leaf="nope")
    with pytest.raises(FileNotFoundError):
        ckpt.restore_phi(str(tmp_path / "empty"))

    eng = FoldInEngine.from_checkpoint(str(tmp_path), len_buckets=(32,),
                                       batch_docs=4, fold_iters=5,
                                       warmup=False)
    assert eng.cfg.vocab_size == W and eng.cfg.num_topics == K
    res = _submit_all(eng, docs[:4])
    assert len(res) == 4


def test_serve_cli_reports_latency(tmp_path, capsys, trained):
    """The serve CLI end-to-end: checkpoint in, p50/p99 + docs/s out."""
    from repro.dist import checkpoint as ckpt
    from repro.launch import serve as serve_mod

    docs, phi_acc, _ = trained
    ckpt.save(str(tmp_path), 3,
              {"state": {"phi_acc": phi_acc, "m": jnp.asarray(3, jnp.int32),
                         "rng": jax.random.PRNGKey(0)}},
              extra={"next_m": 3, "run": {"vocab": W, "topics": K}})
    serve_mod.main(["--mode", "lda", "--ckpt-dir", str(tmp_path),
                    "--requests", "24", "--batch", "8",
                    "--len-buckets", "16,32"])
    out = capsys.readouterr().out
    assert "docs/s" in out and "p99=" in out and "compiles=" in out


def test_restore_phi_with_serving_spec(tmp_path, trained):
    """restore_phi routes through device_put under the dist.sharding
    serving spec (topics over 'model' when present and divisible)."""
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P
    from repro.dist import checkpoint as ckpt
    from repro.dist.sharding import phi_serving_spec

    docs, phi_acc, _ = trained
    ckpt.save(str(tmp_path), 1,
              {"state": {"phi_acc": phi_acc, "m": jnp.asarray(1, jnp.int32),
                         "rng": jax.random.PRNGKey(0)}},
              extra={"next_m": 1})
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    spec = phi_serving_spec(mesh, phi_acc)
    assert spec == P(None, "model")
    phi, _, _ = ckpt.restore_phi(str(tmp_path),
                                 sharding=NamedSharding(mesh, spec))
    np.testing.assert_array_equal(np.asarray(phi), np.asarray(phi_acc))
    # a mesh without a model axis replicates
    mesh1 = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    assert phi_serving_spec(mesh1, phi_acc) == P(None, None)


# ------------------------------------------------- LocalReducer satellite

def test_local_reducer_applies_sync_dtype_cast():
    """N=1 must take the same numeric path as N-shard runs: the bf16
    payload cast round-trip applies under compress even though no bytes
    move (the seed skipped it, forking N=1 numerics)."""
    from repro.core.sync import LocalReducer, SimReducer

    x = jnp.linspace(0.0, 1.0, 7, dtype=jnp.float32) + 1e-4
    local = LocalReducer(sync_dtype=jnp.bfloat16)
    sim = SimReducer(sync_dtype=jnp.bfloat16)
    got = local.psum(x, "power")
    want = sim.psum(x[None], "power")[0]      # N=1 stacked all-reduce
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # compress=False and matching dtypes stay exact no-ops
    np.testing.assert_array_equal(
        np.asarray(local.psum(x, "p", compress=False)), np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(LocalReducer().psum(x, "p")), np.asarray(x))
