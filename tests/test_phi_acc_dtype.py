"""Compressed phi accumulators (DESIGN.md §13): stochastic-rounding
properties, bf16-vs-f32 training parity, checkpoint dtype round-trips in
both directions, halved sync payload accounting, and bf16 serving."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, quantize
from repro.core.pobp import grow_state, init_train_state
from repro.core.sync import CommMeter, SimReducer
from repro.dist import checkpoint as ckpt
from repro.launch import lda_train


# ------------------------------------------------------ stochastic rounding

def test_stochastic_round_exact_on_representables():
    """bf16-representable values never move: the dropped mantissa bits are
    zero, so no dither value can carry."""
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5, 2.0, -3.0, 1.5], jnp.float32)
    out = quantize.stochastic_round(x, jnp.bfloat16, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(x))


def test_stochastic_round_unbiased():
    """E[sr(x)] == x: the mean over many keys lands between the two
    neighbouring bf16 values, close to x itself — round-to-nearest would
    pin it to one side."""
    x = jnp.full((256,), np.float32(1.0) + np.float32(2.0 ** -12))
    lo, hi = np.float32(1.0), np.float32(1.0078125)   # bf16 neighbours
    acc = np.zeros(256, np.float64)
    n = 200
    for i in range(n):
        out = quantize.stochastic_round(x, jnp.bfloat16,
                                        jax.random.PRNGKey(i))
        arr = np.asarray(out, np.float32)
        assert np.all((arr == lo) | (arr == hi))      # rounds to a neighbour
        acc += arr
    mean = (acc / n).mean()
    np.testing.assert_allclose(mean, float(x[0]), rtol=0, atol=2e-4)


def test_stochastic_round_f32_passthrough_and_validation():
    x = jnp.asarray([1.234567], jnp.float32)
    out = quantize.stochastic_round(x, jnp.float32, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    with pytest.raises(ValueError):
        quantize.stochastic_round(x, jnp.float16, jax.random.PRNGKey(0))


def test_phi_acc_dtype_resolver():
    assert quantize.phi_acc_dtype(LDAConfig(10, 4)) == jnp.float32
    cfg = LDAConfig(10, 4, phi_acc_dtype="bfloat16")
    assert quantize.phi_acc_dtype(cfg) == jnp.bfloat16
    with pytest.raises(ValueError):
        quantize.phi_acc_dtype(LDAConfig(10, 4, phi_acc_dtype="float16"))


# ------------------------------------------------------- training parity

def _run(phi_acc_dtype, minibatches=6, **kw):
    return lda_train.train_loop(lda_train.default_args(
        minibatches=minibatches, docs_per_batch=16, vocab=80, topics=8,
        shards=2, log_every=0, warmup_buckets=False,
        phi_acc_dtype=phi_acc_dtype, **kw))


def test_bf16_training_tracks_f32():
    """Full streaming run: the bf16/SR trajectory tracks the f32 one
    within rounding noise and the final carry is stored narrow.

    Batch 1 already ships its delta syncs at bf16 wire width (that IS the
    byte-halving feature) and later batches add unbiased SR fold-back
    noise, so the per-batch mean_r drift is bounded at 1e-2 and the
    converged held-out perplexity at 1% relative."""
    r32 = _run("float32")
    r16 = _run("bfloat16")
    assert r16["phi_acc"].dtype == jnp.bfloat16
    assert r32["phi_acc"].dtype == np.float32
    for a, b in zip(r32["mean_r"], r16["mean_r"]):
        assert abs(a - b) <= 1e-2, (a, b)
    assert abs(r32["ppl"] - r16["ppl"]) / r32["ppl"] <= 1e-2


def test_bf16_run_does_not_perturb_f32_rng():
    """The SR key is fold_in-derived, never split from the stream: two f32
    runs bracket a bf16 run and stay bit-identical."""
    a = _run("float32", minibatches=3)
    _run("bfloat16", minibatches=3)
    b = _run("float32", minibatches=3)
    np.testing.assert_array_equal(a["phi_acc"], b["phi_acc"])


# ------------------------------------------------------------ sync bytes

def test_comm_meter_bytes_halve():
    """phi-delta payloads ship at bf16 width: dense + power phase bytes
    halve exactly; residual syncs (compress=False) stay f32."""
    r32 = _run("float32", minibatches=3)
    r16 = _run("bfloat16", minibatches=3)
    assert r16["bytes_by_phase"]["dense"] * 2 == r32["bytes_by_phase"]["dense"]
    assert r16["bytes_by_phase"]["power"] * 2 == r32["bytes_by_phase"]["power"]


def test_reducer_dtype_override_billing():
    """Unit-level pin of Reducer.psum(dtype=...): the meter records the
    cast payload and the result returns at the caller's dtype."""
    meter = CommMeter()
    red = SimReducer(meter=meter)
    x = jnp.ones((2, 8, 4), jnp.float32)      # leading shard axis N=2
    out = red.psum(x, "unit", dtype=jnp.bfloat16)
    assert out.dtype == jnp.float32
    assert meter.phase_bytes("unit") == 2 * 8 * 4 * 2   # bf16 itemsize
    red.psum(x, "unit32")
    assert meter.phase_bytes("unit32") == 2 * 8 * 4 * 4


# ----------------------------------------------------- checkpoint round-trip

def test_checkpoint_roundtrip_both_directions():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"state": {"phi_acc": jnp.full((40, 8), 1.5,
                                                       jnp.bfloat16)}})
        # bf16 on disk -> f32 template: cast on load
        tpl32 = {"state": {"phi_acc": jnp.zeros((40, 8), jnp.float32)}}
        trees, _, _ = ckpt.restore(d, 1, tpl32, cast_dtypes=("phi_acc",))
        assert trees["state"]["phi_acc"].dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(trees["state"]["phi_acc"]),
                                      1.5)
        # without cast_dtypes the mismatch still raises
        with pytest.raises(ValueError, match="dtype mismatch"):
            ckpt.restore(d, 1, tpl32)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"state": {"phi_acc": jnp.full((40, 8), 0.25,
                                                       jnp.float32)}})
        # f32 on disk -> bf16 template: cast the other way
        tpl16 = {"state": {"phi_acc": jnp.zeros((40, 8), jnp.bfloat16)}}
        trees, _, _ = ckpt.restore(d, 1, tpl16, cast_dtypes=("phi_acc",))
        assert trees["state"]["phi_acc"].dtype == jnp.bfloat16
        # restore_phi: saved dtype by default, cast on request
        arr, _, _ = ckpt.restore_phi(d, leaf="phi_acc")
        assert arr.dtype == jnp.float32
        arr, _, _ = ckpt.restore_phi(d, leaf="phi_acc", dtype=jnp.bfloat16)
        assert arr.dtype == jnp.bfloat16


def test_driver_switches_dtype_at_restore_fence():
    """Train bf16 with checkpoints, resume the stream in f32: the restore
    casts and the run continues (phi_acc_dtype is not a resume key)."""
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ck")
        _run("bfloat16", minibatches=4, ckpt_dir=ck, ckpt_every=2)
        res = _run("float32", minibatches=6, ckpt_dir=ck, ckpt_every=2)
        assert res["first_m"] == 4
        assert res["phi_acc"].dtype == np.float32


# -------------------------------------------------------- growth + serving

def test_grow_state_preserves_storage_dtype():
    cfg = LDAConfig(40, 8, phi_acc_dtype="bfloat16")
    state = init_train_state(cfg, 0)
    grown = grow_state(state, 128)
    assert grown.phi_acc.dtype == jnp.bfloat16
    assert grown.phi_acc.shape == (128, 8)


def test_engine_serves_f32_from_bf16_checkpoint():
    from repro.serve.engine import FoldInEngine

    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ck")
        _run("bfloat16", minibatches=2, ckpt_dir=ck, ckpt_every=2)
        eng = FoldInEngine.from_checkpoint(ck, LDAConfig(80, 8))
        assert eng._phi.dtype == jnp.float32
        eng.submit((np.asarray([1, 2, 3], np.int32),
                    np.asarray([1.0, 2.0, 1.0], np.float32)))
        res = eng.drain()
        assert len(res) == 1
        theta = np.asarray(res[0].theta)
        np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-4)
