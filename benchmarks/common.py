"""Shared fixtures for the paper-figure benchmarks (CPU-scaled corpora)."""

from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.core.types import LDAConfig
from repro.data import lda_corpus, train_test_split_counts


@functools.lru_cache(maxsize=4)
def corpus(seed=0, docs=240, W=400, K=16):
    """ENRON-shaped (Zipf-ish marginals via the LDA generative model)."""
    d, stats, phi = lda_corpus(seed, docs, W, K, doc_len_mean=80)
    return d, stats, phi


def split(docs, seed=0):
    return train_test_split_counts(list(docs), seed)


def base_cfg(**kw) -> LDAConfig:
    d = dict(vocab_size=400, num_topics=16, lambda_w=0.1, lambda_k_abs=8,
             inner_iters=12, residual_tol=0.02)
    d.update(kw)
    return LDAConfig(**d)


def timed(fn, *args, repeats=1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) else None
    return out, (time.time() - t0) / repeats
