import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-probe a cell with the current code (and
optional config overrides) and diff the roofline terms against the stored
baseline record.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen2-72b \
      --shape train_4k [--moe-combine scatter] [--tag iterA]
"""

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402

from repro.configs import get_config                    # noqa: E402
from repro.configs.base import SHAPES                   # noqa: E402
from repro.launch import roofline as rl                 # noqa: E402
from repro.launch.dryrun import (extrapolate_costs,     # noqa: E402
                                 probe_plan, _compile_cell)
from repro.launch.mesh import make_production_mesh      # noqa: E402

BASE = os.path.join(os.path.dirname(__file__), "results", "dryrun_baseline")
OUT = os.path.join(os.path.dirname(__file__), "results", "hillclimb")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--moe-combine", default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.moe_combine and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, combine=args.moe_combine))
    if args.capacity_factor and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=args.capacity_factor))
    if args.attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=args.attn_chunk)
    if args.remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=args.remat_policy)

    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    costs = extrapolate_costs(cfg, shape, mesh)
    terms = rl.roofline_terms(costs["flops"], costs["bytes"],
                              costs["coll_total"])
    dt = time.time() - t0

    base_fp = os.path.join(BASE, f"{args.arch}__{args.shape}__single.json")
    base = json.load(open(base_fp)) if os.path.exists(base_fp) else {}

    def row(name, new, old):
        delta = (f"{new / old:5.2f}x" if old else "  -  ")
        print(f"  {name:14s} new={new:10.3e}  base={old or 0:10.3e}  {delta}")

    print(f"[{args.tag}] {args.arch}/{args.shape}  (probe {dt:.0f}s)")
    row("compute_s", terms.compute_s, base.get("compute_s"))
    row("memory_s", terms.memory_s, base.get("memory_s"))
    row("collective_s", terms.collective_s, base.get("collective_s"))
    for b in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all"):
        row(f"coll:{b}", costs.get(f"coll_{b}", 0.0),
            (base.get("collective_bytes") or {}).get(b))
    print(f"  dominant: {terms.dominant} "
          f"(baseline: {base.get('dominant', '?')})")

    os.makedirs(OUT, exist_ok=True)
    rec = {"arch": args.arch, "shape": args.shape, "tag": args.tag,
           "overrides": {k: v for k, v in vars(args).items()
                         if v is not None and k not in ("arch", "shape",
                                                        "tag")},
           "compute_s": terms.compute_s, "memory_s": terms.memory_s,
           "collective_s": terms.collective_s, "dominant": terms.dominant,
           "costs": {k: v for k, v in costs.items()
                     if not k.startswith("probe")}}
    with open(os.path.join(OUT, f"{args.arch}__{args.shape}__{args.tag}.json"),
              "w") as f:
        json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
