"""Benchmark harness: one entry per paper table/figure (DESIGN.md §7).

Prints ``name,value,derived`` CSV rows; each section also writes a JSON
artifact under benchmarks/results/.  Run:

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _emit(name: str, value, derived: str = ""):
    print(f"{name},{value},{derived}", flush=True)


def _save(name: str, obj):
    os.makedirs(RESULTS, exist_ok=True)
    payload = json.dumps(obj, indent=1, default=str)
    with open(os.path.join(RESULTS, name + ".json"), "w") as f:
        f.write(payload)
    if name.startswith("BENCH_"):
        # canonical top-level copy: the perf-trajectory tooling reads
        # repo-root BENCH_*.json files (benchmarks/results/ keeps the
        # full history alongside the non-BENCH sections)
        with open(os.path.join(REPO_ROOT, name + ".json"), "w") as f:
            f.write(payload)


# ------------------------------------------------------------------
# Fig. 10 / Eqs. 5-6: communication volume, dense vs power sync
# ------------------------------------------------------------------

def bench_comm_volume(quick=False):
    from benchmarks.common import base_cfg, corpus
    from repro.core import make_sim_minibatch_fn
    from repro.data import sharded_minibatch_stream

    docs, stats, _ = corpus()
    out = {}
    for K in ([16] if quick else [16, 32, 64]):
        for mode in ("dense", "power"):
            cfg = base_cfg(num_topics=K, residual_tol=1e-9, inner_iters=8)
            fn, meter = make_sim_minibatch_fn(cfg, 4, mode)
            b = next(iter(sharded_minibatch_stream(docs, 80, 4)))
            fn(b.word_ids, b.counts,
               jnp.zeros((cfg.vocab_size, K)), jax.random.PRNGKey(0),
               jnp.float32(1.0))
            per_iter = (meter.phase_bytes("power") if mode == "power"
                        else meter.phase_bytes("dense_loop"))
            out[f"K{K}_{mode}"] = per_iter
            _emit(f"comm_volume/K{K}/{mode}_bytes_per_iter", per_iter)
        ratio = out[f"K{K}_dense"] / max(out[f"K{K}_power"], 1)
        _emit(f"comm_volume/K{K}/reduction_x", f"{ratio:.1f}",
              "Eq5/Eq6 ratio")
    _save("comm_volume", out)


# ------------------------------------------------------------------
# this repo's parameter-server trajectory (ISSUE 8, DESIGN.md §15):
# measured PS wire bytes vs the allreduce Eq. 5/6 payload, S=0 drift
# vs the allreduce oracle, and prefetch overlap under bounded staleness
# ------------------------------------------------------------------

def bench_comm(quick=False):
    from repro.launch.lda_train import default_args, train_loop

    common = dict(minibatches=8 if quick else 16, docs_per_batch=32,
                  shards=2, vocab=2000 if quick else 4000,
                  inner_iters=8, tol=1e-9, log_every=0, eval_every=0,
                  doc_len_means="12,24,40", len_buckets="16,32,48",
                  ps_servers=4, seed=0)
    cells = [(16, 8)] if quick else [(16, 8), (64, 16)]
    out = {"config": dict(common, cells=cells), "cells": {}}
    gates = []

    for K, Pk in cells:
        cell = dict(common, topics=K, lambda_k=Pk)
        ar = train_loop(default_args(**cell, backend="sim"))
        ps0 = train_loop(default_args(**cell, backend="ps", staleness=0))
        drift = max(abs(a - b) for a, b in
                    zip(ar["mean_r"], ps0["mean_r"]))
        ar_pmb = ar["per_minibatch_bytes"]
        ratio = ps0["ps_wire_per_minibatch"] / max(ar_pmb, 1)
        name = f"K{K}_Pk{Pk}"
        out["cells"][name] = {
            "allreduce_per_minibatch_bytes": ar_pmb,
            "ps_wire_per_minibatch_bytes": ps0["ps_wire_per_minibatch"],
            "ps_vs_allreduce_ratio": ratio,
            "mean_touched_rows": ps0["mean_touched_rows"],
            "per_minibatch_bytes_touched_model":
                ps0["per_minibatch_bytes_touched"],
            "ps_bytes_by_link": ps0["ps_bytes_by_link"],
            "mean_r_drift_s0": drift,
        }
        _emit(f"comm/{name}/ps_vs_allreduce_bytes", f"{ratio:.3f}",
              f"ps={ps0['ps_wire_per_minibatch']:,.0f}B "
              f"ar={ar_pmb:,}B; acceptance <= 0.5")
        _emit(f"comm/{name}/mean_r_drift_s0", f"{drift:.2e}",
              "acceptance <= 1e-6 vs allreduce oracle")
        gates.append((f"{name}: ps/allreduce ratio {ratio:.3f} > 0.5",
                      ratio <= 0.5))
        gates.append((f"{name}: S=0 drift {drift:.2e} > 1e-6",
                      drift <= 1e-6))

    # prefetch overlap: with a real link latency injected, the barriered
    # S=0 run pays push+pull on the critical path every batch; S=2 hides
    # both under the sweep.  Same trajectory family, so "converging" =
    # the residual trace still decreases end over start.
    K, Pk = cells[0]
    cell = dict(common, topics=K, lambda_k=Pk,
                ps_latency=0.004 if quick else 0.008)

    def best_of(staleness, reps=2):
        # wall-clock on a shared CPU is noisy at this scale: best-of-N is
        # the standard estimator of the achievable rate
        runs = [train_loop(default_args(**cell, backend="ps",
                                        staleness=staleness))
                for _ in range(reps)]
        return min(runs, key=lambda r: r["wall_s"])

    barrier = best_of(0)
    overlap = best_of(2)
    # at S=0 the latency lands in push_wait (end_batch barriers on the
    # commit); at S>0 in pull_wait (whatever the sweep did not hide) —
    # the overlap instrument is the TOTAL time the dispatch loop sat
    # blocked on the wire
    wait0 = barrier["ps_pull_wait_s"] + barrier["ps_push_wait_s"]
    wait2 = overlap["ps_pull_wait_s"] + overlap["ps_push_wait_s"]
    out["overlap"] = {
        "latency_s": cell["ps_latency"],
        "wall_s0": barrier["wall_s"], "wall_s2": overlap["wall_s"],
        "sync_wait_s0": wait0, "sync_wait_s2": wait2,
        "pull_wait_s0": barrier["ps_pull_wait_s"],
        "pull_wait_s2": overlap["ps_pull_wait_s"],
        "push_wait_s0": barrier["ps_push_wait_s"],
        "push_wait_s2": overlap["ps_push_wait_s"],
        "ppl_s0": barrier["ppl"], "ppl_s2": overlap["ppl"],
        "mean_r_s2": overlap["mean_r"],
    }
    _emit("comm/overlap/wall_s2_vs_s0",
          f"{overlap['wall_s'] / max(barrier['wall_s'], 1e-9):.2f}",
          f"S=2 {overlap['wall_s']:.2f}s vs S=0 {barrier['wall_s']:.2f}s; "
          f"acceptance: no slower (<= 1.10x for timer noise)")
    _emit("comm/overlap/sync_wait_s", f"{wait2:.3f}",
          f"S=0 sat blocked {wait0:.3f}s; acceptance: S=2 strictly less")
    _emit("comm/overlap/ppl_s2_vs_s0",
          f"{overlap['ppl'] / max(barrier['ppl'], 1e-9):.3f}",
          f"S=2 ppl={overlap['ppl']:.2f} vs S=0 {barrier['ppl']:.2f}; "
          f"acceptance <= 1.05 (bounded staleness still converges)")
    gates.append(
        (f"S=2 wall {overlap['wall_s']:.2f}s slower than S=0 "
         f"{barrier['wall_s']:.2f}s x1.10",
         overlap["wall_s"] <= barrier["wall_s"] * 1.10))
    gates.append(
        (f"S=2 sync wait {wait2:.3f}s not below S=0 {wait0:.3f}s",
         wait2 < wait0))
    gates.append(
        (f"S=2 not converging: ppl {overlap['ppl']:.2f} vs S=0 "
         f"{barrier['ppl']:.2f}",
         overlap["ppl"] <= barrier["ppl"] * 1.05))

    # artifact first, gates second: a failed gate still leaves the
    # numbers on disk for the CI artifact
    _save("BENCH_comm_quick" if quick else "BENCH_comm", out)
    failures = [msg for msg, ok in gates if not ok]
    assert not failures, (failures, out)


# ------------------------------------------------------------------
# Fig. 7: perplexity + time vs lambda_W
# ------------------------------------------------------------------

def bench_lambda_sweep(quick=False):
    from benchmarks.common import base_cfg, corpus, split
    from repro.core import perplexity, run_stream
    from repro.data import docs_to_padded, sharded_minibatch_stream

    docs, stats, _ = corpus()
    train, test = split(docs)
    tr_b, te_b = docs_to_padded(train), docs_to_padded(test)
    key = jax.random.PRNGKey(5)
    out = {}
    lam_ws = [0.05, 0.1, 0.4] if quick else [0.025, 0.05, 0.1, 0.2, 0.4, 1.0]
    for lw in lam_ws:
        # the paper runs each mini-batch to the residual threshold (T up to
        # ~200): small lambda needs more sweeps, same quality (Fig. 7)
        cfg = base_cfg(lambda_w=lw, residual_tol=0.03, inner_iters=60)
        t0 = time.time()
        phi, _, _ = run_stream(sharded_minibatch_stream(train, 80, 2), cfg,
                               num_shards=2, sync_mode="power", seed=1)
        dt = time.time() - t0
        ppl = perplexity.evaluate(key, phi, tr_b, te_b, cfg)
        out[f"lw{lw}"] = {"ppl": float(ppl), "time_s": dt}
        _emit(f"lambda_sweep/lambda_w={lw}/ppl", f"{ppl:.2f}",
              f"time={dt:.1f}s")
    _save("lambda_sweep", out)


# ------------------------------------------------------------------
# Figs. 8/9 + Table 4: accuracy vs baselines (matched budgets)
# ------------------------------------------------------------------

def bench_accuracy(quick=False):
    from benchmarks.common import base_cfg, corpus, split
    from repro.core import perplexity, run_stream
    from repro.core.gibbs import run_gibbs
    from repro.core.vb import run_vb
    from repro.data import docs_to_padded, sharded_minibatch_stream

    docs, stats, _ = corpus(docs=160 if quick else 240)
    train, test = split(docs)
    tr_b, te_b = docs_to_padded(train), docs_to_padded(test)
    key = jax.random.PRNGKey(5)
    cfg = base_cfg(residual_tol=0.03, inner_iters=60)
    out = {}

    t0 = time.time()
    phi, _, _ = run_stream(sharded_minibatch_stream(train, 60, 2), cfg,
                           num_shards=2, sync_mode="power", seed=1)
    out["POBP"] = {"ppl": float(perplexity.evaluate(key, phi, tr_b, te_b,
                                                    cfg)),
                   "time_s": time.time() - t0}

    t0 = time.time()
    phi_g, _ = run_gibbs(jax.random.PRNGKey(2), tr_b, cfg,
                         sweeps=20 if quick else 50)
    out["GS"] = {"ppl": float(perplexity.evaluate(key, phi_g, tr_b, te_b,
                                                  cfg)),
                 "time_s": time.time() - t0}

    t0 = time.time()
    phi_v, _ = run_vb(jax.random.PRNGKey(3), tr_b, cfg,
                      iters=10 if quick else 25)
    out["VB"] = {"ppl": float(perplexity.evaluate(key, phi_v, tr_b, te_b,
                                                  cfg)),
                 "time_s": time.time() - t0}

    rand_ppl = float(perplexity.evaluate(key, jnp.zeros_like(phi), tr_b,
                                         te_b, cfg))
    out["random"] = {"ppl": rand_ppl}
    for name, rec in out.items():
        _emit(f"accuracy/{name}/ppl", f"{rec['ppl']:.2f}",
              f"time={rec.get('time_s', 0):.1f}s")
    gap = (out["GS"]["ppl"] - out["POBP"]["ppl"]) / out["GS"]["ppl"] * 100
    _emit("accuracy/gap_vs_GS_pct", f"{gap:.1f}", "Table 4 analogue")
    _save("accuracy", out)


# ------------------------------------------------------------------
# Fig. 11: training time vs number of topics
# ------------------------------------------------------------------

def bench_speed(quick=False):
    from benchmarks.common import base_cfg, corpus
    from repro.core import run_stream
    from repro.data import sharded_minibatch_stream

    docs, stats, _ = corpus()
    out = {}
    for K in ([16, 32] if quick else [16, 32, 64, 128]):
        for mode in ("dense", "power"):
            cfg = base_cfg(num_topics=K, lambda_k_abs=max(4, K // 8),
                           residual_tol=1e-9, inner_iters=8)
            t0 = time.time()
            run_stream(sharded_minibatch_stream(docs, 80, 2), cfg,
                       num_shards=2, sync_mode=mode, seed=1)
            out[f"K{K}_{mode}"] = time.time() - t0
            _emit(f"speed/K{K}/{mode}_s", f"{out[f'K{K}_{mode}']:.2f}")
    _save("speed", out)


# ------------------------------------------------------------------
# Fig. 12 + Eqs. 16-18: scalability cost model with measured A, B
# ------------------------------------------------------------------

def bench_scalability(quick=False):
    """Overall cost = A/N + B*N (Eq. 16); optimum N* = sqrt(A/B) (Eq. 17).
    A is measured wall-clock of one mini-batch on one shard; B is the
    measured per-processor sync payload / link bandwidth."""
    from benchmarks.common import base_cfg, corpus, timed
    from repro.core import make_sim_minibatch_fn
    from repro.data import docs_to_padded

    docs, stats, _ = corpus()
    cfg = base_cfg(residual_tol=1e-9, inner_iters=8)
    b = docs_to_padded(list(docs)[:80])
    fn, meter = make_sim_minibatch_fn(cfg, 1, "power")
    _, t_compute = timed(
        lambda: fn(b.word_ids, b.counts,
                   jnp.zeros((cfg.vocab_size, cfg.num_topics)),
                   jax.random.PRNGKey(0), jnp.float32(1.0)))
    link_bw = 50e9
    out = {}
    for mode, per_iter in (
            ("power", 2 * cfg.num_power_words * cfg.num_power_topics * 4),
            ("dense", cfg.vocab_size * cfg.num_topics * 4)):
        B_comm = per_iter * cfg.inner_iters / link_bw
        n_star = (t_compute / B_comm) ** 0.5
        out[mode] = {"A_s": t_compute, "B_s": B_comm, "N_star": n_star,
                     "min_cost_s": 2 * (t_compute * B_comm) ** 0.5}
        _emit(f"scalability/{mode}/N_star", f"{n_star:.0f}",
              f"A={t_compute:.3f}s B={B_comm:.2e}s (Eq. 17)")
    _emit("scalability/power_vs_dense_Nstar_x",
          f"{out['power']['N_star'] / out['dense']['N_star']:.1f}",
          "power selection raises the scalability ceiling (Eq. 18-19)")
    _save("scalability", out)


# ------------------------------------------------------------------
# Table 5: per-shard memory — POBP constant vs batch scaling
# ------------------------------------------------------------------

def bench_memory(quick=False):
    from benchmarks.common import base_cfg, corpus

    docs, stats, _ = corpus()
    cfg = base_cfg()
    W, K = cfg.vocab_size, cfg.num_topics
    L = 80  # padded words/doc
    out = {}
    D_m = 20  # per-PROCESSOR mini-batch docs: fixed by the memory quota
    for N in [1, 2, 4, 8, 16]:
        # POBP: constant — each processor always holds D_m docs + phi + r
        pobp = D_m * L * K * 4 + 2 * W * K * 4
        batch = max(stats.num_docs // N, 1) * L * K * 4 + W * K * 4
        out[f"N{N}"] = {"POBP_MB": pobp / 1e6, "batch_MB": batch / 1e6}
        _emit(f"memory/N={N}/POBP_MB", f"{pobp / 1e6:.2f}",
              f"batch={batch / 1e6:.2f}MB (Table 5: POBP constant)")
    _save("memory", out)


# ------------------------------------------------------------------
# Table 2: measured vs analytic complexity
# ------------------------------------------------------------------

def bench_complexity(quick=False):
    from benchmarks.common import base_cfg, corpus
    from repro.core import make_sim_minibatch_fn
    from repro.data import sharded_minibatch_stream

    docs, stats, _ = corpus()
    cfg = base_cfg(residual_tol=1e-9, inner_iters=8)
    N = 4
    fn, meter = make_sim_minibatch_fn(cfg, N, "power")
    b = next(iter(sharded_minibatch_stream(docs, 80, N)))
    _, iters, *_ = fn(b.word_ids, b.counts,
                      jnp.zeros((cfg.vocab_size, cfg.num_topics)),
                      jax.random.PRNGKey(0), jnp.float32(1.0))
    analytic = cfg.num_power_words * cfg.num_power_topics * 2 * 4  # Eq. 6
    measured = meter.phase_bytes("power")
    _emit("complexity/comm_measured_bytes_per_iter", measured,
          f"analytic={analytic} (Table 2 POBP row)")
    assert measured == analytic, (measured, analytic)
    _save("complexity", {"measured": measured, "analytic": analytic,
                         "iters": int(np.asarray(iters).reshape(-1)[0])})


# ------------------------------------------------------------------
# Fig. 5: residual tracks perplexity
# ------------------------------------------------------------------

def bench_convergence(quick=False):
    from benchmarks.common import base_cfg, corpus, split
    from repro.core import perplexity, ref
    from repro.data import docs_to_padded

    docs, stats, _ = corpus()
    train, test = split(docs)
    tr_b, te_b = docs_to_padded(train), docs_to_padded(test)
    cfg = base_cfg(residual_tol=1e-9)
    key = jax.random.PRNGKey(0)
    _, _, _, trace = ref.batch_bp(key, tr_b, cfg, iters=40)
    out = {"residual_trace": np.asarray(trace).tolist()}
    for it in ([20] if quick else [5, 20, 40]):
        _, phi_i, _, _ = ref.batch_bp(key, tr_b, cfg, iters=it)
        ppl = float(perplexity.evaluate(key, phi_i.T, tr_b, te_b, cfg))
        out[f"ppl_iter{it}"] = ppl
        _emit(f"convergence/iter{it}/ppl", f"{ppl:.2f}",
              f"residual={float(trace[min(it, 40) - 1]):.4f} (Fig. 5)")
    _save("convergence", out)


# ------------------------------------------------------------------
# this repo's perf trajectory: the selective inner iteration itself
# (tokens/sec + per-iteration wall time; seed [D, L, K] layout vs the
# token-major packed loop of DESIGN.md §2, plus the dense baseline)
# ------------------------------------------------------------------

def bench_inner_loop(quick=False):
    from benchmarks.common import base_cfg, corpus
    from repro.core import pobp, power as pw
    from repro.core.residuals import (mean_residual, packed_rw_delta,
                                      token_scatter_wk)
    from repro.core.sweep_dispatch import resolve_sweep_policy
    from repro.core.sync import LocalReducer
    from repro.data import docs_to_padded

    docs, stats, _ = corpus()
    batch = docs_to_padded(list(docs))
    red = LocalReducer()
    out = {"iters_timed": 30, "timing_rounds": 3, "parity_iters": 8}

    # (K, Pk) grid crossing the topic count with the power-topic width:
    # the Pk = K//8 diagonal matches bench_speed's regime and (64, 50) is
    # the LDAConfig default lambda_k_abs=50 (the paper's lambda_K*K = 50)
    # — the cell where a K-proportional selective iteration loses to the
    # dense sweep (the ISSUE 5 regression; quick mode keeps it so CI
    # guards the fix).
    grid = ([(64, 8), (64, 50)] if quick
            else [(64, 8), (128, 8), (128, 16), (64, 50)])
    gate_failures = []
    for K, Pk_req in grid:
        cfg = base_cfg(num_topics=K, lambda_k_abs=Pk_req,
                       residual_tol=1e-9, inner_iters=8)
        W, P = cfg.vocab_size, cfg.num_power_words
        Pk = cfg.num_power_topics
        layout = batch.token_layout()
        total_tokens = float(jnp.sum(batch.counts))
        policy = resolve_sweep_policy(cfg, layout.num_slots, K, Pk, P)

        # ---- shared state after the first dense sweep (Fig. 4 lines 3-10)
        key = jax.random.PRNGKey(0)
        u0 = jax.random.uniform(key, (*batch.word_ids.shape, K),
                                minval=0.01, maxval=1.0)
        mu0 = u0 / jnp.sum(u0, -1, keepdims=True)
        phi_eff = token_scatter_wk(batch.word_ids,
                                   batch.counts[..., None] * mu0, W)
        phi_tot = jnp.sum(phi_eff, axis=0)
        mu1, r_glob = pobp.dense_sweep(batch, mu0, phi_eff, phi_tot, cfg, red)
        theta = jnp.einsum("dl,dlk->dk", batch.counts, mu1)
        r_w = jnp.sum(r_glob, axis=1)
        state0 = dict(mu=mu1, theta=theta, phi_eff=phi_eff, phi_tot=phi_tot,
                      r_glob=r_glob, r_w=r_w)

        # ---- seed-layout iteration: full [D, L, K] rewrite + O(W*K) r_w
        def seed_step(mu, theta, phi_eff, phi_tot, r_glob, r_w):
            sel_w = pw.select_power_words(r_w, P)
            sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
            mu, theta, d_pack, r_pack = pobp.selective_sweep(
                batch, mu, theta, phi_eff, phi_tot, sel_w, sel_k, cfg)
            phi_eff = pw.scatter_add_rows(phi_eff, sel_w, sel_k, d_pack)
            phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d_pack)
            r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r_pack)
            return mu, theta, phi_eff, phi_tot, r_glob, jnp.sum(r_glob, 1)

        # ---- token-major iteration (the production body, policy-dispatched)
        def token_step(mu_t, theta, phi_eff, phi_tot, r_glob, r_w):
            sel_w = pw.select_power_words(r_w, P)
            sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
            mu_t, theta, d_pack, r_pack = pobp.selective_sweep_tokens(
                layout, mu_t, theta, phi_eff, phi_tot, sel_w, sel_k, cfg)
            rw_delta = packed_rw_delta(r_glob, sel_w, sel_k, r_pack)
            phi_eff = pw.scatter_add_rows(phi_eff, sel_w, sel_k, d_pack)
            phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d_pack)
            r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r_pack)
            return (mu_t, theta, phi_eff, phi_tot, r_glob,
                    r_w.at[sel_w].add(rw_delta))

        # ---- dense iteration (Eq. 4/5 baseline, for scale)
        def dense_step(mu, theta, phi_eff, phi_tot, r_glob, r_w):
            mu, r_wk = pobp.dense_sweep(batch, mu, phi_eff, phi_tot, cfg, red)
            phi_eff = token_scatter_wk(batch.word_ids,
                                       batch.counts[..., None] * mu, W)
            return (mu, jnp.einsum("dl,dlk->dk", batch.counts, mu), phi_eff,
                    jnp.sum(phi_eff, 0), r_wk, jnp.sum(r_wk, 1))

        def run_loop(step, st, iters, token_major, record_r=False,
                     rounds=1):
            carry0 = (st["mu"].reshape(-1, K) if token_major else st["mu"],
                      st["theta"], st["phi_eff"], st["phi_tot"],
                      st["r_glob"], st["r_w"])
            # NB: no donate_argnums — on CPU, donated carries force XLA into
            # an in-place update path that is ~2x slower than the fused
            # copy-and-update it emits for fresh outputs (both layouts are
            # measured under the same, faster, regime).
            fn = jax.jit(step)
            carry = fn(*carry0)                       # warmup/compile
            jax.block_until_ready(carry)
            best, trace = float("inf"), []
            for _ in range(rounds):                   # best-of to cut noise
                carry, trace = tuple(carry0), []
                t0 = time.time()
                for _ in range(iters):
                    carry = fn(*carry)
                    if record_r:
                        trace.append(float(mean_residual(carry[-1],
                                                         total_tokens)))
                jax.block_until_ready(carry)
                best = min(best, (time.time() - t0) / iters)
            return best, trace

        iters = out["iters_timed"]
        rounds = out["timing_rounds"]
        rec = {"policy": policy}
        for name, step, tm in (("seed_layout", seed_step, False),
                               ("token_major", token_step, True),
                               ("dense", dense_step, False)):
            dt, _ = run_loop(step, state0, iters, tm, rounds=rounds)
            rec[name] = {"iter_s": dt, "tokens_per_s": total_tokens / dt}
            _emit(f"inner_loop/K{K}_Pk{Pk}/{name}_tokens_per_s",
                  f"{total_tokens / dt:.0f}", f"iter={dt * 1e3:.2f}ms")
        speedup = rec["seed_layout"]["iter_s"] / rec["token_major"]["iter_s"]
        sel_vs_dense = rec["dense"]["iter_s"] / rec["token_major"]["iter_s"]
        _emit(f"inner_loop/K{K}_Pk{Pk}/token_major_speedup_x", f"{speedup:.2f}",
              "vs seed layout (acceptance: >= 2x at K >= 64)")
        _emit(f"inner_loop/K{K}_Pk{Pk}/selective_vs_dense_x",
              f"{sel_vs_dense:.2f}",
              f"policy={policy} (acceptance: >= 1 at every cell)")

        # ---- convergence parity: identical mean_r trajectories
        n_par = out["parity_iters"]
        _, tr_seed = run_loop(seed_step, state0, n_par, False, record_r=True)
        _, tr_tok = run_loop(token_step, state0, n_par, True, record_r=True)
        drift = max(abs(a - b) for a, b in zip(tr_seed, tr_tok))
        _emit(f"inner_loop/K{K}_Pk{Pk}/mean_r_max_drift", f"{drift:.2e}",
              "token-major vs seed trajectory (<= 1e-6)")
        rec.update(speedup_x=speedup, selective_vs_dense_x=sel_vs_dense,
                   mean_r_seed=tr_seed, mean_r_token=tr_tok,
                   mean_r_max_drift=drift, tokens=total_tokens, P=P, Pk=Pk,
                   T_slots=int(layout.num_slots))
        out[f"K{K}_Pk{Pk}"] = rec
        # the regression gates this grid exists for: trajectory parity
        # with the seed oracle, and the selective iteration never losing
        # to the dense sweep it replaces.  Quick mode (CI) allows 10%
        # timer noise on sub-second windows; the committed full-grid
        # artifact is the strict acceptance run.  Failures are collected
        # and raised AFTER _save so one flaky cell cannot discard the
        # whole run's measurements.
        floor = 0.9 if quick else 1.0
        if drift > 1e-6:
            gate_failures.append(("drift", K, Pk, drift))
        if sel_vs_dense < floor:
            gate_failures.append(("selective_vs_dense", K, Pk, rec))
    # ---- ultra-high-K cells (DESIGN.md §13): the K-blocked regime.
    # A reduced 48-doc subset keeps the [T, K] carries CPU-sized; each
    # cell pins a per-cell VMEM budget under which the full-K carry
    # kernel provably does NOT fit while the K-blocked variant does
    # (asserted analytically through the kernel's own choosers — the
    # timing below runs the jnp dense-layout mirror, which is what
    # 'kblocked' resolves to off-TPU).  The bf16 variant re-runs the
    # trajectory from a stochastically-rounded carry statistic: the
    # compressed-accumulator drift gate (<= 1e-3 vs <= 1e-6 for f32).
    from repro.core import quantize
    from repro.core.sweep_dispatch import carry_vmem_fit
    from repro.kernels.power_sweep.kernel import carry_vmem_fits, kblock_width

    hk_batch = docs_to_padded(list(docs)[:48])
    hk_grid = ([(1024, 16, 2_000_000)] if quick
               else [(1024, 16, 2_000_000), (4096, 16, 4_000_000)])
    for K, Pk_req, budget in hk_grid:
        cfg = base_cfg(num_topics=K, lambda_k_abs=Pk_req, residual_tol=1e-9,
                       inner_iters=8, vmem_budget_bytes=budget)
        W, P = cfg.vocab_size, cfg.num_power_words
        Pk = cfg.num_power_topics
        layout = hk_batch.token_layout()
        D = hk_batch.word_ids.shape[0]
        total_tokens = float(jnp.sum(hk_batch.counts))

        # the regime this cell exists for: under this budget the one-pass
        # carry kernel cannot hold a useful token tile, the K-blocked one
        # can, and pallas auto resolves accordingly
        assert not carry_vmem_fit(K, P, D, budget), (K, budget)
        P1 = -(-(P + 1) // 8) * 8          # sublane-padded row count
        kb = kblock_width(K, P1, D, budget)
        assert carry_vmem_fits(kb, P1, D, budget)
        policy = resolve_sweep_policy(cfg, layout.num_slots, K, Pk, P,
                                      impl="pallas", n_docs=D)
        assert policy == "kblocked", policy

        key = jax.random.PRNGKey(0)
        u0 = jax.random.uniform(key, (*hk_batch.word_ids.shape, K),
                                minval=0.01, maxval=1.0)
        mu0 = u0 / jnp.sum(u0, -1, keepdims=True)
        phi_eff = token_scatter_wk(hk_batch.word_ids,
                                   hk_batch.counts[..., None] * mu0, W)
        phi_tot = jnp.sum(phi_eff, axis=0)
        mu1, r_glob = pobp.dense_sweep(hk_batch, mu0, phi_eff, phi_tot,
                                       cfg, red)
        theta = jnp.einsum("dl,dlk->dk", hk_batch.counts, mu1)
        state0 = dict(mu=mu1, theta=theta, phi_eff=phi_eff, phi_tot=phi_tot,
                      r_glob=r_glob, r_w=jnp.sum(r_glob, axis=1))

        def tok_step(mu_t, theta, phi_eff, phi_tot, r_glob, r_w):
            sel_w = pw.select_power_words(r_w, P)
            sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
            mu_t, theta, d_pack, r_pack = pobp.selective_sweep_tokens(
                layout, mu_t, theta, phi_eff, phi_tot, sel_w, sel_k, cfg)
            rw_delta = packed_rw_delta(r_glob, sel_w, sel_k, r_pack)
            phi_eff = pw.scatter_add_rows(phi_eff, sel_w, sel_k, d_pack)
            phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d_pack)
            r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r_pack)
            return (mu_t, theta, phi_eff, phi_tot, r_glob,
                    r_w.at[sel_w].add(rw_delta))

        def seed_step(mu, theta, phi_eff, phi_tot, r_glob, r_w):
            sel_w = pw.select_power_words(r_w, P)
            sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
            mu, theta, d_pack, r_pack = pobp.selective_sweep(
                hk_batch, mu, theta, phi_eff, phi_tot, sel_w, sel_k, cfg)
            phi_eff = pw.scatter_add_rows(phi_eff, sel_w, sel_k, d_pack)
            phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d_pack)
            r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r_pack)
            return mu, theta, phi_eff, phi_tot, r_glob, jnp.sum(r_glob, 1)

        def dense_step(mu, theta, phi_eff, phi_tot, r_glob, r_w):
            mu, r_wk = pobp.dense_sweep(hk_batch, mu, phi_eff, phi_tot,
                                        cfg, red)
            phi_eff = token_scatter_wk(hk_batch.word_ids,
                                       hk_batch.counts[..., None] * mu, W)
            return (mu, jnp.einsum("dl,dlk->dk", hk_batch.counts, mu),
                    phi_eff, jnp.sum(phi_eff, 0), r_wk, jnp.sum(r_wk, 1))

        def run_hk(step, st, iters, token_major, record_r=False, rounds=1):
            carry0 = (st["mu"].reshape(-1, K) if token_major else st["mu"],
                      st["theta"], st["phi_eff"], st["phi_tot"],
                      st["r_glob"], st["r_w"])
            fn = jax.jit(step)
            carry = fn(*carry0)
            jax.block_until_ready(carry)
            best, trace = float("inf"), []
            for _ in range(rounds):
                carry, trace = tuple(carry0), []
                t0 = time.time()
                for _ in range(iters):
                    carry = fn(*carry)
                    if record_r:
                        trace.append(float(mean_residual(carry[-1],
                                                         total_tokens)))
                jax.block_until_ready(carry)
                best = min(best, (time.time() - t0) / iters)
            return best, trace

        rec = {"policy": policy, "kb": int(kb), "vmem_budget_bytes": budget,
               "fullk_fits": False, "kblocked_fits": True}
        dt_tok, _ = run_hk(tok_step, state0, 10, True, rounds=2)
        dt_den, _ = run_hk(dense_step, state0, 10, False, rounds=2)
        rec["token_major"] = {"iter_s": dt_tok,
                              "tokens_per_s": total_tokens / dt_tok}
        rec["dense"] = {"iter_s": dt_den,
                        "tokens_per_s": total_tokens / dt_den}
        sel_vs_dense = dt_den / dt_tok
        rec["selective_vs_dense_x"] = sel_vs_dense
        _emit(f"inner_loop/K{K}_Pk{Pk}/selective_vs_dense_x",
              f"{sel_vs_dense:.2f}",
              f"policy={policy} kb={kb} budget={budget} "
              f"(full-K carry does not fit)")

        n_par = 4
        _, tr_seed = run_hk(seed_step, state0, n_par, False, record_r=True)
        _, tr_tok = run_hk(tok_step, state0, n_par, True, record_r=True)
        drift = max(abs(a - b) for a, b in zip(tr_seed, tr_tok))
        st16 = dict(state0, phi_eff=quantize.stochastic_round(
            state0["phi_eff"], jnp.bfloat16,
            jax.random.PRNGKey(1)).astype(jnp.float32))
        _, tr_b16 = run_hk(tok_step, st16, n_par, True, record_r=True)
        drift16 = max(abs(a - b) for a, b in zip(tr_tok, tr_b16))
        _emit(f"inner_loop/K{K}_Pk{Pk}/mean_r_max_drift", f"{drift:.2e}",
              "token-major vs seed trajectory (<= 1e-6)")
        _emit(f"inner_loop/K{K}_Pk{Pk}/mean_r_bf16_drift", f"{drift16:.2e}",
              "bf16-quantized carry statistic vs f32 (<= 1e-3)")
        rec.update(mean_r_seed=tr_seed, mean_r_token=tr_tok,
                   mean_r_bf16=tr_b16, mean_r_max_drift=drift,
                   mean_r_bf16_drift=drift16, tokens=total_tokens,
                   P=P, Pk=Pk, T_slots=int(layout.num_slots), D=D)
        out[f"K{K}_Pk{Pk}"] = rec
        floor = 0.9 if quick else 1.0
        if drift > 1e-6:
            gate_failures.append(("drift", K, Pk, drift))
        if drift16 > 1e-3:
            gate_failures.append(("bf16_drift", K, Pk, drift16))
        if sel_vs_dense < floor:
            gate_failures.append(("selective_vs_dense", K, Pk, rec))

    # quick mode writes a separate file so a smoke run can never clobber
    # the committed full-grid artifact
    _save("BENCH_inner_loop_quick" if quick else "BENCH_inner_loop", out)
    assert not gate_failures, gate_failures


# ------------------------------------------------------------------
# this repo's e2e trajectory: the production streaming driver
# (tokens/s + compile count; fixed-L vs shape-bucketed variable-L —
# acceptance: bucketed within 20% of fixed-L throughput, compiles
# bounded by the bucket count)
# ------------------------------------------------------------------

def bench_e2e(quick=False):
    from repro.launch.lda_train import default_args, train_loop

    common = dict(minibatches=8 if quick else 20, docs_per_batch=32,
                  shards=2, vocab=300, topics=16, lambda_k=8,
                  inner_iters=8, tol=1e-9, log_every=0, eval_every=0,
                  doc_len_means="12,24,40", len_buckets="16,32,48")
    out = {"config": common}
    for name, fixed in (("fixed_L", True), ("bucketed_variable_L", False)):
        # --warmup-buckets (default) pre-compiles every bucket shape, so
        # tokens_per_s is the steady-state rate an unbounded stream
        # converges to; warmup_s is the one-time startup cost.
        res = train_loop(default_args(fixed_len=fixed, **common))
        out[name] = {k: res[k] for k in
                     ("tokens_per_s", "compiles", "wall_s", "warmup_s",
                      "tokens", "per_minibatch_bytes")}
        out[name]["mean_r_final"] = res["mean_r"][-1]
        _emit(f"e2e/{name}/tokens_per_s", f"{res['tokens_per_s']:.0f}",
              f"compiles={res['compiles']} warmup={res['warmup_s']:.1f}s "
              f"wall={res['wall_s']:.1f}s")
    ratio = (out["bucketed_variable_L"]["tokens_per_s"]
             / max(out["fixed_L"]["tokens_per_s"], 1e-9))
    out["bucketed_vs_fixed_throughput"] = ratio
    _emit("e2e/bucketed_vs_fixed_throughput", f"{ratio:.2f}",
          "acceptance: >= 0.8 (ISSUE 2)")
    if not quick:
        # quick mode times ~0.3s windows — too noisy to gate CI on; the
        # full run's longer stream is the acceptance measurement
        assert ratio >= 0.8, out
    n_buckets = len(common["len_buckets"].split(","))
    _emit("e2e/bucketed_compiles", out["bucketed_variable_L"]["compiles"],
          f"bound: <= {n_buckets} buckets")
    # compiles == -1 means the cache-size hook broke (private jax API):
    # fail loudly rather than letting the acceptance gate pass vacuously
    assert 0 < out["bucketed_variable_L"]["compiles"] <= n_buckets
    # quick mode writes a separate file so a smoke run can never clobber
    # the committed full artifact
    _save("BENCH_e2e_quick" if quick else "BENCH_e2e", out)


# ------------------------------------------------------------------
# this repo's serving trajectory: the fold-in engine (ISSUE 3)
# (docs/s + p99 latency; token-major early-exit fold-in vs the dense
# [D, L, K] reference on the same bucket ladder — acceptance: >= 2x
# docs/s at K >= 64)
# ------------------------------------------------------------------

def bench_serve(quick=False):
    from repro.core import infer
    from repro.core.perplexity import normalize_phi
    from repro.core.types import LDAConfig, MiniBatch
    from repro.data import bucket_len, docs_to_padded
    from repro.data.synthetic import lda_corpus
    from repro.serve import FoldInEngine

    buckets = (16, 32, 64)
    batch_docs = 32
    fold_iters = 30
    tol = 1e-2
    n_req = 128 if quick else 256
    out = {"config": dict(buckets=buckets, batch_docs=batch_docs,
                          fold_iters=fold_iters, residual_tol=tol,
                          requests=n_req)}

    def requests(W, K):
        reqs = []
        for i, mean in enumerate((12, 24, 40)):
            d, _, phi_true = lda_corpus(100 + i, -(-n_req // 3), W, K,
                                        doc_len_mean=mean)
            reqs.extend(d)
        return reqs[:n_req], phi_true

    def run_dense(reqs, phi_norm, cfg):
        """The seed's dense fold-in under the SAME bucket ladder/admission
        (fixed sweeps — the dense path has no residual carry to exit on)."""
        fold = jax.jit(lambda key, wid, cnt: infer.fold_in_dense_reference(
            key, MiniBatch(wid, cnt), phi_norm, cfg, iters=fold_iters))
        key = jax.random.PRNGKey(0)
        for b in buckets:                                  # AOT warmup
            jax.block_until_ready(fold(key, jnp.zeros((batch_docs, b),
                                                      jnp.int32),
                                       jnp.zeros((batch_docs, b))))
        queues = {b: [] for b in buckets}
        pending, t0 = [], time.time()
        for doc in reqs:
            b = bucket_len(len(doc[0]), buckets)
            queues[b].append((doc, time.time()))
            if len(queues[b]) == batch_docs:
                batch, queues[b] = queues[b], []
                mb = docs_to_padded([d for d, _ in batch], max_len=b)
                key, sub = jax.random.split(key)
                pending.append((fold(sub, mb.word_ids, mb.counts),
                                [t for _, t in batch]))
        for b in buckets:
            if queues[b]:
                mb = docs_to_padded([d for d, _ in queues[b]], max_len=b)
                key, sub = jax.random.split(key)
                pending.append((fold(sub, mb.word_ids, mb.counts),
                                [t for _, t in queues[b]]))
        lats, t_done = [], t0
        for theta, subs in pending:
            jax.block_until_ready(theta)
            t_done = time.time()
            lats.extend(t_done - t for t in subs)
        return {"docs_per_s": len(reqs) / max(t_done - t0, 1e-9),
                "latency_p99_s": float(np.percentile(lats, 99))}

    for K in ([64] if quick else [64, 128]):
        W = 1000
        cfg = LDAConfig(vocab_size=W, num_topics=K)
        reqs, phi_true = requests(W, K)
        phi_acc = jnp.asarray(phi_true.T) * 200.0      # converged stand-in

        eng = FoldInEngine(phi_acc, cfg, len_buckets=buckets,
                           batch_docs=batch_docs, fold_iters=fold_iters,
                           residual_tol=tol, seed=1)
        for doc in reqs:
            eng.submit(doc)
        eng.drain()
        tok = eng.stats()

        dense = run_dense(reqs, normalize_phi(phi_acc, cfg.beta), cfg)
        speedup = tok["docs_per_s"] / max(dense["docs_per_s"], 1e-9)
        rec = {"token_major": {k: tok[k] for k in
                               ("docs_per_s", "latency_p50_s",
                                "latency_p99_s", "mean_fold_iters",
                                "compiles", "warmup_s")},
               "dense": dense, "speedup_x": speedup}
        out[f"K{K}"] = rec
        _emit(f"serve/K{K}/token_major_docs_per_s",
              f"{tok['docs_per_s']:.0f}",
              f"p99={tok['latency_p99_s'] * 1e3:.1f}ms "
              f"iters={tok['mean_fold_iters']:.1f}")
        _emit(f"serve/K{K}/dense_docs_per_s", f"{dense['docs_per_s']:.0f}",
              f"p99={dense['latency_p99_s'] * 1e3:.1f}ms iters={fold_iters}")
        _emit(f"serve/K{K}/speedup_x", f"{speedup:.2f}",
              "acceptance: >= 2x at K >= 64")
        if not quick:
            # quick mode times sub-second windows — too noisy to gate on
            assert speedup >= 2.0, rec
    # quick mode writes a separate file so a smoke run can never clobber
    # the committed full artifact
    _save("BENCH_serve_quick" if quick else "BENCH_serve", out)


# ------------------------------------------------------------------
# this repo's serving trajectory, continued (ISSUE 9): continuous-
# batching slab vs the bucket ladder under SUSTAINED open-loop load —
# heavy-tailed document lengths, exponential arrivals at target QPS.
# goodput@SLO counts only requests served within the latency objective.
# acceptance (full): peak slab goodput@SLO >= 1.5x the ladder's, and a
# mid-stream swap_phi keeps p99 <= 2x steady-state.  quick gates >= 1x.
# ------------------------------------------------------------------

def bench_serve_sustained(quick=False):
    from repro.core.types import LDAConfig
    from repro.data.synthetic import lda_corpus
    from repro.launch.serve import run_open_loop
    from repro.serve import FoldInEngine, SlabEngine

    K, W = 64, 1000
    fold_iters, tol = 30, 1e-2
    slo_s = 0.040
    n_req = 200 if quick else 600
    rng = np.random.default_rng(42)
    # production length distributions are heavy-tailed — the regime where
    # a bucket ladder needs many rungs, each filling too slowly to batch
    # without staleness flushes (padded work) or queueing delay
    lens = np.clip(np.exp(rng.normal(3.0, 0.8, n_req)), 4, 256).astype(int)
    _, _, phi_true = lda_corpus(100, 8, W, K, doc_len_mean=40)
    reqs = []
    for L in lens:
        ids = rng.choice(W, size=min(int(L), W), replace=False)
        cnt = np.maximum(rng.poisson(1.5, len(ids)), 1)
        reqs.append((ids.astype(np.int32), cnt.astype(np.float32)))
    phi_acc = jnp.asarray(phi_true.T) * 200.0
    cfg = LDAConfig(vocab_size=W, num_topics=K)
    out = {"config": dict(K=K, W=W, requests=n_req, slo_ms=slo_s * 1e3,
                          len_p50=float(np.percentile(lens, 50)),
                          len_p95=float(np.percentile(lens, 95)))}

    def make_slab(**kw):
        return SlabEngine(phi_acc, cfg, slots=64, slot_len=64,
                          sweeps_per_step=4, refill_cap=16,
                          fold_iters=fold_iters, residual_tol=tol,
                          seed=1, **kw)

    def make_bucket():
        return FoldInEngine(phi_acc, cfg,
                            len_buckets=(8, 16, 32, 64, 128, 256),
                            batch_docs=32, fold_iters=fold_iters,
                            residual_tol=tol, seed=1)

    def closed_cap(eng):
        t0 = time.time()
        for doc in reqs:
            eng.submit(doc)
        res = eng.drain()
        assert len(res) == n_req
        return len(res) / max(time.time() - t0, 1e-9)

    out["closed_loop"] = {"slab_docs_per_s": closed_cap(make_slab()),
                          "bucket_docs_per_s": closed_cap(make_bucket())}

    def open_run(eng, qps, **kw):
        res, wall = run_open_loop(eng, reqs, qps, seed=7, **kw)
        lats = np.asarray([r.latency_s for r in res])
        good = int((lats <= slo_s).sum())
        return {"qps": qps, "goodput_slo": good / max(wall, 1e-9),
                "goodput_total": len(res) / max(wall, 1e-9),
                "good_frac": good / max(len(res), 1),
                "latency_p50_s": float(np.percentile(lats, 50)),
                "latency_p99_s": float(np.percentile(lats, 99))}

    # open-loop QPS ladder: goodput@SLO per engine, peak gated
    qps_ladder = [1500] if quick else [800, 1500, 2500]
    best = {"slab": 0.0, "bucket": 0.0}
    for qps in qps_ladder:
        s = open_run(make_slab(), qps)
        b = open_run(make_bucket(), qps, max_age_s=slo_s / 2)
        out[f"qps{qps}"] = {"slab": s, "bucket": b}
        best["slab"] = max(best["slab"], s["goodput_slo"])
        best["bucket"] = max(best["bucket"], b["goodput_slo"])
        _emit(f"serve_sustained/qps{qps}/slab_goodput_slo",
              f"{s['goodput_slo']:.0f}",
              f"p99={s['latency_p99_s'] * 1e3:.1f}ms "
              f"frac={s['good_frac']:.2f}")
        _emit(f"serve_sustained/qps{qps}/bucket_goodput_slo",
              f"{b['goodput_slo']:.0f}",
              f"p99={b['latency_p99_s'] * 1e3:.1f}ms "
              f"frac={b['good_frac']:.2f}")
    ratio = best["slab"] / max(best["bucket"], 1e-9)
    out["goodput_ratio"] = ratio
    _emit("serve_sustained/goodput_slo_ratio", f"{ratio:.2f}",
          "acceptance: >= 1.5x full, >= 1.0x quick")
    assert ratio >= (1.0 if quick else 1.5), out

    # SLO under hot-swap: steady-state p99 vs a mid-stream swap_phi run
    # (same qps; the swap fences by pumping the slab dry, so its cost is
    # bounded by draining one slab of in-flight work)
    qps_swap = qps_ladder[len(qps_ladder) // 2]
    steady = open_run(make_slab(), qps_swap)
    swapped = open_run(make_slab(), qps_swap, swap_at=0.5,
                       swap_fn=lambda e: e.swap_phi(phi_acc))
    out["swap"] = {"steady": steady, "swapped": swapped}
    p99_x = swapped["latency_p99_s"] / max(steady["latency_p99_s"], 1e-9)
    _emit("serve_sustained/swap_p99_x", f"{p99_x:.2f}",
          f"steady p99={steady['latency_p99_s'] * 1e3:.1f}ms "
          f"swapped p99={swapped['latency_p99_s'] * 1e3:.1f}ms "
          "(acceptance: <= 2x, full mode)")
    if not quick:
        # quick mode times sub-second windows — too noisy to gate on
        assert p99_x <= 2.0, out["swap"]

    # theta cache: a duplicate-heavy stream (hot documents repeat).  The
    # hot set is primed first — in production the first arrival of each
    # hot doc pays the fold-in and later repeats hit — then the repeat
    # stream is timed: 'serve' hits skip fold-in entirely, 'warm' hits
    # converge in fewer sweeps
    hot = reqs[:max(1, n_req // 10)]
    dup = [hot[rng.integers(0, len(hot))] for _ in range(n_req)]

    def run_dup(engine):
        for doc in hot:
            engine.submit(doc, tenant="t0")
        engine.drain()
        t0 = time.time()
        for doc in dup:
            engine.submit(doc, tenant="t0")
        engine.drain()
        return time.time() - t0, engine.stats()

    hot_s, cs = run_dup(make_slab(theta_cache=1024))
    cold_s, _ = run_dup(make_slab())
    _, ws = run_dup(make_slab(theta_cache=1024, cache_mode="warm"))
    out["cache"] = {"hit_rate": cs["cache"]["hit_rate"],
                    "serve_mode_wall_s": hot_s, "no_cache_wall_s": cold_s,
                    "serve_speedup_x": cold_s / max(hot_s, 1e-9),
                    "warm_fold_iters": ws["warm_fold_iters"],
                    "cold_fold_iters": ws["cold_fold_iters"]}
    _emit("serve_sustained/cache_hit_rate", f"{cs['cache']['hit_rate']:.2f}",
          f"serve-mode speedup {cold_s / max(hot_s, 1e-9):.1f}x")
    _emit("serve_sustained/warm_vs_cold_iters",
          f"{ws['warm_fold_iters']:.1f} vs {ws['cold_fold_iters']:.1f}",
          "warm starts must converge in fewer sweeps")
    if not quick:
        assert cs["cache"]["hit_rate"] > 0.5, out["cache"]
        assert 0 < ws["warm_fold_iters"] < ws["cold_fold_iters"], \
            out["cache"]
    _save("BENCH_serve_sustained_quick" if quick
          else "BENCH_serve_sustained", out)


# ------------------------------------------------------------------
# this repo's dynamic-vocabulary trajectory (ISSUE 4): the capacity-
# laddered driver on a drifting-vocab stream vs the fixed-W driver —
# acceptance: steady-state tokens/s within 10%, per-minibatch sync
# bytes scaling with live W (not the rung capacity W_cap)
# ------------------------------------------------------------------

def bench_vocab_growth(quick=False):
    from repro.launch.lda_train import default_args, train_loop

    common = dict(minibatches=10 if quick else 24, docs_per_batch=32,
                  shards=2, topics=16, lambda_k=8, inner_iters=8, tol=1e-9,
                  log_every=0, eval_every=0, doc_len_means="12,24,40",
                  len_buckets="16,32,48")
    dyn = train_loop(default_args(
        dynamic_vocab=True, vocab=150, vocab_growth_per_batch=40,
        w_cap_min=128, w_growth=2.0, **common))
    n_rungs = 1 + len(dyn["growth_events"])
    n_buckets = len(common["len_buckets"].split(","))
    # the fixed-W baseline: a static vocabulary the size of the final rung
    fixed = train_loop(default_args(vocab=dyn["w_cap"], **common))

    ratio = dyn["tokens_per_s"] / max(fixed["tokens_per_s"], 1e-9)
    bytes_cap = dyn["per_minibatch_bytes"]
    bytes_live = dyn["per_minibatch_bytes_live"]
    out = {"config": common,
           "dynamic": {k: dyn[k] for k in
                       ("tokens_per_s", "compiles", "wall_s", "warmup_s",
                        "growth_s", "tokens", "w_cap", "live_w",
                        "growth_events", "per_minibatch_bytes",
                        "per_minibatch_bytes_live")},
           "fixed_W": {k: fixed[k] for k in
                       ("tokens_per_s", "compiles", "wall_s", "tokens",
                        "per_minibatch_bytes")},
           "dyn_vs_fixed_throughput": ratio,
           "live_over_cap_bytes": bytes_live / max(bytes_cap, 1)}
    _emit("vocab_growth/dynamic_tokens_per_s", f"{dyn['tokens_per_s']:.0f}",
          f"growths={len(dyn['growth_events'])} W_cap={dyn['w_cap']} "
          f"live={dyn['live_w']}")
    _emit("vocab_growth/fixed_tokens_per_s", f"{fixed['tokens_per_s']:.0f}",
          f"W={dyn['w_cap']}")
    _emit("vocab_growth/dyn_vs_fixed_throughput", f"{ratio:.2f}",
          "acceptance: >= 0.9 (ISSUE 4)")
    _emit("vocab_growth/bytes_live_over_cap",
          f"{out['live_over_cap_bytes']:.2f}",
          f"live={bytes_live:,}B cap={bytes_cap:,}B — scales with live W")
    _emit("vocab_growth/compiles", dyn["compiles"],
          f"bound: <= {n_rungs} rungs x {n_buckets} buckets")
    assert len(dyn["growth_events"]) >= 2, dyn["growth_events"]
    assert 0 < dyn["compiles"] <= n_rungs * n_buckets
    # honest Eq. 5/6 accounting: guard rows never cross the interconnect
    assert bytes_live < bytes_cap
    if not quick:
        # quick mode times sub-second windows — too noisy to gate on
        assert ratio >= 0.9, out
    _save("BENCH_vocab_growth_quick" if quick else "BENCH_vocab_growth", out)


# ------------------------------------------------------------------
# this repo's stream lifecycle (ISSUE 7, DESIGN.md §14): RM decay +
# fenced compaction on a SLIDING drifting-news stream vs the plain
# accumulate-forever driver — acceptance: live-row occupancy stays
# <= 1.2x the drifting-truth vocabulary (vs monotone growth without),
# and end-of-stream sliding held-out ppl is better than no-decay
# ------------------------------------------------------------------

def bench_drift(quick=False):
    from repro.launch.lda_train import default_args, train_loop

    window, drift = 192, 4
    mb = 30 if quick else 60
    common = dict(minibatches=mb, docs_per_batch=32, shards=1, topics=12,
                  vocab=window, lambda_k=8, inner_iters=8, tol=1e-9,
                  dynamic_vocab=True, drift_mode="slide",
                  vocab_growth_per_batch=drift, w_cap_min=128, w_growth=2.0,
                  log_every=0, eval_every=10, eval_docs=96,
                  doc_len_means="24,40", len_buckets="32,48")
    life = train_loop(default_args(
        decay="1,0.3", compact_every=5, compact_min_idle=4,
        compact_mass_tol=60.0, recycle_tol=0.01, **common))
    base = train_loop(default_args(**common))   # accumulate forever

    truth = window          # the drifting-truth live vocabulary, every batch
    occ = life["live_w"] / truth
    occ_base = base["live_w"] / truth
    out = {"config": dict(common, decay="1,0.3", compact_every=5,
                          compact_min_idle=4, compact_mass_tol=60.0,
                          recycle_tol=0.01),
           "truth_vocab": truth,
           "lifecycle": {k: life[k] for k in
                         ("live_w", "w_cap", "ppl", "ppl_trace",
                          "tokens_per_s", "compiles", "compact_s",
                          "compaction_events", "occupancy_trace",
                          "vocab_version", "growth_events")},
           "baseline": {k: base[k] for k in
                        ("live_w", "w_cap", "ppl", "ppl_trace",
                         "tokens_per_s", "compiles", "growth_events")},
           "occupancy_x_truth": occ,
           "baseline_occupancy_x_truth": occ_base,
           "ppl_final": life["ppl"], "ppl_final_baseline": base["ppl"]}
    _emit("drift/lifecycle_live_w", life["live_w"],
          f"= {occ:.2f}x truth vocab ({truth}); acceptance <= 1.2x")
    _emit("drift/baseline_live_w", base["live_w"],
          f"= {occ_base:.2f}x truth — monotone growth without lifecycle")
    _emit("drift/compactions", len(life["compaction_events"]),
          f"vocab_version={life['vocab_version']} "
          f"compact_s={life['compact_s']:.1f}")
    _emit("drift/lifecycle_ppl", f"{life['ppl']:.2f}",
          "sliding held-out, end of stream")
    _emit("drift/baseline_ppl", f"{base['ppl']:.2f}",
          "acceptance: lifecycle ppl strictly better")
    # CI gates (ISSUE 7): bounded occupancy where the baseline grows
    # monotonically, and the decayed model fits the drifted present better
    assert occ <= 1.2, out
    assert occ_base > 1.2, out
    assert len(life["compaction_events"]) >= 2, out
    assert life["ppl"] < base["ppl"], out
    _save("BENCH_drift_quick" if quick else "BENCH_drift", out)


# ------------------------------------------------------------------
# Fig. 6: power-law (rank-size) structure of residuals
# ------------------------------------------------------------------

def bench_powerlaw(quick=False):
    from benchmarks.common import base_cfg
    from repro.core import ref
    from repro.data import docs_to_padded
    from repro.data.synthetic import zipf_corpus

    docs, stats = zipf_corpus(0, 200 if quick else 400, 2000,
                              doc_len_mean=120, zipf_s=1.07)
    cfg = base_cfg(vocab_size=2000, residual_tol=1e-9)
    b = docs_to_padded(list(docs))
    mu = ref.init_messages(jax.random.PRNGKey(0), b, cfg.num_topics)
    phi0 = jnp.zeros((cfg.num_topics, cfg.vocab_size))
    r_wk = None
    for _ in range(10):
        mu, r_wk, _ = ref.bp_sweep(b, mu, phi0, cfg)
    r_w = np.sort(np.asarray(jnp.sum(r_wk, 1)))[::-1]
    r_w = r_w[r_w > 0]
    total = r_w.sum()
    top10 = r_w[: max(1, len(r_w) // 10)].sum() / total * 100
    top20 = r_w[: max(1, len(r_w) // 5)].sum() / total * 100
    n = len(r_w)
    xs, ys = np.log(np.arange(1, n + 1)), np.log(r_w)
    slope = float(np.polyfit(xs[: n // 2], ys[: n // 2], 1)[0])
    _emit("powerlaw/top10pct_share", f"{top10:.1f}%", "paper: ~79% (Fig. 6)")
    _emit("powerlaw/top20pct_share", f"{top20:.1f}%", "paper: ~90%")
    _emit("powerlaw/loglog_slope", f"{slope:.2f}")
    _save("powerlaw", {"top10": float(top10), "top20": float(top20),
                       "slope": slope})


# ------------------------------------------------------------------
# this repo's chaos-hardened runtime (ISSUE 10, DESIGN.md §17).
# train: a seeded fault schedule (drops + duplicate deliveries + one
# server crash/restart) over the PS backend at S=0 must commit phi
# BIT-EXACT with the clean PS run — sequence-number dedup applies each
# delta exactly once and the replay fence restores version order — so
# perplexity holds trivially (gated <= 1.02x for the artifact), and
# the audit logs must show recovery actually completed.  serve: a
# SlabEngine burst against an admission SLO sheds typed and bounded
# (0 < shed_frac <= 0.95) while goodput stays positive, and a
# poisoned request is quarantined without souring the slab.
# ------------------------------------------------------------------

def bench_fault(quick=False):
    from repro.core.types import LDAConfig
    from repro.data.synthetic import lda_corpus
    from repro.launch.lda_train import default_args, train_loop
    from repro.serve import Shed, SlabEngine

    common = dict(minibatches=8 if quick else 16, docs_per_batch=32,
                  shards=2, vocab=2000 if quick else 4000,
                  topics=16, lambda_k=8, inner_iters=8, tol=1e-9,
                  log_every=0, eval_every=0,
                  doc_len_means="12,24,40", len_buckets="16,32,48",
                  ps_servers=4, seed=0)
    chaos_kw = dict(chaos_seed=7, chaos_drop=0.25, chaos_dup=0.25,
                    chaos_crash="1@6", chaos_restart_after=2)
    out = {"config": dict(common, **chaos_kw)}
    gates = []

    ar = train_loop(default_args(**common, backend="sim"))
    clean = train_loop(default_args(**common, backend="ps", staleness=0))
    chaos = train_loop(default_args(**common, backend="ps", staleness=0,
                                    **chaos_kw))

    bitexact = bool(np.array_equal(np.asarray(chaos["phi_acc"]),
                                   np.asarray(clean["phi_acc"])))
    ppl_x = chaos["ppl"] / max(clean["ppl"], 1e-9)
    drift = max(abs(a - b) for a, b in zip(clean["mean_r"], ar["mean_r"]))
    ev = chaos["chaos_events"]
    recovered = sum(e["event"] == "recovered"
                    for e in chaos["ps_recovery_log"])
    out["train"] = {
        "bitexact_phi_vs_clean": bitexact,
        "ppl_clean": clean["ppl"], "ppl_chaos": chaos["ppl"],
        "ppl_ratio": ppl_x,
        "mean_r_drift_s0_vs_allreduce": drift,
        "chaos_events": ev,
        "ps_retries": chaos["ps_retries"],
        "ps_replayed_pushes": chaos["ps_replayed_pushes"],
        "ps_recoveries": chaos["ps_recoveries"],
        "ps_duplicates_dropped": chaos["ps_duplicates_dropped"],
        "ps_retry_wire_bytes": chaos["ps_retry_wire_bytes"],
        "ps_recovery_log": chaos["ps_recovery_log"],
        "wire_bytes_clean": clean["ps_wire_bytes"],
        "wire_bytes_chaos": chaos["ps_wire_bytes"],
    }
    _emit("fault/train/bitexact_phi", bitexact,
          "acceptance: chaos phi == clean PS phi at S=0")
    _emit("fault/train/ppl_ratio", f"{ppl_x:.4f}",
          f"chaos {chaos['ppl']:.2f} vs clean {clean['ppl']:.2f}; "
          "acceptance <= 1.02")
    _emit("fault/train/recoveries", recovered,
          f"events={ev} retries={chaos['ps_retries']} "
          f"replayed={chaos['ps_replayed_pushes']} "
          f"dups_dropped={chaos['ps_duplicates_dropped']}")
    _emit("fault/train/s0_drift_vs_allreduce", f"{drift:.2e}",
          "acceptance <= 1e-6")
    gates.append(("chaos phi not bit-exact with the clean PS run",
                  bitexact))
    gates.append((f"chaos ppl ratio {ppl_x:.4f} > 1.02", ppl_x <= 1.02))
    gates.append((f"recovery never completed: "
                  f"log={chaos['ps_recovery_log']}", recovered >= 1))
    gates.append((f"fault schedule too tame to gate on: events={ev} "
                  f"dups_dropped={chaos['ps_duplicates_dropped']}",
                  ev.get("drop", 0) > 0 and ev.get("crash", 0) == 1
                  and chaos["ps_duplicates_dropped"] > 0))
    gates.append((f"clean S=0 drift {drift:.2e} > 1e-6 vs allreduce",
                  drift <= 1e-6))

    # ---- serve: SLO-aware admission shedding + poison quarantine ----
    # slot_len 32 with 24-token docs -> 1 doc/slot; tenure = fold/sweeps
    # = 8 steps; refill_cap = slots//4 = 2 -> dispatch rate = 1 doc/step.
    # Phase A runs MATCHED load (1 submit per step, the drain rate) long
    # enough for the step EMA to converge past the warm-up compile
    # spikes; the admission SLO is then pinned at 1.5x the empty-queue
    # wait estimate, so the 4x-overload phase B self-regulates: the
    # queue hovers at the boundary, ~3/4 of the excess sheds, the rest
    # is served within the estimate — bounded degradation, not collapse.
    K, W = 32, 500
    cfg = LDAConfig(vocab_size=W, num_topics=K)
    _, _, phi_true = lda_corpus(100, 8, W, K, doc_len_mean=24)
    phi_acc = jnp.asarray(phi_true.T) * 200.0
    rng = np.random.default_rng(3)
    n_req = 64 if quick else 192

    def doc():
        ids = rng.choice(W, size=24, replace=False)
        return ids.astype(np.int32), np.ones(24, np.float32)

    # residual_tol pinned tiny so every doc runs its full fold tenure —
    # early residual exits would drain the slab faster than the burst
    # arrives and the queue (hence the shed boundary) would never build
    eng = SlabEngine(phi_acc, cfg, slots=8, slot_len=32,
                     sweeps_per_step=2, fold_iters=16, residual_tol=1e-9,
                     seed=1, admission_slo_s=10.0)
    for _ in range(40):                     # phase A: matched load
        eng.submit(doc())
        eng.step()
    ema = eng.stats()["step_ema_s"]
    tenure = max(1.0, eng.fold_iters / eng.sweeps_per_step)
    eng.admission_slo_s = ema * tenure * 1.5

    sheds = []
    for i in range(n_req):                  # phase B: 4x overload
        res = eng.submit(doc())
        if isinstance(res, Shed):
            sheds.append(res)
        if i % 4 == 3:
            eng.step()
    bad = eng.submit((np.arange(4, dtype=np.int32),
                      np.array([1.0, np.inf, 1.0, np.nan], np.float32)))
    done = eng.drain()
    st = eng.stats()
    poison = [r for r in done if r.req_id == bad]
    good = [r for r in done if r.error is None]
    out["serve"] = {
        "requests": n_req, "admission_slo_s": eng.admission_slo_s,
        "step_ema_s": ema, "shed": st["shed"],
        "shed_frac": st["shed_frac"], "served_ok": len(good),
        "quarantined": st["quarantined"],
        "shed_est_wait_p50_s": (float(np.median(
            [s.est_wait_s for s in sheds])) if sheds else 0.0),
    }
    _emit("fault/serve/shed_frac", f"{st['shed_frac']:.2f}",
          f"{st['shed']} shed / {len(good)} served ok; "
          "acceptance: 0 < frac <= 0.95")
    _emit("fault/serve/quarantined", st["quarantined"],
          "poisoned request isolated, slab stays healthy")
    gates.append((f"no sheds under {n_req}-deep overload burst",
                  st["shed"] > 0))
    gates.append((f"shed_frac {st['shed_frac']:.2f} outside (0, 0.95] — "
                  "shedding collapsed to all-or-nothing",
                  0 < st["shed_frac"] <= 0.95))
    gates.append(("overloaded slab served nothing cleanly",
                  len(good) > 0))
    gates.append((f"poison not quarantined: {poison}",
                  len(poison) == 1
                  and poison[0].error == "nonfinite_input"
                  and st["quarantined"] >= 1))
    gates.append(("typed Shed lost its diagnostics",
                  all(s.est_wait_s > s.slo_s and s.queue_depth >= 0
                      for s in sheds)))

    # artifact first, gates second: a failed gate still leaves the
    # numbers on disk for the CI artifact
    _save("BENCH_fault_quick" if quick else "BENCH_fault", out)
    failures = [msg for msg, ok in gates if not ok]
    assert not failures, (failures, out)


# ------------------------------------------------------------------

ALL = [bench_comm_volume, bench_comm, bench_lambda_sweep, bench_accuracy,
       bench_speed, bench_inner_loop, bench_e2e, bench_serve,
       bench_serve_sustained, bench_fault, bench_vocab_growth,
       bench_drift, bench_scalability, bench_memory, bench_complexity,
       bench_convergence, bench_powerlaw]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter over section function names "
                         "(legacy; 'comm' now matches both comm sections — "
                         "prefer --sections for exact selection)")
    ap.add_argument("--sections", default=None,
                    help="comma-separated EXACT section names, the function "
                         "name minus its bench_ prefix: e.g. "
                         "--sections comm,inner_loop")
    args = ap.parse_args()
    wanted = None
    if args.sections:
        wanted = {s.strip() for s in args.sections.split(",") if s.strip()}
        known = {fn.__name__[len("bench_"):] for fn in ALL}
        unknown = wanted - known
        if unknown:
            ap.error(f"unknown --sections {sorted(unknown)}; "
                     f"known: {sorted(known)}")
    print("name,value,derived")
    for fn in ALL:
        if wanted is not None and fn.__name__[len("bench_"):] not in wanted:
            continue
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        fn(quick=args.quick)
        _emit(f"_section/{fn.__name__}/wall_s", f"{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
