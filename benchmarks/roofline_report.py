"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir DIR]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    return f"{x:.2e}" if isinstance(x, (int, float)) else "-"


def fmt_gb(x):
    return f"{x / 1e9:.1f}" if isinstance(x, (int, float)) else "-"


def load(dirname):
    recs = []
    for fp in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fp) as f:
            recs.append(json.load(f))
    return recs


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def sort_key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"])
            if r["shape"] in SHAPE_ORDER else 9, r["mesh"])


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile s | live GB/chip | "
            "args GB | temp GB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=sort_key):
        mem = r.get("memory", {}) or {}
        status = r.get("status", "?")
        short = "ok" if status == "ok" else (
            "skip" if status.startswith("skipped") else "FAIL")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {short} | "
            f"{r.get('compile_s', '-')} | {fmt_gb(mem.get('live_bytes'))} | "
            f"{fmt_gb(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_gb(mem.get('temp_size_in_bytes'))} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPs/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=sort_key):
        if r.get("mesh") != "single":
            continue
        if r.get("status", "").startswith("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                        f"skipped (full attention) | - | - |")
            continue
        if "dominant" not in r:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def pick_hillclimb(recs):
    """worst roofline fraction, most collective-bound, most representative."""
    ok = [r for r in recs if r.get("mesh") == "single" and "dominant" in r]
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["collective_s"] /
               max(r["compute_s"] + r["memory_s"] + r["collective_s"], 1e-30))
    return worst, coll


def compare_table(base_recs, opt_recs):
    base = {(r["arch"], r["shape"]): r for r in base_recs
            if r.get("mesh") == "single" and "dominant" in r}
    rows = ["| arch | shape | coll s (base→opt) | mem s (base→opt) | "
            "dominant (opt) | speedup of dominant |",
            "|---|---|---|---|---|---|"]
    for r in sorted(opt_recs, key=sort_key):
        if r.get("mesh") != "single" or "dominant" not in r:
            continue
        b = base.get((r["arch"], r["shape"]))
        if not b:
            continue
        dom = r["dominant"]
        key = {"compute": "compute_s", "memory": "memory_s",
               "collective": "collective_s"}[b["dominant"]]
        sp = b[key] / max(r[key], 1e-30)
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_s(b['collective_s'])}→{fmt_s(r['collective_s'])} | "
            f"{fmt_s(b['memory_s'])}→{fmt_s(r['memory_s'])} | {dom} | "
            f"{sp:.1f}x |")
    return "\n".join(rows)


def sweep_intensity_rows(T=17280, K=64, Pk=50, P=40, D=216, W=400,
                         kb=None):
    """Arithmetic intensity (flop/byte) of one POBP inner iteration per
    formulation — analytic flop and HBM-byte counts at the given shape
    (defaults: the BENCH_inner_loop K64_Pk50 cell).

    The point of the table: the carry-resident ``power_sweep_carry``
    megakernel touches HBM exactly twice per iteration for the [T, K]
    carry (one read, one write — everything else is VMEM-resident), so
    its intensity is ~3x the jnp dense-layout formulation and ~4x the
    dense sweep, i.e. the selective update leaves the memory-bound regime
    the dense baseline lives in.  The K-blocked two-pass variant
    (DESIGN.md §13) pays one extra carry pass (pass 2 recomputes u from
    a fresh read) plus per-(token-tile, K-block) table refetches — the
    price of fitting ultra-high K in VMEM at all.

    Byte counts split into phi-storage-proportional terms (the topic-word
    tables/streams) and everything else, so the compressed-accumulator
    column (``LDAConfig.phi_acc_dtype='bfloat16'``, itemsize 2) can be
    derived from the same model.  Returns
    [(name, flops, bytes_f32_phi, bytes_bf16_phi, flop/byte@f32)].
    """
    P1, f = P + 1, 4  # guard row; f32 bytes
    rows = []         # (name, flops, other_bytes_f32, phi_elems)

    # dense sweep (Eq. 4/5 baseline): full [T, K] update + theta einsum +
    # two [T, K] -> [W, K] scatters (phi rebuild, residual matrix)
    flops = 12 * T * K
    rows.append(("dense sweep", flops, f * 6 * T * K, 2 * W * K))

    # packed formulation: [T, Pk] streams + Pk-term fold-back chain
    # (2 of the 6 token streams and the packed delta are phi reads/writes)
    flops = 10 * T * Pk + 2 * T * K * Pk + 2 * T * K
    rows.append(("selective packed (jnp)", flops,
                 f * (3 * T * K + 4 * T * Pk), 2 * T * Pk))

    # dense-layout formulation: masked one-pass [T, K] update, complex-
    # merged delta/residual scatter, signed-phi row table
    flops = 12 * T * K
    rows.append(("selective dense-layout (jnp)", flops,
                 f * 7 * T * K, 2 * P1 * K))

    # carry-resident megakernel: one HBM read + one write of the carry;
    # gathers/accumulations are MXU one-hots on VMEM-resident tables
    flops = 12 * T * K + 2 * T * (P1 + D) * K   # update + one-hot MACs
    rows.append(("power_sweep_carry megakernel", flops,
                 f * (2 * T * K + T * 2 + 2 * D * K), 2 * P1 * K))

    # K-blocked two-pass megakernel: pass 1 reads the carry once, pass 2
    # reads it again (u is recomputed) and writes it; the [TT, KB] tiling
    # refetches the phi/mask tables once per (token-tile, K-block) grid
    # step and the theta/accumulator tables likewise — K/kb blocks wide,
    # T/TT tiles tall, each block a KB-wide slice.
    try:
        from repro.kernels.power_sweep.kernel import (carry_token_tile,
                                                      kblock_width)
        if kb is None:
            kb = kblock_width(K, P1, D) if K % 128 == 0 else min(K, 128)
        tt = carry_token_tile(kb, P1, D)
    except Exception:                     # standalone render, no repro
        kb, tt = kb or 128, 256
    n_tiles = -(-T // tt)
    flops = 14 * T * K + 2 * T * (P1 + D) * K   # + pass-2 u recompute
    refetch_phi = 2 * P1 * K * n_tiles          # phi+mask, per tile row
    refetch_other = f * (2 * D * K * n_tiles + 4 * T)   # theta + mass/denom
    rows.append((f"power_sweep_carry kblocked (kb={kb}, tt={tt})", flops,
                 f * (3 * T * K + T * 2) + refetch_other, refetch_phi))

    return [(n, fl, other + f * phi, other + 2 * phi,
             fl / (other + f * phi))
            for n, fl, other, phi in rows]


def sweep_intensity_table(T=17280, K=64, Pk=50, P=40, D=216, W=400,
                          kb=None):
    rows = ["| formulation | MFLOP/iter | HBM MB/iter | MB/iter "
            "(bf16 phi) | flop/byte |",
            "|---|---|---|---|---|"]
    for name, fl, b, b16, ai in sweep_intensity_rows(T, K, Pk, P, D, W, kb):
        rows.append(f"| {name} | {fl / 1e6:.1f} | {b / 1e6:.1f} | "
                    f"{b16 / 1e6:.1f} | {ai:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(os.path.dirname(__file__),
                                                  "results", "dryrun"))
    ap.add_argument("--baseline", default=None,
                    help="second dir: render a baseline-vs-optimized diff")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.baseline:
        print("## Perf: baseline vs optimized (single-pod)\n")
        print(compare_table(load(args.baseline), recs))
        print()
    print("## Dry-run (memory fit, both meshes)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod, per device per step)\n")
    print(roofline_table(recs))
    if any("dominant" in r for r in recs):
        worst, coll = pick_hillclimb(recs)
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.4f})")
        print(f"most collective-bound:  {coll['arch']}/{coll['shape']} "
              f"(coll {fmt_s(coll['collective_s'])} vs comp "
              f"{fmt_s(coll['compute_s'])})")
    print("\n## POBP selective-sweep arithmetic intensity "
          "(K64_Pk50 cell, per inner iteration)\n")
    print(sweep_intensity_table())
    print("\n## Ultra-high-K cell (K1024_Pk16, 48-doc subset — "
          "DESIGN.md §13)\n")
    print(sweep_intensity_table(T=7680, K=1024, Pk=16, P=40, D=48, W=400))


if __name__ == "__main__":
    main()
