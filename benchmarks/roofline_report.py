"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir DIR]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    return f"{x:.2e}" if isinstance(x, (int, float)) else "-"


def fmt_gb(x):
    return f"{x / 1e9:.1f}" if isinstance(x, (int, float)) else "-"


def load(dirname):
    recs = []
    for fp in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fp) as f:
            recs.append(json.load(f))
    return recs


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def sort_key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"])
            if r["shape"] in SHAPE_ORDER else 9, r["mesh"])


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile s | live GB/chip | "
            "args GB | temp GB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=sort_key):
        mem = r.get("memory", {}) or {}
        status = r.get("status", "?")
        short = "ok" if status == "ok" else (
            "skip" if status.startswith("skipped") else "FAIL")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {short} | "
            f"{r.get('compile_s', '-')} | {fmt_gb(mem.get('live_bytes'))} | "
            f"{fmt_gb(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_gb(mem.get('temp_size_in_bytes'))} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPs/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=sort_key):
        if r.get("mesh") != "single":
            continue
        if r.get("status", "").startswith("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                        f"skipped (full attention) | - | - |")
            continue
        if "dominant" not in r:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def pick_hillclimb(recs):
    """worst roofline fraction, most collective-bound, most representative."""
    ok = [r for r in recs if r.get("mesh") == "single" and "dominant" in r]
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["collective_s"] /
               max(r["compute_s"] + r["memory_s"] + r["collective_s"], 1e-30))
    return worst, coll


def compare_table(base_recs, opt_recs):
    base = {(r["arch"], r["shape"]): r for r in base_recs
            if r.get("mesh") == "single" and "dominant" in r}
    rows = ["| arch | shape | coll s (base→opt) | mem s (base→opt) | "
            "dominant (opt) | speedup of dominant |",
            "|---|---|---|---|---|---|"]
    for r in sorted(opt_recs, key=sort_key):
        if r.get("mesh") != "single" or "dominant" not in r:
            continue
        b = base.get((r["arch"], r["shape"]))
        if not b:
            continue
        dom = r["dominant"]
        key = {"compute": "compute_s", "memory": "memory_s",
               "collective": "collective_s"}[b["dominant"]]
        sp = b[key] / max(r[key], 1e-30)
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_s(b['collective_s'])}→{fmt_s(r['collective_s'])} | "
            f"{fmt_s(b['memory_s'])}→{fmt_s(r['memory_s'])} | {dom} | "
            f"{sp:.1f}x |")
    return "\n".join(rows)


def sweep_intensity_rows(T=17280, K=64, Pk=50, P=40, D=216, W=400):
    """Arithmetic intensity (flop/byte) of one POBP inner iteration per
    formulation — analytic flop and HBM-byte counts at the given shape
    (defaults: the BENCH_inner_loop K64_Pk50 cell).

    The point of the table: the carry-resident ``power_sweep_carry``
    megakernel touches HBM exactly twice per iteration for the [T, K]
    carry (one read, one write — everything else is VMEM-resident), so
    its intensity is ~3x the jnp dense-layout formulation and ~4x the
    dense sweep, i.e. the selective update leaves the memory-bound regime
    the dense baseline lives in.  Returns [(name, flops, bytes, flop/byte)].
    """
    P1, f = P + 1, 4  # guard row; f32 bytes
    rows = []

    # dense sweep (Eq. 4/5 baseline): full [T, K] update + theta einsum +
    # two [T, K] -> [W, K] scatters (phi rebuild, residual matrix)
    flops = 12 * T * K
    bts = f * (6 * T * K + 2 * W * K)
    rows.append(("dense sweep", flops, bts))

    # packed formulation: [T, Pk] streams + Pk-term fold-back chain
    flops = 10 * T * Pk + 2 * T * K * Pk + 2 * T * K
    bts = f * (3 * T * K + 6 * T * Pk)
    rows.append(("selective packed (jnp)", flops, bts))

    # dense-layout formulation: masked one-pass [T, K] update, complex-
    # merged delta/residual scatter
    flops = 12 * T * K
    bts = f * (7 * T * K + 2 * P1 * K)
    rows.append(("selective dense-layout (jnp)", flops, bts))

    # carry-resident megakernel: one HBM read + one write of the carry;
    # gathers/accumulations are MXU one-hots on VMEM-resident tables
    flops = 12 * T * K + 2 * T * (P1 + D) * K   # update + one-hot MACs
    bts = f * (2 * T * K + T * 2 + (2 * P1 + 2 * D) * K)
    rows.append(("power_sweep_carry megakernel", flops, bts))
    return [(n, fl, b, fl / b) for n, fl, b in rows]


def sweep_intensity_table(T=17280, K=64, Pk=50, P=40, D=216, W=400):
    rows = ["| formulation | MFLOP/iter | HBM MB/iter | flop/byte |",
            "|---|---|---|---|"]
    for name, fl, b, ai in sweep_intensity_rows(T, K, Pk, P, D, W):
        rows.append(f"| {name} | {fl / 1e6:.1f} | {b / 1e6:.1f} | "
                    f"{ai:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(os.path.dirname(__file__),
                                                  "results", "dryrun"))
    ap.add_argument("--baseline", default=None,
                    help="second dir: render a baseline-vs-optimized diff")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.baseline:
        print("## Perf: baseline vs optimized (single-pod)\n")
        print(compare_table(load(args.baseline), recs))
        print()
    print("## Dry-run (memory fit, both meshes)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod, per device per step)\n")
    print(roofline_table(recs))
    if any("dominant" in r for r in recs):
        worst, coll = pick_hillclimb(recs)
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.4f})")
        print(f"most collective-bound:  {coll['arch']}/{coll['shape']} "
              f"(coll {fmt_s(coll['collective_s'])} vs comp "
              f"{fmt_s(coll['compute_s'])})")
    print("\n## POBP selective-sweep arithmetic intensity "
          "(K64_Pk50 cell, per inner iteration)\n")
    print(sweep_intensity_table())


if __name__ == "__main__":
    main()
