"""Residual bookkeeping (Eqs. 7-10) and token->matrix scatters.

Residuals drive both convergence detection (Fig. 5: the mean residual
tracks predictive perplexity) and the dynamic power selection (Fig. 3).
"""

from __future__ import annotations

import jax.numpy as jnp


def token_scatter_wk(word_ids: jnp.ndarray, values_dlk: jnp.ndarray,
                     vocab_size: int) -> jnp.ndarray:
    """Scatter per-token [D, L, K] values into a [W, K] matrix by word id.

    Used for Delta-phi (Eq. 3 contribution) and the residual matrix (Eq. 8).
    Padding tokens carry zero values, so word id 0 padding is harmless.
    """
    K = values_dlk.shape[-1]
    flat_w = word_ids.reshape(-1)
    flat_v = values_dlk.reshape(-1, K)
    return jnp.zeros((vocab_size, K), flat_v.dtype).at[flat_w].add(flat_v)


def mean_residual(r_w: jnp.ndarray, total_tokens: jnp.ndarray) -> jnp.ndarray:
    """Line 26 of Fig. 4: sum_w r_w / sum_{w,d} x_{w,d}."""
    return jnp.sum(r_w) / jnp.maximum(total_tokens, 1.0)
