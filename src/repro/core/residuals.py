"""Residual bookkeeping (Eqs. 7-10) and token->matrix scatters.

Residuals drive both convergence detection (Fig. 5: the mean residual
tracks predictive perplexity) and the dynamic power selection (Fig. 3).
"""

from __future__ import annotations

import jax.numpy as jnp


def token_scatter_wk(word_ids: jnp.ndarray, values_dlk: jnp.ndarray,
                     vocab_size: int) -> jnp.ndarray:
    """Scatter per-token [D, L, K] values into a [W, K] matrix by word id.

    Used for Delta-phi (Eq. 3 contribution) and the residual matrix (Eq. 8).
    Padding tokens carry zero values, so word id 0 padding is harmless.
    """
    K = values_dlk.shape[-1]
    flat_w = word_ids.reshape(-1)
    flat_v = values_dlk.reshape(-1, K)
    return jnp.zeros((vocab_size, K), flat_v.dtype).at[flat_w].add(flat_v)


def token_topic_segment_sum(doc_ids: jnp.ndarray, k_tok: jnp.ndarray,
                            vals: jnp.ndarray, num_docs: int,
                            num_topics: int) -> jnp.ndarray:
    """Segment-sum [T, Pk] per-token values into [D, K] at (doc, topic).

    The O(T*Pk) theta refresh of the selective sweep: each token scatters
    its Pk selected-coordinate deltas straight to its document's row —
    never materializing a [T, K] or [D, L, K] intermediate.  This is what
    the carry-resident power_sweep kernel does on the MXU; on CPU XLA the
    element scatter serializes, so the jnp formulations reach theta
    through contractions instead (DESIGN.md §2 cost table) and this
    helper serves as the layout-free oracle for both.
    """
    flat = (doc_ids[:, None] * num_topics + k_tok).reshape(-1)
    out = jnp.zeros((num_docs * num_topics,), vals.dtype).at[flat].add(
        vals.reshape(-1))
    return out.reshape(num_docs, num_topics)


def mean_residual(r_w: jnp.ndarray, total_tokens: jnp.ndarray) -> jnp.ndarray:
    """Line 26 of Fig. 4: sum_w r_w / sum_{w,d} x_{w,d}."""
    return jnp.sum(r_w) / jnp.maximum(total_tokens, 1.0)


def packed_rw_delta(r_glob_wk: jnp.ndarray, sel_w: jnp.ndarray,
                    sel_k: jnp.ndarray, r_pack_new: jnp.ndarray) -> jnp.ndarray:
    """Per-power-word change of the word residual under a packed refresh.

    The selective iteration only rewrites r at the [P, Pk] power coordinates
    (Eq. 9), so the [W] word-residual vector moves by exactly

        delta[p] = sum_j r_pack_new[p, j] - r_glob[sel_w[p], sel_k[p, j]]

    — an O(P*Pk) update of the convergence signal instead of the seed's
    O(W*K) row reduction per iteration (DESIGN.md §2 packed-carry
    invariant).  Call BEFORE scattering r_pack_new into r_glob.
    Returns delta [P]; the caller adds it at rows sel_w (after the model
    psum when the topic axis is sharded).
    """
    rows = jnp.take(r_glob_wk, sel_w, axis=0)
    old = jnp.take_along_axis(rows, sel_k, axis=1)
    return jnp.sum(r_pack_new - old, axis=1)
