"""Stream-lifecycle state transitions: grow / decay / compact / recycle
(DESIGN.md §14).

The paper's constant-memory claim (§3.2: OBP keeps sufficient statistics,
never the corpus) only survives an *unbounded drifting* stream if the
statistics can also forget.  This module owns every transition of the
phi-accumulator state machine that is not the per-batch Eq. 11 update:

  - ``resize_state``   — capacity-ladder resize: grow pads guard rows
    (trajectory-neutral, the old ``core.pobp.grow_state``); shrink cuts
    guard rows only and is **checkpoint-fenced** — the caller proves the
    fence by passing the live vocabulary size.
  - ``apply_row_remap`` — permute phi rows by a VocabMap compaction remap
    (survivors move to a dense prefix, dead rows zero out), the device
    half of ``data.vocab.VocabMap.compact``.
  - ``dead_rows``       — the two-signal dead-word test: a row must be
    idle (last touched >= ``min_idle`` batches ago) AND its decayed
    statistic must have faded below a prior-level mass floor.  Both
    signals are deterministic functions of the consumed batch prefix, so
    the same stream with the same fence steps always reclaims the same
    rows (hypothesis-pinned in tests/test_lifecycle_properties.py).
  - ``dead_topics`` / ``recycle_topics`` — detect topic columns whose
    live mass has decayed to noise and reseed them from high-residual
    tokens (rows whose mass is least explained by their dominant topic),
    so capacity lost to a faded theme is reallocated to emerging ones.

Every *destructive* transition (shrink, remap, recycle) runs only at a
checkpoint fence: the driver drains the async pipeline, applies the
transition, and immediately persists the new state + vocab + remap, so a
crash on either side of the fence resumes onto a consistent (phi, vocab)
pair (see ``dist.checkpoint`` row-remap restore).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.types import LDATrainState


# --------------------------------------------------------------------------
# capacity resize (grow = old grow_state; shrink = fenced compaction)
# --------------------------------------------------------------------------

def resize_state(state: LDATrainState, new_vocab_cap: int,
                 live_w: Optional[int] = None) -> LDATrainState:
    """Pure-functional W-capacity resize of the training carry.

    **Grow** (``new_vocab_cap > W``): pad zero guard rows — no live word
    maps to them yet, so growing is trajectory-neutral (DESIGN.md §12).

    **Shrink** (``new_vocab_cap < W``): only guard rows may be cut, so
    the caller must pass ``live_w`` — the live vocabulary size at the
    checkpoint fence this shrink runs under — and the new capacity must
    still be a valid rung (strictly above ``live_w``, preserving the
    guard-row invariant).  Shrinking without a fence is refused: cutting
    rows out from under an async pipeline would tear in-flight batches.

    m and the RNG are untouched; the caller re-derives its step function
    for the new capacity (one compile per (rung, bucket) pair).
    """
    W, K = state.phi_acc.shape
    if new_vocab_cap == W:
        return state
    if new_vocab_cap > W:
        phi = jnp.concatenate(
            [state.phi_acc,
             jnp.zeros((new_vocab_cap - W, K), state.phi_acc.dtype)], axis=0)
        return LDATrainState(phi_acc=phi, m=state.m, rng=state.rng)
    if live_w is None:
        raise ValueError(
            f"cannot shrink phi capacity {W} -> {new_vocab_cap} without a "
            f"fence: pass live_w (shrink is checkpoint-fenced — only guard "
            f"rows above the live vocabulary may be cut; DESIGN.md §14)")
    if new_vocab_cap <= live_w:
        raise ValueError(
            f"cannot shrink phi capacity {W} -> {new_vocab_cap} with "
            f"live_w={live_w}: the new rung must stay strictly above the "
            f"live vocabulary (guard-row invariant, DESIGN.md §12)")
    return LDATrainState(phi_acc=state.phi_acc[:new_vocab_cap],
                         m=state.m, rng=state.rng)


def apply_row_remap(state: LDATrainState, remap) -> LDATrainState:
    """Permute phi rows by a compaction remap (``VocabMap.compact``).

    ``remap[i]`` is row i's new row, or -1 for a reclaimed (dead) row;
    surviving rows land at ``phi_new[remap[i]] = phi[i]`` and every other
    row — reclaimed rows and the tail the survivors vacated — is zeroed
    (they are guard rows again, free for OOV reuse).  Capacity is
    unchanged; pair with ``resize_state`` to also drop a rung.
    """
    remap = jnp.asarray(remap, jnp.int32)
    W, _ = state.phi_acc.shape
    if remap.shape[0] > W:
        raise ValueError(f"remap covers {remap.shape[0]} rows but phi has "
                         f"only {W}")
    src = state.phi_acc[:remap.shape[0]]
    # dead rows (-1) route to the out-of-range index W and are dropped
    dst = jnp.where(remap >= 0, remap, W)
    phi = jnp.zeros_like(state.phi_acc).at[dst].set(src, mode="drop")
    return LDATrainState(phi_acc=phi, m=state.m, rng=state.rng)


# --------------------------------------------------------------------------
# dead-row detection (host-side: runs at a fence, after a device sync)
# --------------------------------------------------------------------------

def dead_rows(row_mass, last_touched, step: int, min_idle: int,
              mass_floor: float) -> np.ndarray:
    """bool[live] mask of reclaimable rows at fence ``step``.

    A row is dead only when BOTH signals agree: it has not been touched
    by any consumed batch for ``min_idle`` batches (so it is not merely
    resting between two occurrences), AND its accumulated statistic has
    decayed to ``mass_floor`` or below — i.e. the row is statistically
    indistinguishable from the beta prior (``mass_floor`` is expressed in
    absolute statistic units; callers scale it from K*beta).  Without
    decay an idle row keeps its historical mass forever and the second
    signal (correctly) never fires.
    """
    idle = (step - np.asarray(last_touched)) >= int(min_idle)
    return idle & (np.asarray(row_mass) <= float(mass_floor))


# --------------------------------------------------------------------------
# topic recycling
# --------------------------------------------------------------------------

def dead_topics(phi: np.ndarray, live_w: int, tol: float) -> np.ndarray:
    """Topic columns whose live mass fell below ``tol`` x the mean topic
    mass — themes the decayed stream no longer supports."""
    mass_k = np.asarray(phi[:live_w], np.float64).sum(axis=0)
    return np.nonzero(mass_k <= float(tol) * max(mass_k.mean(), 1e-30))[0]


def recycle_topics(phi: np.ndarray, live_w: int, tol: float,
                   seed_frac: float = 0.1,
                   ) -> Tuple[np.ndarray, List[int]]:
    """Reseed dead topic columns from high-residual tokens.

    A dead topic (``dead_topics``) is re-pointed at the tokens the model
    currently explains worst: per live row, the *residual mass*
    ``row_mass - max_k phi[w, k]`` — mass spread thinly across topics
    with no dominant owner — ranks emerging words no existing topic has
    claimed.  Each dead column is seeded with ``seed_frac`` of the top
    rows' residual mass (deterministic: pure argsort, ties broken by row
    order), giving the next sweeps a non-degenerate starting point that
    the data immediately reshapes.  Returns (new_phi, recycled_topics);
    phi is returned unchanged (same object) when nothing is dead.
    """
    dead = dead_topics(phi, live_w, tol)
    if dead.size == 0:
        return phi, []
    live = np.asarray(phi[:live_w], np.float32)
    row_mass = live.sum(axis=1)
    residual = row_mass - live.max(axis=1)
    n_seed = max(8, live_w // 20)
    top = np.argsort(-residual, kind="stable")[:n_seed]
    out = np.array(phi, np.float32, copy=True)
    for k in dead:
        out[top, k] = seed_frac * residual[top]
    return out, [int(k) for k in dead]


__all__ = ["resize_state", "apply_row_remap", "dead_rows", "dead_topics",
           "recycle_topics"]
