"""Adaptive sweep dispatch — the measured cost model behind
``LDAConfig.sweep_policy`` (DESIGN.md §2).

The selective iteration (Fig. 4 lines 15-21) has two algebraically
identical formulations whose relative cost flips with the shape:

  - **packed**: [T, Pk] token streams + a Pk-term fold-back chain into the
    [T, K] carry.  Work scales with T*K*Pk (the chain) — unbeatable when
    Pk << K, K-proportional pain when Pk approaches K (the K64_Pk50
    regression this module exists to fix).
  - **dense_layout**: the one-pass [T, K] masked formulation (the jnp
    mirror of the carry-resident ``power_sweep`` megakernel): a signed-phi
    row table makes u exactly zero off the power submatrix, so the update,
    fold-back and theta contraction are a handful of fused [T, K] passes —
    Pk-independent.

A third formulation exists only on the pallas side:

  - **kblocked**: the K-blocked two-pass carry megakernel (DESIGN.md
    §13) — same dense-layout math tiled as [TT, KB] topic blocks, for
    ultra-high K where the full-K carry no longer fits a useful token
    tile in VMEM.  On the jnp impl it is an alias of dense_layout (XLA
    has no VMEM constraint to respect).

Both produce the same packed [P, Pk] sync buffers, so the Eq. 6
communication (CommMeter bytes) is invariant to the choice — pinned by
tests/test_sweep_policy.py.

``resolve_sweep_policy`` picks the cheaper formulation per (T, K, Pk, P)
at trace time from a **measured** cost model: four per-element machine
rates (fused elementwise pass, compare-select chain term, row scatter-add,
row gather) are timed once per process on small probe shapes and plugged
into analytic element counts.  The pallas branch extends the model with a
VMEM-fit predicate (`kernels.power_sweep.kernel.carry_vmem_fits`): auto
resolves to the one-pass carry kernel while its footprint admits a >= 64
token tile within the budget (``LDAConfig.vmem_budget_bytes`` >
``REPRO_VMEM_BUDGET_BYTES`` > default), and to kblocked beyond that.
Resolution is cached per shape so dispatch is deterministic within a
process and never retraces across mini-batches (compile-count pinned).

Set ``REPRO_SWEEP_CALIBRATE=0`` to skip the ~100 ms measurement and use
the committed fallback coefficients (measured on a 2-core CPU container).
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SweepCoeffs:
    """Per-element machine rates, nanoseconds (see measure_coeffs)."""

    ew_ns: float        # fused elementwise pass, per element
    chain_ns: float     # one compare-select chain term, per element
    scatter_ns: float   # row-indexed scatter-add, per scattered element
    gather_ns: float    # per gathered element ([T, Pk]-style take_along)


# Fallback (and test-determinism) coefficients, measured in this repo's
# CPU container; real TPUs resolve through the pallas branch below, which
# never consults them.
DEFAULT_COEFFS = SweepCoeffs(ew_ns=0.55, chain_ns=0.30, scatter_ns=1.9,
                             gather_ns=1.3)

_MEASURED: Optional[SweepCoeffs] = None


def _time_jitted(fn, *args, reps: int = 5) -> float:
    """Best-of-reps wall seconds for one call of a jitted fn."""
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_coeffs() -> SweepCoeffs:
    """Time the four elementary access patterns on small probe shapes.

    One-time ~100 ms; cached for the process.  Probe shapes are big enough
    to swamp dispatch overhead (~1M elements) and small enough to stay
    cache-resident the way the real sweeps are not — the absolute rates
    matter less than their ratios, which is what the dispatch compares.
    """
    global _MEASURED
    if _MEASURED is not None:
        return _MEASURED
    if os.environ.get("REPRO_SWEEP_CALIBRATE", "1") == "0":
        _MEASURED = DEFAULT_COEFFS
        return _MEASURED
    import jax
    import jax.numpy as jnp

    T0, K0 = 16384, 64
    n = T0 * K0
    a = jnp.linspace(0.1, 1.0, n, dtype=jnp.float32).reshape(T0, K0)
    b = a[::-1]

    ew = jax.jit(lambda a, b: a * b + a - 0.5 * b)
    t_ew = _time_jitted(ew, a, b) / (n * 1)

    idx = (jnp.arange(T0, dtype=jnp.int32) * 7919) % 64
    kcol = ((jnp.arange(T0, dtype=jnp.int32) * 31) % K0)[:, None]
    CH = 8
    iota = jnp.arange(K0, dtype=jnp.int32)[None, :]

    def chain(a, kcol):
        d = jnp.zeros_like(a)
        for j in range(CH):
            d = d + jnp.where(iota == (kcol + j) % K0, 1.0, 0.0)
        return d

    t_chain = _time_jitted(jax.jit(chain), a, kcol) / (n * CH)

    scat = jax.jit(lambda a, idx: jnp.zeros((64, K0), jnp.float32)
                   .at[idx].add(a))
    t_scat = _time_jitted(scat, a, idx) / n

    gath = jax.jit(lambda a, kcol: jnp.take_along_axis(
        a, (kcol + iota[:, :8]) % K0, axis=1))
    t_gath = _time_jitted(gath, a, kcol) / (T0 * 8)

    _MEASURED = SweepCoeffs(ew_ns=t_ew * 1e9, chain_ns=t_chain * 1e9,
                            scatter_ns=t_scat * 1e9, gather_ns=t_gath * 1e9)
    return _MEASURED


def packed_cost(T: int, K: int, Pk: int, P: int, crossover: int,
                c: SweepCoeffs) -> float:
    """Analytic cost (ns) of one packed-formulation iteration.

    Element counts mirror core/pobp._selective_sweep_packed: ~4 gathered
    [T, Pk] streams, ~10 fused elementwise ops on them, the Pk-term
    fold-back chain over [T, K], the carry add + theta contraction
    (2 passes over [T, K]), and the [P, Pk] accumulation (one-hot MXU
    mirror below the crossover, row scatter above).
    """
    stream = T * Pk * (4 * c.gather_ns + 10 * c.ew_ns)
    chain = T * K * Pk * c.chain_ns
    fold = 2 * T * K * c.ew_ns
    if T * P <= crossover:
        accum = 2.0 * T * P * Pk * 0.5 * c.ew_ns     # MAC ~ half a fused op
    else:
        accum = 2 * T * Pk * c.scatter_ns
    return stream + chain + fold + accum


def dense_layout_cost(T: int, K: int, Pk: int, P: int,
                      c: SweepCoeffs) -> float:
    """Analytic cost (ns) of one dense-layout iteration.

    Mirrors core/pobp._selective_sweep_dense_layout: one [T, K] row gather
    of the signed-phi table, ~8 fused [T, K] update passes, the theta
    contraction, the complex-merged delta/residual row scatter (~1.2x a
    plain [T, K] scatter for the doubled payload width), and the O(P*K)
    table build (charged as scatter elements).
    """
    gather = T * K * 0.35 * c.gather_ns   # row gather: contiguous K runs
    update = 8 * T * K * c.ew_ns
    theta = T * K * c.ew_ns
    scatter = 1.2 * T * K * c.scatter_ns
    table = 2 * P * K * c.scatter_ns
    return gather + update + theta + scatter + table


def _pad_to(n: int, m: int) -> int:
    return -(-int(n) // m) * m


def carry_vmem_fit(K: int, P: int, n_docs: int,
                   vmem_budget_bytes=None) -> bool:
    """Dispatch-side VMEM-fit predicate for the one-pass carry kernel.

    Takes LOGICAL shapes (K topics, P power rows, n_docs documents) and
    applies the kernel's padding contract (K to 128 lanes, rows/docs to
    8 sublanes plus the guard row) before asking
    `kernels.power_sweep.kernel.carry_vmem_fits` whether the footprint
    admits a >= 64 token tile within the budget.
    """
    from repro.kernels.power_sweep.kernel import carry_vmem_fits
    return carry_vmem_fits(_pad_to(max(K, 1), 128),
                           _pad_to(int(P) + 1, 8),
                           _pad_to(max(n_docs, 1), 8),
                           vmem_budget_bytes)


@functools.lru_cache(maxsize=512)
def _resolve_cached(policy: str, T: int, K: int, Pk: int, P: int,
                    crossover: int, impl: str, n_docs: int,
                    budget: int) -> str:
    if policy == "kblocked" and impl != "pallas":
        # XLA has no VMEM budget: the jnp mirror of kblocked IS the
        # dense-layout formulation (same math, same sync bytes)
        return "dense_layout"
    if policy != "auto":
        return policy
    if impl == "pallas":
        # the carry-resident megakernel IS the dense-layout formulation:
        # one HBM read + one write of the [T, K] carry per iteration, all
        # one-hot work on the MXU (kernels/power_sweep).  When the full-K
        # carry footprint stops admitting a useful token tile, the
        # K-blocked two-pass variant takes over (DESIGN.md §13).  The
        # packed kernel path remains reachable via sweep_policy='packed'.
        if carry_vmem_fit(K, P, n_docs, budget):
            return "dense_layout"
        return "kblocked"
    c = measure_coeffs()
    cp = packed_cost(T, K, Pk, P, crossover, c)
    cd = dense_layout_cost(T, K, Pk, P, c)
    return "packed" if cp <= cd else "dense_layout"


def resolve_sweep_policy(cfg, T: int, K: int, Pk: int, P: int,
                         impl: Optional[str] = None,
                         n_docs: Optional[int] = None) -> str:
    """Resolve cfg.sweep_policy to a concrete formulation for this shape.

    Called at trace time (all arguments are static Python ints), cached
    per shape: the same (cfg, shape) always dispatches identically within
    a process, so bucketed streams never retrace on policy flapping.
    ``n_docs`` feeds the pallas VMEM-fit predicate (the theta table is
    grid-resident); callers that don't know it get a conservative
    default that only matters near the budget boundary.
    """
    policy = cfg.sweep_policy
    if policy not in ("auto", "packed", "dense_layout", "kblocked"):
        raise ValueError(f"unknown sweep_policy: {policy!r} (expected "
                         f"auto | packed | dense_layout | kblocked)")
    from repro.kernels.power_sweep.kernel import vmem_budget
    budget = vmem_budget(getattr(cfg, "vmem_budget_bytes", None))
    return _resolve_cached(policy, int(T), int(K), int(Pk), int(P),
                           int(cfg.onehot_crossover),
                           cfg.impl if impl is None else impl,
                           int(n_docs) if n_docs is not None else 256,
                           budget)
