"""Variational Bayes for LDA (Blei et al. 2003) — the paper's PVB comparator.

Mean-field coordinate ascent on the **token-major runtime** (DESIGN.md
§2): the padded-CSR batch flattens to the TokenLayout once, the per-token
variational posterior (resp) is carried as a flat [T, K] stream with
exp(digamma) weights gathered per token, and every per-doc reduction is a
counts contraction — the same engineering the POBP inner loop runs on, so
the accuracy benchmarks compare algorithms, not layouts (ROADMAP "GS/VB
on the token-major runtime"; gibbs stays seed-style).

  E-step: gamma_d via exp(digamma) responsibilities over [T, K];
  M-step: lambda = beta + sum_t c_t * resp_t (token scatter).

The parallel variant syncs the dense lambda matrix each iteration (the
pattern that gives PVB the worst communication bill in Fig. 10 — float
payload, full matrix, every iteration).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma

from repro.core.types import LDAConfig, MiniBatch, TokenLayout


def _e_step_tokens(layout: TokenLayout, counts2: jnp.ndarray,
                   elog_phi_tok: jnp.ndarray, cfg: LDAConfig,
                   inner: int = 8):
    """Per-document gamma updates with phi weights fixed, token-major.

    ``elog_phi_tok`` [T, K] is the per-token exp-digamma weight, gathered
    once per sweep (phi is fixed across the inner gamma iterations).
    Returns (gamma [D, K], resp [T, K]).
    """
    D, L = layout.num_docs, layout.max_len
    K = elog_phi_tok.shape[-1]
    total = jnp.sum(layout.counts)
    gamma = jnp.full((D, K), cfg.alpha + total / (D * K))

    def body(gamma, _):
        elog_theta = digamma(gamma) - digamma(
            jnp.sum(gamma, -1, keepdims=True))                  # [D, K]
        logr = (jnp.broadcast_to(elog_theta[:, None, :], (D, L, K))
                .reshape(layout.num_slots, K) + elog_phi_tok)   # [T, K]
        logr = logr - jax.scipy.special.logsumexp(logr, -1, keepdims=True)
        resp = jnp.exp(logr)
        gamma = cfg.alpha + jnp.einsum(
            "dl,dlk->dk", counts2, resp.reshape(D, L, K))
        return gamma, resp

    gamma, resps = jax.lax.scan(body, gamma, None, length=inner)
    return gamma, resps[-1]


def vb_sweep(batch: MiniBatch, lam_wk: jnp.ndarray, cfg: LDAConfig):
    """One batch-VB iteration: E-step then the lambda statistic (M-step input).

    Token-major: the E-step runs on the flat [T, K] resp stream and the
    statistic scatters straight from it (one [T] -> [W] row scatter, the
    same op class as `residuals.token_scatter_wk`).
    """
    layout = batch.token_layout()
    counts2 = layout.counts.reshape(layout.num_docs, layout.max_len)
    elog_phi = digamma(lam_wk) - digamma(jnp.sum(lam_wk, axis=0, keepdims=True))
    elog_phi_tok = jnp.take(elog_phi, layout.word_ids, axis=0)   # [T, K], once
    gamma, resp = _e_step_tokens(layout, counts2, elog_phi_tok, cfg)
    stat = jnp.zeros_like(lam_wk).at[layout.word_ids].add(
        layout.counts * resp)
    return gamma, stat


def run_vb(key: jax.Array, batch: MiniBatch, cfg: LDAConfig, iters: int):
    """Batch VB.  Returns (phi_hat[W, K] = lambda - beta, gamma[D, K])."""
    lam = cfg.beta + jax.random.uniform(
        key, (cfg.vocab_size, cfg.num_topics), minval=0.5, maxval=1.5)
    sweep = jax.jit(lambda l: vb_sweep(batch, l, cfg))
    gamma = None
    for _ in range(iters):
        gamma, stat = sweep(lam)
        lam = cfg.beta + stat
    return lam - cfg.beta, gamma


def run_parallel_vb(key: jax.Array, batches, cfg: LDAConfig, iters: int):
    """PVB: per-shard E-steps, dense lambda sync each iteration.

    Returns (phi_hat, comm_bytes) — comm is the full float matrix per shard
    per iteration (cf. Fig. 10's worst case).
    """
    lam = cfg.beta + jax.random.uniform(
        key, (cfg.vocab_size, cfg.num_topics), minval=0.5, maxval=1.5)
    sweeps = [jax.jit(lambda l, b=b: vb_sweep(b, l, cfg)) for b in batches]
    comm_bytes = 0
    for _ in range(iters):
        stat = jnp.zeros_like(lam)
        for sw in sweeps:
            _, s = sw(lam)
            stat = stat + s
        lam = cfg.beta + stat
        comm_bytes += int(lam.size) * 4 * len(batches)
    return lam - cfg.beta, None
