"""Variational Bayes for LDA (Blei et al. 2003) — the paper's PVB comparator.

Mean-field coordinate ascent, vectorized over the padded-CSR batch:
  E-step: gamma_d, per-token variational posterior via exp(digamma) weights;
  M-step: lambda = beta + sum_d x * resp.
The parallel variant syncs the dense lambda matrix each iteration (the
pattern that gives PVB the worst communication bill in Fig. 10 — float
payload, full matrix, every iteration).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma

from repro.core.types import LDAConfig, MiniBatch


def _e_step(batch: MiniBatch, elog_phi_tok: jnp.ndarray, cfg: LDAConfig,
            inner: int = 8):
    """Per-document gamma updates with phi weights fixed.  Returns (gamma, resp)."""
    D, L = batch.word_ids.shape
    K = elog_phi_tok.shape[-1]
    gamma = jnp.full((D, K), cfg.alpha + batch.num_tokens() / (batch.num_docs * K))

    def body(gamma, _):
        elog_theta = digamma(gamma) - digamma(jnp.sum(gamma, -1, keepdims=True))
        logr = elog_theta[:, None, :] + elog_phi_tok               # [D, L, K]
        logr = logr - jax.scipy.special.logsumexp(logr, -1, keepdims=True)
        resp = jnp.exp(logr)
        gamma = cfg.alpha + jnp.einsum("dl,dlk->dk", batch.counts, resp)
        return gamma, resp

    gamma, resps = jax.lax.scan(body, gamma, None, length=inner)
    return gamma, resps[-1]


def vb_sweep(batch: MiniBatch, lam_wk: jnp.ndarray, cfg: LDAConfig):
    """One batch-VB iteration: E-step then the lambda statistic (M-step input)."""
    elog_phi = digamma(lam_wk) - digamma(jnp.sum(lam_wk, axis=0, keepdims=True))
    elog_phi_tok = jnp.take(elog_phi, batch.word_ids, axis=0)      # [D, L, K]
    gamma, resp = _e_step(batch, elog_phi_tok, cfg)
    stat = jnp.zeros_like(lam_wk).at[batch.word_ids.reshape(-1)].add(
        (batch.counts[..., None] * resp).reshape(-1, lam_wk.shape[1]))
    return gamma, stat


def run_vb(key: jax.Array, batch: MiniBatch, cfg: LDAConfig, iters: int):
    """Batch VB.  Returns (phi_hat[W, K] = lambda - beta, gamma[D, K])."""
    lam = cfg.beta + jax.random.uniform(
        key, (cfg.vocab_size, cfg.num_topics), minval=0.5, maxval=1.5)
    sweep = jax.jit(lambda l: vb_sweep(batch, l, cfg))
    gamma = None
    for _ in range(iters):
        gamma, stat = sweep(lam)
        lam = cfg.beta + stat
    return lam - cfg.beta, gamma


def run_parallel_vb(key: jax.Array, batches, cfg: LDAConfig, iters: int):
    """PVB: per-shard E-steps, dense lambda sync each iteration.

    Returns (phi_hat, comm_bytes) — comm is the full float matrix per shard
    per iteration (cf. Fig. 10's worst case).
    """
    lam = cfg.beta + jax.random.uniform(
        key, (cfg.vocab_size, cfg.num_topics), minval=0.5, maxval=1.5)
    sweeps = [jax.jit(lambda l, b=b: vb_sweep(b, l, cfg)) for b in batches]
    comm_bytes = 0
    for _ in range(iters):
        stat = jnp.zeros_like(lam)
        for sw in sweeps:
            _, s = sw(lam)
            stat = stat + s
        lam = cfg.beta + stat
        comm_bytes += int(lam.size) * 4 * len(batches)
    return lam - cfg.beta, None
