"""Synchronization layer: dense (Eq. 4 — the PGS/MPA baseline) and
power-selected sparse (Eq. 6 — the paper's contribution) all-reduces,
with trace-time byte accounting.

The `Reducer` abstraction lets the same POBP code run
  - under ``shard_map`` on a real mesh (``MeshReducer`` -> lax.psum), and
  - in single-device N-shard simulation (``SimReducer`` -> sum over a
    stacked axis), used by CPU tests and paper-figure benchmarks.

Byte accounting happens at *trace time*: payload shapes are static, so each
``psum`` registers its logical payload (size x itemsize) in a phase bucket.
Per-mini-batch totals are then ``dense_bytes + (iters-1) * sparse_bytes``
with `iters` known only at run time.  This reproduces Eqs. (5)/(6) exactly
and is cross-checked against HLO collective parsing in the roofline pass.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Sequence[str]]


@dataclasses.dataclass
class CommMeter:
    """Trace-time logical-byte counter, bucketed by phase label."""

    bytes_by_phase: Dict[str, int] = dataclasses.field(default_factory=dict)
    calls: List[str] = dataclasses.field(default_factory=list)

    def record(self, phase: str, arr: jnp.ndarray) -> None:
        nbytes = int(arr.size) * arr.dtype.itemsize
        self.bytes_by_phase[phase] = self.bytes_by_phase.get(phase, 0) + nbytes
        self.calls.append(f"{phase}:{arr.shape}:{arr.dtype}:{nbytes}")

    def phase_bytes(self, phase: str) -> int:
        return self.bytes_by_phase.get(phase, 0)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_phase.values())


class Reducer:
    """All-reduce provider; subclasses define where the sum happens."""

    def __init__(self, meter: Optional[CommMeter] = None, sync_dtype=jnp.float32):
        self.meter = meter or CommMeter()
        self.sync_dtype = sync_dtype

    def _sum(self, x: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def psum(self, x: jnp.ndarray, phase: str, compress: bool = True) -> jnp.ndarray:
        """All-reduce `x`; payload cast to sync_dtype when `compress`."""
        orig = x.dtype
        if compress and x.dtype != self.sync_dtype:
            x = x.astype(self.sync_dtype)
        self.meter.record(phase, x)
        out = self._sum(x)
        return out.astype(orig)


class MeshReducer(Reducer):
    """psum over named mesh axes — for shard_map'd POBP."""

    def __init__(self, axis_name: AxisName, **kw):
        super().__init__(**kw)
        self.axis_name = axis_name

    def _sum(self, x):
        return jax.lax.psum(x, self.axis_name)


class SimReducer(Reducer):
    """Per-shard values carry a leading N axis; 'all-reduce' = sum + broadcast.

    Used by the single-device simulation path (tests, CPU benchmarks); the
    byte meter still records exactly what one shard would send.
    """

    def _sum(self, x):
        return jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True), x.shape)


class LocalReducer(Reducer):
    """N=1 degenerate reducer (OBP on a single processor) — no communication,
    so nothing is recorded in the meter."""

    def psum(self, x, phase: str, compress: bool = True):
        return x

    def _sum(self, x):
        return x


def dense_sync_bytes(W: int, K: int, itemsize: int = 4) -> int:
    """Eq. (5) per-iteration payload of the MPA baseline: the full phi matrix."""
    return W * K * itemsize


def power_sync_bytes(P: int, Pk: int, W: int, itemsize: int = 4) -> int:
    """Eq. (6) per-iteration payload of POBP: packed phi + packed r + r_w vector."""
    return 2 * P * Pk * itemsize + W * 4
