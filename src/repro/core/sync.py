"""Synchronization layer: dense (Eq. 4 — the PGS/MPA baseline) and
power-selected sparse (Eq. 6 — the paper's contribution) all-reduces,
with trace-time byte accounting.

The `Reducer` abstraction lets the same POBP code run
  - under ``shard_map`` on a real mesh (``MeshReducer`` -> lax.psum), and
  - in single-device N-shard simulation (``SimReducer`` -> sum over a
    stacked axis), used by CPU tests and paper-figure benchmarks.

Byte accounting happens at *trace time*: payload shapes are static, so each
``psum`` registers its logical payload (size x itemsize) in a phase bucket.
Recording is **idempotent under retracing**: a reshape-triggered retrace of
the same program (e.g. a variable-length mini-batch stream hitting a new
padded shape) must not inflate the totals, so every record is attributed to
the trace it happens under and two traces whose record sequences are
identical count once (see ``CommMeter``).  Per-mini-batch totals are then
``dense_bytes + (iters-1) * sparse_bytes`` with `iters` known only at run
time (``CommMeter.per_minibatch_bytes``).  This reproduces Eqs. (5)/(6)
exactly and is cross-checked against HLO collective parsing in the
roofline pass.
"""

from __future__ import annotations

import weakref
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Sequence[str]]

# phases recorded once per *inner-loop iteration* (their psums live in
# trace-once while bodies — core/pobp.py names every in-body psum with a
# distinct loop phase); everything else is a once-per-mini-batch payload.
_BASE_LOOP_PHASES = ("power", "dense_loop", "model_rw_loop", "model_norm_loop")
# the parameter-server reducer splits every vocabulary-proportional wire
# payload into a ``.push`` and a ``.pull`` leg (see ``PSReducer``); the
# loop-phase set covers both so ``per_minibatch_bytes`` stays correct
# under either reducer.
LOOP_PHASES = _BASE_LOOP_PHASES + tuple(
    f"{p}{leg}" for p in _BASE_LOOP_PHASES for leg in (".push", ".pull"))


class CommMeter:
    """Trace-time logical-byte counter, bucketed by phase label.

    Each ``record`` is keyed to the jax trace it happens under: retracing —
    a new padded shape on a variable-length stream, a fresh ``vmap``
    application — creates new trace objects, so each traced program section
    yields its own ordered log of (phase, shape, dtype) records.  Logs then
    merge into per-phase totals as follows:

      - identical logs count ONCE (a plain retrace of the same section must
        not double-count — the bug this class replaces);
      - logs with the same *phase sequence* but different payload shapes
        are shape-bucket variants of one section (e.g. the L-dependent
        ``model_norm`` psum across length buckets): the per-phase MAX is
        taken — what the worst single mini-batch pays — never the sum;
      - distinct phase sequences are genuinely different program sections
        (dense body vs power loop, another sync mode) and add up.

    Records from eager (untraced) psums accumulate per call, since each one
    is a real execution.  Traces are held only by weakref, so the meter
    neither extends trace lifetimes nor trips jax's tracer-leak checker;
    a log whose trace id gets reused by a later trace is frozen first.
    """

    def __init__(self) -> None:
        self.calls: List[str] = []                 # every record ever (debug)
        self._archived: List[Tuple[Tuple, ...]] = []   # frozen trace logs
        # live trace id -> [weakref-to-trace (or the trace itself when it
        # rejects weakrefs), ordered (phase, shape, dtype, nbytes, w_rows)
        # records]
        self._live: Dict[int, list] = {}
        self._eager: List[Tuple] = []

    def record(self, phase: str, arr: jnp.ndarray,
               w_rows: Optional[int] = None) -> None:
        """Register one psum payload.

        ``w_rows`` marks a payload whose size is proportional to the
        vocabulary capacity: it is recorded at the full W_cap = ``w_rows``
        shape (what the compiled program allocates), but only the live
        fraction logically crosses the interconnect — guard rows are
        identically zero on every shard, so a deployment transmits
        ``live_w`` rows (DESIGN.md §12).  ``bytes_by_phase_at(live_w)``
        scales marked records by ``live_w / w_rows``.
        """
        nbytes = int(arr.size) * arr.dtype.itemsize
        sig = (phase, tuple(arr.shape), str(arr.dtype), nbytes,
               int(w_rows) if w_rows else 0)
        self.calls.append(f"{phase}:{tuple(arr.shape)}:{arr.dtype}:{nbytes}")
        trace = getattr(arr, "_trace", None)
        if trace is None:
            self._eager.append(sig)
            return
        tid = id(trace)
        entry = self._live.get(tid)
        if entry is not None:
            ref, log = entry
            cur = ref() if isinstance(ref, weakref.ref) else ref
            if cur is not trace:           # id reused by a newer trace
                self._archived.append(tuple(log))
                entry = None
        if entry is None:
            try:
                ref = weakref.ref(trace)
            except TypeError:
                ref = trace
            entry = [ref, []]
            self._live[tid] = entry
        entry[1].append(sig)

    def record_host(self, phase: str, nbytes: int,
                    w_rows: int = 0) -> None:
        """Register host-side wire traffic that never flows through a
        traced psum — parameter-server retry re-issues and
        crash-recovery replays (DESIGN.md §17).  Accumulates per call
        (eager path), under its own phase (``ps.retry.push``,
        ``ps.retry.pull``, ``ps.replay``) so clean-run Eq. 5/6 phases
        stay untouched and the overhead is separately auditable."""
        nbytes = int(nbytes)
        sig = (phase, (), "host", nbytes, int(w_rows))
        self.calls.append(f"{phase}:host:{nbytes}")
        self._eager.append(sig)

    def _logs(self) -> List[Tuple[Tuple, ...]]:
        return self._archived + [tuple(log) for _, log in self._live.values()]

    def _merged(self, live_w: Optional[int] = None) -> Dict[str, int]:
        # group deduplicated logs by phase sequence; max-merge within a
        # group (shape-bucket variants), sum across groups and eager records

        def scaled(nbytes: int, w_rows: int) -> int:
            if live_w is None or not w_rows:
                return nbytes
            return int(nbytes * min(int(live_w), w_rows) // w_rows)

        groups: Dict[Tuple[str, ...], Dict[str, int]] = {}
        for log in set(self._logs()):
            per: Dict[str, int] = {}
            for phase, _, _, nbytes, w_rows in log:
                per[phase] = per.get(phase, 0) + scaled(nbytes, w_rows)
            g = groups.setdefault(tuple(s[0] for s in log), {})
            for phase, nbytes in per.items():
                g[phase] = max(g.get(phase, 0), nbytes)
        out: Dict[str, int] = {}
        for phase, _, _, nbytes, w_rows in self._eager:
            out[phase] = out.get(phase, 0) + scaled(nbytes, w_rows)
        for g in groups.values():
            for phase, nbytes in g.items():
                out[phase] = out.get(phase, 0) + nbytes
        return out

    @property
    def bytes_by_phase(self) -> Dict[str, int]:
        return self._merged()

    def bytes_by_phase_at(self, live_w: int) -> Dict[str, int]:
        """Per-phase bytes with W-proportional payloads (``record``'s
        ``w_rows`` mark) scaled to the live vocabulary — the honest
        Eq. 5/6 accounting of a capacity-laddered run: guard rows are
        structurally zero, so they never cross the interconnect."""
        return self._merged(live_w)

    def phase_bytes(self, phase: str) -> int:
        return self.bytes_by_phase.get(phase, 0)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_phase.values())

    def per_minibatch_bytes(self, iters,
                            loop_phases: Sequence[str] = LOOP_PHASES,
                            live_w: Optional[int] = None) -> int:
        """The documented ``dense + (iters-1) * sparse`` mini-batch total.

        `loop_phases` payloads cross the interconnect once per inner
        iteration (their psums live in a trace-once while body); every
        other phase is paid once per mini-batch.  `iters` includes the
        first dense iteration, mirroring ``MinibatchResult.iters``.
        `live_w` scales W-proportional payloads to the live vocabulary
        (capacity-laddered runs; see ``bytes_by_phase_at``).
        """
        by = self._merged(live_w)
        once = sum(v for p, v in by.items() if p not in loop_phases)
        loop = sum(v for p, v in by.items() if p in loop_phases)
        return int(once + max(int(iters) - 1, 0) * loop)

    def reset(self) -> None:
        self.calls.clear()
        self._archived.clear()
        self._live.clear()
        self._eager.clear()


class Reducer:
    """All-reduce provider; subclasses define where the sum happens."""

    def __init__(self, meter: Optional[CommMeter] = None, sync_dtype=jnp.float32):
        self.meter = meter or CommMeter()
        self.sync_dtype = sync_dtype

    def _sum(self, x: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def psum(self, x: jnp.ndarray, phase: str, compress: bool = True,
             w_rows: Optional[int] = None, dtype=None) -> jnp.ndarray:
        """All-reduce `x`; payload cast to sync_dtype when `compress`.

        ``w_rows`` marks a vocabulary-proportional payload (recorded at
        capacity, billed at live W by ``CommMeter.bytes_by_phase_at``).
        ``dtype`` overrides the payload dtype for this call (compressed
        phi-statistic runs ship their deltas at phi_acc_dtype width —
        the meter bills the cast payload, so bytes halve for real)."""
        orig = x.dtype
        wire = dtype if dtype is not None else self.sync_dtype
        if compress and x.dtype != wire:
            x = x.astype(wire)
        self.meter.record(phase, x, w_rows=w_rows)
        out = self._sum(x)
        return out.astype(orig)

    def bill(self, x: jnp.ndarray, phase: str,
             w_rows: Optional[int] = None) -> jnp.ndarray:
        """Record a *local* full-statistic touch without reducing.

        The RM decay step (DESIGN.md §14) rescales every shard's resident
        phi-accumulator slice in place — no payload crosses the
        interconnect, but the [W, K] statistic read-modify-write is real
        memory traffic the cost model must see.  Billed once per
        mini-batch (the ``decay`` phase is not in ``LOOP_PHASES``),
        scaled to live W like any vocabulary-proportional record."""
        self.meter.record(phase, x, w_rows=w_rows)
        return x


class MeshReducer(Reducer):
    """psum over named mesh axes — for shard_map'd POBP."""

    def __init__(self, axis_name: AxisName, **kw):
        super().__init__(**kw)
        self.axis_name = axis_name

    def _sum(self, x):
        return jax.lax.psum(x, self.axis_name)


class SimReducer(Reducer):
    """Per-shard values carry a leading N axis; 'all-reduce' = sum + broadcast.

    Used by the single-device simulation path (tests, CPU benchmarks); the
    byte meter still records exactly what one shard would send.
    """

    def _sum(self, x):
        return jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True), x.shape)


class LocalReducer(Reducer):
    """N=1 degenerate reducer (OBP on a single processor) — no communication,
    so nothing is recorded in the meter.  The sync_dtype cast round-trip is
    still applied under `compress`, so an N=1 run is numerically identical
    to an N-shard run with the same sync_dtype (the payload precision is a
    property of the algorithm configuration, not of the shard count)."""

    def psum(self, x, phase: str, compress: bool = True,
             w_rows: Optional[int] = None, dtype=None):
        wire = dtype if dtype is not None else self.sync_dtype
        if compress and x.dtype != wire:
            return x.astype(wire).astype(x.dtype)
        return x

    def _sum(self, x):
        return x


class PSReducer(Reducer):
    """Parameter-server billing peer of ``MeshReducer``/``LocalReducer``.

    Under the pull-based PS architecture (DESIGN.md §15,
    ``dist/paramserver.py``) the in-step math is unchanged — the shard
    body still reduces the same payloads, so ``PSReducer`` delegates the
    actual sum to a wrapped inner reducer and the training trajectory at
    staleness 0 matches the allreduce backend.  What changes is the wire
    model:

      - every vocabulary-proportional payload (``w_rows``-marked) crosses
        the interconnect TWICE — once as a touched-row delta *push* to
        the owning server shards and once as a touched-row slice *pull*
        for the next mini-batch — so it is billed as two phases,
        ``{phase}.push`` and ``{phase}.pull``, both ``w_rows``-marked so
        ``bytes_by_phase_at(live_w)`` scales each leg down to the rows
        that actually travel (pass the measured mean touched-row count as
        ``live_w`` for touched-granularity billing);
      - payloads that are NOT vocabulary rows (per-topic scalars, r_k)
        never live on the row-sharded servers: with a single worker
        (``LocalReducer`` inner) they need no communication at all and
        are not billed; with several workers they still need a worker
        all-reduce and are billed unchanged.

    The host-side transport (``dist.paramserver.SimTransport``) counts
    the *measured* wire truth; this reducer is the trace-time model the
    bench cross-checks it against.
    """

    def __init__(self, inner: Reducer, **kw):
        kw.setdefault("meter", inner.meter)
        kw.setdefault("sync_dtype", inner.sync_dtype)
        super().__init__(**kw)
        self.inner = inner

    def psum(self, x: jnp.ndarray, phase: str, compress: bool = True,
             w_rows: Optional[int] = None, dtype=None) -> jnp.ndarray:
        orig = x.dtype
        wire = dtype if dtype is not None else self.sync_dtype
        if compress and x.dtype != wire:
            x = x.astype(wire)
        if w_rows:
            self.meter.record(f"{phase}.push", x, w_rows=w_rows)
            self.meter.record(f"{phase}.pull", x, w_rows=w_rows)
        elif not isinstance(self.inner, LocalReducer):
            self.meter.record(phase, x)
        out = self.inner._sum(x)
        return out.astype(orig)

    def bill(self, x: jnp.ndarray, phase: str,
             w_rows: Optional[int] = None) -> jnp.ndarray:
        # local statistic touches (decay) are identical under PS
        self.meter.record(phase, x, w_rows=w_rows)
        return x

    def _sum(self, x):
        return self.inner._sum(x)


def dense_sync_bytes(W: int, K: int, itemsize: int = 4) -> int:
    """Eq. (5) per-iteration payload of the MPA baseline: the full phi matrix.

    ``W`` is the LIVE vocabulary: on a capacity-laddered run the guard
    rows above live W are identically zero on every shard and never need
    to travel (DESIGN.md §12) — pass live W here, not the rung capacity.
    """
    return W * K * itemsize


def power_sync_bytes(P: int, Pk: int, W: int, itemsize: int = 4,
                     rw_itemsize: int = 4) -> int:
    """Eq. (6) per-iteration payload of POBP: packed phi + packed r at
    `itemsize` (the sync_dtype width) plus the [W] word-residual vector at
    `rw_itemsize`.

    `rw_itemsize` defaults to 4 because ``core/pobp.py`` syncs residuals
    with ``compress=False`` — those psums always travel at float32 width
    regardless of sync_dtype.  Pass ``rw_itemsize=itemsize`` only for a
    deployment that compresses the r_w sync too.

    ``W`` (and a ``P`` derived from it) is the LIVE vocabulary on a
    capacity-laddered run — guard rows carry zero residual and zero
    packed mass, so the honest Eq. 6 payload scales with live W, not
    with the rung capacity (DESIGN.md §12).
    """
    return 2 * P * Pk * itemsize + W * rw_itemsize


def touched_power_sync_bytes(P: int, Pk: int, touched_w: int,
                             itemsize: int = 4,
                             rw_itemsize: int = 4) -> int:
    """Touched-W refinement of Eq. (6): the per-iteration payload when a
    worker exchanges only the rows its current mini-batch touched
    (DESIGN.md §15 — the parameter-server wire model).

    The packed submatrix can cover at most ``min(P, touched_w)`` rows —
    power-selected rows the batch never touched carry no delta and need
    no pull — and the word-residual leg shrinks from the full [W] vector
    to the touched rows.  With the corpus-wide touched fraction ``f``
    this is ~``f`` × the allreduce payload, which is where the PS mode's
    measured-bytes win comes from (BENCH_comm gates the measured wire
    against exactly this model).
    """
    Pt = min(P, touched_w)
    return 2 * Pt * Pk * itemsize + touched_w * rw_itemsize
