"""Collapsed Gibbs sampling for LDA — the paper's GS-family comparator
(PGS [15] / PFGS [6] / PSGS [21] / YLDA [14] are all GS-based).

Token-level sequential sampler under ``lax.scan`` (the textbook Griffiths &
Steyvers chain).  The *parallel* variant follows the AD-LDA approximation of
Newman et al. [15]: shards sample independently against a stale global
word-topic count and all-reduce count deltas at the end of each sweep —
which is exactly why PGS "can yield only an approximate result" (§2) while
BP-based sync is exact.  Used by accuracy/speed benchmarks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import LDAConfig, MiniBatch


def tokens_from_batch(batch: MiniBatch) -> Tuple[np.ndarray, np.ndarray]:
    """Expand padded-CSR counts into flat (doc_id, word_id) token arrays.

    Vectorized with ``np.repeat`` over the row-major [D*L] slot grid —
    order-identical to the per-token double loop it replaces (slots emit
    in (d, l) order, each repeated count times), which was the setup
    bottleneck of the accuracy benchmark.
    """
    wid = np.asarray(batch.word_ids).reshape(-1).astype(np.int32)
    cnt = np.asarray(batch.counts).reshape(-1).astype(np.int64)
    D, L = batch.word_ids.shape
    doc = np.repeat(np.arange(D, dtype=np.int32), L)
    keep = cnt > 0
    return (np.repeat(doc[keep], cnt[keep]),
            np.repeat(wid[keep], cnt[keep]))


def gibbs_init(key: jax.Array, doc_ids, word_ids, D: int, cfg: LDAConfig):
    """Random topic assignment + count matrices (n_dk, n_wk, n_k)."""
    T = doc_ids.shape[0]
    z = jax.random.randint(key, (T,), 0, cfg.num_topics)
    n_dk = jnp.zeros((D, cfg.num_topics), jnp.float32).at[doc_ids, z].add(1.0)
    n_wk = jnp.zeros((cfg.vocab_size, cfg.num_topics), jnp.float32).at[word_ids, z].add(1.0)
    n_k = jnp.sum(n_wk, axis=0)
    return z, n_dk, n_wk, n_k


def gibbs_sweep(key: jax.Array, z, n_dk, n_wk, n_k, doc_ids, word_ids, cfg: LDAConfig):
    """One full sequential sweep over all tokens."""
    W = cfg.vocab_size

    def step(carry, inp):
        z_t, d, w, k_old_key = inp
        key_t = k_old_key
        n_dk, n_wk, n_k = carry
        # remove current assignment
        n_dk = n_dk.at[d, z_t].add(-1.0)
        n_wk = n_wk.at[w, z_t].add(-1.0)
        n_k = n_k.at[z_t].add(-1.0)
        logits = (jnp.log(n_dk[d] + cfg.alpha)
                  + jnp.log(n_wk[w] + cfg.beta)
                  - jnp.log(n_k + W * cfg.beta))
        z_new = jax.random.categorical(key_t, logits)
        n_dk = n_dk.at[d, z_new].add(1.0)
        n_wk = n_wk.at[w, z_new].add(1.0)
        n_k = n_k.at[z_new].add(1.0)
        return (n_dk, n_wk, n_k), z_new

    keys = jax.random.split(key, z.shape[0])
    (n_dk, n_wk, n_k), z_new = jax.lax.scan(
        step, (n_dk, n_wk, n_k), (z, doc_ids, word_ids, keys))
    return z_new, n_dk, n_wk, n_k


def run_gibbs(key: jax.Array, batch: MiniBatch, cfg: LDAConfig, sweeps: int):
    """Batch collapsed GS.  Returns (phi_hat[W, K], theta_hat[D, K])."""
    doc_ids, word_ids = tokens_from_batch(batch)
    doc_ids, word_ids = jnp.asarray(doc_ids), jnp.asarray(word_ids)
    key, sub = jax.random.split(key)
    z, n_dk, n_wk, n_k = gibbs_init(sub, doc_ids, word_ids, batch.num_docs, cfg)
    sweep = jax.jit(lambda k, z, a, b, c: gibbs_sweep(k, z, a, b, c,
                                                      doc_ids, word_ids, cfg))
    for _ in range(sweeps):
        key, sub = jax.random.split(key)
        z, n_dk, n_wk, n_k = sweep(sub, z, n_dk, n_wk, n_k)
    return n_wk, n_dk


def run_parallel_gibbs(key: jax.Array, batches, cfg: LDAConfig, sweeps: int):
    """AD-LDA (PGS): shards sweep independently, sync n_wk deltas per sweep.

    `batches`: list of per-shard MiniBatch.  Returns (phi_hat, comm_bytes).
    """
    shards = []
    for i, b in enumerate(batches):
        d, w = tokens_from_batch(b)
        shards.append((jnp.asarray(d), jnp.asarray(w), b.num_docs))
    key, *subs = jax.random.split(key, len(shards) + 1)
    states = []
    n_wk_glob = jnp.zeros((cfg.vocab_size, cfg.num_topics), jnp.float32)
    for (d, w, nd), sk in zip(shards, subs):
        z, n_dk, n_wk, n_k = gibbs_init(sk, d, w, nd, cfg)
        states.append([z, n_dk])
        n_wk_glob = n_wk_glob + n_wk
    comm_bytes = 0
    for s in range(sweeps):
        n_k_glob = jnp.sum(n_wk_glob, axis=0)
        deltas = jnp.zeros_like(n_wk_glob)
        for i, ((d, w, nd), st) in enumerate(zip(shards, states)):
            key, sub = jax.random.split(key)
            z, n_dk = st
            z2, n_dk2, n_wk2, _ = gibbs_sweep(sub, z, n_dk, n_wk_glob, n_k_glob,
                                              d, w, cfg)
            local_before = jnp.zeros_like(n_wk_glob).at[w, z].add(1.0)
            local_after = jnp.zeros_like(n_wk_glob).at[w, z2].add(1.0)
            deltas = deltas + (local_after - local_before)
            states[i] = [z2, n_dk2]
        n_wk_glob = n_wk_glob + deltas            # Eq. (4) style dense sync
        comm_bytes += int(n_wk_glob.size) * 4 * len(shards)
    return n_wk_glob, comm_bytes
