"""Compressed phi accumulators (DESIGN.md §13).

``LDAConfig.phi_acc_dtype = 'bfloat16'`` stores the streaming Eq. 11
statistic at half width: phi_acc HBM halves, the phi-delta sync payloads
ship at bf16 (``Reducer.psum(dtype=...)``), and checkpoints round-trip
the narrow dtype.  The accumulate itself always runs in float32 —
``phi_eff = phi_acc + delta`` promotes automatically — and only the
fold-back into the carry narrows.

A round-to-nearest fold-back would be biased: a per-batch delta smaller
than half a bf16 ULP of the running statistic rounds away to nothing
every single batch, so slowly-accumulating words stop learning.  The
fold-back therefore uses **stochastic rounding**: dither the 16 mantissa
bits that truncation drops with uniform random bits, then truncate.  Each
fold-back is unbiased (E[sr(x)] == x), so small deltas survive in
expectation and the bf16 trajectory tracks the f32 one within rounding
noise (tests/test_phi_acc_dtype.py pins the per-batch mean_r drift and
the converged held-out perplexity; a single sweep from a shared phi
drifts <= 1e-3 — the BENCH_inner_loop gate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_PHI_ACC_DTYPES = ("float32", "bfloat16")


def phi_acc_dtype(cfg) -> jnp.dtype:
    """Resolve cfg.phi_acc_dtype to the jnp storage dtype."""
    name = getattr(cfg, "phi_acc_dtype", "float32")
    if name not in _PHI_ACC_DTYPES:
        raise ValueError(f"unknown phi_acc_dtype: {name!r} "
                         f"(expected one of {_PHI_ACC_DTYPES})")
    return jnp.bfloat16 if name == "bfloat16" else jnp.float32


def stochastic_round(x: jnp.ndarray, dtype, key: jax.Array) -> jnp.ndarray:
    """Cast f32 ``x`` to ``dtype`` with stochastic rounding.

    bf16 is f32's top 16 bits, so truncation after adding uniform dither
    to the 16 dropped mantissa bits rounds x up with probability equal to
    the dropped fraction — unbiased in expectation.  The dither never
    crosses the sign bit (IEEE sign-magnitude: adding to the magnitude
    bits moves |x| up, possibly carrying into the exponent, which is the
    correct rounding-up of the magnitude).  float32 passes through.
    """
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float32:
        return x.astype(jnp.float32)
    if dtype != jnp.dtype(jnp.bfloat16):
        raise ValueError(f"stochastic_round supports float32/bfloat16, "
                         f"got {dtype}")
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    dither = jax.random.randint(key, x.shape, 0, 1 << 16,
                                dtype=jnp.int32).astype(jnp.uint32)
    rounded = (bits + dither) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded,
                                        jnp.float32).astype(jnp.bfloat16)
