"""Pure-jnp reference implementation of belief propagation for LDA.

This is the *oracle* for everything else in the repo:
  - the batch BP algorithm of Zeng et al. (paper ref [5]), synchronous
    (Jacobi) schedule,
  - the message update Eq. (1) with exact self-exclusion terms,
  - sufficient statistics Eqs. (2)-(3),
  - residuals Eq. (7).

No sharding, no selection, no streaming — deliberately simple and slow.
OBP (M>1), POBP (N>1) and the Pallas kernel are all tested against this.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import LDAConfig, MiniBatch


def init_messages(key: jax.Array, batch: MiniBatch, K: int) -> jnp.ndarray:
    """Random normalized messages mu[D, L, K] (Fig. 4 line 3)."""
    D, L = batch.word_ids.shape
    u = jax.random.uniform(key, (D, L, K), minval=0.01, maxval=1.0)
    return u / jnp.sum(u, axis=-1, keepdims=True)


def theta_hat_from(batch: MiniBatch, mu: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2) inclusive form: theta_hat[d, k] = sum_l c[d,l] mu[d,l,k]."""
    return jnp.einsum("dl,dlk->dk", batch.counts, mu)


def phi_delta_from(batch: MiniBatch, mu: jnp.ndarray, W: int) -> jnp.ndarray:
    """Mini-batch contribution to Eq. (3): Delta phi_hat[k, w] (scatter-add over tokens)."""
    weighted = batch.counts[..., None] * mu                     # [D, L, K]
    flat_w = batch.word_ids.reshape(-1)                         # [D*L]
    flat = weighted.reshape(-1, mu.shape[-1])                   # [D*L, K]
    out = jnp.zeros((W, mu.shape[-1]), flat.dtype).at[flat_w].add(flat)
    return out.T                                                # [K, W]


def bp_sweep(
    batch: MiniBatch,
    mu: jnp.ndarray,
    phi_prior: jnp.ndarray,
    cfg: LDAConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One synchronous BP sweep over all tokens.

    phi_prior[K, W] is the accumulated statistic from *previous* mini-batches
    (zero for pure batch BP).  Returns (mu_new, residual_wk[W, K], theta_hat).
    """
    K, W = cfg.num_topics, cfg.vocab_size
    theta = theta_hat_from(batch, mu)                           # [D, K]
    phi = phi_prior + phi_delta_from(batch, mu, W)              # [K, W]
    phi_tot = jnp.sum(phi, axis=1)                              # [K]

    c = batch.counts[..., None]                                 # [D, L, 1]
    self_contrib = c * mu                                       # [D, L, K]
    th = theta[:, None, :] - self_contrib + cfg.alpha           # Eq.(1) numerator, theta part
    ph = jnp.take(phi.T, batch.word_ids, axis=0) - self_contrib + cfg.beta
    pt = phi_tot[None, None, :] - self_contrib + W * cfg.beta
    unnorm = th * ph / pt
    mu_new = unnorm / jnp.sum(unnorm, axis=-1, keepdims=True)

    # Eq. (7): r[w, k] accumulated over tokens of word w.
    r_tok = batch.counts[..., None] * jnp.abs(mu_new - mu)      # [D, L, K]
    flat_w = batch.word_ids.reshape(-1)
    r_wk = jnp.zeros((W, K), r_tok.dtype).at[flat_w].add(r_tok.reshape(-1, K))
    return mu_new, r_wk, theta


def batch_bp(
    key: jax.Array,
    batch: MiniBatch,
    cfg: LDAConfig,
    iters: int,
    phi_prior: jnp.ndarray | None = None,
):
    """Full batch BP: `iters` synchronous sweeps.  Returns (mu, phi_hat, theta_hat, residual_trace)."""
    K, W = cfg.num_topics, cfg.vocab_size
    if phi_prior is None:
        phi_prior = jnp.zeros((K, W), jnp.float32)
    mu = init_messages(key, batch, K)
    tokens = jnp.maximum(batch.num_tokens(), 1.0)

    def body(mu, _):
        mu_new, r_wk, _ = bp_sweep(batch, mu, phi_prior, cfg)
        return mu_new, jnp.sum(r_wk) / tokens

    mu, res_trace = jax.lax.scan(body, mu, None, length=iters)
    theta = theta_hat_from(batch, mu)
    phi = phi_prior + phi_delta_from(batch, mu, W)
    return mu, phi, theta, res_trace


def log_likelihood(batch: MiniBatch, theta: jnp.ndarray, phi: jnp.ndarray,
                   cfg: LDAConfig) -> jnp.ndarray:
    """Token log-likelihood sum_{w,d} x log(sum_k theta_d(k) phi_w(k)) with
    normalized (smoothed) multinomials."""
    theta_n = (theta + cfg.alpha)
    theta_n = theta_n / jnp.sum(theta_n, axis=-1, keepdims=True)        # [D, K]
    phi_n = (phi + cfg.beta)
    phi_n = phi_n / jnp.sum(phi_n, axis=1, keepdims=True)               # [K, W]
    p_tok = jnp.einsum("dk,kdl->dl", theta_n,
                       jnp.take(phi_n, batch.word_ids, axis=1))         # [D, L]
    logp = jnp.where(batch.counts > 0, jnp.log(jnp.maximum(p_tok, 1e-30)), 0.0)
    return jnp.sum(batch.counts * logp)
