"""repro.core — the paper's contribution: OBP / POBP with the
communication-efficient power-selection MPA, plus reference baselines."""

from repro.core.types import (LDAConfig, LDAState, LDATrainState,  # noqa: F401
                              MiniBatch)
from repro.core.pobp import (  # noqa: F401
    dense_sweep,
    selective_sweep,
    pobp_minibatch,
    pobp_shard_body,
    grow_state,
    init_train_state,
    make_train_step,
    make_mesh_shard_fn,
    make_sim_minibatch_fn,
    run_stream,
)
from repro.core import (ref, power, residuals, sync,  # noqa: F401
                        infer, lifecycle, perplexity)
