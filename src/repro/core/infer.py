"""Fixed-phi inference core — the ONE token-major fold-in body shared by
serving, evaluation and the training driver's held-out hook (DESIGN.md §11).

The paper's deployment protocol (Eq. 20, §4) estimates theta for incoming
documents by BP fold-in with phi frozen.  This module is that inner loop as
a production artifact:

  - **token-major carry** (`TokenLayout`, DESIGN.md §2): messages live as
    [T, Kl] flat token streams; the fixed phi is gathered to [T, Kl] ONCE
    per batch (it never changes), so every sweep is pure elementwise work
    plus one per-doc reduction — no [D, L, K] rewrite per iteration;
  - **residual-based early exit per document**: each sweep carries the
    per-doc message residual r_d = sum_l c |mu' - mu|, whose sweep-over-
    sweep decay rho estimates the document's REMAINING movement as the
    geometric tail r_d * rho / (1 - rho).  A document freezes once that
    tail drops below ``residual_tol`` per token (its tokens stop updating,
    so its theta never moves again — and would have moved at most ~tol had
    it kept running); the loop ends when every document is frozen or
    ``iters`` is reached — the serving analogue of Fig. 4 line 26;
  - **kernel reuse with the phi update disabled**: the Pallas path runs
    the carry-resident `power_sweep_carry` megakernel with
    ``update_phi=False`` (the training-side packed delta/residual
    accumulation is dead; the per-doc theta delta and |delta| residual
    accumulate in-kernel instead) and the full vocabulary as the "power"
    rows, with frozen tokens routed to the guard row so the freeze
    happens in-kernel;
  - **topic sharding**: the renormalization and residual reductions go
    through a `Reducer` ("model"-axis psums, byte-metered), so the same
    body serves a topic-sharded phi — the init draws the random field at
    the GLOBAL K and slices the local columns (the K-axis analogue of
    ``LDAConfig.init_pad_len``), keeping sharded and unsharded fold-ins
    numerically aligned.

`fold_in_dense_reference` keeps the seed's dense [D, L, K] scan as the
semantics oracle and the BENCH_serve baseline; no production path calls it.

**W-capacity note** (DESIGN.md §12): the body is W-shape-agnostic — phi
arrives as an argument and tokens only ever gather their own rows — so a
capacity-laddered phi (guard rows above the live vocabulary) folds in
unchanged.  The live-W masking lives entirely in how phi_norm is built
(``perplexity.normalize_phi(..., live_w=...)``): guard rows carry the
beta-prior mass, which is what makes serving's OOV admission exact.
The Pallas path derives its row tables and guard-row index from phi's
own row count, so no part of the body depends on ``cfg.vocab_size``
matching the (possibly capacity-grown) phi it serves.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sync import CommMeter, LocalReducer, MeshReducer, Reducer
from repro.core.types import LDAConfig, MiniBatch


@dataclasses.dataclass
class FoldInResult:
    """Device-resident fold-in diagnostics (a jax pytree).

    theta:  [D, Kl] normalized topic mixture (local topic shard)
    iters:  int32 scalar — sweeps actually run (early exit included)
    mean_r: final mean residual per token (the Fig. 4 line 26 quantity)
    r_doc:  [D] final per-document residual (the early-exit signal)
    """

    theta: jnp.ndarray
    iters: jnp.ndarray
    mean_r: jnp.ndarray
    r_doc: jnp.ndarray


jax.tree_util.register_dataclass(
    FoldInResult, data_fields=("theta", "iters", "mean_r", "r_doc"),
    meta_fields=())


def _init_messages(key: jax.Array, batch: MiniBatch, cfg: LDAConfig,
                   kl: int, model_reducer: Reducer) -> jnp.ndarray:
    """Random init, invariant to both the L bucket and the topic shard.

    Drawn at [D, max(init_pad_len, L), K_global] and sliced to this batch's
    L and this shard's topic columns, so the same document produces the
    same theta whichever bucket admitted it and however phi is sharded.
    """
    D, L = batch.word_ids.shape
    K = cfg.num_topics
    Lpad = L if cfg.init_pad_len is None else max(cfg.init_pad_len, L)
    u = jax.random.uniform(key, (D, Lpad, K), minval=0.01, maxval=1.0)[:, :L]
    if kl != K:
        idx = jax.lax.axis_index(model_reducer.axis_name)
        u = jax.lax.dynamic_slice_in_dim(u, idx * kl, kl, axis=2)
    norm = model_reducer.psum(jnp.sum(u, -1, keepdims=True), "model_norm",
                              compress=False)
    return u / norm


def fold_in_tokens(key: jax.Array, batch: MiniBatch, phi_norm_wk: jnp.ndarray,
                   cfg: LDAConfig, iters: int = 30,
                   residual_tol: float = 0.0,
                   model_reducer: Optional[Reducer] = None,
                   impl: Optional[str] = None) -> FoldInResult:
    """Token-major BP fold-in with phi fixed (the shared inference body).

    `phi_norm_wk` [W, Kl] is the NORMALIZED topic-word matrix (this shard's
    topic columns when the model axis is sharded).  ``residual_tol == 0``
    disables early exit (every document sweeps all `iters` — the protocol
    `fold_in_dense_reference` implements); a positive tolerance freezes
    each document once its per-token residual drops below it and ends the
    loop when all have.  Returns a `FoldInResult` of device values.
    """
    model_reducer = model_reducer or LocalReducer()
    impl = cfg.impl if impl is None else impl
    D, L = batch.word_ids.shape
    Kl = phi_norm_wk.shape[1]
    layout = batch.token_layout()
    T = layout.num_slots
    c = layout.counts                                           # [T, 1]
    tok_d = c.reshape(D, L).sum(axis=1)                         # [D]
    total = jnp.maximum(jnp.sum(tok_d), 1.0)

    mu_t = _init_messages(key, batch, cfg, Kl, model_reducer).reshape(T, Kl)
    phi_tok = jnp.take(phi_norm_wk, layout.word_ids, axis=0)    # [T, Kl], once
    theta0 = (c * mu_t).reshape(D, L, Kl).sum(axis=1)           # [D, Kl]

    use_pallas = impl == "pallas" and isinstance(model_reducer, LocalReducer)
    if use_pallas:
        from repro.kernels.power_sweep.ops import power_sweep_carry
        # constant phi row table for the carry megakernel, built once per
        # fold-in: every phi row is a "power" row over all topics (the
        # kernel's update_phi=False mode needs no mask table — selection
        # is one compare against the appended guard row, which freezes
        # tokens in-kernel).  Everything derives from phi's OWN row count
        # so a capacity-grown phi folds in correctly whatever
        # cfg.vocab_size the caller holds.
        w_rows = phi_norm_wk.shape[0]
        phi_rows = jnp.concatenate(
            [phi_norm_wk, jnp.zeros((1, Kl), phi_norm_wk.dtype)], axis=0)
        mask_dummy = jnp.zeros((1, Kl), jnp.float32)
        pt_zero = jnp.zeros((Kl,), jnp.float32)
        # same VMEM-fit dispatch as training (DESIGN.md §13), with the
        # serving row table being the whole vocabulary: the full-K carry
        # kernel while it fits, the K-blocked two-pass kernel beyond, or
        # pinned by an explicit cfg.sweep_policy == 'kblocked'
        from repro.core.sweep_dispatch import carry_vmem_fit
        serve_kblocked = (
            cfg.sweep_policy == "kblocked"
            or (cfg.sweep_policy == "auto"
                and not carry_vmem_fit(Kl, w_rows, D,
                                       cfg.vmem_budget_bytes)))

    def active_docs(r_doc, r_prev):
        # geometric-tail bound on the theta movement still to come: with
        # per-sweep decay rho = r/r_prev, the remaining total is about
        # r * rho / (1 - rho).  The measured rho is floored at a
        # pessimistic 0.8 (fold-in decay slows as it converges, so the
        # instantaneous ratio understates the tail) and capped below 1 so
        # plateauing documents stay active until the iteration cap.
        rho = jnp.clip(r_doc / jnp.maximum(r_prev, 1e-30), 0.8, 0.95)
        tail = r_doc * rho / (1.0 - rho)
        return tail > residual_tol * tok_d

    def cond(carry):
        _, _, r_doc, r_prev, t = carry
        return jnp.logical_and(t < iters,
                               jnp.any(active_docs(r_doc, r_prev)))

    def body(carry):
        mu_t, theta, r_doc, r_prev, t = carry
        act_tok = active_docs(r_doc, r_prev)[layout.doc_ids]    # [T]
        if use_pallas:
            # carry-resident megakernel with the phi update disabled
            # (update_phi=False, kernels/power_sweep): one grid pass does
            # the theta gather, the pure update u = (theta - c*mu + alpha)
            # * phi_norm (beta = 0 passes phi through bit-exactly; the
            # zero pt argument and unit wbeta make the denominator exactly
            # 1), the fold-back, the per-doc theta delta AND the per-doc
            # |delta| residual.  Frozen tokens hit the guard row so the
            # freeze happens in-kernel; the packed delta/residual outputs
            # are dead on this path.
            p_tok = jnp.where(act_tok, layout.word_ids,
                              w_rows).astype(jnp.int32)
            mu_new, th_delta, _, _, r_local = power_sweep_carry(
                p_tok, layout.doc_ids, c, mu_t, theta, pt_zero,
                phi_rows, mask_dummy, alpha=cfg.alpha, beta=0.0, wbeta=1.0,
                update_phi=False, kblocked=serve_kblocked,
                vmem_budget_bytes=cfg.vmem_budget_bytes)
            theta = theta + th_delta
        else:
            th = theta[layout.doc_ids] - c * mu_t + cfg.alpha
            unnorm = th * phi_tok
            norm = model_reducer.psum(
                jnp.sum(unnorm, -1, keepdims=True), "model_norm_loop",
                compress=False)
            mu_new = unnorm / jnp.maximum(norm, 1e-30)
            mu_new = jnp.where(act_tok[:, None], mu_new, mu_t)
            delta = mu_new - mu_t
            theta = theta + (c * delta).reshape(D, L, Kl).sum(axis=1)
            r_local = (c * jnp.abs(delta)).reshape(D, L, Kl).sum(axis=(1, 2))
        r_new = model_reducer.psum(r_local, "model_rw_loop", compress=False)
        return mu_new, theta, r_new, r_doc, t + 1

    # r_doc starts at inf (everything active), r_prev at 1 so the first
    # rho is a clean clipped value rather than inf/inf
    carry0 = (mu_t, theta0, jnp.full((D,), jnp.inf, jnp.float32),
              jnp.ones((D,), jnp.float32), jnp.asarray(0, jnp.int32))
    _, theta, r_doc, _, t = jax.lax.while_loop(cond, body, carry0)

    th = theta + cfg.alpha
    denom = model_reducer.psum(jnp.sum(th, -1, keepdims=True), "theta_norm",
                               compress=False)
    return FoldInResult(theta=th / denom, iters=t,
                        mean_r=jnp.sum(r_doc) / total, r_doc=r_doc)


def make_fold_in_step(cfg: LDAConfig, fold_iters: int = 30,
                      residual_tol: float = 0.0, topic_shards: int = 1,
                      sync_dtype=jnp.float32, donate: bool = True,
                      impl: Optional[str] = None
                      ) -> Tuple[object, CommMeter]:
    """The production serving step: one jitted fixed-phi fold-in batch.

    Returns (step, meter) with ``step(phi_norm, key, word_ids, counts) ->
    (theta [D, K], iters, mean_r)``.  `phi_norm` is an argument (not a
    closure constant) so the engine keeps ONE device-resident copy across
    every bucket shape; with ``topic_shards > 1`` it is [N, W, K/N] stacked
    and the body runs under ``jax.vmap(axis_name="model")`` with psum'd
    renormalization — bit-identical collectives to a real model-axis mesh,
    byte-metered per request batch.  The batch buffers (key, word_ids,
    counts) are donated: per-request device allocations are recycled
    step-over-step.  Compiles once per distinct (D, L); feed it bucketed
    shapes (`data/batching.bucket_len`) to bound the compile count.
    """
    meter = CommMeter()
    if topic_shards == 1:
        reducer: Reducer = LocalReducer(meter=meter, sync_dtype=sync_dtype)
    else:
        reducer = MeshReducer("model", meter=meter, sync_dtype=sync_dtype)

    def body(phi_norm, key, word_ids, counts):
        res = fold_in_tokens(key, MiniBatch(word_ids, counts), phi_norm, cfg,
                             iters=fold_iters, residual_tol=residual_tol,
                             model_reducer=reducer, impl=impl)
        return res.theta, res.iters, res.mean_r

    def step(phi_norm, key, word_ids, counts):
        if topic_shards == 1:
            theta, it, mean_r = body(phi_norm, key, word_ids, counts)
        else:
            theta, it, mean_r = jax.vmap(
                body, in_axes=(0, None, None, None), axis_name="model")(
                    phi_norm, key, word_ids, counts)
            # [N, D, K/N] local shards -> [D, K] global mixture; the scalar
            # diagnostics are shard-identical by construction
            theta = jnp.transpose(theta, (1, 0, 2)).reshape(
                theta.shape[1], -1)
            it, mean_r = it[0], mean_r[0]
        return theta, it, mean_r

    donate_argnums = (1, 2, 3) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums), meter


# --------------------------------------------------------------------------
# continuous-batching slab step (DESIGN.md §16)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SlabState:
    """Persistent in-flight fold-in slab (a jax pytree, donated step-over-step).

    A fixed [B, L] grid of request slots: each live slot holds one
    document mid-fold-in.  All per-slot state advances together in
    `make_slab_step`'s jitted step; retirement/refill swaps individual
    slots from the host without ever changing a compiled shape.

    word_rows: int32 [B, L]   phi rows per token slot (0 when empty)
    counts:    f32   [B, L]   token counts (0 when empty / padding)
    mu:        f32   [B*L,Kl] token-major messages ([N, B*L, Kl] sharded)
    theta:     f32   [B, Kl]  doc-topic statistic  ([N, B, Kl] sharded)
    r_doc:     f32   [B]      last per-doc residual (early-exit signal)
    r_prev:    f32   [B]      previous residual (the geometric-tail rho)
    it:        int32 [B]      fold-in sweeps this slot's document has run
    live:      bool  [B]      slot holds an un-retired request
    """

    word_rows: jnp.ndarray
    counts: jnp.ndarray
    mu: jnp.ndarray
    theta: jnp.ndarray
    r_doc: jnp.ndarray
    r_prev: jnp.ndarray
    it: jnp.ndarray
    live: jnp.ndarray


jax.tree_util.register_dataclass(
    SlabState,
    data_fields=("word_rows", "counts", "mu", "theta", "r_doc", "r_prev",
                 "it", "live"),
    meta_fields=())


def make_slab_step(cfg: LDAConfig, *, slots: int, slot_len: int,
                   refill_cap: Optional[int] = None,
                   sweeps_per_step: int = 2, fold_iters: int = 30,
                   residual_tol: float = 1e-2, topic_shards: int = 1,
                   sync_dtype=jnp.float32, donate: bool = True,
                   impl: Optional[str] = None):
    """Continuous-batching serving step: advance every in-flight slot a few
    fold-in sweeps, retire the converged, refill mid-flight (DESIGN.md §16).

    Replaces bucket-barrier admission: instead of a batch that lives and
    dies together, a persistent [B = slots, L = slot_len] slab carries one
    live document per slot.  Each call to the returned ``step``:

      1. **refills**: scatters up to ``refill_cap`` freshly admitted
         documents into the slot indices the host picked (a retired or
         never-used slot; index ``slots`` marks an unused refill lane and
         is scatter-dropped), drawing each new document's random message
         init — or a warm-start init from a cached theta — in-step;
      2. **iterates**: runs ``sweeps_per_step`` token-major fold-in sweeps
         over the whole slab (the exact `fold_in_tokens` update; frozen /
         empty slots are masked, and on the Pallas path routed to the
         carry megakernel's guard row);
      3. **retires**: recomputes each live slot's geometric-tail residual
         bound; a slot whose remaining theta movement clears
         ``residual_tol`` per token (or that hit ``fold_iters``) comes
         back in the ``retired`` mask with its normalized theta.

    Compiles ONCE for the slab geometry — request shapes never reach the
    compiler, so admission is barrier-free: no request waits for a bucket
    to fill and no converged document holds its slot while stragglers
    finish.

    Returns ``(init_state, step, meter)`` where

      init_state() -> SlabState (all slots empty)
      step(phi_norm, state, refill_rows [R, L], refill_cnt [R, L],
           refill_slot [R], warm_theta [R, K], warm_mask [R], key)
        -> (state', retired [B] bool, theta_out [B, K], iters [B] int32,
            r_doc [B])

    ``phi_norm`` is an argument (one device-resident copy, swap-friendly);
    with ``topic_shards > 1`` it is the [N, W, K/N] stack from
    `split_topic_shards` and the body runs under ``jax.vmap`` with psum'd
    renormalization, byte-metered — the same simulation contract as
    `make_fold_in_step`.  ``state`` is donated: the slab never reallocates.
    """
    B, L = int(slots), int(slot_len)
    R = B if refill_cap is None else int(refill_cap)
    if not 0 < R <= B:
        raise ValueError(f"refill_cap={R} outside [1, slots={B}]")
    if sweeps_per_step < 1:
        raise ValueError(f"sweeps_per_step must be >= 1: {sweeps_per_step}")
    K = cfg.num_topics
    if K % topic_shards:
        raise ValueError(f"num_topics={K} does not divide over "
                         f"{topic_shards} topic shards")
    Kl = K // topic_shards
    meter = CommMeter()
    if topic_shards == 1:
        reducer: Reducer = LocalReducer(meter=meter, sync_dtype=sync_dtype)
    else:
        reducer = MeshReducer("model", meter=meter, sync_dtype=sync_dtype)
    impl_r = cfg.impl if impl is None else impl
    use_pallas = impl_r == "pallas" and topic_shards == 1
    doc_ids = jnp.repeat(jnp.arange(B, dtype=jnp.int32), L)       # [B*L]
    tol = float(residual_tol)

    def init_state() -> SlabState:
        lead = () if topic_shards == 1 else (topic_shards,)
        return SlabState(
            word_rows=jnp.zeros((B, L), jnp.int32),
            counts=jnp.zeros((B, L), jnp.float32),
            mu=jnp.zeros(lead + (B * L, Kl), jnp.float32),
            theta=jnp.zeros(lead + (B, Kl), jnp.float32),
            r_doc=jnp.zeros((B,), jnp.float32),
            r_prev=jnp.ones((B,), jnp.float32),
            it=jnp.zeros((B,), jnp.int32),
            live=jnp.zeros((B,), bool))

    def active_slots(r_doc, r_prev, it, live, tok_d):
        # the fold_in_tokens geometric-tail bound, per slot: remaining
        # theta movement ~ r * rho / (1 - rho) with rho the sweep-over-
        # sweep decay (pessimistic floor 0.8, capped below 1)
        rho = jnp.clip(r_doc / jnp.maximum(r_prev, 1e-30), 0.8, 0.95)
        tail = r_doc * rho / (1.0 - rho)
        return live & (it < fold_iters) & (tail > tol * tok_d)

    def body(phi_norm, state: SlabState, refill_rows, refill_cnt,
             refill_slot, warm_theta, warm_mask, key):
        valid = refill_slot < B                                    # [R]
        wid = state.word_rows.at[refill_slot].set(refill_rows, mode="drop")
        cnt = state.counts.at[refill_slot].set(refill_cnt, mode="drop")
        live = state.live.at[refill_slot].set(valid, mode="drop")

        # ---- fresh init for refilled slots (in-step, per-slot random) --
        # drawn at the GLOBAL K and sliced per topic shard, the same
        # K-invariant contract as _init_messages; warm-started slots seed
        # their messages from the cached theta instead (one BP half-step:
        # m_l ∝ theta_cached * phi_w_l), which restarts the fold-in near
        # the cached posterior so the residual bound clears in fewer sweeps
        u = jax.random.uniform(key, (R, L, K), minval=0.01, maxval=1.0)
        if Kl != K:
            idx = jax.lax.axis_index("model")
            u = jax.lax.dynamic_slice_in_dim(u, idx * Kl, Kl, axis=2)
            warm_theta = jax.lax.dynamic_slice_in_dim(
                warm_theta, idx * Kl, Kl, axis=1)
        phi_new = jnp.take(phi_norm, refill_rows.reshape(-1),
                           axis=0).reshape(R, L, Kl)
        warm_u = warm_theta[:, None, :] * phi_new                 # [R, L, Kl]
        u = jnp.where(warm_mask[:, None, None], warm_u, u)
        norm0 = reducer.psum(jnp.sum(u, -1, keepdims=True),
                             "slab_init_norm", compress=False)
        mu0 = u / jnp.maximum(norm0, 1e-30)
        c_new = refill_cnt[..., None]                             # [R, L, 1]
        theta0 = jnp.sum(c_new * mu0, axis=1)                     # [R, Kl]

        mu = state.mu.reshape(B, L, Kl).at[refill_slot].set(
            mu0, mode="drop").reshape(B * L, Kl)
        theta = state.theta.at[refill_slot].set(theta0, mode="drop")
        r_doc = state.r_doc.at[refill_slot].set(
            jnp.where(valid, jnp.inf, 0.0), mode="drop")
        r_prev = state.r_prev.at[refill_slot].set(1.0, mode="drop")
        it = state.it.at[refill_slot].set(0, mode="drop")

        # ---- iterate: sweeps_per_step token-major fold-in sweeps -------
        c = cnt.reshape(B * L, 1)
        tok_d = cnt.sum(axis=1)                                    # [B]
        wid_t = wid.reshape(B * L)
        phi_tok = jnp.take(phi_norm, wid_t, axis=0)                # [T, Kl]
        if use_pallas:
            from repro.core.sweep_dispatch import carry_vmem_fit
            from repro.kernels.power_sweep.ops import power_sweep_carry
            w_rows = phi_norm.shape[0]
            phi_rows = jnp.concatenate(
                [phi_norm, jnp.zeros((1, Kl), phi_norm.dtype)], axis=0)
            mask_dummy = jnp.zeros((1, Kl), jnp.float32)
            pt_zero = jnp.zeros((Kl,), jnp.float32)
            kblocked = (cfg.sweep_policy == "kblocked"
                        or (cfg.sweep_policy == "auto"
                            and not carry_vmem_fit(Kl, w_rows, B,
                                                   cfg.vmem_budget_bytes)))
        for _ in range(sweeps_per_step):
            act_d = active_slots(r_doc, r_prev, it, live, tok_d)   # [B]
            act_tok = act_d[doc_ids]                               # [T]
            if use_pallas:
                p_tok = jnp.where(act_tok, wid_t, w_rows).astype(jnp.int32)
                mu_new, th_delta, _, _, r_local = power_sweep_carry(
                    p_tok, doc_ids, c, mu, theta, pt_zero,
                    phi_rows, mask_dummy, alpha=cfg.alpha, beta=0.0,
                    wbeta=1.0, update_phi=False, kblocked=kblocked,
                    vmem_budget_bytes=cfg.vmem_budget_bytes)
                theta = theta + th_delta
            else:
                th = theta[doc_ids] - c * mu + cfg.alpha
                unnorm = th * phi_tok
                norm = reducer.psum(jnp.sum(unnorm, -1, keepdims=True),
                                    "slab_norm_loop", compress=False)
                mu_new = unnorm / jnp.maximum(norm, 1e-30)
                mu_new = jnp.where(act_tok[:, None], mu_new, mu)
                delta = mu_new - mu
                theta = theta + (c * delta).reshape(B, L, Kl).sum(axis=1)
                r_local = (c * jnp.abs(delta)).reshape(B, L, Kl).sum(
                    axis=(1, 2))
            r_new = reducer.psum(r_local, "slab_rw_loop", compress=False)
            r_prev = jnp.where(act_d, r_doc, r_prev)
            r_doc = jnp.where(act_d, r_new, r_doc)
            it = it + act_d.astype(jnp.int32)
            mu = mu_new

        # ---- retire: live slots whose residual bound cleared -----------
        still = active_slots(r_doc, r_prev, it, live, tok_d)
        retired = live & ~still
        th_out = theta + cfg.alpha
        denom = reducer.psum(jnp.sum(th_out, -1, keepdims=True),
                             "slab_theta_norm", compress=False)
        theta_out = th_out / denom                                  # [B, Kl]
        state = SlabState(word_rows=wid, counts=cnt, mu=mu, theta=theta,
                          r_doc=r_doc, r_prev=r_prev, it=it, live=still)
        return state, retired, theta_out, it, r_doc

    def step(phi_norm, state, refill_rows, refill_cnt, refill_slot,
             warm_theta, warm_mask, key):
        if topic_shards == 1:
            return body(phi_norm, state, refill_rows, refill_cnt,
                        refill_slot, warm_theta, warm_mask, key)
        in_state = SlabState(word_rows=None, counts=None, mu=0, theta=0,
                             r_doc=None, r_prev=None, it=None, live=None)
        out_st, retired, theta_out, it, r_doc = jax.vmap(
            body, in_axes=(0, in_state, None, None, None, None, None, None),
            axis_name="model")(phi_norm, state, refill_rows, refill_cnt,
                               refill_slot, warm_theta, warm_mask, key)
        # shared fields come back shard-replicated: keep shard 0; the
        # sharded mu/theta keep their leading [N] axis
        state = SlabState(word_rows=out_st.word_rows[0],
                          counts=out_st.counts[0], mu=out_st.mu,
                          theta=out_st.theta, r_doc=out_st.r_doc[0],
                          r_prev=out_st.r_prev[0], it=out_st.it[0],
                          live=out_st.live[0])
        # [N, B, K/N] local mixtures -> [B, K] global
        theta_out = jnp.transpose(theta_out, (1, 0, 2)).reshape(B, -1)
        return state, retired[0], theta_out, it[0], r_doc[0]

    donate_argnums = (1,) if donate else ()
    return init_state, jax.jit(step, donate_argnums=donate_argnums), meter


def split_topic_shards(phi_norm_wk: jnp.ndarray, topic_shards: int
                       ) -> jnp.ndarray:
    """[W, K] -> [N, W, K/N] contiguous topic shards (the layout
    `make_fold_in_step`'s vmap simulation consumes)."""
    if topic_shards == 1:
        return phi_norm_wk
    W, K = phi_norm_wk.shape
    if K % topic_shards:
        raise ValueError(f"num_topics={K} does not divide over "
                         f"{topic_shards} topic shards")
    return jnp.transpose(
        phi_norm_wk.reshape(W, topic_shards, K // topic_shards), (1, 0, 2))


def fold_in_dense_reference(key: jax.Array, batch: MiniBatch,
                            phi_norm_wk: jnp.ndarray, cfg: LDAConfig,
                            iters: int = 30) -> jnp.ndarray:
    """SEED-LAYOUT ORACLE: the dense [D, L, K] fold-in scan.

    Kept only as the semantics oracle for tests/test_serve.py and the
    BENCH_serve dense baseline — every production path (serve, eval, the
    driver's held-out hook) routes through `fold_in_tokens`.  Fixed-count
    scan, no early exit, whole-tensor rewrite per iteration.
    """
    D, L = batch.word_ids.shape
    K = phi_norm_wk.shape[1]
    Lpad = L if cfg.init_pad_len is None else max(cfg.init_pad_len, L)
    u = jax.random.uniform(key, (D, Lpad, K), minval=0.01, maxval=1.0)[:, :L]
    mu = u / jnp.sum(u, -1, keepdims=True)
    phi_tok = jnp.take(phi_norm_wk, batch.word_ids, axis=0)      # [D, L, K]
    c = batch.counts[..., None]

    def body(mu, _):
        theta = jnp.einsum("dl,dlk->dk", batch.counts, mu)
        th = theta[:, None, :] - c * mu + cfg.alpha
        unnorm = th * phi_tok
        mu = unnorm / jnp.maximum(jnp.sum(unnorm, -1, keepdims=True), 1e-30)
        return mu, None

    mu, _ = jax.lax.scan(body, mu, None, length=iters)
    theta = jnp.einsum("dl,dlk->dk", batch.counts, mu) + cfg.alpha
    return theta / jnp.sum(theta, -1, keepdims=True)
