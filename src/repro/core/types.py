"""Core dataclasses for the LDA / POBP stack.

The document-word matrix x[W, D] of the paper is represented in
*padded-CSR* form per mini-batch: each document d owns up to L distinct
word slots; slot l holds a vocabulary index ``word_ids[d, l]`` and a count
``counts[d, l]``.  Padding slots use ``word_ids == 0`` and ``counts == 0``
(zero count makes every padded contribution vanish; alpha/beta smoothing
keeps the message update finite there).

Notation maps 1:1 onto the paper (Table 1):
  D   documents per mini-batch          W   vocabulary size
  K   topics                            L   max distinct words per doc
  mu[D, L, K]        messages (Eq. 1)
  theta_hat[D, K]    doc-topic sufficient statistics (Eq. 2)
  phi_hat[K, W]      topic-word sufficient statistics (Eq. 3)
  r[W, K]            residual matrix (Eqs. 7-9)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LDAConfig:
    """Static configuration of an LDA/POBP run (hashable; safe to close over jit).

    ``vocab_size`` is the *allocated* W — on a dynamic-vocabulary run it
    is the current capacity-ladder rung W_cap (DESIGN.md §12): phi/r
    buffers are [W_cap, K]-shaped, rows in [live_w, W_cap) are guard rows,
    and the traced live_w that flows through ``core.pobp`` carries the
    actual vocabulary size (smoothing, selection, byte accounting).  On a
    fixed-vocabulary run the two coincide and live_w stays None.
    """

    vocab_size: int                 # W (capacity rung W_cap when dynamic)
    num_topics: int                 # K
    alpha: float = 0.1              # Dirichlet prior on theta (paper: 2/K)
    beta: float = 0.01              # Dirichlet prior on phi   (paper: 0.01)
    # --- power selection (the paper's contribution) ---
    lambda_w: float = 0.1           # ratio of power words   (paper default 0.1)
    lambda_k_abs: int = 50          # number of power topics per word (paper: lambda_K*K = 50)
    # --- convergence / schedule ---
    inner_iters: int = 10           # T_m: max message-passing sweeps per mini-batch
    residual_tol: float = 0.1       # line 26 of Fig. 4: mean residual per token
    # --- online learning rate (Eq. 11); 'paper' => 1/max(m-1, 1) ---
    lr_schedule: str = "paper"      # 'paper' | 'power'
    lr_tau0: float = 1.0            # used by the 'power' schedule (tau0 + m)^-kappa
    lr_kappa: float = 0.9
    # --- Robbins-Monro forgetting on the phi accumulator (DESIGN.md §14) ---
    # The Eq. 11 fold-back becomes
    #     phi_acc <- (1 - rho_m) * phi_acc + delta_weight * Delta_phi,
    # with rho_m = (decay_tau0 + m)^(-decay_kappa) the classic RM step on
    # the historical statistic: stale mass fades (a row that stops
    # receiving tokens decays multiplicatively toward the prior) while the
    # current batch always enters at full weight.  decay_kappa == 0
    # statically disables the term — the fold-back is then the *identical
    # expression* the plain-accumulation path always ran, so kappa=0 runs
    # are bit-exact with the pre-lifecycle trajectory (pinned in
    # tests/test_lifecycle.py).
    decay_tau0: float = 1.0
    decay_kappa: float = 0.0
    # --- communication payload ---
    sync_dtype: str = "float32"     # 'float32' | 'bfloat16' (beyond-paper byte halving)
    # --- compute backend for the dense sweep ---
    impl: str = "jnp"               # 'jnp' | 'pallas' (fused bp_update kernel)
    # --- selective-sweep formulation (DESIGN.md §2 / §13 cost model) ---
    # 'auto' picks per (T, K, Pk, P) from the measured cost model at trace
    # time (on pallas, extended with the VMEM-fit predicate: full-K carry
    # while it fits, kblocked beyond); 'packed' forces the [T, Pk] stream +
    # fold-back chain; 'dense_layout' forces the one-pass [T, K] masked
    # formulation (the jnp mirror of the carry-resident power_sweep
    # megakernel); 'kblocked' forces the K-blocked two-pass carry kernel
    # (ultra-high K; on the jnp impl an alias of dense_layout).  Identical
    # selective math and identical packed Eq. 6 communication any way.
    sweep_policy: str = "auto"  # 'auto'|'packed'|'dense_layout'|'kblocked'
    # VMEM byte budget for the pallas tile choosers and the kblocked
    # dispatch predicate; None resolves REPRO_VMEM_BUDGET_BYTES then the
    # built-in default (kernels/power_sweep/kernel.py).
    vmem_budget_bytes: Optional[int] = None
    # --- compressed phi accumulators (DESIGN.md §13) ---
    # Storage dtype of the streaming phi_acc statistic: 'float32' (exact)
    # or 'bfloat16' (halves accumulator HBM + Eq. 6 phi-delta sync bytes;
    # the Eq. 11 accumulate runs in f32 and folds back with stochastic
    # rounding so small per-batch deltas are not systematically lost).
    phi_acc_dtype: str = "float32"  # 'float32' | 'bfloat16'
    # Crossover for the packed path's [P, Pk] accumulation: one-hot MXU
    # contraction while T*P <= crossover, row-scatter above.  Consumed by
    # the dispatch cost model (core/sweep_dispatch.py).
    onehot_crossover: int = 8_000_000
    # --- shape-bucketed streaming ---
    # When set, the random message init is drawn at [D, init_pad_len, K] and
    # sliced to the batch's L, so phi_acc is invariant to how far L was
    # padded (padding slots carry zero counts and contribute nothing).  The
    # streaming driver sets this to its largest length bucket, making
    # bucketed and unbucketed runs of the same corpus agree.
    init_pad_len: Optional[int] = None

    @property
    def num_power_words(self) -> int:
        return max(1, int(round(self.lambda_w * self.vocab_size)))

    @property
    def num_power_topics(self) -> int:
        return max(1, min(self.lambda_k_abs, self.num_topics))

    def delta_weight(self, m: int) -> float:
        """Weight on the current mini-batch's unnormalized gradient Delta-phi.

        The paper's Eq. (11) writes a 1/(m-1) learning rate, but (as §3.2.1
        notes) parameter estimation is invariant to the scaling of sufficient
        statistics: plain accumulation of the *unnormalized* statistic
        (Fig. 4 line 5, weight 1.0) IS the Robbins-Monro 1/m rate on the
        normalized parameter.  'paper' therefore returns 1.0; 'power' gives
        the OVB-style decaying weight for ablations.
        """
        if self.lr_schedule == "paper":
            return 1.0
        return float((self.lr_tau0 + m) ** (-self.lr_kappa))


@dataclasses.dataclass
class MiniBatch:
    """Padded-CSR mini-batch of documents.

    word_ids: int32[D, L]   vocabulary indices (0 for padding)
    counts:   float32[D, L] word counts        (0 for padding)
    """

    word_ids: jnp.ndarray
    counts: jnp.ndarray

    @property
    def num_docs(self) -> int:
        return self.word_ids.shape[0]

    @property
    def max_len(self) -> int:
        return self.word_ids.shape[1]

    def num_tokens(self) -> jnp.ndarray:
        return jnp.sum(self.counts)

    def token_layout(self) -> "TokenLayout":
        """Flatten to the token-major [T] layout (T = D*L, row-major)."""
        D, L = self.word_ids.shape
        return TokenLayout(
            word_ids=self.word_ids.reshape(-1),
            counts=self.counts.reshape(-1, 1),
            doc_ids=jnp.repeat(jnp.arange(D, dtype=jnp.int32), L),
            num_docs=D, max_len=L)


@dataclasses.dataclass(frozen=True)
class TokenLayout:
    """Token-major view of a padded-CSR mini-batch (DESIGN.md §2).

    The [D, L] slot grid flattens row-major to T = D*L token slots, built
    ONCE per mini-batch and carried through every sweep — per-token state
    (messages mu) lives as [T, K] and per-token metadata as [T] vectors, so
    sweeps are flat streams over tokens with no [D, L, K] reshapes.

    word_ids: int32[T]    vocabulary index per token slot (0 for padding)
    counts:   float32[T,1] count per token slot            (0 for padding)
    doc_ids:  int32[T]    owning document of each slot
    """

    word_ids: jnp.ndarray
    counts: jnp.ndarray
    doc_ids: jnp.ndarray
    num_docs: int
    max_len: int

    @property
    def num_slots(self) -> int:
        return self.num_docs * self.max_len

    def to_batch_major(self, values_tk: jnp.ndarray) -> jnp.ndarray:
        """[T, K] token-major tensor back to the [D, L, K] batch view."""
        return values_tk.reshape(self.num_docs, self.max_len, -1)


@dataclasses.dataclass
class LDAState:
    """Persistent (cross-mini-batch) state of an online run.

    phi_acc[K, W]  accumulated topic-word sufficient statistics (Eq. 11)
    m              1-indexed count of mini-batches consumed so far
    """

    phi_acc: jnp.ndarray
    m: int = 0


@dataclasses.dataclass
class LDATrainState:
    """Device-carried state of the streaming POBP driver (a jax pytree).

    This is the donated carry of ``core.pobp.make_train_step``: it never
    leaves the device between mini-batches (asynchronous dispatch) and is
    the exact payload of a driver checkpoint — phi_acc, the mini-batch
    cursor and the RNG together make a crash-resumed run bit-identical to
    an uninterrupted one.

    phi_acc[W, K]  accumulated topic-word sufficient statistics (Eq. 11);
                   W is the capacity rung on a dynamic-vocabulary run —
                   ``core.pobp.grow_state`` pads it to the next rung
                   (guard rows stay exactly zero, DESIGN.md §12)
    m              int32 scalar: mini-batches consumed so far (0-indexed
                   cursor; batch m+1 is the next one, matching Eq. 11's m)
    rng            PRNG key split once per mini-batch
    """

    phi_acc: jnp.ndarray
    m: jnp.ndarray
    rng: jnp.ndarray


jax.tree_util.register_dataclass(
    LDATrainState, data_fields=("phi_acc", "m", "rng"), meta_fields=())


@dataclasses.dataclass
class SweepStats:
    """Diagnostics from one message-passing sweep."""

    mean_residual: jnp.ndarray            # sum_w r_w / sum tokens (line 26)
    comm_bytes: int                       # bytes all-reduced this sweep (analytic meter)
    selected_words: Optional[jnp.ndarray] = None   # power word indices, if selective
