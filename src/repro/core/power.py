"""Two-step power word / power topic selection (paper §3.1, Fig. 2)
and the packed gather/scatter ops that realize sparse synchronization.

Layout convention: every *sync-side* matrix is [W, K] ("wk" layout) —
residual matrix r and phi sufficient statistics alike.  Rows are words,
so power-word selection is a row gather and power-topic selection a
per-row column gather, which is exactly the paper's Fig. 2 picture.

Because selection is computed from the *synchronized* residual (Eq. 9),
every shard computes identical indices — no index traffic is needed,
only the packed [P, Pk] value tensor crosses the interconnect.  This is
the property that makes the paper's scheme XLA/TPU-native (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def select_power_words(r_w: jnp.ndarray, num_power_words: int) -> jnp.ndarray:
    """Top-`num_power_words` vocabulary indices by total residual (Eq. 10).

    The paper uses a partial sort (Fig. 4 lines 12/27); `lax.top_k` is the
    on-device equivalent.
    """
    _, idx = jax.lax.top_k(r_w, num_power_words)
    return idx.astype(jnp.int32)


def select_power_words_live(r_w: jnp.ndarray, num_power_words: int,
                            live_w: jnp.ndarray,
                            lambda_w: float) -> jnp.ndarray:
    """Live-W-masked power-word selection on a capacity-laddered run.

    ``r_w`` is [W_cap]-shaped; rows in [live_w, W_cap) are guard rows and
    must never be selected, and the *number* of power words must track
    the live vocabulary — ``P_live = max(1, floor(lambda_w * live_w))``
    — so the selection (and therefore the whole trajectory) depends only
    on the live vocabulary, never on which rung W_cap happens to be.
    ``floor`` guarantees ``P_live <= num_power_words`` for every
    ``live_w < W_cap`` (`num_power_words` rounds at capacity).

    The returned vector still has the static shape [num_power_words]:
    slots past P_live point at row ``live_w`` — the first guard row, a
    row no token maps to and whose residual/phi entries are identically
    zero — so the packed buffers they feed transmit exact zeros and every
    downstream scatter is a no-op (the W-axis analogue of the power_sweep
    kernel's guard-row token routing).
    """
    W = r_w.shape[0]
    live_w = jnp.asarray(live_w, jnp.int32)
    masked = jnp.where(jnp.arange(W) < live_w, r_w, -jnp.inf)
    _, idx = jax.lax.top_k(masked, num_power_words)
    p_live = jnp.maximum(
        1, jnp.floor(lambda_w * live_w.astype(jnp.float32))).astype(jnp.int32)
    slot = jnp.arange(num_power_words, dtype=jnp.int32)
    return jnp.where(slot < p_live, idx.astype(jnp.int32), live_w)


def select_power_topics(r_wk: jnp.ndarray, word_idx: jnp.ndarray,
                        num_power_topics: int) -> jnp.ndarray:
    """Per power word, top-`num_power_topics` topic indices (Fig. 4 lines 13/28).

    r_wk: [W, K] synchronized residual matrix (local K-shard when the topic
    axis is model-sharded — see DESIGN.md §2 on the per-shard variant).
    Returns [P, Pk] int32.
    """
    rows = jnp.take(r_wk, word_idx, axis=0)          # [P, K]
    _, idx = jax.lax.top_k(rows, num_power_topics)   # [P, Pk]
    return idx.astype(jnp.int32)


def word_to_row(word_idx: jnp.ndarray, vocab_size: int) -> jnp.ndarray:
    """Inverse map: word -> its row in the packed buffer, or -1 if not selected."""
    rows = jnp.full((vocab_size,), -1, jnp.int32)
    return rows.at[word_idx].set(jnp.arange(word_idx.shape[0], dtype=jnp.int32))


def token_power_rows(word_ids_t: jnp.ndarray, sel_w: jnp.ndarray,
                     vocab_size: int) -> jnp.ndarray:
    """Token-major power-row map: token -> packed row in [0, P), or P.

    The P "guard" value is what the power_sweep kernel and the packed
    scatters use to drop non-power tokens (DESIGN.md §2) — one [W] scatter
    plus one [T] gather per iteration, never a [T, K] mask.
    """
    P = sel_w.shape[0]
    word_row = word_to_row(sel_w, vocab_size)
    p_tok = jnp.take(word_row, word_ids_t, axis=0)
    return jnp.where(p_tok >= 0, p_tok, P).astype(jnp.int32)


def pack_rows(mat_wk: jnp.ndarray, word_idx: jnp.ndarray,
              topic_idx: jnp.ndarray) -> jnp.ndarray:
    """Gather the [P, Pk] power submatrix out of a [W, K] matrix."""
    rows = jnp.take(mat_wk, word_idx, axis=0)                    # [P, K]
    return jnp.take_along_axis(rows, topic_idx, axis=1)          # [P, Pk]


def scatter_add_rows(mat_wk: jnp.ndarray, word_idx: jnp.ndarray,
                     topic_idx: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """mat[word_idx[p], topic_idx[p, j]] += vals[p, j]  (sync of phi deltas, Eq. 4/15)."""
    return mat_wk.at[word_idx[:, None], topic_idx].add(vals)


def scatter_set_rows(mat_wk: jnp.ndarray, word_idx: jnp.ndarray,
                     topic_idx: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """mat[word_idx[p], topic_idx[p, j]] = vals[p, j]  (residual refresh, Eq. 9)."""
    return mat_wk.at[word_idx[:, None], topic_idx].set(vals)
