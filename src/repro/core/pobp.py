"""POBP — parallel online belief propagation for LDA (the paper's Fig. 4).

One code path serves every execution mode:

  - **real mesh**: the per-shard functions below run under ``shard_map``
    with documents sharded over the ``data`` (and ``pod``) mesh axes and,
    optionally, topics sharded over the ``model`` axis
    (``launch/mesh.py`` + ``launch/dryrun.py``);
  - **simulation**: the same functions run under ``jax.vmap(axis_name=...)``
    with a leading shard axis — bit-identical collectives on one CPU device
    (tests, paper-figure benchmarks);
  - **OBP** (N=1): a ``LocalReducer`` degenerates every psum to identity —
    "If N = 1, POBP reduces to the OBP algorithm" (§3.2);
  - **batch BP** (M=1): one mini-batch covering the corpus — "If M = 1,
    POBP reduces to the parallel batch BP algorithm" (§3.2).

Sync modes:
  - ``power``  — the paper's communication-efficient MPA: dense sync at
    t=1, packed [P, Pk] power-submatrix sync for t>=2 (Eq. 6);
  - ``dense``  — the classic MPA baseline (Newman et al.; Eq. 4/5):
    full phi matrix every iteration.  Implemented for the paper's
    before/after comparison.

The power inner loop is **token-major and packed** (DESIGN.md §2): the
padded-CSR [D, L] batch flattens to a [T, K] token layout once per
mini-batch, each selective iteration works on flat token streams plus the
[P, Pk] sync buffers, and the word-residual convergence signal is carried
and updated incrementally in packed form.  The selective iteration has
two algebraically identical formulations — the [T, Pk] **packed** stream
with a fold-back chain, and the one-pass [T, K] **dense-layout** masked
update (the jnp mirror of the carry-resident `power_sweep` megakernel) —
chosen per shape by ``cfg.sweep_policy`` through the measured cost model
in `core.sweep_dispatch` (DESIGN.md §2 cost table).  Either way the
packed [P, Pk] Eq. 6 sync buffers are identical, so the communication
bill never depends on the compute layout.  `selective_sweep` is kept
below as the oracle/benchmark baseline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import power as pw
from repro.core import quantize
from repro.core.residuals import (mean_residual, packed_rw_delta,
                                  token_scatter_wk)
from repro.core.sweep_dispatch import resolve_sweep_policy
from repro.core.sync import CommMeter, LocalReducer, MeshReducer, Reducer
from repro.core.types import LDAConfig, LDATrainState, MiniBatch, TokenLayout


# --------------------------------------------------------------------------
# dense (full) sweep — Fig. 4 lines 3-8 and the `dense` sync mode
# --------------------------------------------------------------------------

def dense_sweep(
    batch: MiniBatch,
    mu: jnp.ndarray,
    phi_eff_wk: jnp.ndarray,
    phi_tot: jnp.ndarray,
    cfg: LDAConfig,
    model_reducer: Reducer,
    norm_phase: str = "model_norm",
    wbeta=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One synchronous full update of all messages (Eq. 1).

    phi_eff_wk [W, Kl] is the *effective* topic-word statistic (accumulated
    prior + current-mini-batch contribution, already synchronized over data
    shards).  Kl is the local topic-shard width.  Returns (mu_new, r_wk).
    `norm_phase` labels the cross-topic-shard normalization psum — callers
    inside the inner while loop pass the per-iteration "model_norm_loop"
    so the byte meter can bill it per iteration (sync.LOOP_PHASES).
    `wbeta` overrides the W*beta smoothing mass — a capacity-laddered run
    passes the traced live_w*beta so guard rows never inflate the
    denominator (DESIGN.md §12); None keeps the static cfg value.
    """
    W = cfg.vocab_size
    wb = W * cfg.beta if wbeta is None else wbeta
    theta = jnp.einsum("dl,dlk->dk", batch.counts, mu)           # Eq. (2), local topics
    c = batch.counts[..., None]
    self_c = c * mu
    th = theta[:, None, :] - self_c + cfg.alpha
    ph = jnp.take(phi_eff_wk, batch.word_ids, axis=0) - self_c + cfg.beta
    pt = phi_tot[None, None, :] - self_c + wb
    unnorm = th * ph / pt
    norm = model_reducer.psum(jnp.sum(unnorm, axis=-1, keepdims=True),
                              norm_phase, compress=False)
    mu_new = unnorm / norm
    r_wk = token_scatter_wk(batch.word_ids, c * jnp.abs(mu_new - mu), W)
    return mu_new, r_wk


# --------------------------------------------------------------------------
# selective sweep — Fig. 4 lines 15-21 (power words x power topics only)
# --------------------------------------------------------------------------

def selective_sweep(
    batch: MiniBatch,
    mu: jnp.ndarray,
    theta: jnp.ndarray,
    phi_eff_wk: jnp.ndarray,
    phi_tot: jnp.ndarray,
    sel_w: jnp.ndarray,           # [P]      power word ids (identical on all shards)
    sel_k: jnp.ndarray,           # [P, Pk]  power topic ids per power word (local shard)
    cfg: LDAConfig,
):
    """Update messages only at (power word, power topic) coordinates.

    SEED-LAYOUT ORACLE: operates on the [D, L, K] batch-major messages and
    rewrites the full tensor per call.  The production inner loop uses the
    token-major `selective_sweep_tokens` below (numerically equivalent —
    pinned by tests/test_power_sweep.py); this version stays as the
    semantics oracle and the `benchmarks.run --only inner_loop` baseline.

    Never materializes a [W, K] intermediate: token deltas scatter straight
    into the packed [P, Pk] sync buffers (the TPU-native formulation of the
    paper's sparse communication — DESIGN.md §2).

    Returns (mu_new, theta_new, delta_phi_packed, r_packed).
    """
    D, L = batch.word_ids.shape
    P, Pk = sel_k.shape
    word_row = pw.word_to_row(sel_w, cfg.vocab_size)             # [W]
    p_tok = jnp.take(word_row, batch.word_ids, axis=0)           # [D, L] row or -1
    is_power = p_tok >= 0
    p_safe = jnp.where(is_power, p_tok, 0)
    k_tok = jnp.take(sel_k, p_safe, axis=0)                      # [D, L, Pk]

    c = batch.counts[..., None]                                  # [D, L, 1]
    mu_sel = jnp.take_along_axis(mu, k_tok, axis=-1)             # [D, L, Pk]
    sel_mass = jnp.sum(mu_sel, axis=-1, keepdims=True)           # conserved per shard
    self_c = c * mu_sel
    theta_sel = jnp.take_along_axis(
        jnp.broadcast_to(theta[:, None, :], (D, L, theta.shape[-1])), k_tok, axis=-1)
    phi_pack = pw.pack_rows(phi_eff_wk, sel_w, sel_k)            # [P, Pk]
    phi_sel = jnp.take(phi_pack, p_safe, axis=0)                 # [D, L, Pk]
    pt_sel = jnp.take(phi_tot, k_tok)                            # [D, L, Pk]

    th = theta_sel - self_c + cfg.alpha
    ph = phi_sel - self_c + cfg.beta
    pt = pt_sel - self_c + cfg.vocab_size * cfg.beta
    u = th * ph / pt
    # renormalize within the selected coordinates, conserving their old mass
    # (unselected message entries stay put => sum_k mu == 1 is invariant).
    mu_new_sel = u * sel_mass / jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new_sel = jnp.where(is_power[..., None], mu_new_sel, mu_sel)

    d_mu = mu_new_sel - mu_sel                                   # [D, L, Pk]
    mu_new = jnp.put_along_axis(mu, k_tok, mu_new_sel, axis=-1, inplace=False)

    # theta update: scatter c * d_mu into [D, Kl] at selected topic coords
    d_idx = jnp.broadcast_to(jnp.arange(D)[:, None, None], (D, L, Pk))
    theta_new = theta.at[d_idx, k_tok].add((c * d_mu))

    # packed sync buffers: scatter straight to [P, Pk] (row==P drops padding)
    p_drop = jnp.where(is_power, p_tok, P).reshape(-1)           # [D*L]
    dv = (c * d_mu).reshape(-1, Pk)
    rv = (c * jnp.abs(d_mu)).reshape(-1, Pk)
    delta_phi_packed = jnp.zeros((P, Pk), mu.dtype).at[p_drop].add(dv, mode="drop")
    r_packed = jnp.zeros((P, Pk), mu.dtype).at[p_drop].add(rv, mode="drop")
    return mu_new, theta_new, delta_phi_packed, r_packed


# --------------------------------------------------------------------------
# token-major selective sweep — the production inner-loop body
# --------------------------------------------------------------------------

def _gather_selection(layout: TokenLayout, mu_t, theta, phi_tot, sel_k,
                      p_tok, num_power):
    """Per-token [T, Pk] gathers at the selected coordinates.

    All gathers are flat token streams — no [T, K] broadcast or temporary
    is ever formed (the jaxpr contract pinned in DESIGN.md §2).
    """
    p_safe = jnp.where(p_tok < num_power, p_tok, 0)
    k_tok = jnp.take(sel_k, p_safe, axis=0)                      # [T, Pk]
    mu_sel = jnp.take_along_axis(mu_t, k_tok, axis=1)            # [T, Pk]
    theta_sel = theta[layout.doc_ids[:, None], k_tok]            # [T, Pk]
    pt_sel = jnp.take(phi_tot, k_tok)                            # [T, Pk]
    return k_tok, mu_sel, theta_sel, pt_sel


def _apply_token_update(layout: TokenLayout, mu_t, theta, k_tok, mu_sel,
                        mu_new_sel):
    """Fold the [T, Pk] update back into the carried mu_t/theta, scatter-free.

    XLA's general scatter serializes per update element (~100ns/elem on
    CPU, similarly painful per-core on TPU); at T*Pk updates per iteration
    it dominates the sweep.  Instead the delta is accumulated through a
    static compare-select chain over the Pk selected columns — Pk fused
    vectorized passes that XLA folds into a single elementwise loop over
    the donated carry — and theta's per-doc reduction contracts the same
    delta against the counts in one einsum pass over the free [D, L, K]
    reshape view (an order of magnitude faster than the reduce_sum it
    replaces — DESIGN.md §2 cost table).  The true O(T*Pk) theta refresh
    (`residuals.token_topic_segment_sum`) is what the carry-resident
    kernel realizes on the MXU; XLA's element scatter loses to the
    contraction on CPU.

    Non-power tokens have d_mu == 0 exactly, so their carry entries are
    bit-identical after the add.
    """
    d_mu = mu_new_sel - mu_sel                                   # [T, Pk]
    K = mu_t.shape[1]
    iota = jnp.arange(K, dtype=k_tok.dtype)[None, :]
    delta = jnp.zeros_like(mu_t)
    for j in range(k_tok.shape[1]):                              # static Pk
        delta = delta + jnp.where(iota == k_tok[:, j:j + 1],
                                  d_mu[:, j:j + 1], 0.0)
    mu_t_new = mu_t + delta
    counts2 = layout.counts.reshape(layout.num_docs, layout.max_len)
    theta_new = theta + jnp.einsum(
        "dl,dlk->dk", counts2,
        delta.reshape(layout.num_docs, layout.max_len, K))
    return mu_t_new, theta_new, d_mu


def _selective_sweep_packed(
    layout: TokenLayout,
    mu_t: jnp.ndarray,            # [T, Kl] token-major messages
    theta: jnp.ndarray,           # [Dl, Kl]
    phi_eff_wk: jnp.ndarray,      # [W, Kl]
    phi_tot: jnp.ndarray,         # [Kl]
    sel_w: jnp.ndarray,           # [P]
    sel_k: jnp.ndarray,           # [P, Pk]
    cfg: LDAConfig,
    wbeta=None,
):
    """Packed-stream formulation: [T, Pk] gathers + fold-back chain.

    Same math as `selective_sweep` restricted to flat [T, Pk] streams:
    mass-conserving renormalization within the selected coordinates, packed
    [P, Pk] delta/residual outputs, untouched entries bit-identical.
    `wbeta` overrides the W*beta smoothing mass (live-W runs, §12).

    Returns (mu_t_new, theta_new, delta_phi_packed, r_packed).
    """
    P, Pk = sel_k.shape
    wb = cfg.vocab_size * cfg.beta if wbeta is None else wbeta
    p_tok = pw.token_power_rows(layout.word_ids, sel_w, cfg.vocab_size)
    k_tok, mu_sel, theta_sel, pt_sel = _gather_selection(
        layout, mu_t, theta, phi_tot, sel_k, p_tok, P)
    phi_pack = pw.pack_rows(phi_eff_wk, sel_w, sel_k)            # [P, Pk]
    phi_sel = jnp.take(phi_pack, jnp.where(p_tok < P, p_tok, 0), axis=0)

    c = layout.counts
    self_c = c * mu_sel
    sel_mass = jnp.sum(mu_sel, axis=-1, keepdims=True)           # conserved
    th = theta_sel - self_c + cfg.alpha
    ph = phi_sel - self_c + cfg.beta
    pt = pt_sel - self_c + wb
    u = th * ph / pt
    mu_new_sel = u * sel_mass / jnp.maximum(
        jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new_sel = jnp.where((p_tok < P)[:, None], mu_new_sel, mu_sel)

    mu_t_new, theta_new, d_mu = _apply_token_update(
        layout, mu_t, theta, k_tok, mu_sel, mu_new_sel)
    cd, rv = c * d_mu, c * jnp.abs(d_mu)
    if layout.num_slots * P <= cfg.onehot_crossover:
        # one-hot contraction (the jnp mirror of the power_sweep kernel's
        # packed accumulation): tokens with p_tok == P match no column and
        # drop out.  The row scatter below covers shapes past the
        # configured crossover, where [T, P] MACs stop paying for
        # themselves (cfg.onehot_crossover, consumed by the dispatch cost
        # model in core/sweep_dispatch).
        onehot_p = (p_tok[:, None] ==
                    jnp.arange(P, dtype=p_tok.dtype)[None, :]).astype(mu_t.dtype)
        dims = (((0,), (0,)), ((), ()))
        delta_phi_packed = jax.lax.dot_general(onehot_p, cd, dims)
        r_packed = jax.lax.dot_general(onehot_p, rv, dims)
    else:
        # p_tok == P for non-power tokens -> dropped by the bounds check
        delta_phi_packed = jnp.zeros((P, Pk), mu_t.dtype).at[p_tok].add(
            cd, mode="drop")
        r_packed = jnp.zeros((P, Pk), mu_t.dtype).at[p_tok].add(
            rv, mode="drop")
    return mu_t_new, theta_new, delta_phi_packed, r_packed


def _selective_sweep_dense_layout(
    layout: TokenLayout, mu_t, theta, phi_eff_wk, phi_tot, sel_w, sel_k,
    cfg: LDAConfig, wbeta=None,
):
    """One-pass dense-layout formulation: masked [T, K] update, no chain.

    The jnp mirror of the carry-resident `power_sweep_carry` megakernel:
    the [T, K] carry is read and written exactly once per iteration,
    whatever Pk is.  A [P+1, K] *signed-phi* row table carries both the
    packed phi values and the selection in one gather — selected
    coordinates hold phi >= 0, everything else (and the whole p == P
    guard row) holds -1 — so the update

        u      = (theta - c mu + alpha)(phi - c mu + beta)
                 / (phi_tot - c mu + W beta)        where selected, else 0
        mu'    = u * mass / sum u                    (mass = selected mass)

    is a handful of fused [T, K] passes with u *exactly* zero off the
    power submatrix and untouched entries bit-identical (`where`, not
    arithmetic masking).  theta comes back through one counts contraction
    over the updated carry (theta == einsum(c, mu) is a loop invariant),
    and the packed [P, Pk] delta/residual buffers accumulate through a
    single complex-merged row scatter (delta in the real lane, |delta| in
    the imaginary lane — halves the serialized scatter elements) followed
    by an O(P*Pk) column pack.  Same contract and packed outputs as
    `_selective_sweep_packed`.
    """
    P, Pk = sel_k.shape
    Kl = mu_t.shape[1]
    D, L = layout.num_docs, layout.max_len
    wb = cfg.vocab_size * cfg.beta if wbeta is None else wbeta
    p_tok3 = pw.token_power_rows(layout.word_ids, sel_w,
                                 cfg.vocab_size).reshape(D, L)
    mask = jnp.zeros((P + 1, Kl), bool).at[
        jnp.arange(P)[:, None], sel_k].set(True, mode="drop")
    phi_rows = jnp.concatenate(
        [jnp.take(phi_eff_wk, sel_w, axis=0),
         jnp.zeros((1, Kl), mu_t.dtype)], axis=0)                # [P+1, Kl]
    # sign carries the selection: selected coords hold phi (clamped at 0 —
    # incremental scatter_add refreshes can take a near-zero statistic a
    # few ulp negative, which must not flip the encoding), others -1.
    sphi = jnp.where(mask, jnp.maximum(phi_rows, 0.0), -1.0)
    sphi_tok = jnp.take(sphi, p_tok3, axis=0)                    # [D, L, Kl]
    selp = sphi_tok >= 0.0

    mu3 = mu_t.reshape(D, L, Kl)
    counts2 = layout.counts.reshape(D, L)
    c3 = counts2[..., None]
    self_c = c3 * mu3
    th = theta[:, None, :] - self_c + cfg.alpha
    ph = sphi_tok - self_c + cfg.beta
    pt = phi_tot[None, None, :] - self_c + wb
    u = jnp.where(selp, th * ph / pt, 0.0)
    mass = jnp.sum(jnp.where(selp, mu3, 0.0), -1, keepdims=True)
    denom = jnp.maximum(jnp.sum(u, -1, keepdims=True), 1e-30)
    mu_new = jnp.where(selp, u * (mass / denom), mu3)
    theta_new = jnp.einsum("dl,dlk->dk", counts2, mu_new)
    cd = c3 * (mu_new - mu3)
    zc = jax.lax.complex(cd, jnp.abs(cd)).reshape(layout.num_slots, Kl)
    rows = jnp.zeros((P + 1, Kl), jnp.complex64).at[
        p_tok3.reshape(-1)].add(zc)
    d_pack = jnp.take_along_axis(jnp.real(rows[:P]), sel_k, axis=1)
    r_pack = jnp.take_along_axis(jnp.imag(rows[:P]), sel_k, axis=1)
    return (mu_new.reshape(layout.num_slots, Kl),
            theta_new, d_pack.astype(mu_t.dtype), r_pack.astype(mu_t.dtype))


def selective_sweep_tokens(
    layout: TokenLayout,
    mu_t: jnp.ndarray,            # [T, Kl] token-major messages
    theta: jnp.ndarray,           # [Dl, Kl]
    phi_eff_wk: jnp.ndarray,      # [W, Kl]
    phi_tot: jnp.ndarray,         # [Kl]
    sel_w: jnp.ndarray,           # [P]
    sel_k: jnp.ndarray,           # [P, Pk]
    cfg: LDAConfig,
    wbeta=None,
):
    """Token-major selective sweep (jnp production path, DESIGN.md §2).

    Dispatches between the packed-stream and dense-layout formulations per
    (T, K, Pk, P) through ``cfg.sweep_policy`` (resolved at trace time —
    static per compiled shape, never retraces across mini-batches).  Both
    produce identical packed [P, Pk] sync buffers and trajectories within
    float associativity; `theta` must be the doc-topic statistic of the
    incoming `mu_t` (a loop invariant of every caller).
    `wbeta` overrides the W*beta smoothing mass (live-W runs, §12).

    Returns (mu_t_new, theta_new, delta_phi_packed, r_packed).
    """
    P, Pk = sel_k.shape
    policy = resolve_sweep_policy(cfg, layout.num_slots, mu_t.shape[1],
                                  Pk, P, impl="jnp",
                                  n_docs=theta.shape[0])
    # 'kblocked' resolves to dense_layout on the jnp impl (same math; XLA
    # has no VMEM budget), so only two formulations exist here
    fn = (_selective_sweep_packed if policy == "packed"
          else _selective_sweep_dense_layout)
    return fn(layout, mu_t, theta, phi_eff_wk, phi_tot, sel_w, sel_k, cfg,
              wbeta=wbeta)


def _selective_sweep_carry_pallas(
    layout: TokenLayout, mu_t, theta, phi_eff_wk, phi_tot, sel_w, sel_k,
    cfg: LDAConfig, wbeta=None, kblocked: bool = False,
):
    """Carry-resident megakernel iteration (kernels/power_sweep).

    One grid pass over token tiles: the [TT, K] mu carry tile loads into
    VMEM once, the packed-phi/mask row tables and theta gather on the MXU
    (one-hot contractions), the selective update + renorm + fold-back
    write the carry back once, and the per-doc theta delta plus the
    [P1, K] delta/residual rows accumulate in VMEM across the whole grid
    — one HBM read and one write of the carry per iteration.  The small
    O(P*Pk) column pack happens outside the kernel; the packed [P, Pk]
    sync payload is identical to the jnp formulations.
    """
    from repro.kernels.power_sweep.ops import power_sweep_carry

    P, Pk = sel_k.shape
    Kl = mu_t.shape[1]
    p_tok = pw.token_power_rows(layout.word_ids, sel_w, cfg.vocab_size)
    mask = jnp.zeros((P + 1, Kl), jnp.float32).at[
        jnp.arange(P)[:, None], sel_k].set(1.0, mode="drop")
    phi_rows = jnp.concatenate(
        [jnp.take(phi_eff_wk, sel_w, axis=0), jnp.zeros((1, Kl))], axis=0)
    if wbeta is None:
        pt_arg, wb_static = phi_tot, cfg.vocab_size * cfg.beta
    else:
        # traced live-W smoothing folds into the phi_tot argument with the
        # kernel's static wbeta pinned at 1.0 (same trick as core/infer)
        pt_arg, wb_static = phi_tot + (wbeta - 1.0), 1.0
    mu_new, theta_delta, d_rows, r_rows, _ = power_sweep_carry(
        p_tok, layout.doc_ids, layout.counts, mu_t, theta, pt_arg,
        phi_rows, mask, alpha=cfg.alpha, beta=cfg.beta, wbeta=wb_static,
        update_phi=True, kblocked=kblocked,
        vmem_budget_bytes=cfg.vmem_budget_bytes)
    d_pack = jnp.take_along_axis(d_rows[:P], sel_k, axis=1)
    r_pack = jnp.take_along_axis(r_rows[:P], sel_k, axis=1)
    return mu_new, theta + theta_delta, d_pack, r_pack


def selective_sweep_tokens_pallas(
    layout: TokenLayout, mu_t, theta, phi_eff_wk, phi_tot, sel_w, sel_k,
    cfg: LDAConfig, wbeta=None,
):
    """Fused-kernel selective sweep, policy-dispatched like the jnp path.

    ``dense_layout`` (the 'auto' resolution on the pallas backend while
    the full-K carry fits VMEM) runs the carry-resident
    `power_sweep_carry` megakernel — one HBM read + one write of the
    [T, K] carry per iteration.  ``kblocked`` (auto's resolution past the
    VMEM-fit boundary, DESIGN.md §13) runs the same math as the K-blocked
    two-pass kernel.  ``packed`` keeps the
    [T, Pk]-stream pipeline: Pallas power_pack gather + the power_sweep
    kernel + the jnp fold-back chain.  Same contract either way.  A
    traced `wbeta` (live-W runs) folds into the pre-gathered pt argument
    with the kernel's static wbeta pinned at 1.0 — the kernels need no
    new code, and the unit offset keeps the ops-layer lane padding away
    from 0/0 (same trick as core/infer).
    """
    P, Pk = sel_k.shape
    policy = resolve_sweep_policy(cfg, layout.num_slots, mu_t.shape[1],
                                  Pk, P, impl="pallas",
                                  n_docs=theta.shape[0])
    if policy in ("dense_layout", "kblocked"):
        return _selective_sweep_carry_pallas(
            layout, mu_t, theta, phi_eff_wk, phi_tot, sel_w, sel_k, cfg,
            wbeta=wbeta, kblocked=(policy == "kblocked"))

    from repro.kernels.power_pack import ops as pp_ops
    from repro.kernels.power_sweep.ops import power_sweep

    p_tok = pw.token_power_rows(layout.word_ids, sel_w, cfg.vocab_size)
    k_tok, mu_sel, theta_sel, pt_sel = _gather_selection(
        layout, mu_t, theta, phi_tot, sel_k, p_tok, P)
    phi_pack = pp_ops.pack_rows(phi_eff_wk, sel_w, sel_k)        # Pallas
    if wbeta is None:
        pt_arg, wb_static = pt_sel, cfg.vocab_size * cfg.beta
    else:
        pt_arg, wb_static = pt_sel + (wbeta - 1.0), 1.0
    mu_new_sel, delta_phi_packed, r_packed = power_sweep(
        p_tok, layout.counts, mu_sel, theta_sel, pt_arg, phi_pack,
        alpha=cfg.alpha, beta=cfg.beta, wbeta=wb_static)
    mu_t_new, theta_new, _ = _apply_token_update(
        layout, mu_t, theta, k_tok, mu_sel, mu_new_sel)
    return mu_t_new, theta_new, delta_phi_packed, r_packed


# --------------------------------------------------------------------------
# the per-shard mini-batch routine (Fig. 4 body, one m)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MinibatchResult:
    phi_acc_new: jnp.ndarray       # [W, Kl] accumulated statistic after this batch
    iters: jnp.ndarray             # iterations actually run (incl. the dense one)
    mean_r: jnp.ndarray            # final mean residual (line 26 quantity)
    mu: jnp.ndarray                # final messages (for theta/perplexity)
    theta: jnp.ndarray             # final doc-topic statistics [Dl, Kl]


def pobp_minibatch(
    batch: MiniBatch,
    phi_acc_wk: jnp.ndarray,
    key: jax.Array,
    total_tokens: jnp.ndarray,
    delta_weight: jnp.ndarray,
    cfg: LDAConfig,
    data_reducer: Reducer,
    model_reducer: Optional[Reducer] = None,
    sync_mode: str = "power",
    live_w=None,
    decay=None,
) -> MinibatchResult:
    """Run one mini-batch to convergence on this shard (all Fig. 4 lines).

    `batch` is this shard's document slice; `phi_acc_wk` [W, Kl] is the
    synchronized accumulated statistic (identical on all data shards);
    `total_tokens` is the *global* mini-batch token count (psum'd once by the
    caller); `delta_weight` scales the accumulated gradient (Eq. 11).

    `live_w` (a traced int32 scalar) switches the W axis to capacity-ladder
    semantics (DESIGN.md §12): phi_acc_wk is [W_cap, Kl] with rows in
    [live_w, W_cap) as guard rows — every batch word id is < live_w, the
    W*beta smoothing uses live_w, power selection masks guard rows and
    caps the power-word count at the live lambda_w fraction.  Because all
    of this depends only on live_w (never on the rung), a run that grew
    across rungs and a fresh run allocated at the final rung compute
    identical trajectories.  None keeps the static fixed-W behavior.

    `decay` (a traced f32 scalar, or None) is the Robbins-Monro retention
    factor 1 - rho_m on the historical statistic (DESIGN.md §14): the
    Eq. 11 fold-back becomes ``decay * phi_acc + delta_weight * Delta``,
    so stale mass fades multiplicatively while the current batch enters
    at full weight.  None (decay_kappa == 0) keeps the exact
    plain-accumulation expression — bit-exact with the pre-lifecycle
    trajectory.  The decay pass reads + rewrites the full [W, Kl]
    statistic once per mini-batch, billed to the meter's ``decay`` phase.
    """
    model_reducer = model_reducer or LocalReducer(meter=data_reducer.meter)
    W = cfg.vocab_size
    Kl = phi_acc_wk.shape[1]
    P, Pk = cfg.num_power_words, min(cfg.num_power_topics, Kl)
    wbeta = (None if live_w is None
             else jnp.asarray(live_w, jnp.float32) * cfg.beta)
    layout = batch.token_layout()    # persistent token-major view (§2)
    # compressed-accumulator runs (DESIGN.md §13) ship every phi/residual
    # statistic sync at the storage width: the Eq. 5/6 payload bytes halve
    # and the wire round-trip matches the precision the statistic is kept
    # at anyway.  None leaves the cfg.sync_dtype behavior untouched.
    phi_wire = (jnp.bfloat16 if cfg.phi_acc_dtype == "bfloat16" else None)

    # ---- lines 3-8: random init, local stats, first dense update ----
    # cfg.init_pad_len: draw the random field at a fixed padded length and
    # slice, so phi_acc is invariant to the L bucket this batch landed in
    # (shape-bucketed streaming; padding slots have zero counts).
    D, L = batch.word_ids.shape
    Lpad = L if cfg.init_pad_len is None else max(cfg.init_pad_len, L)
    u0 = jax.random.uniform(key, (D, Lpad, Kl), minval=0.01, maxval=1.0)[:, :L]
    mu0 = u0 / model_reducer.psum(jnp.sum(u0, -1, keepdims=True), "model_norm",
                                  compress=False)
    delta_local0 = token_scatter_wk(batch.word_ids, batch.counts[..., None] * mu0, W)
    phi_eff = phi_acc_wk + delta_local0          # local phi^0 (Fig. 4 line 5)
    phi_tot = jnp.sum(phi_eff, axis=0)
    if cfg.impl == "pallas" and isinstance(model_reducer, LocalReducer):
        # fused Pallas kernel (normalization in-kernel => K must be unsharded)
        from repro.kernels.bp_update.ops import dense_sweep_pallas
        mu1, r_wk_local = dense_sweep_pallas(batch, mu0, phi_eff, phi_tot, cfg,
                                             layout, wbeta=wbeta)
    else:
        mu1, r_wk_local = dense_sweep(batch, mu0, phi_eff, phi_tot, cfg,
                                      model_reducer, wbeta=wbeta)

    # ---- lines 9-10: dense synchronization of phi and r ----
    delta_glob = data_reducer.psum(
        token_scatter_wk(batch.word_ids, batch.counts[..., None] * mu1, W),
        "dense", w_rows=W, dtype=phi_wire)
    phi_eff = phi_acc_wk + delta_glob
    phi_tot = jnp.sum(phi_eff, axis=0)
    r_glob = data_reducer.psum(r_wk_local, "dense", w_rows=W,
                               dtype=phi_wire)
    theta = jnp.einsum("dl,dlk->dk", batch.counts, mu1)
    r_w = model_reducer.psum(jnp.sum(r_glob, axis=1), "model_rw",
                             compress=False, w_rows=W)

    if sync_mode == "power":
        # Token-major persistent inner loop (DESIGN.md §2): messages are
        # carried as [T, Kl], every iteration touches only [T, Pk] token
        # streams + [P, Pk] packed buffers, and the r_w convergence signal
        # updates incrementally from the packed residual refresh instead of
        # an O(W*K) row reduction per iteration.
        if cfg.impl == "pallas":
            sweep_fn = selective_sweep_tokens_pallas
            from repro.kernels.power_pack import ops as pp_ops
            phi_scatter = pp_ops.scatter_add_rows
        else:
            sweep_fn = selective_sweep_tokens
            phi_scatter = pw.scatter_add_rows
        carry0 = (mu1.reshape(layout.num_slots, Kl), theta, phi_eff, phi_tot,
                  r_glob, r_w, jnp.asarray(1, jnp.int32))

        def cond(carry):
            *_, r_w_c, t = carry
            return jnp.logical_and(t < cfg.inner_iters,
                                   mean_residual(r_w_c, total_tokens) > cfg.residual_tol)

        def body(carry):
            mu_t, theta, phi_eff, phi_tot, r_glob, r_w_c, t = carry
            # lines 12-13 / 27-28: two-step power selection (identical on
            # every data shard -- computed from synchronized residuals).
            # Live-W runs mask guard rows out and cap the selection at the
            # live lambda_w fraction; dead slots point at the first guard
            # row, whose packed values are exact zeros (§12).
            if live_w is None:
                sel_w = pw.select_power_words(r_w_c, P)
            else:
                sel_w = pw.select_power_words_live(r_w_c, P, live_w,
                                                   cfg.lambda_w)
            sel_k = pw.select_power_topics(r_glob, sel_w, Pk)
            mu_t, theta, d_phi_pack, r_pack = sweep_fn(
                layout, mu_t, theta, phi_eff, phi_tot, sel_w, sel_k, cfg,
                wbeta=wbeta)
            # lines 23-24: communicate only the power submatrices (the [P,
            # Pk] buffers scale with W through P = lambda_w*W: live-W
            # accounting bills only the live fraction of their rows)
            d_phi_pack = data_reducer.psum(d_phi_pack, "power", w_rows=W,
                                           dtype=phi_wire)
            r_pack = data_reducer.psum(r_pack, "power", w_rows=W,
                                       dtype=phi_wire)
            # packed-carry refresh: O(P*Pk) state updates, Eq. 9
            rw_delta = packed_rw_delta(r_glob, sel_w, sel_k, r_pack)
            phi_eff = phi_scatter(phi_eff, sel_w, sel_k, d_phi_pack)
            phi_tot = phi_tot + jnp.zeros_like(phi_tot).at[sel_k].add(d_phi_pack)
            r_glob = pw.scatter_set_rows(r_glob, sel_w, sel_k, r_pack)
            rw_delta = model_reducer.psum(rw_delta, "model_rw_loop",
                                          compress=False, w_rows=W)
            r_w_c = r_w_c.at[sel_w].add(rw_delta)
            return (mu_t, theta, phi_eff, phi_tot, r_glob, r_w_c, t + 1)

        mu_t, theta, phi_eff, phi_tot, r_glob, r_w, t = jax.lax.while_loop(
            cond, body, carry0)
        mu = layout.to_batch_major(mu_t)
    elif sync_mode == "dense":
        carry0 = (mu1, theta, phi_eff, phi_tot, r_w, jnp.asarray(1, jnp.int32))

        def cond(carry):
            *_, r_w_c, t = carry
            return jnp.logical_and(t < cfg.inner_iters,
                                   mean_residual(r_w_c, total_tokens) > cfg.residual_tol)

        def body(carry):
            mu, theta, phi_eff, phi_tot, _, t = carry
            mu, r_wk = dense_sweep(batch, mu, phi_eff, phi_tot, cfg,
                                   model_reducer, norm_phase="model_norm_loop",
                                   wbeta=wbeta)
            delta = data_reducer.psum(
                token_scatter_wk(batch.word_ids, batch.counts[..., None] * mu, W),
                "dense_loop", w_rows=W, dtype=phi_wire)
            phi_eff = phi_acc_wk + delta
            phi_tot = jnp.sum(phi_eff, axis=0)
            theta = jnp.einsum("dl,dlk->dk", batch.counts, mu)
            r_w_c = model_reducer.psum(
                jnp.sum(data_reducer.psum(r_wk, "dense_loop", w_rows=W,
                                          dtype=phi_wire),
                        axis=1),
                "model_rw_loop", compress=False, w_rows=W)
            return (mu, theta, phi_eff, phi_tot, r_w_c, t + 1)

        mu, theta, phi_eff, phi_tot, r_w, t = jax.lax.while_loop(cond, body, carry0)
    else:
        raise ValueError(f"unknown sync_mode: {sync_mode}")

    # ---- Eq. (11): accumulate this batch's synchronized gradient ----
    if decay is None:
        phi_acc_new = phi_acc_wk + delta_weight * (phi_eff - phi_acc_wk)
    else:
        # RM decay (§14): retain (1 - rho_m) of the historical statistic.
        # phi_eff - phi_acc_wk is exactly this batch's synchronized Delta,
        # so the expression below is the decayed Eq. 11 and reduces to the
        # branch above at decay == 1.  The full-statistic touch is billed
        # once per mini-batch (not a psum — decay is shard-local and
        # identical everywhere, but it is a real [W, Kl] HBM pass).
        data_reducer.bill(phi_acc_wk, "decay", w_rows=W)
        phi_acc_new = decay * phi_acc_wk + delta_weight * (phi_eff - phi_acc_wk)
    return MinibatchResult(phi_acc_new=phi_acc_new, iters=t,
                           mean_r=mean_residual(r_w, total_tokens),
                           mu=mu, theta=theta)


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------
#
# Every execution mode funnels through ONE per-shard body (pobp_shard_body):
#   - `make_train_step`        jitted, donated-carry production step
#                              (vmap N-shard simulation; the streaming
#                              driver `launch.lda_train` and `run_stream`)
#   - `make_sim_minibatch_fn`  the stateless single-mini-batch entry used
#                              by tests and paper-figure benchmarks
#   - `make_mesh_shard_fn`     the shard_map body for the production mesh
#                              (launch.dryrun's compile-only cell and
#                              launch.lda_train's --backend shard_map)


def pobp_shard_body(word_ids, counts, phi_acc, key, delta_weight,
                    cfg: LDAConfig, data_reducer: Reducer,
                    model_reducer: Optional[Reducer] = None,
                    sync_mode: str = "power", live_w=None, decay=None):
    """One shard's complete mini-batch routine (Fig. 4, one m).

    `word_ids`/`counts` are THIS shard's [Dl, L] slice; `phi_acc` is the
    synchronized accumulated statistic.  The global token count is psum'd
    here ("tokens" phase), so callers never pre-reduce anything.
    `live_w` (traced) enables capacity-ladder W semantics (§12); `decay`
    (traced, or None) the RM retention on the fold-back (§14).
    Returns (phi_acc_new, iters, mean_r, mu, theta).
    """
    batch = MiniBatch(word_ids=word_ids, counts=counts)
    total = data_reducer.psum(jnp.sum(counts), "tokens", compress=False)
    res = pobp_minibatch(batch, phi_acc, key, total, delta_weight, cfg,
                         data_reducer, model_reducer, sync_mode=sync_mode,
                         live_w=live_w, decay=decay)
    return res.phi_acc_new, res.iters, res.mean_r, res.mu, res.theta


# fold_in tag deriving the stochastic-rounding key from the per-batch key
# without consuming the split stream (float32 runs stay bit-identical)
_SR_FOLD = 0x5F0C4


def _delta_weight(cfg: LDAConfig, m):
    """Traced Eq. 11 weight for the (1-indexed, possibly traced) batch m."""
    if cfg.lr_schedule == "paper":
        return jnp.float32(1.0)
    return (cfg.lr_tau0 + m.astype(jnp.float32)) ** (-cfg.lr_kappa)


def _decay_factor(cfg: LDAConfig, m):
    """Traced RM retention 1 - rho_m for batch m, or None when decay is off.

    rho_m = (decay_tau0 + m)^(-decay_kappa) is the classic Robbins-Monro
    step size (Hoffman-style online VB, DESIGN.md §14): the historical
    statistic keeps a (1 - rho_m) fraction per batch, so an untouched row
    decays multiplicatively toward zero while rho_m -> 0 makes the memory
    horizon grow as the model matures.  decay_kappa == 0 returns None —
    a *static* bypass, so the fold-back runs the identical expression the
    plain-accumulation path always ran (bit-exact, not merely close).
    """
    if not cfg.decay_kappa:
        return None
    rho = (jnp.float32(cfg.decay_tau0) + m.astype(jnp.float32)
           ) ** jnp.float32(-cfg.decay_kappa)
    return jnp.float32(1.0) - rho


def init_train_state(cfg: LDAConfig, seed: int = 0) -> LDATrainState:
    """Cold-start carry for `make_train_step` (phi_acc = 0, m = 0).

    phi_acc is allocated at ``cfg.phi_acc_dtype`` (DESIGN.md §13): the
    accumulate still runs in f32 — the carry only STORES narrow."""
    return LDATrainState(
        phi_acc=jnp.zeros((cfg.vocab_size, cfg.num_topics),
                          quantize.phi_acc_dtype(cfg)),
        m=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(seed))


def grow_state(state: LDATrainState, new_vocab_cap: int) -> LDATrainState:
    """Grow-only capacity resize — `core.lifecycle.resize_state` without a
    fence (DESIGN.md §14 owns the full grow/shrink lifecycle; shrinking
    here still raises because no live_w fence is provided)."""
    from repro.core.lifecycle import resize_state
    return resize_state(state, new_vocab_cap)


def make_train_step(cfg: LDAConfig, num_shards: int = 1,
                    sync_mode: str = "power", sync_dtype=jnp.float32,
                    donate: bool = True, reducer: Optional[Reducer] = None):
    """The production streaming step: one jitted, donated-carry POBP batch.

    Returns (step, meter) with ``step(state, word_ids, counts) ->
    (new_state, diag)``.  `word_ids`/`counts` are [Dl, L] (num_shards == 1)
    or [N, Dl, L] stacked; `state` is an `LDATrainState` whose buffers are
    donated (constant memory over an unbounded stream — §3.2 / Table 5).
    `diag` = {iters, mean_r, theta} stays on device: the caller decides
    when to pay a host sync (asynchronous dispatch — the driver fetches
    every --log-every batches, never per batch).

    The step recompiles once per distinct (Dl, L) input shape; feed it
    through `repro.data.batching.bucketed_minibatch_stream` to bound the
    compile count.  Compiles so far: ``step._cache_size()``.

    ``step`` also accepts an optional trailing ``live_w`` (int32 scalar):
    the live vocabulary size of a capacity-laddered run whose cfg
    ``vocab_size`` is the current rung.  live_w is *traced*, so vocabulary
    growth within a rung never recompiles — only crossing a rung does
    (``grow_state`` + a fresh step; compiles <= #rungs x #buckets).

    ``reducer`` injects an alternative sync provider for the SAME shard
    body — `launch.lda_train --backend ps` passes a ``sync.PSReducer``
    (push/pull wire billing; identical in-step math) while the allreduce
    backends keep the default Local/Mesh reducer.  Injected reducers over
    a multi-shard body must reduce over axis name ``"shards"``.
    """
    if reducer is not None:
        meter = reducer.meter
    else:
        meter = CommMeter()
        if num_shards == 1:
            reducer = LocalReducer(meter=meter, sync_dtype=sync_dtype)
        else:
            reducer = MeshReducer("shards", meter=meter, sync_dtype=sync_dtype)

    storage = quantize.phi_acc_dtype(cfg)

    def body(wid, cnt, phi_acc, key, weight, live_w, decay):
        return pobp_shard_body(wid, cnt, phi_acc, key, weight, cfg, reducer,
                               sync_mode=sync_mode, live_w=live_w,
                               decay=decay)

    def step(state: LDATrainState, word_ids, counts, live_w=None):
        rng, sub = jax.random.split(state.rng)
        weight = _delta_weight(cfg, state.m + 1)
        decay = _decay_factor(cfg, state.m + 1)
        if num_shards == 1:
            phi, iters, mean_r, _mu, theta = body(word_ids, counts,
                                                  state.phi_acc, sub, weight,
                                                  live_w, decay)
        else:
            keys = jax.random.split(sub, num_shards)
            phi, iters, mean_r, _mu, theta = jax.vmap(
                body, in_axes=(0, 0, None, 0, None, None, None),
                axis_name="shards")(
                    word_ids, counts, state.phi_acc, keys, weight, live_w,
                    decay)
            # shard-identical by construction: carry shard 0's copy
            phi, iters, mean_r = phi[0], iters[0], mean_r[0]
        if storage != jnp.float32:
            # fold the f32 accumulate back into the narrow carry with
            # stochastic rounding (core/quantize).  The SR key derives by
            # fold_in so the per-batch split stream above stays
            # bit-identical to a float32 run's.
            phi = quantize.stochastic_round(
                phi, storage, jax.random.fold_in(sub, _SR_FOLD))
        new_state = LDATrainState(phi_acc=phi, m=state.m + 1, rng=rng)
        return new_state, dict(iters=iters, mean_r=mean_r, theta=theta)

    return jax.jit(step, donate_argnums=(0,) if donate else ()), meter


def make_sim_minibatch_fn(cfg: LDAConfig, num_shards: int, sync_mode: str = "power",
                          sync_dtype=jnp.float32):
    """N-shard simulation on one device: vmap over a leading shard axis with a
    named axis so lax.psum is bit-identical to the mesh execution.

    Returns (jitted_fn, meter).  jitted_fn(word_ids[N,Dl,L], counts[N,Dl,L],
    phi_acc[W,Kl], key, delta_weight) -> MinibatchResult with leading N axis
    on mu/theta and shard-identical phi_acc_new (checked in tests).
    """
    meter = CommMeter()
    if num_shards == 1:
        reducer: Reducer = LocalReducer(meter=meter, sync_dtype=sync_dtype)
    else:
        reducer = MeshReducer("shards", meter=meter, sync_dtype=sync_dtype)

    def per_shard(word_ids, counts, phi_acc, key, delta_weight):
        return pobp_shard_body(word_ids, counts, phi_acc, key, delta_weight,
                               cfg, reducer, sync_mode=sync_mode)

    def fn(word_ids, counts, phi_acc, key, delta_weight):
        if num_shards == 1:
            return per_shard(word_ids, counts, phi_acc, key, delta_weight)
        keys = jax.random.split(key, num_shards)
        return jax.vmap(per_shard, in_axes=(0, 0, None, 0, None),
                        axis_name="shards")(word_ids, counts, phi_acc, keys,
                                            delta_weight)

    return jax.jit(fn), meter


def make_mesh_shard_fn(cfg: LDAConfig, mesh_axis_names, sync_mode: str = "power",
                       sync_dtype=jnp.float32, meter: Optional[CommMeter] = None,
                       with_decay: bool = False, reducer_factory=None):
    """Per-shard POBP body for ``shard_map`` on a production mesh: documents
    sharded over the data (and pod) axes, topics over the 'model' axis.

    Shared by ``launch.dryrun.run_lda_cell`` (compile-only HLO analysis) and
    ``launch.lda_train`` (--backend shard_map), so the production cell and
    the streaming driver cannot fork.  Returns (local_fn, meter) with
    ``local_fn(wid, cnt, phi_acc, key, delta_weight) ->
    (phi_acc_new, iters, mean_r)``; ``with_decay=True`` (a decayed run,
    cfg.decay_kappa > 0) appends a trailing RM-retention scalar argument —
    the arity is static so the undecayed program stays byte-identical.

    ``reducer_factory(axis_name, meter, sync_dtype) -> Reducer`` replaces
    the default ``MeshReducer`` for the DATA reducer (the vocabulary-row
    sync the parameter-server mode reroutes); the model-axis reducer is
    always a plain mesh psum — topic shards of one worker live on one
    host and never cross the PS wire.
    """
    dp = tuple(a for a in mesh_axis_names if a in ("pod", "data"))
    meter = meter or CommMeter()

    def run(wid, cnt, phi_acc, key, delta_weight, decay):
        if reducer_factory is not None:
            data_red = reducer_factory(dp, meter, sync_dtype)
        else:
            data_red = MeshReducer(dp, meter=meter, sync_dtype=sync_dtype)
        model_red = MeshReducer("model", meter=meter, sync_dtype=sync_dtype)
        phi, iters, mean_r, _mu, _theta = pobp_shard_body(
            wid, cnt, phi_acc, key, delta_weight, cfg, data_red, model_red,
            sync_mode=sync_mode, decay=decay)
        return phi, iters, mean_r

    if with_decay:
        local = run
    else:
        def local(wid, cnt, phi_acc, key, delta_weight):
            return run(wid, cnt, phi_acc, key, delta_weight, None)

    return local, meter


def shard_map_minibatch_fn(cfg: LDAConfig, mesh, sync_mode: str = "power",
                           sync_dtype=jnp.float32,
                           meter: Optional[CommMeter] = None,
                           with_decay: bool = False):
    """`make_mesh_shard_fn` wrapped in shard_map on `mesh`, partition specs
    included: fn(wid[D, L], cnt[D, L], phi_acc[W, K], key, delta_weight)
    -> (phi_acc_new, iters, mean_r) with documents split over data/pod and
    topics over 'model'.  The ONE wrapper both `launch.dryrun.run_lda_cell`
    (lower/compile) and `launch.lda_train` (execute) use — specs cannot
    fork between the compile-only cell and the production driver.
    ``with_decay=True`` appends the replicated RM-retention scalar (§14).
    Returns (fn, meter).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    local, meter = make_mesh_shard_fn(cfg, mesh.axis_names, sync_mode,
                                      sync_dtype, meter,
                                      with_decay=with_decay)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    in_specs = (P(dp, None), P(dp, None), P(None, "model"), P(), P())
    if with_decay:
        in_specs += (P(),)
    fn = shard_map(local, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=(P(None, "model"), P(), P()),
                   check_rep=False)
    return fn, meter


class DiagBuffer:
    """Buffers per-batch device scalars and materializes them to host
    values in blocks: dispatch stays asynchronous (a flushed value is many
    batches old, its compute long finished) while the set of live device
    buffers stays bounded on an unbounded stream.  Shared by `run_stream`
    and `launch.lda_train`."""

    def __init__(self, block: int = 64):
        self.block = max(int(block), 1)
        self._pending: list = []
        self._done: list = []

    def append(self, *vals) -> None:
        self._pending.append(vals)
        if len(self._pending) >= self.block:
            self.flush()

    def flush(self) -> None:
        import numpy as np
        self._done.extend(
            tuple(np.asarray(v).reshape(-1)[0] for v in vals)
            for vals in self._pending)
        self._pending.clear()

    def rows(self) -> list:
        self.flush()
        return self._done


def run_stream(
    stream,
    cfg: LDAConfig,
    num_shards: int = 1,
    sync_mode: str = "power",
    seed: int = 0,
    sync_dtype=jnp.float32,
    callback=None,
    state: Optional[LDATrainState] = None,
    donate: bool = True,
):
    """OBP/POBP outer loop over a mini-batch stream (Fig. 4 outer `for m`),
    built on the donated-carry `make_train_step`.

    `stream` yields either MiniBatch (N=1) or [N, Dl, L] stacked arrays.
    Dispatch is asynchronous: nothing forces a host sync per mini-batch —
    history diagnostics are materialized once, after the loop.  `callback`
    (if given) receives ``(m, phi_acc, rec, theta)`` with *device* scalars
    in `rec`; convert them only as often as a sync is affordable.  Because
    the carry is donated, the phi_acc handed to the callback is only valid
    until the next step runs — ``np.asarray`` it if it must outlive that
    (checkpointing does exactly this).  Pass `state` to continue a run.
    Returns (phi_acc[W, K], history list of per-batch dicts, meter).
    """
    step, meter = make_train_step(cfg, num_shards, sync_mode, sync_dtype,
                                  donate=donate)
    if state is None:
        state = init_train_state(cfg, seed)
    buf = DiagBuffer()
    for m, batch in enumerate(stream, start=int(state.m) + 1):
        state, diag = step(state, batch.word_ids, batch.counts)
        buf.append(m, diag["iters"], diag["mean_r"])
        if callback is not None:
            callback(m, state.phi_acc,
                     dict(m=m, iters=diag["iters"], mean_r=diag["mean_r"]),
                     diag["theta"])
    history = [dict(m=int(m), iters=int(it), mean_r=float(r))
               for m, it, r in buf.rows()]
    return state.phi_acc, history, meter
