"""Predictive perplexity (paper Eq. 20, §4 protocol).

Protocol: per document, tokens are split 80/20.  With phi fixed, theta is
estimated on the 80% split by BP fold-in from a fixed random init; perplexity
is evaluated on the held-out 20% split.  Lower is better.

Fold-in routes through the shared token-major inference body
(`core.infer.fold_in_tokens`) — eval, the training driver's held-out hook
and the serving engine all compile the exact same program (DESIGN.md §11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import infer
from repro.core.types import LDAConfig, MiniBatch


def normalize_phi(phi_acc_wk: jnp.ndarray, beta: float,
                  live_w=None) -> jnp.ndarray:
    """phi[w, k] = (phi_hat + beta) / sum_w (phi_hat + beta)  — per-topic normalize.

    `live_w` switches to capacity-ladder semantics (DESIGN.md §12): rows in
    [live_w, W_cap) are guard rows, EXCLUDED from the per-topic denominator
    (their statistic is structurally zero, and W_cap*beta smoothing mass
    would otherwise jump every time the rung grows) and assigned the
    beta-prior value beta/denom — the posterior mass of one unseen word,
    which is exactly what serving's OOV admission folds in.  With
    ``live_w == W_cap`` (or None) this reduces to the fixed-W formula.
    """
    sm = phi_acc_wk + beta
    if live_w is None:
        return sm / jnp.sum(sm, axis=0, keepdims=True)
    live = jnp.arange(phi_acc_wk.shape[0])[:, None] < live_w
    denom = jnp.sum(jnp.where(live, sm, 0.0), axis=0, keepdims=True)
    return jnp.where(live, sm, beta) / jnp.maximum(denom, 1e-30)


def fold_in_theta(key: jax.Array, batch: MiniBatch, phi_norm_wk: jnp.ndarray,
                  cfg: LDAConfig, iters: int = 30,
                  residual_tol: float = 0.0) -> jnp.ndarray:
    """Estimate theta[D, K] on the training split with phi fixed (BP fold-in).

    Thin wrapper over `core.infer.fold_in_tokens` (the one fold-in body);
    ``residual_tol > 0`` enables the serving engine's per-document early
    exit, 0 keeps the paper's fixed-sweep eval protocol.
    """
    return infer.fold_in_tokens(key, batch, phi_norm_wk, cfg, iters=iters,
                                residual_tol=residual_tol).theta


def predictive_perplexity(theta: jnp.ndarray, phi_norm_wk: jnp.ndarray,
                          test: MiniBatch) -> jnp.ndarray:
    """Eq. (20) on the held-out split."""
    phi_tok = jnp.take(phi_norm_wk, test.word_ids, axis=0)       # [D, L, K]
    p = jnp.einsum("dk,dlk->dl", theta, phi_tok)
    logp = jnp.where(test.counts > 0, jnp.log(jnp.maximum(p, 1e-30)), 0.0)
    n = jnp.maximum(jnp.sum(test.counts), 1.0)
    return jnp.exp(-jnp.sum(test.counts * logp) / n)


def evaluate(key: jax.Array, phi_acc_wk: jnp.ndarray, train: MiniBatch,
             test: MiniBatch, cfg: LDAConfig, fold_iters: int = 30,
             live_w=None) -> float:
    """End-to-end: normalize phi, fold in theta, score the 20% split.

    `live_w` evaluates a capacity-laddered phi at its live vocabulary:
    guard rows get the beta-prior mass, so held-out documents whose words
    were mapped to a guard/OOV row still score finitely (DESIGN.md §12).
    """
    phi_norm = normalize_phi(phi_acc_wk, cfg.beta, live_w=live_w)
    theta = fold_in_theta(key, train, phi_norm, cfg, iters=fold_iters)
    return float(predictive_perplexity(theta, phi_norm, test))
