"""Predictive perplexity (paper Eq. 20, §4 protocol).

Protocol: per document, tokens are split 80/20.  With phi fixed, theta is
estimated on the 80% split by BP fold-in from a fixed random init; perplexity
is evaluated on the held-out 20% split.  Lower is better.

Fold-in routes through the shared token-major inference body
(`core.infer.fold_in_tokens`) — eval, the training driver's held-out hook
and the serving engine all compile the exact same program (DESIGN.md §11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import infer
from repro.core.types import LDAConfig, MiniBatch


def normalize_phi(phi_acc_wk: jnp.ndarray, beta: float) -> jnp.ndarray:
    """phi[w, k] = (phi_hat + beta) / sum_w (phi_hat + beta)  — per-topic normalize."""
    sm = phi_acc_wk + beta
    return sm / jnp.sum(sm, axis=0, keepdims=True)


def fold_in_theta(key: jax.Array, batch: MiniBatch, phi_norm_wk: jnp.ndarray,
                  cfg: LDAConfig, iters: int = 30,
                  residual_tol: float = 0.0) -> jnp.ndarray:
    """Estimate theta[D, K] on the training split with phi fixed (BP fold-in).

    Thin wrapper over `core.infer.fold_in_tokens` (the one fold-in body);
    ``residual_tol > 0`` enables the serving engine's per-document early
    exit, 0 keeps the paper's fixed-sweep eval protocol.
    """
    return infer.fold_in_tokens(key, batch, phi_norm_wk, cfg, iters=iters,
                                residual_tol=residual_tol).theta


def predictive_perplexity(theta: jnp.ndarray, phi_norm_wk: jnp.ndarray,
                          test: MiniBatch) -> jnp.ndarray:
    """Eq. (20) on the held-out split."""
    phi_tok = jnp.take(phi_norm_wk, test.word_ids, axis=0)       # [D, L, K]
    p = jnp.einsum("dk,dlk->dl", theta, phi_tok)
    logp = jnp.where(test.counts > 0, jnp.log(jnp.maximum(p, 1e-30)), 0.0)
    n = jnp.maximum(jnp.sum(test.counts), 1.0)
    return jnp.exp(-jnp.sum(test.counts * logp) / n)


def evaluate(key: jax.Array, phi_acc_wk: jnp.ndarray, train: MiniBatch,
             test: MiniBatch, cfg: LDAConfig, fold_iters: int = 30) -> float:
    """End-to-end: normalize phi, fold in theta, score the 20% split."""
    phi_norm = normalize_phi(phi_acc_wk, cfg.beta)
    theta = fold_in_theta(key, train, phi_norm, cfg, iters=fold_iters)
    return float(predictive_perplexity(theta, phi_norm, test))
