"""Predictive perplexity (paper Eq. 20, §4 protocol).

Protocol: per document, tokens are split 80/20.  With phi fixed, theta is
estimated on the 80% split by BP fold-in from a fixed random init; perplexity
is evaluated on the held-out 20% split.  Lower is better.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import LDAConfig, MiniBatch


def normalize_phi(phi_acc_wk: jnp.ndarray, beta: float) -> jnp.ndarray:
    """phi[w, k] = (phi_hat + beta) / sum_w (phi_hat + beta)  — per-topic normalize."""
    sm = phi_acc_wk + beta
    return sm / jnp.sum(sm, axis=0, keepdims=True)


def fold_in_theta(key: jax.Array, batch: MiniBatch, phi_norm_wk: jnp.ndarray,
                  cfg: LDAConfig, iters: int = 30) -> jnp.ndarray:
    """Estimate theta[D, K] on the training split with phi fixed (BP fold-in)."""
    D, L = batch.word_ids.shape
    K = phi_norm_wk.shape[1]
    u = jax.random.uniform(key, (D, L, K), minval=0.01, maxval=1.0)
    mu = u / jnp.sum(u, -1, keepdims=True)
    phi_tok = jnp.take(phi_norm_wk, batch.word_ids, axis=0)      # [D, L, K]
    c = batch.counts[..., None]

    def body(mu, _):
        theta = jnp.einsum("dl,dlk->dk", batch.counts, mu)
        th = theta[:, None, :] - c * mu + cfg.alpha
        unnorm = th * phi_tok
        mu = unnorm / jnp.maximum(jnp.sum(unnorm, -1, keepdims=True), 1e-30)
        return mu, None

    mu, _ = jax.lax.scan(body, mu, None, length=iters)
    theta = jnp.einsum("dl,dlk->dk", batch.counts, mu) + cfg.alpha
    return theta / jnp.sum(theta, -1, keepdims=True)


def predictive_perplexity(theta: jnp.ndarray, phi_norm_wk: jnp.ndarray,
                          test: MiniBatch) -> jnp.ndarray:
    """Eq. (20) on the held-out split."""
    phi_tok = jnp.take(phi_norm_wk, test.word_ids, axis=0)       # [D, L, K]
    p = jnp.einsum("dk,dlk->dl", theta, phi_tok)
    logp = jnp.where(test.counts > 0, jnp.log(jnp.maximum(p, 1e-30)), 0.0)
    n = jnp.maximum(jnp.sum(test.counts), 1.0)
    return jnp.exp(-jnp.sum(test.counts * logp) / n)


def evaluate(key: jax.Array, phi_acc_wk: jnp.ndarray, train: MiniBatch,
             test: MiniBatch, cfg: LDAConfig, fold_iters: int = 30) -> float:
    """End-to-end: normalize phi, fold in theta, score the 20% split."""
    phi_norm = normalize_phi(phi_acc_wk, cfg.beta)
    theta = fold_in_theta(key, train, phi_norm, cfg, iters=fold_iters)
    return float(predictive_perplexity(theta, phi_norm, test))
