"""Mixture-of-experts FFN: top-k routing, capacity-based dispatch.

Design for SPMD (DESIGN.md §6): tokens stay batch-sharded ('data'/'pod');
the dispatch buffer [B, E, C, D] is built with *per-row* (per-batch-element)
positions so construction is local to the data shard; the expert GEMM is
sharded over experts on the 'model' axis (expert parallelism).  GSPMD
inserts the dispatch/combine resharding (the all-to-all analogue) at the
einsum boundaries.  Active-FLOP accounting is exact: expert GEMMs process
E*C = top_k * capacity_factor * S slots per row, never the dense E-fold
blowup.

Router aux (load-balance) loss follows Switch/GShard: E * sum_e f_e * P_e.
Overflowed tokens (pos >= C) are dropped by scatter mode='drop' — their
residual path still carries them (standard capacity semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ShardingCtx, dense_init
from repro.models.mlp import mlp_apply, mlp_params


def moe_params(key, cfg: ArchConfig):
    m = cfg.moe
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "wr": dense_init(ks[0], D, m.num_experts, dtype=jnp.float32),
        "wi": jax.vmap(lambda k: dense_init(k, D, m.d_expert))(
            jax.random.split(ks[1], m.num_experts)),
        "wg": jax.vmap(lambda k: dense_init(k, D, m.d_expert))(
            jax.random.split(ks[2], m.num_experts)),
        "wo": jax.vmap(lambda k: dense_init(k, m.d_expert, D))(
            jax.random.split(ks[3], m.num_experts)),
    }
    if m.num_shared:
        p["shared"] = mlp_params(ks[4], D, m.num_shared * m.d_expert, act="silu")
    return p


def capacity(S: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(S * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, -(-c // 8) * 8)


def moe_apply(p, x, *, cfg: ArchConfig, ctx: ShardingCtx):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar).

    On a mesh, token routing runs inside a shard_map island: MANUAL over the
    data axes (the dispatch scatter/combine gather are token-local, so GSPMD
    never sees a data-dependent scatter to replicate — §Perf: it replicated
    the [B_global, S*k, D] dispatch updates, 275 GB/layer on olmoe), AUTO
    over the model axis (expert GEMMs stay EP-sharded by GSPMD).
    """
    if ctx.active and ctx.mesh is not None and ctx.batch and ctx.model:
        from jax.sharding import PartitionSpec as P_

        mesh = ctx.mesh
        dp, mx = ctx.batch, ctx.model

        def inner(x_loc, p_loc):
            y_partial, aux = _moe_apply_manual(p_loc, x_loc, cfg=cfg,
                                               model_axis=mx)
            y = jax.lax.psum(y_partial, mx)          # combine across experts
            return y, jax.lax.pmean(aux, dp)

        wspec = {
            "wr": P_(),                              # router replicated
            "wi": P_(mx, None, None),                # experts EP-sharded
            "wg": P_(mx, None, None),
            "wo": P_(mx, None, None),
        }
        if "shared" in p:
            wspec["shared"] = {"wi": P_(None, mx),   # shared experts TP-split
                               "wg": P_(None, mx),
                               "wo": P_(mx, None)}
        from jax.experimental.shard_map import shard_map
        return shard_map(inner, mesh=mesh,
                         in_specs=(P_(dp, None, None), wspec),
                         out_specs=(P_(dp, None, None), P_()),
                         check_rep=False)(x, p)
    return _moe_apply_local(p, x, cfg=cfg, ctx=ctx)


def _moe_apply_manual(p, x, *, cfg: ArchConfig, model_axis: str):
    """Manual EP: runs per (data, model) shard.  Tokens are replicated over
    the model axis; each model shard dispatches to ITS E_loc experts and
    produces a partial [B, S, D] (the caller psums over the model axis).
    Identical math to _moe_apply_local (tested)."""
    B, S, D = x.shape
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    C = capacity(S, cfg)
    E_loc = p["wi"].shape[0]
    midx = jax.lax.axis_index(model_axis)
    lo = midx * E_loc * C

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["wr"])
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    choice_e = topi.reshape(B, S * k)
    onehot = jax.nn.one_hot(choice_e, E, dtype=jnp.int32)
    pos = jnp.einsum("bte,bte->bt", jnp.cumsum(onehot, axis=1) - 1, onehot)
    keep = pos < C
    slot = jnp.where(keep, choice_e * C + pos, E * C)       # global slots
    slot_loc = jnp.where(
        jnp.logical_and(slot >= lo, slot < lo + E_loc * C),
        slot - lo, E_loc * C)                               # mine or drop

    xt = jnp.repeat(x.reshape(B, S, 1, D), k, axis=2).reshape(B, S * k, D)
    disp = jnp.zeros((B, E_loc * C + 1, D), x.dtype)
    disp = disp.at[jnp.arange(B)[:, None], slot_loc].add(xt, mode="drop")
    disp = disp[:, : E_loc * C].reshape(B, E_loc, C, D)

    h = jnp.einsum("becd,edf->becf", disp, p["wi"])
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", disp, p["wg"]))
    y_e = jnp.einsum("becf,efd->becd", h * g, p["wo"])

    y_flat = y_e.reshape(B, E_loc * C, D)
    y_flat = jnp.concatenate([y_flat, jnp.zeros((B, 1, D), y_e.dtype)], 1)
    picked = jnp.take_along_axis(y_flat, slot_loc[..., None], axis=1)
    picked = picked.reshape(B, S, k, D)
    y = jnp.einsum("bskd,bsk->bsd", picked, topv.astype(x.dtype))

    if m.num_shared:
        from repro.models.mlp import mlp_apply
        from repro.models.common import NULL_CTX
        y = y + mlp_apply(p["shared"], x, act="silu", ctx=NULL_CTX)
    return y, aux.astype(jnp.float32)


def _moe_apply_local(p, x, *, cfg: ArchConfig, ctx: ShardingCtx):
    B, S, D = x.shape
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    C = capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["wr"])
    gates = jax.nn.softmax(logits, axis=-1)                     # [B, S, E]
    topv, topi = jax.lax.top_k(gates, k)                        # [B, S, k]
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch form) ----
    me = jnp.mean(gates, axis=(0, 1))                           # P_e
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], E), axis=(0, 1)) # f_e (top-1)
    aux = E * jnp.sum(me * ce)

    # ---- per-row positions in each expert queue (local to the shard) ----
    choice_e = topi.reshape(B, S * k)                           # row-major choices
    onehot = jax.nn.one_hot(choice_e, E, dtype=jnp.int32)       # [B, S*k, E]
    pos = jnp.einsum("bte,bte->bt", jnp.cumsum(onehot, axis=1) - 1, onehot)
    keep = pos < C
    slot = jnp.where(keep, choice_e * C + pos, E * C)           # OOR -> dropped

    # ---- dispatch: [B, E*C(+pad), D] scatter, then expert GEMMs ----
    xt = jnp.repeat(x.reshape(B, S, 1, D), k, axis=2).reshape(B, S * k, D)
    disp = jnp.zeros((B, E * C + 1, D), x.dtype)
    disp = disp.at[jnp.arange(B)[:, None], slot].add(xt, mode="drop")
    disp = disp[:, : E * C].reshape(B, E, C, D)
    disp = ctx.ct(disp, ctx.batch, ctx.model, None, None)       # EP layout

    h = jnp.einsum("becd,edf->becf", disp, p["wi"])
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", disp, p["wg"]))
    y_e = jnp.einsum("becf,efd->becd", h * g, p["wo"])          # [B, E, C, D]
    y_e = ctx.ct(y_e, ctx.batch, None, None, None)              # combine layout

    # ---- combine ----
    if m.combine == "scatter":
        # slots scatter-add back into token order.  y_e stays EP-sharded, so
        # each model shard contributes its own (disjoint) slots and GSPMD
        # emits partial-[T,D] + all-reduce — k*cf/2 x fewer bytes than
        # all-gathering [B,E,C,D] (§Perf, MoE cells).
        # slots are unique per (token, choice) by construction, so .set is
        # race-free; dropped entries write index E*C which is sliced away.
        gate_of_slot = jnp.zeros((B, E * C + 1), jnp.float32)
        gate_of_slot = gate_of_slot.at[jnp.arange(B)[:, None], slot].set(
            topv.reshape(B, S * k))
        tok_of_slot = jnp.full((B, E * C + 1), S, jnp.int32)
        tok_of_slot = tok_of_slot.at[jnp.arange(B)[:, None], slot].set(
            jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(S * k))
        y_flat = y_e.reshape(B, E * C, D)
        weighted = y_flat * gate_of_slot[:, : E * C, None].astype(y_e.dtype)
        y = jnp.zeros((B, S + 1, D), y_e.dtype).at[
            jnp.arange(B)[:, None], tok_of_slot[:, : E * C]].add(
            weighted, mode="drop")[:, :S]
    else:
        y_flat = y_e.reshape(B, E * C, D)
        y_flat = jnp.concatenate([y_flat, jnp.zeros((B, 1, D), y_e.dtype)],
                                 axis=1)
        picked = jnp.take_along_axis(y_flat, slot[..., None], axis=1)
        picked = picked.reshape(B, S, k, D)
        y = jnp.einsum("bskd,bsk->bsd", picked, topv.astype(x.dtype))

    if m.num_shared:
        y = y + mlp_apply(p["shared"], x, act="silu", ctx=ctx)
    return y, aux.astype(jnp.float32)
