"""Attention variants: GQA (with optional QKV bias + sliding window),
MLA (DeepSeek multi-head latent attention, absorbed decode form), and
cross-attention (VLM / enc-dec memory).

Cache contract (decode):
  GQA   cache = {"k": [B, S, KV, hd], "v": [B, S, KV, hd]}
  MLA   cache = {"ckv": [B, S, kv_lora], "kr": [B, S, qk_rope]}
  cross cache = {"mk": [B, M, H, hd], "mv": [B, M, H, hd]}  (static memory)
`pos` is the write index; queries attend to cache positions <= pos.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (COMPUTE_DTYPE, NULL_CTX as NULL_CTX_,
                                 ShardingCtx, apply_rope, dense_init,
                                 rope_freqs)

NEG_INF = -2.0e38


def _attend(q, k, v, *, mask, scale, ctx: ShardingCtx):
    """q [B,Sq,G,Hk,hd] k/v [B,Skv,Hk,hd] (G = query groups per kv head)."""
    scores = jnp.einsum("bsghd,bthd->bghst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bghst,bthd->bsghd", w, v)
    return out


def chunked_attend(q, k, v, *, causal: bool, window: int, scale: float,
                   chunk: int, unroll: bool = False):
    """Memory-bounded attention: scan over query blocks so the live score
    buffer is [B, G, Hk, C, Skv] instead of [B, G, Hk, Sq, Skv].

    Mandatory for the 32k/500k shapes (full S^2 scores would be TBs) and
    keeps train_4k inside the 16 GB/chip HBM budget.  q [B,Sq,G,Hk,hd],
    k/v [B,Skv,Hk,hd]; q/kv positions are absolute [0..S).  `unroll` mirrors
    cfg.scan_layers=False for cost-analysis probes (while bodies are counted
    once by XLA cost analysis).
    """
    B, Sq, G, Hk, hd = q.shape
    Skv = k.shape[1]
    C = min(chunk, Sq)
    pad = (-Sq) % C
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = q.shape[1] // C
    qb = q.reshape(B, nq, C, G, Hk, hd).transpose(1, 0, 2, 3, 4, 5)
    starts = jnp.arange(nq) * C
    j = jnp.arange(Skv)

    def body(_, inp):
        q_blk, start = inp                               # [B,C,G,Hk,hd]
        i = start + jnp.arange(C)
        if causal:
            m = j[None, :] <= i[:, None]
            if window:
                m = jnp.logical_and(m, j[None, :] > i[:, None] - window)
        else:
            m = jnp.ones((C, Skv), bool)
        out_blk = _attend(q_blk, k, v, mask=m[None, None, None], scale=scale,
                          ctx=NULL_CTX_)
        return None, out_blk                             # [B,C,G,Hk,hd]

    if unroll:
        outs = [body(None, (qb[i], starts[i]))[1] for i in range(nq)]
        out = jnp.stack(outs)
    else:
        _, out = jax.lax.scan(body, None, (qb, starts))
    dv = v.shape[-1]   # v head_dim may differ from q/k head_dim (MLA)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * C, G, Hk, dv)
    return out[:, :Sq]


# ---------------------------------------------------------------- GQA

def gqa_params(key, cfg: ArchConfig):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd),
        "wk": dense_init(ks[1], D, KV * hd),
        "wv": dense_init(ks[2], D, KV * hd),
        "wo": dense_init(ks[3], H * hd, D),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), COMPUTE_DTYPE)
        p["bk"] = jnp.zeros((KV * hd,), COMPUTE_DTYPE)
        p["bv"] = jnp.zeros((KV * hd,), COMPUTE_DTYPE)
    return p


def gqa_apply(p, x, *, cfg: ArchConfig, ctx: ShardingCtx,
              positions: jnp.ndarray, cache: Optional[dict] = None,
              pos: Optional[jnp.ndarray] = None,
              window: int = 0):
    """x [B, S, D].  Train/prefill: cache=None/new-cache; decode: S==1 + cache."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    inv_freq = rope_freqs(hd, cfg.rope_theta)

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # constrain only the merged-head projections (always divisible); head
    # granularity constraints fight the (KV, G) reshape when KV < model size
    # and trigger involuntary full remats in the SPMD partitioner.
    q = ctx.ct(q, ctx.batch, None, ctx.model)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)

    if cache is not None and pos is not None:            # ---- decode step
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        Sc = kc.shape[1]
        idx = jnp.arange(Sc)
        valid = idx <= pos
        if window:
            valid = jnp.logical_and(valid, idx > pos - window)
        mask = valid[None, None, None, None, :]
        qg = q.reshape(B, S, KV, G, hd).transpose(0, 1, 3, 2, 4)  # [B,1,G,KV,hd]
        out = _attend(qg, kc, vc, mask=mask, scale=hd ** -0.5, ctx=ctx)
        out = out.transpose(0, 1, 3, 2, 4).reshape(B, S, H * hd)  # [B,1,H*hd]
        return jnp.einsum("bsh,hd->bsd", out, p["wo"]), {"k": kc, "v": vc}

    # ---- train / prefill: causal (optionally sliding-window) attention,
    # q-chunked so the score buffer stays O(C * S) not O(S^2).
    # KV heads are REPLICATED to H flat heads (standard TP practice): a flat
    # H dim shards 16-way cleanly, whereas the (KV, G) split forces GSPMD
    # into inconsistent factorizations and it replicates the whole score
    # tensor (§Perf iteration: -11.8 TB/step of all-gathers on qwen2-72b).
    qf = q[:, :, None]                                   # [B,S,1,H,hd]
    k_rep = jnp.repeat(k, G, axis=2)                     # [B,S,H,hd]
    v_rep = jnp.repeat(v, G, axis=2)
    out = chunked_attend(qf, k_rep, v_rep, causal=True, window=window,
                         scale=hd ** -0.5, chunk=cfg.attn_chunk,
                         unroll=not cfg.scan_layers)
    out = out[:, :, 0].reshape(B, S, H * hd)
    out = ctx.ct(out, ctx.batch, None, ctx.model)
    y = ctx.ct_seq(jnp.einsum("bsh,hd->bsd", out, p["wo"]))
    new_cache = {"k": k, "v": v}
    return y, new_cache


# ---------------------------------------------------------------- MLA

def mla_params(key, cfg: ArchConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], D, H * (m.qk_nope + m.qk_rope)),
        "wdkv": dense_init(ks[1], D, m.kv_lora + m.qk_rope),
        "kv_norm": jnp.ones((m.kv_lora,), COMPUTE_DTYPE),
        "wuk": dense_init(ks[2], m.kv_lora, H * m.qk_nope),
        "wuv": dense_init(ks[3], m.kv_lora, H * m.v_head),
        "wo": dense_init(ks[4], H * m.v_head, D),
    }


def mla_apply(p, x, *, cfg: ArchConfig, ctx: ShardingCtx,
              positions: jnp.ndarray, cache: Optional[dict] = None,
              pos: Optional[jnp.ndarray] = None, window: int = 0):
    from repro.models.common import rmsnorm

    B, S, D = x.shape
    m = cfg.mla
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope, m.qk_rope, m.v_head
    scale = (dn + dr) ** -0.5
    inv_freq = rope_freqs(dr, cfg.rope_theta)

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, inv_freq)

    dkv = jnp.einsum("bsd,dh->bsh", x, p["wdkv"])             # [B,S,lora+dr]
    ckv = rmsnorm(dkv[..., :m.kv_lora], p["kv_norm"])
    k_rope = apply_rope(dkv[..., m.kv_lora:][:, :, None, :], positions,
                        inv_freq)[:, :, 0, :]                 # [B,S,dr] shared

    if cache is not None and pos is not None:                 # ---- decode
        ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
        kr_c = jax.lax.dynamic_update_slice(cache["kr"], k_rope, (0, pos, 0))
        Sc = ckv_c.shape[1]
        # absorbed form: fold wuk into the query -> score vs compressed cache
        wuk = p["wuk"].reshape(m.kv_lora, H, dn)
        q_abs = jnp.einsum("bshn,lhn->bshl", q_nope, wuk)     # [B,1,H,lora]
        scores = (jnp.einsum("bshl,btl->bhst", q_abs, ckv_c)
                  + jnp.einsum("bshr,btr->bhst", q_rope, kr_c))
        scores = scores.astype(jnp.float32) * scale
        valid = (jnp.arange(Sc) <= pos)[None, None, None, :]
        w = jax.nn.softmax(jnp.where(valid, scores, NEG_INF), -1).astype(x.dtype)
        ctx_l = jnp.einsum("bhst,btl->bshl", w, ckv_c)        # [B,1,H,lora]
        wuv = p["wuv"].reshape(m.kv_lora, H, dv)
        out = jnp.einsum("bshl,lhv->bshv", ctx_l, wuv).reshape(B, S, H * dv)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"]), {"ckv": ckv_c, "kr": kr_c}

    # ---- train / prefill: materialized k/v, q-chunked attention.
    # concat nope+rope along head_dim so one contraction computes
    # q_nope.k_nope + q_rope.k_rope (the shared k_rope broadcasts to heads).
    k_nope = jnp.einsum("bsl,lh->bsh", ckv, p["wuk"]).reshape(B, S, H, dn)
    v = jnp.einsum("bsl,lh->bsh", ckv, p["wuv"]).reshape(B, S, H, dv)
    q_cat = jnp.concatenate([q_nope, q_rope], -1)                 # [B,S,H,dn+dr]
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
    out = chunked_attend(q_cat[:, :, None], k_cat, v, causal=True,
                         window=window, scale=scale, chunk=cfg.attn_chunk,
                         unroll=not cfg.scan_layers)               # G=1
    out = out[:, :, 0].reshape(B, S, H * dv)
    y = ctx.ct_seq(jnp.einsum("bsh,hd->bsd", out, p["wo"]))
    return y, {"ckv": ckv, "kr": k_rope}


# --------------------------------------------------------------- cross

def cross_params(key, cfg: ArchConfig, d_mem: Optional[int] = None):
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    d_mem = d_mem or D
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], D, H * hd),
        "wk": dense_init(ks[1], d_mem, H * hd),
        "wv": dense_init(ks[2], d_mem, H * hd),
        "wo": dense_init(ks[3], H * hd, D),
    }


def cross_apply(p, x, memory, *, cfg: ArchConfig, ctx: ShardingCtx,
                mem_kv: Optional[dict] = None):
    """x [B,S,D] attends to memory [B,M,d_mem].  mem_kv caches k/v(memory)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    if mem_kv is None:
        k = jnp.einsum("bmd,dh->bmh", memory, p["wk"]).reshape(B, -1, H, hd)
        v = jnp.einsum("bmd,dh->bmh", memory, p["wv"]).reshape(B, -1, H, hd)
        mem_kv = {"mk": k, "mv": v}
    k, v = mem_kv["mk"], mem_kv["mv"]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * hd ** -0.5
    w = jax.nn.softmax(scores, -1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v).reshape(B, S, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), mem_kv
