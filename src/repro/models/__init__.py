"""Pure-JAX architecture zoo (pytree params, no framework deps).

Families: dense GQA decoders, MLA+MoE (DeepSeek-style), pure MoE, Mamba2
(SSD), hybrid SSM+attention (Zamba2-style), cross-attention VLM backbones,
and encoder-decoder audio backbones.  Every model exposes:

  init(key, cfg)                       -> params pytree
  loss_fn(params, batch, cfg)          -> scalar LM loss   (train shapes)
  prefill(params, tokens, cfg)         -> (logits, cache)  (prefill shapes)
  decode_step(params, token, cache, pos, cfg) -> (logits, cache)  (decode)

Layer stacks are `lax.scan`-ned over stacked [n_layers, ...] params so the
lowered HLO stays small enough to compile 88-layer/123B configs on this
container's CPU within the dry-run budget.
"""

from repro.models import common, attention, mlp, moe, ssm, lm, encdec  # noqa: F401
from repro.models.registry import build, MODEL_FAMILIES  # noqa: F401
