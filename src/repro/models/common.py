"""Shared model components: init helpers, norms, RoPE, sharding context."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.bfloat16


# --------------------------------------------------------------- sharding

@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Activation-sharding hints; no-ops when no mesh is active.

    `batch` covers DP axes ('pod','data'); `model` is TP/EP; `seq` is the
    sequence-parallel axis for the residual stream between layers (Megatron
    SP) — set to the model axis in training so the scan-saved per-layer
    carries shrink by the TP degree (123B-scale memory fit; DESIGN.md §9).
    """

    active: bool = False
    batch: Optional[Tuple[str, ...]] = ("data",)
    model: Optional[str] = "model"
    seq: Optional[str] = None
    # concrete Mesh for shard_map islands (MoE token routing — GSPMD
    # replicates data-dependent scatters, manual-over-data avoids it)
    mesh: Optional[object] = None

    def ct(self, x: jnp.ndarray, *spec):
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    def ct_seq(self, x: jnp.ndarray):
        """Pin a [B, S, D] projection output to the sequence-parallel layout
        *before* the residual add, so XLA's reduce-scatter-creation pass can
        rewrite the row-parallel partial-sum all-reduce into a reduce-scatter
        (§Perf iteration B — halves those collective bytes)."""
        if not self.active or self.seq is None:
            return x
        return jax.lax.with_sharding_constraint(x, P(self.batch, self.seq,
                                                     None))


NULL_CTX = ShardingCtx(active=False)


# ----------------------------------------------------------------- params

def dense_init(key, d_in: int, d_out: int, dtype=PARAM_DTYPE,
               scale: float | None = None) -> jnp.ndarray:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=PARAM_DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def stack_init(key, n: int, init_fn):
    """vmap an init over a leading layer axis (for scanned stacks)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ------------------------------------------------------------------ norms

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


# ------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x [..., S, H, hd] (hd even), positions [..., S] -> rotated x."""
    ang = positions[..., None].astype(jnp.float32) * inv_freq           # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                                    # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- loss

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 ignore_id: int = -1) -> jnp.ndarray:
    """Mean token cross-entropy in fp32; labels==ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def causal_mask(S: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.tril(jnp.ones((S, S), bool))
