"""Decoder-only LM assembly for all decoder families:

  dense   — [attn + mlp] x N, scanned
  moe     — dense_first_n plain layers, then [attn + moe] x rest, scanned
  vlm     — scanned groups of (cross_attn_every-1 self layers + 1 cross layer);
            vision frontend is a stub (precomputed patch embeddings input)
  ssm     — [mamba2] x N, scanned
  hybrid  — scanned groups of (shared_attn_every mamba2 layers) + one SHARED
            attention block (weights reused across groups, Zamba2-style,
            fed concat(hidden, initial embedding))

Stacks are `lax.scan`-ned over [n_layers, ...] stacked params; train mode
wraps the block in `jax.checkpoint` (remat) so 123B-scale activations fit.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (COMPUTE_DTYPE, NULL_CTX, ShardingCtx,
                                 dense_init, embed_init, rmsnorm, layernorm,
                                 softmax_xent, stack_init)


def _remat(fn, cfg: ArchConfig):
    """jax.checkpoint with the configured policy.  'dots' saves matmul
    outputs (no forward recompute in backward — §Perf iteration: cuts the
    remat re-gather of FSDP weights and the recompute byte traffic; saved
    dot outputs are cheap because they are SP/TP-sharded)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def stack_scan(body, carry, xs, cfg: ArchConfig):
    """lax.scan over stacked layer params, or an unrolled python loop when
    cfg.scan_layers=False (cost-analysis probes: XLA counts while-loop body
    costs once, so rooflines are extrapolated from unrolled reduced-depth
    builds — launch/dryrun.py)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else None
    return carry, stacked


# ---------------------------------------------------------------- blocks

def _norm(params, x, cfg):
    if cfg.norm == "rms":
        return rmsnorm(x, params["w"])
    return layernorm(x, params["w"], params["b"])


def _norm_params(cfg):
    p = {"w": jnp.ones((cfg.d_model,), COMPUTE_DTYPE)}
    if cfg.norm != "rms":
        p["b"] = jnp.zeros((cfg.d_model,), COMPUTE_DTYPE)
    return p


def self_block_params(key, cfg: ArchConfig, use_moe: bool):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": _norm_params(cfg), "ln2": _norm_params(cfg)}
    if cfg.attn_type == "mla":
        p["attn"] = attn.mla_params(k1, cfg)
    else:
        p["attn"] = attn.gqa_params(k1, cfg)
    if use_moe:
        p["moe"] = moe_mod.moe_params(k2, cfg)
    else:
        p["mlp"] = mlp_mod.mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def self_block_apply(p, x, *, cfg: ArchConfig, ctx: ShardingCtx, positions,
                     cache=None, pos=None, window: int = 0):
    """Pre-norm attn + FFN.  Returns (x, cache, aux)."""
    h = _norm(p["ln1"], x, cfg)
    if cfg.attn_type == "mla":
        a, new_cache = attn.mla_apply(p["attn"], h, cfg=cfg, ctx=ctx,
                                      positions=positions, cache=cache, pos=pos,
                                      window=window)
    else:
        a, new_cache = attn.gqa_apply(p["attn"], h, cfg=cfg, ctx=ctx,
                                      positions=positions, cache=cache, pos=pos,
                                      window=window)
    x = x + a
    h = _norm(p["ln2"], x, cfg)
    if "moe" in p:
        f, aux = moe_mod.moe_apply(p["moe"], h, cfg=cfg, ctx=ctx)
    else:
        f, aux = mlp_mod.mlp_apply(p["mlp"], h, act=cfg.act, ctx=ctx), 0.0
    return x + f, new_cache, aux


def cross_block_params(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_params(cfg), "ln2": _norm_params(cfg),
            "xattn": attn.cross_params(k1, cfg),
            "mlp": mlp_mod.mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act),
            "gate": jnp.zeros((1,), COMPUTE_DTYPE)}


def cross_block_apply(p, x, memory, *, cfg, ctx, mem_kv=None):
    h = _norm(p["ln1"], x, cfg)
    a, mem_kv = attn.cross_apply(p["xattn"], h, memory, cfg=cfg, ctx=ctx,
                                 mem_kv=mem_kv)
    x = x + jnp.tanh(p["gate"]) * a
    h = _norm(p["ln2"], x, cfg)
    return x + mlp_mod.mlp_apply(p["mlp"], h, act=cfg.act, ctx=ctx), mem_kv


def ssm_block_params(key, cfg: ArchConfig):
    return {"ln": _norm_params(cfg), "ssm": ssm_mod.ssm_params(key, cfg)}


def shared_attn_params(key, cfg: ArchConfig):
    """Zamba2 shared block: concat(hidden, embed0) [2D] -> D, attn + mlp."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"in_proj": dense_init(k1, 2 * cfg.d_model, cfg.d_model),
            "block": self_block_params(k2, cfg, use_moe=False)}


# ---------------------------------------------------------------- init

def _n_groups(cfg: ArchConfig) -> Tuple[int, int]:
    """(group_size, n_groups) of the scanned stack for this family."""
    if cfg.family == "vlm":
        return cfg.cross_attn_every, cfg.n_layers // cfg.cross_attn_every
    if cfg.family == "hybrid":
        return cfg.shared_attn_every, cfg.n_layers // cfg.shared_attn_every
    return 1, cfg.n_layers - cfg.dense_first_n


def init(key, cfg: ArchConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": embed_init(keys[0], cfg.padded_vocab,
                                                  cfg.d_model),
                              "ln_f": _norm_params(cfg)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab)

    gsize, ngroups = _n_groups(cfg)
    if cfg.family in ("dense", "moe"):
        if cfg.dense_first_n:
            params["head_blocks"] = [
                self_block_params(k, cfg, use_moe=False)
                for k in jax.random.split(keys[2], cfg.dense_first_n)]
        params["stack"] = stack_init(
            keys[3], ngroups,
            lambda k: self_block_params(k, cfg, use_moe=cfg.moe is not None))
    elif cfg.family == "vlm":
        params["stack"] = stack_init(
            keys[3], ngroups,
            lambda k: {
                "selfs": stack_init(k, gsize - 1,
                                    lambda kk: self_block_params(kk, cfg, False)),
                "cross": cross_block_params(jax.random.fold_in(k, 7), cfg),
            })
    elif cfg.family == "ssm":
        params["stack"] = stack_init(keys[3], cfg.n_layers,
                                     lambda k: ssm_block_params(k, cfg))
    elif cfg.family == "hybrid":
        params["stack"] = stack_init(
            keys[3], ngroups,
            lambda k: stack_init(k, gsize, lambda kk: ssm_block_params(kk, cfg)))
        params["shared_attn"] = shared_attn_params(keys[4], cfg)
    else:
        raise ValueError(f"lm.init: unsupported family {cfg.family}")
    return params


# ------------------------------------------------------------- forward

def _logits(params, x, cfg, ctx):
    x = _norm(params["ln_f"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.padded_vocab != cfg.vocab_size:   # mask padding rows to -inf
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    if ctx.seq is not None and logits.shape[1] > 1:
        return ctx.ct(logits, ctx.batch, ctx.seq, None)
    return ctx.ct(logits, ctx.batch, None, ctx.model)


def forward(params, tokens, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX,
            *, image_embeds=None, mode: str = "train"):
    """Full-sequence forward.  Returns (logits, caches, aux_loss).

    mode='train' remats each scanned block; mode='prefill' also returns
    the KV caches / SSM states needed to continue decoding.
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.ct(x, ctx.batch, None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    remat = mode == "train"
    caches: Dict[str, Any] = {}
    aux_total = jnp.float32(0.0)

    if cfg.family in ("dense", "moe"):
        head_caches = []
        for hb in params.get("head_blocks", []):
            # head blocks are dense even in MoE archs (DeepSeek layer 0)
            hcfg = cfg
            x, c, _ = self_block_apply(hb, x, cfg=hcfg, ctx=ctx,
                                       positions=positions,
                                       window=cfg.sliding_window)
            head_caches.append(c)
        caches["head"] = head_caches

        def body(carry, layer_p):
            x, aux = carry
            x2, c, a = self_block_apply(layer_p, x, cfg=cfg, ctx=ctx,
                                        positions=positions,
                                        window=cfg.sliding_window)
            x2 = ctx.ct(x2, ctx.batch, ctx.seq, None)
            return (x2, aux + a), c

        fn = _remat(body, cfg) if remat else body
        (x, aux_total), stack_cache = stack_scan(fn, (x, aux_total), params["stack"], cfg)
        caches["stack"] = stack_cache

    elif cfg.family == "vlm":
        memory = image_embeds.astype(x.dtype)

        def body(carry, layer_p):
            x, aux = carry

            def inner(xc, sp):
                xc2, c, _ = self_block_apply(sp, xc, cfg=cfg, ctx=ctx,
                                             positions=positions)
                return xc2, c

            x, self_caches = stack_scan(inner, x, layer_p["selfs"], cfg)
            x, mem_kv = cross_block_apply(layer_p["cross"], x, memory,
                                          cfg=cfg, ctx=ctx)
            x = ctx.ct(x, ctx.batch, ctx.seq, None)
            return (x, aux), {"selfs": self_caches, "mem_kv": mem_kv}

        fn = _remat(body, cfg) if remat else body
        (x, aux_total), stack_cache = stack_scan(fn, (x, aux_total), params["stack"], cfg)
        caches["stack"] = stack_cache

    elif cfg.family == "ssm":
        def body(carry, layer_p):
            x, aux = carry
            h = _norm(layer_p["ln"], x, cfg)
            y, st = ssm_mod.ssm_apply(layer_p["ssm"], h, cfg=cfg, ctx=ctx)
            return (ctx.ct(x + y, ctx.batch, ctx.seq, None), aux), st

        fn = _remat(body, cfg) if remat else body
        (x, aux_total), stack_cache = stack_scan(fn, (x, aux_total), params["stack"], cfg)
        caches["stack"] = stack_cache

    elif cfg.family == "hybrid":
        x_emb0 = x
        shared = params["shared_attn"]

        def body(carry, group_p):
            x, aux = carry

            def inner(xc, lp):
                h = _norm(lp["ln"], xc, cfg)
                y, st = ssm_mod.ssm_apply(lp["ssm"], h, cfg=cfg, ctx=ctx)
                return xc + y, st

            x, states = stack_scan(inner, x, group_p, cfg)
            h = jnp.einsum("bsd,dh->bsh",
                           jnp.concatenate([x, x_emb0], -1), shared["in_proj"])
            h2, kv, _ = self_block_apply(shared["block"], h, cfg=cfg, ctx=ctx,
                                         positions=positions,
                                         window=cfg.sliding_window)
            return (ctx.ct(x + h2, ctx.batch, ctx.seq, None), aux),\
                {"ssm": states, "attn_kv": kv}

        fn = _remat(body, cfg) if remat else body
        (x, aux_total), stack_cache = stack_scan(fn, (x, aux_total), params["stack"], cfg)
        caches["stack"] = stack_cache
    else:
        raise ValueError(cfg.family)

    return _logits(params, x, cfg, ctx), caches, aux_total


# ---------------------------------------------------------- decode step

def decode_step(params, token, caches, pos, cfg: ArchConfig,
                ctx: ShardingCtx = NULL_CTX, *, image_embeds=None):
    """One decode step.  token [B, 1] int32; pos scalar int32 (write index).

    Caches carry [n_layers, ...] stacked KV / SSM state and are scanned in
    lock-step with the params.  Returns (logits [B, 1, V], new_caches).
    """
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    if cfg.family in ("dense", "moe"):
        new_head = []
        for hb, c in zip(params.get("head_blocks", []), caches["head"]):
            x, c2, _ = self_block_apply(hb, x, cfg=cfg, ctx=ctx,
                                        positions=positions, cache=c, pos=pos,
                                        window=cfg.sliding_window)
            new_head.append(c2)

        def body(x, pc):
            layer_p, c = pc
            x2, c2, _ = self_block_apply(layer_p, x, cfg=cfg, ctx=ctx,
                                         positions=positions, cache=c, pos=pos,
                                         window=cfg.sliding_window)
            return x2, c2

        x, stack_cache = stack_scan(body, x, (params["stack"], caches["stack"]), cfg)
        new_caches = {"head": new_head, "stack": stack_cache}

    elif cfg.family == "vlm":
        def body(x, pc):
            layer_p, c = pc

            def inner(xc, spc):
                sp, sc = spc
                xc2, sc2, _ = self_block_apply(sp, xc, cfg=cfg, ctx=ctx,
                                               positions=positions, cache=sc,
                                               pos=pos)
                return xc2, sc2

            x, self_caches = stack_scan(inner, x, (layer_p["selfs"], c["selfs"]), cfg)
            x, _ = cross_block_apply(layer_p["cross"], x, None, cfg=cfg,
                                     ctx=ctx, mem_kv=c["mem_kv"])
            return x, {"selfs": self_caches, "mem_kv": c["mem_kv"]}

        x, stack_cache = stack_scan(body, x, (params["stack"], caches["stack"]), cfg)
        new_caches = {"stack": stack_cache}

    elif cfg.family == "ssm":
        def body(x, pc):
            layer_p, st = pc
            h = _norm(layer_p["ln"], x, cfg)
            y, st2 = ssm_mod.ssm_decode_step(layer_p["ssm"], h, st, cfg=cfg,
                                             ctx=ctx)
            return x + y, st2

        x, stack_cache = stack_scan(body, x, (params["stack"], caches["stack"]), cfg)
        new_caches = {"stack": stack_cache}

    elif cfg.family == "hybrid":
        x_emb0 = x
        shared = params["shared_attn"]

        def body(x, pc):
            group_p, c = pc

            def inner(xc, lpst):
                lp, st = lpst
                h = _norm(lp["ln"], xc, cfg)
                y, st2 = ssm_mod.ssm_decode_step(lp["ssm"], h, st, cfg=cfg,
                                                 ctx=ctx)
                return xc + y, st2

            x, states = stack_scan(inner, x, (group_p, c["ssm"]), cfg)
            h = jnp.einsum("bsd,dh->bsh",
                           jnp.concatenate([x, x_emb0], -1), shared["in_proj"])
            h2, kv, _ = self_block_apply(shared["block"], h, cfg=cfg, ctx=ctx,
                                         positions=positions, cache=c["attn_kv"],
                                         pos=pos, window=cfg.sliding_window)
            return x + h2, {"ssm": states, "attn_kv": kv}

        x, stack_cache = stack_scan(body, x, (params["stack"], caches["stack"]), cfg)
        new_caches = {"stack": stack_cache}
    else:
        raise ValueError(cfg.family)

    return _logits(params, x, cfg, ctx), new_caches


# -------------------------------------------------------------- training

def loss_fn(params, batch, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX):
    logits, _, aux = forward(params, batch["tokens"], cfg, ctx,
                             image_embeds=batch.get("image_embeds"),
                             mode="train")
    loss = softmax_xent(logits, batch["labels"])
    return loss + 0.01 * aux
