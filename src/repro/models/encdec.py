"""Encoder-decoder backbone (seamless-m4t family).

The audio frontend is a STUB per the assignment: `frames` are precomputed
frame embeddings [B, S_enc, d_model].  Encoder: bidirectional self-attn +
GeLU FFN.  Decoder: causal self-attn (cached) + cross-attn to the encoder
output (memory k/v cached once) + GeLU FFN.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (COMPUTE_DTYPE, NULL_CTX, ShardingCtx,
                                 embed_init, dense_init, softmax_xent,
                                 stack_init)
from repro.models.lm import (_norm, _norm_params, _remat, self_block_apply,
                             self_block_params, cross_block_params,
                             cross_block_apply, _logits, stack_scan)


def enc_block_params(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_params(cfg), "ln2": _norm_params(cfg),
            "attn": attn.gqa_params(k1, cfg),
            "mlp": mlp_mod.mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act)}


def enc_block_apply(p, x, *, cfg, ctx, positions):
    """Bidirectional self-attention block (no mask, no cache)."""
    B, S, D = x.shape
    h = _norm(p["ln1"], x, cfg)
    q = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wq"]).reshape(
        B, S, cfg.n_heads, cfg.hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wk"]).reshape(
        B, S, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wv"]).reshape(
        B, S, cfg.n_kv_heads, cfg.hd)
    from repro.models.common import apply_rope, rope_freqs
    inv = rope_freqs(cfg.hd, cfg.rope_theta)
    q, k = apply_rope(q, positions, inv), apply_rope(k, positions, inv)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, G, cfg.hd).transpose(0, 1, 3, 2, 4)
    out = attn.chunked_attend(qg, k, v, causal=False, window=0,
                              scale=cfg.hd ** -0.5, chunk=cfg.attn_chunk,
                              unroll=not cfg.scan_layers)
    out = out.transpose(0, 1, 3, 2, 4).reshape(B, S, cfg.n_heads * cfg.hd)
    x = x + jnp.einsum("bsh,hd->bsd", out, p["attn"]["wo"])
    h = _norm(p["ln2"], x, cfg)
    return x + mlp_mod.mlp_apply(p["mlp"], h, act=cfg.act, ctx=ctx)


def dec_block_params(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    p = self_block_params(k1, cfg, use_moe=False)
    p["cross"] = cross_block_params(k2, cfg)
    return p


def dec_block_apply(p, x, memory, *, cfg, ctx, positions, cache=None,
                    pos=None):
    x, kv, _ = self_block_apply({k: v for k, v in p.items() if k != "cross"},
                                x, cfg=cfg, ctx=ctx, positions=positions,
                                cache=None if cache is None else cache["kv"],
                                pos=pos)
    x, mem_kv = cross_block_apply(p["cross"], x, memory, cfg=cfg, ctx=ctx,
                                  mem_kv=None if cache is None
                                  else cache["mem_kv"])
    return x, {"kv": kv, "mem_kv": mem_kv}


def init(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    n_enc = cfg.enc_layers or cfg.n_layers
    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "lm_head": dense_init(ks[1], cfg.d_model, cfg.padded_vocab),
        "ln_f": _norm_params(cfg),
        "ln_enc": _norm_params(cfg),
        "enc": stack_init(ks[2], n_enc, lambda k: enc_block_params(k, cfg)),
        "dec": stack_init(ks[3], cfg.n_layers,
                          lambda k: dec_block_params(k, cfg)),
    }


def encode(params, frames, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX,
           remat: bool = False):
    B, S, _ = frames.shape
    x = frames.astype(COMPUTE_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, lp):
        y = enc_block_apply(lp, x, cfg=cfg, ctx=ctx, positions=positions)
        return ctx.ct(y, ctx.batch, ctx.seq, None), None

    fn = _remat(body, cfg) if remat else body
    x, _ = stack_scan(fn, x, params["enc"], cfg)
    return _norm(params["ln_enc"], x, cfg)


def forward(params, tokens, frames, cfg: ArchConfig,
            ctx: ShardingCtx = NULL_CTX, mode: str = "train"):
    """Teacher-forced decoder over `tokens` given encoder `frames`."""
    memory = encode(params, frames, cfg, ctx, remat=(mode == "train"))
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, lp):
        x, cache = dec_block_apply(lp, x, memory, cfg=cfg, ctx=ctx,
                                   positions=positions)
        return ctx.ct(x, ctx.batch, ctx.seq, None), cache

    fn = _remat(body, cfg) if mode == "train" else body
    x, caches = stack_scan(fn, x, params["dec"], cfg)
    return _logits(params, x, cfg, ctx), {"stack": caches}, jnp.float32(0.0)


def decode_step(params, token, caches, pos, cfg: ArchConfig,
                ctx: ShardingCtx = NULL_CTX):
    """One decoder step; cross k/v and self KV cache come from `caches`."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    def body(x, pc):
        lp, c = pc
        x, c2 = dec_block_apply(lp, x, None, cfg=cfg, ctx=ctx,
                                positions=positions, cache=c, pos=pos)
        return x, c2

    x, new_caches = stack_scan(body, x, (params["dec"], caches["stack"]), cfg)
    return _logits(params, x, cfg, ctx), {"stack": new_caches}


def loss_fn(params, batch, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX):
    logits, _, _ = forward(params, batch["tokens"], batch["frames"], cfg, ctx,
                           mode="train")
    return softmax_xent(logits, batch["labels"])
