"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm: within chunks of length Q the dual (attention-like)
quadratic form runs on the MXU; across chunks a linear recurrence over the
[H, N, P] states runs under `lax.scan`.  Decode is the O(1)-per-token
recurrent update — the property that makes `long_500k` runnable for the
SSM/hybrid architectures (DESIGN.md §6).

Conventions (inclusive-cumsum): h_t = exp(a_t) h_{t-1} + dt_t B_t (x) x_t,
y_t = C_t . h_t + D x_t,  a_t = dt_t * A_h.  ngroups == 1 (B/C shared
across heads), as in the assigned mamba2-780m / zamba2 configs.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import COMPUTE_DTYPE, ShardingCtx, dense_init, rmsnorm


def ssm_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.headdim
    conv_ch = d_inner + 2 * s.state
    return d_inner, H, conv_ch


def ssm_params(key, cfg: ArchConfig):
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model,
                              2 * d_inner + 2 * s.state + H),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, 1, conv_ch),
                                     jnp.float32) * 0.1).astype(COMPUTE_DTYPE),
        "conv_b": jnp.zeros((conv_ch,), COMPUTE_DTYPE),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), COMPUTE_DTYPE),
        "out_proj": dense_init(ks[3], d_inner, cfg.d_model),
    }


def _split_proj(p, x, cfg: ArchConfig):
    s = cfg.ssm
    d_inner, H, _ = ssm_dims(cfg)
    zxbcdt = jnp.einsum("btd,dh->bth", x, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * s.state]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * s.state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    return z, xBC, dt


def _causal_conv(p, xBC, cfg: ArchConfig):
    w = cfg.ssm.conv_width
    pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, p["conv_w"].astype(xBC.dtype), (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xBC.shape[-1])
    return jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))


def ssm_apply(p, x, *, cfg: ArchConfig, ctx: ShardingCtx,
              state: Optional[dict] = None):
    """Full-sequence SSD.  x [B, T, D] (T % chunk == 0 after padding).

    Returns (y [B, T, D], final_state dict) — the state seeds decode.
    """
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    P, N, Q = s.headdim, s.state, s.chunk
    B_, T, _ = x.shape
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Tp = x.shape[1]
    nc = Tp // Q

    z, xBC, dt = _split_proj(p, x, cfg)
    xBC = _causal_conv(p, xBC, cfg)
    xs = xBC[..., :d_inner].reshape(B_, Tp, H, P)
    Bm = xBC[..., d_inner: d_inner + N].astype(jnp.float32)      # [B,T,N]
    Cm = xBC[..., d_inner + N:].astype(jnp.float32)              # [B,T,N]

    A = -jnp.exp(p["A_log"])                                     # [H]
    a = dt * A                                                   # [B,T,H] log decay
    # chunk views
    ac = a.reshape(B_, nc, Q, H)
    dtc = dt.reshape(B_, nc, Q, H)
    xc = xs.reshape(B_, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B_, nc, Q, N)
    Cc = Cm.reshape(B_, nc, Q, N)
    cum = jnp.cumsum(ac, axis=2)                                 # inclusive

    # ---- intra-chunk (dual quadratic form) ----
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)                   # [B,nc,Q,Q]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,nc,Q,Q,H]
    qi = jnp.arange(Q)
    mask = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    scores = CB[..., None] * jnp.where(mask, decay, 0.0) * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc)

    # ---- chunk states + inter-chunk recurrence ----
    last = cum[:, :, -1:, :]                                     # [B,nc,1,H]
    sdecay = jnp.exp(last - cum)                                 # [B,nc,Q,H]
    S_c = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", sdecay * dtc, Bc, xc)
    tot = jnp.exp(last[:, :, 0, :])                              # [B,nc,H]

    h0 = (state["h"] if state is not None
          else jnp.zeros((B_, H, N, P), jnp.float32))

    def step(h, inp):
        S_i, tot_i = inp
        h_new = tot_i[:, :, None, None] * h + S_i
        return h_new, h                                          # emit h_{c-1}

    hT, h_prev = jax.lax.scan(step, h0,
                              (S_c.transpose(1, 0, 2, 3, 4),
                               tot.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                     # [B,nc,H,N,P]
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, jnp.exp(cum), h_prev)

    y = (y_intra + y_inter).reshape(B_, Tp, H, P)
    y = y + p["D"][None, None, :, None] * xc.reshape(B_, Tp, H, P)
    y = y.reshape(B_, Tp, d_inner).astype(x.dtype)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    y = jnp.einsum("btd,dh->bth", y, p["out_proj"])
    if pad:
        y = y[:, :T]

    conv_state = xBC_raw_tail(p, x, cfg)                         # [B,w-1,conv_ch]
    return y, {"h": hT, "conv": conv_state}


def xBC_raw_tail(p, x, cfg: ArchConfig):
    """Last conv_width-1 pre-conv xBC rows (seed for decode's conv cache)."""
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    tail = x[:, -(s.conv_width - 1):, :]
    zxbcdt = jnp.einsum("btd,dh->bth", tail, p["in_proj"])
    return zxbcdt[..., d_inner: d_inner + conv_ch]


def ssm_decode_step(p, x, state, *, cfg: ArchConfig, ctx: ShardingCtx):
    """One-token recurrent update.  x [B, 1, D]; state {h, conv}."""
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    P, N = s.headdim, s.state
    B_ = x.shape[0]

    z, xBC, dt = _split_proj(p, x, cfg)                          # xBC [B,1,ch]
    window = jnp.concatenate([state["conv"], xBC], axis=1)       # [B,w,ch]
    conv_out = jnp.sum(window * p["conv_w"][:, 0, :].astype(x.dtype)[None],
                       axis=1, keepdims=True) + p["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out)                             # [B,1,ch]
    new_conv = window[:, 1:]

    xs = conv_out[..., :d_inner].reshape(B_, H, P).astype(jnp.float32)
    Bm = conv_out[..., d_inner: d_inner + N][:, 0].astype(jnp.float32)
    Cm = conv_out[..., d_inner + N:][:, 0].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dt1 = dt[:, 0]                                               # [B,H]
    decay = jnp.exp(dt1 * A)                                     # [B,H]
    h = (decay[:, :, None, None] * state["h"]
         + jnp.einsum("bh,bn,bhp->bhnp", dt1, Bm, xs))
    y = jnp.einsum("bn,bhnp->bhp", Cm, h) + p["D"][None, :, None] * xs
    y = y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    y = jnp.einsum("btd,dh->bth", y, p["out_proj"])
    return y, {"h": h, "conv": new_conv}
