"""Family dispatch: one uniform interface over decoder and enc-dec models,
plus cache-spec construction (for decode dry-runs without running prefill)."""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lm
from repro.models import ssm as ssm_mod
from repro.models.common import COMPUTE_DTYPE

MODEL_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


def build(cfg: ArchConfig):
    """Returns the module implementing init/forward/decode_step/loss_fn."""
    return encdec if cfg.family == "audio" else lm


def _cache_tree(cfg: ArchConfig, B: int, S: int,
                make: Callable[..., Any]) -> Dict[str, Any]:
    """Cache pytree for a decode step, leaves built by `make(shape, dtype)`.

    Matches exactly the pytree structure emitted by forward(mode='prefill')
    (asserted in tests/test_archs.py).
    """
    hd = cfg.hd

    def kv(prefix=(), length=S):
        if cfg.attn_type == "mla":
            m = cfg.mla
            return {"ckv": make((*prefix, B, length, m.kv_lora), COMPUTE_DTYPE),
                    "kr": make((*prefix, B, length, m.qk_rope), COMPUTE_DTYPE)}
        return {"k": make((*prefix, B, length, cfg.n_kv_heads, hd),
                          COMPUTE_DTYPE),
                "v": make((*prefix, B, length, cfg.n_kv_heads, hd),
                          COMPUTE_DTYPE)}

    def ssm_state(prefix=()):
        d_inner, H, conv_ch = ssm_mod.ssm_dims(cfg)
        s = cfg.ssm
        return {"h": make((*prefix, B, H, s.state, s.headdim), jnp.float32),
                "conv": make((*prefix, B, s.conv_width - 1, conv_ch),
                             COMPUTE_DTYPE)}

    win = min(S, cfg.sliding_window) if cfg.sliding_window else S
    if cfg.family in ("dense", "moe"):
        n_scan = cfg.n_layers - cfg.dense_first_n
        return {"head": [kv() for _ in range(cfg.dense_first_n)],
                "stack": kv(prefix=(n_scan,))}
    if cfg.family == "vlm":
        g = cfg.cross_attn_every
        ng = cfg.n_layers // g
        return {"stack": {
            "selfs": kv(prefix=(ng, g - 1)),
            "mem_kv": {"mk": make((ng, B, cfg.frontend_tokens, cfg.n_heads, hd),
                                  COMPUTE_DTYPE),
                       "mv": make((ng, B, cfg.frontend_tokens, cfg.n_heads, hd),
                                  COMPUTE_DTYPE)}}}
    if cfg.family == "ssm":
        return {"stack": ssm_state(prefix=(cfg.n_layers,))}
    if cfg.family == "hybrid":
        g = cfg.shared_attn_every
        ng = cfg.n_layers // g
        return {"stack": {"ssm": ssm_state(prefix=(ng, g)),
                          "attn_kv": kv(prefix=(ng,), length=win)}}
    if cfg.family == "audio":
        return {"stack": {
            "kv": kv(prefix=(cfg.n_layers,)),
            "mem_kv": {"mk": make((cfg.n_layers, B, S, cfg.n_heads, hd),
                                  COMPUTE_DTYPE),
                       "mv": make((cfg.n_layers, B, S, cfg.n_heads, hd),
                                  COMPUTE_DTYPE)}}}
    raise ValueError(cfg.family)


def cache_zeros(cfg: ArchConfig, B: int, S: int):
    """Materialized zero cache (smoke tests, serving loop)."""
    return _cache_tree(cfg, B, S, lambda shape, dt: jnp.zeros(shape, dt))


def cache_specs(cfg: ArchConfig, B: int, S: int):
    """ShapeDtypeStruct cache (dry-run: no allocation)."""
    return _cache_tree(cfg, B, S, jax.ShapeDtypeStruct)
