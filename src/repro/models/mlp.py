"""Feed-forward blocks: SwiGLU (llama family) and GeLU (seamless/enc-dec)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ShardingCtx, dense_init


def mlp_params(key, d_model: int, d_ff: int, act: str = "silu"):
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d_model, d_ff),
         "wo": dense_init(ks[1], d_ff, d_model)}
    if act == "silu":                     # SwiGLU needs the gate projection
        p["wg"] = dense_init(ks[2], d_model, d_ff)
    return p


def mlp_apply(p, x, *, act: str = "silu", ctx: ShardingCtx):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    # NOTE (§Perf iteration A): no explicit constraint on the hidden — the
    # column-parallel wi/wg sharding already propagates F-over-model, and an
    # explicit ct here forced a pathological S<->F resharding of the hidden
    # GRADIENT in backward (TB-scale all-gathers on qwen2-72b train).
    return ctx.ct_seq(jnp.einsum("bsf,fd->bsd", h, p["wo"]))
