"""Pure-jnp oracle for power_pack kernels — same contract, plain gathers."""

from __future__ import annotations

import jax.numpy as jnp


def pack_rows_ref(mat_wk, sel_w, sel_k):
    rows = jnp.take(mat_wk, sel_w, axis=0)
    return jnp.take_along_axis(rows, sel_k, axis=1)


def scatter_add_rows_ref(mat_wk, sel_w, sel_k, vals):
    return mat_wk.at[sel_w[:, None], sel_k].add(vals)
