"""jit'd wrappers for power_pack: padding to TPU tile multiples + dispatch.

Out-of-range (padding) topic indices hit all-zero one-hot rows, so padded
columns pack to 0 and scatter adds 0 — no masking needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import pad_axis as _pad_axis
from repro.kernels.power_pack.kernel import (pack_rows_pallas,
                                             scatter_add_rows_pallas)


@jax.jit
def pack_rows(mat_wk: jnp.ndarray, sel_w: jnp.ndarray,
              sel_k: jnp.ndarray) -> jnp.ndarray:
    P, Pk = sel_k.shape
    W, K = mat_wk.shape
    mat_p = _pad_axis(mat_wk.astype(jnp.float32), 1, 128)
    sel_k_p = _pad_axis(sel_k, 1, 128, value=mat_p.shape[1])  # OOR -> zero
    out = pack_rows_pallas(mat_p, sel_w, sel_k_p)
    return out[:, :Pk].astype(mat_wk.dtype)


@jax.jit
def scatter_add_rows(mat_wk: jnp.ndarray, sel_w: jnp.ndarray,
                     sel_k: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    W, K = mat_wk.shape
    mat_p = _pad_axis(mat_wk.astype(jnp.float32), 1, 128)
    sel_k_p = _pad_axis(sel_k, 1, 128, value=mat_p.shape[1])
    vals_p = _pad_axis(vals.astype(jnp.float32), 1, 128)
    out = scatter_add_rows_pallas(mat_p, sel_w, sel_k_p, vals_p)
    return out[:, :K].astype(mat_wk.dtype)
