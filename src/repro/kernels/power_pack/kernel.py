"""Power-submatrix pack/scatter kernels (the sync path's memory hot-spot).

TPU Pallas has no general dynamic gather, so the two-step selection is
realized TPU-natively:

  - the *row* gather (power words) uses scalar-prefetched indices in the
    BlockSpec index_map — the DMA engine fetches exactly the selected
    [1, K] rows of the [W, K] matrix from HBM, never touching the rest;
  - the *column* gather (power topics, per row) is a one-hot contraction
    `row[1,K] @ onehot[K,Pk]` on the MXU — branch-free and layout-friendly.

The inverse scatter aliases the destination matrix in-place and adds
`onehot @ vals` back into the selected rows only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K_


def _onehot(sel_row: jnp.ndarray, k_width: int) -> jnp.ndarray:
    """[Pk] int32 -> [Pk, K] f32 one-hot (out-of-range index -> zero row)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (sel_row.shape[0], k_width), 1)
    return (iota == sel_row[:, None]).astype(jnp.float32)


def _pack_kernel(sel_w_ref, sel_k_ref, mat_ref, out_ref):
    row = mat_ref[...]                                  # [1, K] selected row
    oh = _onehot(sel_k_ref[0], row.shape[1])            # [Pk, K]
    out_ref[...] = jax.lax.dot_general(
        row, oh, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # [1, Pk]


def _scatter_add_kernel(sel_w_ref, sel_k_ref, vals_ref, mat_ref, out_ref):
    oh = _onehot(sel_k_ref[0], out_ref.shape[1])        # [Pk, K]
    contrib = jax.lax.dot_general(
        vals_ref[...], oh, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # [1, K]
    out_ref[...] = mat_ref[...] + contrib


def pack_rows_pallas(mat_wk: jnp.ndarray, sel_w: jnp.ndarray,
                     sel_k: jnp.ndarray) -> jnp.ndarray:
    """out[p, j] = mat[sel_w[p], sel_k[p, j]] — [P, Pk] packed submatrix.

    Caller guarantees K % 128 == 0 and Pk % 128 == 0 (ops.py pads).
    """
    P, Pk = sel_k.shape
    W, K = mat_wk.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, Pk), lambda p, sel_w: (p, 0)),          # sel_k
            pl.BlockSpec((1, K), lambda p, sel_w: (sel_w[p], 0)),    # mat row
        ],
        out_specs=pl.BlockSpec((1, Pk), lambda p, sel_w: (p, 0)),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, Pk), jnp.float32),
        interpret=K_.INTERPRET,
    )(sel_w, sel_k, mat_wk)


def scatter_add_rows_pallas(mat_wk: jnp.ndarray, sel_w: jnp.ndarray,
                            sel_k: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """mat[sel_w[p], sel_k[p, j]] += vals[p, j], in place (aliased)."""
    P, Pk = sel_k.shape
    W, K = mat_wk.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, Pk), lambda p, sel_w: (p, 0)),          # sel_k
            pl.BlockSpec((1, Pk), lambda p, sel_w: (p, 0)),          # vals
            pl.BlockSpec((1, K), lambda p, sel_w: (sel_w[p], 0)),    # mat row
        ],
        out_specs=pl.BlockSpec((1, K), lambda p, sel_w: (sel_w[p], 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((W, K), jnp.float32),
        # input indices count the scalar-prefetch operand: sel_w=0, sel_k=1,
        # vals=2, mat=3 -> alias mat onto the (sole) output.
        input_output_aliases={3: 0},
        interpret=K_.INTERPRET,
    )(sel_w, sel_k, vals, mat_wk)
