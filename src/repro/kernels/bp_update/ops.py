"""jit'd wrapper: batch-level dense BP sweep backed by the Pallas kernel.

Handles layout (padded-CSR [D, L] -> token-major [T, K]), padding to tile
multiples, the per-token theta/phi gathers, and the residual scatter back to
[W, K].  Drop-in replacement for `repro.core.pobp.dense_sweep` when the
topic axis is not model-sharded (the normalization is fused in-kernel; the
sharded path keeps the jnp implementation — see DESIGN.md §4).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.residuals import token_scatter_wk
from repro.core.types import LDAConfig, MiniBatch, TokenLayout
from repro.kernels.bp_update.kernel import bp_update_tokens


def _pad_to(x: jnp.ndarray, axis: int, multiple: int):
    from repro.kernels import pad_axis
    return pad_axis(x, axis, multiple), x.shape[axis]


def dense_sweep_pallas(batch: MiniBatch, mu: jnp.ndarray,
                       phi_eff_wk: jnp.ndarray, phi_tot: jnp.ndarray,
                       cfg: LDAConfig, layout: TokenLayout = None,
                       wbeta=None):
    """Fused-kernel version of core.pobp.dense_sweep (K unsharded).

    Accepts an optional precomputed TokenLayout so callers that already
    run token-major (core.pobp's persistent inner loop) don't rebuild it.
    Returns (mu_new [D, L, K], r_wk [W, K]) — bitwise-compatible contract.
    A traced `wbeta` (the live_w*beta smoothing of a capacity-laddered
    run, DESIGN.md §12) folds into the phi_tot argument with the kernel's
    static wbeta pinned at 1.0 (the unit offset keeps padded lanes'
    denominator nonzero); the kernel itself needs no new code.
    """
    D, L = batch.word_ids.shape
    K = mu.shape[-1]
    layout = layout or batch.token_layout()
    theta = jnp.einsum("dl,dlk->dk", batch.counts, mu)
    if wbeta is None:
        wb_static = cfg.vocab_size * cfg.beta
    else:
        phi_tot, wb_static = phi_tot + (wbeta - 1.0), 1.0

    counts_t = layout.counts                                       # [T, 1]
    mu_t = mu.reshape(-1, K)
    theta_t = jnp.take(theta, layout.doc_ids, axis=0)              # token-major
    phi_t = jnp.take(phi_eff_wk, layout.word_ids, axis=0)

    # pad K to lane multiple; padded topics get phi_tot=+inf-ish guard via
    # zero phi & theta: u=alpha*beta/(wbeta) > 0 -> contributes to the norm!
    # So pad with theta=-alpha, phi=-beta => u = 0 exactly.
    kpad = (-K) % 128
    if kpad:
        mu_t = jnp.pad(mu_t, ((0, 0), (0, kpad)))
        theta_t = jnp.pad(theta_t, ((0, 0), (0, kpad)), constant_values=-cfg.alpha)
        phi_t = jnp.pad(phi_t, ((0, 0), (0, kpad)), constant_values=-cfg.beta)
        phi_tot_p = jnp.pad(phi_tot.reshape(1, -1), ((0, 0), (0, kpad)),
                            constant_values=1.0)
    else:
        phi_tot_p = phi_tot.reshape(1, -1)

    counts_t, T0 = _pad_to(counts_t, 0, 8)
    mu_t, _ = _pad_to(mu_t, 0, 8)
    theta_t, _ = _pad_to(theta_t, 0, 8)
    phi_t, _ = _pad_to(phi_t, 0, 8)

    mu_new_t, r_t = bp_update_tokens(
        counts_t, mu_t, theta_t, phi_t, phi_tot_p,
        alpha=cfg.alpha, beta=cfg.beta, wbeta=wb_static)

    mu_new = mu_new_t[:T0, :K].reshape(D, L, K)
    r_tok = r_t[:T0, :K].reshape(D, L, K)
    r_wk = token_scatter_wk(batch.word_ids, r_tok, cfg.vocab_size)
    return mu_new, r_wk
