"""Pure-jnp oracle for the fused bp_update kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bp_update_tokens_ref(counts_t, mu_t, theta_t, phi_t, phi_tot, *,
                         alpha: float, beta: float, wbeta: float):
    """Identical math to kernel.py, plain XLA ops.  [T, K] in, [T, K] out x2."""
    self_c = counts_t * mu_t
    th = theta_t - self_c + alpha
    ph = phi_t - self_c + beta
    pt = phi_tot - self_c + wbeta
    u = th * ph / pt
    mu_new = u / jnp.maximum(jnp.sum(u, -1, keepdims=True), 1e-30)
    r = counts_t * jnp.abs(mu_new - mu_t)
    return mu_new, r
