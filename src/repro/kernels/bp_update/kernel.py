"""Fused BP message update kernel (Eq. 1 + Eq. 7), token-major layout.

Tokens (the non-zero doc-word entries) are flattened to a [T, K] layout.
Each grid program owns a TT-token tile with the full (local) topic width K
resident in VMEM, computes

    u      = (theta - c*mu + alpha) * (phi - c*mu + beta) / (phi_tot - c*mu + W*beta)
    mu'    = u / sum_k u
    r      = c * |mu' - mu|

in one pass — five HBM streams (mu, theta, phi in; mu', r out) instead of the
~12 an unfused XLA graph issues, and zero [T, K] temporaries in HBM.

Tiling: TT is chosen so 5 * TT * K * 4 bytes fits in ~12.5 MB of VMEM
(leaving headroom of the 16 MB/core budget); K is padded to a multiple of
128 (lane width) and TT to a multiple of 8 (sublane width) by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import kernels as K_


def _kernel(counts_ref, mu_ref, theta_ref, phi_ref, phi_tot_ref,
            mu_out_ref, r_out_ref, *, alpha: float, beta: float, wbeta: float):
    c = counts_ref[...]                       # [TT, 1]
    mu = mu_ref[...]                          # [TT, K]
    self_c = c * mu
    th = theta_ref[...] - self_c + alpha
    ph = phi_ref[...] - self_c + beta
    pt = phi_tot_ref[...] - self_c + wbeta    # [1, K] broadcasts over TT
    u = th * ph / pt
    denom = jnp.sum(u, axis=-1, keepdims=True)
    mu_new = u / jnp.maximum(denom, 1e-30)
    mu_out_ref[...] = mu_new
    r_out_ref[...] = c * jnp.abs(mu_new - mu)


def token_tile(k_width: int, vmem_budget_bytes: int = 12_500_000) -> int:
    """Largest power-of-two TT in [8, 512] s.t. 5 [TT, K] f32 tiles fit VMEM.

    Power of two so the divisibility fallback (halving until TT | T, T a
    multiple of 8) never collapses to a degenerate non-aligned tile.
    """
    tt = max(8, min(512, vmem_budget_bytes // (5 * k_width * 4)))
    return 1 << (tt.bit_length() - 1)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "wbeta"))
def bp_update_tokens(counts_t: jnp.ndarray, mu_t: jnp.ndarray,
                     theta_t: jnp.ndarray, phi_t: jnp.ndarray,
                     phi_tot: jnp.ndarray, *, alpha: float, beta: float,
                     wbeta: float):
    """Token-major fused update.

    counts_t [T, 1], mu_t/theta_t/phi_t [T, K], phi_tot [1, K];
    T % TT == 0 and K % 128 == 0 are the caller's (ops.py) responsibility.
    Returns (mu_new [T, K], r_tok [T, K]).
    """
    T, K = mu_t.shape
    TT = token_tile(K)
    while T % TT:
        TT //= 2
    grid = (T // TT,)
    spec_tk = pl.BlockSpec((TT, K), lambda i: (i, 0))
    spec_c = pl.BlockSpec((TT, 1), lambda i: (i, 0))
    spec_pt = pl.BlockSpec((1, K), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, wbeta=wbeta),
        grid=grid,
        in_specs=[spec_c, spec_tk, spec_tk, spec_tk, spec_pt],
        out_specs=[spec_tk, spec_tk],
        out_shape=[jax.ShapeDtypeStruct((T, K), mu_t.dtype),
                   jax.ShapeDtypeStruct((T, K), mu_t.dtype)],
        interpret=K_.INTERPRET,
    )(counts_t, mu_t, theta_t, phi_t, phi_tot)
