"""jit'd wrapper for power_sweep: TPU tile padding + dispatch.

Padding contract (keeps the fused math exact — see kernel.py):
  - Pk -> lane multiple (128): mu/pt/phi pad 0, theta pads -alpha so the
    padded columns contribute u == 0 to the in-tile renormalization;
  - packed rows -> sublane multiple (8) past the P+1 guard row, zero rows;
  - T -> tile multiple: padded tokens carry p_tok == P (guard) and c == 0,
    so they update nothing and scatter exact zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pad_axis as _pad_axis
from repro.kernels.power_sweep.kernel import power_sweep_tokens


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "wbeta"))
def power_sweep(p_tok: jnp.ndarray, counts_t: jnp.ndarray,
                mu_sel: jnp.ndarray, theta_sel: jnp.ndarray,
                pt_sel: jnp.ndarray, phi_pack: jnp.ndarray, *,
                alpha: float, beta: float, wbeta: float):
    """Fused selective sweep over pre-gathered token tiles.

    p_tok [T] int32 in [0, P] (P => token not selected); counts_t [T, 1];
    mu_sel/theta_sel/pt_sel [T, Pk] gathered at the token's power topic
    coords; phi_pack [P, Pk] packed effective phi.
    Returns (mu_new_sel [T, Pk], d_pack [P, Pk], r_pack [P, Pk]).
    """
    T0, Pk = mu_sel.shape
    P = phi_pack.shape[0]
    f32 = jnp.float32

    mu_p = _pad_axis(mu_sel.astype(f32), 1, 128)
    th_p = _pad_axis(theta_sel.astype(f32), 1, 128, value=-alpha)
    pt_p = _pad_axis(pt_sel.astype(f32), 1, 128)
    phi_p = _pad_axis(_pad_axis(phi_pack.astype(f32), 1, 128), 0, 8,
                      value=0.0)
    if phi_p.shape[0] < P + 1:                    # guard row must exist
        phi_p = jnp.pad(phi_p, ((0, 8), (0, 0)))

    c_p = _pad_axis(counts_t.astype(f32), 0, 8)
    mu_p = _pad_axis(mu_p, 0, 8)
    th_p = _pad_axis(th_p, 0, 8, value=-alpha)
    pt_p = _pad_axis(pt_p, 0, 8)
    p_tok_p = _pad_axis(p_tok.astype(jnp.int32), 0, 8, value=P)

    mu_new, d_pack, r_pack = power_sweep_tokens(
        p_tok_p, c_p, mu_p, th_p, pt_p, phi_p,
        alpha=alpha, beta=beta, wbeta=wbeta, n_pow=P)
    return (mu_new[:T0, :Pk].astype(mu_sel.dtype),
            d_pack[:P, :Pk].astype(mu_sel.dtype),
            r_pack[:P, :Pk].astype(mu_sel.dtype))


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "wbeta",
                                             "update_phi", "kblocked",
                                             "kb", "vmem_budget_bytes"))
def power_sweep_carry(p_tok: jnp.ndarray, doc_ids: jnp.ndarray,
                      counts_t: jnp.ndarray, mu_t: jnp.ndarray,
                      theta: jnp.ndarray, phi_tot: jnp.ndarray,
                      phi_rows: jnp.ndarray, mask_rows: jnp.ndarray, *,
                      alpha: float, beta: float, wbeta: float,
                      update_phi: bool = True, kblocked: bool = False,
                      kb=None, vmem_budget_bytes=None):
    """Carry-resident megakernel over the full [T, K] mu carry.

    ``kblocked=True`` dispatches the K-blocked two-pass variant
    (DESIGN.md §13) under the identical padding contract — the lane
    padding already makes K a multiple of 128, which every candidate
    topic-block width divides; ``kb``/``vmem_budget_bytes`` tune the
    block width and the tile chooser's budget (default: env/global).

    p_tok [T] int32 in [0, P] (P = the guard row: non-power / frozen /
    padding tokens — mask zero, token untouched); doc_ids [T] int32;
    counts_t [T, 1]; mu_t [T, K]; theta [D, K] (the doc-topic statistic of
    mu_t); phi_tot [K] (the Eq. 1 denominator row); phi_rows/mask_rows
    [P+1, K] — packed phi rows densified over K with their 0/1 topic
    selection, guard row all zeros.  On the serving path
    ``update_phi=False`` the selection is implicit (every row but the
    guard selects all topics — the kernel compares p_tok against the
    guard id instead of gathering a mask, and ``mask_rows`` is replaced
    by a dummy); ``beta`` must be 0 there so the K lane padding keeps
    u == 0 exactly.

    Padding contract (keeps the fused math exact — see kernel.py):
      - K -> lane multiple (128): mask pads 0, so padded columns carry
        u == 0 and mu stays bit-identical (phi_tot pads 0, denominator
        wbeta > 0 keeps the division finite);
      - rows -> sublane multiple (8): zero phi/mask rows;
      - D -> sublane multiple (8): no doc_id points there, rows accumulate
        exact zeros;
      - T -> tile multiple: padded tokens carry p_tok == P (guard) and
        c == 0, so they update nothing and accumulate exact zeros.

    Returns (mu_new [T, K], theta_delta [D, K], d_rows [P, K],
    r_rows [P, K], rdoc [D]).  The mode-dead outputs come back as zeros
    of truncated shape (the kernel never allocates them at full size):
    d_rows/r_rows are [0, K] on the serving path ``update_phi=False``,
    rdoc (the per-doc |c*delta| mass) is all-zero [D] on the training
    path.
    """
    from repro.kernels.power_sweep.kernel import (
        power_sweep_carry_kblocked_tokens, power_sweep_carry_tokens)

    T0, K0 = mu_t.shape
    P = phi_rows.shape[0] - 1
    D0 = theta.shape[0]
    f32 = jnp.float32

    if not update_phi and beta != 0.0:
        raise ValueError("power_sweep_carry(update_phi=False) requires "
                         "beta == 0 (serving phi is pre-normalized; a "
                         "nonzero beta would leak into the lane padding)")

    mu_p = _pad_axis(_pad_axis(mu_t.astype(f32), 1, 128), 0, 8)
    th_p = _pad_axis(_pad_axis(theta.astype(f32), 1, 128), 0, 8)
    pt_p = _pad_axis(phi_tot.astype(f32).reshape(1, -1), 1, 128)
    phi_p = _pad_axis(_pad_axis(phi_rows.astype(f32), 1, 128), 0, 8)
    if update_phi:
        msk_p = _pad_axis(_pad_axis(mask_rows.astype(f32), 1, 128), 0, 8)
    else:  # implicit all-topics mask: ship a sublane-sized dummy instead
        msk_p = jnp.zeros((8, phi_p.shape[1]), f32)
    c_p = _pad_axis(counts_t.astype(f32), 0, 8)
    p_tok_p = _pad_axis(p_tok.astype(jnp.int32), 0, 8, value=P)
    doc_p = _pad_axis(doc_ids.astype(jnp.int32), 0, 8)

    if kblocked:
        sweep_fn = functools.partial(power_sweep_carry_kblocked_tokens,
                                     kb=kb,
                                     vmem_budget_bytes=vmem_budget_bytes)
    else:
        sweep_fn = functools.partial(power_sweep_carry_tokens,
                                     vmem_budget_bytes=vmem_budget_bytes)
    mu_new, th_delta, d_rows, r_rows, rd_rows = sweep_fn(
        p_tok_p, doc_p, c_p, mu_p, th_p, pt_p, phi_p, msk_p,
        alpha=alpha, beta=beta, wbeta=wbeta, update_phi=update_phi,
        n_guard=P)
    dt = mu_t.dtype
    n_keep = P if update_phi else 0
    return (mu_new[:T0, :K0].astype(dt),
            th_delta[:D0, :K0].astype(dt),
            d_rows[:n_keep, :K0].astype(dt),
            r_rows[:n_keep, :K0].astype(dt),
            (jnp.sum(rd_rows[:D0, :K0], axis=1) if not update_phi
             else jnp.zeros((D0,), jnp.float32)).astype(dt))


def power_sweep_carry_kblocked(*args, **kwargs):
    """`power_sweep_carry` pinned to the K-blocked two-pass kernel."""
    return power_sweep_carry(*args, kblocked=True, **kwargs)
