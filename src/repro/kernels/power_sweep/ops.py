"""jit'd wrapper for power_sweep: TPU tile padding + dispatch.

Padding contract (keeps the fused math exact — see kernel.py):
  - Pk -> lane multiple (128): mu/pt/phi pad 0, theta pads -alpha so the
    padded columns contribute u == 0 to the in-tile renormalization;
  - packed rows -> sublane multiple (8) past the P+1 guard row, zero rows;
  - T -> tile multiple: padded tokens carry p_tok == P (guard) and c == 0,
    so they update nothing and scatter exact zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pad_axis as _pad_axis
from repro.kernels.power_sweep.kernel import power_sweep_tokens


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "wbeta"))
def power_sweep(p_tok: jnp.ndarray, counts_t: jnp.ndarray,
                mu_sel: jnp.ndarray, theta_sel: jnp.ndarray,
                pt_sel: jnp.ndarray, phi_pack: jnp.ndarray, *,
                alpha: float, beta: float, wbeta: float):
    """Fused selective sweep over pre-gathered token tiles.

    p_tok [T] int32 in [0, P] (P => token not selected); counts_t [T, 1];
    mu_sel/theta_sel/pt_sel [T, Pk] gathered at the token's power topic
    coords; phi_pack [P, Pk] packed effective phi.
    Returns (mu_new_sel [T, Pk], d_pack [P, Pk], r_pack [P, Pk]).
    """
    T0, Pk = mu_sel.shape
    P = phi_pack.shape[0]
    f32 = jnp.float32

    mu_p = _pad_axis(mu_sel.astype(f32), 1, 128)
    th_p = _pad_axis(theta_sel.astype(f32), 1, 128, value=-alpha)
    pt_p = _pad_axis(pt_sel.astype(f32), 1, 128)
    phi_p = _pad_axis(_pad_axis(phi_pack.astype(f32), 1, 128), 0, 8,
                      value=0.0)
    if phi_p.shape[0] < P + 1:                    # guard row must exist
        phi_p = jnp.pad(phi_p, ((0, 8), (0, 0)))

    c_p = _pad_axis(counts_t.astype(f32), 0, 8)
    mu_p = _pad_axis(mu_p, 0, 8)
    th_p = _pad_axis(th_p, 0, 8, value=-alpha)
    pt_p = _pad_axis(pt_p, 0, 8)
    p_tok_p = _pad_axis(p_tok.astype(jnp.int32), 0, 8, value=P)

    mu_new, d_pack, r_pack = power_sweep_tokens(
        p_tok_p, c_p, mu_p, th_p, pt_p, phi_p,
        alpha=alpha, beta=beta, wbeta=wbeta, n_pow=P)
    return (mu_new[:T0, :Pk].astype(mu_sel.dtype),
            d_pack[:P, :Pk].astype(mu_sel.dtype),
            r_pack[:P, :Pk].astype(mu_sel.dtype))
