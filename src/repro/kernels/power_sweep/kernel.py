"""Fused selective power-sweep kernels (Fig. 4 lines 15-21, token-major).

Three kernels share this package:

  - ``power_sweep_tokens`` — the packed-stream kernel: pre-gathered
    [T, Pk] tiles in, updated [T, Pk] tiles + packed [P1, Pk] buffers out
    (the caller folds the tiles back into the carry);
  - ``power_sweep_carry_tokens`` — the carry-resident megakernel: the
    full [TT, K] mu carry tile loads into VMEM, the packed-phi/mask row
    gathers, the selective update + mass-conserving renorm, the fold-back,
    the per-doc theta delta and the [P1, K] delta/residual accumulation
    all happen in that one grid pass (one HBM read + one write of the
    carry per iteration; every gather/scatter is an MXU one-hot
    contraction).  A static ``update_phi=False`` turns the same kernel
    into the serving fold-in body (core/infer): phi is a normalized
    constant (no self-count subtraction, zero packed outputs) and the
    per-doc |delta| residual accumulates instead.
  - ``power_sweep_carry_kblocked_tokens`` — the K-blocked megakernel
    (DESIGN.md §13): the same carry-resident math tiled as [TT, KB]
    topic blocks over a 2D grid, so the token tile no longer shrinks
    with K.  The mass-conserving renormalization needs complete per-token
    row sums over ALL of K before any mu can be rewritten, and a Pallas
    output block may only be revisited on consecutive grid steps — so the
    sweep runs as two pallas_calls: a **sums pass** with K blocks
    innermost (per-token mass/denominator accumulators stay grid-resident
    at [TT, 1]) and an **update pass** with token tiles innermost (the
    per-K-block table accumulators stay grid-resident at [rows, KB]).
    The update pass recomputes the u block instead of staging a [T, K]
    temporary — the gathers run twice, trading MXU flops for the VMEM/HBM
    a staged u would cost.  One K block covering all of K routes straight
    back to the one-pass megakernel: the full-K kernel is the NKB == 1
    specialization of this path.

One packed-stream grid pass performs, entirely in VMEM:

  1. the per-token gather of the packed phi power rows — the tile's
     scalar-prefetched power-row ids ``p_tok`` select rows of the
     VMEM-resident ``phi_pack [P1, Pk]`` through an MXU one-hot contraction
     (TPU Pallas has no dynamic vector gather; cf. kernels/power_pack);
  2. the selective message update + mass-conserving renormalization
     (Eq. 1 restricted to the power submatrix, DESIGN.md §2):
         u   = (theta_sel - c*mu + alpha)(phi_sel - c*mu + beta)
               / (pt_sel - c*mu + W*beta)
         mu' = u * mass / sum_j u        on power tokens, mu otherwise;
  3. the packed delta/residual scatter: ``onehot^T @ (c*d)`` accumulates
     straight into the [P1, Pk] sync buffers, which live in VMEM across the
     whole grid (their BlockSpec index is constant) and are written back to
     HBM once — the token loop never touches a [W, K] or [T, K] temporary.

Non-power and padding tokens carry ``p_tok == n_pow`` (the guard row):
their mask keeps mu unchanged, so their deltas are exactly zero and the
guard row accumulates nothing but zeros.

Layout contract (ops.py): Pk padded to 128 lanes with theta padded to
-alpha (=> u == 0 on pad columns), T padded to a tile multiple with zero
counts, packed rows padded to a sublane multiple with zero phi rows.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K_

# default per-core VMEM byte budget for the tile choosers; override per
# call (LDAConfig.vmem_budget_bytes) or process-wide via the
# REPRO_VMEM_BUDGET_BYTES environment variable
DEFAULT_VMEM_BUDGET = 12_500_000


def vmem_budget(override=None) -> int:
    """Resolve the VMEM byte budget: explicit override > env > default."""
    if override is not None:
        return int(override)
    env = os.environ.get("REPRO_VMEM_BUDGET_BYTES", "")
    return int(env) if env else DEFAULT_VMEM_BUDGET


def _pow2_tile(fixed_bytes: int, per_token_bytes: int, budget: int) -> int:
    """Largest power-of-two TT in [8, 512] fitting the VMEM budget.

    ``fixed_bytes`` is the grid-resident footprint (tables/accumulators
    whose BlockSpec index is constant), ``per_token_bytes`` the marginal
    cost of one carry row.  Power of two so `fit_token_tile`'s halving
    always lands on a full sublane-aligned tile; floors at 8 even when
    the fixed footprint alone busts the budget — that case surfaces as a
    Mosaic VMEM error on real TPU rather than a silent wrong answer.
    """
    tt = max(8, min(512, max(0, budget - fixed_bytes) // per_token_bytes))
    return 1 << (tt.bit_length() - 1)


def fit_token_tile(n_tokens: int, tt: int) -> int:
    """Shrink TT (power of two) until it divides T, clamped at the floor
    of 8.  T not divisible by 8 is a caller bug — the grid would silently
    drop the trailing tokens — so it raises instead of degenerating to
    TT < 8 (ops.py always pads T to a multiple of 8).
    """
    while n_tokens % tt and tt > 8:
        tt //= 2
    if n_tokens % tt:
        raise ValueError(
            f"token count {n_tokens} is not a multiple of the minimum "
            f"tile 8; pad T before calling (see ops.py padding contract)")
    return tt


def _kernel(p_tok_ref, c_ref, mu_ref, th_ref, pt_ref, phi_ref,
            mu_out_ref, d_out_ref, r_out_ref, *,
            alpha: float, beta: float, wbeta: float, tt: int, n_pow: int):
    i = pl.program_id(0)
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    n_rows = phi_ref.shape[0]
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (tt, n_rows), 1)
    onehot = (iota_p == p_tile[:, None]).astype(jnp.float32)   # [TT, P1]

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, Pk]
    phi_sel = jax.lax.dot_general(                             # MXU row gather
        onehot, phi_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [TT, Pk]

    self_c = c * mu
    th = th_ref[...] - self_c + alpha
    ph = phi_sel - self_c + beta
    pt = pt_ref[...] - self_c + wbeta
    u = th * ph / pt
    mass = jnp.sum(mu, axis=-1, keepdims=True)                 # conserved mass
    denom = jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new = u * mass / denom
    is_power = (p_tile < n_pow)[:, None]
    mu_new = jnp.where(is_power, mu_new, mu)

    d_mu = mu_new - mu
    dv = c * d_mu
    rv = c * jnp.abs(d_mu)
    mu_out_ref[...] = mu_new

    # packed scatter: guard row n_pow only ever receives exact zeros
    contrib_d = jax.lax.dot_general(
        onehot, dv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [P1, Pk]
    contrib_r = jax.lax.dot_general(
        onehot, rv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        r_out_ref[...] = jnp.zeros_like(r_out_ref)

    d_out_ref[...] += contrib_d
    r_out_ref[...] += contrib_r


def token_tile(pk_width: int, n_rows: int,
               vmem_budget_bytes=None) -> int:
    """Packed-stream tile: 5 [TT, Pk] tiles + the [TT, P1] one-hot +
    3 [P1, Pk] packed buffers (phi in, delta/residual out), all f32.
    Budget resolves via `vmem_budget` (override > env > default)."""
    fixed = 3 * n_rows * pk_width * 4
    per_token = (5 * pk_width + n_rows) * 4
    return _pow2_tile(fixed, per_token, vmem_budget(vmem_budget_bytes))


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "wbeta", "n_pow"))
def power_sweep_tokens(p_tok: jnp.ndarray, counts_t: jnp.ndarray,
                       mu_sel: jnp.ndarray, theta_sel: jnp.ndarray,
                       pt_sel: jnp.ndarray, phi_pack: jnp.ndarray, *,
                       alpha: float, beta: float, wbeta: float, n_pow: int):
    """Fused selective update over pre-gathered [T, Pk] token tiles.

    p_tok [T] int32 power-row id per token (n_pow => not selected);
    counts_t [T, 1]; mu_sel/theta_sel/pt_sel [T, Pk]; phi_pack [P1, Pk]
    with P1 > n_pow.  T % TT == 0, Pk % 128 == 0 and P1 % 8 == 0 are the
    caller's (ops.py) responsibility.
    Returns (mu_new_sel [T, Pk], d_pack [P1, Pk], r_pack [P1, Pk]).
    """
    T, Pk = mu_sel.shape
    P1 = phi_pack.shape[0]
    TT = fit_token_tile(T, token_tile(Pk, P1))
    grid = (T // TT,)
    spec_tk = pl.BlockSpec((TT, Pk), lambda i, p_tok: (i, 0))
    spec_c = pl.BlockSpec((TT, 1), lambda i, p_tok: (i, 0))
    spec_pack = pl.BlockSpec((P1, Pk), lambda i, p_tok: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[spec_c, spec_tk, spec_tk, spec_tk, spec_pack],
        out_specs=[spec_tk, spec_pack, spec_pack],
    )
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, wbeta=wbeta,
                          tt=TT, n_pow=n_pow),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((T, Pk), jnp.float32),
                   jax.ShapeDtypeStruct((P1, Pk), jnp.float32),
                   jax.ShapeDtypeStruct((P1, Pk), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, counts_t, mu_sel, theta_sel, pt_sel, phi_pack)


# --------------------------------------------------------------------------
# carry-resident megakernel (dense-layout formulation, DESIGN.md §2)
# --------------------------------------------------------------------------


def _block_terms(p_tile, d_tile, c, mu, theta_ref, pt_ref, phi_ref,
                 mask_ref, *, alpha: float, beta: float, wbeta: float,
                 update_phi: bool, n_guard: int):
    """One [TT, KB] block of the selective update, shared by the full-K
    carry kernel (KB == K) and both passes of the K-blocked pair.

    Gathers the block's phi/theta rows through MXU one-hot contractions
    and returns (u, m_tok, onehot_p, onehot_d) — the unnormalized message
    u = th*ph/pt masked by the token's topic selection.  The
    renormalization (mass / sum u) is the caller's job: it needs the
    complete row sum over all of K, which a K block cannot see.
    """
    tt = mu.shape[0]
    n_rows = phi_ref.shape[0]                                  # P1 (padded)
    n_docs = theta_ref.shape[0]                                # D  (padded)
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (tt, n_rows), 1)
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (tt, n_docs), 1)
    onehot_p = (iota_p == p_tile[:, None]).astype(jnp.float32) # [TT, P1]
    onehot_d = (iota_d == d_tile[:, None]).astype(jnp.float32) # [TT, D]

    row_dims = (((1,), (0,)), ((), ()))
    phi_tok = jax.lax.dot_general(                             # MXU row gathers
        onehot_p, phi_ref[...], row_dims,
        preferred_element_type=jnp.float32)                    # [TT, KB]
    theta_tok = jax.lax.dot_general(
        onehot_d, theta_ref[...], row_dims,
        preferred_element_type=jnp.float32)                    # [TT, KB]

    self_c = c * mu
    th = theta_tok - self_c + alpha
    if update_phi:
        m_tok = jax.lax.dot_general(
            onehot_p, mask_ref[...], row_dims,
            preferred_element_type=jnp.float32)                # [TT, KB]
        ph = phi_tok - self_c + beta
        pt = pt_ref[...] - self_c + wbeta
    else:
        # serving fold-in: every live row selects ALL topics, so the mask
        # collapses to one guard compare per token (mask_ref is a dummy —
        # no [W, K] ones table in VMEM, no second full-vocab one-hot dot);
        # phi is a fixed normalized constant (the caller passes beta = 0,
        # keeping the K lane padding at u == 0 exactly) and the
        # denominator trick (pt_ref = 0, wbeta = 1) makes pt exactly 1
        m_tok = (p_tile != n_guard)[:, None].astype(jnp.float32)
        ph = phi_tok + beta
        pt = pt_ref[...] + wbeta                               # [1, KB] bcast
    u = th * ph / pt * m_tok
    return u, m_tok, onehot_p, onehot_d


def _carry_kernel(p_tok_ref, doc_ref, c_ref, mu_ref, theta_ref, pt_ref,
                  phi_ref, mask_ref,
                  mu_out_ref, th_out_ref, d_out_ref, r_out_ref, rd_out_ref,
                  *, alpha: float, beta: float, wbeta: float, tt: int,
                  update_phi: bool, n_guard: int):
    i = pl.program_id(0)
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    d_tile = pl.load(doc_ref, (pl.dslice(i * tt, tt),))        # [TT] int32

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, K]
    u, m_tok, onehot_p, onehot_d = _block_terms(
        p_tile, d_tile, c, mu, theta_ref, pt_ref, phi_ref, mask_ref,
        alpha=alpha, beta=beta, wbeta=wbeta, update_phi=update_phi,
        n_guard=n_guard)
    mass = jnp.sum(mu * m_tok, axis=-1, keepdims=True)         # conserved
    denom = jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new = jnp.where(m_tok > 0, u * (mass / denom), mu)
    mu_out_ref[...] = mu_new                                   # fold-back

    cd = c * (mu_new - mu)
    acc_dims = (((0,), (0,)), ((), ()))

    @pl.when(i == 0)
    def _init():
        th_out_ref[...] = jnp.zeros_like(th_out_ref)
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        r_out_ref[...] = jnp.zeros_like(r_out_ref)
        rd_out_ref[...] = jnp.zeros_like(rd_out_ref)

    th_out_ref[...] += jax.lax.dot_general(                    # theta delta
        onehot_d, cd, acc_dims, preferred_element_type=jnp.float32)
    if update_phi:
        d_out_ref[...] += jax.lax.dot_general(
            onehot_p, cd, acc_dims, preferred_element_type=jnp.float32)
        r_out_ref[...] += jax.lax.dot_general(
            onehot_p, jnp.abs(cd), acc_dims,
            preferred_element_type=jnp.float32)
    else:
        rd_out_ref[...] += jax.lax.dot_general(                # doc residual
            onehot_d, jnp.abs(cd), acc_dims,
            preferred_element_type=jnp.float32)


def _carry_footprint(k_width: int, n_rows: int, n_docs: int):
    """(fixed, per_token) f32 bytes of the carry kernel at block width
    ``k_width``: ~5 [TT, k] tiles + [TT, P1]/[TT, D] one-hots per token,
    and the grid-resident tables/accumulators (phi/mask/d/r at [P1, k],
    theta in/out + rd at [D, k])."""
    fixed = (4 * n_rows + 3 * n_docs) * k_width * 4
    per_token = (5 * k_width + n_rows + n_docs) * 4
    return fixed, per_token


def carry_token_tile(k_width: int, n_rows: int, n_docs: int,
                     vmem_budget_bytes=None) -> int:
    """Carry-kernel tile at block width ``k_width`` (the full K for the
    one-pass megakernel, KB for the K-blocked pair).  Same power-of-two /
    floor-at-8 contract as `token_tile`; budget via `vmem_budget`."""
    fixed, per_token = _carry_footprint(k_width, n_rows, n_docs)
    return _pow2_tile(fixed, per_token, vmem_budget(vmem_budget_bytes))


def carry_vmem_fits(k_width: int, n_rows: int, n_docs: int,
                    vmem_budget_bytes=None, min_tile: int = 64) -> bool:
    """Does the carry kernel fit the VMEM budget at block width
    ``k_width`` with a usefully large token tile?

    The chooser floors TT at 8 no matter what, so "fits" here means the
    fixed tables plus ``min_tile`` carry rows stay inside the budget — a
    tile below ~64 re-fetches the grid-resident tables so often the
    kernel loses to the K-blocked path anyway.  This is the dispatch-side
    predicate `core.sweep_dispatch` uses to pick full-K vs kblocked.
    """
    fixed, per_token = _carry_footprint(k_width, n_rows, n_docs)
    return fixed + min_tile * per_token <= vmem_budget(vmem_budget_bytes)


def kblock_width(k_width: int, n_rows: int, n_docs: int,
                 vmem_budget_bytes=None) -> int:
    """Topic-block width KB for the K-blocked sweep: the largest of
    (512, 256, 128) dividing K whose carry footprint passes
    `carry_vmem_fits`, else the smallest divisor (the Mosaic VMEM error
    then surfaces on real TPU instead of a silent wrong answer).
    K must be lane-padded (multiple of 128) so 128 always divides.
    """
    if k_width % 128:
        raise ValueError(f"kblock_width needs K padded to 128, got {k_width}")
    cands = [d for d in (512, 256, 128) if k_width % d == 0]
    for d in cands:
        if carry_vmem_fits(d, n_rows, n_docs, vmem_budget_bytes):
            return d
    return cands[-1]


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "wbeta", "update_phi",
                                    "n_guard", "vmem_budget_bytes"))
def power_sweep_carry_tokens(p_tok: jnp.ndarray, doc_ids: jnp.ndarray,
                             counts_t: jnp.ndarray, mu_t: jnp.ndarray,
                             theta: jnp.ndarray, pt_row: jnp.ndarray,
                             phi_rows: jnp.ndarray, mask_rows: jnp.ndarray,
                             *, alpha: float, beta: float, wbeta: float,
                             update_phi: bool = True, n_guard: int = -1,
                             vmem_budget_bytes=None):
    """Carry-resident selective sweep over the full [T, K] carry.

    p_tok [T] int32 power-row id per token (rows with an all-zero mask —
    the guard row and padding — leave the token untouched); doc_ids [T]
    int32; counts_t [T, 1]; mu_t [T, K]; theta [D, K]; pt_row [1, K]
    (phi_tot, the update denominator); phi_rows/mask_rows [P1, K].
    T % TT == 0, K % 128 == 0, P1 % 8 == 0 and D % 8 == 0 are the
    caller's (ops.py) responsibility.
    Returns (mu_new [T, K], theta_delta [D, K], d_rows, r_rows, rdoc_rows).

    On the serving path ``update_phi=False`` the selection collapses to
    "every row but the guard selects all topics": the mask derives from
    one compare against the static ``n_guard`` (the logical guard-row id,
    required when not update_phi) and ``mask_rows`` may be a dummy — no
    [W, K] ones table in VMEM, no second full-vocab one-hot contraction.
    Mode-dead accumulators shrink to an (8, K) dummy so they cost no HBM
    on the hot path: d_rows/r_rows are [P1, K] only when ``update_phi``
    (else (8, K) of zeros), rdoc_rows is [D, K] only when not (else
    (8, K) of zeros).
    """
    if not update_phi and n_guard < 0:
        raise ValueError("update_phi=False requires the static n_guard "
                         "(logical guard-row id) for the mask compare")
    T, K = mu_t.shape
    P1 = phi_rows.shape[0]
    D = theta.shape[0]
    n_mask = mask_rows.shape[0]
    TT = fit_token_tile(T, carry_token_tile(K, P1, D, vmem_budget_bytes))
    grid = (T // TT,)
    n_dr = P1 if update_phi else 8
    n_rd = 8 if update_phi else D
    spec_tk = pl.BlockSpec((TT, K), lambda i, p_tok, doc_ids: (i, 0))
    spec_c = pl.BlockSpec((TT, 1), lambda i, p_tok, doc_ids: (i, 0))
    spec_rows = pl.BlockSpec((P1, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_mask = pl.BlockSpec((n_mask, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_dr = pl.BlockSpec((n_dr, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_docs = pl.BlockSpec((D, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_rd = pl.BlockSpec((n_rd, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_pt = pl.BlockSpec((1, K), lambda i, p_tok, doc_ids: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[spec_c, spec_tk, spec_docs, spec_pt, spec_rows, spec_mask],
        out_specs=[spec_tk, spec_docs, spec_dr, spec_dr, spec_rd],
    )
    return pl.pallas_call(
        functools.partial(_carry_kernel, alpha=alpha, beta=beta,
                          wbeta=wbeta, tt=TT, update_phi=update_phi,
                          n_guard=n_guard),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((T, K), jnp.float32),
                   jax.ShapeDtypeStruct((D, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_dr, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_dr, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_rd, K), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, doc_ids, counts_t, mu_t, theta, pt_row, phi_rows, mask_rows)


# --------------------------------------------------------------------------
# K-blocked carry megakernel (ultra-high-K formulation, DESIGN.md §13)
# --------------------------------------------------------------------------


def _carry_sums_kernel(p_tok_ref, doc_ref, c_ref, mu_ref, theta_ref, pt_ref,
                       phi_ref, mask_ref, mass_ref, denom_ref, *,
                       alpha: float, beta: float, wbeta: float, tt: int,
                       update_phi: bool, n_guard: int):
    """Pass 1 of the K-blocked sweep: complete the per-token row sums.

    Grid (T//TT, NKB) with K blocks innermost, so the [TT, 1] mass and
    denominator outputs are revisited only on consecutive steps (the
    Pallas output-revisit rule) and stay grid-resident while the token
    tile's K blocks stream through VMEM.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    d_tile = pl.load(doc_ref, (pl.dslice(i * tt, tt),))        # [TT] int32

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, KB]
    u, m_tok, _, _ = _block_terms(
        p_tile, d_tile, c, mu, theta_ref, pt_ref, phi_ref, mask_ref,
        alpha=alpha, beta=beta, wbeta=wbeta, update_phi=update_phi,
        n_guard=n_guard)

    @pl.when(j == 0)
    def _init():
        mass_ref[...] = jnp.zeros_like(mass_ref)
        denom_ref[...] = jnp.zeros_like(denom_ref)

    mass_ref[...] += jnp.sum(mu * m_tok, axis=-1, keepdims=True)
    denom_ref[...] += jnp.sum(u, axis=-1, keepdims=True)


def _carry_update_kernel(p_tok_ref, doc_ref, c_ref, mass_ref, denom_ref,
                         mu_ref, theta_ref, pt_ref, phi_ref, mask_ref,
                         mu_out_ref, th_out_ref, d_out_ref, r_out_ref,
                         rd_out_ref, *, alpha: float, beta: float,
                         wbeta: float, tt: int, update_phi: bool,
                         n_guard: int):
    """Pass 2 of the K-blocked sweep: renormalize, fold back, accumulate.

    Grid (NKB, T//TT) with token tiles innermost, so each K block's
    [rows, KB] table accumulators (theta delta, packed d/r, doc residual)
    stay grid-resident across the whole token stream and are written to
    HBM once per block.  u is recomputed from the same inputs as pass 1 —
    the gathers run twice, which is cheaper than staging a [T, K] u.
    """
    j = pl.program_id(0)                                       # K block
    i = pl.program_id(1)                                       # token tile
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    d_tile = pl.load(doc_ref, (pl.dslice(i * tt, tt),))        # [TT] int32

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, KB]
    u, m_tok, onehot_p, onehot_d = _block_terms(
        p_tile, d_tile, c, mu, theta_ref, pt_ref, phi_ref, mask_ref,
        alpha=alpha, beta=beta, wbeta=wbeta, update_phi=update_phi,
        n_guard=n_guard)
    mass = mass_ref[...]                                       # complete sums
    denom = jnp.maximum(denom_ref[...], 1e-30)
    mu_new = jnp.where(m_tok > 0, u * (mass / denom), mu)
    mu_out_ref[...] = mu_new                                   # fold-back

    cd = c * (mu_new - mu)
    acc_dims = (((0,), (0,)), ((), ()))

    @pl.when(i == 0)
    def _init():
        th_out_ref[...] = jnp.zeros_like(th_out_ref)
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        r_out_ref[...] = jnp.zeros_like(r_out_ref)
        rd_out_ref[...] = jnp.zeros_like(rd_out_ref)

    th_out_ref[...] += jax.lax.dot_general(                    # theta delta
        onehot_d, cd, acc_dims, preferred_element_type=jnp.float32)
    if update_phi:
        d_out_ref[...] += jax.lax.dot_general(
            onehot_p, cd, acc_dims, preferred_element_type=jnp.float32)
        r_out_ref[...] += jax.lax.dot_general(
            onehot_p, jnp.abs(cd), acc_dims,
            preferred_element_type=jnp.float32)
    else:
        rd_out_ref[...] += jax.lax.dot_general(                # doc residual
            onehot_d, jnp.abs(cd), acc_dims,
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "wbeta", "update_phi",
                                    "n_guard", "kb", "vmem_budget_bytes"))
def power_sweep_carry_kblocked_tokens(
        p_tok: jnp.ndarray, doc_ids: jnp.ndarray, counts_t: jnp.ndarray,
        mu_t: jnp.ndarray, theta: jnp.ndarray, pt_row: jnp.ndarray,
        phi_rows: jnp.ndarray, mask_rows: jnp.ndarray, *,
        alpha: float, beta: float, wbeta: float, update_phi: bool = True,
        n_guard: int = -1, kb=None, vmem_budget_bytes=None):
    """K-blocked carry-resident sweep: identical contract and outputs as
    `power_sweep_carry_tokens`, with the carry tiled as [TT, KB] topic
    blocks over a 2D grid so TT no longer shrinks with K.

    ``kb`` pins the topic-block width (must divide K); by default
    `kblock_width` picks the largest of (512, 256, 128) whose footprint
    fits the VMEM budget.  A single block covering all of K routes back
    to the one-pass megakernel — the full-K kernel is the NKB == 1
    specialization.  Results differ from full-K only by the summation
    order of the renormalization reductions (float associativity).
    """
    T, K = mu_t.shape
    P1 = phi_rows.shape[0]
    D = theta.shape[0]
    n_mask = mask_rows.shape[0]
    KB = int(kb) if kb else kblock_width(K, P1, D, vmem_budget_bytes)
    if K % KB:
        raise ValueError(f"kb={KB} must divide the padded K={K}")
    if KB >= K:
        return power_sweep_carry_tokens(
            p_tok, doc_ids, counts_t, mu_t, theta, pt_row, phi_rows,
            mask_rows, alpha=alpha, beta=beta, wbeta=wbeta,
            update_phi=update_phi, n_guard=n_guard,
            vmem_budget_bytes=vmem_budget_bytes)
    if not update_phi and n_guard < 0:
        raise ValueError("update_phi=False requires the static n_guard "
                         "(logical guard-row id) for the mask compare")
    NKB = K // KB
    TT = fit_token_tile(T, carry_token_tile(KB, P1, D, vmem_budget_bytes))
    n_dr = P1 if update_phi else 8
    n_rd = 8 if update_phi else D
    body = dict(alpha=alpha, beta=beta, wbeta=wbeta, tt=TT,
                update_phi=update_phi, n_guard=n_guard)

    # pass 1 — K blocks innermost: per-token sums stay grid-resident
    s_tk = pl.BlockSpec((TT, KB), lambda i, j, p_tok, doc_ids: (i, j))
    s_c = pl.BlockSpec((TT, 1), lambda i, j, p_tok, doc_ids: (i, 0))
    s_rows = pl.BlockSpec((P1, KB), lambda i, j, p_tok, doc_ids: (0, j))
    s_mask = pl.BlockSpec((n_mask, KB), lambda i, j, p_tok, doc_ids: (0, j))
    s_docs = pl.BlockSpec((D, KB), lambda i, j, p_tok, doc_ids: (0, j))
    s_pt = pl.BlockSpec((1, KB), lambda i, j, p_tok, doc_ids: (0, j))
    s_sum = pl.BlockSpec((TT, 1), lambda i, j, p_tok, doc_ids: (i, 0))
    sums_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T // TT, NKB),
        in_specs=[s_c, s_tk, s_docs, s_pt, s_rows, s_mask],
        out_specs=[s_sum, s_sum],
    )
    mass, denom = pl.pallas_call(
        functools.partial(_carry_sums_kernel, **body),
        grid_spec=sums_spec,
        out_shape=[jax.ShapeDtypeStruct((T, 1), jnp.float32),
                   jax.ShapeDtypeStruct((T, 1), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, doc_ids, counts_t, mu_t, theta, pt_row, phi_rows, mask_rows)

    # pass 2 — token tiles innermost: table accumulators stay grid-resident
    u_tk = pl.BlockSpec((TT, KB), lambda j, i, p_tok, doc_ids: (i, j))
    u_c = pl.BlockSpec((TT, 1), lambda j, i, p_tok, doc_ids: (i, 0))
    u_rows = pl.BlockSpec((P1, KB), lambda j, i, p_tok, doc_ids: (0, j))
    u_mask = pl.BlockSpec((n_mask, KB), lambda j, i, p_tok, doc_ids: (0, j))
    u_docs = pl.BlockSpec((D, KB), lambda j, i, p_tok, doc_ids: (0, j))
    u_pt = pl.BlockSpec((1, KB), lambda j, i, p_tok, doc_ids: (0, j))
    u_dr = pl.BlockSpec((n_dr, KB), lambda j, i, p_tok, doc_ids: (0, j))
    u_rd = pl.BlockSpec((n_rd, KB), lambda j, i, p_tok, doc_ids: (0, j))
    u_sum = pl.BlockSpec((TT, 1), lambda j, i, p_tok, doc_ids: (i, 0))
    upd_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(NKB, T // TT),
        in_specs=[u_c, u_sum, u_sum, u_tk, u_docs, u_pt, u_rows, u_mask],
        out_specs=[u_tk, u_docs, u_dr, u_dr, u_rd],
    )
    return pl.pallas_call(
        functools.partial(_carry_update_kernel, **body),
        grid_spec=upd_spec,
        out_shape=[jax.ShapeDtypeStruct((T, K), jnp.float32),
                   jax.ShapeDtypeStruct((D, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_dr, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_dr, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_rd, K), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, doc_ids, counts_t, mass, denom, mu_t, theta, pt_row,
      phi_rows, mask_rows)
