"""Fused selective power-sweep kernels (Fig. 4 lines 15-21, token-major).

Two kernels share this package:

  - ``power_sweep_tokens`` — the packed-stream kernel: pre-gathered
    [T, Pk] tiles in, updated [T, Pk] tiles + packed [P1, Pk] buffers out
    (the caller folds the tiles back into the carry);
  - ``power_sweep_carry_tokens`` — the carry-resident megakernel: the
    full [TT, K] mu carry tile loads into VMEM, the packed-phi/mask row
    gathers, the selective update + mass-conserving renorm, the fold-back,
    the per-doc theta delta and the [P1, K] delta/residual accumulation
    all happen in that one grid pass (one HBM read + one write of the
    carry per iteration; every gather/scatter is an MXU one-hot
    contraction).  A static ``update_phi=False`` turns the same kernel
    into the serving fold-in body (core/infer): phi is a normalized
    constant (no self-count subtraction, zero packed outputs) and the
    per-doc |delta| residual accumulates instead.

One packed-stream grid pass performs, entirely in VMEM:

  1. the per-token gather of the packed phi power rows — the tile's
     scalar-prefetched power-row ids ``p_tok`` select rows of the
     VMEM-resident ``phi_pack [P1, Pk]`` through an MXU one-hot contraction
     (TPU Pallas has no dynamic vector gather; cf. kernels/power_pack);
  2. the selective message update + mass-conserving renormalization
     (Eq. 1 restricted to the power submatrix, DESIGN.md §2):
         u   = (theta_sel - c*mu + alpha)(phi_sel - c*mu + beta)
               / (pt_sel - c*mu + W*beta)
         mu' = u * mass / sum_j u        on power tokens, mu otherwise;
  3. the packed delta/residual scatter: ``onehot^T @ (c*d)`` accumulates
     straight into the [P1, Pk] sync buffers, which live in VMEM across the
     whole grid (their BlockSpec index is constant) and are written back to
     HBM once — the token loop never touches a [W, K] or [T, K] temporary.

Non-power and padding tokens carry ``p_tok == n_pow`` (the guard row):
their mask keeps mu unchanged, so their deltas are exactly zero and the
guard row accumulates nothing but zeros.

Layout contract (ops.py): Pk padded to 128 lanes with theta padded to
-alpha (=> u == 0 on pad columns), T padded to a tile multiple with zero
counts, packed rows padded to a sublane multiple with zero phi rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K_


def _kernel(p_tok_ref, c_ref, mu_ref, th_ref, pt_ref, phi_ref,
            mu_out_ref, d_out_ref, r_out_ref, *,
            alpha: float, beta: float, wbeta: float, tt: int, n_pow: int):
    i = pl.program_id(0)
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    n_rows = phi_ref.shape[0]
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (tt, n_rows), 1)
    onehot = (iota_p == p_tile[:, None]).astype(jnp.float32)   # [TT, P1]

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, Pk]
    phi_sel = jax.lax.dot_general(                             # MXU row gather
        onehot, phi_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [TT, Pk]

    self_c = c * mu
    th = th_ref[...] - self_c + alpha
    ph = phi_sel - self_c + beta
    pt = pt_ref[...] - self_c + wbeta
    u = th * ph / pt
    mass = jnp.sum(mu, axis=-1, keepdims=True)                 # conserved mass
    denom = jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new = u * mass / denom
    is_power = (p_tile < n_pow)[:, None]
    mu_new = jnp.where(is_power, mu_new, mu)

    d_mu = mu_new - mu
    dv = c * d_mu
    rv = c * jnp.abs(d_mu)
    mu_out_ref[...] = mu_new

    # packed scatter: guard row n_pow only ever receives exact zeros
    contrib_d = jax.lax.dot_general(
        onehot, dv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [P1, Pk]
    contrib_r = jax.lax.dot_general(
        onehot, rv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        r_out_ref[...] = jnp.zeros_like(r_out_ref)

    d_out_ref[...] += contrib_d
    r_out_ref[...] += contrib_r


def token_tile(pk_width: int, n_rows: int,
               vmem_budget_bytes: int = 12_500_000) -> int:
    """Largest power-of-two TT in [8, 512] fitting the VMEM budget.

    Resident per grid step: 5 [TT, Pk] tiles + the [TT, P1] one-hot +
    3 [P1, Pk] packed buffers (phi in, delta/residual out), all f32.
    Power of two so the caller's divisibility fallback (halving until
    TT | T, with T padded to a multiple of 8) always lands on a full
    sublane-aligned tile instead of collapsing to a degenerate size.
    Floors at 8 even when the resident packed buffers alone bust the
    budget (huge P1) — that case surfaces as a Mosaic VMEM error on real
    TPU rather than a silent wrong answer.
    """
    fixed = 3 * n_rows * pk_width * 4
    per_token = (5 * pk_width + n_rows) * 4
    tt = max(8, min(512, max(0, vmem_budget_bytes - fixed) // per_token))
    return 1 << (tt.bit_length() - 1)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "wbeta", "n_pow"))
def power_sweep_tokens(p_tok: jnp.ndarray, counts_t: jnp.ndarray,
                       mu_sel: jnp.ndarray, theta_sel: jnp.ndarray,
                       pt_sel: jnp.ndarray, phi_pack: jnp.ndarray, *,
                       alpha: float, beta: float, wbeta: float, n_pow: int):
    """Fused selective update over pre-gathered [T, Pk] token tiles.

    p_tok [T] int32 power-row id per token (n_pow => not selected);
    counts_t [T, 1]; mu_sel/theta_sel/pt_sel [T, Pk]; phi_pack [P1, Pk]
    with P1 > n_pow.  T % TT == 0, Pk % 128 == 0 and P1 % 8 == 0 are the
    caller's (ops.py) responsibility.
    Returns (mu_new_sel [T, Pk], d_pack [P1, Pk], r_pack [P1, Pk]).
    """
    T, Pk = mu_sel.shape
    P1 = phi_pack.shape[0]
    TT = token_tile(Pk, P1)
    while T % TT:
        TT //= 2
    grid = (T // TT,)
    spec_tk = pl.BlockSpec((TT, Pk), lambda i, p_tok: (i, 0))
    spec_c = pl.BlockSpec((TT, 1), lambda i, p_tok: (i, 0))
    spec_pack = pl.BlockSpec((P1, Pk), lambda i, p_tok: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[spec_c, spec_tk, spec_tk, spec_tk, spec_pack],
        out_specs=[spec_tk, spec_pack, spec_pack],
    )
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, wbeta=wbeta,
                          tt=TT, n_pow=n_pow),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((T, Pk), jnp.float32),
                   jax.ShapeDtypeStruct((P1, Pk), jnp.float32),
                   jax.ShapeDtypeStruct((P1, Pk), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, counts_t, mu_sel, theta_sel, pt_sel, phi_pack)


# --------------------------------------------------------------------------
# carry-resident megakernel (dense-layout formulation, DESIGN.md §2)
# --------------------------------------------------------------------------


def _carry_kernel(p_tok_ref, doc_ref, c_ref, mu_ref, theta_ref, pt_ref,
                  phi_ref, mask_ref,
                  mu_out_ref, th_out_ref, d_out_ref, r_out_ref, rd_out_ref,
                  *, alpha: float, beta: float, wbeta: float, tt: int,
                  update_phi: bool, n_guard: int):
    i = pl.program_id(0)
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    d_tile = pl.load(doc_ref, (pl.dslice(i * tt, tt),))        # [TT] int32
    n_rows = phi_ref.shape[0]                                  # P1 (padded)
    n_docs = theta_ref.shape[0]                                # D  (padded)
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (tt, n_rows), 1)
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (tt, n_docs), 1)
    onehot_p = (iota_p == p_tile[:, None]).astype(jnp.float32) # [TT, P1]
    onehot_d = (iota_d == d_tile[:, None]).astype(jnp.float32) # [TT, D]

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, K]
    row_dims = (((1,), (0,)), ((), ()))
    phi_tok = jax.lax.dot_general(                             # MXU row gathers
        onehot_p, phi_ref[...], row_dims,
        preferred_element_type=jnp.float32)                    # [TT, K]
    theta_tok = jax.lax.dot_general(
        onehot_d, theta_ref[...], row_dims,
        preferred_element_type=jnp.float32)                    # [TT, K]

    self_c = c * mu
    th = theta_tok - self_c + alpha
    if update_phi:
        m_tok = jax.lax.dot_general(
            onehot_p, mask_ref[...], row_dims,
            preferred_element_type=jnp.float32)                # [TT, K]
        ph = phi_tok - self_c + beta
        pt = pt_ref[...] - self_c + wbeta
    else:
        # serving fold-in: every live row selects ALL topics, so the mask
        # collapses to one guard compare per token (mask_ref is a dummy —
        # no [W, K] ones table in VMEM, no second full-vocab one-hot dot);
        # phi is a fixed normalized constant (the caller passes beta = 0,
        # keeping the K lane padding at u == 0 exactly) and the
        # denominator trick (pt_ref = 0, wbeta = 1) makes pt exactly 1
        m_tok = (p_tile != n_guard)[:, None].astype(jnp.float32)
        ph = phi_tok + beta
        pt = pt_ref[...] + wbeta                               # [1, K] bcast
    u = th * ph / pt * m_tok
    mass = jnp.sum(mu * m_tok, axis=-1, keepdims=True)         # conserved
    denom = jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new = jnp.where(m_tok > 0, u * (mass / denom), mu)
    mu_out_ref[...] = mu_new                                   # fold-back

    cd = c * (mu_new - mu)
    acc_dims = (((0,), (0,)), ((), ()))

    @pl.when(i == 0)
    def _init():
        th_out_ref[...] = jnp.zeros_like(th_out_ref)
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        r_out_ref[...] = jnp.zeros_like(r_out_ref)
        rd_out_ref[...] = jnp.zeros_like(rd_out_ref)

    th_out_ref[...] += jax.lax.dot_general(                    # theta delta
        onehot_d, cd, acc_dims, preferred_element_type=jnp.float32)
    if update_phi:
        d_out_ref[...] += jax.lax.dot_general(
            onehot_p, cd, acc_dims, preferred_element_type=jnp.float32)
        r_out_ref[...] += jax.lax.dot_general(
            onehot_p, jnp.abs(cd), acc_dims,
            preferred_element_type=jnp.float32)
    else:
        rd_out_ref[...] += jax.lax.dot_general(                # doc residual
            onehot_d, jnp.abs(cd), acc_dims,
            preferred_element_type=jnp.float32)


def carry_token_tile(k_width: int, n_rows: int, n_docs: int,
                     vmem_budget_bytes: int = 12_500_000) -> int:
    """Largest power-of-two TT in [8, 512] fitting the VMEM budget.

    Resident per grid step: ~5 [TT, K] tiles, the [TT, P1] + [TT, D]
    one-hots, and the grid-resident tables/accumulators (phi/mask/d/r at
    [P1, K], theta in/out + rd at [D, K]), all f32.  Same power-of-two /
    floor-at-8 contract as `token_tile`.
    """
    fixed = (4 * n_rows + 3 * n_docs) * k_width * 4
    per_token = (5 * k_width + n_rows + n_docs) * 4
    tt = max(8, min(512, max(0, vmem_budget_bytes - fixed) // per_token))
    return 1 << (tt.bit_length() - 1)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "wbeta", "update_phi",
                                    "n_guard"))
def power_sweep_carry_tokens(p_tok: jnp.ndarray, doc_ids: jnp.ndarray,
                             counts_t: jnp.ndarray, mu_t: jnp.ndarray,
                             theta: jnp.ndarray, pt_row: jnp.ndarray,
                             phi_rows: jnp.ndarray, mask_rows: jnp.ndarray,
                             *, alpha: float, beta: float, wbeta: float,
                             update_phi: bool = True, n_guard: int = -1):
    """Carry-resident selective sweep over the full [T, K] carry.

    p_tok [T] int32 power-row id per token (rows with an all-zero mask —
    the guard row and padding — leave the token untouched); doc_ids [T]
    int32; counts_t [T, 1]; mu_t [T, K]; theta [D, K]; pt_row [1, K]
    (phi_tot, the update denominator); phi_rows/mask_rows [P1, K].
    T % TT == 0, K % 128 == 0, P1 % 8 == 0 and D % 8 == 0 are the
    caller's (ops.py) responsibility.
    Returns (mu_new [T, K], theta_delta [D, K], d_rows, r_rows, rdoc_rows).

    On the serving path ``update_phi=False`` the selection collapses to
    "every row but the guard selects all topics": the mask derives from
    one compare against the static ``n_guard`` (the logical guard-row id,
    required when not update_phi) and ``mask_rows`` may be a dummy — no
    [W, K] ones table in VMEM, no second full-vocab one-hot contraction.
    Mode-dead accumulators shrink to an (8, K) dummy so they cost no HBM
    on the hot path: d_rows/r_rows are [P1, K] only when ``update_phi``
    (else (8, K) of zeros), rdoc_rows is [D, K] only when not (else
    (8, K) of zeros).
    """
    if not update_phi and n_guard < 0:
        raise ValueError("update_phi=False requires the static n_guard "
                         "(logical guard-row id) for the mask compare")
    T, K = mu_t.shape
    P1 = phi_rows.shape[0]
    D = theta.shape[0]
    n_mask = mask_rows.shape[0]
    TT = carry_token_tile(K, P1, D)
    while T % TT:
        TT //= 2
    grid = (T // TT,)
    n_dr = P1 if update_phi else 8
    n_rd = 8 if update_phi else D
    spec_tk = pl.BlockSpec((TT, K), lambda i, p_tok, doc_ids: (i, 0))
    spec_c = pl.BlockSpec((TT, 1), lambda i, p_tok, doc_ids: (i, 0))
    spec_rows = pl.BlockSpec((P1, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_mask = pl.BlockSpec((n_mask, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_dr = pl.BlockSpec((n_dr, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_docs = pl.BlockSpec((D, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_rd = pl.BlockSpec((n_rd, K), lambda i, p_tok, doc_ids: (0, 0))
    spec_pt = pl.BlockSpec((1, K), lambda i, p_tok, doc_ids: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[spec_c, spec_tk, spec_docs, spec_pt, spec_rows, spec_mask],
        out_specs=[spec_tk, spec_docs, spec_dr, spec_dr, spec_rd],
    )
    return pl.pallas_call(
        functools.partial(_carry_kernel, alpha=alpha, beta=beta,
                          wbeta=wbeta, tt=TT, update_phi=update_phi,
                          n_guard=n_guard),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((T, K), jnp.float32),
                   jax.ShapeDtypeStruct((D, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_dr, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_dr, K), jnp.float32),
                   jax.ShapeDtypeStruct((n_rd, K), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, doc_ids, counts_t, mu_t, theta, pt_row, phi_rows, mask_rows)
