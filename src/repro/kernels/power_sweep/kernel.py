"""Fused selective power-sweep kernel (Fig. 4 lines 15-21, token-major).

One grid pass over token tiles performs, entirely in VMEM:

  1. the per-token gather of the packed phi power rows — the tile's
     scalar-prefetched power-row ids ``p_tok`` select rows of the
     VMEM-resident ``phi_pack [P1, Pk]`` through an MXU one-hot contraction
     (TPU Pallas has no dynamic vector gather; cf. kernels/power_pack);
  2. the selective message update + mass-conserving renormalization
     (Eq. 1 restricted to the power submatrix, DESIGN.md §2):
         u   = (theta_sel - c*mu + alpha)(phi_sel - c*mu + beta)
               / (pt_sel - c*mu + W*beta)
         mu' = u * mass / sum_j u        on power tokens, mu otherwise;
  3. the packed delta/residual scatter: ``onehot^T @ (c*d)`` accumulates
     straight into the [P1, Pk] sync buffers, which live in VMEM across the
     whole grid (their BlockSpec index is constant) and are written back to
     HBM once — the token loop never touches a [W, K] or [T, K] temporary.

Non-power and padding tokens carry ``p_tok == n_pow`` (the guard row):
their mask keeps mu unchanged, so their deltas are exactly zero and the
guard row accumulates nothing but zeros.

Layout contract (ops.py): Pk padded to 128 lanes with theta padded to
-alpha (=> u == 0 on pad columns), T padded to a tile multiple with zero
counts, packed rows padded to a sublane multiple with zero phi rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K_


def _kernel(p_tok_ref, c_ref, mu_ref, th_ref, pt_ref, phi_ref,
            mu_out_ref, d_out_ref, r_out_ref, *,
            alpha: float, beta: float, wbeta: float, tt: int, n_pow: int):
    i = pl.program_id(0)
    p_tile = pl.load(p_tok_ref, (pl.dslice(i * tt, tt),))      # [TT] int32
    n_rows = phi_ref.shape[0]
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (tt, n_rows), 1)
    onehot = (iota_p == p_tile[:, None]).astype(jnp.float32)   # [TT, P1]

    c = c_ref[...]                                             # [TT, 1]
    mu = mu_ref[...]                                           # [TT, Pk]
    phi_sel = jax.lax.dot_general(                             # MXU row gather
        onehot, phi_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [TT, Pk]

    self_c = c * mu
    th = th_ref[...] - self_c + alpha
    ph = phi_sel - self_c + beta
    pt = pt_ref[...] - self_c + wbeta
    u = th * ph / pt
    mass = jnp.sum(mu, axis=-1, keepdims=True)                 # conserved mass
    denom = jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new = u * mass / denom
    is_power = (p_tile < n_pow)[:, None]
    mu_new = jnp.where(is_power, mu_new, mu)

    d_mu = mu_new - mu
    dv = c * d_mu
    rv = c * jnp.abs(d_mu)
    mu_out_ref[...] = mu_new

    # packed scatter: guard row n_pow only ever receives exact zeros
    contrib_d = jax.lax.dot_general(
        onehot, dv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # [P1, Pk]
    contrib_r = jax.lax.dot_general(
        onehot, rv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        d_out_ref[...] = jnp.zeros_like(d_out_ref)
        r_out_ref[...] = jnp.zeros_like(r_out_ref)

    d_out_ref[...] += contrib_d
    r_out_ref[...] += contrib_r


def token_tile(pk_width: int, n_rows: int,
               vmem_budget_bytes: int = 12_500_000) -> int:
    """Largest power-of-two TT in [8, 512] fitting the VMEM budget.

    Resident per grid step: 5 [TT, Pk] tiles + the [TT, P1] one-hot +
    3 [P1, Pk] packed buffers (phi in, delta/residual out), all f32.
    Power of two so the caller's divisibility fallback (halving until
    TT | T, with T padded to a multiple of 8) always lands on a full
    sublane-aligned tile instead of collapsing to a degenerate size.
    Floors at 8 even when the resident packed buffers alone bust the
    budget (huge P1) — that case surfaces as a Mosaic VMEM error on real
    TPU rather than a silent wrong answer.
    """
    fixed = 3 * n_rows * pk_width * 4
    per_token = (5 * pk_width + n_rows) * 4
    tt = max(8, min(512, max(0, vmem_budget_bytes - fixed) // per_token))
    return 1 << (tt.bit_length() - 1)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "wbeta", "n_pow"))
def power_sweep_tokens(p_tok: jnp.ndarray, counts_t: jnp.ndarray,
                       mu_sel: jnp.ndarray, theta_sel: jnp.ndarray,
                       pt_sel: jnp.ndarray, phi_pack: jnp.ndarray, *,
                       alpha: float, beta: float, wbeta: float, n_pow: int):
    """Fused selective update over pre-gathered [T, Pk] token tiles.

    p_tok [T] int32 power-row id per token (n_pow => not selected);
    counts_t [T, 1]; mu_sel/theta_sel/pt_sel [T, Pk]; phi_pack [P1, Pk]
    with P1 > n_pow.  T % TT == 0, Pk % 128 == 0 and P1 % 8 == 0 are the
    caller's (ops.py) responsibility.
    Returns (mu_new_sel [T, Pk], d_pack [P1, Pk], r_pack [P1, Pk]).
    """
    T, Pk = mu_sel.shape
    P1 = phi_pack.shape[0]
    TT = token_tile(Pk, P1)
    while T % TT:
        TT //= 2
    grid = (T // TT,)
    spec_tk = pl.BlockSpec((TT, Pk), lambda i, p_tok: (i, 0))
    spec_c = pl.BlockSpec((TT, 1), lambda i, p_tok: (i, 0))
    spec_pack = pl.BlockSpec((P1, Pk), lambda i, p_tok: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[spec_c, spec_tk, spec_tk, spec_tk, spec_pack],
        out_specs=[spec_tk, spec_pack, spec_pack],
    )
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, wbeta=wbeta,
                          tt=TT, n_pow=n_pow),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((T, Pk), jnp.float32),
                   jax.ShapeDtypeStruct((P1, Pk), jnp.float32),
                   jax.ShapeDtypeStruct((P1, Pk), jnp.float32)],
        interpret=K_.INTERPRET,
    )(p_tok, counts_t, mu_sel, theta_sel, pt_sel, phi_pack)
