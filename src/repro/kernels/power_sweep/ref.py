"""Pure-jnp oracle for the fused power_sweep kernel — same contract."""

from __future__ import annotations

import jax.numpy as jnp


def power_sweep_tokens_ref(p_tok, counts_t, mu_sel, theta_sel, pt_sel,
                           phi_pack, *, alpha: float, beta: float,
                           wbeta: float, n_pow: int):
    """Identical math to kernel.py in plain XLA ops.

    Shapes as in kernel.power_sweep_tokens (no padding requirements here:
    phi_pack [P1, Pk] only needs P1 > n_pow so the guard row exists).
    Returns (mu_new_sel [T, Pk], d_pack [P1, Pk], r_pack [P1, Pk]).
    """
    P1 = phi_pack.shape[0]
    is_power = (p_tok < n_pow)[:, None]
    phi_sel = jnp.take(phi_pack, p_tok, axis=0)
    self_c = counts_t * mu_sel
    th = theta_sel - self_c + alpha
    ph = phi_sel - self_c + beta
    pt = pt_sel - self_c + wbeta
    u = th * ph / pt
    mass = jnp.sum(mu_sel, axis=-1, keepdims=True)
    mu_new = u * mass / jnp.maximum(jnp.sum(u, -1, keepdims=True), 1e-30)
    mu_new = jnp.where(is_power, mu_new, mu_sel)
    d_mu = mu_new - mu_sel
    zeros = jnp.zeros((P1, mu_sel.shape[1]), jnp.float32)
    d_pack = zeros.at[p_tok].add(counts_t * d_mu)
    r_pack = zeros.at[p_tok].add(counts_t * jnp.abs(d_mu))
    # the guard row only ever collects exact zeros; clear it regardless so
    # both implementations agree bit-for-bit
    d_pack = d_pack.at[n_pow].set(0.0)
    r_pack = r_pack.at[n_pow].set(0.0)
    return mu_new, d_pack, r_pack


def power_sweep_carry_ref(p_tok, doc_ids, counts_t, mu_t, theta, phi_tot,
                          phi_rows, mask_rows, *, alpha: float, beta: float,
                          wbeta: float, update_phi: bool = True):
    """Identical math to kernel._carry_kernel in plain XLA ops.

    Shapes as in ops.power_sweep_carry before padding: mu_t [T, K], theta
    [D, K], phi_rows/mask_rows [P+1, K] (guard row last, all zeros).
    Returns (mu_new [T, K], theta_delta [D, K], d_rows [P, K],
    r_rows [P, K], rdoc [D]).
    """
    P = phi_rows.shape[0] - 1
    if update_phi:
        m_tok = jnp.take(mask_rows, p_tok, axis=0)              # [T, K]
    else:
        # serving mode: every row but the guard selects all topics — the
        # mask is implicit (one guard compare), mask_rows is ignored
        m_tok = jnp.broadcast_to((p_tok != P)[:, None].astype(jnp.float32),
                                 mu_t.shape)
    phi_tok = jnp.take(phi_rows, p_tok, axis=0)
    theta_tok = jnp.take(theta, doc_ids, axis=0)
    self_c = counts_t * mu_t
    th = theta_tok - self_c + alpha
    if update_phi:
        ph = phi_tok - self_c + beta
        pt = phi_tot[None, :] - self_c + wbeta
    else:
        ph = phi_tok + beta
        pt = jnp.broadcast_to(phi_tot[None, :] + wbeta, mu_t.shape)
    u = th * ph / pt * m_tok
    mass = jnp.sum(mu_t * m_tok, axis=-1, keepdims=True)
    denom = jnp.maximum(jnp.sum(u, axis=-1, keepdims=True), 1e-30)
    mu_new = jnp.where(m_tok > 0, u * (mass / denom), mu_t)
    cd = counts_t * (mu_new - mu_t)
    theta_delta = jnp.zeros_like(theta).at[doc_ids].add(cd)
    zeros_rows = jnp.zeros((P, mu_t.shape[1]), jnp.float32)
    if update_phi:
        d_rows = zeros_rows.at[p_tok].add(cd, mode="drop")
        r_rows = zeros_rows.at[p_tok].add(jnp.abs(cd), mode="drop")
        rdoc = jnp.zeros((theta.shape[0],), jnp.float32)
    else:
        d_rows = r_rows = zeros_rows
        rdoc = jnp.zeros((theta.shape[0],), jnp.float32).at[doc_ids].add(
            jnp.sum(jnp.abs(cd), axis=1))
    return mu_new, theta_delta, d_rows, r_rows, rdoc


def power_sweep_carry_kblocked_ref(*args, kb=None, **kwargs):
    """Oracle for the K-blocked kernel.  Topic blocking only changes the
    summation order of the renormalization reductions (float
    associativity) — the math is the full-K reference's; ``kb`` is
    accepted and ignored."""
    return power_sweep_carry_ref(*args, **kwargs)
