"""Pure-jnp oracle for the fused power_sweep kernel — same contract."""

from __future__ import annotations

import jax.numpy as jnp


def power_sweep_tokens_ref(p_tok, counts_t, mu_sel, theta_sel, pt_sel,
                           phi_pack, *, alpha: float, beta: float,
                           wbeta: float, n_pow: int):
    """Identical math to kernel.py in plain XLA ops.

    Shapes as in kernel.power_sweep_tokens (no padding requirements here:
    phi_pack [P1, Pk] only needs P1 > n_pow so the guard row exists).
    Returns (mu_new_sel [T, Pk], d_pack [P1, Pk], r_pack [P1, Pk]).
    """
    P1 = phi_pack.shape[0]
    is_power = (p_tok < n_pow)[:, None]
    phi_sel = jnp.take(phi_pack, p_tok, axis=0)
    self_c = counts_t * mu_sel
    th = theta_sel - self_c + alpha
    ph = phi_sel - self_c + beta
    pt = pt_sel - self_c + wbeta
    u = th * ph / pt
    mass = jnp.sum(mu_sel, axis=-1, keepdims=True)
    mu_new = u * mass / jnp.maximum(jnp.sum(u, -1, keepdims=True), 1e-30)
    mu_new = jnp.where(is_power, mu_new, mu_sel)
    d_mu = mu_new - mu_sel
    zeros = jnp.zeros((P1, mu_sel.shape[1]), jnp.float32)
    d_pack = zeros.at[p_tok].add(counts_t * d_mu)
    r_pack = zeros.at[p_tok].add(counts_t * jnp.abs(d_mu))
    # the guard row only ever collects exact zeros; clear it regardless so
    # both implementations agree bit-for-bit
    d_pack = d_pack.at[n_pow].set(0.0)
    r_pack = r_pack.at[n_pow].set(0.0)
    return mu_new, d_pack, r_pack
