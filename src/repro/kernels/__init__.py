"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's inner loop — the BP message update over non-zero doc-word
entries (Eq. 1) — dominates computation (Table 2: eta*lambda_K*lambda_W*KWDT).
`bp_update` fuses the update arithmetic, normalization and residual into one
VMEM-resident pass.  `power_pack` implements the packed gather/scatter of the
power submatrix (the sync path's memory hot-spot) with MXU-friendly one-hot
contractions instead of unsupported dynamic gathers.

Kernels target TPU (pl.pallas_call + BlockSpec); on CPU they run with
``interpret=True`` which executes the kernel body in Python — the mode used
by this container's test suite.
"""

import jax

# interpret=True everywhere except on real TPU.
INTERPRET = jax.default_backend() != "tpu"
