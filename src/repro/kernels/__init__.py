"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's inner loop — the BP message update over non-zero doc-word
entries (Eq. 1) — dominates computation (Table 2: eta*lambda_K*lambda_W*KWDT).
Three kernel packages cover it (DESIGN.md §2/§4):

  - `bp_update`: the t=1 dense sweep — update arithmetic, normalization and
    residual fused into one VMEM-resident token-major pass;
  - `power_sweep`: the t>=2 selective sweep — per-token packed phi gather
    (scalar-prefetched power-row ids), mass-conserving renormalization over
    the [Pk] selected topics, and the [P, Pk] delta/residual accumulation,
    all in one grid pass (the packed sync buffers stay VMEM-resident across
    the whole grid);
  - `power_pack`: the packed gather/scatter of the power submatrix (the
    sync path's memory hot-spot) with MXU-friendly one-hot contractions
    instead of unsupported dynamic gathers.

Kernels target TPU (pl.pallas_call + BlockSpec); on CPU they run with
``interpret=True`` which executes the kernel body in Python — the mode used
by this container's test suite.
"""

import jax
import jax.numpy as jnp

# interpret=True everywhere except on real TPU.
INTERPRET = jax.default_backend() != "tpu"


def pad_axis(x, axis: int, multiple: int, value=0):
    """Right-pad `axis` of `x` to a multiple of `multiple` with `value`.

    The shared TPU tile-padding contract of every kernel wrapper
    (bp_update / power_pack / power_sweep ops.py).
    """
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
