"""Pull-based power-slice parameter server (DESIGN.md §15).

The allreduce backends ship the full packed ``[P, Pk]`` + ``[W]`` r_w
payload on every Eq. 6 sync and the full ``[W, K]`` statistic on every
dense sync — each worker pays for vocabulary it never touched in that
mini-batch.  This module is the peer architecture the paper's
communication claim actually describes: **server shards own contiguous
phi row ranges**, and a worker

  (a) *pushes* sparse packed deltas only for the rows its current
      mini-batch touched,
  (b) *pulls* only the row slices its NEXT mini-batch needs, prefetched
      one segment ahead so the pull overlaps the sweep, and
  (c) tolerates a configurable bounded staleness ``S`` — a pull for
      batch ``m`` may be served from a server snapshot missing at most
      the last ``S`` committed pushes.  ``S = 0`` is the barriered mode:
      every pull reflects every prior push, so the training trajectory
      matches the allreduce backend (pinned ≤ 1e-6 in BENCH_comm and
      tests/test_paramserver.py).

Layering:

  - ``RowShards``      pure metadata: contiguous row ranges per server.
  - ``ParamServer``    the authoritative row-sharded [W, K] statistic
                       (host numpy; per-shard locks; a committed-version
                       counter + condition variable gives the staleness
                       bound its teeth).
  - ``Transport``      ABC between ONE worker and the server shards.
                       ``SimTransport`` is the in-process/threaded
                       backend (optional per-op link latency so prefetch
                       overlap is measurable) with per-link byte
                       counters — the *measured* wire truth BENCH_comm
                       gates on.  ``JaxDistributedTransport`` is the
                       multi-host slot: it validates the environment and
                       raises until the jax.distributed backend lands
                       (ROADMAP backlog head).
  - ``PSClient``       worker-side replica manager: keeps the full
                       [W, K] device replica the unchanged POBP shard
                       body consumes, refreshing touched rows from pulls
                       and emitting touched-row delta pushes.

The worker's replica is exact at S=0 and stale-bounded at S>0: a pull
may overwrite local rows with a snapshot missing ≤ S of the worker's own
recent pushes — those deltas are never lost (the server holds them);
they reappear in the next pull that covers the row.  This is classic
stale-synchronous-parallel semantics (Petterson & Caetano's async LDA is
the ancestry; see PAPERS.md).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

_ROW_ID_BYTES = 4      # int32 row ids accompany every pushed/pulled slice


class TransportError(RuntimeError):
    """Base class for retryable transport-layer failures: the op did not
    take effect (or its effect is unknown) and may be safely re-issued —
    pushes are idempotent under the per-client sequence-number protocol
    (DESIGN.md §17)."""


class ServerUnavailableError(TransportError):
    """An op addressed a server shard that is currently down."""

    def __init__(self, server: int, detail: str = ""):
        self.server = int(server)
        super().__init__(f"server shard {server} is down"
                         + (f": {detail}" if detail else ""))


# --------------------------------------------------------------------------
# row sharding metadata
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RowShards:
    """Contiguous-range row ownership: server ``s`` owns rows
    ``[ranges[s][0], ranges[s][1])``.  Ranges are balanced to within one
    row and cover ``[0, w_cap)`` exactly."""

    w_cap: int
    num_servers: int

    def __post_init__(self):
        if self.num_servers < 1 or self.w_cap < 1:
            raise ValueError(f"need w_cap >= 1, num_servers >= 1, got "
                             f"({self.w_cap}, {self.num_servers})")

    @property
    def ranges(self) -> List[Tuple[int, int]]:
        base, rem = divmod(self.w_cap, self.num_servers)
        out, lo = [], 0
        for s in range(self.num_servers):
            hi = lo + base + (1 if s < rem else 0)
            out.append((lo, hi))
            lo = hi
        return out

    def owner(self, row: int) -> int:
        for s, (lo, hi) in enumerate(self.ranges):
            if lo <= row < hi:
                return s
        raise ValueError(f"row {row} outside [0, {self.w_cap})")

    def split(self, rows: np.ndarray) -> Dict[int, np.ndarray]:
        """Partition sorted unique `rows` into per-server id arrays; only
        servers with at least one row appear (a touched-row push never
        wakes a shard it does not address)."""
        rows = np.asarray(rows, np.int64)
        out: Dict[int, np.ndarray] = {}
        for s, (lo, hi) in enumerate(self.ranges):
            sel = rows[(rows >= lo) & (rows < hi)]
            if sel.size:
                out[s] = sel
        return out


# --------------------------------------------------------------------------
# the authoritative server group
# --------------------------------------------------------------------------

class ParamServer:
    """Row-sharded owner of the accumulated [W, K] statistic.

    Pushes are *deltas* (commutative adds — multiple writers compose);
    a batch push spans several shards and becomes visible atomically
    through ``commit(version)``.  Pulls carry a ``min_version``: the
    caller blocks until at least that many batch pushes have committed —
    the server-side half of the bounded-staleness contract.

    Chaos hardening (DESIGN.md §17): pushes may carry a per-client
    monotonic ``(client_id, seq)`` tag — a shard applies each tag at most
    once per shard lifetime, so duplicated or replayed deliveries are
    idempotent.  ``crash(s)`` loses a shard's in-memory rows and dedup
    memory; ``restart(s)`` restores the rows from the last server-synced
    snapshot (``mark_synced()``, the checkpoint-fence handshake) and
    holds pulls from that shard until a client replays its retained
    post-fence deltas and calls ``mark_recovered(s)``.
    """

    def __init__(self, phi0: np.ndarray, num_servers: int = 1,
                 version: int = 0, pull_timeout: float = 60.0):
        phi0 = np.asarray(phi0, np.float32)
        self.shards = RowShards(phi0.shape[0], num_servers)
        self._phi = phi0.copy()
        self._locks = [threading.Lock() for _ in range(num_servers)]
        self._cv = threading.Condition()
        self._committed = int(version)
        self.pull_timeout = float(pull_timeout)
        # -- fault-tolerance state --
        self._down: set = set()           # crashed shard ids
        self._replaying: set = set()      # restarted, awaiting delta replay
        self._applied: List[Dict[str, set]] = [dict()
                                               for _ in range(num_servers)]
        # the last server-synced snapshot: stands in for the checkpoint
        # bytes the fence persisted — what a restarted shard reloads
        self._sync_phi = phi0.copy()
        self._sync_version = int(version)
        self.duplicates_dropped = 0
        self.recovery_log: List[Dict[str, Any]] = []

    @property
    def committed(self) -> int:
        with self._cv:
            return self._committed

    def apply_push(self, server: int, rows: np.ndarray,
                   deltas: np.ndarray, client_id: Optional[str] = None,
                   seq: Optional[int] = None, replay: bool = False) -> bool:
        """Apply a delta push to one shard; returns False when the
        ``(client_id, seq)`` tag was already applied (duplicate/replay).

        A shard awaiting replay accepts ONLY replay-tagged pushes: letting
        an in-flight retry land before the replayed backlog would re-sum
        the shard's rows in a different order (float addition is not
        associative) and break the S=0 bit-exactness pin.
        """
        with self._cv:
            if server in self._down:
                raise ServerUnavailableError(server, "push rejected")
            if server in self._replaying and not replay:
                raise ServerUnavailableError(
                    server, "shard replaying retained deltas; ordinary "
                            "pushes fenced until recovery")
        lo, hi = self.shards.ranges[server]
        rows = np.asarray(rows, np.int64)
        if rows.size and not ((rows >= lo) & (rows < hi)).all():
            raise ValueError(f"push to server {server} carries rows outside "
                             f"[{lo}, {hi})")
        with self._locks[server]:
            if client_id is not None and seq is not None:
                seen = self._applied[server].setdefault(client_id, set())
                if seq in seen:
                    self.duplicates_dropped += 1
                    return False
                seen.add(seq)
            np.add.at(self._phi, rows, np.asarray(deltas, np.float32))
        return True

    def commit(self, version: int) -> None:
        with self._cv:
            self._committed = max(self._committed, int(version))
            self._cv.notify_all()

    def serve_pull(self, server: int, rows: np.ndarray, min_version: int,
                   timeout: Optional[float] = None) -> Tuple[np.ndarray, int]:
        if timeout is None:
            timeout = self.pull_timeout
        lo, hi = self.shards.ranges[server]
        with self._cv:
            # ready, OR down (wake to fail fast so the client can back
            # off + recover instead of burning the whole timeout)
            ok = self._cv.wait_for(
                lambda: (server in self._down
                         or (self._committed >= min_version
                             and server not in self._replaying)),
                timeout=timeout)
            if server in self._down:
                raise ServerUnavailableError(server, "pull rejected")
            if not ok:
                raise TimeoutError(
                    f"pull from server shard {server} (rows [{lo}, {hi})) "
                    f"waited {timeout}s for committed version "
                    f">= {min_version} (at {self._committed}"
                    + (", shard awaiting delta replay"
                       if server in self._replaying else "")
                    + "); a push was lost or never committed")
            version = self._committed
        rows = np.asarray(rows, np.int64)
        if rows.size and not ((rows >= lo) & (rows < hi)).all():
            raise ValueError(f"pull from server {server} asks rows outside "
                             f"[{lo}, {hi})")
        with self._locks[server]:
            return self._phi[rows].copy(), version

    # ---- crash / recovery state machine (DESIGN.md §17) ----
    def is_up(self, server: int) -> bool:
        with self._cv:
            return server not in self._down

    def needs_replay(self) -> frozenset:
        with self._cv:
            return frozenset(self._replaying)

    def crash(self, server: int) -> None:
        """Lose a shard: its rows and its dedup memory are gone (the
        replica of a real process death).  In-flight ops observe
        ``ServerUnavailableError``."""
        lo, hi = self.shards.ranges[server]
        with self._locks[server]:
            with self._cv:
                self._down.add(server)
                self._cv.notify_all()
            self._phi[lo:hi] = 0.0
            self._applied[server] = dict()
        self.recovery_log.append({"event": "crash", "server": int(server)})

    def restart(self, server: int) -> None:
        """Bring a crashed shard back: rows reload from the last synced
        snapshot; the shard then refuses pulls until a client replays
        its retained post-fence deltas (``mark_recovered``)."""
        lo, hi = self.shards.ranges[server]
        with self._locks[server]:
            self._phi[lo:hi] = self._sync_phi[lo:hi]
            with self._cv:
                self._down.discard(server)
                self._replaying.add(server)
                self._cv.notify_all()
        self.recovery_log.append({"event": "restart", "server": int(server),
                                  "restored_version": self._sync_version})

    def mark_recovered(self, server: int) -> None:
        with self._cv:
            self._replaying.discard(server)
            self._cv.notify_all()
        self.recovery_log.append({"event": "recovered",
                                  "server": int(server)})

    def mark_synced(self) -> None:
        """Checkpoint-fence handshake: the current committed state is now
        durable — it becomes the restart-recovery base, and clients may
        trim their retained delta logs (``PSClient.mark_durable``)."""
        for lock in self._locks:
            lock.acquire()
        try:
            with self._cv:
                self._sync_version = self._committed
            self._sync_phi = self._phi.copy()
        finally:
            for lock in self._locks:
                lock.release()

    # ---- checkpoint handshake (DESIGN.md §15): the server copy is the
    # authoritative statistic a fence persists / a resume rehydrates.
    def snapshot(self) -> Tuple[np.ndarray, int]:
        with self._cv:
            version = self._committed
        for lock in self._locks:
            lock.acquire()
        try:
            return self._phi.copy(), version
        finally:
            for lock in self._locks:
                lock.release()

    def manifest(self) -> Dict[str, Any]:
        """JSON-able server-side state for the checkpoint manifest
        (``extra['ps']``); the phi payload itself rides the normal
        checkpoint tree."""
        return {"num_servers": self.shards.num_servers,
                "w_cap": self.shards.w_cap,
                "ranges": [list(r) for r in self.shards.ranges],
                "version": self.committed}


# --------------------------------------------------------------------------
# transports
# --------------------------------------------------------------------------

class Transport:
    """Worker <-> server-shard message layer.

    Implementations must be safe to call from one worker thread; pulls
    are asynchronous (a ``Future``) so the client can prefetch one
    segment ahead.  Byte counters are per link (= per server shard, per
    direction) and count the real encoded payload: row ids at int32 plus
    values at the wire dtype.
    """

    def __init__(self, num_servers: int):
        self.pushed_bytes = [0] * num_servers
        self.pulled_bytes = [0] * num_servers

    def push_batch(self, version: int, rows: np.ndarray,
                   deltas: np.ndarray, *, client_id: Optional[str] = None,
                   seq: Optional[int] = None,
                   replay: bool = False) -> Future:
        raise NotImplementedError

    def pull(self, rows: np.ndarray, min_version: int) -> Future:
        """-> Future[(values [len(rows), K], served_version)]."""
        raise NotImplementedError

    def close(self) -> None:
        pass

    # ---- recovery surface (no-ops for transports without failures) ----
    def needs_replay(self) -> frozenset:
        """Shard ids that restarted and await client delta replay."""
        return frozenset()

    def mark_recovered(self, server: int) -> None:
        pass

    def crash_server(self, server: int) -> None:
        raise NotImplementedError(f"{type(self).__name__} cannot inject "
                                  "server crashes")

    def restart_server(self, server: int) -> None:
        raise NotImplementedError(f"{type(self).__name__} cannot restart "
                                  "servers")

    # ---- shared accounting ----
    def _bill(self, counter: List[int], server: int, n_rows: int,
              k: int, itemsize: int) -> None:
        counter[server] += n_rows * (k * itemsize + _ROW_ID_BYTES)

    @property
    def total_bytes(self) -> int:
        return sum(self.pushed_bytes) + sum(self.pulled_bytes)

    def bytes_by_link(self) -> Dict[str, int]:
        out = {}
        for s, b in enumerate(self.pushed_bytes):
            out[f"push:s{s}"] = b
        for s, b in enumerate(self.pulled_bytes):
            out[f"pull:s{s}"] = b
        return out


class SimTransport(Transport):
    """In-process threaded transport over a live ``ParamServer``.

    ``latency_s`` injects a per-operation link delay (one way) so the
    prefetch-overlap claim is measurable on localhost: a barriered S=0
    run pays the pull latency on the critical path; an S>=1 run hides it
    under the sweep (BENCH_comm's overlap gate).  ``wire_dtype`` is the
    value encoding on the wire (numpy dtype; bf16 halves PS wire bytes
    exactly like the allreduce sync_dtype path — the round-trip cast is
    applied so billed bytes and delivered precision agree).
    """

    def __init__(self, server: ParamServer, latency_s: float = 0.0,
                 wire_dtype=np.float32, max_workers: int = 4):
        super().__init__(server.shards.num_servers)
        self.server = server
        self.latency_s = float(latency_s)
        self.wire_dtype = np.dtype(wire_dtype)
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="repro-ps")

    def _encode(self, values: np.ndarray) -> np.ndarray:
        if values.dtype != self.wire_dtype:
            # the wire cast round-trip (mirrors Reducer.psum compress)
            return values.astype(self.wire_dtype).astype(np.float32)
        return np.asarray(values, np.float32)

    def _do_push(self, version, by_server, deltas, k, client_id, seq,
                 replay):
        if self.latency_s:
            time.sleep(self.latency_s)
        for s, (rows, idx) in by_server.items():
            # bill before applying: the payload is on the wire whether
            # the shard dedupes it (duplicate) or rejects it (down) —
            # retry/duplicate overhead shows up in the measured truth
            self._bill(self.pushed_bytes, s, len(rows), k,
                       self.wire_dtype.itemsize)
            self.server.apply_push(s, rows, deltas[idx],
                                   client_id=client_id, seq=seq,
                                   replay=replay)
        self.server.commit(version)

    def push_batch(self, version: int, rows: np.ndarray,
                   deltas: np.ndarray, *, client_id: Optional[str] = None,
                   seq: Optional[int] = None,
                   replay: bool = False) -> Future:
        rows = np.asarray(rows, np.int64)
        deltas = self._encode(np.asarray(deltas))
        k = deltas.shape[1] if deltas.ndim == 2 else 1
        order = np.argsort(rows, kind="stable")
        rows_s, idx_s = rows[order], order
        by_server = {}
        for s, sel in self.server.shards.split(rows_s).items():
            mask = np.isin(rows_s, sel)
            by_server[s] = (rows_s[mask], idx_s[mask])
        return self._pool.submit(self._do_push, version, by_server, deltas,
                                 k, client_id, seq, replay)

    def _do_pull(self, by_server, n_rows, k, min_version):
        if self.latency_s:
            time.sleep(self.latency_s)
        out = np.zeros((n_rows, k), np.float32)
        version = min_version
        for s, (rows, idx) in by_server.items():
            vals, version = self.server.serve_pull(s, rows, min_version)
            out[idx] = self._encode(vals)
            self._bill(self.pulled_bytes, s, len(rows), k,
                       self.wire_dtype.itemsize)
        return out, version

    def pull(self, rows: np.ndarray, min_version: int) -> Future:
        rows = np.asarray(rows, np.int64)
        k = self.server._phi.shape[1]
        idx_all = np.arange(rows.size)
        by_server = {}
        for s, sel in self.server.shards.split(rows).items():
            mask = np.isin(rows, sel)
            by_server[s] = (rows[mask], idx_all[mask])
        return self._pool.submit(self._do_pull, by_server, rows.size, k,
                                 min_version)

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    # ---- recovery surface: delegate to the live server group ----
    def needs_replay(self) -> frozenset:
        return self.server.needs_replay()

    def mark_recovered(self, server: int) -> None:
        self.server.mark_recovered(server)

    def crash_server(self, server: int) -> None:
        self.server.crash(server)

    def restart_server(self, server: int) -> None:
        self.server.restart(server)


class JaxDistributedTransport(Transport):
    """Multi-host slot: the same push/pull contract over
    ``jax.distributed`` collectives.

    Deliberately a validated stub (ROADMAP: real multi-host PS training
    rides the jax.distributed init + per-host data loading work): it
    fails loudly with the wiring instructions instead of silently
    falling back to the simulator, so a cluster launch can never
    *appear* to run multi-host while actually running in-process.
    """

    def __init__(self, num_servers: int):
        import jax

        if not getattr(jax.distributed, "is_initialized", lambda: False)():
            raise RuntimeError(
                "JaxDistributedTransport requires jax.distributed."
                "initialize() (coordinator address + process ids) before "
                "construction; for single-host runs use SimTransport "
                "(--backend ps defaults to it)")
        super().__init__(num_servers)

    def push_batch(self, version, rows, deltas, **kw) -> Future:
        raise NotImplementedError(
            "multi-host PS push is the ROADMAP backlog head: encode "
            "(rows, deltas) per owning host and send over a "
            "jax.distributed side channel; SimTransport defines the "
            "contract this must satisfy (tests/test_paramserver.py)")

    def pull(self, rows, min_version) -> Future:
        raise NotImplementedError(
            "multi-host PS pull is the ROADMAP backlog head; see "
            "push_batch")


# --------------------------------------------------------------------------
# the worker-side client
# --------------------------------------------------------------------------

def _pad_rows(rows: np.ndarray,
              min_bucket: int = 64) -> Tuple[np.ndarray, int]:
    """Pad a touched-row id vector to the next power-of-two bucket
    (>= min_bucket) by repeating ``rows[0]``, returning (padded, pad).

    Touched counts vary freely per batch; without bucketing every
    distinct count compiles a fresh device gather/scatter executable.
    Bucketing bounds compiled shapes by ~log2(W), and the duplicated row
    is written with its own pulled value so the extra scatter lanes are
    idempotent."""
    n = rows.size
    b = min_bucket
    while b < n:
        b *= 2
    return np.concatenate([rows, np.full(b - n, rows[0], rows.dtype)]), b - n

@dataclasses.dataclass
class _PushRec:
    """One issued delta push, retained until a checkpoint fence makes it
    durable — the unit of retry re-issue and crash-recovery replay."""

    seq: int
    version: int
    rows: np.ndarray
    delta: np.ndarray
    future: Optional[Future] = None


class PSClient:
    """Keeps one worker's full-capacity device replica fresh through
    touched-row pulls and emits touched-row delta pushes.

    The replica is what the unchanged POBP shard body consumes, so the
    training step never knows it runs under a parameter server.  Per
    batch ``m`` (1-indexed):

      ``begin_batch(m, rows, phi)``  waits for the prefetched pull
          covering `rows` (issuing a blocking pull if none was
          prefetched), overwrites the replica's touched rows with the
          pulled server slice, and caches the pulled base values the
          push will difference against.  The wait is timed —
          ``pull_wait_s`` is the prefetch-overlap instrument.
      ``prefetch(m_next, rows_next)``  issues the next pull with
          ``min_version = m_next - 1 - S``: at S=0 the transport thread
          blocks until this batch's push commits (barriered); at S>0 it
          can be served immediately from a bounded-stale snapshot, fully
          overlapping the sweep.
      ``end_batch(m, phi_new, rows)``  gathers the updated touched rows,
          pushes ``new - pulled_base`` as version ``m``, and bounds the
          number of uncommitted pushes by S + 1.

    Chaos hardening (DESIGN.md §17): every push carries a monotonic
    ``(client_id, seq)`` tag so re-issue is idempotent; failed push/pull
    ops retry with exponential backoff + deterministic jitter under a
    per-op ``retry_deadline_s``; every push since the last durable fence
    is retained (``mark_durable`` trims), and when a restarted shard
    advertises ``needs_replay`` the client replays the retained log in
    version order — at S=0 the recovered phi is bit-exact with the
    clean run.  Retry/replay wire overhead is billed into ``meter``
    under ``ps.retry.*`` / ``ps.replay`` phases (core/sync.py).
    """

    _RETRYABLE = (TransportError, TimeoutError)

    def __init__(self, transport: Transport, staleness: int = 0,
                 client_id: str = "w0", retry_deadline_s: float = 60.0,
                 backoff0_s: float = 0.01, backoff_max_s: float = 0.5,
                 meter=None):
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        self.transport = transport
        self.staleness = int(staleness)
        self.client_id = str(client_id)
        self.retry_deadline_s = float(retry_deadline_s)
        self.backoff0_s = float(backoff0_s)
        self.backoff_max_s = float(backoff_max_s)
        self.meter = meter
        self.pull_wait_s = 0.0
        self.push_wait_s = 0.0
        self.touched_history: List[int] = []
        self.retries = 0
        self.replayed_pushes = 0
        self.recoveries = 0
        self.retry_wire_bytes = 0
        self._prefetched: Optional[Tuple[int, np.ndarray, Future]] = None
        self._base_rows: Optional[np.ndarray] = None       # pulled values
        self._k: Optional[int] = None                      # replica width
        self._pending: List[_PushRec] = []
        self._retained: List[_PushRec] = []   # since the last durable fence
        self._seq = 0
        self._retry_counter = 0
        import zlib
        self._jitter_key = zlib.crc32(self.client_id.encode())

    # -- helpers ----------------------------------------------------------
    def _min_version(self, m: int) -> int:
        return max(0, m - 1 - self.staleness)

    def _wire_itemsize(self) -> int:
        return np.dtype(getattr(self.transport, "wire_dtype",
                                np.float32)).itemsize

    def _op_nbytes(self, rows: np.ndarray, k: int) -> int:
        return int(rows.size) * (k * self._wire_itemsize() + _ROW_ID_BYTES)

    def _bill_retry(self, phase: str, nbytes: int) -> None:
        self.retry_wire_bytes += nbytes
        if self.meter is not None:
            self.meter.record_host(phase, nbytes)

    def _backoff(self, attempt: int) -> None:
        """Exponential backoff with deterministic jitter: the sleep for
        retry ``n`` of this client is a pure function of
        ``(client_id, retry counter)`` — chaos runs stay replayable."""
        base = min(self.backoff_max_s, self.backoff0_s * (2.0 ** attempt))
        rng = np.random.default_rng((self._jitter_key, self._retry_counter))
        self._retry_counter += 1
        time.sleep(base * (0.5 + rng.random()))

    # -- retry / recovery core --------------------------------------------
    def _recover_if_needed(self) -> None:
        """If any shard restarted and awaits replay, re-push the retained
        post-fence deltas in version order, then clear the barrier.
        Dedup on still-healthy shards makes the replay a no-op there;
        the restarted shard re-applies exactly the deltas it lost."""
        need = sorted(self.transport.needs_replay())
        if not need:
            return
        self.recoveries += len(need)
        for rec in self._retained:
            k = rec.delta.shape[1] if rec.delta.ndim == 2 else 1
            self._bill_retry("ps.replay", self._op_nbytes(rec.rows, k))
            # replay=True: a replaying shard fences ordinary pushes, so
            # the retained backlog re-applies in version order BEFORE any
            # in-flight retry can land out of order (float adds are not
            # associative — order is part of the bit-exactness contract)
            fut = self.transport.push_batch(rec.version, rec.rows, rec.delta,
                                            client_id=self.client_id,
                                            seq=rec.seq, replay=True)
            t0, attempt = time.time(), 0
            while True:
                try:
                    fut.result()
                    break
                except self._RETRYABLE as e:
                    if time.time() - t0 > self.retry_deadline_s:
                        raise TimeoutError(
                            f"replay of push seq {rec.seq} (version "
                            f"{rec.version}) exceeded retry deadline "
                            f"{self.retry_deadline_s}s: {e}") from e
                    self._backoff(attempt)
                    attempt += 1
                    self.retries += 1
                    self._bill_retry("ps.replay",
                                     self._op_nbytes(rec.rows, k))
                    fut = self.transport.push_batch(
                        rec.version, rec.rows, rec.delta,
                        client_id=self.client_id, seq=rec.seq, replay=True)
            self.replayed_pushes += 1
        for s in need:
            self.transport.mark_recovered(s)

    def _await_push(self, rec: _PushRec) -> None:
        t0, attempt = time.time(), 0
        while True:
            try:
                rec.future.result()
                return
            except self._RETRYABLE as e:
                self._recover_if_needed()
                if time.time() - t0 > self.retry_deadline_s:
                    raise TimeoutError(
                        f"push seq {rec.seq} (version {rec.version}) by "
                        f"client {self.client_id!r} exceeded retry deadline "
                        f"{self.retry_deadline_s}s: {e}") from e
                self._backoff(attempt)
                attempt += 1
                self.retries += 1
                k = rec.delta.shape[1] if rec.delta.ndim == 2 else 1
                self._bill_retry("ps.retry.push",
                                 self._op_nbytes(rec.rows, k))
                rec.future = self.transport.push_batch(
                    rec.version, rec.rows, rec.delta,
                    client_id=self.client_id, seq=rec.seq)

    def _repair_pending(self) -> None:
        """Re-issue any in-flight push whose future already failed — a
        pull timeout is often downstream of our own dropped push."""
        for rec in self._pending:
            if rec.future.done() and rec.future.exception() is not None:
                exc = rec.future.exception()
                if not isinstance(exc, self._RETRYABLE):
                    continue
                self.retries += 1
                k = rec.delta.shape[1] if rec.delta.ndim == 2 else 1
                self._bill_retry("ps.retry.push",
                                 self._op_nbytes(rec.rows, k))
                rec.future = self.transport.push_batch(
                    rec.version, rec.rows, rec.delta,
                    client_id=self.client_id, seq=rec.seq)

    def _pull_with_retry(self, rows: np.ndarray, min_version: int,
                         fut: Optional[Future] = None):
        if fut is None:
            fut = self.transport.pull(rows, min_version)
        t0, attempt = time.time(), 0
        while True:
            try:
                return fut.result()
            except self._RETRYABLE as e:
                self._recover_if_needed()
                self._repair_pending()
                if time.time() - t0 > self.retry_deadline_s:
                    raise TimeoutError(
                        f"pull (min_version {min_version}, {rows.size} "
                        f"rows) by client {self.client_id!r} exceeded retry "
                        f"deadline {self.retry_deadline_s}s: {e}") from e
                self._backoff(attempt)
                attempt += 1
                self.retries += 1
                self._bill_retry("ps.retry.pull",
                                 self._op_nbytes(rows, self._k or 1))
                fut = self.transport.pull(rows, min_version)

    def prefetch(self, m_next: int, rows_next: np.ndarray) -> None:
        if self._prefetched is not None:
            # a stale prefetch (e.g. a fence rebuilt the stream) is
            # drained, not leaked
            self._prefetched[2].result()
        rows_next = np.asarray(rows_next, np.int64)
        self._prefetched = (m_next, rows_next,
                            self.transport.pull(rows_next,
                                                self._min_version(m_next)))

    def begin_batch(self, m: int, rows: np.ndarray, phi):
        """Refresh the replica's `rows` from the server; returns the
        updated replica (a new device array — safe under donation)."""
        import jax.numpy as jnp

        rows = np.asarray(rows, np.int64)
        t0 = time.time()
        if (self._prefetched is not None and self._prefetched[0] == m
                and np.array_equal(self._prefetched[1], rows)):
            vals, _ = self._pull_with_retry(rows, self._min_version(m),
                                            fut=self._prefetched[2])
        else:
            if self._prefetched is not None:
                try:                             # drain a mismatched pull
                    self._prefetched[2].result()
                except self._RETRYABLE:
                    pass                         # value unused; not retried
            vals, _ = self._pull_with_retry(rows, self._min_version(m))
        self._prefetched = None
        self.pull_wait_s += time.time() - t0
        self.touched_history.append(int(rows.size))
        self._base_rows = vals
        if vals.ndim == 2:
            self._k = int(vals.shape[1])
        if not rows.size:
            return phi
        # the device scatter runs at a BUCKETED row count (_pad_rows):
        # per-batch touched counts vary freely, but the compiled scatter
        # shapes stay bounded by #buckets — the same discipline the
        # stream applies to L.  Padding duplicates rows[0] with its own
        # pulled value, so the duplicate writes are idempotent.
        rows_p, pad = _pad_rows(rows)
        vals_p = np.concatenate([vals, np.broadcast_to(vals[:1],
                                                       (pad,) + vals.shape[1:])])
        pulled = jnp.asarray(vals_p, phi.dtype)
        return phi.at[jnp.asarray(rows_p)].set(pulled)

    def end_batch(self, m: int, phi_new, rows: np.ndarray) -> None:
        """Push this batch's touched-row delta as version `m`."""
        import jax.numpy as jnp

        rows = np.asarray(rows, np.int64)
        if rows.size:
            # bucketed gather (see begin_batch): duplicate trailing rows
            # are sliced off after the fetch
            rows_p, _ = _pad_rows(rows)
            new_rows = np.asarray(phi_new[jnp.asarray(rows_p)],
                                  np.float32)[:rows.size]
        else:
            new_rows = np.zeros((0,) + np.shape(phi_new)[1:], np.float32)
        if self._base_rows is None or self._base_rows.shape != new_rows.shape:
            raise RuntimeError("end_batch without a matching begin_batch")
        delta = new_rows - self._base_rows
        self._base_rows = None
        rec = _PushRec(seq=self._seq, version=m, rows=rows, delta=delta)
        self._seq += 1
        rec.future = self.transport.push_batch(
            m, rows, delta, client_id=self.client_id, seq=rec.seq)
        # retained until the next durable fence: the crash-recovery
        # replay source (trimmed by mark_durable, bounded by ckpt_every)
        self._retained.append(rec)
        self._pending.append(rec)
        # bounded staleness also bounds worker memory: at most S + 1
        # pushes may be uncommitted before the oldest must land
        t0 = time.time()
        while len(self._pending) > self.staleness:
            self._await_push(self._pending.pop(0))
        self.push_wait_s += time.time() - t0

    def flush(self) -> None:
        """Commit every outstanding push (checkpoint fences, shutdown)."""
        while self._pending:
            self._await_push(self._pending.pop(0))
        if self._prefetched is not None:
            try:
                self._prefetched[2].result()
            except self._RETRYABLE:
                pass          # value unused; the next begin_batch re-pulls
            self._prefetched = None

    def mark_durable(self) -> None:
        """Checkpoint-fence handshake: every retained push is now covered
        by a server-synced snapshot (``ParamServer.mark_synced``) — the
        replay log can be trimmed."""
        self._retained.clear()

    @property
    def mean_touched_rows(self) -> float:
        if not self.touched_history:
            return 0.0
        return float(np.mean(self.touched_history))

    def stats(self) -> Dict[str, Any]:
        return {"pull_wait_s": self.pull_wait_s,
                "push_wait_s": self.push_wait_s,
                "mean_touched_rows": self.mean_touched_rows,
                "wire_bytes": self.transport.total_bytes,
                "bytes_by_link": self.transport.bytes_by_link(),
                "retries": self.retries,
                "replayed_pushes": self.replayed_pushes,
                "recoveries": self.recoveries,
                "retry_wire_bytes": self.retry_wire_bytes,
                "retained_pushes": len(self._retained)}


def touched_rows_of(word_ids, counts) -> np.ndarray:
    """Sorted unique vocabulary rows a mini-batch actually touches
    (padding slots carry zero counts and never count).  Accepts [D, L]
    or [N, Dl, L] stacked arrays."""
    wid = np.asarray(word_ids).reshape(-1)
    cnt = np.asarray(counts).reshape(-1)
    return np.unique(wid[cnt > 0]).astype(np.int64)


def sliced_sum(deltas_by_shard: Sequence[np.ndarray],
               touched_by_shard: Sequence[np.ndarray],
               w_cap: int) -> np.ndarray:
    """The PS sum a server group computes: each shard contributes ONLY
    its touched-row slice, applied in shard order.

    This is the algebra the sliced exchange stands on: when each shard's
    dense delta is zero off its touched rows (true by construction for
    POBP's token-scatter payloads), the union-of-touched-row slice sum
    equals the full dense allreduce BIT-EXACTLY — per-row, the same
    floats add in the same order; rows outside every touched set add
    nothing at all.  tests/test_ps_properties.py pins this, including
    live-W guard rows and the bf16 wire-cast path.
    """
    k = deltas_by_shard[0].shape[1]
    out = np.zeros((w_cap, k), deltas_by_shard[0].dtype)
    for delta, touched in zip(deltas_by_shard, touched_by_shard):
        touched = np.asarray(touched, np.int64)
        out[touched] += np.asarray(delta)[touched]
    return out
