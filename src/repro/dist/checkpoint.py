"""Atomic pytree checkpointing with a 3-step retention window.

Layout on disk (one directory per step):

    <dir>/step_0000010/
        manifest.json    # per-leaf key path, shape, dtype + the extra dict
        data.npz         # raw little-endian bytes per leaf (dtype-agnostic,
                         # so bf16 and any future ml_dtypes survive np.savez)

Writes are atomic: everything lands in a ``.tmp-<step>`` staging directory
that is ``os.rename``d into place — a crash mid-save can never leave a
half-written checkpoint that ``latest_step`` would pick up.  Against
corruption that atomic rename can't rule out (a torn write below the
filesystem, bit rot, an operator truncating a file), ``restore_latest``
verifies integrity newest-first — manifest parses, data.npz opens, every
leaf's byte count matches its manifest shape × dtype — and falls back to
the next retained step with a loud warning instead of crashing the
resume (DESIGN.md §17).  Restore is template-driven: the caller supplies
a pytree of like-shaped arrays (or ShapeDtypeStructs) and gets the same
structure back; any mismatch is a ``ValueError`` rather than a silently
reshaped parameter (a template mismatch is a caller bug, never a
fall-back).
"""

from __future__ import annotations

import json
import os
import shutil
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_RETAIN = 3          # checkpoints kept on disk (newest first)
_PREFIX = "step_"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_PREFIX}{step:07d}")


def _resize_rows(arr: np.ndarray, rows: int, what: str,
                 row_remap=None) -> np.ndarray:
    """Elastic W-reshard of one leaf — the ONE place row validation lives
    (``restore``/``restore_latest``/``restore_phi`` all route through it).

    Growing zero-pads axis 0 up to `rows` (the pad rows are guard rows;
    the host-side mirror of ``core.lifecycle.resize_state``).  Shrinking
    or reordering requires `row_remap` — the manifest-versioned
    compaction remap saved at a checkpoint fence (``extra['dyn']
    ['row_remap']``; ``remap[i]`` = row i's post-compaction row, -1 for a
    reclaimed row): surviving rows land at their remapped index, dead and
    vacated rows come back as zero guard rows.  Without a remap a shrink
    still raises — bare row-cutting would silently drop live statistics.
    """
    if row_remap is not None:
        remap = np.asarray(row_remap, np.int64)
        out = np.zeros((rows,) + arr.shape[1:], arr.dtype)
        src = arr[:remap.shape[0]]
        ok = (remap >= 0) & (remap < rows)
        out[remap[ok]] = src[ok]
        return out
    if rows < arr.shape[0]:
        raise ValueError(
            f"cannot shrink {what} from {arr.shape[0]} to {rows} rows "
            f"without a compaction remap — vocab eviction is supported "
            f"only via the checkpoint-fenced remap path (pass row_remap "
            f"from the fence manifest; DESIGN.md §14)")
    if rows == arr.shape[0]:
        return arr
    return np.concatenate(
        [arr, np.zeros((rows - arr.shape[0],) + arr.shape[1:], arr.dtype)],
        axis=0)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return ([(jax.tree_util.keystr(path), leaf) for path, leaf in leaves],
            treedef)


def save(directory: str, step: int, trees: Dict[str, Any],
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Persist `trees` (a dict of pytrees) + a JSON-able `extra` dict."""
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(trees)
    manifest = {"step": int(step), "extra": extra or {}, "leaves": []}
    payload = {}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        manifest["leaves"].append({"key": key, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
        arr = np.ascontiguousarray(arr)  # NB: promotes 0-d to 1-d
        # raw bytes: np.savez can't serialize ml_dtypes (bf16) headers
        payload[f"leaf_{i}"] = np.frombuffer(arr.tobytes(), np.uint8)

    tmp = os.path.join(directory, f".tmp-{step}-{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "data.npz"), **payload)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    final = _step_dir(directory, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _enforce_retention(directory)
    return final


def _enforce_retention(directory: str) -> None:
    steps = sorted(_all_steps(directory))
    for s in steps[:-_RETAIN]:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def _all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if not name.startswith(_PREFIX):
            continue
        if not os.path.exists(os.path.join(directory, name, "manifest.json")):
            continue  # staging dirs / partial writes never qualify
        try:
            out.append(int(name[len(_PREFIX):]))
        except ValueError:
            continue
    return out


def latest_step(directory: str) -> Optional[int]:
    """Newest complete checkpoint step in `directory`, or None."""
    steps = _all_steps(directory)
    return max(steps) if steps else None


def verify_step(directory: str, step: int) -> Optional[str]:
    """Integrity-check one retained checkpoint WITHOUT a template.

    Returns None when the step is intact, else a human-readable
    description of the corruption: manifest missing / unparseable,
    data.npz missing / not a zip, a leaf entry absent, or a leaf whose
    byte count disagrees with its manifest shape × dtype (the signature
    of a torn or truncated write).  Cheap relative to a restore — bytes
    are length-checked, not decoded into arrays.
    """
    path = _step_dir(directory, step)
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "data.npz")) as data:
            for i, rec in enumerate(manifest["leaves"]):
                want = (int(np.prod(rec["shape"])) if rec["shape"] else 1) \
                    * np.dtype(rec["dtype"]).itemsize
                if f"leaf_{i}" not in data:
                    return f"data.npz is missing leaf_{i} ({rec['key']})"
                got = int(data[f"leaf_{i}"].nbytes)
                if got != want:
                    return (f"leaf_{i} ({rec['key']}) holds {got} bytes, "
                            f"manifest says {want} — torn write?")
    except Exception as e:  # noqa: BLE001 — any decode failure IS the answer
        return f"{type(e).__name__}: {e}"
    return None


def peek_extra(directory: str, step: Optional[int] = None
               ) -> Optional[Tuple[Dict[str, Any], int]]:
    """Read only the manifest `extra` dict (no array bytes), or None.

    The dynamic-vocabulary driver needs the saved capacity rung BEFORE it
    can build a restore template of the right shape (DESIGN.md §12) —
    this is the cheap first half of that handshake.  Auto-picking
    (``step=None``) skips a step whose manifest fails to parse — the
    restore that follows falls back to the same older step, so the two
    halves of the handshake stay consistent; an explicit `step` raises.
    """
    if step is None:
        for s in sorted(_all_steps(directory), reverse=True):
            try:
                with open(os.path.join(_step_dir(directory, s),
                                       "manifest.json")) as f:
                    manifest = json.load(f)
            except Exception as e:  # noqa: BLE001
                warnings.warn(
                    f"manifest of {_step_dir(directory, s)} is unreadable "
                    f"({type(e).__name__}: {e}); peeking the previous "
                    f"retained step", RuntimeWarning, stacklevel=2)
                continue
            return manifest.get("extra", {}), int(manifest["step"])
        return None
    with open(os.path.join(_step_dir(directory, step), "manifest.json")) as f:
        manifest = json.load(f)
    return manifest.get("extra", {}), int(manifest["step"])


def restore_latest(directory: str, template: Dict[str, Any],
                   shardings: Optional[Dict[str, Any]] = None,
                   grow_rows: Tuple[str, ...] = (),
                   cast_dtypes: Tuple[str, ...] = (),
                   row_remaps: Optional[Dict[str, Any]] = None
                   ) -> Optional[Tuple[Dict[str, Any], Dict[str, Any], int]]:
    """Restore the newest complete checkpoint, or return None.

    The cold-start branch of a crash-resume driver collapses to
    ``got = restore_latest(dir, template)`` followed by an ``if got:``.
    `grow_rows` enables the elastic W-reshard, `cast_dtypes` the dtype
    up/down-cast and `row_remaps` the fenced compaction remap for the
    named leaves (see ``restore``).

    Retained steps are tried newest-first with ``verify_step`` integrity
    checks: a corrupt newest checkpoint (torn write, truncation, bit rot
    — the failures atomic rename can't rule out) warns loudly and falls
    back to the previous retained step instead of crashing the resume.
    Only corruption falls back; a template mismatch on an INTACT step is
    a caller bug and still raises (DESIGN.md §17).
    """
    skipped = 0
    for step in sorted(_all_steps(directory), reverse=True):
        bad = verify_step(directory, step)
        if bad is not None:
            warnings.warn(
                f"checkpoint {_step_dir(directory, step)} is corrupt "
                f"({bad}); falling back to the previous retained step",
                RuntimeWarning, stacklevel=2)
            skipped += 1
            continue
        if skipped:
            warnings.warn(
                f"resuming from step {step} after skipping {skipped} "
                f"corrupt newer checkpoint(s) — up to that many save "
                f"intervals of work will be recomputed",
                RuntimeWarning, stacklevel=2)
        return restore(directory, step, template, shardings,
                       grow_rows=grow_rows, cast_dtypes=cast_dtypes,
                       row_remaps=row_remaps)
    return None


def restore_phi(directory: str, step: Optional[int] = None,
                leaf: str = "phi_acc", sharding: Optional[Any] = None,
                w_cap: Optional[int] = None, dtype: Optional[Any] = None,
                row_remap: Optional[Any] = None
                ) -> Tuple[Any, Dict[str, Any], int]:
    """Serving entry point: load ONE leaf of a driver checkpoint.

    A serving process needs the trained ``phi_acc`` and nothing else — not
    the RNG, not the mini-batch cursor, not optimizer state — and it knows
    no template shapes up front.  This reads the manifest, locates the
    single leaf whose key path ends in `leaf`, and materializes just its
    bytes; shape and dtype come from the manifest.  `sharding` (e.g. a
    ``NamedSharding`` built from ``dist.sharding.phi_serving_spec``) routes
    the array through ``jax.device_put`` for a topic-sharded serving mesh.
    `w_cap` resizes the vocabulary axis across capacity rungs (elastic
    W-reshard, DESIGN.md §12): a phi saved at a smaller rung is zero-padded
    to `w_cap` rows (the pad rows are guard rows); shrinking needs the
    fenced compaction remap — pass `row_remap` (e.g. the manifest's
    ``extra['dyn']['row_remap']``) to restore a pre-compaction phi into a
    post-compaction row space (DESIGN.md §14); a bare shrink raises.
    `dtype` casts the restored leaf (compressed-accumulator round-trips,
    DESIGN.md §13: a bf16-trained phi may serve in f32 and vice versa);
    None keeps the saved dtype.
    Returns (array, extra, step); raises ``FileNotFoundError`` when the
    directory holds no complete checkpoint and ``ValueError`` when `leaf`
    is missing or ambiguous.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no complete checkpoint under {directory!r} — train one "
                f"first (launch.lda_train --ckpt-dir)")
    path = _step_dir(directory, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    hits = [(i, rec) for i, rec in enumerate(manifest["leaves"])
            if rec["key"].endswith(f"['{leaf}']")]
    if len(hits) != 1:
        raise ValueError(
            f"checkpoint at {path} has {len(hits)} leaves matching "
            f"{leaf!r}: {[r['key'] for _, r in hits]}")
    i, rec = hits[0]
    data = np.load(os.path.join(path, "data.npz"))
    arr = np.frombuffer(data[f"leaf_{i}"].tobytes(),
                        np.dtype(rec["dtype"])).reshape(tuple(rec["shape"]))
    if w_cap is not None:
        arr = _resize_rows(arr, w_cap, repr(leaf), row_remap=row_remap)
    if dtype is not None and arr.dtype != np.dtype(dtype):
        arr = arr.astype(np.dtype(dtype))
    if sharding is not None:
        arr = jax.device_put(arr, sharding)
    else:
        arr = jax.numpy.asarray(arr)
    return arr, manifest.get("extra", {}), int(manifest["step"])


def restore(directory: str, step: int, template: Dict[str, Any],
            shardings: Optional[Dict[str, Any]] = None,
            grow_rows: Tuple[str, ...] = (),
            cast_dtypes: Tuple[str, ...] = (),
            row_remaps: Optional[Dict[str, Any]] = None
            ) -> Tuple[Dict[str, Any], Dict[str, Any], int]:
    """Load the checkpoint at `step` into the structure of `template`.

    `template` leaves only provide structure/shape/dtype for validation —
    their values are never read.  `shardings` (same structure) routes each
    restored leaf through ``jax.device_put`` for the elastic-remesh path.
    `grow_rows` names leaves (by key-path suffix, e.g. ``"phi_acc"``) whose
    axis-0 size may be SMALLER in the checkpoint than in the template: the
    saved rows are zero-padded up to the template (elastic W-reshard across
    capacity rungs, DESIGN.md §12 — pad rows are guard rows).
    `cast_dtypes` (same suffix matching) permits a dtype MISMATCH for the
    named leaves: the saved leaf is cast to the template dtype on load
    (compressed-accumulator round-trips, DESIGN.md §13 — switch a run
    between float32 and bfloat16 phi_acc at a restore fence).
    `row_remaps` maps leaf suffixes to a fenced compaction remap
    (``extra['dyn']['row_remap']``): the named leaves may then shrink or
    permute their rows — survivors land at ``remap[i]``, reclaimed rows
    come back as zero guard rows (DESIGN.md §14).  Any other mismatch,
    including a remap-less shrink, still raises.
    Returns (trees, extra, step).
    """
    path = _step_dir(directory, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "data.npz"))

    flat, treedef = _flatten(template)
    recs = manifest["leaves"]
    if len(recs) != len(flat):
        raise ValueError(f"checkpoint leaf count mismatch: saved {len(recs)} "
                         f"!= template {len(flat)}")
    sh_flat = None
    if shardings is not None:
        sh_flat, _ = _flatten(shardings)

    leaves = []
    for i, ((key, leaf), rec) in enumerate(zip(flat, recs)):
        if rec["key"] != key:
            raise ValueError(f"checkpoint key mismatch at leaf {i}: "
                             f"saved {rec['key']!r} != template {key!r}")
        shape = tuple(rec["shape"])
        want = tuple(np.shape(leaf))
        remap = next((v for name, v in (row_remaps or {}).items()
                      if key.endswith(f"['{name}']")), None)
        rows_ok = len(shape) == len(want) and shape[1:] == want[1:]
        growable = (any(key.endswith(f"['{name}']") for name in grow_rows)
                    and rows_ok and shape[0] <= want[0])
        if shape != want and not growable and not (remap is not None
                                                   and rows_ok):
            raise ValueError(f"shape mismatch for {key}: saved {shape} != "
                             f"template {want}")
        want_dtype = getattr(leaf, "dtype", None)
        castable = (want_dtype is not None
                    and any(key.endswith(f"['{name}']")
                            for name in cast_dtypes))
        if (want_dtype is not None and not castable
                and np.dtype(rec["dtype"]) != np.dtype(want_dtype)):
            raise ValueError(f"dtype mismatch for {key}: saved "
                             f"{rec['dtype']} != template {np.dtype(want_dtype)}")
        raw = data[f"leaf_{i}"]
        arr = np.frombuffer(raw.tobytes(), np.dtype(rec["dtype"]))
        arr = arr.reshape(shape)
        if remap is not None or shape != want:  # fenced remap / rung pad
            arr = _resize_rows(arr, want[0], key, row_remap=remap)
        if castable and arr.dtype != np.dtype(want_dtype):
            arr = arr.astype(np.dtype(want_dtype))
        if sh_flat is not None:
            arr = jax.device_put(arr, sh_flat[i][1])
        else:
            arr = jax.numpy.asarray(arr)
        leaves.append(arr)
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            manifest.get("extra", {}), int(manifest["step"]))
