"""Deterministic fault injection for the parameter-server transport
(DESIGN.md §17).

The clean ``SimTransport`` assumes links never drop, servers never die.
Production does not.  This module makes every failure scenario a
*reproducible test fixture*:

  - ``FaultPlan``       a pure, seed-keyed description of what goes wrong:
                        per-op drop / duplicate / delay probabilities,
                        partition windows in op-index space, and one
                        scheduled server crash + restart.  Every decision
                        is a counter-keyed hash of ``(seed, kind, index)``
                        — no hidden RNG state, so replaying the same op
                        sequence replays the same faults bit-for-bit.
  - ``ChaosTransport``  wraps ANY ``Transport`` and applies the plan at
                        the issue boundary: a dropped op returns a future
                        that raises ``FaultInjectedError`` (the payload
                        never reached a server), a duplicated push is
                        delivered twice (exercising the server's
                        sequence-number dedup), a delayed op sleeps at
                        issue, and the scheduled crash/restart calls
                        through to the inner transport's server hooks.

The hardened ``PSClient`` retry layer (exponential backoff + jitter +
deadline, retained-delta replay after a shard restart) is what makes
training *survive* a plan; at ``--staleness 0`` the committed phi under
any eventually-delivering plan is bit-exact with the clean run, because
every push is applied exactly once (sequence-number idempotence) in the
same version order (tests/test_faults.py pins this, BENCH_fault gates
it).
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.dist.paramserver import Transport, TransportError

_KIND_ID = {"push": 1, "pull": 2}


class FaultInjectedError(TransportError):
    """An op was dropped (or issued into a partition window) by a
    ``FaultPlan``.  Retryable: the payload never reached any server."""


def _decision_bits(seed: int, kind: str, index: int) -> np.ndarray:
    """Three uniform [0, 1) draws keyed purely by (seed, kind, index) —
    replaying op `index` replays its fate."""
    rng = np.random.default_rng((int(seed), _KIND_ID[kind], int(index)))
    return rng.random(3)


@dataclasses.dataclass(frozen=True)
class Decision:
    drop: bool = False
    duplicate: bool = False
    delay_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seed-replayable fault schedule.

    Probabilities are per *op attempt* (a retry of a dropped push is a
    new op index with its own draw, so an eventually-delivering plan
    needs only ``drop < 1``).  ``partitions`` are half-open windows
    ``(kind, lo, hi)`` in per-kind op-index space during which every op
    of that kind fails — a worker partitioned from the cluster.
    ``crash_server``/``crash_at_push`` schedule one server loss when the
    push op counter reaches the index; ``restart_after_pushes`` later
    the server restarts from its last synced snapshot and waits for
    client delta replay.
    """

    seed: int = 0
    drop_push: float = 0.0
    drop_pull: float = 0.0
    dup_push: float = 0.0
    delay_s: float = 0.0
    delay_prob: float = 0.0
    partitions: Tuple[Tuple[str, int, int], ...] = ()
    crash_server: Optional[int] = None
    crash_at_push: Optional[int] = None
    restart_after_pushes: int = 2

    def __post_init__(self):
        for p, name, hi in ((self.drop_push, "drop_push", 1.0),
                            (self.drop_pull, "drop_pull", 1.0),
                            (self.dup_push, "dup_push", 1.0 + 1e-9),
                            (self.delay_prob, "delay_prob", 1.0 + 1e-9)):
            if not 0.0 <= p < hi:
                # drop probabilities must stay < 1: retries draw fresh
                # fates, so eventual delivery needs a nonzero pass rate
                raise ValueError(f"{name} must be in [0, 1) for drops / "
                                 f"[0, 1] otherwise, got {p}")
        if (self.crash_server is None) != (self.crash_at_push is None):
            raise ValueError("crash_server and crash_at_push must be set "
                             "together")
        for kind, lo, hi in self.partitions:
            if kind not in _KIND_ID or hi <= lo:
                raise ValueError(f"bad partition window {(kind, lo, hi)}")

    @property
    def active(self) -> bool:
        return bool(self.drop_push or self.drop_pull or self.dup_push
                    or self.delay_prob or self.partitions
                    or self.crash_server is not None)

    def partitioned(self, kind: str, index: int) -> bool:
        return any(k == kind and lo <= index < hi
                   for k, lo, hi in self.partitions)

    def decide(self, kind: str, index: int) -> Decision:
        """The fate of the `index`-th op of `kind` — a pure function."""
        if self.partitioned(kind, index):
            return Decision(drop=True)
        r = _decision_bits(self.seed, kind, index)
        drop_p = self.drop_push if kind == "push" else self.drop_pull
        drop = bool(r[0] < drop_p)
        dup = bool(kind == "push" and not drop and r[1] < self.dup_push)
        delay = self.delay_s if r[2] < self.delay_prob else 0.0
        return Decision(drop=drop, duplicate=dup, delay_s=delay)

    @staticmethod
    def parse_crash(spec: str) -> Tuple[Optional[int], Optional[int]]:
        """``"SERVER@PUSHOP"`` (e.g. ``"1@6"``) -> (server, push op index);
        empty string -> (None, None)."""
        if not spec:
            return None, None
        try:
            server, at = spec.split("@")
            return int(server), int(at)
        except ValueError:
            raise ValueError(
                f"--chaos-crash expects SERVER@PUSHOP (e.g. '1@6'), "
                f"got {spec!r}") from None


def _failed_future(exc: Exception) -> Future:
    f: Future = Future()
    f.set_exception(exc)
    return f


class ChaosTransport(Transport):
    """Fault-injecting wrapper over any ``Transport``.

    Byte counters delegate to the inner transport, so the *measured*
    wire truth includes retry and duplicate overhead — exactly what
    BENCH_fault scores.  ``events`` is the replayable audit log the
    recovery gates read (drop / duplicate / crash / restart entries with
    their op indices).
    """

    def __init__(self, inner: Transport, plan: FaultPlan):
        super().__init__(len(inner.pushed_bytes))
        self.inner = inner
        self.plan = plan
        self.events: List[Dict[str, Any]] = []
        self._push_idx = 0
        self._pull_idx = 0
        self._crashed = False
        self._restarted = False
        self._dup_futures: List[Future] = []

    # ---- delegated accounting / recovery surface ----
    @property
    def total_bytes(self) -> int:
        return self.inner.total_bytes

    def bytes_by_link(self) -> Dict[str, int]:
        return self.inner.bytes_by_link()

    @property
    def wire_dtype(self):
        return getattr(self.inner, "wire_dtype", np.dtype(np.float32))

    def needs_replay(self):
        return self.inner.needs_replay()

    def mark_recovered(self, server: int) -> None:
        self.inner.mark_recovered(server)

    def crash_server(self, server: int) -> None:
        self.inner.crash_server(server)

    def restart_server(self, server: int) -> None:
        self.inner.restart_server(server)

    # ---- the scheduled crash/restart state machine ----
    def _tick_crash_schedule(self, push_index: int) -> None:
        plan = self.plan
        if plan.crash_server is None:
            return
        if not self._crashed and push_index >= plan.crash_at_push:
            self._crashed = True
            self.inner.crash_server(plan.crash_server)
            self.events.append({"event": "crash", "server": plan.crash_server,
                                "push_op": push_index})
        elif (self._crashed and not self._restarted and push_index
              >= plan.crash_at_push + plan.restart_after_pushes):
            self._restarted = True
            self.inner.restart_server(plan.crash_server)
            self.events.append({"event": "restart",
                                "server": plan.crash_server,
                                "push_op": push_index})

    # ---- the op surface ----
    def push_batch(self, version: int, rows: np.ndarray,
                   deltas: np.ndarray, *, client_id: Optional[str] = None,
                   seq: Optional[int] = None,
                   replay: bool = False) -> Future:
        i = self._push_idx
        self._push_idx += 1
        self._tick_crash_schedule(i)
        d = self.plan.decide("push", i)
        if d.delay_s:
            time.sleep(d.delay_s)
        if d.drop:
            self.events.append({"event": "drop", "op": "push", "index": i,
                                "version": int(version)})
            return _failed_future(FaultInjectedError(
                f"push op {i} (version {version}, seq {seq}) dropped by "
                f"fault plan seed={self.plan.seed}"))
        fut = self.inner.push_batch(version, rows, deltas,
                                    client_id=client_id, seq=seq,
                                    replay=replay)
        if d.duplicate:
            self.events.append({"event": "duplicate", "op": "push",
                                "index": i, "version": int(version)})
            dup = self.inner.push_batch(version, rows, deltas,
                                        client_id=client_id, seq=seq,
                                        replay=replay)
            # retrieve the duplicate's outcome so a dup delivered into a
            # down server never surfaces as an unretrieved-exception leak
            dup.add_done_callback(lambda f: f.exception())
            self._dup_futures.append(dup)
        return fut

    def pull(self, rows: np.ndarray, min_version: int) -> Future:
        i = self._pull_idx
        self._pull_idx += 1
        d = self.plan.decide("pull", i)
        if d.delay_s:
            time.sleep(d.delay_s)
        if d.drop:
            self.events.append({"event": "drop", "op": "pull", "index": i,
                                "min_version": int(min_version)})
            return _failed_future(FaultInjectedError(
                f"pull op {i} (min_version {min_version}) dropped by fault "
                f"plan seed={self.plan.seed}"))
        return self.inner.pull(rows, min_version)

    def event_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e["event"]] = out.get(e["event"], 0) + 1
        return out

    def close(self) -> None:
        for f in self._dup_futures:
            try:
                f.result()
            except TransportError:
                pass
        self.inner.close()
