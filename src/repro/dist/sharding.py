"""Sharding policy (pure metadata — no multi-device runtime required).

One name-based rule table maps every parameter leaf to a PartitionSpec:
matmul weights are FSDP-sharded on their input dim (``data``) and
tensor-parallel on their output dim (``model``); output projections flip
the pair so the TP all-reduce happens after the second matmul; experts are
expert-parallel over ``model``; norms/biases/gates replicate.  Scanned
stacks contribute leading layer dims that are never sharded — the rule
matches the *trailing* dims, so the same table covers unstacked blocks
(zamba2's shared block), scanned stacks, and doubly-stacked VLM groups.

``validate_specs`` then drops any sharded axis that does not divide the
mesh axis size — the dry-run can never hit the pjit divisibility error
(tests/test_sharding.py pins this contract).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_DP_AXES = ("pod", "data")

# leaf name -> (trailing-dim sharding, under-moe override)
_RULES: Dict[str, Tuple] = {
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    "wo": ("model", "data"),
    "out_proj": ("model", "data"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wi": ("data", "model"),
    "wg": ("data", "model"),
    "in_proj": ("data", "model"),
    "wdkv": ("data", None),
    "wuk": (None, "model"),
    "wuv": (None, "model"),
    "wr": ("data", None),
}
# experts carry a leading E dim sharded over `model` (EP); d_model stays FSDP
_MOE_RULES: Dict[str, Tuple] = {
    "wi": ("model", "data", None),
    "wg": ("model", "data", None),
    "wo": ("model", None, "data"),
}


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for entry in path:
        if hasattr(entry, "key"):
            keys.append(str(entry.key))
        elif hasattr(entry, "idx"):
            keys.append(str(entry.idx))
    return tuple(keys)


def spec_for(path_keys: Tuple[str, ...], leaf) -> P:
    """PartitionSpec for one parameter leaf, from its tree path + rank.

    Leading dims beyond the rule's trailing pattern (scan/stack dims) are
    always unsharded; unknown names replicate fully.
    """
    name = path_keys[-1] if path_keys else ""
    parent = path_keys[-2] if len(path_keys) > 1 else ""
    rank = len(np.shape(leaf))
    trailing = None
    if parent == "moe" and name in _MOE_RULES:
        trailing = _MOE_RULES[name]
    elif name in _RULES:
        trailing = _RULES[name]
    if trailing is None or rank < len(trailing):
        return P(*([None] * rank))
    lead = rank - len(trailing)
    return P(*([None] * lead), *trailing)


def param_specs(params) -> Any:
    """PartitionSpec pytree mirroring a parameter pytree (shapes only read)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(_path_keys(path), leaf), params)


def _dp(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in _DP_AXES)


def batch_specs(batch, mesh) -> Any:
    """Input batches shard their leading (batch) dim over the data axes."""
    dp = _dp(mesh)

    def one(leaf):
        rank = len(np.shape(leaf))
        if rank == 0:
            return P()
        return P(dp, *([None] * (rank - 1)))

    return jax.tree_util.tree_map(one, batch)


# decode-cache leaves have a known trailing rank; the batch dim sits just
# before it (leading dims are scan/group stacking, never sharded).
_CACHE_BASE_RANK = {"k": 4, "v": 4, "ckv": 3, "kr": 3,
                    "h": 4, "conv": 3, "mk": 4, "mv": 4}


def cache_pspecs(cache, mesh, cfg=None) -> Any:
    """Decode caches shard their batch dim over the data axes."""
    dp = _dp(mesh)

    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        rank = len(np.shape(leaf))
        base = _CACHE_BASE_RANK.get(name)
        if base is None or rank < base:
            return P(*([None] * rank))
        spec = [None] * rank
        spec[rank - base] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def phi_serving_spec(mesh, phi) -> P:
    """Serving-time spec for a [W, K] topic-word matrix: topics shard over
    the ``model`` axis when the mesh has one and K divides it, words stay
    replicated (every shard folds in the full vocabulary of its documents —
    the same split the training inner loop uses, DESIGN.md §2/§11).

    The W axis is never sharded, so the spec stays valid under dynamic
    vocabulary growth (§12): a phi grown to any capacity rung — including
    the +1 guard/OOV row the serving engine appends — resolves to the same
    ``P(None, 'model')`` with no divisibility constraint on W.

    Specs are dtype-agnostic: a compressed bfloat16 phi_acc (§13,
    ``LDAConfig.phi_acc_dtype``) shards identically to float32 — only the
    per-shard byte footprint halves."""
    spec = P(None, "model" if "model" in mesh.axis_names else None)
    return validate_specs(spec, phi, mesh)


def _axis_size(mesh, entry) -> int:
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    return int(np.prod([mesh.shape[a] for a in axes]))


def validate_specs(specs, tree, mesh) -> Any:
    """Drop every sharded spec axis that does not divide its dim size."""

    def one(spec, leaf):
        shape = np.shape(leaf)
        fixed = []
        for i, entry in enumerate(spec):
            if entry is None or i >= len(shape):
                fixed.append(None)
                continue
            size = _axis_size(mesh, entry)
            fixed.append(entry if size and shape[i] % size == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map(one, specs, tree, is_leaf=_is_spec)
