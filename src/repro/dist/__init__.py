"""Distribution utilities: fault-tolerant checkpointing, sharding policy,
and the pull-based parameter server.

`checkpoint` persists pytrees of (possibly bf16) arrays atomically with a
bounded retention window — the crash/restart contract of launch/train.py and
examples/stream_big_corpus.py.  `sharding` is pure metadata: it maps param /
batch / cache pytrees to PartitionSpecs for the production meshes
(launch/mesh.py) and validates divisibility so pjit never sees a
non-divisible sharded axis (DESIGN.md §6).  `paramserver` is the row-sharded
push/pull sync backend of ``launch.lda_train --backend ps``
(DESIGN.md §15): touched-row delta pushes, prefetched slice pulls, bounded
staleness.  `faults` makes failure a reproducible fixture (DESIGN.md §17):
a seed-replayable ``FaultPlan`` + ``ChaosTransport`` inject drops,
duplicates, delays, partitions, and scheduled server crash/restart into
any transport; the hardened client/server survive them via sequence-number
idempotence, backoff retry, and retained-delta replay.
"""

from repro.dist import checkpoint, faults, paramserver, sharding  # noqa: F401
