"""Distribution utilities: fault-tolerant checkpointing and sharding policy.

`checkpoint` persists pytrees of (possibly bf16) arrays atomically with a
bounded retention window — the crash/restart contract of launch/train.py and
examples/stream_big_corpus.py.  `sharding` is pure metadata: it maps param /
batch / cache pytrees to PartitionSpecs for the production meshes
(launch/mesh.py) and validates divisibility so pjit never sees a
non-divisible sharded axis (DESIGN.md §6).
"""

from repro.dist import checkpoint, sharding  # noqa: F401
