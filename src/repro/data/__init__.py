from repro.data.synthetic import (  # noqa: F401
    drifting_vocab_docs,
    lda_corpus,
    zipf_corpus,
    CorpusStats,
)
from repro.data.batching import (  # noqa: F401
    bucket_len,
    bucketed_minibatch_stream,
    docs_to_padded,
    make_len_buckets,
    minibatch_stream,
    prefetched,
    sharded_minibatch_stream,
    slab_refill,
    stack_shards,
    train_test_split_counts,
    truncate_doc,
    shard_docs,
    vocab_mapped_minibatch_stream,
)
from repro.data.vocab import VocabMap, next_capacity  # noqa: F401
