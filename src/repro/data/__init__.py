from repro.data.synthetic import (  # noqa: F401
    lda_corpus,
    zipf_corpus,
    CorpusStats,
)
from repro.data.batching import (  # noqa: F401
    docs_to_padded,
    minibatch_stream,
    sharded_minibatch_stream,
    train_test_split_counts,
    shard_docs,
)
