"""Synthetic corpora with the statistics the paper's data sets exhibit.

Two generators:
  - ``lda_corpus``: exact LDA generative model (known ground-truth phi) —
    used for accuracy tests: an inference algorithm must recover topics.
  - ``zipf_corpus``: Zipf-distributed word frequencies (power-law marginals,
    Fig. 6 of the paper) — used for power-law/selection benchmarks.

Both return a list of ``(word_ids, counts)`` numpy pairs (one per document)
plus summary stats mirroring Table 3 (D, W, N_token, NNZ).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

Doc = Tuple[np.ndarray, np.ndarray]           # (word_ids[int32], counts[float32])


@dataclasses.dataclass
class CorpusStats:
    num_docs: int
    vocab_size: int
    num_tokens: int
    nnz: int

    def __str__(self) -> str:  # Table 3 style line
        return (f"D={self.num_docs} W={self.vocab_size} "
                f"N_token={self.num_tokens} NNZ={self.nnz}")


def _docs_from_token_lists(token_lists: List[np.ndarray], W: int):
    docs: List[Doc] = []
    n_tok = 0
    nnz = 0
    for toks in token_lists:
        ids, cnt = np.unique(toks, return_counts=True)
        docs.append((ids.astype(np.int32), cnt.astype(np.float32)))
        n_tok += int(toks.size)
        nnz += int(ids.size)
    stats = CorpusStats(len(docs), W, n_tok, nnz)
    return docs, stats


def lda_corpus(
    seed: int,
    num_docs: int,
    vocab_size: int,
    num_topics: int,
    doc_len_mean: int = 160,
    alpha: float = 0.1,
    beta: float = 0.01,
):
    """Sample a corpus from the smoothed-LDA generative model.

    Returns (docs, stats, true_phi[K, W]).
    """
    rng = np.random.default_rng(seed)
    phi = rng.dirichlet(np.full(vocab_size, beta + 0.05), size=num_topics)  # [K, W]
    token_lists = []
    for _ in range(num_docs):
        n = max(4, int(rng.poisson(doc_len_mean)))
        theta = rng.dirichlet(np.full(num_topics, alpha + 0.05))
        z = rng.choice(num_topics, size=n, p=theta)
        # vectorized per-topic word draws
        toks = np.empty(n, np.int64)
        for k in np.unique(z):
            idx = np.nonzero(z == k)[0]
            toks[idx] = rng.choice(vocab_size, size=idx.size, p=phi[k])
        token_lists.append(toks)
    docs, stats = _docs_from_token_lists(token_lists, vocab_size)
    return docs, stats, phi.astype(np.float32)


def lda_corpus_from_phi(seed: int, num_docs: int, phi: np.ndarray,
                        doc_len_mean: int = 160, alpha: float = 0.1):
    """Sample documents from a FIXED topic-word matrix phi[K, W] — for
    streaming scenarios where every mini-batch must share the same
    ground-truth topics (life-long regime, M -> inf)."""
    rng = np.random.default_rng(seed)
    K, W = phi.shape
    token_lists = []
    for _ in range(num_docs):
        n = max(4, int(rng.poisson(doc_len_mean)))
        theta = rng.dirichlet(np.full(K, alpha + 0.05))
        z = rng.choice(K, size=n, p=theta)
        toks = np.empty(n, np.int64)
        for k in np.unique(z):
            idx = np.nonzero(z == k)[0]
            toks[idx] = rng.choice(W, size=idx.size, p=phi[k])
        token_lists.append(toks)
    return _docs_from_token_lists(token_lists, W)


def drifting_vocab_docs(
    seed: int,
    m: int,
    num_docs: int,
    active_vocab: int,
    num_topics: int,
    doc_len_mean: int = 40,
    alpha: float = 0.1,
    score_cache: dict | None = None,
):
    """Batch ``m`` of a drifting-vocabulary stream (DESIGN.md §12).

    The external vocabulary grows over time: batch m draws only from the
    first ``active_vocab`` external word ids, with per-word topic scores
    generated *counter-based* (one rng per (seed, word)), so

      - extending the active prefix never changes earlier words'
        distributions (prefix stability), and
      - batch m is a pure function of (seed, m, active_vocab) — no
        stream state to persist across a crash-resume, and any two runs
        (grown-capacity or fresh-at-final-rung) see identical documents.

    Returns docs with EXTERNAL word ids in [0, active_vocab); feed them
    through ``data.vocab.VocabMap`` for dense phi rows.  ``score_cache``
    (a dict) memoizes the per-word score matrix across batches.
    """
    cache = score_cache if score_cache is not None else {}
    scores = cache.get("scores")
    have = 0 if scores is None else scores.shape[0]
    if have < active_vocab:
        new = np.stack([
            np.random.default_rng([seed, 104_729, w]).gamma(0.5,
                                                            size=num_topics)
            for w in range(have, active_vocab)])
        scores = new if scores is None else np.vstack([scores, new])
        cache["scores"] = scores
    act = scores[:active_vocab] + 1e-6                  # [W_act, K]
    p_wk = act / act.sum(axis=0, keepdims=True)         # per-topic word dist

    rng = np.random.default_rng([seed, 7, m])
    token_lists = []
    for _ in range(num_docs):
        n = max(4, int(rng.poisson(doc_len_mean)))
        theta = rng.dirichlet(np.full(num_topics, alpha + 0.05))
        z = rng.choice(num_topics, size=n, p=theta)
        toks = np.empty(n, np.int64)
        for k in np.unique(z):
            idx = np.nonzero(z == k)[0]
            toks[idx] = rng.choice(active_vocab, size=idx.size, p=p_wk[:, k])
        token_lists.append(toks)
    return _docs_from_token_lists(token_lists, active_vocab)


def drifting_news_stream(
    seed: int,
    m: int,
    num_docs: int,
    vocab_window: int,
    drift_per_batch: int,
    num_topics: int,
    doc_len_mean: int = 40,
    alpha: float = 0.1,
    score_cache: dict | None = None,
    heldout: bool = False,
):
    """Batch ``m`` of a news-like SLIDING-vocabulary stream (DESIGN.md §14).

    Unlike ``drifting_vocab_docs`` (vocabulary only grows), this models
    topic/vocabulary *drift*: batch m draws from the external-id window
    ``[drift_per_batch * m, drift_per_batch * m + vocab_window)`` — every
    batch retires ``drift_per_batch`` old words and introduces as many
    new ones, so the drifting-truth live vocabulary is always exactly
    ``vocab_window`` while the cumulative vocabulary grows without
    bound.  A lifecycle-less model must keep a row for every word ever
    seen (monotone occupancy growth) and keeps spending probability mass
    on words that can no longer occur; decay + compaction keeps both
    bounded — the contrast BENCH_drift measures.

    Per-word topic scores are counter-based (one rng per (seed, word),
    shared with ``drifting_vocab_docs``'s cache layout), so the window's
    word distributions are prefix-stable and batch m is a pure function
    of (seed, m, window, drift) — crash-resume replays identical
    documents.  ``heldout=True`` draws an independent document set from
    the SAME window distribution (a disjoint rng stream): the sliding
    held-out set for perplexity that moves with the drift.

    Returns docs with EXTERNAL word ids; feed them through
    ``data.vocab.VocabMap`` for dense phi rows.
    """
    lo = drift_per_batch * m
    hi = lo + vocab_window
    cache = score_cache if score_cache is not None else {}
    scores = cache.get("scores")
    have = 0 if scores is None else scores.shape[0]
    if have < hi:
        new = np.stack([
            np.random.default_rng([seed, 104_729, w]).gamma(0.5,
                                                            size=num_topics)
            for w in range(have, hi)])
        scores = new if scores is None else np.vstack([scores, new])
        cache["scores"] = scores
    act = scores[lo:hi] + 1e-6                          # [window, K]
    p_wk = act / act.sum(axis=0, keepdims=True)         # per-topic word dist

    rng = np.random.default_rng([seed, 11 if heldout else 7, m])
    token_lists = []
    for _ in range(num_docs):
        n = max(4, int(rng.poisson(doc_len_mean)))
        theta = rng.dirichlet(np.full(num_topics, alpha + 0.05))
        z = rng.choice(num_topics, size=n, p=theta)
        toks = np.empty(n, np.int64)
        for k in np.unique(z):
            idx = np.nonzero(z == k)[0]
            toks[idx] = lo + rng.choice(vocab_window, size=idx.size,
                                        p=p_wk[:, k])
        token_lists.append(toks)
    return _docs_from_token_lists(token_lists, vocab_window)


def zipf_corpus(
    seed: int,
    num_docs: int,
    vocab_size: int,
    doc_len_mean: int = 160,
    zipf_s: float = 1.07,
):
    """Zipf word marginals (power-law, the regime of Fig. 6).  Returns (docs, stats)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    p = ranks ** (-zipf_s)
    p /= p.sum()
    token_lists = []
    for _ in range(num_docs):
        n = max(4, int(rng.poisson(doc_len_mean)))
        token_lists.append(rng.choice(vocab_size, size=n, p=p))
    return _docs_from_token_lists(token_lists, vocab_size)
