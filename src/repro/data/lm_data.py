"""Deterministic synthetic LM token stream.

Tokens are Zipf-distributed (power-law marginals — the regime the paper's
§3.3 analysis assumes) with a learnable bigram structure so a trained LM has
signal to fit.  The stream is a pure function of (seed, step), which makes
checkpoint/restart bit-deterministic: the data cursor is just the step
counter (DESIGN.md §9).
"""

from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def _zipf_probs(vocab: int, s: float = 1.1) -> np.ndarray:
    p = np.arange(1, vocab + 1, dtype=np.float64) ** (-s)
    return (p / p.sum()).astype(np.float32)


def batch_at(seed: int, step: int, batch: int, seq: int, vocab: int,
             shards: int = 0) -> Dict[str, jnp.ndarray]:
    """The batch for a given step (pure function — restartable)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    p = _zipf_probs(vocab)
    toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
    # inject bigram structure: every even position predicts (t*7+3) % vocab
    toks[:, 1::2] = (toks[:, 0:-1:2] * 7 + 3) % vocab
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if shards:
        out = {k: v.reshape(shards, batch // shards, seq) for k, v in
               out.items()}
    return out


def token_stream(seed: int, steps: int, batch: int, seq: int, vocab: int,
                 start_step: int = 0, shards: int = 0
                 ) -> Iterator[Dict[str, jnp.ndarray]]:
    for step in range(start_step, steps):
        yield batch_at(seed, step, batch, seq, vocab, shards)
