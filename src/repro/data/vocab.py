"""Dynamic vocabulary: deterministic token->row assignment + the W
capacity ladder (DESIGN.md §12).

The paper fixes W before step 0; every real stream grows its vocabulary
over time.  This module makes W a *managed runtime dimension* with the
same philosophy the repo already applies to L (shape bucketing, §10):

  - ``VocabMap`` assigns each external token key its phi row in strict
    first-seen order (append-only, never reassigned), so any two runs
    that consume the same batch sequence build bit-identical maps —
    the property that makes grown-run vs fresh-run trajectories and
    crash-resume replay exact.  The map round-trips through the
    checkpoint manifest as a plain key list (row i -> keys[i]).
  - ``next_capacity`` is the geometric W rung ladder: phi_acc/r_glob are
    allocated at the rung, rows in [live_w, W_cap) are *guard rows*
    (zero counts, masked out of power selection, excluded from the
    W*beta smoothing), and a step recompiles only when the live
    vocabulary crosses a rung — compiles stay bounded by
    #W rungs x #L buckets.  Rungs are chosen STRICTLY above live_w so a
    guard row always exists (serving uses the first one as the OOV row).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.data.synthetic import Doc


def next_capacity(live_w: int, current_cap: int = 0, min_cap: int = 64,
                  growth: float = 2.0, multiple: int = 8) -> int:
    """Smallest ladder rung strictly greater than ``live_w``.

    Rungs start at ``min_cap`` (rounded up to ``multiple``) and grow
    geometrically; ``current_cap`` (if already on the ladder) is reused
    as the starting point so repeated calls walk the same rung sequence.
    Strictly greater: the invariant ``live_w < W_cap`` guarantees at
    least one guard row, which doubles as the dead-selection row of the
    masked power selection and the serving OOV row.
    """
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1, got {growth}")
    cap = max(1, -(-int(min_cap) // multiple) * multiple)
    cap = max(cap, int(current_cap))
    while cap <= live_w:
        cap = max(cap + multiple,
                  -(-int(round(cap * growth)) // multiple) * multiple)
    return cap


class VocabMap:
    """Append-only external-token -> dense-row map.

    Keys may be any hashable JSON-able value (ints for the synthetic
    streams, strings for real corpora).  Admission order IS the row
    order; between compaction fences rows are never reassigned or
    reused, so the first ``n`` keys always describe the exact vocabulary
    after the n-th admission — which is what lets the async driver
    checkpoint a consistent prefix (``keys_upto``) while a prefetch
    thread keeps admitting ahead.  ``compact`` (checkpoint-fenced,
    DESIGN.md §14) is the ONE exception: dead rows are reclaimed and
    survivors slide down to a dense prefix, described to the rest of the
    stack by the returned row remap.
    """

    def __init__(self, keys: Iterable = (), touched: Optional[Iterable] = ()):
        self._keys: List = list(keys)
        self._rows: Dict = {k: i for i, k in enumerate(self._keys)}
        if len(self._rows) != len(self._keys):
            raise ValueError("VocabMap keys must be unique")
        # last-touched step per row (-1 = never observed with a step).
        # Touches use max-merge semantics, so replaying an already-consumed
        # batch prefix (crash-resume) reproduces the same touched vector.
        t = list(touched) if touched else []
        if len(t) > len(self._keys):
            raise ValueError(f"touched covers {len(t)} rows but only "
                             f"{len(self._keys)} keys exist")
        self._touched: List[int] = t + [-1] * (len(self._keys) - len(t))

    def __len__(self) -> int:
        return len(self._keys)

    @property
    def live(self) -> int:
        """Current live vocabulary size (== the next row to be assigned)."""
        return len(self._keys)

    def lookup(self, key) -> Optional[int]:
        return self._rows.get(key)

    def admit(self, key, step: Optional[int] = None) -> int:
        """Row of ``key``, appending it if unseen.

        ``step`` stamps the row's last-touched batch index (max-merge:
        an out-of-order or replayed touch never moves the stamp
        backwards), feeding the lifecycle dead-row test (DESIGN.md §14).
        """
        row = self._rows.get(key)
        if row is None:
            row = len(self._keys)
            self._rows[key] = row
            self._keys.append(key)
            self._touched.append(-1)
        if step is not None and self._touched[row] < step:
            self._touched[row] = step
        return row

    def rows(self, keys: Sequence, admit: bool = True,
             oov_row: Optional[int] = None,
             step: Optional[int] = None) -> np.ndarray:
        """Vectorized key -> row translation.

        ``admit=True`` appends unseen keys (training admission);
        ``admit=False`` maps them to ``oov_row`` instead (serving /
        eval: the vocabulary must not move under a lookup).  ``step``
        stamps every translated row as touched at that batch index.
        """
        if admit:
            return np.asarray([self.admit(k, step=step) for k in keys],
                              np.int32)
        if oov_row is None:
            raise ValueError("admit=False needs an oov_row")
        get = self._rows.get
        return np.asarray([get(k, oov_row) for k in keys], np.int32)

    def map_docs(self, docs: Sequence[Doc], admit: bool = True,
                 oov_row: Optional[int] = None,
                 step: Optional[int] = None) -> List[Doc]:
        """Translate a list of (word_keys, counts) docs to row-space docs."""
        return [(self.rows(ids.tolist() if hasattr(ids, "tolist") else ids,
                           admit=admit, oov_row=oov_row, step=step), counts)
                for ids, counts in docs]

    def keys_upto(self, n: int) -> List:
        """The first ``n`` keys — the vocabulary as of the admission that
        produced live size ``n`` (safe to call while another thread
        appends: the prefix of an append-only list is immutable)."""
        return list(self._keys[:n])

    def touched_upto(self, n: int) -> List[int]:
        """Last-touched step of the first ``n`` rows (manifest payload —
        same consistent-prefix contract as ``keys_upto``)."""
        return list(self._touched[:n])

    def compact(self, keep: Sequence[bool]) -> np.ndarray:
        """Drop dead rows; survivors slide down to a dense prefix.

        ``keep`` is a bool mask over the first ``len(keep)`` rows (rows
        beyond it — admitted after the dead decision was taken — are
        always kept).  Returns the int32 remap over the pre-compaction
        live rows: ``remap[i]`` is row i's new row, -1 where reclaimed —
        exactly the payload ``core.lifecycle.apply_row_remap`` and the
        checkpoint row-remap restore consume.  Survivors keep their
        relative order, so the remap is a deterministic function of the
        mask alone (hypothesis-pinned).  Freed rows return to the guard
        pool: the next admissions reuse them before the ladder grows.
        """
        keep = np.asarray(list(keep) + [True] * (len(self._keys) - len(keep)),
                          bool)
        remap = np.where(keep, np.cumsum(keep) - 1, -1).astype(np.int32)
        self._keys = [k for k, b in zip(self._keys, keep) if b]
        self._touched = [t for t, b in zip(self._touched, keep) if b]
        self._rows = {k: i for i, k in enumerate(self._keys)}
        return remap

    def to_state(self) -> List:
        """JSON-able payload for the checkpoint manifest."""
        return list(self._keys)

    @classmethod
    def from_state(cls, keys: Iterable,
                   touched: Optional[Iterable] = ()) -> "VocabMap":
        return cls(keys, touched=touched)
