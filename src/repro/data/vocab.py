"""Dynamic vocabulary: deterministic token->row assignment + the W
capacity ladder (DESIGN.md §12).

The paper fixes W before step 0; every real stream grows its vocabulary
over time.  This module makes W a *managed runtime dimension* with the
same philosophy the repo already applies to L (shape bucketing, §10):

  - ``VocabMap`` assigns each external token key its phi row in strict
    first-seen order (append-only, never reassigned), so any two runs
    that consume the same batch sequence build bit-identical maps —
    the property that makes grown-run vs fresh-run trajectories and
    crash-resume replay exact.  The map round-trips through the
    checkpoint manifest as a plain key list (row i -> keys[i]).
  - ``next_capacity`` is the geometric W rung ladder: phi_acc/r_glob are
    allocated at the rung, rows in [live_w, W_cap) are *guard rows*
    (zero counts, masked out of power selection, excluded from the
    W*beta smoothing), and a step recompiles only when the live
    vocabulary crosses a rung — compiles stay bounded by
    #W rungs x #L buckets.  Rungs are chosen STRICTLY above live_w so a
    guard row always exists (serving uses the first one as the OOV row).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.data.synthetic import Doc


def next_capacity(live_w: int, current_cap: int = 0, min_cap: int = 64,
                  growth: float = 2.0, multiple: int = 8) -> int:
    """Smallest ladder rung strictly greater than ``live_w``.

    Rungs start at ``min_cap`` (rounded up to ``multiple``) and grow
    geometrically; ``current_cap`` (if already on the ladder) is reused
    as the starting point so repeated calls walk the same rung sequence.
    Strictly greater: the invariant ``live_w < W_cap`` guarantees at
    least one guard row, which doubles as the dead-selection row of the
    masked power selection and the serving OOV row.
    """
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1, got {growth}")
    cap = max(1, -(-int(min_cap) // multiple) * multiple)
    cap = max(cap, int(current_cap))
    while cap <= live_w:
        cap = max(cap + multiple,
                  -(-int(round(cap * growth)) // multiple) * multiple)
    return cap


class VocabMap:
    """Append-only external-token -> dense-row map.

    Keys may be any hashable JSON-able value (ints for the synthetic
    streams, strings for real corpora).  Admission order IS the row
    order; rows are never reassigned or reused, so the first ``n`` keys
    always describe the exact vocabulary after the n-th admission —
    which is what lets the async driver checkpoint a consistent prefix
    (``keys_upto``) while a prefetch thread keeps admitting ahead.
    """

    def __init__(self, keys: Iterable = ()):
        self._keys: List = list(keys)
        self._rows: Dict = {k: i for i, k in enumerate(self._keys)}
        if len(self._rows) != len(self._keys):
            raise ValueError("VocabMap keys must be unique")

    def __len__(self) -> int:
        return len(self._keys)

    @property
    def live(self) -> int:
        """Current live vocabulary size (== the next row to be assigned)."""
        return len(self._keys)

    def lookup(self, key) -> Optional[int]:
        return self._rows.get(key)

    def admit(self, key) -> int:
        """Row of ``key``, appending it if unseen."""
        row = self._rows.get(key)
        if row is None:
            row = len(self._keys)
            self._rows[key] = row
            self._keys.append(key)
        return row

    def rows(self, keys: Sequence, admit: bool = True,
             oov_row: Optional[int] = None) -> np.ndarray:
        """Vectorized key -> row translation.

        ``admit=True`` appends unseen keys (training admission);
        ``admit=False`` maps them to ``oov_row`` instead (serving /
        eval: the vocabulary must not move under a lookup).
        """
        if admit:
            return np.asarray([self.admit(k) for k in keys], np.int32)
        if oov_row is None:
            raise ValueError("admit=False needs an oov_row")
        get = self._rows.get
        return np.asarray([get(k, oov_row) for k in keys], np.int32)

    def map_docs(self, docs: Sequence[Doc], admit: bool = True,
                 oov_row: Optional[int] = None) -> List[Doc]:
        """Translate a list of (word_keys, counts) docs to row-space docs."""
        return [(self.rows(ids.tolist() if hasattr(ids, "tolist") else ids,
                           admit=admit, oov_row=oov_row), counts)
                for ids, counts in docs]

    def keys_upto(self, n: int) -> List:
        """The first ``n`` keys — the vocabulary as of the admission that
        produced live size ``n`` (safe to call while another thread
        appends: the prefix of an append-only list is immutable)."""
        return list(self._keys[:n])

    def to_state(self) -> List:
        """JSON-able payload for the checkpoint manifest."""
        return list(self._keys)

    @classmethod
    def from_state(cls, keys: Iterable) -> "VocabMap":
        return cls(keys)
