"""Padded-CSR batching + mini-batch streaming.

The streaming layer realizes the paper's §2.1 contract: mini-batches are
loaded one at a time (constant memory), swept to convergence, then freed.
A background prefetch thread overlaps host-side batch construction with
device compute (the TPU analogue of the paper's disk-as-extension trick).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.types import MiniBatch
from repro.data.synthetic import Doc


def docs_to_padded(docs: Sequence[Doc], max_len: int | None = None,
                   pad_multiple: int = 8) -> MiniBatch:
    """Pack a list of (word_ids, counts) docs into a padded MiniBatch.

    Pads L up to a multiple of ``pad_multiple`` (TPU lane friendliness).
    Documents longer than max_len keep their ``max_len`` highest-count words
    (the tail carries negligible probability mass — same truncation argument
    the paper uses for the vocabulary).
    """
    import jax.numpy as jnp

    if max_len is None:
        max_len = max((len(d[0]) for d in docs), default=1)
    max_len = max(1, -(-max_len // pad_multiple) * pad_multiple)
    D = len(docs)
    wid = np.zeros((D, max_len), np.int32)
    cnt = np.zeros((D, max_len), np.float32)
    for i, (ids, counts) in enumerate(docs):
        if len(ids) > max_len:
            keep = np.argsort(-counts)[:max_len]
            ids, counts = ids[keep], counts[keep]
        wid[i, : len(ids)] = ids
        cnt[i, : len(ids)] = counts
    return MiniBatch(word_ids=jnp.asarray(wid), counts=jnp.asarray(cnt))


def shard_docs(docs: Sequence[Doc], num_shards: int) -> List[List[Doc]]:
    """Evenly distribute documents over shards (paper §4: 'evenly distribute
    D documents to N processors to avoid load imbalance')."""
    shards: List[List[Doc]] = [[] for _ in range(num_shards)]
    order = np.argsort([-float(c.sum()) for _, c in docs])  # greedy balance by tokens
    loads = np.zeros(num_shards)
    for i in order:
        j = int(np.argmin(loads))
        shards[j].append(docs[i])
        loads[j] += float(docs[i][1].sum())
    return shards


def minibatch_stream(
    docs: Sequence[Doc],
    batch_docs: int,
    max_len: int | None = None,
    prefetch: int = 2,
    pad_docs_multiple: int = 1,
) -> Iterator[MiniBatch]:
    """Yield MiniBatches of ``batch_docs`` documents with background prefetch."""
    n_batches = -(-len(docs) // batch_docs)

    def slices():
        for m in range(n_batches):
            chunk = list(docs[m * batch_docs: (m + 1) * batch_docs])
            if pad_docs_multiple > 1 and len(chunk) % pad_docs_multiple:
                # pad with empty docs so shard_map divisibility holds
                pad = pad_docs_multiple - len(chunk) % pad_docs_multiple
                chunk += [(np.zeros(1, np.int32), np.zeros(1, np.float32))] * pad
            yield docs_to_padded(chunk, max_len)

    if prefetch <= 0:
        yield from slices()
        return

    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    _SENTINEL = object()

    def worker():
        try:
            for b in slices():
                q.put(b)
        finally:
            q.put(_SENTINEL)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _SENTINEL:
            break
        yield item
    t.join()


def sharded_minibatch_stream(
    docs: Sequence[Doc],
    batch_docs: int,
    num_shards: int,
    max_len: int | None = None,
    prefetch: int = 2,
) -> Iterator[MiniBatch]:
    """Yield MiniBatches with a leading shard axis [N, Dl, L] (for the
    vmap-simulated POBP path and for host sharding onto a real mesh)."""
    import jax.numpy as jnp

    per_shard = -(-batch_docs // num_shards)
    for mb in minibatch_stream(docs, per_shard * num_shards, max_len,
                               prefetch, pad_docs_multiple=num_shards):
        D, L = mb.word_ids.shape
        yield MiniBatch(
            word_ids=jnp.reshape(mb.word_ids, (num_shards, D // num_shards, L)),
            counts=jnp.reshape(mb.counts, (num_shards, D // num_shards, L)),
        )


def train_test_split_counts(docs: Sequence[Doc], seed: int, test_frac: float = 0.2
                            ) -> Tuple[List[Doc], List[Doc]]:
    """Per-document 80/20 token split for predictive perplexity (paper §4, Eq. 20).

    Splits each document's *token* multiset, returning (train_docs, test_docs)
    aligned by position.
    """
    rng = np.random.default_rng(seed)
    train, test = [], []
    for ids, counts in docs:
        tr = np.zeros_like(counts)
        te = np.zeros_like(counts)
        for j, c in enumerate(counts):
            k = rng.binomial(int(c), test_frac)
            te[j] = k
            tr[j] = c - k
        keep_tr = tr > 0
        keep_te = te > 0
        train.append((ids[keep_tr], tr[keep_tr].astype(np.float32)))
        test.append((ids[keep_te], te[keep_te].astype(np.float32)))
    return train, test
