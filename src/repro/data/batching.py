"""Padded-CSR batching + mini-batch streaming.

The streaming layer realizes the paper's §2.1 contract: mini-batches are
loaded one at a time (constant memory), swept to convergence, then freed.
A background prefetch thread overlaps host-side batch construction with
device compute (the TPU analogue of the paper's disk-as-extension trick).

Shape bucketing (`bucketed_minibatch_stream`) is what makes the streaming
regime *production-grade* under jit: every yielded batch has a constant
document count and an L snapped up to a small ladder of buckets, so an
arbitrary-length corpus hits at most ``len(len_buckets)`` distinct step
shapes — a handful of compiles instead of one per natural shape.
"""

from __future__ import annotations

import queue
import threading
import warnings
from typing import Callable, Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.types import MiniBatch
from repro.data.synthetic import Doc

_SENTINEL = object()


def truncate_doc(ids: np.ndarray, counts: np.ndarray, max_len: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Keep a document's ``max_len`` highest-count words (the tail carries
    negligible probability mass — same truncation argument the paper uses
    for the vocabulary).  No-op for documents that already fit."""
    if len(ids) > max_len:
        keep = np.argsort(-counts)[:max_len]
        return ids[keep], counts[keep]
    return ids, counts


def docs_to_padded(docs: Sequence[Doc], max_len: int | None = None,
                   pad_multiple: int = 8) -> MiniBatch:
    """Pack a list of (word_ids, counts) docs into a padded MiniBatch.

    Pads L up to a multiple of ``pad_multiple`` (TPU lane friendliness).
    Documents longer than max_len are truncated via ``truncate_doc``.
    """
    import jax.numpy as jnp

    if max_len is None:
        max_len = max((len(d[0]) for d in docs), default=1)
    max_len = max(1, -(-max_len // pad_multiple) * pad_multiple)
    D = len(docs)
    wid = np.zeros((D, max_len), np.int32)
    cnt = np.zeros((D, max_len), np.float32)
    for i, (ids, counts) in enumerate(docs):
        ids, counts = truncate_doc(ids, counts, max_len)
        wid[i, : len(ids)] = ids
        cnt[i, : len(ids)] = counts
    return MiniBatch(word_ids=jnp.asarray(wid), counts=jnp.asarray(cnt))


def slab_refill(docs: Sequence[Doc], slot_ids: Sequence[int], *,
                capacity: int, slot_len: int, pad_slot: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pack pending documents into fixed-size slab refill buffers
    (DESIGN.md §16 — the host half of ``core.infer.make_slab_step``).

    Takes up to ``min(len(docs), len(slot_ids), capacity)`` documents and
    lays each into one row of a [capacity, slot_len] (word_rows, counts)
    buffer pair, truncating over-long documents via ``truncate_doc``.
    Unused refill lanes carry ``pad_slot`` as their slot index (the step's
    scatter drops them — ``pad_slot`` must be the slab's slot count).

    Returns ``(word_rows [capacity, slot_len] int32,
    counts [capacity, slot_len] float32, slots [capacity] int32, taken)``
    where ``taken`` is how many documents were actually packed — the
    caller pops exactly that many from its queue and marks that many slot
    ids occupied.
    """
    n = min(len(docs), len(slot_ids), capacity)
    wid = np.zeros((capacity, slot_len), np.int32)
    cnt = np.zeros((capacity, slot_len), np.float32)
    slot = np.full((capacity,), int(pad_slot), np.int32)
    for i in range(n):
        ids, counts = truncate_doc(np.asarray(docs[i][0]),
                                   np.asarray(docs[i][1], np.float32),
                                   slot_len)
        wid[i, : len(ids)] = ids
        cnt[i, : len(ids)] = counts
        slot[i] = int(slot_ids[i])
    return wid, cnt, slot, n


def shard_docs(docs: Sequence[Doc], num_shards: int) -> List[List[Doc]]:
    """Evenly distribute documents over shards (paper §4: 'evenly distribute
    D documents to N processors to avoid load imbalance')."""
    shards: List[List[Doc]] = [[] for _ in range(num_shards)]
    order = np.argsort([-float(c.sum()) for _, c in docs])  # greedy balance by tokens
    loads = np.zeros(num_shards)
    for i in order:
        j = int(np.argmin(loads))
        shards[j].append(docs[i])
        loads[j] += float(docs[i][1].sum())
    return shards


# --------------------------------------------------------------------------
# prefetch plumbing
# --------------------------------------------------------------------------

def _put_until_stopped(q: "queue.Queue", item, stop: threading.Event) -> bool:
    """Bounded put that polls `stop` instead of blocking forever."""
    while not stop.is_set():
        try:
            q.put(item, timeout=0.05)
            return True
        except queue.Full:
            continue
    return False


def prefetched(gen_factory: Callable[[], Iterator], prefetch: int) -> Iterator:
    """Run ``gen_factory()`` on a background thread with a bounded queue.

    The worker never blocks unconditionally on a full queue: its puts poll a
    stop event, so *abandoning* the returned generator (a consumer crash, a
    cancelled request — Python delivers GeneratorExit via ``close()``/GC)
    stops and joins the thread instead of leaking it parked on ``q.put``
    forever.  Worker exceptions are re-raised in the consumer.
    """
    if prefetch <= 0:
        yield from gen_factory()
        return

    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()
    err: List[BaseException] = []

    def worker():
        try:
            for item in gen_factory():
                if not _put_until_stopped(q, item, stop):
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced in the consumer
            err.append(e)
        finally:
            _put_until_stopped(q, _SENTINEL, stop)

    t = threading.Thread(target=worker, daemon=True, name="repro-prefetch")
    t.start()
    raised = False
    try:
        while True:
            item = q.get()
            if item is _SENTINEL:
                break
            yield item
        if err:
            raised = True
            raise err[0]
    finally:
        # normal exhaustion, consumer exception, or GeneratorExit: the
        # worker always observes `stop` within one put poll and terminates.
        stop.set()
        t.join(timeout=10.0)
        if t.is_alive():
            # a silent leak otherwise: the daemon thread would park in
            # gen_factory() past this generator's lifetime
            warnings.warn(
                "prefetch worker 'repro-prefetch' failed to stop within "
                "10s of shutdown and was leaked (stuck in the source "
                "generator?)", RuntimeWarning, stacklevel=2)
        if err and not raised:
            # the consumer is shutting down (GeneratorExit / early close),
            # so raising would be swallowed — at least make it loud
            warnings.warn(
                f"prefetch worker died with {err[0]!r}; the exception was "
                f"masked by consumer shutdown", RuntimeWarning,
                stacklevel=2)


def minibatch_stream(
    docs: Sequence[Doc],
    batch_docs: int,
    max_len: int | None = None,
    prefetch: int = 2,
    pad_docs_multiple: int = 1,
) -> Iterator[MiniBatch]:
    """Yield MiniBatches of ``batch_docs`` documents with background prefetch."""
    n_batches = -(-len(docs) // batch_docs)

    def slices():
        for m in range(n_batches):
            chunk = list(docs[m * batch_docs: (m + 1) * batch_docs])
            if pad_docs_multiple > 1 and len(chunk) % pad_docs_multiple:
                # pad with empty docs so shard_map divisibility holds
                pad = pad_docs_multiple - len(chunk) % pad_docs_multiple
                chunk += [(np.zeros(1, np.int32), np.zeros(1, np.float32))] * pad
            yield docs_to_padded(chunk, max_len)

    yield from prefetched(slices, prefetch)


def sharded_minibatch_stream(
    docs: Sequence[Doc],
    batch_docs: int,
    num_shards: int,
    max_len: int | None = None,
    prefetch: int = 2,
) -> Iterator[MiniBatch]:
    """Yield MiniBatches with a leading shard axis [N, Dl, L] (for the
    vmap-simulated POBP path and for host sharding onto a real mesh)."""
    per_shard = -(-batch_docs // num_shards)
    for mb in minibatch_stream(docs, per_shard * num_shards, max_len,
                               prefetch, pad_docs_multiple=num_shards):
        yield stack_shards(mb, num_shards)


# --------------------------------------------------------------------------
# shape bucketing
# --------------------------------------------------------------------------

def stack_shards(mb: MiniBatch, num_shards: int) -> MiniBatch:
    """[D, L] -> [N, D//N, L] leading-shard stack (host-side sharding for
    the vmap simulation; shard_map shards the flat batch on device)."""
    if num_shards <= 1:
        return mb
    import jax.numpy as jnp

    D, L = mb.word_ids.shape
    if D % num_shards:
        raise ValueError(f"batch of {D} docs does not divide over "
                         f"{num_shards} shards")
    return MiniBatch(
        word_ids=jnp.reshape(mb.word_ids, (num_shards, D // num_shards, L)),
        counts=jnp.reshape(mb.counts, (num_shards, D // num_shards, L)))


def make_len_buckets(max_len: int, min_len: int = 8, growth: float = 2.0,
                     pad_multiple: int = 8) -> Tuple[int, ...]:
    """Geometric ladder of L buckets covering [1, max_len].

    Every bucket is a multiple of ``pad_multiple`` (so ``docs_to_padded``
    pads exactly to the bucket) and the last bucket is >= max_len.
    """
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1, got {growth}")
    buckets: List[int] = []
    b = float(max(min_len, 1))
    while True:
        bb = int(-(-int(round(b)) // pad_multiple) * pad_multiple)
        if not buckets or bb > buckets[-1]:
            buckets.append(bb)
        if bb >= max_len:
            return tuple(buckets)
        b *= growth


def bucket_len(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; the largest bucket when n exceeds them all
    (``docs_to_padded`` then truncates each doc to the bucket — the same
    highest-count-tail truncation contract as its ``max_len``)."""
    for b in buckets:
        if b >= n:
            return int(b)
    return int(buckets[-1])


def bucketed_minibatch_stream(
    docs: Sequence[Doc],
    batch_docs: int,
    num_shards: int = 1,
    len_buckets: Sequence[int] = (16, 32, 64, 128),
    prefetch: int = 2,
) -> Iterator[MiniBatch]:
    """Shape-bucketed streaming for the production driver.

    Every yielded batch has EXACTLY ``batch_docs`` documents (a short final
    chunk is padded with empty docs, so D never varies) and an L snapped up
    to one of ``len_buckets`` — an arbitrary-length corpus therefore
    compiles a jitted step at most ``len(len_buckets)`` times.  Yields
    [N, Dl, L]-stacked MiniBatches when ``num_shards > 1``.
    """
    len_buckets = tuple(sorted(int(b) for b in len_buckets))
    if any(b % 8 for b in len_buckets):
        raise ValueError(f"len_buckets must be multiples of 8: {len_buckets}")
    if batch_docs % max(num_shards, 1):
        raise ValueError(f"batch_docs={batch_docs} must divide over "
                         f"num_shards={num_shards}")
    n_batches = -(-len(docs) // batch_docs)

    def slices():
        for m in range(n_batches):
            chunk = list(docs[m * batch_docs: (m + 1) * batch_docs])
            nat = max((len(ids) for ids, _ in chunk), default=1)
            if len(chunk) < batch_docs:
                chunk += [(np.zeros(1, np.int32), np.zeros(1, np.float32))
                          ] * (batch_docs - len(chunk))
            mb = docs_to_padded(chunk, max_len=bucket_len(nat, len_buckets))
            yield stack_shards(mb, num_shards)

    yield from prefetched(slices, prefetch)


def vocab_mapped_minibatch_stream(
    docs: Sequence[Doc],
    vocab,
    batch_docs: int,
    num_shards: int = 1,
    len_buckets: Sequence[int] = (16, 32, 64, 128),
    prefetch: int = 2,
    admit: bool = True,
    oov_row: int | None = None,
) -> Iterator[Tuple[MiniBatch, int]]:
    """Shape-bucketed streaming over raw external-id docs (DESIGN.md §12).

    Each chunk's word keys pass through ``vocab`` (a
    ``data.vocab.VocabMap``) *before* padding, so batches carry dense phi
    rows; yields ``(MiniBatch, live_w)`` pairs where ``live_w`` is the
    live vocabulary size after this batch's admissions — the per-batch
    snapshot the dynamic-W training step consumes.  The snapshot is taken
    in generation order on the prefetch thread, so the value is
    deterministic however far prefetch runs ahead.

    This is the admission contract for an in-memory corpus; the streaming
    driver's ``launch.lda_train.drifting_stream`` applies the same
    map->snapshot->bucket->pad sequence to batches it generates lazily
    per (seed, m) (resumable from a cursor, no materialized doc list) —
    keep the two in step.
    """
    len_buckets = tuple(sorted(int(b) for b in len_buckets))
    if any(b % 8 for b in len_buckets):
        raise ValueError(f"len_buckets must be multiples of 8: {len_buckets}")
    if batch_docs % max(num_shards, 1):
        raise ValueError(f"batch_docs={batch_docs} must divide over "
                         f"num_shards={num_shards}")
    n_batches = -(-len(docs) // batch_docs)

    def slices():
        for m in range(n_batches):
            chunk = vocab.map_docs(docs[m * batch_docs: (m + 1) * batch_docs],
                                   admit=admit, oov_row=oov_row)
            live = vocab.live
            nat = max((len(ids) for ids, _ in chunk), default=1)
            if len(chunk) < batch_docs:
                chunk += [(np.zeros(1, np.int32), np.zeros(1, np.float32))
                          ] * (batch_docs - len(chunk))
            mb = docs_to_padded(chunk, max_len=bucket_len(nat, len_buckets))
            yield stack_shards(mb, num_shards), live

    yield from prefetched(slices, prefetch)


def train_test_split_counts(docs: Sequence[Doc], seed: int, test_frac: float = 0.2
                            ) -> Tuple[List[Doc], List[Doc]]:
    """Per-document 80/20 token split for predictive perplexity (paper §4, Eq. 20).

    Splits each document's *token* multiset, returning (train_docs, test_docs)
    aligned by position.
    """
    rng = np.random.default_rng(seed)
    train, test = [], []
    for ids, counts in docs:
        tr = np.zeros_like(counts)
        te = np.zeros_like(counts)
        for j, c in enumerate(counts):
            k = rng.binomial(int(c), test_frac)
            te[j] = k
            tr[j] = c - k
        keep_tr = tr > 0
        keep_te = te > 0
        train.append((ids[keep_tr], tr[keep_tr].astype(np.float32)))
        test.append((ids[keep_te], te[keep_te].astype(np.float32)))
    return train, test
