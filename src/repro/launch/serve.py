"""Serving drivers.

LDA mode (the paper's kind, DESIGN.md §11, §16): load a trained phi from
a streaming-driver checkpoint and serve topic mixtures for an incoming
document stream.  ``--admission slab`` (the default) runs the
continuous-batching `repro.serve.SlabEngine` — in-flight admission,
optional per-tenant theta cache, OOV retraining trigger;
``--admission bucket`` runs the `FoldInEngine` bucket ladder.  With
``--qps`` the stream becomes OPEN-LOOP: requests arrive on an
exponential clock at the target rate while the driver services the
engine between arrivals (the sustained-load protocol BENCH_serve
gates on); ``--swap-at 0.5`` hot-swaps phi mid-stream and ``--slo-ms``
checks p99 against a latency objective.  ``--report-json PATH`` writes
the full latency/goodput/oov report as JSON.

  # 1. train + checkpoint
  PYTHONPATH=src python -m repro.launch.lda_train --ckpt-dir /tmp/lda_ck
  # 2. serve from the checkpoint (closed-loop)
  PYTHONPATH=src python -m repro.launch.serve --mode lda \
      --ckpt-dir /tmp/lda_ck --requests 256
  # 3. sustained load at 500 docs/s with a mid-stream hot-swap
  PYTHONPATH=src python -m repro.launch.serve --mode lda \
      --ckpt-dir /tmp/lda_ck --requests 2000 --qps 500 --swap-at 0.5 \
      --slo-ms 200 --report-json /tmp/serve_report.json

LM mode: batched prefill + greedy decode with KV caches (exercises the same
decode_step the decode_32k/long_500k dry-run cells lower).

  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch smollm-360m \
      --reduced --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry


def run_open_loop(engine, reqs, qps: float, *, seed: int = 0,
                  swap_at=None, swap_fn=None, max_age_s: float = 0.05,
                  tenants=None):
    """Open-loop sustained load: submit ``reqs`` on an exponential
    arrival clock at ``qps`` docs/s, servicing the engine between
    arrivals (slab: ``step``; bucket: ``flush_stale`` + ``poll``).
    The arrival process never waits for the engine — exactly the regime
    where bucket barriers turn into queueing delay.  ``swap_fn(engine)``
    fires once when ``swap_at`` (a stream fraction) is crossed.
    Returns ``(results, wall_s)``."""
    from repro.serve import SlabEngine

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, size=len(reqs))
    is_slab = isinstance(engine, SlabEngine)
    swap_idx = (int(swap_at * len(reqs)) if swap_at is not None else None)
    results = []
    t0 = time.time()
    arrive = t0 + np.cumsum(gaps)
    for i, doc in enumerate(reqs):
        if swap_idx is not None and i == swap_idx and swap_fn is not None:
            swap_fn(engine)
        while True:
            now = time.time()
            if now >= arrive[i]:
                break
            if is_slab:
                if engine.in_flight():
                    engine.step()
                    results.extend(engine.poll())
                else:
                    time.sleep(min(1e-3, arrive[i] - now))
            else:
                n = engine.flush_stale(max_age_s)
                got = engine.poll()
                results.extend(got)
                if not n and not got:
                    time.sleep(min(1e-3, arrive[i] - now))
        if tenants is not None and is_slab:
            engine.submit(doc, tenant=tenants[i])
        else:
            engine.submit(doc)
    results.extend(engine.drain())
    return results, time.time() - t0


def _request_stream(cfg, args):
    """Synthetic mixed-length ingress — stands in for production traffic;
    every submit is non-blocking."""
    from repro.data.synthetic import lda_corpus

    means = [int(x) for x in args.doc_len_means.split(",")]
    reqs = []
    for i, mean in enumerate(means):
        d, _, _ = lda_corpus(args.seed + 100 + i,
                             -(-args.requests // len(means)),
                             cfg.vocab_size, cfg.num_topics,
                             doc_len_mean=mean)
        reqs.extend(d)
    return reqs[:args.requests]


def serve_lda(args):
    from repro.serve import FoldInEngine, OOVTrigger, SlabEngine

    if args.admission == "slab":
        engine = SlabEngine.from_checkpoint(
            args.ckpt_dir, slots=args.slots, slot_len=args.slot_len,
            sweeps_per_step=args.sweeps_per_step,
            fold_iters=args.fold_iters, residual_tol=args.tol,
            topic_shards=args.topic_shards, seed=args.seed,
            theta_cache=args.theta_cache or None,
            cache_mode=args.cache_mode,
            oov_trigger=(OOVTrigger(args.oov_retrain_rate)
                         if args.oov_retrain_rate > 0 else None),
            admission_slo_s=(args.admission_slo_ms / 1e3
                             if args.admission_slo_ms else None))
        geom = (f"slab {engine.slots}x{engine.slot_len} "
                f"({engine.sweeps_per_step} sweeps/step)")
    else:
        engine = FoldInEngine.from_checkpoint(
            args.ckpt_dir,
            len_buckets=tuple(int(b) for b in args.len_buckets.split(",")),
            batch_docs=args.batch, fold_iters=args.fold_iters,
            residual_tol=args.tol, topic_shards=args.topic_shards,
            seed=args.seed)
        geom = f"buckets {engine.len_buckets}"
    cfg = engine.cfg
    print(f"[load] phi[{cfg.vocab_size}, {cfg.num_topics}] from "
          f"{args.ckpt_dir}  (live vocab {engine.live_words}, "
          f"warmup {engine.warmup_s:.2f}s, {geom})")

    reqs = _request_stream(cfg, args)
    swap_fn = None
    if args.swap_at is not None:
        # mid-stream hot-swap: re-serve the SAME checkpointed statistic
        # as a new generation — exercises the fencing, version stamping
        # and cache invalidation without needing a second training run
        from repro.dist import checkpoint as ckpt

        phi_next, _, _ = ckpt.restore_phi(args.ckpt_dir,
                                          dtype=jnp.float32)

        def swap_fn(e, _phi=phi_next):
            t0 = time.time()
            e.swap_phi(_phi)
            print(f"[swap] phi generation {e.phi_version} installed "
                  f"({time.time() - t0:.2f}s fence+install)")

    t_wall0 = time.time()
    if args.qps > 0:
        results, wall = run_open_loop(
            engine, reqs, args.qps, seed=args.seed, swap_at=args.swap_at,
            swap_fn=swap_fn, max_age_s=args.max_age_ms / 1e3)
    else:
        if swap_fn is not None:
            half = int(args.swap_at * len(reqs))
            for doc in reqs[:half]:
                engine.submit(doc)
            swap_fn(engine)
            for doc in reqs[half:]:
                engine.submit(doc)
        else:
            for doc in reqs:
                engine.submit(doc)
        results = engine.drain()
        wall = time.time() - t_wall0

    s = engine.stats()
    goodput = len(results) / wall if wall > 0 else float("nan")
    batches = (f" in {s['dispatches']} batches" if "dispatches" in s
               else f" over {s['steps']} slab steps")
    print(f"[serve] {s['served']} docs{batches}: "
          f"{goodput:,.0f} docs/s  "
          f"p50={s['latency_p50_s'] * 1e3:.1f}ms  "
          f"p99={s['latency_p99_s'] * 1e3:.1f}ms  "
          f"mean fold iters={s['mean_fold_iters']:.1f}  "
          f"oov rate={s['oov_rate']:.3f}  "
          f"occupancy={s['live_words']}/{s['w_cap']} "
          f"({s['occupancy']:.2f})  "
          f"compiles={s['compiles']}")
    if args.admission == "slab":
        print(f"[slab] occupancy={s['slot_occupancy']:.2f}  "
              f"cache_served={s['cache_served']}  "
              f"warm_starts={s['warm_starts']}  "
              f"retrain_batches={s['retrain_batches']}")
        if s["shed"] or s["quarantined"]:
            print(f"[shed] {s['shed']} requests shed "
                  f"({s['shed_frac']:.2%} of offered load, SLO "
                  f"{s['admission_slo_s']}s)  "
                  f"quarantined={s['quarantined']}")
    if s["bytes_by_phase"]:
        print(f"[comm] per-request bytes={s['per_request_bytes']:,.0f} "
              f"(phases: {s['bytes_by_phase']})")
    slo_ok = None
    if args.slo_ms is not None:
        slo_ok = bool(s["latency_p99_s"] * 1e3 <= args.slo_ms)
        print(f"[slo] p99 {s['latency_p99_s'] * 1e3:.1f}ms vs "
              f"{args.slo_ms:.0f}ms objective: "
              f"{'MET' if slo_ok else 'BREACHED'}")
    top = np.asarray(results[0].theta).argsort()[-3:][::-1]
    print(f"[sample] req 0: top topics {top.tolist()} "
          f"(theta {np.asarray(results[0].theta)[top].round(3).tolist()})")
    if args.report_json:
        report = {"admission": args.admission, "requests": len(reqs),
                  "qps_target": args.qps, "wall_s": wall,
                  "goodput_docs_per_s": goodput, "slo_ms": args.slo_ms,
                  "slo_met": slo_ok, "swap_at": args.swap_at,
                  "stats": s}
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, default=float)
        print(f"[report] wrote {args.report_json}")
    return results, s


def serve_lm(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mod = registry.build(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    total = S + args.gen
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size).astype(jnp.int32)
    caches = registry.cache_zeros(cfg, B, total)

    decode = jax.jit(lambda p, t, c, pos: mod.decode_step(p, t, c, pos, cfg))
    # prefill via decode steps (keeps cache shapes static; production would
    # use forward(mode='prefill') with a right-sized cache)
    tok = prompt[:, :1]
    t0 = time.time()
    out_toks = []
    for i in range(total - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(i))
        if i + 1 < S:
            tok = prompt[:, i + 1:i + 2]
        else:
            last = logits[:, -1] if logits.ndim == 3 else logits
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            out_toks.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    print(f"[serve-lm] {B} streams x {args.gen} new tokens in {dt:.2f}s "
          f"({B * args.gen / max(dt, 1e-9):.1f} tok/s); "
          f"sample: {[int(t[0]) for t in out_toks[:8]]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lda", choices=["lda", "lm"])
    # lda serving
    ap.add_argument("--ckpt-dir", default=None,
                    help="streaming-driver checkpoint to serve from "
                         "(required for --mode lda)")
    ap.add_argument("--admission", default="slab",
                    choices=["slab", "bucket"],
                    help="continuous-batching slab (default) or the "
                         "bucket-ladder baseline")
    ap.add_argument("--slots", type=int, default=64,
                    help="slab: in-flight document slots")
    ap.add_argument("--slot-len", type=int, default=64,
                    help="slab: tokens per slot (longer docs truncate "
                         "by top count mass)")
    ap.add_argument("--sweeps-per-step", type=int, default=4,
                    help="slab: fold-in sweeps per jitted step")
    ap.add_argument("--theta-cache", type=int, default=0,
                    help="slab: theta LRU capacity (0 = off)")
    ap.add_argument("--cache-mode", default="serve",
                    choices=["serve", "warm"],
                    help="slab: cache hits skip fold-in (serve) or "
                         "warm-start it (warm)")
    ap.add_argument("--oov-retrain-rate", type=float, default=0.0,
                    help="slab: OOV token rate that triggers a hot-OOV "
                         "retraining batch (0 = off)")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop arrival rate in docs/s "
                         "(0 = closed-loop: submit all, then drain)")
    ap.add_argument("--swap-at", type=float, default=None,
                    help="hot-swap phi after this fraction of the "
                         "request stream")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="p99 latency objective to check the run against")
    ap.add_argument("--admission-slo-ms", type=float, default=None,
                    help="slab: shed a request at submit when the "
                         "drain-model wait estimate exceeds this deadline "
                         "(typed Shed result; default: queue unboundedly)")
    ap.add_argument("--max-age-ms", type=float, default=50.0,
                    help="bucket: flush a bucket once its oldest request "
                         "waited this long (open-loop only)")
    ap.add_argument("--report-json", default=None,
                    help="write the latency/goodput/oov report to this "
                         "path as JSON")
    ap.add_argument("--len-buckets", default="16,32,64",
                    help="bucket admission L ladder (multiples of 8)")
    ap.add_argument("--fold-iters", type=int, default=30)
    ap.add_argument("--tol", type=float, default=1e-2,
                    help="per-document early-exit residual tolerance")
    ap.add_argument("--topic-shards", type=int, default=1)
    ap.add_argument("--doc-len-means", default="12,24,40")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    # shared / lm
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="lda: docs per fold-in batch (default 32); "
                         "lm: decode streams (default 8)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args(argv)
    if args.batch is None:
        args.batch = 32 if args.mode == "lda" else 8
    if args.mode == "lda":
        if not args.ckpt_dir:
            ap.error("--mode lda needs --ckpt-dir (train one with "
                     "`python -m repro.launch.lda_train --ckpt-dir ...`)")
        serve_lda(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
