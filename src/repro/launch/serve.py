"""Serving drivers.

LDA mode (the paper's kind, DESIGN.md §11): load a trained phi from a
streaming-driver checkpoint and serve topic mixtures for an incoming
document stream through `repro.serve.FoldInEngine` — shape-bucketed
admission, AOT-warmed jitted fold-in (the SAME inference body eval and
training use), asynchronous dispatch, p50/p99 latency + docs/s report.

  # 1. train + checkpoint
  PYTHONPATH=src python -m repro.launch.lda_train --ckpt-dir /tmp/lda_ck
  # 2. serve from the checkpoint
  PYTHONPATH=src python -m repro.launch.serve --mode lda \
      --ckpt-dir /tmp/lda_ck --requests 256

LM mode: batched prefill + greedy decode with KV caches (exercises the same
decode_step the decode_32k/long_500k dry-run cells lower).

  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch smollm-360m \
      --reduced --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry


def serve_lda(args):
    from repro.serve import FoldInEngine

    engine = FoldInEngine.from_checkpoint(
        args.ckpt_dir,
        len_buckets=tuple(int(b) for b in args.len_buckets.split(",")),
        batch_docs=args.batch, fold_iters=args.fold_iters,
        residual_tol=args.tol, topic_shards=args.topic_shards,
        seed=args.seed)
    cfg = engine.cfg
    print(f"[load] phi[{cfg.vocab_size}, {cfg.num_topics}] from "
          f"{args.ckpt_dir}  (live vocab {engine.live_words}, "
          f"warmup {engine.warmup_s:.2f}s, buckets {engine.len_buckets})")

    # synthetic request stream with variable document lengths — stands in
    # for the production ingress; every submit is non-blocking
    from repro.data.synthetic import lda_corpus

    means = [int(x) for x in args.doc_len_means.split(",")]
    reqs = []
    for i, mean in enumerate(means):
        d, _, _ = lda_corpus(args.seed + 100 + i,
                             -(-args.requests // len(means)),
                             cfg.vocab_size, cfg.num_topics,
                             doc_len_mean=mean)
        reqs.extend(d)
    reqs = reqs[:args.requests]

    for doc in reqs:
        engine.submit(doc)
    results = engine.drain()
    s = engine.stats()
    print(f"[serve] {s['served']} docs in {s['dispatches']} batches: "
          f"{s['docs_per_s']:,.0f} docs/s  "
          f"p50={s['latency_p50_s'] * 1e3:.1f}ms  "
          f"p99={s['latency_p99_s'] * 1e3:.1f}ms  "
          f"mean fold iters={s['mean_fold_iters']:.1f}  "
          f"oov rate={s['oov_rate']:.3f}  "
          f"occupancy={s['live_words']}/{s['w_cap']} "
          f"({s['occupancy']:.2f})  "
          f"compiles={s['compiles']} (<= {len(s['len_buckets'])} buckets)")
    if s["bytes_by_phase"]:
        print(f"[comm] per-request bytes={s['per_request_bytes']:,.0f} "
              f"(phases: {s['bytes_by_phase']})")
    top = np.asarray(results[0].theta).argsort()[-3:][::-1]
    print(f"[sample] req 0: top topics {top.tolist()} "
          f"(theta {np.asarray(results[0].theta)[top].round(3).tolist()})")
    return results, s


def serve_lm(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mod = registry.build(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    total = S + args.gen
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size).astype(jnp.int32)
    caches = registry.cache_zeros(cfg, B, total)

    decode = jax.jit(lambda p, t, c, pos: mod.decode_step(p, t, c, pos, cfg))
    # prefill via decode steps (keeps cache shapes static; production would
    # use forward(mode='prefill') with a right-sized cache)
    tok = prompt[:, :1]
    t0 = time.time()
    out_toks = []
    for i in range(total - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(i))
        if i + 1 < S:
            tok = prompt[:, i + 1:i + 2]
        else:
            last = logits[:, -1] if logits.ndim == 3 else logits
            tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
            out_toks.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    print(f"[serve-lm] {B} streams x {args.gen} new tokens in {dt:.2f}s "
          f"({B * args.gen / max(dt, 1e-9):.1f} tok/s); "
          f"sample: {[int(t[0]) for t in out_toks[:8]]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lda", choices=["lda", "lm"])
    # lda serving
    ap.add_argument("--ckpt-dir", default=None,
                    help="streaming-driver checkpoint to serve from "
                         "(required for --mode lda)")
    ap.add_argument("--len-buckets", default="16,32,64",
                    help="admission L buckets (multiples of 8)")
    ap.add_argument("--fold-iters", type=int, default=30)
    ap.add_argument("--tol", type=float, default=1e-2,
                    help="per-document early-exit residual tolerance")
    ap.add_argument("--topic-shards", type=int, default=1)
    ap.add_argument("--doc-len-means", default="12,24,40")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    # shared / lm
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="lda: docs per fold-in batch (default 32); "
                         "lm: decode streams (default 8)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args(argv)
    if args.batch is None:
        args.batch = 32 if args.mode == "lda" else 8
    if args.mode == "lda":
        if not args.ckpt_dir:
            ap.error("--mode lda needs --ckpt-dir (train one with "
                     "`python -m repro.launch.lda_train --ckpt-dir ...`)")
        serve_lda(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
