"""Serving drivers.

LDA mode (the paper's kind): load a trained phi, fold-in batched incoming
documents (theta estimation with phi fixed) and return topic mixtures —
the standard production use of a topic model.

LM mode: batched prefill + greedy decode with KV caches (exercises the same
decode_step the decode_32k/long_500k dry-run cells lower).

  PYTHONPATH=src python -m repro.launch.serve --mode lda
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch smollm-360m \
      --reduced --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import LDAConfig, perplexity, run_stream
from repro.data import docs_to_padded, lda_corpus, minibatch_stream
from repro.models import registry


def serve_lda(args):
    cfg = LDAConfig(vocab_size=500, num_topics=20, lambda_w=0.2,
                    lambda_k_abs=8, inner_iters=10, residual_tol=0.02)
    docs, stats, _ = lda_corpus(0, 400, cfg.vocab_size, cfg.num_topics)
    print(f"[train] {stats}")
    phi, hist, _ = run_stream(minibatch_stream(docs, 100), cfg, num_shards=1)
    phi_norm = perplexity.normalize_phi(phi, cfg.beta)

    # batched serving: fold-in incoming requests with phi fixed
    reqs, _, _ = lda_corpus(7, args.requests, cfg.vocab_size, cfg.num_topics)
    fold = jax.jit(lambda b_ids, b_cnt: perplexity.fold_in_theta(
        jax.random.PRNGKey(1),
        type(docs_to_padded(reqs[:1]))(b_ids, b_cnt), phi_norm, cfg, 20))
    t0 = time.time()
    done = 0
    for i in range(0, len(reqs), args.batch):
        b = docs_to_padded(reqs[i:i + args.batch], max_len=64)
        theta = fold(b.word_ids, b.counts)
        done += theta.shape[0]
    dt = time.time() - t0
    print(f"[serve] {done} docs in {dt:.2f}s "
          f"({done / max(dt, 1e-9):.0f} docs/s); "
          f"theta shape per batch: {theta.shape}")


def serve_lm(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mod = registry.build(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    total = S + args.gen
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size).astype(jnp.int32)
    caches = registry.cache_zeros(cfg, B, total)

    decode = jax.jit(lambda p, t, c, pos: mod.decode_step(p, t, c, pos, cfg))
    # prefill via decode steps (keeps cache shapes static; production would
    # use forward(mode='prefill') with a right-sized cache)
    tok = prompt[:, :1]
    t0 = time.time()
    out_toks = []
    for i in range(total - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(i))
        if i + 1 < S:
            tok = prompt[:, i + 1:i + 2]
        else:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)[..., 0][:, None] \
                if logits.ndim == 3 else jnp.argmax(logits, -1)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out_toks.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    print(f"[serve-lm] {B} streams x {args.gen} new tokens in {dt:.2f}s "
          f"({B * args.gen / max(dt, 1e-9):.1f} tok/s); "
          f"sample: {[int(t[0]) for t in out_toks[:8]]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lda", choices=["lda", "lm"])
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()
    if args.mode == "lda":
        serve_lda(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
