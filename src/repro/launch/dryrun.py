import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove memory fit, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out benchmarks/results/dryrun

The first two lines of this file MUST stay first: jax locks the device
count at first init, and the 512 placeholder CPU devices exist only here —
tests/benchmarks keep seeing 1 device.
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, cell_supported, get_config,  # noqa: E402
                           input_specs)
from repro.configs.base import SHAPES, ArchConfig, ShapeSpec  # noqa: E402
from repro.dist.sharding import (batch_specs, cache_pspecs,  # noqa: E402
                                 param_specs, validate_specs)
from repro.launch.mesh import make_production_mesh, mesh_chip_count  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.models.common import ShardingCtx  # noqa: E402
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update  # noqa: E402


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def n_params(param_structs) -> int:
    return int(sum(x.size for x in jax.tree.leaves(param_structs)))


def n_active_params(cfg: ArchConfig, total: int) -> float:
    """Active params per token (MoE: only routed top-k experts count)."""
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    n_moe_layers = cfg.n_layers - cfg.dense_first_n
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return float(total - inactive)


def grad_accum_steps(cfg: ArchConfig, shape: ShapeSpec, mesh,
                     budget_bytes: float = 1e9) -> int:
    """Microbatch count so the scan-saved residual carries fit the budget.

    Per-device carry bytes ~= n_saved_layers * (B_loc/k) * S * d_model * 2,
    already divided by the TP degree via sequence parallelism."""
    dp = _dp_size(mesh)
    tp = mesh.shape.get("model", 1)
    if cfg.family in ("vlm",):
        n_saved = cfg.n_layers // cfg.cross_attn_every
    elif cfg.family == "hybrid":
        n_saved = cfg.n_layers // cfg.shared_attn_every
    elif cfg.family == "audio":
        n_saved = cfg.n_layers + (cfg.enc_layers or cfg.n_layers)
    else:
        n_saved = cfg.n_layers
    b_loc = max(1, shape.global_batch // dp)
    carry = n_saved * b_loc * shape.seq_len * cfg.d_model * 2 / tp
    k = 1
    while carry / k > budget_bytes and k < b_loc:
        k *= 2
    # floor: micro-batch <= 4 rows/device — bounds the B-proportional
    # transients (attention chunks, SSD chunk buffers) at >=2B-param widths
    if cfg.d_model >= 2048:
        k = max(k, min(b_loc, -(-b_loc // 4)))
    return k


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh,
               donate_cache: bool = True):
    """Returns (fn, arg_structs, in_shardings, out_shardings, donate)."""
    mod = registry.build(cfg)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: mod.init(k, cfg), key)
    p_specs = validate_specs(param_specs(params_s), params_s, mesh)
    p_shard = _shardings(p_specs, mesh)

    if shape.kind == "train":
        # sequence-parallel residual stream (Megatron SP): per-layer saved
        # carries shrink by the TP degree — required for 123B memory fit.
        ctx = ShardingCtx(active=True, batch=dp, model="model", seq="model",
                          mesh=mesh)
        accum = grad_accum_steps(cfg, shape, mesh)
        opt_s = jax.eval_shape(adamw_init, params_s)
        o_specs = AdamWState(master=p_specs, m=p_specs, v=p_specs, step=P())
        o_shard = _shardings(o_specs, mesh)
        batch_s = input_specs(cfg, shape, make=jax.ShapeDtypeStruct)
        b_shard = _shardings(validate_specs(batch_specs(batch_s, mesh),
                                            batch_s, mesh), mesh)
        acfg = AdamWConfig()

        def train_step(params, opt, batch):
            vag = jax.value_and_grad(
                lambda p, b: mod.loss_fn(p, b, cfg, ctx))
            if accum == 1:
                loss, grads = vag(params, batch)
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:]), batch)
                # accumulate in the grad dtype (bf16): at accum<=16 the
                # rounding is negligible next to grad noise, and it halves
                # the accumulation buffers (live-bytes fit at 123B scale)
                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                  params)

                def micro(carry, mb):
                    gsum, lsum = carry
                    l, g = vag(params, mb)
                    gsum = jax.tree.map(lambda a, b: a + b, gsum, g)
                    return (gsum, lsum + l), None

                if cfg.scan_layers:
                    (gsum, lsum), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
                else:
                    carry = (g0, 0.0)
                    for i in range(accum):
                        carry, _ = micro(carry, jax.tree.map(
                            lambda x: x[i], mbs))
                    gsum, lsum = carry
                grads = jax.tree.map(lambda g: g / accum, gsum)
                loss = lsum / accum
            new_params, new_opt = adamw_update(grads, opt, acfg)
            return loss, new_params, new_opt

        # donate params+opt: the update aliases them in place (live-bytes
        # realism — a real trainer never holds two copies of 123B state)
        return (train_step, (params_s, opt_s, batch_s),
                (p_shard, o_shard, b_shard),
                (NamedSharding(mesh, P()), p_shard, o_shard), (0, 1))
    ctx = ShardingCtx(active=True, batch=dp, model="model", mesh=mesh)

    if shape.kind == "prefill":
        batch_s = input_specs(cfg, shape, make=jax.ShapeDtypeStruct)
        b_shard = _shardings(validate_specs(batch_specs(batch_s, mesh), batch_s, mesh), mesh)

        if cfg.family == "audio":
            def prefill_step(params, batch):
                logits, caches, _ = mod.forward(params, batch["tokens"],
                                                batch["frames"], cfg, ctx,
                                                mode="prefill")
                return logits, caches
        else:
            def prefill_step(params, batch):
                logits, caches, _ = mod.forward(
                    params, batch["tokens"], cfg, ctx,
                    image_embeds=batch.get("image_embeds"), mode="prefill")
                return logits, caches

        return (prefill_step, (params_s, batch_s), (p_shard, b_shard),
                None, ())

    # decode: one token against a cache of length seq_len
    B, S = shape.global_batch, shape.seq_len
    cache_s = registry.cache_specs(cfg, B, S)
    c_specs = validate_specs(cache_pspecs(cache_s, mesh, cfg), cache_s, mesh)
    c_shard = _shardings(c_specs, mesh)
    tok_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = P(dp) if B % _dp_size(mesh) == 0 and B > 1 else P()
    tok_shard = NamedSharding(mesh, tok_spec if B > 1 else P())

    def serve_step(params, cache, token, pos):
        logits, new_cache = mod.decode_step(params, token, cache, pos, cfg,
                                            ctx)
        return logits, new_cache

    return (serve_step, (params_s, cache_s, tok_s, pos_s),
            (p_shard, c_shard, tok_shard, NamedSharding(mesh, P())),
            (None, c_shard), (1,) if donate_cache else ())


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def probe_plan(cfg: ArchConfig):
    """(make_cfg(c), (c_a, c_b, c_full)) — c counts scanned stack entries.

    XLA's cost analysis counts while-loop (scan) bodies ONCE, so per-cell
    costs are measured on two UNROLLED reduced-depth builds and extrapolated
    affinely in the stack length (exact: HLO cost is a + b*c)."""
    if cfg.family == "vlm":
        g, full = cfg.cross_attn_every, cfg.n_layers // cfg.cross_attn_every
        return (lambda c: dataclasses.replace(cfg, n_layers=c * g,
                                              scan_layers=False), (1, 2, full))
    if cfg.family == "hybrid":
        g, full = cfg.shared_attn_every, cfg.n_layers // cfg.shared_attn_every
        return (lambda c: dataclasses.replace(cfg, n_layers=c * g,
                                              scan_layers=False), (1, 2, full))
    if cfg.family == "audio":
        return (lambda c: dataclasses.replace(cfg, n_layers=c, enc_layers=c,
                                              scan_layers=False),
                (1, 2, cfg.n_layers))
    full = cfg.n_layers - cfg.dense_first_n
    return (lambda c: dataclasses.replace(
        cfg, n_layers=c + cfg.dense_first_n, scan_layers=False), (1, 2, full))


def _compile_cell(cfg, shape, mesh, donate_cache=True):
    fn, structs, in_sh, out_sh, donate = build_cell(
        cfg, shape, mesh, donate_cache=donate_cache)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*structs)
        compiled = lowered.compile()
    return compiled, structs


def measure_costs(compiled) -> Dict[str, float]:
    cb = rl.collective_bytes(compiled.as_text())
    fb = rl.flops_and_bytes(compiled)
    return {"flops": fb["flops"], "bytes": fb["bytes"],
            "coll_total": cb["total"],
            **{f"coll_{k}": v for k, v in cb.items() if k != "total"}}


def extrapolate_costs(cfg: ArchConfig, shape, mesh) -> Dict[str, Any]:
    """Two unrolled probes -> affine extrapolation of every cost metric."""
    mk, (ca, cb_, cfull) = probe_plan(cfg)
    proben = {}
    for c in (ca, cb_):
        compiled, _ = _compile_cell(mk(c), shape, mesh)
        proben[c] = measure_costs(compiled)
    out = {}
    for k in proben[ca]:
        slope = (proben[cb_][k] - proben[ca][k]) / (cb_ - ca)
        out[k] = max(0.0, proben[ca][k] + slope * (cfull - ca))
    out["probe_counts"] = (ca, cb_, cfull)
    out["probe_raw"] = proben
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             donate_cache: bool = True, probes: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell (full scale, scanned); extract memory fit;
    derive roofline terms from unrolled probes (single-pod only)."""
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind}
    if not cell_supported(arch, shape_name):
        rec["status"] = "skipped (full attention; long_500k is for "
        rec["status"] += "sub-quadratic families — DESIGN.md §6)"
        return rec
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_chip_count(mesh)

    # 1) the required full-scale lower+compile (scanned stacks): proves the
    #    sharding config is coherent and the memory fits.
    t0 = time.time()
    compiled, structs = _compile_cell(cfg, shape, mesh,
                                      donate_cache=donate_cache)
    t_compile = time.time() - t0
    mem = rl.memory_info(compiled)
    total = n_params(structs[0])
    active = n_active_params(cfg, total)
    rec.update(status="ok", chips=chips, compile_s=round(t_compile, 1),
               params_total=total, params_active=int(active), memory=mem,
               scan_counted_once=measure_costs(compiled))

    # 2) roofline terms from unrolled probes (exact per-layer costs).
    if probes:
        t0 = time.time()
        costs = extrapolate_costs(cfg, shape, mesh)
        rec["probe_s"] = round(time.time() - t0, 1)
        terms = rl.roofline_terms(costs["flops"], costs["bytes"],
                                  costs["coll_total"])
        mf = rl.model_flops(cfg, shape, active, chips)
        rec.update(
            hlo_flops=costs["flops"], hlo_bytes=costs["bytes"],
            collective_bytes={k[5:]: v for k, v in costs.items()
                              if k.startswith("coll_")},
            probe_counts=costs["probe_counts"], probe_raw=costs["probe_raw"],
            compute_s=terms.compute_s, memory_s=terms.memory_s,
            collective_s=terms.collective_s, dominant=terms.dominant,
            model_flops=mf, useful_flop_ratio=mf / max(costs["flops"], 1.0),
            roofline_fraction=terms.fraction_of_roofline,
        )
    return rec


def run_lda_cell(K: int, mesh_kind: str, sync_mode: str,
                 D_m: int = 8192, L: int = 128, W: int = 141043
                 ) -> Dict[str, Any]:
    """The paper's own workload at PUBMED scale on the production mesh:
    one POBP mini-batch under shard_map — documents over the data (and pod)
    axes, topics over the model axis.  The HLO while-body collectives give
    the *per-iteration* sync bytes, so the Eq. 5 (dense) vs Eq. 6 (power)
    reduction is measured directly in the compiled collective schedule."""
    from repro.core.pobp import shard_map_minibatch_fn
    from repro.core.types import LDAConfig

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_chip_count(mesh)
    model_size = mesh.shape["model"]
    cfg = LDAConfig(vocab_size=W, num_topics=K,
                    lambda_w=0.1,
                    lambda_k_abs=max(1, round(50 / model_size)),  # global ~50
                    inner_iters=200, residual_tol=0.1)

    # the SAME shard_map'd step the streaming driver executes
    # (launch.lda_train --backend shard_map) — compile-only here.
    fn, _meter = shard_map_minibatch_fn(cfg, mesh, sync_mode)

    wid_s = jax.ShapeDtypeStruct((D_m, L), jnp.int32)
    cnt_s = jax.ShapeDtypeStruct((D_m, L), jnp.float32)
    phi_s = jax.ShapeDtypeStruct((W, K), jnp.float32)
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    w_s = jax.ShapeDtypeStruct((), jnp.float32)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(wid_s, cnt_s, phi_s, key_s, w_s)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    txt = compiled.as_text()
    loop_bytes, once_bytes, per_comp = rl.collective_bytes_split(txt)
    fb = rl.flops_and_bytes(compiled)
    mem = rl.memory_info(compiled)
    from repro.core.sync import dense_sync_bytes, power_sync_bytes
    # packed phi+r and the r_w vector (Eq. 6) / per-device phi+r (Eq. 5)
    analytic_power = power_sync_bytes(cfg.num_power_words,
                                      cfg.num_power_topics, W)
    analytic_dense = 2 * dense_sync_bytes(W, K // model_size)
    # T-iteration mini-batch totals (T=200 the paper's regime)
    T = cfg.inner_iters
    total_coll = once_bytes + loop_bytes * (T - 1)
    return {
        "arch": f"lda-pubmed-K{K}", "shape": f"pobp_{sync_mode}",
        "mesh": mesh_kind, "status": "ok", "chips": chips,
        "compile_s": round(t_compile, 1), "memory": mem,
        "hlo_flops_per_iter": fb["flops"], "hlo_bytes_per_iter": fb["bytes"],
        "loop_coll_bytes_per_iter": loop_bytes,
        "once_coll_bytes": once_bytes,
        "analytic_loop_bytes_per_iter": (
            analytic_power if sync_mode == "power" else analytic_dense),
        "minibatch_coll_bytes_T200": total_coll,
        "compute_s": fb["flops"] / rl.HW["peak_flops"],
        "memory_s": fb["bytes"] / rl.HW["hbm_bw"],
        "collective_s": total_coll / rl.HW["ici_bw"],
        "dominant": max(
            (("compute", fb["flops"] / rl.HW["peak_flops"]),
             ("memory", fb["bytes"] / rl.HW["hbm_bw"]),
             ("collective", loop_bytes / rl.HW["ici_bw"])),
            key=lambda kv: kv[1])[0],
        "cfg": {"W": W, "K": K, "D_m": D_m, "L": L,
                "P": cfg.num_power_words, "Pk": cfg.num_power_topics},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--lda", action="store_true",
                    help="run the paper's own POBP cells (PUBMED scale)")
    ap.add_argument("--reprobe", action="store_true",
                    help="recompute roofline probes for existing records "
                         "(e.g. after a collective-parser fix)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    if args.reprobe:
        import glob
        for fp in sorted(glob.glob(os.path.join(args.out, "*__single.json"))):
            with open(fp) as f:
                rec = json.load(f)
            if rec.get("status") != "ok" or "lda-pubmed" in rec["arch"]:
                continue
            print(f"[reprobe] {os.path.basename(fp)} ...", flush=True)
            try:
                cfg = get_config(rec["arch"])
                shape = SHAPES[rec["shape"]]
                mesh = make_production_mesh(multi_pod=False)
                costs = extrapolate_costs(cfg, shape, mesh)
                terms = rl.roofline_terms(costs["flops"], costs["bytes"],
                                          costs["coll_total"])
                mf = rl.model_flops(cfg, shape, rec["params_active"],
                                    rec["chips"])
                rec.update(
                    hlo_flops=costs["flops"], hlo_bytes=costs["bytes"],
                    collective_bytes={k[5:]: v for k, v in costs.items()
                                      if k.startswith("coll_")},
                    probe_counts=costs["probe_counts"],
                    probe_raw=costs["probe_raw"],
                    compute_s=terms.compute_s, memory_s=terms.memory_s,
                    collective_s=terms.collective_s,
                    dominant=terms.dominant, model_flops=mf,
                    useful_flop_ratio=mf / max(costs["flops"], 1.0),
                    roofline_fraction=terms.fraction_of_roofline)
                with open(fp, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                print(f"[done] {rec['arch']}/{rec['shape']}: "
                      f"dominant={rec['dominant']} "
                      f"coll={rec['collective_s']:.2e}s", flush=True)
            except Exception as e:
                print(f"[reprobe FAILED] {fp}: {e}", flush=True)
        return

    if args.lda:
        os.makedirs(args.out, exist_ok=True)
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for K in (2000, 10000):
            for mode in ("power", "dense"):
                for mk in meshes:
                    tag = f"lda-pubmed-K{K}__pobp_{mode}__{mk}"
                    fp = os.path.join(args.out, tag + ".json")
                    if os.path.exists(fp):
                        print(f"[skip existing] {tag}")
                        continue
                    print(f"[dryrun] {tag} ...", flush=True)
                    try:
                        rec = run_lda_cell(K, mk, mode)
                    except Exception as e:
                        rec = {"arch": f"lda-pubmed-K{K}",
                               "shape": f"pobp_{mode}", "mesh": mk,
                               "status": f"FAILED: {type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()}
                    with open(fp, "w") as f:
                        json.dump(rec, f, indent=1, default=str)
                    print(f"[done] {tag}: {rec.get('status')}", flush=True)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch}__{shape}__{mk}"
            fp = os.path.join(args.out, tag + ".json")
            if os.path.exists(fp):
                print(f"[skip existing] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                # roofline probes are single-pod only (the §Roofline table);
                # the multi-pod pass proves the 'pod' axis shards.
                rec = run_cell(arch, shape, mk, probes=(mk == "single"))
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "status": f"FAILED: {type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()}
            with open(fp, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            status = rec.get("status")
            extra = ""
            if status == "ok" and "dominant" in rec:
                extra = (f" dominant={rec['dominant']}"
                         f" compute={rec['compute_s']:.2e}s"
                         f" mem={rec['memory_s']:.2e}s"
                         f" coll={rec['collective_s']:.2e}s"
                         f" compile={rec['compile_s']:.0f}s")
            elif status == "ok":
                extra = f" compile={rec['compile_s']:.0f}s (memory-fit pass)"
            print(f"[done] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
