"""Production streaming POBP driver — the paper's Fig. 4 outer loop as a
service-grade artifact (constant memory over an unbounded mini-batch
stream, §3.2 / Table 5).

One jitted, donated-carry step (`repro.core.pobp.make_train_step`)
consumes the stream with:

  - **shape-bucketed batching**: mini-batch L snaps up to a small ladder
    of buckets (`repro.data.batching`), so an arbitrary-length corpus
    compiles the step at most once per bucket instead of once per natural
    shape; D is constant by construction.
  - **asynchronous dispatch**: no ``float()``/``int()`` host sync per
    mini-batch — convergence diagnostics stay on device and are fetched
    every ``--log-every`` batches.
  - **crash-resume**: the full state (phi_acc, m, RNG, stream cursor) is
    checkpointed through `repro.dist.checkpoint`; ``--crash-at N``
    simulates a hard failure on a FRESH run (it does not re-fire on a
    resumed one), so rerunning the same command continues from the
    latest checkpoint with a matching mean_r trajectory.  Resuming
    validates the checkpoint's seed/sync/backend against the flags.
  - **periodic held-out perplexity** every ``--eval-every`` batches,
    through ``perplexity.evaluate`` — i.e. the shared token-major
    fold-in body in `repro.core.infer`, the same program the serving
    engine runs (DESIGN.md §11).
  - execution either as the vmap N-shard simulation (``--backend sim``,
    CPU tests/benchmarks) or under ``shard_map`` on the production mesh
    (``--backend shard_map`` — the dryrun cell's per-shard body, shared
    via `make_mesh_shard_fn`, not forked).
  - **dynamic vocabulary** (``--dynamic-vocab``, DESIGN.md §12): the
    stream's vocabulary drifts; external word keys map to phi rows
    through an append-only ``VocabMap``, phi_acc is allocated on a
    geometric W capacity ladder and grows (``grow_state``) when the live
    vocabulary crosses a rung — compiles stay bounded by
    #rungs x #buckets, growth events are checkpoint-fenced, and
    crash-resume reproduces the grown trajectory exactly.
  - **stream lifecycle** (DESIGN.md §14): ``--decay tau0,kappa`` turns on
    Robbins-Monro forgetting of the phi statistic (kappa=0 bit-exact with
    plain accumulation); ``--compact-every N`` adds checkpoint-fenced
    dead-row compaction (idle + mass-below-prior rows reclaimed, the
    VocabMap remap persisted in the manifest) with optional topic
    recycling (``--recycle-tol``); ``--drift-mode slide`` swaps the
    grow-only stream for the sliding-window news stream whose held-out
    set drifts with it — a month-long stream stays bounded in live rows
    AND keeps fitting the present.

  PYTHONPATH=src python -m repro.launch.lda_train --shards 4 --sync power \
      --minibatches 24 --ckpt-dir /tmp/lda_ck --crash-at 10
  # rerun the same command: resumes from the latest checkpoint

NB: jax is imported lazily so ``--backend shard_map`` can force the host
platform device count before first jax use (same contract as dryrun.py).
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, Optional

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    # stream
    ap.add_argument("--minibatches", type=int, default=24)
    ap.add_argument("--docs-per-batch", type=int, default=64)
    ap.add_argument("--doc-len-means", default="12,24,40",
                    help="cycled per mini-batch: a variable-length stream")
    ap.add_argument("--len-buckets", default="16,32,48",
                    help="L buckets (multiples of 8); compiles <= #buckets")
    ap.add_argument("--fixed-len", action="store_true",
                    help="pad every batch to the largest bucket "
                         "(single-compile baseline for BENCH_e2e)")
    ap.add_argument("--prefetch", type=int, default=2)
    # model
    ap.add_argument("--vocab", type=int, default=500,
                    help="vocabulary size (dynamic mode: the INITIAL "
                         "external vocabulary of the drifting stream)")
    ap.add_argument("--topics", type=int, default=16)
    # dynamic vocabulary (DESIGN.md §12)
    ap.add_argument("--dynamic-vocab", action="store_true",
                    help="treat W as a managed runtime dimension: the "
                         "stream's vocabulary drifts, rows are assigned "
                         "through a VocabMap, and phi grows along the "
                         "capacity ladder (--backend sim only)")
    ap.add_argument("--vocab-growth-per-batch", type=int, default=24,
                    help="external words entering circulation per "
                         "mini-batch (drifting synthetic stream); in "
                         "--drift-mode slide, the words RETIRED per batch "
                         "as well (the window slides)")
    ap.add_argument("--drift-mode", default="grow",
                    choices=["grow", "slide"],
                    help="'grow': vocabulary only accretes "
                         "(drifting_vocab_docs, DESIGN.md §12); 'slide': "
                         "news-like drift — each batch retires as many "
                         "words as it introduces (drifting_news_stream, "
                         "§14), with --vocab as the window size")
    # stream lifecycle (DESIGN.md §14)
    ap.add_argument("--decay", default="1,0",
                    help="Robbins-Monro forgetting 'tau0,kappa' on the phi "
                         "fold-back: retain (1 - (tau0+m)^-kappa) of the "
                         "accumulated statistic each batch; kappa=0 "
                         "disables (bit-exact with the plain accumulator)")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="checkpoint-fenced dead-row compaction every N "
                         "mini-batches (0 = never): reclaim rows idle "
                         ">= --compact-min-idle batches whose decayed mass "
                         "fell below the prior floor, slide survivors to a "
                         "dense prefix, and reuse the freed rows for new "
                         "admissions (dynamic vocab only)")
    ap.add_argument("--compact-min-idle", type=int, default=5,
                    help="batches a row must be untouched before it is a "
                         "compaction candidate")
    ap.add_argument("--compact-mass-tol", type=float, default=25.0,
                    help="dead-mass floor in units of K*beta: a candidate "
                         "row dies when its statistic <= tol*K*beta")
    ap.add_argument("--recycle-tol", type=float, default=0.0,
                    help="recycle topics whose live mass <= tol x the mean "
                         "topic mass, reseeding from high-residual tokens "
                         "at each compaction fence (0 = never)")
    ap.add_argument("--w-cap-min", type=int, default=64,
                    help="first W capacity rung")
    ap.add_argument("--w-growth", type=float, default=2.0,
                    help="geometric W ladder factor")
    ap.add_argument("--lambda-w", type=float, default=0.1)
    ap.add_argument("--lambda-k", type=int, default=8)
    ap.add_argument("--inner-iters", type=int, default=12)
    ap.add_argument("--tol", type=float, default=0.05)
    ap.add_argument("--sync", default="power", choices=["power", "dense"])
    ap.add_argument("--sync-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--sweep-policy", default="auto",
                    choices=["auto", "packed", "dense_layout", "kblocked"],
                    help="selective-sweep formulation: 'auto' picks per "
                         "(T, K, Pk, P) from the measured cost model at "
                         "trace time, falling back to the K-blocked carry "
                         "megakernel when the full-K carry does not fit "
                         "VMEM (DESIGN.md §2/§13); identical math and "
                         "identical Eq. 6 sync bytes either way")
    ap.add_argument("--phi-acc-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="phi_acc storage dtype (DESIGN.md §13): 'bfloat16' "
                         "halves phi HBM + phi-delta sync bytes; the "
                         "accumulate runs in f32 with a stochastic-rounded "
                         "fold-back, so the trajectory tracks f32 within "
                         "rounding noise")
    ap.add_argument("--onehot-crossover", type=int, default=8_000_000,
                    help="T*P above which the packed path's [P, Pk] "
                         "accumulation switches from one-hot contraction "
                         "to row scatter (consumed by the cost model)")
    # execution
    ap.add_argument("--shards", type=int, default=4,
                    help="simulated data shards (--backend sim)")
    ap.add_argument("--backend", default="sim",
                    choices=["sim", "shard_map", "ps"])
    # parameter server (--backend ps, DESIGN.md §15)
    ap.add_argument("--staleness", type=int, default=0,
                    help="bounded staleness S for --backend ps: a pull for "
                         "mini-batch m may be served from a server snapshot "
                         "missing at most the last S pushes; S=0 barriers "
                         "every pull behind the previous push (trajectory "
                         "matches the allreduce backend), S>=1 lets the "
                         "prefetched pull fully overlap the sweep")
    ap.add_argument("--ps-servers", type=int, default=4,
                    help="row-sharded server shards, each owning a "
                         "contiguous phi row range (--backend ps)")
    ap.add_argument("--ps-latency", type=float, default=0.0,
                    help="injected per-operation transport latency in "
                         "seconds (SimTransport) — makes prefetch overlap "
                         "measurable on localhost; 0 = in-process speed")
    ap.add_argument("--ps-pull-timeout", type=float, default=60.0,
                    help="server-side pull wait in seconds before a "
                         "TimeoutError names the shard and awaited version "
                         "(--backend ps); the client retry deadline is "
                         "2x this")
    # chaos / fault tolerance (DESIGN.md §17)
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="FaultPlan seed: replaying the same run replays "
                         "the same drop/dup/delay decisions")
    ap.add_argument("--chaos-drop", type=float, default=0.0,
                    help="per-op drop probability for pushes AND pulls "
                         "(< 1; retries draw fresh fates)")
    ap.add_argument("--chaos-dup", type=float, default=0.0,
                    help="per-op duplicate-delivery probability for pushes "
                         "(exercises sequence-number dedup)")
    ap.add_argument("--chaos-delay", type=float, default=0.0,
                    help="injected issue-side delay in seconds when a "
                         "delay fires")
    ap.add_argument("--chaos-delay-prob", type=float, default=0.0,
                    help="per-op probability of the --chaos-delay")
    ap.add_argument("--chaos-crash", default="",
                    help="scheduled server loss as SERVER@PUSHOP (e.g. "
                         "'1@6'): shard SERVER crashes when the push op "
                         "counter reaches PUSHOP, restarts "
                         "--chaos-restart-after ops later, and recovers "
                         "from the last synced snapshot + client replay")
    ap.add_argument("--chaos-restart-after", type=int, default=2,
                    help="push ops between scheduled crash and restart")
    # elastic worker membership (--backend ps, staleness 0)
    ap.add_argument("--elastic-workers", default="w0",
                    help="comma-separated initial logical worker ids; each "
                         "gets its own PSClient (own seq space + retained "
                         "replay log) over the shared transport, and "
                         "mini-batch m goes to active[m %% len(active)]")
    ap.add_argument("--elastic-events", default="",
                    help="comma-separated membership events "
                         "'join:NAME@M', 'leave:NAME@M', 'crash:NAME@M' "
                         "applied at mini-batch index M (0-based): join/"
                         "leave repartition the stream at the batch fence; "
                         "crash kills NAME mid-batch — its un-pushed batch "
                         "is replayed by a surviving worker (trajectory "
                         "parity at S=0)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"],
                    help="production mesh for --backend shard_map")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh as 'data,model' (smoke tests), "
                         "e.g. --mesh-shape 4,2")
    # driving
    ap.add_argument("--warmup-buckets", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="pre-compile every bucket shape before the stream "
                         "starts (predictable latency: no compile hiccups "
                         "mid-stream; timed throughput is steady-state)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--eval-docs", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a hard failure after minibatch N")
    return ap


def default_args(**overrides) -> argparse.Namespace:
    """Programmatic entry: parser defaults + keyword overrides."""
    args = build_parser().parse_args([])
    for k, v in overrides.items():
        if not hasattr(args, k):
            raise TypeError(f"unknown driver arg: {k}")
        setattr(args, k, v)
    return args


def _csv_ints(s: str):
    return tuple(int(x) for x in str(s).split(",") if str(x).strip())


def _parse_elastic_events(spec: str) -> Dict[int, list]:
    """``"join:w1@4,leave:w0@8,crash:w1@12"`` -> {batch index: [(kind,
    name), ...]}, applied at that 0-based mini-batch (DESIGN.md §17)."""
    events: Dict[int, list] = {}
    for tok in str(spec).split(","):
        tok = tok.strip()
        if not tok:
            continue
        try:
            kind, rest = tok.split(":")
            name, at = rest.split("@")
            at = int(at)
        except ValueError:
            raise ValueError(f"bad --elastic-events entry {tok!r}; expected "
                             f"kind:NAME@M (e.g. 'join:w1@4')") from None
        if kind not in ("join", "leave", "crash"):
            raise ValueError(f"unknown elastic event kind {kind!r} in "
                             f"{tok!r} (join/leave/crash)")
        events.setdefault(at, []).append((kind, name))
    return events


def _parse_decay(s: str):
    parts = [p.strip() for p in str(s).split(",")]
    if len(parts) != 2:
        raise ValueError(f"--decay expects 'tau0,kappa', got {s!r}")
    return float(parts[0]), float(parts[1])


def _build_cfg(args, vocab_size=None):
    from repro.core.types import LDAConfig
    buckets = tuple(sorted(_csv_ints(args.len_buckets)))
    if any(b % 8 for b in buckets):
        # docs_to_padded rounds L up to a multiple of 8: an unaligned bucket
        # would warm up a shape the stream never produces and break the
        # compiles <= #buckets contract
        raise ValueError(f"--len-buckets must be multiples of 8: {buckets}")
    decay_tau0, decay_kappa = _parse_decay(getattr(args, "decay", "1,0"))
    return LDAConfig(vocab_size=vocab_size or args.vocab,
                     num_topics=args.topics,
                     lambda_w=args.lambda_w, lambda_k_abs=args.lambda_k,
                     inner_iters=args.inner_iters, residual_tol=args.tol,
                     decay_tau0=decay_tau0, decay_kappa=decay_kappa,
                     sync_dtype=args.sync_dtype, impl=args.impl,
                     sweep_policy=args.sweep_policy,
                     onehot_crossover=args.onehot_crossover,
                     phi_acc_dtype=args.phi_acc_dtype,
                     init_pad_len=buckets[-1]), buckets


def _true_phi(args):
    """One fixed ground-truth topic set shared by the whole stream
    (life-long regime: every mini-batch is drawn from the same model)."""
    return np.random.default_rng(args.seed).dirichlet(
        np.full(args.vocab, 0.06), size=args.topics).astype(np.float32)


def synthetic_stream(args, buckets, start_m: int, stacked: bool):
    """Deterministic, resumable variable-length stream factory.

    Batch m is generated purely from (seed, m), so resuming from a
    checkpoint cursor only needs `start_m` — no stream state to persist.
    Yields (MiniBatch, host_token_count); batches are [N, Dl, L] stacked
    when `stacked`, global [D, L] otherwise (shard_map shards on device).
    """
    from repro.data.batching import bucket_len, docs_to_padded, stack_shards
    from repro.data.synthetic import lda_corpus_from_phi

    phi = _true_phi(args)
    means = _csv_ints(args.doc_len_means)

    def gen():
        for m in range(start_m, args.minibatches):
            docs, stats = lda_corpus_from_phi(
                args.seed * 1_000_003 + m, args.docs_per_batch, phi,
                doc_len_mean=means[m % len(means)])
            nat = max(len(ids) for ids, _ in docs)
            L = buckets[-1] if args.fixed_len else bucket_len(nat, buckets)
            mb = docs_to_padded(docs, max_len=L)
            if stacked:
                mb = stack_shards(mb, args.shards)
            # tokens actually processed (docs_to_padded truncates docs
            # beyond the bucket); the sync runs on the prefetch thread,
            # never on the dispatch loop
            yield mb, float(mb.counts.sum())

    return gen


def drifting_stream(args, buckets, start_m: int, stacked: bool, vocab,
                    end_m: Optional[int] = None):
    """Deterministic drifting-vocabulary stream (DESIGN.md §12/§14).

    ``--drift-mode grow``: batch m draws from the first
    ``vocab + growth*m`` EXTERNAL word ids; ``--drift-mode slide``: from
    the sliding window ``[growth*m, growth*m + vocab)`` — words retire as
    fast as they arrive (``drifting_news_stream``).  Either way word
    topic scores are counter-based (a pure function of (seed, m)) and
    admission happens through `vocab` in generation order, stamping each
    translated row as touched at batch m; the per-batch live_w snapshot
    is taken right after admission, so it is deterministic however far
    the prefetch thread runs ahead.  Resume replays: a vocab restored
    from the checkpoint prefix re-admits known words as no-ops (touch
    stamps max-merge), and new admissions continue at the same rows.

    ``end_m`` fences the stream: the generator STOPS before batch
    ``end_m``, so the prefetch thread can never admit or touch past a
    compaction fence — the fence's dead-row decisions are a pure
    function of the consumed prefix (DESIGN.md §14).
    Yields (MiniBatch, host_token_count, live_w).
    """
    from repro.data.batching import bucket_len, docs_to_padded, stack_shards
    from repro.data.synthetic import drifting_news_stream, drifting_vocab_docs

    means = _csv_ints(args.doc_len_means)
    cache: Dict[str, Any] = {}
    stop = args.minibatches if end_m is None else end_m
    slide = getattr(args, "drift_mode", "grow") == "slide"

    def gen():
        for m in range(start_m, stop):
            if slide:
                docs, _ = drifting_news_stream(
                    args.seed, m, args.docs_per_batch, args.vocab,
                    args.vocab_growth_per_batch, args.topics,
                    doc_len_mean=means[m % len(means)], score_cache=cache)
            else:
                active = args.vocab + args.vocab_growth_per_batch * m
                docs, _ = drifting_vocab_docs(
                    args.seed, m, args.docs_per_batch, active, args.topics,
                    doc_len_mean=means[m % len(means)], score_cache=cache)
            docs = vocab.map_docs(docs, admit=True, step=m)
            live = vocab.live
            nat = max(len(ids) for ids, _ in docs)
            L = buckets[-1] if args.fixed_len else bucket_len(nat, buckets)
            mb = docs_to_padded(docs, max_len=L)
            if stacked:
                mb = stack_shards(mb, args.shards)
            yield mb, float(mb.counts.sum()), live

    return gen


def _eval_split(args):
    from repro.data.batching import docs_to_padded, train_test_split_counts
    from repro.data.synthetic import lda_corpus_from_phi

    # disjoint from every stream batch seed (those stay < ~minibatches)
    docs, _ = lda_corpus_from_phi(args.seed * 1_000_003 + 987_654_321,
                                  args.eval_docs, _true_phi(args),
                                  doc_len_mean=40)
    train, test = train_test_split_counts(docs, args.seed)
    return docs_to_padded(train), docs_to_padded(test)


def _eval_split_dynamic(args):
    """Held-out docs for the drifting stream, in EXTERNAL id space.

    Drawn from the batch-0 active prefix with a disjoint batch counter, so
    the split never mutates the training vocabulary; each eval call remaps
    through the vocab with OOV words routed to the first guard row, where
    the live-masked phi normalization gives them the beta-prior mass.
    """
    from repro.data.batching import train_test_split_counts
    from repro.data.synthetic import drifting_vocab_docs

    docs, _ = drifting_vocab_docs(args.seed, 987_654_321, args.eval_docs,
                                  args.vocab, args.topics, doc_len_mean=40)
    return train_test_split_counts(docs, args.seed)


def _eval_split_slide(args, m: int):
    """SLIDING held-out docs for --drift-mode slide: an independent
    document set (disjoint rng stream, ``heldout=True``) from the SAME
    window distribution batch ``m`` trains on — the held-out set drifts
    with the stream, so end-of-stream perplexity measures fit to what the
    stream looks like NOW, which is exactly where a decay-less model pays
    for its stale mass (DESIGN.md §14)."""
    from repro.data.batching import train_test_split_counts
    from repro.data.synthetic import drifting_news_stream

    docs, _ = drifting_news_stream(args.seed, m, args.eval_docs, args.vocab,
                                   args.vocab_growth_per_batch, args.topics,
                                   doc_len_mean=40, heldout=True)
    return train_test_split_counts(docs, args.seed)


def _make_mesh(args):
    import jax
    if args.mesh_shape:
        dims = _csv_ints(args.mesh_shape)
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        devices = jax.devices()
        need = int(np.prod(dims))
        if len(devices) < need:
            raise RuntimeError(f"mesh {dims} needs {need} devices, found "
                               f"{len(devices)}")
        return jax.sharding.Mesh(np.asarray(devices[:need]).reshape(dims), axes)
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(args.mesh == "multi"))


def make_shardmap_train_step(cfg, mesh, sync_mode="power",
                             sync_dtype=None, donate: bool = True):
    """The driver step under shard_map on a real mesh: documents over the
    data (and pod) axes, topics over 'model'.  Same carry/diag contract as
    `core.pobp.make_train_step`; the per-shard body is the exact function
    `launch.dryrun.run_lda_cell` compiles (`make_mesh_shard_fn`)."""
    import jax
    import jax.numpy as jnp
    from repro.core import quantize
    from repro.core.pobp import (_SR_FOLD, _decay_factor, _delta_weight,
                                 shard_map_minibatch_fn)
    from repro.core.types import LDATrainState

    sync_dtype = jnp.float32 if sync_dtype is None else sync_dtype
    with_decay = bool(cfg.decay_kappa)
    sm, meter = shard_map_minibatch_fn(cfg, mesh, sync_mode, sync_dtype,
                                       with_decay=with_decay)
    storage = quantize.phi_acc_dtype(cfg)

    def step(state, word_ids, counts):
        rng, sub = jax.random.split(state.rng)
        weight = _delta_weight(cfg, state.m + 1)
        extra = ((_decay_factor(cfg, state.m + 1),) if with_decay else ())
        phi, iters, mean_r = sm(word_ids, counts, state.phi_acc, sub, weight,
                                *extra)
        if storage != jnp.float32:
            # compressed accumulators (§13): stochastic-rounded fold-back to
            # the storage dtype; the fold_in keeps the split stream (and so
            # every f32 trajectory) untouched
            phi = quantize.stochastic_round(
                phi, storage, jax.random.fold_in(sub, _SR_FOLD))
        new_state = LDATrainState(phi_acc=phi, m=state.m + 1, rng=rng)
        return new_state, dict(iters=iters, mean_r=mean_r, theta=None)

    return jax.jit(step, donate_argnums=(0,) if donate else ()), meter


def _with_lookahead(it):
    """Pair each stream item with its successor (None at the end) so the
    PS client can prefetch the NEXT mini-batch's touched rows while the
    current sweep runs (DESIGN.md §15).  Rides on top of the prefetched
    stream, so generation itself still overlaps too."""
    prev = None
    for item in it:
        if prev is not None:
            yield prev, item
        prev = item
    if prev is not None:
        yield prev, None


def _state_tree(state) -> Dict[str, Any]:
    """The checkpoint payload: exactly the driver carry, with stable keys."""
    return {"state": {"phi_acc": state.phi_acc, "m": state.m,
                      "rng": state.rng}}


# every flag that shapes the per-batch trajectory: resuming under ANY other
# value silently breaks the matching-mean_r guarantee, so all are saved in
# the checkpoint and validated on restore.  (minibatches / logging /
# checkpoint cadence / warmup / crash-at only affect when the run stops.)
_RESUME_KEYS = ("seed", "sync", "backend", "shards", "vocab", "topics",
                "lambda_w", "lambda_k", "inner_iters", "tol", "sync_dtype",
                "impl", "docs_per_batch", "doc_len_means", "len_buckets",
                "fixed_len", "dynamic_vocab", "vocab_growth_per_batch",
                "w_cap_min", "w_growth", "drift_mode", "decay",
                "compact_every", "compact_min_idle", "compact_mass_tol",
                "recycle_tol", "staleness", "ps_servers")
# ps_latency is NOT a resume key: injected transport latency changes wall
# clock, never the trajectory (pushes are applied in batch order either way).
# The chaos_* / ps_pull_timeout / elastic_* flags are likewise not resume
# keys: chaos faults are retried/replayed to the SAME committed state (the
# §17 bit-exactness pin), and elastic membership at S=0 only re-labels which
# client pushes a batch — the trajectory is identical (elastic requires
# staleness 0 for exactly this reason).
# NB: sweep_policy / onehot_crossover are deliberately NOT resume keys:
# both formulations compute the same trajectory (within float
# associativity) and the same sync bytes, so a resumed run may re-resolve
# the formulation for its own hardware.  phi_acc_dtype is likewise not a
# resume key: the restore casts the saved phi_acc to the requested storage
# (``cast_dtypes``), so a run may switch between float32 and bfloat16 at a
# checkpoint fence (DESIGN.md §13 — the trajectory then tracks within
# stochastic-rounding noise, not bit-exactly).


def _run_signature(args) -> Dict[str, Any]:
    return {k: getattr(args, k) for k in _RESUME_KEYS}


def _compiles(step_fn) -> int:
    """Compile count via the jitted function's cache (private jax API; -1
    when absent — BENCH_e2e asserts positivity so a break is loud)."""
    try:
        return int(step_fn._cache_size())
    except AttributeError:
        return -1


class _CompileClock:
    """Total jax compile seconds, via a process-wide jax.monitoring listener
    (registered once; train_loop reads before/after snapshots)."""

    def __init__(self):
        self.total = 0.0
        self._registered = False

    def ensure_registered(self):
        if self._registered:
            return
        import jax

        def _on_duration(name, dur, **kw):
            if name.startswith("/jax/core/compile/"):
                self.total += dur

        jax.monitoring.register_event_duration_secs_listener(_on_duration)
        self._registered = True


_COMPILE_CLOCK = _CompileClock()


def train_loop(args, on_batch=None) -> Dict[str, Any]:
    """Run the streaming driver; returns a result dict (see bottom).

    `on_batch(step_no, state, diag)` is an optional per-batch hook (the
    example uses it for RSS tracking); `diag` values are device scalars —
    converting them forces a sync, so hooks should do that sparingly.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.core import lifecycle, perplexity
    from repro.core.pobp import DiagBuffer, init_train_state, make_train_step
    from repro.core.types import LDATrainState
    from repro.data.batching import prefetched
    from repro.data.vocab import VocabMap, next_capacity
    from repro.dist import checkpoint as ckpt

    dynamic = bool(getattr(args, "dynamic_vocab", False))
    if dynamic and args.backend != "sim":
        raise ValueError("--dynamic-vocab currently requires --backend sim "
                         "(shard_map growth is on the ROADMAP backlog)")
    ps = args.backend == "ps"
    if ps and _parse_decay(getattr(args, "decay", "1,0"))[1]:
        raise ValueError("--backend ps with --decay kappa>0 is not supported "
                         "yet: RM forgetting rescales EVERY phi row each "
                         "batch, so a touched-row delta push would silently "
                         "drop the decay on untouched server rows "
                         "(per-segment decay billing rides the multi-host "
                         "backlog item, ROADMAP)")
    chaos_on = bool(getattr(args, "chaos_drop", 0.0)
                    or getattr(args, "chaos_dup", 0.0)
                    or getattr(args, "chaos_delay_prob", 0.0)
                    or getattr(args, "chaos_crash", ""))
    elastic_events = _parse_elastic_events(
        getattr(args, "elastic_events", ""))
    worker_names = [w.strip()
                    for w in getattr(args, "elastic_workers", "w0").split(",")
                    if w.strip()] or ["w0"]
    if len(set(worker_names)) != len(worker_names):
        raise ValueError(f"duplicate --elastic-workers ids: {worker_names}")
    if not ps and (chaos_on or elastic_events or worker_names != ["w0"]):
        raise ValueError("--chaos-* and --elastic-* flags require "
                         "--backend ps (DESIGN.md §17)")
    if elastic_events and args.staleness != 0:
        raise ValueError("--elastic-events requires --staleness 0: crash "
                         "replay parity holds only when every pull reflects "
                         "every prior push (DESIGN.md §17)")
    if (getattr(args, "chaos_crash", "")
            and (len(worker_names) > 1 or elastic_events)):
        raise ValueError(
            "--chaos-crash with multiple/elastic workers is unsupported: "
            "shard recovery replays the RETAINED LOG OF ONE CLIENT, so a "
            "multi-writer shard would come back missing the other "
            "clients' post-fence deltas (DESIGN.md §17 records this "
            "limitation; use a single worker for server-crash chaos)")
    compact_every = int(getattr(args, "compact_every", 0) or 0)
    if compact_every and not dynamic:
        raise ValueError("--compact-every needs --dynamic-vocab: a fixed-W "
                         "run has no VocabMap to compact (DESIGN.md §14)")
    sync_dtype = jnp.bfloat16 if args.sync_dtype == "bfloat16" else jnp.float32

    if args.crash_at and not args.ckpt_dir:
        raise ValueError("--crash-at needs --ckpt-dir: without a checkpoint "
                         "the rerun restarts from scratch and hits the same "
                         "simulated failure forever")
    if args.crash_at and args.ckpt_dir and args.crash_at <= args.ckpt_every:
        print(f"[warn] --crash-at {args.crash_at} fires before the first "
              f"checkpoint (--ckpt-every {args.ckpt_every}); the rerun will "
              f"restart from scratch and crash again", flush=True)

    # dynamic mode: the capacity rung must be known BEFORE the restore
    # template can be built, so peek at the manifest extra first (§12).
    vocab = VocabMap()
    live_done = 0            # live vocab as of the last CONSUMED batch
    vocab_version = 0        # bumped at every compaction fence (§14)
    last_remap = None        # the latest fence's row remap (manifest payload)
    w_cap = next_capacity(0, 0, args.w_cap_min, args.w_growth)
    if dynamic and args.ckpt_dir:
        peeked = ckpt.peek_extra(args.ckpt_dir)
        if peeked is not None and "dyn" in peeked[0]:
            dyn = peeked[0]["dyn"]
            w_cap = int(dyn["w_cap"])
            live_done = int(dyn["live_w"])
            vocab = VocabMap(dyn["vocab_keys"],
                             touched=dyn.get("touched", ()))
            vocab_version = int(dyn.get("vocab_version", 0))
            last_remap = dyn.get("row_remap")

    cfg, buckets = _build_cfg(args, vocab_size=w_cap if dynamic else None)
    state = init_train_state(cfg, args.seed)
    start_m = 0
    if args.ckpt_dir:
        try:
            got = ckpt.restore_latest(args.ckpt_dir, _state_tree(state),
                                      grow_rows=("phi_acc",),
                                      cast_dtypes=("phi_acc",))
        except ValueError as e:
            raise ValueError(
                f"cannot restore checkpoint from {args.ckpt_dir} ({e}); it "
                f"was probably written by an older/other tool — use a fresh "
                f"--ckpt-dir") from e
        if got is not None:
            trees, extra, ck_step = got
            want = _run_signature(args)
            for key, saved in extra.get("run", {}).items():
                if key in want and saved != want[key]:
                    raise ValueError(
                        f"checkpoint in {args.ckpt_dir} was written with "
                        f"{key}={saved!r} but this run has "
                        f"{key}={want[key]!r}; rerun with matching flags "
                        f"or a fresh --ckpt-dir")
            state = LDATrainState(**trees["state"])
            start_m = int(extra["next_m"])
            print(f"[restore] resumed from checkpoint step {ck_step} -> "
                  f"next minibatch {start_m + 1}", flush=True)
            if start_m >= args.minibatches:
                print(f"[restore] checkpoint already covers all "
                      f"{args.minibatches} minibatches — nothing to train "
                      f"(raise --minibatches or use a fresh --ckpt-dir)",
                      flush=True)

    def build_step(cfg):
        if args.backend == "sim":
            return make_train_step(cfg, args.shards, args.sync, sync_dtype)
        if ps:
            # the SAME shard body under the PS wire model (DESIGN.md §15):
            # in-step math is the sim backend's (N simulated shards reduced
            # over the vmap axis — the whole step is ONE PS worker), but the
            # meter bills every vocabulary-row payload as touched-granular
            # push + pull legs.  The host-side exchange is PSClient below.
            from repro.core.sync import (CommMeter, LocalReducer, MeshReducer,
                                         PSReducer)
            meter = CommMeter()
            inner = (LocalReducer(meter=meter, sync_dtype=sync_dtype)
                     if args.shards == 1 else
                     MeshReducer("shards", meter=meter,
                                 sync_dtype=sync_dtype))
            return make_train_step(cfg, args.shards, args.sync, sync_dtype,
                                   reducer=PSReducer(inner))
        mesh = _make_mesh(args)
        return make_shardmap_train_step(cfg, mesh, args.sync, sync_dtype)

    def warm_buckets(step_fn, cfg):
        # AOT warmup: push an all-padding batch of every bucket shape
        # through the step on a throwaway state, so the stream never stalls
        # on a mid-run compile (startup cost, not steady-state cost).  The
        # dynamic variant warms with a live_w argument so the compiled
        # program is the one the stream will actually run.
        scratch = init_train_state(cfg, args.seed)
        for L in (buckets[-1:] if args.fixed_len else buckets):
            if args.backend in ("sim", "ps") and args.shards > 1:
                shape = (args.shards, args.docs_per_batch // args.shards, L)
            else:
                shape = (args.docs_per_batch, L)
            zargs = (jnp.zeros(shape, jnp.int32), jnp.zeros(shape, jnp.float32))
            if dynamic:
                scratch, _ = step_fn(scratch, *zargs,
                                     jnp.asarray(1, jnp.int32))
            else:
                scratch, _ = step_fn(scratch, *zargs)
        jax.block_until_ready(scratch.phi_acc)

    step_fn, meter = build_step(cfg)

    ps_server = ps_transport = touched_rows_of = None
    ps_workers: Dict[str, Any] = {}
    ps_active: list = []
    ps_retired: list = []       # left/crashed workers, kept for stats
    elastic_log: list = []
    if ps:
        from repro.dist.faults import ChaosTransport, FaultPlan
        from repro.dist.paramserver import (ParamServer, PSClient,
                                            SimTransport, touched_rows_of)
        # the server group owns the authoritative statistic; a resumed run
        # rehydrates it from the restored carry at version start_m (the
        # checkpoint was written server-synced, see ps_sync_state)
        ps_server = ParamServer(np.asarray(state.phi_acc, np.float32),
                                num_servers=args.ps_servers,
                                version=start_m,
                                pull_timeout=args.ps_pull_timeout)
        wire_np = (np.float32 if args.sync_dtype == "float32"
                   else jnp.bfloat16)
        ps_transport = SimTransport(ps_server, latency_s=args.ps_latency,
                                    wire_dtype=wire_np)
        if chaos_on:
            crash_server, crash_at = FaultPlan.parse_crash(args.chaos_crash)
            plan = FaultPlan(
                seed=args.chaos_seed, drop_push=args.chaos_drop,
                drop_pull=args.chaos_drop, dup_push=args.chaos_dup,
                delay_s=args.chaos_delay,
                delay_prob=args.chaos_delay_prob,
                crash_server=crash_server, crash_at_push=crash_at,
                restart_after_pushes=args.chaos_restart_after)
            ps_transport = ChaosTransport(ps_transport, plan)

        def make_worker(name: str) -> "PSClient":
            return PSClient(ps_transport, staleness=args.staleness,
                            client_id=name,
                            retry_deadline_s=2.0 * args.ps_pull_timeout,
                            meter=meter)

        ps_workers = {name: make_worker(name) for name in worker_names}
        ps_active = list(worker_names)

    def ps_sync_state():
        """Drain the PS pipeline and adopt the server-authoritative phi as
        the carry (checkpoint fences / end of stream).  At S=0 this is a
        numerical no-op (replica rows equal the server up to the delta-add
        ulp); at S>0 it also heals any bounded staleness in the replica.
        The fence is also the durability handshake (DESIGN.md §17): the
        snapshot becomes the crash-recovery base, so every worker may trim
        its retained replay log."""
        nonlocal state
        for w in ps_workers.values():
            w.flush()
        phi_srv, _ = ps_server.snapshot()
        ps_server.mark_synced()
        for w in ps_workers.values():
            w.mark_durable()
        state = LDATrainState(
            phi_acc=jnp.asarray(phi_srv, state.phi_acc.dtype),
            m=state.m, rng=state.rng)

    def make_stream(seg_start: int, seg_end: int):
        # one prefetched generator per fence segment: the generator stops
        # BEFORE seg_end, so prefetch admissions/touches can never cross a
        # compaction fence (§14 determinism)
        if dynamic:
            return prefetched(
                drifting_stream(args, buckets, seg_start,
                                stacked=(args.backend == "sim"), vocab=vocab,
                                end_m=seg_end),
                args.prefetch)
        return prefetched(
            synthetic_stream(args, buckets, seg_start,
                             stacked=(args.backend in ("sim", "ps"))),
            args.prefetch)

    _COMPILE_CLOCK.ensure_registered()
    warmup_s = 0.0
    if args.warmup_buckets:
        t0 = time.time()
        warm_buckets(step_fn, cfg)
        warmup_s = time.time() - t0

    # per-batch diagnostics: device scalars buffered and flushed to host
    # values in blocks (DiagBuffer), so the stream stays async while live
    # device buffers stay bounded on an unbounded stream (§3.2).
    buf = DiagBuffer(block=max(args.log_every, 64))
    ppl_trace = []
    eval_split = None
    consumed_m = start_m - 1     # last consumed batch index (slide eval)
    slide = dynamic and getattr(args, "drift_mode", "grow") == "slide"

    def heldout():
        nonlocal eval_split
        if slide:
            # sliding held-out set: re-drawn from the CURRENT window each
            # eval, so end-of-stream ppl measures fit to the stream NOW
            return _eval_split_slide(args, max(consumed_m, 0))
        if eval_split is None:  # built once, reused by every eval
            eval_split = (_eval_split_dynamic(args) if dynamic
                          else _eval_split(args))
        return eval_split

    def eval_ppl():
        from repro.data.batching import docs_to_padded
        tr, te = heldout()
        if not dynamic:
            return perplexity.evaluate(jax.random.PRNGKey(args.seed + 1),
                                       state.phi_acc, tr, te, cfg)
        # dynamic: the raw split lives in external-id space — remap it at
        # the CURRENT vocabulary (lookup only, OOV -> first guard row,
        # where the live-masked phi gives the beta-prior mass)
        tr_b = docs_to_padded(vocab.map_docs(tr, admit=False,
                                             oov_row=live_done))
        te_b = docs_to_padded(vocab.map_docs(te, admit=False,
                                             oov_row=live_done))
        return perplexity.evaluate(jax.random.PRNGKey(args.seed + 1),
                                   state.phi_acc, tr_b, te_b, cfg,
                                   live_w=live_done)

    def dyn_extra(next_m: int, live: int) -> Dict[str, Any]:
        extra = {"next_m": next_m, "run": _run_signature(args)}
        if dynamic:
            # touched stamps saved mid-segment may include prefetch-ahead
            # touches of existing rows — harmless: resume replays those
            # batches and max-merge regenerates a superset-consistent
            # vector by the next fence (§14 determinism note).
            # row_remap is the LATEST fence's remap, the manifest payload
            # that lets an older (pre-compaction) phi restore into this
            # row space (dist.checkpoint row_remaps / restore_phi).
            extra["dyn"] = {"w_cap": cfg.vocab_size, "live_w": live,
                            "vocab_keys": vocab.keys_upto(live),
                            "touched": vocab.touched_upto(live),
                            "vocab_version": vocab_version,
                            "row_remap": last_remap}
        if ps:
            # server-side state in the manifest: saves are written with the
            # pipeline drained and the carry server-synced (ps_sync_state),
            # so the phi payload IS the server statistic at this version
            extra["ps"] = {**ps_server.manifest(),
                           "staleness": args.staleness}
        return extra

    tokens = 0.0
    eval_compile_s = 0.0
    growth_s = 0.0
    compact_s = 0.0
    growth_events = []
    compaction_events = []
    occupancy_trace = []
    compiles_prev = 0
    compile_s0 = _COMPILE_CLOCK.total
    t0 = time.time()

    def compaction_fence(fence_m: int):
        """Checkpoint-fenced dead-row compaction + topic recycling (§14).

        Runs with the pipeline drained: the segment generator stopped
        BEFORE `fence_m`, every yielded batch has been consumed, so
        ``vocab.live == live_done`` and the touched vector covers exactly
        the consumed prefix — the dead decision (and hence the remap) is
        a pure function of (stream, fence step).  The fence persists the
        post-compaction state + vocab + remap immediately: a crash on
        either side resumes onto a consistent (phi, vocab) pair.
        """
        nonlocal state, cfg, step_fn, meter, compiles_prev, live_done, \
            vocab_version, last_remap, compact_s
        jax.block_until_ready(state.phi_acc)
        t_c = time.time()
        live = vocab.live
        phi_host = np.asarray(state.phi_acc[:live]).astype(np.float32)
        floor = float(args.compact_mass_tol) * cfg.num_topics * cfg.beta
        dead = lifecycle.dead_rows(
            phi_host.sum(axis=1), vocab.touched_upto(live), fence_m - 1,
            args.compact_min_idle, floor)
        n_dead = int(dead.sum())
        live_new = live
        if n_dead:
            remap = vocab.compact(~dead)
            state = lifecycle.apply_row_remap(state, remap)
            live_new = vocab.live
            last_remap = [int(r) for r in remap]
            vocab_version += 1
        recycled = []
        if args.recycle_tol:
            phi2, recycled = lifecycle.recycle_topics(
                np.asarray(state.phi_acc).astype(np.float32), live_new,
                args.recycle_tol)
            if recycled:
                state = LDATrainState(
                    phi_acc=jnp.asarray(phi2, state.phi_acc.dtype),
                    m=state.m, rng=state.rng)
        # drop capacity rungs the compacted vocabulary no longer needs
        new_cap = next_capacity(live_new, 0, args.w_cap_min, args.w_growth)
        if new_cap < cfg.vocab_size:
            state = lifecycle.resize_state(state, new_cap, live_w=live_new)
            compiles_prev += max(_compiles(step_fn), 0)
            cfg = dataclasses.replace(cfg, vocab_size=new_cap)
            step_fn, meter = build_step(cfg)
            if args.warmup_buckets:
                warm_buckets(step_fn, cfg)
        live_done = live_new
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, fence_m, _state_tree(state),
                      extra=dyn_extra(fence_m, live_done))
        compact_s += time.time() - t_c
        if n_dead or recycled:
            compaction_events.append(
                {"m": fence_m, "dead": n_dead, "live_before": live,
                 "live_after": live_new, "w_cap": cfg.vocab_size,
                 "recycled": recycled})
            print(f"minibatch {fence_m:5d}  [compact] dead={n_dead} "
                  f"live_w={live} -> {live_new}  W_cap={cfg.vocab_size}"
                  + (f"  recycled_topics={recycled}" if recycled else ""),
                  flush=True)
        occupancy_trace.append({"m": fence_m, "live_w": live_done,
                                "w_cap": cfg.vocab_size})

    seg_start = start_m
    while seg_start < args.minibatches:
        # compaction fences cut the stream into segments: a fresh
        # prefetched generator per segment means prefetch can never run
        # past the fence, so the fence sees a fully-drained pipeline
        seg_end = (min(args.minibatches,
                       (seg_start // compact_every + 1) * compact_every)
                   if compact_every else args.minibatches)
        stream = make_stream(seg_start, seg_end)
        if ps:
            stream = _with_lookahead(stream)
        for m, item in enumerate(stream, start=seg_start):
            nxt = None
            if ps:
                item, nxt = item
            if dynamic:
                batch, ntok, live_b = item
            else:
                (batch, ntok), live_b = item, None
            if dynamic and live_b >= cfg.vocab_size:
                # capacity-rung crossing: fence the async pipeline, pad the
                # carry to the next rung (guard rows), rebuild + rewarm the
                # step, and checkpoint the grown state so a crash right here
                # resumes cleanly on the new rung (§12).  live_done (the
                # pre-growth prefix) is what the fence persists — this batch
                # has not been consumed yet.
                jax.block_until_ready(state.phi_acc)
                t_g = time.time()
                new_cap = next_capacity(live_b, cfg.vocab_size,
                                        args.w_cap_min, args.w_growth)
                state = lifecycle.resize_state(state, new_cap)
                compiles_prev += max(_compiles(step_fn), 0)
                cfg = dataclasses.replace(cfg, vocab_size=new_cap)
                step_fn, meter = build_step(cfg)
                if args.warmup_buckets:
                    warm_buckets(step_fn, cfg)
                if args.ckpt_dir:
                    ckpt.save(args.ckpt_dir, m, _state_tree(state),
                              extra=dyn_extra(m, live_done))
                growth_s += time.time() - t_g
                growth_events.append({"m": m, "w_cap": new_cap,
                                      "live_w": live_b})
                print(f"minibatch {m + 1:5d}  [grow] live_w={live_b} -> "
                      f"W_cap={new_cap}", flush=True)
            crash_victims = []
            if ps:
                # elastic membership events fence at batch index m (§17):
                # joins/leaves repartition the round-robin stream BEFORE
                # assignment; a crash fires AFTER the step (the victim
                # dies mid-batch, its push is lost)
                for kind, name in elastic_events.get(m, ()):
                    if kind == "join":
                        if name not in ps_workers:
                            ps_workers[name] = make_worker(name)
                        if name not in ps_active:
                            ps_active.append(name)
                        elastic_log.append({"m": m, "event": "join",
                                            "worker": name})
                    elif kind == "leave":
                        if name not in ps_active:
                            raise ValueError(f"elastic leave of unknown "
                                             f"worker {name!r} at batch {m}")
                        if len(ps_active) == 1:
                            raise ValueError(f"elastic leave of {name!r} at "
                                             f"batch {m} leaves no workers")
                        ps_workers[name].flush()
                        ps_retired.append(ps_workers.pop(name))
                        ps_active.remove(name)
                        elastic_log.append({"m": m, "event": "leave",
                                            "worker": name})
                    else:
                        crash_victims.append(name)
                cli = ps_workers[ps_active[m % len(ps_active)]]
                # refresh the replica's touched rows from the server (waits
                # on the prefetched pull; the wait is the overlap instrument)
                rows = touched_rows_of(batch.word_ids, batch.counts)
                state_pre = None
                if crash_victims:
                    # crash-replay restore point: DEEP copies, because the
                    # victim's step donates every carry leaf (m, rng,
                    # phi_acc) and the survivor must re-run from intact
                    # buffers
                    state_pre = LDATrainState(
                        phi_acc=jnp.array(state.phi_acc),
                        m=jnp.array(state.m), rng=jnp.array(state.rng))
                state = LDATrainState(
                    phi_acc=cli.begin_batch(m + 1, rows,
                                            state.phi_acc),
                    m=state.m, rng=state.rng)
            if dynamic:
                state, diag = step_fn(state, batch.word_ids, batch.counts,
                                      jnp.asarray(live_b, jnp.int32))
            else:
                state, diag = step_fn(state, batch.word_ids, batch.counts)
            if ps:
                for name in crash_victims:
                    if name not in ps_active:
                        continue           # already left/crashed
                    if len(ps_active) == 1:
                        raise ValueError(f"elastic crash of {name!r} at "
                                         f"batch {m} leaves no survivor")
                    assigned = ps_active[m % len(ps_active)] == name
                    ps_retired.append(ps_workers.pop(name))
                    ps_active.remove(name)
                    elastic_log.append({"m": m, "event": "crash",
                                        "worker": name,
                                        "replayed": assigned})
                    if assigned:
                        # the victim died before pushing this batch: a
                        # survivor replays it from the pre-batch carry.
                        # begin_batch re-pulls the same committed rows
                        # (the victim never pushed) and the step re-runs
                        # with the same rng, so the trajectory is
                        # identical to an uncrashed run at S=0 (pinned)
                        cli = ps_workers[ps_active[m % len(ps_active)]]
                        state = LDATrainState(
                            phi_acc=cli.begin_batch(m + 1, rows,
                                                    state_pre.phi_acc),
                            m=state_pre.m, rng=state_pre.rng)
                        if dynamic:
                            state, diag = step_fn(
                                state, batch.word_ids, batch.counts,
                                jnp.asarray(live_b, jnp.int32))
                        else:
                            state, diag = step_fn(state, batch.word_ids,
                                                  batch.counts)
                # prefetch BEFORE the push settles: at S>=1 the pull is
                # served from a bounded-stale snapshot and fully overlaps;
                # at S=0 it blocks server-side until this push commits.
                # The prefetch is issued on the worker the NEXT batch is
                # assigned to (membership events at m+1 may reroute it —
                # the mismatched prefetch is then drained, not leaked).
                if nxt is not None:
                    nb = nxt[0]
                    nxt_cli = ps_workers[ps_active[(m + 1) % len(ps_active)]]
                    nxt_cli.prefetch(
                        m + 2, touched_rows_of(nb.word_ids, nb.counts))
                cli.end_batch(m + 1, state.phi_acc, rows)
            buf.append(diag["mean_r"], diag["iters"])
            tokens += ntok
            if live_b is not None:
                live_done = live_b
            consumed_m = m
            step_no = m + 1
            if args.log_every and step_no % args.log_every == 0:
                # the ONLY recurring host sync, amortized over --log-every
                dt = time.time() - t0
                print(f"minibatch {step_no:5d}  "
                      f"mean_r={float(diag['mean_r']):.4f}"
                      f"  iters={int(diag['iters']):3d}"
                      f"  tokens/s={tokens / max(dt, 1e-9):,.0f}"
                      f"  compiles={compiles_prev + _compiles(step_fn)}",
                      flush=True)
            if args.eval_every and step_no % args.eval_every == 0:
                c_eval = _COMPILE_CLOCK.total
                ppl = eval_ppl()
                eval_compile_s += _COMPILE_CLOCK.total - c_eval
                ppl_trace.append((step_no, float(ppl)))
                print(f"minibatch {step_no:5d}  held-out ppl={ppl:.2f}",
                      flush=True)
            if on_batch is not None:
                on_batch(step_no, state, diag)
            if args.crash_at and step_no == args.crash_at and start_m == 0:
                # fresh runs only: a resumed run sails past the simulated
                # failure, so "rerun the same command" terminates
                raise SystemExit(f"[simulated crash] after minibatch "
                                 f"{step_no}")
            if args.ckpt_dir and args.ckpt_every and \
                    step_no % args.ckpt_every == 0:
                if ps:
                    ps_sync_state()
                ckpt.save(args.ckpt_dir, step_no, _state_tree(state),
                          extra=dyn_extra(step_no, live_done))
        seg_start = seg_end
        if compact_every:
            compaction_fence(seg_end)

    jax.block_until_ready(state.phi_acc)
    if ps:
        # drain + adopt the authoritative server statistic (part of the
        # run: a real fleet pays this once at shutdown)
        ps_sync_state()
    wall = time.time() - t0
    # step-function compiles only: eval jits are accounted separately
    compile_s = _COMPILE_CLOCK.total - compile_s0 - eval_compile_s

    ppl = float(eval_ppl())
    rows = buf.rows()
    mean_r = [float(r) for r, _ in rows]
    iters = [int(i) for _, i in rows]
    # steady-state throughput: mid-stream rung growth and compaction
    # fences (compile + rewarm + fence) are bounded startup-like costs,
    # excluded the same way the pre-loop warmup is; wall_s is inclusive.
    steady_s = max(wall - growth_s - compact_s, 1e-9)
    result = {
        "first_m": start_m,
        "mean_r": mean_r,
        "iters": iters,
        "compiles": compiles_prev + _compiles(step_fn),
        "len_buckets": list(buckets),
        "tokens": tokens,
        "wall_s": wall,
        "warmup_s": warmup_s,
        "compile_s": compile_s,
        "tokens_per_s": tokens / steady_s,
        "ppl": ppl,
        "ppl_trace": ppl_trace,
        "bytes_by_phase": dict(meter.bytes_by_phase),
        "per_minibatch_bytes": (meter.per_minibatch_bytes(iters[-1])
                                if iters else 0),
        "phi_acc": np.asarray(state.phi_acc),
    }
    if ps:
        # aggregate worker-side stats over every client that ever ran
        # (elastic membership: retired workers still did work)
        all_workers = list(ps_workers.values()) + ps_retired
        touched_all = [t for w in all_workers for t in w.touched_history]
        st = {
            "wire_bytes": ps_transport.total_bytes,
            "bytes_by_link": ps_transport.bytes_by_link(),
            "pull_wait_s": sum(w.pull_wait_s for w in all_workers),
            "push_wait_s": sum(w.push_wait_s for w in all_workers),
            "mean_touched_rows": (float(np.mean(touched_all))
                                  if touched_all else 0.0),
        }
        done_b = max(args.minibatches - start_m, 1)
        mt = max(int(round(st["mean_touched_rows"])), 1)
        result.update(
            staleness=args.staleness,
            ps_wire_bytes=int(st["wire_bytes"]),
            ps_wire_per_minibatch=st["wire_bytes"] / done_b,
            ps_pull_wait_s=st["pull_wait_s"],
            ps_push_wait_s=st["push_wait_s"],
            mean_touched_rows=st["mean_touched_rows"],
            ps_bytes_by_link=st["bytes_by_link"],
            # fault-tolerance instruments (DESIGN.md §17)
            ps_retries=sum(w.retries for w in all_workers),
            ps_replayed_pushes=sum(w.replayed_pushes for w in all_workers),
            ps_recoveries=sum(w.recoveries for w in all_workers),
            ps_retry_wire_bytes=sum(w.retry_wire_bytes
                                    for w in all_workers),
            ps_duplicates_dropped=ps_server.duplicates_dropped,
            ps_recovery_log=list(ps_server.recovery_log),
            chaos_events=(ps_transport.event_counts()
                          if chaos_on else {}),
            elastic_log=elastic_log,
            ps_workers=sorted(ps_workers),
            # trace-time push/pull model billed at the measured mean
            # touched-row count (CommMeter w_rows scaling) — the analytic
            # cross-check of the measured wire bytes above
            bytes_by_phase_touched=dict(meter.bytes_by_phase_at(mt)),
            per_minibatch_bytes_touched=(
                meter.per_minibatch_bytes(iters[-1], live_w=mt)
                if iters else 0))
        ps_transport.close()
    if dynamic:
        result.update(
            w_cap=cfg.vocab_size,
            live_w=live_done,
            growth_s=growth_s,
            growth_events=growth_events,
            compact_s=compact_s,
            compaction_events=compaction_events,
            occupancy_trace=occupancy_trace,
            vocab_version=vocab_version,
            vocab_keys=vocab.keys_upto(live_done),
            bytes_by_phase_live=dict(meter.bytes_by_phase_at(live_done)),
            per_minibatch_bytes_live=(
                meter.per_minibatch_bytes(iters[-1], live_w=live_done)
                if iters else 0))
    return result


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.backend == "shard_map" and "XLA_FLAGS" not in os.environ:
        # must happen before first jax import (same contract as dryrun.py)
        n = 512 if not args.mesh_shape else int(np.prod(_csv_ints(args.mesh_shape)))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n}")
    res = train_loop(args)
    done = args.minibatches - res["first_m"]
    print(f"[done] {done} minibatches  final mean_r="
          f"{res['mean_r'][-1] if res['mean_r'] else float('nan'):.4f}  "
          f"held-out ppl={res['ppl']:.2f}")
    print(f"[perf] tokens/s={res['tokens_per_s']:,.0f}  "
          f"compiles={res['compiles']} (buckets={res['len_buckets']})  "
          f"warmup={res['warmup_s']:.1f}s  wall={res['wall_s']:.1f}s "
          f"(+{res['compile_s']:.1f}s in-stream compile)")
    print(f"[comm] per-minibatch bytes={res['per_minibatch_bytes']:,} "
          f"(phases: {res['bytes_by_phase']})")
    if args.backend == "ps":
        print(f"[ps] staleness={res['staleness']}  wire/minibatch="
              f"{res['ps_wire_per_minibatch']:,.0f}B  mean_touched_rows="
              f"{res['mean_touched_rows']:.0f}  pull_wait="
              f"{res['ps_pull_wait_s']:.2f}s  push_wait="
              f"{res['ps_push_wait_s']:.2f}s")
        if res.get("chaos_events") or res.get("ps_retries"):
            print(f"[chaos] events={res['chaos_events']}  "
                  f"retries={res['ps_retries']}  "
                  f"replayed={res['ps_replayed_pushes']}  "
                  f"recoveries={res['ps_recoveries']}  "
                  f"dup_dropped={res['ps_duplicates_dropped']}")
        if res.get("elastic_log"):
            print(f"[elastic] workers={res['ps_workers']}  "
                  f"events={res['elastic_log']}")
    if args.dynamic_vocab:
        print(f"[vocab] live_w={res['live_w']}  W_cap={res['w_cap']}  "
              f"growths={len(res['growth_events'])} "
              f"({res['growth_s']:.1f}s)  per-minibatch bytes at live W="
              f"{res['per_minibatch_bytes_live']:,}")
        if args.compact_every:
            print(f"[lifecycle] compactions={len(res['compaction_events'])} "
                  f"({res['compact_s']:.1f}s)  vocab_version="
                  f"{res['vocab_version']}  occupancy="
                  f"{res['live_w']}/{res['w_cap']}")
    return res


if __name__ == "__main__":
    main()
