"""Production streaming POBP driver — the paper's Fig. 4 outer loop as a
service-grade artifact (constant memory over an unbounded mini-batch
stream, §3.2 / Table 5).

One jitted, donated-carry step (`repro.core.pobp.make_train_step`)
consumes the stream with:

  - **shape-bucketed batching**: mini-batch L snaps up to a small ladder
    of buckets (`repro.data.batching`), so an arbitrary-length corpus
    compiles the step at most once per bucket instead of once per natural
    shape; D is constant by construction.
  - **asynchronous dispatch**: no ``float()``/``int()`` host sync per
    mini-batch — convergence diagnostics stay on device and are fetched
    every ``--log-every`` batches.
  - **crash-resume**: the full state (phi_acc, m, RNG, stream cursor) is
    checkpointed through `repro.dist.checkpoint`; ``--crash-at N``
    simulates a hard failure on a FRESH run (it does not re-fire on a
    resumed one), so rerunning the same command continues from the
    latest checkpoint with a matching mean_r trajectory.  Resuming
    validates the checkpoint's seed/sync/backend against the flags.
  - **periodic held-out perplexity** every ``--eval-every`` batches,
    through ``perplexity.evaluate`` — i.e. the shared token-major
    fold-in body in `repro.core.infer`, the same program the serving
    engine runs (DESIGN.md §11).
  - execution either as the vmap N-shard simulation (``--backend sim``,
    CPU tests/benchmarks) or under ``shard_map`` on the production mesh
    (``--backend shard_map`` — the dryrun cell's per-shard body, shared
    via `make_mesh_shard_fn`, not forked).

  PYTHONPATH=src python -m repro.launch.lda_train --shards 4 --sync power \
      --minibatches 24 --ckpt-dir /tmp/lda_ck --crash-at 10
  # rerun the same command: resumes from the latest checkpoint

NB: jax is imported lazily so ``--backend shard_map`` can force the host
platform device count before first jax use (same contract as dryrun.py).
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, Optional

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    # stream
    ap.add_argument("--minibatches", type=int, default=24)
    ap.add_argument("--docs-per-batch", type=int, default=64)
    ap.add_argument("--doc-len-means", default="12,24,40",
                    help="cycled per mini-batch: a variable-length stream")
    ap.add_argument("--len-buckets", default="16,32,48",
                    help="L buckets (multiples of 8); compiles <= #buckets")
    ap.add_argument("--fixed-len", action="store_true",
                    help="pad every batch to the largest bucket "
                         "(single-compile baseline for BENCH_e2e)")
    ap.add_argument("--prefetch", type=int, default=2)
    # model
    ap.add_argument("--vocab", type=int, default=500)
    ap.add_argument("--topics", type=int, default=16)
    ap.add_argument("--lambda-w", type=float, default=0.1)
    ap.add_argument("--lambda-k", type=int, default=8)
    ap.add_argument("--inner-iters", type=int, default=12)
    ap.add_argument("--tol", type=float, default=0.05)
    ap.add_argument("--sync", default="power", choices=["power", "dense"])
    ap.add_argument("--sync-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--impl", default="jnp", choices=["jnp", "pallas"])
    # execution
    ap.add_argument("--shards", type=int, default=4,
                    help="simulated data shards (--backend sim)")
    ap.add_argument("--backend", default="sim", choices=["sim", "shard_map"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"],
                    help="production mesh for --backend shard_map")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh as 'data,model' (smoke tests), "
                         "e.g. --mesh-shape 4,2")
    # driving
    ap.add_argument("--warmup-buckets", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="pre-compile every bucket shape before the stream "
                         "starts (predictable latency: no compile hiccups "
                         "mid-stream; timed throughput is steady-state)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--eval-docs", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a hard failure after minibatch N")
    return ap


def default_args(**overrides) -> argparse.Namespace:
    """Programmatic entry: parser defaults + keyword overrides."""
    args = build_parser().parse_args([])
    for k, v in overrides.items():
        if not hasattr(args, k):
            raise TypeError(f"unknown driver arg: {k}")
        setattr(args, k, v)
    return args


def _csv_ints(s: str):
    return tuple(int(x) for x in str(s).split(",") if str(x).strip())


def _build_cfg(args):
    from repro.core.types import LDAConfig
    buckets = tuple(sorted(_csv_ints(args.len_buckets)))
    if any(b % 8 for b in buckets):
        # docs_to_padded rounds L up to a multiple of 8: an unaligned bucket
        # would warm up a shape the stream never produces and break the
        # compiles <= #buckets contract
        raise ValueError(f"--len-buckets must be multiples of 8: {buckets}")
    return LDAConfig(vocab_size=args.vocab, num_topics=args.topics,
                     lambda_w=args.lambda_w, lambda_k_abs=args.lambda_k,
                     inner_iters=args.inner_iters, residual_tol=args.tol,
                     sync_dtype=args.sync_dtype, impl=args.impl,
                     init_pad_len=buckets[-1]), buckets


def _true_phi(args):
    """One fixed ground-truth topic set shared by the whole stream
    (life-long regime: every mini-batch is drawn from the same model)."""
    return np.random.default_rng(args.seed).dirichlet(
        np.full(args.vocab, 0.06), size=args.topics).astype(np.float32)


def synthetic_stream(args, buckets, start_m: int, stacked: bool):
    """Deterministic, resumable variable-length stream factory.

    Batch m is generated purely from (seed, m), so resuming from a
    checkpoint cursor only needs `start_m` — no stream state to persist.
    Yields (MiniBatch, host_token_count); batches are [N, Dl, L] stacked
    when `stacked`, global [D, L] otherwise (shard_map shards on device).
    """
    from repro.data.batching import bucket_len, docs_to_padded, stack_shards
    from repro.data.synthetic import lda_corpus_from_phi

    phi = _true_phi(args)
    means = _csv_ints(args.doc_len_means)

    def gen():
        for m in range(start_m, args.minibatches):
            docs, stats = lda_corpus_from_phi(
                args.seed * 1_000_003 + m, args.docs_per_batch, phi,
                doc_len_mean=means[m % len(means)])
            nat = max(len(ids) for ids, _ in docs)
            L = buckets[-1] if args.fixed_len else bucket_len(nat, buckets)
            mb = docs_to_padded(docs, max_len=L)
            if stacked:
                mb = stack_shards(mb, args.shards)
            # tokens actually processed (docs_to_padded truncates docs
            # beyond the bucket); the sync runs on the prefetch thread,
            # never on the dispatch loop
            yield mb, float(mb.counts.sum())

    return gen


def _eval_split(args):
    from repro.data.batching import docs_to_padded, train_test_split_counts
    from repro.data.synthetic import lda_corpus_from_phi

    # disjoint from every stream batch seed (those stay < ~minibatches)
    docs, _ = lda_corpus_from_phi(args.seed * 1_000_003 + 987_654_321,
                                  args.eval_docs, _true_phi(args),
                                  doc_len_mean=40)
    train, test = train_test_split_counts(docs, args.seed)
    return docs_to_padded(train), docs_to_padded(test)


def _make_mesh(args):
    import jax
    if args.mesh_shape:
        dims = _csv_ints(args.mesh_shape)
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        devices = jax.devices()
        need = int(np.prod(dims))
        if len(devices) < need:
            raise RuntimeError(f"mesh {dims} needs {need} devices, found "
                               f"{len(devices)}")
        return jax.sharding.Mesh(np.asarray(devices[:need]).reshape(dims), axes)
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(args.mesh == "multi"))


def make_shardmap_train_step(cfg, mesh, sync_mode="power",
                             sync_dtype=None, donate: bool = True):
    """The driver step under shard_map on a real mesh: documents over the
    data (and pod) axes, topics over 'model'.  Same carry/diag contract as
    `core.pobp.make_train_step`; the per-shard body is the exact function
    `launch.dryrun.run_lda_cell` compiles (`make_mesh_shard_fn`)."""
    import jax
    import jax.numpy as jnp
    from repro.core.pobp import _delta_weight, shard_map_minibatch_fn
    from repro.core.types import LDATrainState

    sync_dtype = jnp.float32 if sync_dtype is None else sync_dtype
    sm, meter = shard_map_minibatch_fn(cfg, mesh, sync_mode, sync_dtype)

    def step(state, word_ids, counts):
        rng, sub = jax.random.split(state.rng)
        weight = _delta_weight(cfg, state.m + 1)
        phi, iters, mean_r = sm(word_ids, counts, state.phi_acc, sub, weight)
        new_state = LDATrainState(phi_acc=phi, m=state.m + 1, rng=rng)
        return new_state, dict(iters=iters, mean_r=mean_r, theta=None)

    return jax.jit(step, donate_argnums=(0,) if donate else ()), meter


def _state_tree(state) -> Dict[str, Any]:
    """The checkpoint payload: exactly the driver carry, with stable keys."""
    return {"state": {"phi_acc": state.phi_acc, "m": state.m,
                      "rng": state.rng}}


# every flag that shapes the per-batch trajectory: resuming under ANY other
# value silently breaks the matching-mean_r guarantee, so all are saved in
# the checkpoint and validated on restore.  (minibatches / logging /
# checkpoint cadence / warmup / crash-at only affect when the run stops.)
_RESUME_KEYS = ("seed", "sync", "backend", "shards", "vocab", "topics",
                "lambda_w", "lambda_k", "inner_iters", "tol", "sync_dtype",
                "impl", "docs_per_batch", "doc_len_means", "len_buckets",
                "fixed_len")


def _run_signature(args) -> Dict[str, Any]:
    return {k: getattr(args, k) for k in _RESUME_KEYS}


def _compiles(step_fn) -> int:
    """Compile count via the jitted function's cache (private jax API; -1
    when absent — BENCH_e2e asserts positivity so a break is loud)."""
    try:
        return int(step_fn._cache_size())
    except AttributeError:
        return -1


class _CompileClock:
    """Total jax compile seconds, via a process-wide jax.monitoring listener
    (registered once; train_loop reads before/after snapshots)."""

    def __init__(self):
        self.total = 0.0
        self._registered = False

    def ensure_registered(self):
        if self._registered:
            return
        import jax

        def _on_duration(name, dur, **kw):
            if name.startswith("/jax/core/compile/"):
                self.total += dur

        jax.monitoring.register_event_duration_secs_listener(_on_duration)
        self._registered = True


_COMPILE_CLOCK = _CompileClock()


def train_loop(args, on_batch=None) -> Dict[str, Any]:
    """Run the streaming driver; returns a result dict (see bottom).

    `on_batch(step_no, state, diag)` is an optional per-batch hook (the
    example uses it for RSS tracking); `diag` values are device scalars —
    converting them forces a sync, so hooks should do that sparingly.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import perplexity
    from repro.core.pobp import DiagBuffer, init_train_state, make_train_step
    from repro.core.types import LDATrainState
    from repro.data.batching import prefetched
    from repro.dist import checkpoint as ckpt

    cfg, buckets = _build_cfg(args)
    sync_dtype = jnp.bfloat16 if args.sync_dtype == "bfloat16" else jnp.float32

    if args.crash_at and not args.ckpt_dir:
        raise ValueError("--crash-at needs --ckpt-dir: without a checkpoint "
                         "the rerun restarts from scratch and hits the same "
                         "simulated failure forever")
    if args.crash_at and args.ckpt_dir and args.crash_at <= args.ckpt_every:
        print(f"[warn] --crash-at {args.crash_at} fires before the first "
              f"checkpoint (--ckpt-every {args.ckpt_every}); the rerun will "
              f"restart from scratch and crash again", flush=True)

    state = init_train_state(cfg, args.seed)
    start_m = 0
    if args.ckpt_dir:
        try:
            got = ckpt.restore_latest(args.ckpt_dir, _state_tree(state))
        except ValueError as e:
            raise ValueError(
                f"cannot restore checkpoint from {args.ckpt_dir} ({e}); it "
                f"was probably written by an older/other tool — use a fresh "
                f"--ckpt-dir") from e
        if got is not None:
            trees, extra, ck_step = got
            want = _run_signature(args)
            for key, saved in extra.get("run", {}).items():
                if key in want and saved != want[key]:
                    raise ValueError(
                        f"checkpoint in {args.ckpt_dir} was written with "
                        f"{key}={saved!r} but this run has "
                        f"{key}={want[key]!r}; rerun with matching flags "
                        f"or a fresh --ckpt-dir")
            state = LDATrainState(**trees["state"])
            start_m = int(extra["next_m"])
            print(f"[restore] resumed from checkpoint step {ck_step} -> "
                  f"next minibatch {start_m + 1}", flush=True)
            if start_m >= args.minibatches:
                print(f"[restore] checkpoint already covers all "
                      f"{args.minibatches} minibatches — nothing to train "
                      f"(raise --minibatches or use a fresh --ckpt-dir)",
                      flush=True)

    if args.backend == "sim":
        step_fn, meter = make_train_step(cfg, args.shards, args.sync,
                                         sync_dtype)
    else:
        mesh = _make_mesh(args)
        step_fn, meter = make_shardmap_train_step(cfg, mesh, args.sync,
                                                  sync_dtype)

    stream = prefetched(
        synthetic_stream(args, buckets, start_m, stacked=(args.backend == "sim")),
        args.prefetch)

    _COMPILE_CLOCK.ensure_registered()
    warmup_s = 0.0
    if args.warmup_buckets:
        # AOT warmup: push an all-padding batch of every bucket shape
        # through the step on a throwaway state, so the stream never stalls
        # on a mid-run compile (startup cost, not steady-state cost).
        t0 = time.time()
        scratch = init_train_state(cfg, args.seed)
        for L in (buckets[-1:] if args.fixed_len else buckets):
            if args.backend == "sim" and args.shards > 1:
                shape = (args.shards, args.docs_per_batch // args.shards, L)
            else:
                shape = (args.docs_per_batch, L)
            scratch, _ = step_fn(scratch, jnp.zeros(shape, jnp.int32),
                                 jnp.zeros(shape, jnp.float32))
        jax.block_until_ready(scratch.phi_acc)
        warmup_s = time.time() - t0

    # per-batch diagnostics: device scalars buffered and flushed to host
    # values in blocks (DiagBuffer), so the stream stays async while live
    # device buffers stay bounded on an unbounded stream (§3.2).
    buf = DiagBuffer(block=max(args.log_every, 64))
    ppl_trace = []
    eval_split = None

    def heldout():
        nonlocal eval_split
        if eval_split is None:  # built once, reused by every eval
            eval_split = _eval_split(args)
        return eval_split

    tokens = 0.0
    eval_compile_s = 0.0
    compile_s0 = _COMPILE_CLOCK.total
    t0 = time.time()
    for m, (batch, ntok) in enumerate(stream, start=start_m):
        state, diag = step_fn(state, batch.word_ids, batch.counts)
        buf.append(diag["mean_r"], diag["iters"])
        tokens += ntok
        step_no = m + 1
        if args.log_every and step_no % args.log_every == 0:
            # the ONLY recurring host sync, amortized over --log-every batches
            dt = time.time() - t0
            print(f"minibatch {step_no:5d}  mean_r={float(diag['mean_r']):.4f}"
                  f"  iters={int(diag['iters']):3d}"
                  f"  tokens/s={tokens / max(dt, 1e-9):,.0f}"
                  f"  compiles={_compiles(step_fn)}", flush=True)
        if args.eval_every and step_no % args.eval_every == 0:
            c_eval = _COMPILE_CLOCK.total
            tr_b, te_b = heldout()
            ppl = perplexity.evaluate(jax.random.PRNGKey(args.seed + 1),
                                      state.phi_acc, tr_b, te_b, cfg)
            eval_compile_s += _COMPILE_CLOCK.total - c_eval
            ppl_trace.append((step_no, float(ppl)))
            print(f"minibatch {step_no:5d}  held-out ppl={ppl:.2f}", flush=True)
        if on_batch is not None:
            on_batch(step_no, state, diag)
        if args.crash_at and step_no == args.crash_at and start_m == 0:
            # fresh runs only: a resumed run sails past the simulated
            # failure, so "rerun the same command" terminates
            raise SystemExit(f"[simulated crash] after minibatch {step_no}")
        if args.ckpt_dir and args.ckpt_every and \
                step_no % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step_no, _state_tree(state),
                      extra={"next_m": step_no,
                             "run": _run_signature(args)})

    jax.block_until_ready(state.phi_acc)
    wall = time.time() - t0
    # step-function compiles only: eval jits are accounted separately
    compile_s = _COMPILE_CLOCK.total - compile_s0 - eval_compile_s

    tr_b, te_b = heldout()
    ppl = float(perplexity.evaluate(jax.random.PRNGKey(args.seed + 1),
                                    state.phi_acc, tr_b, te_b, cfg))
    rows = buf.rows()
    mean_r = [float(r) for r, _ in rows]
    iters = [int(i) for _, i in rows]
    return {
        "first_m": start_m,
        "mean_r": mean_r,
        "iters": iters,
        "compiles": _compiles(step_fn),
        "len_buckets": list(buckets),
        "tokens": tokens,
        "wall_s": wall,
        "warmup_s": warmup_s,
        "compile_s": compile_s,
        "tokens_per_s": tokens / max(wall, 1e-9),
        "ppl": ppl,
        "ppl_trace": ppl_trace,
        "bytes_by_phase": dict(meter.bytes_by_phase),
        "per_minibatch_bytes": (meter.per_minibatch_bytes(iters[-1])
                                if iters else 0),
        "phi_acc": np.asarray(state.phi_acc),
    }


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.backend == "shard_map" and "XLA_FLAGS" not in os.environ:
        # must happen before first jax import (same contract as dryrun.py)
        n = 512 if not args.mesh_shape else int(np.prod(_csv_ints(args.mesh_shape)))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n}")
    res = train_loop(args)
    done = args.minibatches - res["first_m"]
    print(f"[done] {done} minibatches  final mean_r="
          f"{res['mean_r'][-1] if res['mean_r'] else float('nan'):.4f}  "
          f"held-out ppl={res['ppl']:.2f}")
    print(f"[perf] tokens/s={res['tokens_per_s']:,.0f}  "
          f"compiles={res['compiles']} (buckets={res['len_buckets']})  "
          f"warmup={res['warmup_s']:.1f}s  wall={res['wall_s']:.1f}s "
          f"(+{res['compile_s']:.1f}s in-stream compile)")
    print(f"[comm] per-minibatch bytes={res['per_minibatch_bytes']:,} "
          f"(phases: {res['bytes_by_phase']})")
    return res


if __name__ == "__main__":
    main()
