"""Training driver: LM training with data-parallel gradient sync via
PowerSync (the paper's technique generalized) or dense all-reduce, plus
checkpoint/restart fault tolerance.

CPU-runnable end-to-end (reduced configs, simulated DP shards through
vmap(axis_name=...) — identical collective semantics to a real mesh); the
production-mesh path reuses the same step through shard_map on TPU pods.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --batch 16 --seq 64 --shards 4 --sync power \
      --ckpt-dir /tmp/ckpt

Fault tolerance: --crash-at N simulates a hard failure; rerunning the same
command restores the latest checkpoint (params, optimizer, PowerSync
residuals, RNG, data cursor) and converges to the same trajectory.
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sync import CommMeter, LocalReducer, MeshReducer
from repro.data.lm_data import batch_at
from repro.dist import checkpoint as ckpt
from repro.models import registry
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.powersync import (PowerSyncConfig, dense_sync_tree,
                                   powersync_tree, residual_init)


def build_trainer(cfg, acfg: AdamWConfig, pscfg: PowerSyncConfig,
                  shards: int, sync: str):
    mod = registry.build(cfg)
    meter = CommMeter()
    reducer = (MeshReducer("dp", meter=meter) if shards > 1
               else LocalReducer(meter=meter))

    def step_one(params, opt, residual, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.loss_fn(p, batch, cfg))(params)
        if sync == "power":
            synced, new_res = powersync_tree(grads, residual, reducer, pscfg,
                                             max(shards, 1))
        else:
            synced = dense_sync_tree(grads, reducer, max(shards, 1))
            new_res = residual
        new_params, new_opt = adamw_update(synced, opt, acfg)
        return loss, new_params, new_opt, new_res

    if shards > 1:
        stepped = jax.vmap(step_one, in_axes=(None, None, 0, 0),
                           axis_name="dp")
    else:
        stepped = step_one
    return jax.jit(stepped), meter, mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--sync", default="power", choices=["power", "dense"])
    ap.add_argument("--lambda-rows", type=float, default=0.2)
    ap.add_argument("--lambda-cols", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    acfg = AdamWConfig(lr=args.lr, warmup_steps=20)
    pscfg = PowerSyncConfig(lambda_rows=args.lambda_rows,
                            lambda_cols=args.lambda_cols)
    step_fn, meter, mod = build_trainer(cfg, acfg, pscfg, args.shards,
                                        args.sync)

    params = mod.init(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params)
    residual = residual_init(params)
    if args.shards > 1:
        residual = jax.tree.map(
            lambda r: jnp.broadcast_to(r, (args.shards, *r.shape)), residual)
    start = 0

    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            trees, extra, _ = ckpt.restore(
                args.ckpt_dir, latest,
                {"params": params, "opt": opt, "residual": residual})
            params, opt, residual = (trees["params"], trees["opt"],
                                     trees["residual"])
            start = extra["next_step"]
            print(f"[restore] resumed from step {latest} -> next {start}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = batch_at(args.seed, step, args.batch, args.seq,
                         cfg.vocab_size,
                         shards=args.shards if args.shards > 1 else 0)
        loss, p_new, o_new, residual = step_fn(params, opt, residual, batch)
        params = jax.tree.map(lambda x: x[0], p_new) if args.shards > 1 else p_new
        opt = jax.tree.map(lambda x: x[0], o_new) if args.shards > 1 else o_new
        losses.append(float(np.mean(np.asarray(loss))))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if args.crash_at and step + 1 == args.crash_at:
            raise SystemExit(f"[simulated crash] at step {step + 1}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": opt, "residual": residual},
                      extra={"next_step": step + 1, "seed": args.seed,
                             "sync": args.sync})
    print(f"[done] final loss {losses[-1]:.4f}; "
          f"comm bytes/step by phase: {meter.bytes_by_phase}")
    return losses, meter


if __name__ == "__main__":
    main()
