"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use;
tests and benchmarks must keep seeing 1 CPU device).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax (launch/dryrun.py does).")
    # more devices than needed (the 512-device dry-run building the 256-chip
    # single-pod mesh): use a prefix slice.
    return jax.sharding.Mesh(
        np.asarray(devices[:need]).reshape(shape), axes)


def mesh_chip_count(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
