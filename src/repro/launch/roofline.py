"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (per device, per step):

  compute    = HLO_FLOPs / peak_FLOP/s          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes_accessed / HBM_bw      (819 GB/s)
  collective = ICI_bytes_moved / link_bw        (~50 GB/s/link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the per-device
SPMD program).  ICI bytes are parsed from ``compiled.as_text()``: for each
collective op we extract the payload shape and the replica-group size G and
apply the standard ring-algorithm factors:

  all-reduce        2 * bytes * (G-1)/G
  all-gather        out_bytes * (G-1)/G
  reduce-scatter    out_bytes * (G-1)        (input = G * output)
  all-to-all        bytes * (G-1)/G
  collective-permute  bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

HW = {
    "peak_flops": 197e12,     # bf16 per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "ici_bw": 50e9,           # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    base = re.match(r"[a-z]+\d*", dtype).group(0)
    return n * _DTYPE_BYTES.get(base, 4)


def _group_size(line: str) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI bytes moved, bucketed by collective type."""
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        parts = stripped.split(" = ", 1)
        if len(parts) != 2:
            continue
        rhs = parts[1]
        op = None
        for c in _COLLECTIVES:
            # the op invocation appears as "<shapes> <op>(" (tuple-shaped
            # outputs start with "(f32[...], ...)", so search the full rhs)
            m = re.search(rf"\b{c}(-start)?\(", rhs)
            if m is not None and f"{c}-done" not in rhs:
                op = c
                seg = rhs[: m.start()]
                break
        if op is None:
            continue
        shapes = _SHAPE_RE.findall(seg)
        payload = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if payload == 0:
            continue
        G = _group_size(stripped)
        if op == "all-reduce":
            moved = 2.0 * payload * (G - 1) / G
        elif op == "all-gather":
            moved = payload * (G - 1) / G
        elif op == "reduce-scatter":
            moved = payload * (G - 1)
        elif op == "all-to-all":
            moved = payload * (G - 1) / G
        else:
            moved = float(payload)
        out[op] += moved
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def collective_bytes_split(hlo_text: str):
    """(loop_body_bytes, one_time_bytes) — attributes collectives to while
    bodies vs straight-line code.  For POBP this separates the per-iteration
    power sync (Eq. 6) from the once-per-mini-batch dense sync (Fig. 4
    lines 9-10)."""
    bodies = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    cur = None
    per_comp: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if not line.startswith(" "):  # computation header
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = m.group(1)
            continue
        sub = collective_bytes(line)
        if sub["total"]:
            per_comp[cur] = per_comp.get(cur or "?", 0.0) + sub["total"]
    loop = sum(v for k, v in per_comp.items() if k in bodies)
    once = sum(per_comp.values()) - loop
    return loop, once, per_comp


def flops_and_bytes(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = ca or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_info(compiled) -> Dict[str, Optional[float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"available": False}
    if ma is None:
        return {"available": False}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out: Dict[str, Optional[float]] = {"available": True}
    for k in keys:
        out[k] = float(getattr(ma, k, 0) or 0)
    out["live_bytes"] = (out.get("argument_size_in_bytes", 0)
                         + out.get("output_size_in_bytes", 0)
                         + out.get("temp_size_in_bytes", 0)
                         - out.get("alias_size_in_bytes", 0))
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: the dominant term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def fraction_of_roofline(self) -> float:
        """compute_s / step_s — how close the step is to compute-bound."""
        return self.compute_s / max(self.step_s, 1e-30)


def roofline_terms(flops: float, hbm_bytes: float, ici_bytes: float) -> Roofline:
    return Roofline(compute_s=flops / HW["peak_flops"],
                    memory_s=hbm_bytes / HW["hbm_bw"],
                    collective_s=ici_bytes / HW["ici_bw"])


def model_flops(cfg, shape, n_params_active: float, chips: int) -> float:
    """Analytic useful FLOPs per device per step: 6ND train, 2ND inference."""
    if shape.kind == "train":
        tok = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tok / chips
    if shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tok / chips
    return 2.0 * n_params_active * shape.global_batch / chips
