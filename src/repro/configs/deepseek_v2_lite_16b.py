"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512, 64 routed experts top-6 + 2 shared,
first layer dense (d_ff=10944) [arXiv:2405.04434; hf].

NOTE (DESIGN.md §6): the assignment line also says "160 routed"; that figure
belongs to DeepSeek-V2 (full, 236B).  The inline spec "MoE 64e top-6" matches
the lite-16B model reproduced here.
"""

from repro.configs.base import ArchConfig, MLASpec, MoESpec

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    vocab_size=102400,
    d_model=2048,
    n_layers=27,
    n_heads=16,
    n_kv_heads=16,            # MLA: all heads share the compressed KV
    d_ff=10944,               # the dense first layer's FFN width
    head_dim=128,
    rope_theta=10000.0,
    attn_type="mla",
    norm="rms",
    act="silu",
    mla=MLASpec(kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
    moe=MoESpec(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                capacity_factor=1.25),
    dense_first_n=1,
)
