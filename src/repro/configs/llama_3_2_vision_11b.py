"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer; vision frontend is a
STUB (input_specs supplies precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    vocab_size=128256,
    d_model=4096,
    n_layers=40,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    head_dim=128,
    rope_theta=500000.0,
    attn_type="gqa",
    norm="rms",
    act="silu",
    cross_attn_every=5,
    frontend_tokens=1601,     # 1 CLS + 40x40 patches, one tile (stub)
)
