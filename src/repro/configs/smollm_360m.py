"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-360M; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    vocab_size=49152,
    d_model=960,
    n_layers=32,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    head_dim=64,
    rope_theta=10000.0,
    tie_embeddings=True,
    attn_type="gqa",
    norm="rms",
    act="silu",
    remat_policy="dots",   # fits (8.4 GB live) and cuts all terms 15-20%
)
