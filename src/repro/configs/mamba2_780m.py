"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

d_inner = 2*1536 = 3072, headdim=64 -> 48 SSD heads; no attention, no MLP
(Mamba2 blocks only) — `long_500k` runs on this arch (O(1)-state decode).
"""

from repro.configs.base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    vocab_size=50280,
    d_model=1536,
    n_layers=48,
    n_heads=48,               # informational: SSD heads = d_inner/headdim
    n_kv_heads=48,
    d_ff=0,                   # attn-free, MLP-free family
    tie_embeddings=True,
    norm="rms",
    ssm=SSMSpec(state=128, headdim=64, conv_width=4, expand=2, chunk=128),
)
