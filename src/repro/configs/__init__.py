"""Architecture registry: ``--arch <id>`` resolution + input specs.

`input_specs(arch, shape, smoke=False)` builds the exact jit arguments for
each (architecture x shape) cell — ShapeDtypeStruct stand-ins for the
dry-run (no allocation) or materialized arrays for smoke tests.
"""

from __future__ import annotations

import importlib
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES, SMOKE_SHAPES,
                                MoESpec, MLASpec, SSMSpec)

ARCH_IDS = (
    "granite-3-2b",
    "mistral-large-123b",
    "qwen2-72b",
    "smollm-360m",
    "llama-3.2-vision-11b",
    "mamba2-780m",
    "deepseek-v2-lite-16b",
    "olmoe-1b-7b",
    "zamba2-2.7b",
    "seamless-m4t-medium",
)

# cells skipped per the assignment rule: long_500k only for sub-quadratic
# families (SSM / hybrid); all others are full attention (DESIGN.md §6).
LONG_CONTEXT_ARCHS = ("mamba2-780m", "zamba2-2.7b")


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def cell_supported(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                make=jax.ShapeDtypeStruct) -> Dict[str, Any]:
    """Model inputs for one cell; `make(shape, dtype)` builds each leaf.

    train  -> {tokens, labels [, image_embeds | frames]}
    prefill-> {tokens [, image_embeds | frames]}
    decode -> {token [B,1], pos scalar} (+ cache specs, built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        d: Dict[str, Any] = {"tokens": make((B, S), tok),
                             "labels": make((B, S), tok)}
        if cfg.family == "vlm":
            d["image_embeds"] = make((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.bfloat16)
        if cfg.family == "audio":
            d["frames"] = make((B, S, cfg.d_model), jnp.bfloat16)
        return d
    if shape.kind == "prefill":
        d = {"tokens": make((B, S), tok)}
        if cfg.family == "vlm":
            d["image_embeds"] = make((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.bfloat16)
        if cfg.family == "audio":
            d["frames"] = make((B, S, cfg.d_model), jnp.bfloat16)
        return d
    # decode: one new token against a cache of length S
    return {"token": make((B, 1), tok), "pos": make((), jnp.int32)}
