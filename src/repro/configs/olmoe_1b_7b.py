"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024
vocab=50304, 64 experts top-8 [arXiv:2409.02060; hf]."""

from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    vocab_size=50304,
    d_model=2048,
    n_layers=16,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    head_dim=128,
    rope_theta=10000.0,
    attn_type="gqa",
    norm="rms",
    act="silu",
    moe=MoESpec(num_experts=64, top_k=8, d_expert=1024, num_shared=0,
                capacity_factor=1.25),
)
