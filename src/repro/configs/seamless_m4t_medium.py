"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].

The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings [B, S, d_model]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    vocab_size=256206,
    d_model=1024,
    n_layers=12,              # decoder depth
    enc_layers=12,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    head_dim=64,
    norm="ln",
    act="gelu",
)
