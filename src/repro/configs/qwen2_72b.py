"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    vocab_size=152064,
    d_model=8192,
    n_layers=80,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    head_dim=128,
    rope_theta=1000000.0,
    qkv_bias=True,
    attn_type="gqa",
    norm="rms",
    act="silu",
)
