"""Architecture + shape configuration schema (the `--arch` / `--shape` axes).

One frozen dataclass tree per architecture lives in src/repro/configs/<id>.py;
`reduced()` derives the CPU smoke-test variant of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    num_shared: int = 0            # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25
    # combine path: 'gather' (baseline — GSPMD all-gathers the [B,E,C,D]
    # buffer over the EP axis) or 'scatter' (slots scatter-add into token
    # order -> partial sums + all-reduce of [T,D]: k*cf/2 x fewer bytes).
    # §Perf iteration for the MoE cells; see EXPERIMENTS.md.
    combine: str = "gather"


@dataclasses.dataclass(frozen=True)
class MLASpec:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    state: int = 128               # N, the SSM state size
    headdim: int = 64              # P, channels per SSD head
    conv_width: int = 4
    expand: int = 2                # d_inner = expand * d_model
    chunk: int = 128               # SSD chunk length
    ngroups: int = 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    attn_type: str = "gqa"         # gqa | mla
    norm: str = "rms"              # rms | ln
    act: str = "silu"              # silu | gelu
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None
    dense_first_n: int = 0         # first N layers use dense FFN (DeepSeek)
    cross_attn_every: int = 0      # VLM: a cross-attn layer every N layers
    frontend_tokens: int = 1601    # VLM/audio stub: embeddings supplied per item
    shared_attn_every: int = 0     # Zamba2: shared attn block every N SSM layers
    sliding_window: int = 0        # 0 = full attention
    enc_layers: int = 0            # audio enc-dec: encoder depth (dec = n_layers)
    scan_layers: bool = True       # False: unroll stacks (cost-analysis probes —
                                   # XLA while-body costs are counted once)
    attn_chunk: int = 512          # q-block size for chunked attention
    remat_policy: str = "full"     # 'full' (save nothing) | 'dots' (save
                                   # matmul outputs: no fwd recompute in bwd,
                                   # -15-20% on all three roofline terms, but
                                   # +10-40 GB live on >=2B archs -> only the
                                   # sub-1B configs enable it; EXPERIMENTS §Perf)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/lm_head vocab
        dim divides every mesh axis (jit argument shardings require exact
        divisibility).  Padded logit rows are masked to -inf in the head —
        the output distribution over the true vocab is exact."""
        return -(-self.vocab_size // 256) * 256

    def reduced(self) -> "ArchConfig":
        """Same family, CPU-smoke scale (layers/width/vocab/experts shrunk)."""
        small = dataclasses.replace(
            self,
            vocab_size=min(self.vocab_size, 512),
            d_model=128,
            n_layers=min(self.n_layers, 4) if not self.shared_attn_every
            else 2 * self.shared_attn_every,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else 4,
            d_ff=256 if self.d_ff else 0,
            head_dim=32 if self.head_dim else 0,
            frontend_tokens=16,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            dense_first_n=min(self.dense_first_n, 1),
            cross_attn_every=min(self.cross_attn_every, 2) if self.cross_attn_every else 0,
        )
        if self.moe:
            small = dataclasses.replace(
                small, moe=dataclasses.replace(self.moe, num_experts=8,
                                               top_k=min(self.moe.top_k, 2),
                                               d_expert=64))
        if self.mla:
            small = dataclasses.replace(
                small, mla=MLASpec(kv_lora=64, qk_nope=32, qk_rope=16, v_head=32))
        if self.ssm:
            small = dataclasses.replace(
                small, ssm=dataclasses.replace(self.ssm, state=16, headdim=16,
                                               chunk=16))
        return small


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# the assignment's four LM shapes
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# smoke-scale versions of the same four kinds
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}
