"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    vocab_size=49155,
    d_model=2048,
    n_layers=40,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    head_dim=64,
    rope_theta=10000.0,
    tie_embeddings=True,
    attn_type="gqa",
    norm="rms",
    act="silu",
)
