"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention block every
6 layers (weights reused, fed concat(hidden, embed0), Zamba2-style)
[arXiv:2411.15242; hf].

At the long_500k shape the shared attention runs a 4096-token sliding
window so the hybrid stays sub-quadratic (the Mamba2 backbone is the
long-range path) — DESIGN.md §6.
"""

from repro.configs.base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    vocab_size=32000,
    d_model=2560,
    n_layers=54,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    head_dim=80,
    rope_theta=10000.0,
    norm="rms",
    act="silu",
    ssm=SSMSpec(state=64, headdim=64, conv_width=4, expand=2, chunk=128),
    shared_attn_every=6,
    sliding_window=4096,
)
