"""Per-tenant theta LRU cache for repeat documents (DESIGN.md §16).

Serving workloads are heavy-tailed in *content*: the same document (a hot
article, a template, a retried request) arrives again and again, often from
the same tenant.  Fixed-phi fold-in is a pure function of
(document, phi generation), so its result is perfectly cacheable:

  - keys are ``(tenant, content digest)`` where the digest hashes the raw
    (word_ids, counts) payload BEFORE vocab translation — two requests
    with identical content collide whatever rows the current vocabulary
    maps them to;
  - every entry is stamped with the ``phi_version`` that produced it; a
    lookup under any other version MISSES (and evicts the stale entry), so
    a phi hot-swap invalidates the whole cache at zero cost — no stale
    theta is ever served across a model refresh;
  - eviction is LRU over a bounded entry count, shared across tenants
    (a tenant's working set competes like any other — per-tenant quotas
    would go here).

Two consumption modes (the engine's ``cache_mode``):
  ``serve``: a hit skips fold-in entirely — the cached theta is returned
             with zero device work and ~zero latency;
  ``warm``:  a hit still folds in, but the slot's messages initialize from
             the cached theta instead of the random field, so the residual
             bound clears in fewer sweeps (measured in ``stats()``).
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Hashable, Optional, Tuple

import numpy as np

Key = Tuple[Hashable, str]


def doc_digest(ids, counts) -> str:
    """Content hash of one (word_ids, counts) document payload."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(np.asarray(ids, np.int64)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(counts, np.float32)).tobytes())
    return h.hexdigest()


class ThetaCache:
    """Bounded LRU of ``(tenant, digest) -> (phi_version, theta)``."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1: {capacity}")
        self.capacity = int(capacity)
        self._d: "OrderedDict[Key, Tuple[int, np.ndarray]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.stale = 0          # lookups that found an older-phi entry

    def __len__(self) -> int:
        return len(self._d)

    def get(self, tenant: Hashable, digest: str, phi_version: int
            ) -> Optional[np.ndarray]:
        """The cached theta for this content under THIS phi generation,
        or None.  A version mismatch is a miss and evicts the dead entry
        (it can never hit again — versions only move forward)."""
        key = (tenant, digest)
        ent = self._d.get(key)
        if ent is None:
            self.misses += 1
            return None
        ver, theta = ent
        if ver != phi_version:
            del self._d[key]
            self.stale += 1
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return theta

    def put(self, tenant: Hashable, digest: str, phi_version: int,
            theta: np.ndarray) -> None:
        key = (tenant, digest)
        self._d[key] = (int(phi_version), np.asarray(theta))
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def purge(self) -> None:
        """Drop every entry (an explicit swap-time invalidation; version
        stamping already guarantees stale entries never serve, purging
        just reclaims the memory eagerly)."""
        self._d.clear()

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"entries": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "stale_evictions": self.stale,
                "hit_rate": self.hits / total if total else 0.0}
