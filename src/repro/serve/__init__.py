"""Production serving layer for trained LDA models (DESIGN.md §11).

`engine.FoldInEngine` wraps the shared fixed-phi inference body
(`core.infer.fold_in_tokens`) in a request queue with shape-bucketed
admission, AOT-warmed jitted fold-in steps, asynchronous dispatch and
per-request latency / communication-byte accounting.
"""

from repro.serve.engine import FoldInEngine, ServeResult  # noqa: F401
