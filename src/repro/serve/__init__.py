"""Production serving layer for trained LDA models (DESIGN.md §11, §16).

`engine.SlabEngine` is the continuous-batching runtime (§16): a fixed
in-flight slab with mid-flight admission, per-tenant theta caching and an
OOV retraining trigger.  `engine.FoldInEngine` is the bucket-ladder
baseline (§11): shape-bucketed admission with AOT-warmed jitted fold-in
steps.  Both wrap the shared fixed-phi inference bodies in `core.infer`
with asynchronous dispatch and per-request latency / communication-byte
accounting.
"""

from repro.serve.cache import ThetaCache, doc_digest  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    FoldInEngine,
    OOVTrigger,
    ServeResult,
    Shed,
    SlabEngine,
)
