"""Fold-in serving engine: the paper's train-once / fold-in-forever
deployment (Eq. 20 protocol) as a production request loop (DESIGN.md §11).

Architecture — every piece reuses the training stack, none forks it:

  - **one inference body**: the jitted step is
    `core.infer.make_fold_in_step` — the exact program `perplexity.evaluate`
    and the streaming driver's held-out hook compile;
  - **shape-bucketed admission**: requests queue per length bucket
    (`data/batching.bucket_len` on the same ladder the training driver
    uses); a bucket dispatches when `batch_docs` requests accumulate (or on
    `flush`, padded with empty documents so D never varies).  The step
    therefore compiles at most ``len(len_buckets)`` times, all of them at
    construction (AOT warmup) — a serving process never stalls a request
    on a compile;
  - **asynchronous dispatch**: `submit` never blocks on device work;
    dispatched batches park as device futures (theta + diagnostics stay
    device-resident) and are materialized in `drain`, where per-request
    latency is measured at the moment the batch's result is actually ready;
  - **accounting**: the `CommMeter` threaded through the fold-in reducers
    bills the per-iteration renormalization/residual psums of a
    topic-sharded phi, so `stats()` reports bytes-per-request next to
    p50/p99 latency and docs/s.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import infer, perplexity
from repro.core.types import LDAConfig
from repro.data.batching import bucket_len, docs_to_padded

_EMPTY_DOC = (np.zeros(1, np.int32), np.zeros(1, np.float32))


@dataclasses.dataclass
class ServeResult:
    """One served request: the topic mixture plus serving diagnostics."""

    req_id: int
    theta: np.ndarray              # [K] normalized topic mixture
    latency_s: float               # submit -> batch result ready
    bucket: int                    # L bucket that admitted the request
    iters: int                     # fold-in sweeps the batch ran
    mean_r: float                  # batch residual at exit


@dataclasses.dataclass
class _Dispatch:
    bucket: int
    reqs: List[Tuple[int, float]]           # (req_id, t_submit) real docs only
    theta: jnp.ndarray                      # device future [D, K]
    iters: jnp.ndarray                      # device scalar
    mean_r: jnp.ndarray                     # device scalar


class FoldInEngine:
    """Serve topic mixtures for incoming documents with phi fixed.

    `phi_acc` is the trained sufficient statistic ([W, K], as checkpointed
    by the streaming driver); pass ``normalized=True`` when handing an
    already-normalized topic-word matrix.  ``topic_shards > 1`` serves a
    topic-sharded phi ([N, W, K/N] internally) with psum'd renormalization
    under the vmap simulation — bit-identical collectives to a model-axis
    mesh, metered per batch.
    """

    def __init__(self, phi_acc, cfg: LDAConfig, *,
                 len_buckets: Sequence[int] = (16, 32, 64, 128),
                 batch_docs: int = 32, fold_iters: int = 30,
                 residual_tol: float = 1e-2, topic_shards: int = 1,
                 sync_dtype=None, normalized: bool = False,
                 impl: Optional[str] = None, seed: int = 0,
                 warmup: bool = True):
        self.len_buckets = tuple(sorted(int(b) for b in len_buckets))
        if any(b % 8 for b in self.len_buckets):
            raise ValueError(f"len_buckets must be multiples of 8 "
                             f"(docs_to_padded pads L to 8): "
                             f"{self.len_buckets}")
        # the driver's L-invariant init contract carries over to serving:
        # the random field is drawn at the largest bucket and sliced, so a
        # document's theta does not depend on which bucket admitted it
        self.cfg = cfg = dataclasses.replace(
            cfg, init_pad_len=max(self.len_buckets[-1],
                                  cfg.init_pad_len or 0))
        if sync_dtype is None:
            sync_dtype = (jnp.bfloat16 if cfg.sync_dtype == "bfloat16"
                          else jnp.float32)
        self.batch_docs = int(batch_docs)
        self.fold_iters = int(fold_iters)
        self.residual_tol = float(residual_tol)
        phi_norm = (jnp.asarray(phi_acc) if normalized
                    else perplexity.normalize_phi(jnp.asarray(phi_acc),
                                                  cfg.beta))
        self._phi = infer.split_topic_shards(phi_norm, topic_shards)
        self._step, self.meter = infer.make_fold_in_step(
            cfg, fold_iters=self.fold_iters, residual_tol=self.residual_tol,
            topic_shards=topic_shards, sync_dtype=sync_dtype, impl=impl)
        self._key = jax.random.PRNGKey(seed)
        self._queues: Dict[int, List[Tuple[int, tuple, float]]] = {
            b: [] for b in self.len_buckets}
        self._pending: List[_Dispatch] = []
        self._next_id = 0
        self._dispatches = 0
        self._iters_sum = 0
        self._latencies: List[float] = []
        self._served = 0
        self._t_first: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self.warmup_s = 0.0
        if warmup:
            self._warmup()

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg: Optional[LDAConfig] = None,
                        step: Optional[int] = None, sharding=None,
                        **kw) -> "FoldInEngine":
        """Checkpoint-to-serve: load phi (and, when `cfg` is omitted, the
        model geometry from the driver's saved run signature) and build an
        engine — no training carry ever touches the serving process."""
        from repro.dist import checkpoint as ckpt

        phi_acc, extra, _ = ckpt.restore_phi(ckpt_dir, step=step,
                                             sharding=sharding)
        if cfg is None:
            run = extra.get("run", {})
            if "vocab" not in run or "topics" not in run:
                raise ValueError(
                    f"checkpoint extra carries no run signature "
                    f"({sorted(run)}); pass cfg= explicitly")
            # carry every saved knob the fold-in body reads: impl routes
            # the jnp vs Pallas path, sync_dtype the reducer payload width
            cfg = LDAConfig(vocab_size=int(run["vocab"]),
                            num_topics=int(run["topics"]),
                            impl=str(run.get("impl", "jnp")),
                            sync_dtype=str(run.get("sync_dtype",
                                                   "float32")))
        return cls(phi_acc, cfg, **kw)

    # ---------------------------------------------------------- admission

    def submit(self, doc: Tuple[np.ndarray, np.ndarray],
               req_id: Optional[int] = None) -> int:
        """Enqueue one document (word_ids, counts); never blocks on device
        work.  Returns the request id its `ServeResult` will carry."""
        if req_id is None:
            req_id = self._next_id
        self._next_id = max(self._next_id, req_id) + 1
        now = time.time()
        if self._t_first is None:
            self._t_first = now
        b = bucket_len(len(doc[0]), self.len_buckets)
        q = self._queues[b]
        q.append((req_id, doc, now))
        if len(q) >= self.batch_docs:
            self._dispatch(b)
        return req_id

    def flush(self) -> None:
        """Dispatch every partially-filled bucket (padded with empty docs,
        so D — and therefore the compiled shapes — never varies)."""
        for b in self.len_buckets:
            while self._queues[b]:
                self._dispatch(b)

    def _dispatch(self, bucket: int) -> None:
        q = self._queues[bucket]
        take, self._queues[bucket] = q[:self.batch_docs], q[self.batch_docs:]
        docs = [doc for _, doc, _ in take]
        docs += [_EMPTY_DOC] * (self.batch_docs - len(docs))
        mb = docs_to_padded(docs, max_len=bucket)
        self._key, sub = jax.random.split(self._key)
        theta, iters, mean_r = self._step(self._phi, sub,
                                          mb.word_ids, mb.counts)
        self._pending.append(_Dispatch(
            bucket=bucket, reqs=[(rid, t) for rid, _, t in take],
            theta=theta, iters=iters, mean_r=mean_r))
        self._dispatches += 1

    def _warmup(self) -> None:
        """AOT-compile the step for every bucket shape before any request
        arrives (the driver's --warmup-buckets contract carries over)."""
        t0 = time.time()
        key = jax.random.PRNGKey(0)
        out = None
        for b in self.len_buckets:
            out = self._step(self._phi, key,
                             jnp.zeros((self.batch_docs, b), jnp.int32),
                             jnp.zeros((self.batch_docs, b), jnp.float32))
            key = jax.random.PRNGKey(0)
        if out is not None:
            jax.block_until_ready(out[0])
        self.warmup_s = time.time() - t0

    # ------------------------------------------------------------ harvest

    def drain(self) -> List[ServeResult]:
        """Flush partial buckets, then materialize every pending batch in
        dispatch order.  Per-request latency is measured when the batch's
        theta is actually ready — the first host sync any request pays."""
        self.flush()
        results: List[ServeResult] = []
        for d in self._pending:
            theta = np.asarray(jax.block_until_ready(d.theta))
            t_done = time.time()
            iters, mean_r = int(d.iters), float(d.mean_r)
            self._iters_sum += iters
            for row, (rid, t_sub) in enumerate(d.reqs):
                lat = t_done - t_sub
                self._latencies.append(lat)
                results.append(ServeResult(
                    req_id=rid, theta=theta[row], latency_s=lat,
                    bucket=d.bucket, iters=iters, mean_r=mean_r))
            self._t_last_done = t_done
        self._served += len(results)
        self._pending.clear()
        return results

    # -------------------------------------------------------------- stats

    def _compiles(self) -> int:
        try:
            return int(self._step._cache_size())
        except AttributeError:
            return -1

    def stats(self) -> Dict[str, object]:
        """Serving scorecard: docs/s, latency percentiles, compile bound,
        and the per-request communication bytes of a sharded phi."""
        lats = np.asarray(self._latencies, np.float64)
        span = ((self._t_last_done - self._t_first)
                if self._latencies and self._t_first is not None else 0.0)
        mean_iters = (self._iters_sum / self._dispatches
                      if self._dispatches else 0.0)
        per_batch_bytes = self.meter.per_minibatch_bytes(max(mean_iters, 1))
        return {
            "served": self._served,
            "dispatches": self._dispatches,
            "docs_per_s": self._served / span if span > 0 else float("nan"),
            "latency_p50_s": float(np.percentile(lats, 50)) if lats.size else
            float("nan"),
            "latency_p99_s": float(np.percentile(lats, 99)) if lats.size else
            float("nan"),
            "mean_fold_iters": mean_iters,
            "compiles": self._compiles(),
            "len_buckets": list(self.len_buckets),
            "warmup_s": self.warmup_s,
            "bytes_by_phase": dict(self.meter.bytes_by_phase),
            "per_request_bytes": per_batch_bytes / max(self.batch_docs, 1),
        }
