"""Fold-in serving engine: the paper's train-once / fold-in-forever
deployment (Eq. 20 protocol) as a production request loop (DESIGN.md §11).

Architecture — every piece reuses the training stack, none forks it:

  - **one inference body**: the jitted step is
    `core.infer.make_fold_in_step` — the exact program `perplexity.evaluate`
    and the streaming driver's held-out hook compile;
  - **shape-bucketed admission**: requests queue per length bucket
    (`data/batching.bucket_len` on the same ladder the training driver
    uses); a bucket dispatches when `batch_docs` requests accumulate (or on
    `flush`, padded with empty documents so D never varies).  The step
    therefore compiles at most ``len(len_buckets)`` times, all of them at
    construction (AOT warmup) — a serving process never stalls a request
    on a compile;
  - **asynchronous dispatch**: `submit` never blocks on device work;
    dispatched batches park as device futures (theta + diagnostics stay
    device-resident) and are materialized in `drain`, where per-request
    latency is measured at the moment the batch's result is actually ready;
  - **accounting**: the `CommMeter` threaded through the fold-in reducers
    bills the per-iteration renormalization/residual psums of a
    topic-sharded phi, so `stats()` reports bytes-per-request next to
    p50/p99 latency and docs/s;
  - **OOV admission** (DESIGN.md §12): unknown or out-of-range words are
    folded in through a guard row carrying the beta-prior mass — a
    request containing words the model never trained on returns a finite
    theta (never an exception), with the OOV token rate reported in
    `stats()` and per result.  ``from_checkpoint`` picks up the vocab
    table and live size a dynamic-vocabulary driver checkpoint carries.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import infer, perplexity
from repro.core.types import LDAConfig
from repro.data.batching import bucket_len, docs_to_padded

_EMPTY_DOC = (np.zeros(1, np.int32), np.zeros(1, np.float32))


@dataclasses.dataclass
class ServeResult:
    """One served request: the topic mixture plus serving diagnostics."""

    req_id: int
    theta: np.ndarray              # [K] normalized topic mixture
    latency_s: float               # submit -> batch result ready
    bucket: int                    # L bucket that admitted the request
    iters: int                     # fold-in sweeps the batch ran
    mean_r: float                  # batch residual at exit
    oov_tokens: float = 0.0        # token mass folded in via the OOV row
    phi_version: int = 0           # vocab/phi generation that served it (§14)


@dataclasses.dataclass
class _Dispatch:
    bucket: int
    reqs: List[Tuple[int, float, float]]    # (req_id, t_submit, oov_tokens)
    theta: jnp.ndarray                      # device future [D, K]
    iters: jnp.ndarray                      # device scalar
    mean_r: jnp.ndarray                     # device scalar
    phi_version: int = 0                    # phi generation at dispatch


class FoldInEngine:
    """Serve topic mixtures for incoming documents with phi fixed.

    `phi_acc` is the trained sufficient statistic ([W, K], as checkpointed
    by the streaming driver); pass ``normalized=True`` when handing an
    already-normalized topic-word matrix.  ``topic_shards > 1`` serves a
    topic-sharded phi ([N, W, K/N] internally) with psum'd renormalization
    under the vmap simulation — bit-identical collectives to a model-axis
    mesh, metered per batch.

    **OOV admission** (DESIGN.md §12): a serving process must never crash
    or silently corrupt on an unseen word.  `live_words` marks rows
    [live_words, W) of phi as guard rows (a dynamic-vocabulary
    checkpoint); when absent, one guard row is appended.  phi is
    normalized over the live rows only and every guard row carries the
    beta-prior mass beta/denom — the posterior of one unseen word — so
    folding an OOV token in is exact smoothed-LDA math, not a clamp.
    Incoming word ids are translated through `vocab` (an external-key
    ``data.vocab.VocabMap``, lookup only) when given, else range-checked;
    unknown/out-of-range words route to the first guard row and their
    token mass is reported as ``oov_rate`` in ``stats()`` and
    ``oov_tokens`` per result.
    """

    def __init__(self, phi_acc, cfg: LDAConfig, *,
                 len_buckets: Sequence[int] = (16, 32, 64, 128),
                 batch_docs: int = 32, fold_iters: int = 30,
                 residual_tol: float = 1e-2, topic_shards: int = 1,
                 sync_dtype=None, normalized: bool = False,
                 impl: Optional[str] = None, seed: int = 0,
                 warmup: bool = True, vocab=None,
                 live_words: Optional[int] = None,
                 phi_version: int = 0):
        self.len_buckets = tuple(sorted(int(b) for b in len_buckets))
        if any(b % 8 for b in self.len_buckets):
            raise ValueError(f"len_buckets must be multiples of 8 "
                             f"(docs_to_padded pads L to 8): "
                             f"{self.len_buckets}")
        # the driver's L-invariant init contract carries over to serving:
        # the random field is drawn at the largest bucket and sliced, so a
        # document's theta does not depend on which bucket admitted it
        self.cfg = cfg = dataclasses.replace(
            cfg, init_pad_len=max(self.len_buckets[-1],
                                  cfg.init_pad_len or 0))
        if sync_dtype is None:
            sync_dtype = (jnp.bfloat16 if cfg.sync_dtype == "bfloat16"
                          else jnp.float32)
        self.batch_docs = int(batch_docs)
        self.fold_iters = int(fold_iters)
        self.residual_tol = float(residual_tol)
        self.phi_version = int(phi_version)
        self._topic_shards = int(topic_shards)
        self._sync_dtype = sync_dtype
        self._impl = impl
        phi_in = jnp.asarray(phi_acc)
        if jnp.issubdtype(phi_in.dtype, jnp.floating) \
                and phi_in.dtype != jnp.float32:
            # compressed accumulators (DESIGN.md §13): the statistic may
            # arrive bf16 from a phi_acc_dtype='bfloat16' run — serving
            # math (normalization, fold-in) always runs in f32
            phi_in = phi_in.astype(jnp.float32)
        self.w_cap = int(phi_in.shape[0])   # trained capacity rung (§12/§14)
        self.live_words = (int(live_words) if live_words is not None
                           else int(phi_in.shape[0]))
        if not 0 < self.live_words <= phi_in.shape[0]:
            # live_words=0 (a checkpoint fenced before any admission) is
            # rejected too: there is no trained row to serve from
            raise ValueError(f"live_words={live_words} outside phi's "
                             f"{phi_in.shape[0]} rows")
        if self.live_words == phi_in.shape[0]:
            # guarantee a guard row to serve OOV words from (appended rows
            # are zero statistic == pure beta prior after normalization)
            phi_in = jnp.concatenate(
                [phi_in, jnp.zeros((1, phi_in.shape[1]), phi_in.dtype)])
        self._oov_row = self.live_words
        self._vocab = vocab
        if normalized:
            # caller-normalized phi: guard rows fall back to the uniform
            # topic prior (no statistic left to derive beta/denom from)
            guard = jnp.arange(phi_in.shape[0])[:, None] >= self.live_words
            phi_norm = jnp.where(guard, 1.0 / phi_in.shape[1], phi_in)
        else:
            phi_norm = perplexity.normalize_phi(phi_in, cfg.beta,
                                                live_w=self.live_words)
        # the step's compiled W (and the Pallas guard-row index) is the
        # padded serving capacity, not the user-visible cfg.vocab_size
        self._cfg = dataclasses.replace(cfg, vocab_size=phi_norm.shape[0])
        self._phi = infer.split_topic_shards(phi_norm, topic_shards)
        self._step, self.meter = infer.make_fold_in_step(
            self._cfg, fold_iters=self.fold_iters,
            residual_tol=self.residual_tol, topic_shards=topic_shards,
            sync_dtype=sync_dtype, impl=impl)
        self._key = jax.random.PRNGKey(seed)
        self._queues: Dict[int, List[Tuple[int, tuple, float, float]]] = {
            b: [] for b in self.len_buckets}
        self._pending: List[_Dispatch] = []
        self._next_id = 0
        self._dispatches = 0
        self._iters_sum = 0
        self._latencies: List[float] = []
        self._served = 0
        self._oov_tokens = 0.0
        self._total_tokens = 0.0
        self._t_first: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self.warmup_s = 0.0
        self._warm = bool(warmup)
        if warmup:
            self._warmup()

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg: Optional[LDAConfig] = None,
                        step: Optional[int] = None, sharding=None,
                        **kw) -> "FoldInEngine":
        """Checkpoint-to-serve: load phi (and, when `cfg` is omitted, the
        model geometry from the driver's saved run signature) and build an
        engine — no training carry ever touches the serving process."""
        from repro.data.vocab import VocabMap
        from repro.dist import checkpoint as ckpt

        # dtype=float32 up-casts a compressed (bf16) checkpoint at load:
        # serving math always runs in f32 whatever the training storage
        phi_acc, extra, _ = ckpt.restore_phi(ckpt_dir, step=step,
                                             sharding=sharding,
                                             dtype=jnp.float32)
        dyn = extra.get("dyn")
        if dyn is not None:
            # dynamic-vocabulary checkpoint: pick up the vocab table and
            # live size saved with phi — rows above live_w are guard rows.
            # vocab_version stamps which compaction generation this table
            # belongs to (served back as phi_version on every result, §14)
            kw.setdefault("live_words", int(dyn["live_w"]))
            kw.setdefault("phi_version", int(dyn.get("vocab_version", 0)))
            if dyn.get("vocab_keys") is not None:
                kw.setdefault("vocab", VocabMap(dyn["vocab_keys"]))
        if cfg is None:
            run = extra.get("run", {})
            # geometry comes from phi itself (always right, including the
            # capacity rung of a dynamic checkpoint); the saved run
            # signature only routes the knobs the fold-in body reads —
            # impl (jnp vs Pallas) and sync_dtype (reducer payload width)
            if not run:
                import warnings
                warnings.warn(
                    f"checkpoint in {ckpt_dir!r} carries no run signature; "
                    f"serving with impl='jnp' sync_dtype='float32' — pass "
                    f"cfg= if the model was trained with other knobs",
                    stacklevel=2)
            cfg = LDAConfig(vocab_size=int(phi_acc.shape[0]),
                            num_topics=int(phi_acc.shape[1]),
                            impl=str(run.get("impl", "jnp")),
                            sync_dtype=str(run.get("sync_dtype",
                                                   "float32")))
        return cls(phi_acc, cfg, **kw)

    # ----------------------------------------------------- lifecycle swap

    def swap_phi(self, phi_acc, *, live_words: Optional[int] = None,
                 vocab=None, phi_version: Optional[int] = None) -> None:
        """Install a new (phi statistic, vocab table) generation — the
        serving half of a training-side lifecycle event (DESIGN.md §14:
        a compaction remap, a decayed refresh, a recycled topic set).

        Torn-remap-proof by construction: requests already queued were
        admitted (translated to rows) under the OLD vocab, so they are
        flushed and dispatched against the old phi first — a dispatched
        batch captures the phi it runs on, and its results keep the old
        ``phi_version`` stamp.  Everything submitted after the swap
        translates and folds in under the new generation.  The jitted
        step is rebuilt only when the serving capacity actually changes
        (a compaction that dropped a rung); same-capacity swaps — a
        remap within the rung — reuse the compiled program.
        """
        self.flush()
        phi_in = jnp.asarray(phi_acc)
        if jnp.issubdtype(phi_in.dtype, jnp.floating) \
                and phi_in.dtype != jnp.float32:
            phi_in = phi_in.astype(jnp.float32)
        self.w_cap = int(phi_in.shape[0])
        live = (int(live_words) if live_words is not None
                else int(phi_in.shape[0]))
        if not 0 < live <= phi_in.shape[0]:
            raise ValueError(f"live_words={live_words} outside phi's "
                             f"{phi_in.shape[0]} rows")
        if live == phi_in.shape[0]:
            phi_in = jnp.concatenate(
                [phi_in, jnp.zeros((1, phi_in.shape[1]), phi_in.dtype)])
        phi_norm = perplexity.normalize_phi(phi_in, self.cfg.beta,
                                            live_w=live)
        rebuilt = phi_norm.shape[0] != self._cfg.vocab_size
        if rebuilt:
            self._cfg = dataclasses.replace(self._cfg,
                                            vocab_size=phi_norm.shape[0])
            self._step, self.meter = infer.make_fold_in_step(
                self._cfg, fold_iters=self.fold_iters,
                residual_tol=self.residual_tol,
                topic_shards=self._topic_shards,
                sync_dtype=self._sync_dtype, impl=self._impl)
        self.live_words = live
        self._oov_row = live
        if vocab is not None:
            self._vocab = vocab
        self._phi = infer.split_topic_shards(phi_norm, self._topic_shards)
        self.phi_version = (int(phi_version) if phi_version is not None
                            else self.phi_version + 1)
        if rebuilt and self._warm:
            self._warmup()

    # ---------------------------------------------------------- admission

    def _admit_doc(self, doc: Tuple[np.ndarray, np.ndarray]
                   ) -> Tuple[tuple, float]:
        """Translate a document into live phi rows; never raises on OOV.

        With a vocab table the ids are EXTERNAL keys (lookup only — a
        serving process must not move the vocabulary); without one they
        are raw rows, range-checked against the live vocabulary.  Either
        way unknown words land on the first guard row, whose normalized
        phi value is the beta-prior mass (finite theta by construction).
        Returns ((rows, counts), oov_token_mass).
        """
        ids, counts = doc
        counts = np.asarray(counts, np.float32)
        if self._vocab is not None:
            rows = self._vocab.rows(
                ids.tolist() if hasattr(ids, "tolist") else ids,
                admit=False, oov_row=self._oov_row)
        else:
            ids = np.asarray(ids)
            rows = np.where((ids >= 0) & (ids < self.live_words),
                            ids, self._oov_row).astype(np.int32)
        oov = float(counts[rows == self._oov_row].sum())
        self._oov_tokens += oov
        self._total_tokens += float(counts.sum())
        return (rows, counts), oov

    def submit(self, doc: Tuple[np.ndarray, np.ndarray],
               req_id: Optional[int] = None) -> int:
        """Enqueue one document (word_ids, counts); never blocks on device
        work.  Returns the request id its `ServeResult` will carry."""
        if req_id is None:
            req_id = self._next_id
        self._next_id = max(self._next_id, req_id) + 1
        now = time.time()
        if self._t_first is None:
            self._t_first = now
        doc, oov = self._admit_doc(doc)
        b = bucket_len(len(doc[0]), self.len_buckets)
        q = self._queues[b]
        q.append((req_id, doc, now, oov))
        if len(q) >= self.batch_docs:
            self._dispatch(b)
        return req_id

    def flush(self) -> None:
        """Dispatch every partially-filled bucket (padded with empty docs,
        so D — and therefore the compiled shapes — never varies)."""
        for b in self.len_buckets:
            while self._queues[b]:
                self._dispatch(b)

    def _dispatch(self, bucket: int) -> None:
        q = self._queues[bucket]
        take, self._queues[bucket] = q[:self.batch_docs], q[self.batch_docs:]
        docs = [doc for _, doc, _, _ in take]
        docs += [_EMPTY_DOC] * (self.batch_docs - len(docs))
        mb = docs_to_padded(docs, max_len=bucket)
        self._key, sub = jax.random.split(self._key)
        theta, iters, mean_r = self._step(self._phi, sub,
                                          mb.word_ids, mb.counts)
        self._pending.append(_Dispatch(
            bucket=bucket, reqs=[(rid, t, oov) for rid, _, t, oov in take],
            theta=theta, iters=iters, mean_r=mean_r,
            phi_version=self.phi_version))
        self._dispatches += 1

    def _warmup(self) -> None:
        """AOT-compile the step for every bucket shape before any request
        arrives (the driver's --warmup-buckets contract carries over)."""
        t0 = time.time()
        key = jax.random.PRNGKey(0)
        out = None
        for b in self.len_buckets:
            out = self._step(self._phi, key,
                             jnp.zeros((self.batch_docs, b), jnp.int32),
                             jnp.zeros((self.batch_docs, b), jnp.float32))
            key = jax.random.PRNGKey(0)
        if out is not None:
            jax.block_until_ready(out[0])
        self.warmup_s = time.time() - t0

    # ------------------------------------------------------------ harvest

    def drain(self) -> List[ServeResult]:
        """Flush partial buckets, then materialize every pending batch in
        dispatch order.  Per-request latency is measured when the batch's
        theta is actually ready — the first host sync any request pays."""
        self.flush()
        results: List[ServeResult] = []
        for d in self._pending:
            theta = np.asarray(jax.block_until_ready(d.theta))
            t_done = time.time()
            iters, mean_r = int(d.iters), float(d.mean_r)
            self._iters_sum += iters
            for row, (rid, t_sub, oov) in enumerate(d.reqs):
                lat = t_done - t_sub
                self._latencies.append(lat)
                results.append(ServeResult(
                    req_id=rid, theta=theta[row], latency_s=lat,
                    bucket=d.bucket, iters=iters, mean_r=mean_r,
                    oov_tokens=oov, phi_version=d.phi_version))
            self._t_last_done = t_done
        self._served += len(results)
        self._pending.clear()
        return results

    # -------------------------------------------------------------- stats

    def _compiles(self) -> int:
        try:
            return int(self._step._cache_size())
        except AttributeError:
            return -1

    def stats(self) -> Dict[str, object]:
        """Serving scorecard: docs/s, latency percentiles, compile bound,
        and the per-request communication bytes of a sharded phi."""
        lats = np.asarray(self._latencies, np.float64)
        span = ((self._t_last_done - self._t_first)
                if self._latencies and self._t_first is not None else 0.0)
        mean_iters = (self._iters_sum / self._dispatches
                      if self._dispatches else 0.0)
        per_batch_bytes = self.meter.per_minibatch_bytes(max(mean_iters, 1))
        return {
            "served": self._served,
            "dispatches": self._dispatches,
            "docs_per_s": self._served / span if span > 0 else float("nan"),
            "latency_p50_s": float(np.percentile(lats, 50)) if lats.size else
            float("nan"),
            "latency_p99_s": float(np.percentile(lats, 99)) if lats.size else
            float("nan"),
            "mean_fold_iters": mean_iters,
            "compiles": self._compiles(),
            "len_buckets": list(self.len_buckets),
            "warmup_s": self.warmup_s,
            "bytes_by_phase": dict(self.meter.bytes_by_phase),
            "per_request_bytes": per_batch_bytes / max(self.batch_docs, 1),
            "live_words": self.live_words,
            "w_cap": self.w_cap,
            # ladder occupancy: how full the trained capacity rung is —
            # climbing toward 1.0 means the next admission wave grows the
            # ladder; falling after a swap means compaction reclaimed rows
            "occupancy": self.live_words / max(self.w_cap, 1),
            "phi_version": self.phi_version,
            "oov_rate": (self._oov_tokens / self._total_tokens
                         if self._total_tokens else 0.0),
        }
