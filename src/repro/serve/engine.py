"""Serving engines: the paper's train-once / fold-in-forever deployment
(Eq. 20 protocol) as a production request loop (DESIGN.md §11, §16).

Two admission runtimes share one inference core (`core.infer`):

  - **`SlabEngine` — continuous batching (DESIGN.md §16, the default).**
    A fixed [slots, slot_len] in-flight slab where every slot holds one
    live document; the jitted step advances all slots a few fold-in
    sweeps, slots whose residual bound clears retire and are refilled
    from the queue mid-flight.  No bucket barriers: a request never
    waits for a batch to fill and a converged document never holds its
    slot while stragglers finish.  Compiles are bounded by the slab
    geometry (ONE step shape), never by request shapes.  On top: a
    per-tenant theta LRU (`serve.cache.ThetaCache`) serving or
    warm-starting repeat documents, and an `OOVTrigger` turning the
    oov_rate stat into hot-OOV admission batches for the train side.
  - **`FoldInEngine` — bucket-ladder admission (DESIGN.md §11).**
    Requests queue per length bucket and dispatch when `batch_docs`
    accumulate (or on flush).  Kept as the barrier baseline BENCH_serve
    measures the slab against, and for strictly batch-at-a-time
    deployments (offline eval sweeps).

Shared contracts: asynchronous dispatch (submit never blocks on device
work), per-request latency measured when the result is actually ready,
`CommMeter`-billed sync bytes for a topic-sharded phi — the slab bills
per retired document at retirement (requests share a step, so batch-level
attribution would be wrong), the bucket engine per dispatched batch —
OOV admission through the guard row (never an exception, DESIGN.md §12),
and version-stamped `swap_phi` hot-swap (DESIGN.md §14): queued work
drains under the generation that admitted it, so no request ever
observes a torn phi.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import infer, perplexity
from repro.core.types import LDAConfig
from repro.data.batching import bucket_len, docs_to_padded, slab_refill
from repro.serve.cache import ThetaCache, doc_digest

_EMPTY_DOC = (np.zeros(1, np.int32), np.zeros(1, np.float32))


@dataclasses.dataclass
class ServeResult:
    """One served request: the topic mixture plus serving diagnostics."""

    req_id: int
    theta: np.ndarray              # [K] normalized topic mixture
    latency_s: float               # submit -> batch result ready
    bucket: int                    # L bucket / slab slot that admitted it
    iters: int                     # fold-in sweeps run (0 for a cache hit)
    mean_r: float                  # residual at exit (per-doc on the slab)
    oov_tokens: float = 0.0        # token mass folded in via the OOV row
    phi_version: int = 0           # vocab/phi generation that served it (§14)
    comm_bytes: float = 0.0        # sync bytes billed to this request (§16)
    cached: bool = False           # served straight from the theta cache
    tenant: Optional[Hashable] = None
    error: Optional[str] = None    # quarantine flag: "nonfinite_input" /
    #                                "nonfinite_theta" — theta is the prior
    #                                mixture, never cached (§17)


@dataclasses.dataclass
class Shed:
    """A typed admission rejection (DESIGN.md §17): the queue would blow
    the SLO deadline, so the request is refused at submit time instead of
    queueing unboundedly.  Returned by ``SlabEngine.submit`` when
    ``admission_slo_s`` is set; never mixed into served results."""

    req_id: int
    est_wait_s: float              # drain-model estimate that tripped it
    slo_s: float
    queue_depth: int
    tenant: Optional[Hashable] = None


def _prepare_phi(phi_acc, cfg: LDAConfig, live_words: Optional[int],
                 normalized: bool) -> Tuple[jnp.ndarray, int, int]:
    """Normalize a phi statistic for serving: f32 upcast, guard-row
    guarantee, live-W beta-prior normalization (DESIGN.md §12).

    Returns ``(phi_norm [W', K], live, w_cap)`` where W' >= w_cap includes
    at least one guard row above ``live`` serving the OOV mass.
    """
    phi_in = jnp.asarray(phi_acc)
    if jnp.issubdtype(phi_in.dtype, jnp.floating) \
            and phi_in.dtype != jnp.float32:
        # compressed accumulators (DESIGN.md §13): the statistic may
        # arrive bf16 from a phi_acc_dtype='bfloat16' run — serving
        # math (normalization, fold-in) always runs in f32
        phi_in = phi_in.astype(jnp.float32)
    w_cap = int(phi_in.shape[0])
    live = int(live_words) if live_words is not None else w_cap
    if not 0 < live <= w_cap:
        # live_words=0 (a checkpoint fenced before any admission) is
        # rejected too: there is no trained row to serve from
        raise ValueError(f"live_words={live_words} outside phi's "
                         f"{w_cap} rows")
    if live == w_cap:
        # guarantee a guard row to serve OOV words from (appended rows
        # are zero statistic == pure beta prior after normalization)
        phi_in = jnp.concatenate(
            [phi_in, jnp.zeros((1, phi_in.shape[1]), phi_in.dtype)])
    if normalized:
        # caller-normalized phi: guard rows fall back to the uniform
        # topic prior (no statistic left to derive beta/denom from)
        guard = jnp.arange(phi_in.shape[0])[:, None] >= live
        phi_norm = jnp.where(guard, 1.0 / phi_in.shape[1], phi_in)
    else:
        phi_norm = perplexity.normalize_phi(phi_in, cfg.beta, live_w=live)
    return phi_norm, live, w_cap


class OOVTrigger:
    """Close the serve->train loop on vocabulary drift (DESIGN.md §16).

    The engines already *measure* OOV pressure (``oov_rate`` in
    ``stats()``); this turns the measurement into an actionable training
    signal.  Every admitted request reports its OOV keys here; once at
    least ``min_docs`` documents accumulated AND their windowed OOV token
    rate crossed ``rate_threshold``, the hottest unseen keys are emitted
    as an *admission batch*: a list of raw external-key documents shaped
    exactly like a training corpus chunk, ready for
    ``data.batching.vocab_mapped_minibatch_stream(batch, vocab,
    admit=True)`` (or the streaming driver's admission path) to fold the
    hot vocabulary into the next training segment.  The window resets on
    emission, so a sustained drift emits a batch per window rather than
    one giant batch at shutdown.
    """

    def __init__(self, rate_threshold: float = 0.05, min_docs: int = 64,
                 batch_keys: int = 128):
        self.rate_threshold = float(rate_threshold)
        self.min_docs = int(min_docs)
        self.batch_keys = int(batch_keys)
        self._hot: Counter = Counter()
        self._docs = 0
        self._tokens = 0.0
        self._oov_tokens = 0.0
        self._batches: List[list] = []
        self.emitted = 0

    def observe(self, oov_keys, oov_counts, total_tokens: float) -> None:
        """One admitted request: its OOV (external key, count) pairs and
        its total token mass."""
        self._docs += 1
        self._tokens += float(total_tokens)
        for k, c in zip(oov_keys, oov_counts):
            self._hot[k] += float(c)
            self._oov_tokens += float(c)
        self._maybe_emit()

    def _maybe_emit(self) -> None:
        if self._docs < self.min_docs or self._tokens <= 0:
            return
        if self._oov_tokens / self._tokens < self.rate_threshold:
            return
        hot = self._hot.most_common(self.batch_keys)
        if not hot:
            return
        keys = np.asarray([k for k, _ in hot], np.int64)
        cnts = np.asarray([c for _, c in hot], np.float32)
        # one admission batch == one corpus chunk of raw external-key docs
        self._batches.append([(keys, cnts)])
        self.emitted += 1
        self._hot.clear()
        self._docs = 0
        self._tokens = 0.0
        self._oov_tokens = 0.0

    def take(self) -> List[list]:
        """Pop every pending admission batch (the train side's poll)."""
        out, self._batches = self._batches, []
        return out


def _load_serving_checkpoint(ckpt_dir: str, cfg: Optional[LDAConfig],
                             step: Optional[int], sharding, kw: dict):
    """Shared checkpoint-to-serve loader for both engines: restore phi,
    pick up a dynamic-vocabulary table, and (when `cfg` is omitted) derive
    the model geometry from the driver's saved run signature."""
    from repro.data.vocab import VocabMap
    from repro.dist import checkpoint as ckpt

    # dtype=float32 up-casts a compressed (bf16) checkpoint at load:
    # serving math always runs in f32 whatever the training storage
    phi_acc, extra, _ = ckpt.restore_phi(ckpt_dir, step=step,
                                         sharding=sharding,
                                         dtype=jnp.float32)
    dyn = extra.get("dyn")
    if dyn is not None:
        # dynamic-vocabulary checkpoint: pick up the vocab table and
        # live size saved with phi — rows above live_w are guard rows.
        # vocab_version stamps which compaction generation this table
        # belongs to (served back as phi_version on every result, §14)
        kw.setdefault("live_words", int(dyn["live_w"]))
        kw.setdefault("phi_version", int(dyn.get("vocab_version", 0)))
        if dyn.get("vocab_keys") is not None:
            kw.setdefault("vocab", VocabMap(dyn["vocab_keys"]))
    if cfg is None:
        run = extra.get("run", {})
        # geometry comes from phi itself (always right, including the
        # capacity rung of a dynamic checkpoint); the saved run
        # signature only routes the knobs the fold-in body reads —
        # impl (jnp vs Pallas) and sync_dtype (reducer payload width)
        if not run:
            import warnings
            warnings.warn(
                f"checkpoint in {ckpt_dir!r} carries no run signature; "
                f"serving with impl='jnp' sync_dtype='float32' — pass "
                f"cfg= if the model was trained with other knobs",
                stacklevel=2)
        cfg = LDAConfig(vocab_size=int(phi_acc.shape[0]),
                        num_topics=int(phi_acc.shape[1]),
                        impl=str(run.get("impl", "jnp")),
                        sync_dtype=str(run.get("sync_dtype",
                                               "float32")))
    return phi_acc, cfg, kw


@dataclasses.dataclass
class _Dispatch:
    bucket: int
    reqs: List[Tuple[int, float, float]]    # (req_id, t_submit, oov_tokens)
    theta: jnp.ndarray                      # device future [D, K]
    iters: jnp.ndarray                      # device scalar
    mean_r: jnp.ndarray                     # device scalar
    phi_version: int = 0                    # phi generation at dispatch


class FoldInEngine:
    """Serve topic mixtures for incoming documents with phi fixed.

    `phi_acc` is the trained sufficient statistic ([W, K], as checkpointed
    by the streaming driver); pass ``normalized=True`` when handing an
    already-normalized topic-word matrix.  ``topic_shards > 1`` serves a
    topic-sharded phi ([N, W, K/N] internally) with psum'd renormalization
    under the vmap simulation — bit-identical collectives to a model-axis
    mesh, metered per batch.

    **OOV admission** (DESIGN.md §12): a serving process must never crash
    or silently corrupt on an unseen word.  `live_words` marks rows
    [live_words, W) of phi as guard rows (a dynamic-vocabulary
    checkpoint); when absent, one guard row is appended.  phi is
    normalized over the live rows only and every guard row carries the
    beta-prior mass beta/denom — the posterior of one unseen word — so
    folding an OOV token in is exact smoothed-LDA math, not a clamp.
    Incoming word ids are translated through `vocab` (an external-key
    ``data.vocab.VocabMap``, lookup only) when given, else range-checked;
    unknown/out-of-range words route to the first guard row and their
    token mass is reported as ``oov_rate`` in ``stats()`` and
    ``oov_tokens`` per result.
    """

    def __init__(self, phi_acc, cfg: LDAConfig, *,
                 len_buckets: Sequence[int] = (16, 32, 64, 128),
                 batch_docs: int = 32, fold_iters: int = 30,
                 residual_tol: float = 1e-2, topic_shards: int = 1,
                 sync_dtype=None, normalized: bool = False,
                 impl: Optional[str] = None, seed: int = 0,
                 warmup: bool = True, vocab=None,
                 live_words: Optional[int] = None,
                 phi_version: int = 0):
        self.len_buckets = tuple(sorted(int(b) for b in len_buckets))
        if any(b % 8 for b in self.len_buckets):
            raise ValueError(f"len_buckets must be multiples of 8 "
                             f"(docs_to_padded pads L to 8): "
                             f"{self.len_buckets}")
        # the driver's L-invariant init contract carries over to serving:
        # the random field is drawn at the largest bucket and sliced, so a
        # document's theta does not depend on which bucket admitted it
        self.cfg = cfg = dataclasses.replace(
            cfg, init_pad_len=max(self.len_buckets[-1],
                                  cfg.init_pad_len or 0))
        if sync_dtype is None:
            sync_dtype = (jnp.bfloat16 if cfg.sync_dtype == "bfloat16"
                          else jnp.float32)
        self.batch_docs = int(batch_docs)
        self.fold_iters = int(fold_iters)
        self.residual_tol = float(residual_tol)
        self.phi_version = int(phi_version)
        self._topic_shards = int(topic_shards)
        self._sync_dtype = sync_dtype
        self._impl = impl
        phi_norm, self.live_words, self.w_cap = _prepare_phi(
            phi_acc, cfg, live_words, normalized)
        self._oov_row = self.live_words
        self._vocab = vocab
        # the step's compiled W (and the Pallas guard-row index) is the
        # padded serving capacity, not the user-visible cfg.vocab_size
        self._cfg = dataclasses.replace(cfg, vocab_size=phi_norm.shape[0])
        self._phi = infer.split_topic_shards(phi_norm, topic_shards)
        self._step, self.meter = infer.make_fold_in_step(
            self._cfg, fold_iters=self.fold_iters,
            residual_tol=self.residual_tol, topic_shards=topic_shards,
            sync_dtype=sync_dtype, impl=impl)
        self._key = jax.random.PRNGKey(seed)
        self._queues: Dict[int, List[Tuple[int, tuple, float, float]]] = {
            b: [] for b in self.len_buckets}
        self._pending: List[_Dispatch] = []
        self._next_id = 0
        self._dispatches = 0
        self._iters_sum = 0
        self._latencies: List[float] = []
        self._served = 0
        self._oov_tokens = 0.0
        self._total_tokens = 0.0
        self._t_first: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self.warmup_s = 0.0
        self._warm = bool(warmup)
        if warmup:
            self._warmup()

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg: Optional[LDAConfig] = None,
                        step: Optional[int] = None, sharding=None,
                        **kw) -> "FoldInEngine":
        """Checkpoint-to-serve: load phi (and, when `cfg` is omitted, the
        model geometry from the driver's saved run signature) and build an
        engine — no training carry ever touches the serving process."""
        phi_acc, cfg, kw = _load_serving_checkpoint(ckpt_dir, cfg, step,
                                                    sharding, kw)
        return cls(phi_acc, cfg, **kw)

    # ----------------------------------------------------- lifecycle swap

    def swap_phi(self, phi_acc, *, live_words: Optional[int] = None,
                 vocab=None, phi_version: Optional[int] = None) -> None:
        """Install a new (phi statistic, vocab table) generation — the
        serving half of a training-side lifecycle event (DESIGN.md §14:
        a compaction remap, a decayed refresh, a recycled topic set).

        Torn-remap-proof by construction: requests already queued were
        admitted (translated to rows) under the OLD vocab, so they are
        flushed and dispatched against the old phi first — a dispatched
        batch captures the phi it runs on, and its results keep the old
        ``phi_version`` stamp.  Everything submitted after the swap
        translates and folds in under the new generation.  The jitted
        step is rebuilt only when the serving capacity actually changes
        (a compaction that dropped a rung); same-capacity swaps — a
        remap within the rung — reuse the compiled program.
        """
        self.flush()
        phi_norm, live, self.w_cap = _prepare_phi(phi_acc, self.cfg,
                                                  live_words, False)
        rebuilt = phi_norm.shape[0] != self._cfg.vocab_size
        if rebuilt:
            self._cfg = dataclasses.replace(self._cfg,
                                            vocab_size=phi_norm.shape[0])
            self._step, self.meter = infer.make_fold_in_step(
                self._cfg, fold_iters=self.fold_iters,
                residual_tol=self.residual_tol,
                topic_shards=self._topic_shards,
                sync_dtype=self._sync_dtype, impl=self._impl)
        self.live_words = live
        self._oov_row = live
        if vocab is not None:
            self._vocab = vocab
        self._phi = infer.split_topic_shards(phi_norm, self._topic_shards)
        self.phi_version = (int(phi_version) if phi_version is not None
                            else self.phi_version + 1)
        if rebuilt and self._warm:
            self._warmup()

    # ---------------------------------------------------------- admission

    def _admit_doc(self, doc: Tuple[np.ndarray, np.ndarray]
                   ) -> Tuple[tuple, float]:
        """Translate a document into live phi rows; never raises on OOV.

        With a vocab table the ids are EXTERNAL keys (lookup only — a
        serving process must not move the vocabulary); without one they
        are raw rows, range-checked against the live vocabulary.  Either
        way unknown words land on the first guard row, whose normalized
        phi value is the beta-prior mass (finite theta by construction).
        Returns ((rows, counts), oov_token_mass).
        """
        ids, counts = doc
        counts = np.asarray(counts, np.float32)
        if self._vocab is not None:
            rows = self._vocab.rows(
                ids.tolist() if hasattr(ids, "tolist") else ids,
                admit=False, oov_row=self._oov_row)
        else:
            ids = np.asarray(ids)
            rows = np.where((ids >= 0) & (ids < self.live_words),
                            ids, self._oov_row).astype(np.int32)
        oov = float(counts[rows == self._oov_row].sum())
        self._oov_tokens += oov
        self._total_tokens += float(counts.sum())
        return (rows, counts), oov

    def submit(self, doc: Tuple[np.ndarray, np.ndarray],
               req_id: Optional[int] = None) -> int:
        """Enqueue one document (word_ids, counts); never blocks on device
        work.  Returns the request id its `ServeResult` will carry."""
        if req_id is None:
            req_id = self._next_id
        self._next_id = max(self._next_id, req_id) + 1
        now = time.time()
        if self._t_first is None:
            self._t_first = now
        doc, oov = self._admit_doc(doc)
        b = bucket_len(len(doc[0]), self.len_buckets)
        q = self._queues[b]
        q.append((req_id, doc, now, oov))
        if len(q) >= self.batch_docs:
            self._dispatch(b)
        return req_id

    def flush(self) -> None:
        """Dispatch every partially-filled bucket (padded with empty docs,
        so D — and therefore the compiled shapes — never varies)."""
        for b in self.len_buckets:
            while self._queues[b]:
                self._dispatch(b)

    def flush_stale(self, max_age_s: float, now: Optional[float] = None
                    ) -> int:
        """Dispatch buckets whose OLDEST queued request has waited at
        least ``max_age_s`` — the open-loop latency bound of bucket-ladder
        admission.  Under a sustained arrival process a bucket may fill
        too slowly (mixed-length traffic spreads over the ladder); this
        caps a request's queueing delay at the cost of padded-slot work
        (a partial flush still computes the full ``batch_docs``).
        Returns the number of dispatches."""
        now = time.time() if now is None else now
        n = 0
        for b in self.len_buckets:
            while self._queues[b] and now - self._queues[b][0][2] >= \
                    max_age_s:
                self._dispatch(b)
                n += 1
        return n

    def _dispatch(self, bucket: int) -> None:
        q = self._queues[bucket]
        take, self._queues[bucket] = q[:self.batch_docs], q[self.batch_docs:]
        docs = [doc for _, doc, _, _ in take]
        docs += [_EMPTY_DOC] * (self.batch_docs - len(docs))
        mb = docs_to_padded(docs, max_len=bucket)
        self._key, sub = jax.random.split(self._key)
        theta, iters, mean_r = self._step(self._phi, sub,
                                          mb.word_ids, mb.counts)
        self._pending.append(_Dispatch(
            bucket=bucket, reqs=[(rid, t, oov) for rid, _, t, oov in take],
            theta=theta, iters=iters, mean_r=mean_r,
            phi_version=self.phi_version))
        self._dispatches += 1

    def _warmup(self) -> None:
        """AOT-compile the step for every bucket shape before any request
        arrives (the driver's --warmup-buckets contract carries over)."""
        t0 = time.time()
        key = jax.random.PRNGKey(0)
        out = None
        for b in self.len_buckets:
            out = self._step(self._phi, key,
                             jnp.zeros((self.batch_docs, b), jnp.int32),
                             jnp.zeros((self.batch_docs, b), jnp.float32))
            key = jax.random.PRNGKey(0)
        if out is not None:
            jax.block_until_ready(out[0])
        self.warmup_s = time.time() - t0

    # ------------------------------------------------------------ harvest

    def _materialize(self, d: _Dispatch) -> List[ServeResult]:
        theta = np.asarray(jax.block_until_ready(d.theta))
        t_done = time.time()
        iters, mean_r = int(d.iters), float(d.mean_r)
        self._iters_sum += iters
        results = []
        for row, (rid, t_sub, oov) in enumerate(d.reqs):
            lat = t_done - t_sub
            self._latencies.append(lat)
            results.append(ServeResult(
                req_id=rid, theta=theta[row], latency_s=lat,
                bucket=d.bucket, iters=iters, mean_r=mean_r,
                oov_tokens=oov, phi_version=d.phi_version))
        self._t_last_done = t_done
        self._served += len(results)
        return results

    def drain(self) -> List[ServeResult]:
        """Flush partial buckets, then materialize every pending batch in
        dispatch order.  Per-request latency is measured when the batch's
        theta is actually ready — the first host sync any request pays."""
        self.flush()
        results: List[ServeResult] = []
        for d in self._pending:
            results.extend(self._materialize(d))
        self._pending.clear()
        return results

    def poll(self) -> List[ServeResult]:
        """Materialize only the dispatched batches whose device work has
        ALREADY finished (never blocks, never flushes) — the open-loop
        driver's harvest.  Dispatches complete in order on one stream, so
        the ready set is a prefix of the pending list."""
        results: List[ServeResult] = []
        while self._pending:
            head = self._pending[0]
            try:
                ready = head.theta.is_ready()
            except AttributeError:      # older jax: no readiness probe
                break
            if not ready:
                break
            results.extend(self._materialize(head))
            self._pending.pop(0)
        return results

    def in_flight(self) -> int:
        """Requests submitted but not yet returned (queued + dispatched)."""
        return (sum(len(q) for q in self._queues.values())
                + sum(len(d.reqs) for d in self._pending))

    # -------------------------------------------------------------- stats

    def _compiles(self) -> int:
        try:
            return int(self._step._cache_size())
        except AttributeError:
            return -1

    def stats(self) -> Dict[str, object]:
        """Serving scorecard: docs/s, latency percentiles, compile bound,
        and the per-request communication bytes of a sharded phi."""
        lats = np.asarray(self._latencies, np.float64)
        span = ((self._t_last_done - self._t_first)
                if self._latencies and self._t_first is not None else 0.0)
        mean_iters = (self._iters_sum / self._dispatches
                      if self._dispatches else 0.0)
        per_batch_bytes = self.meter.per_minibatch_bytes(max(mean_iters, 1))
        return {
            "served": self._served,
            "dispatches": self._dispatches,
            "docs_per_s": self._served / span if span > 0 else float("nan"),
            "latency_p50_s": float(np.percentile(lats, 50)) if lats.size else
            float("nan"),
            "latency_p99_s": float(np.percentile(lats, 99)) if lats.size else
            float("nan"),
            "mean_fold_iters": mean_iters,
            "compiles": self._compiles(),
            "len_buckets": list(self.len_buckets),
            "warmup_s": self.warmup_s,
            "bytes_by_phase": dict(self.meter.bytes_by_phase),
            "per_request_bytes": per_batch_bytes / max(self.batch_docs, 1),
            "live_words": self.live_words,
            "w_cap": self.w_cap,
            # ladder occupancy: how full the trained capacity rung is —
            # climbing toward 1.0 means the next admission wave grows the
            # ladder; falling after a swap means compaction reclaimed rows
            "occupancy": self.live_words / max(self.w_cap, 1),
            "phi_version": self.phi_version,
            "oov_rate": (self._oov_tokens / self._total_tokens
                         if self._total_tokens else 0.0),
        }


# ---------------------------------------------------------------------------
# continuous-batching slab engine (DESIGN.md §16)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SlabReq:
    """Host-side record of one admitted request (queued or in a slot)."""

    req_id: int
    t_submit: float
    oov: float
    tenant: Optional[Hashable] = None
    digest: Optional[str] = None
    warm: Optional[np.ndarray] = None    # cached theta for warm-start


@dataclasses.dataclass
class _StepOut:
    """Device futures of one slab step, awaiting harvest.  Steps chain on
    the donated state without any host sync; the retirement mask is read
    back lazily (`is_ready` probe, or blocking once the pipeline window
    fills), so the jitted steps dispatch back-to-back."""

    retired: jnp.ndarray               # [B] bool (device future)
    theta: jnp.ndarray                 # [B, K]
    iters: jnp.ndarray                 # [B] int32
    r_doc: jnp.ndarray                 # [B] f32
    phi_version: int


class SlabEngine:
    """Continuous-batching serving: one persistent in-flight slab instead
    of bucket barriers (DESIGN.md §16).

    Admission state machine, per slot: **admit** (translate, queue) ->
    **iterate** (the jitted `core.infer.make_slab_step` advances every
    live slot ``sweeps_per_step`` fold-in sweeps) -> **retire** (the
    slot's geometric-tail residual bound clears ``residual_tol`` or hits
    ``fold_iters``; its theta is harvested and billed) -> **refill** (the
    freed slot takes the next queued request mid-flight, no barrier).
    The compiled step shape is fixed by the slab geometry — requests of
    any length share ONE compile (over-long documents are truncated to
    ``slot_len`` by top-count mass, the same argument the paper applies
    to the vocabulary tail).

    On top of the slab:

      - **theta cache** (``theta_cache=``, an int capacity or a
        `serve.cache.ThetaCache`): repeat (tenant, content) documents
        either skip fold-in entirely (``cache_mode='serve'``) or
        warm-start their slot from the cached theta and retire in fewer
        sweeps (``cache_mode='warm'``); entries are phi_version-stamped,
        so a hot-swap invalidates them for free;
      - **OOV retraining trigger** (``oov_trigger=``, an `OOVTrigger`):
        admitted OOV keys feed a windowed rate threshold that emits
        hot-OOV admission batches for the train side
        (``take_retrain_batches()``);
      - **per-request byte billing**: requests share a step, so batch
        attribution would be wrong — each retired document is billed its
        own sweeps' share of the slab's metered collective bytes
        (``ServeResult.comm_bytes``), at retirement.

    ``swap_phi`` pumps the slab to empty first: queued work was
    row-translated under the admitting vocabulary, so it completes under
    the old (phi, version) and post-swap submissions fold in under the
    new one — no request ever observes a torn phi.  phi is a step
    *argument*, so a capacity change merely re-specializes the jit (the
    ``compiles`` stat counts it); same-capacity swaps reuse the program.
    """

    def __init__(self, phi_acc, cfg: LDAConfig, *, slots: int = 64,
                 slot_len: int = 64, sweeps_per_step: int = 4,
                 refill_cap: Optional[int] = None, fold_iters: int = 30,
                 residual_tol: float = 1e-2, topic_shards: int = 1,
                 sync_dtype=None, normalized: bool = False,
                 impl: Optional[str] = None, seed: int = 0,
                 warmup: bool = True, vocab=None,
                 live_words: Optional[int] = None, phi_version: int = 0,
                 theta_cache=None, cache_mode: str = "serve",
                 oov_trigger: Optional[OOVTrigger] = None,
                 pipeline: int = 4,
                 admission_slo_s: Optional[float] = None):
        if cache_mode not in ("serve", "warm"):
            raise ValueError(f"cache_mode must be 'serve' or 'warm': "
                             f"{cache_mode!r}")
        self.cfg = cfg
        self.slots = int(slots)
        self.slot_len = int(slot_len)
        self.sweeps_per_step = int(sweeps_per_step)
        # default refill lanes = slots/4: the refill scatter + in-step
        # random init run EVERY step whether lanes are used or not, so
        # full-width lanes tax steady state to speed up only cold start
        self._refill_cap = (max(1, self.slots // 4) if refill_cap is None
                            else int(refill_cap))
        self.fold_iters = int(fold_iters)
        self.residual_tol = float(residual_tol)
        self.phi_version = int(phi_version)
        self._topic_shards = int(topic_shards)
        self._K = int(cfg.num_topics)
        self.cache = (ThetaCache(theta_cache)
                      if isinstance(theta_cache, int) else theta_cache)
        self.cache_mode = cache_mode
        self.trigger = oov_trigger
        if sync_dtype is None:
            sync_dtype = (jnp.bfloat16 if cfg.sync_dtype == "bfloat16"
                          else jnp.float32)
        phi_norm, self.live_words, self.w_cap = _prepare_phi(
            phi_acc, cfg, live_words, normalized)
        self._oov_row = self.live_words
        self._vocab = vocab
        self._cfg = dataclasses.replace(cfg,
                                        vocab_size=int(phi_norm.shape[0]))
        self._phi = infer.split_topic_shards(phi_norm, topic_shards)
        self._init_state, self._step, self.meter = infer.make_slab_step(
            self._cfg, slots=self.slots, slot_len=self.slot_len,
            refill_cap=self._refill_cap,
            sweeps_per_step=self.sweeps_per_step,
            fold_iters=self.fold_iters, residual_tol=self.residual_tol,
            topic_shards=topic_shards, sync_dtype=sync_dtype, impl=impl)
        self._state = self._init_state()
        self._key = jax.random.PRNGKey(seed)
        self._queue: "deque[Tuple[_SlabReq, np.ndarray, np.ndarray]]" = \
            deque()
        self._slot_req: List[Optional[_SlabReq]] = [None] * self.slots
        self._free: "deque[int]" = deque(range(self.slots))
        self._done: List[ServeResult] = []
        # steps in flight on the device, harvested lazily: deeper windows
        # pipeline better but delay retire->refill by up to that many steps
        self._pipeline = max(0, int(pipeline))
        self._pending: "deque[_StepOut]" = deque()
        self._next_id = 0
        self._steps = 0
        self._occ_sum = 0
        self._served = 0
        self._cache_served = 0
        self._warm_served = 0
        self._cold_served = 0
        self._iters_sum = 0
        self._warm_iters = 0
        self._cold_iters = 0
        self._billed_bytes = 0.0
        self._latencies: List[float] = []
        self._oov_tokens = 0.0
        self._total_tokens = 0.0
        self._t_first: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self._rates: Optional[Tuple[float, float]] = None
        self.admission_slo_s = (float(admission_slo_s)
                                if admission_slo_s is not None else None)
        self._shed_count = 0
        self._quarantined = 0
        self._step_ema_s: Optional[float] = None
        self.warmup_s = 0.0
        self._warm_flag = bool(warmup)
        if warmup:
            self._warmup()

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg: Optional[LDAConfig] = None,
                        step: Optional[int] = None, sharding=None,
                        **kw) -> "SlabEngine":
        """Checkpoint-to-serve for the slab runtime (same contract as
        `FoldInEngine.from_checkpoint`)."""
        phi_acc, cfg, kw = _load_serving_checkpoint(ckpt_dir, cfg, step,
                                                    sharding, kw)
        return cls(phi_acc, cfg, **kw)

    # ---------------------------------------------------------- admission

    def _admit_doc(self, doc: Tuple[np.ndarray, np.ndarray]
                   ) -> Tuple[np.ndarray, np.ndarray, float]:
        """Translate external ids to live phi rows (OOV -> guard row,
        never an exception — DESIGN.md §12) and feed the OOV trigger."""
        ids, counts = doc
        ids = np.asarray(ids)
        counts = np.asarray(counts, np.float32)
        if self._vocab is not None:
            rows = np.asarray(self._vocab.rows(
                ids.tolist(), admit=False, oov_row=self._oov_row),
                np.int32)
        else:
            rows = np.where((ids >= 0) & (ids < self.live_words),
                            ids, self._oov_row).astype(np.int32)
        oov_mask = rows == self._oov_row
        oov = float(counts[oov_mask].sum())
        self._oov_tokens += oov
        self._total_tokens += float(counts.sum())
        if self.trigger is not None:
            self.trigger.observe(ids[oov_mask].tolist(), counts[oov_mask],
                                 float(counts.sum()))
        return rows, counts, oov

    def submit(self, doc: Tuple[np.ndarray, np.ndarray],
               req_id: Optional[int] = None,
               tenant: Optional[Hashable] = None) -> "int | Shed":
        """Admit one document; never blocks on device work.  A theta-cache
        hit in ``serve`` mode completes immediately (harvest via
        ``poll``/``drain``); otherwise the request queues for the next
        free slot.  With ``admission_slo_s`` set, a request whose
        drain-model wait estimate exceeds the SLO is refused with a
        typed ``Shed`` instead of queueing (DESIGN.md §17); a document
        with non-finite counts retires immediately with
        ``error='nonfinite_input'`` instead of poisoning the slab."""
        if req_id is None:
            req_id = self._next_id
        self._next_id = max(self._next_id, req_id) + 1
        now = time.time()
        if self._t_first is None:
            self._t_first = now
        if not np.isfinite(np.asarray(doc[1], np.float32)).all():
            # poisoned payload: quarantine at admission — flat-prior theta
            # with an error flag, never a slab crash, never cached
            self._quarantined += 1
            t_done = time.time()
            lat = t_done - now
            self._done.append(ServeResult(
                req_id=req_id,
                theta=np.full((self._K,), 1.0 / self._K, np.float32),
                latency_s=lat, bucket=-1, iters=0, mean_r=0.0,
                oov_tokens=0.0, phi_version=self.phi_version,
                comm_bytes=0.0, cached=False, tenant=tenant,
                error="nonfinite_input"))
            self._latencies.append(lat)
            self._served += 1
            self._t_last_done = t_done
            return req_id
        # digest hashes the RAW payload, before vocab translation: repeat
        # content collides whatever rows this generation maps it to
        digest = (doc_digest(doc[0], doc[1])
                  if self.cache is not None else None)
        rows, counts, oov = self._admit_doc(doc)
        req = _SlabReq(req_id=req_id, t_submit=now, oov=oov,
                       tenant=tenant, digest=digest)
        if self.cache is not None:
            hit = self.cache.get(tenant, digest, self.phi_version)
            if hit is not None:
                if self.cache_mode == "serve":
                    t_done = time.time()
                    lat = t_done - now
                    self._done.append(ServeResult(
                        req_id=req_id, theta=np.asarray(hit),
                        latency_s=lat, bucket=-1, iters=0, mean_r=0.0,
                        oov_tokens=oov, phi_version=self.phi_version,
                        comm_bytes=0.0, cached=True, tenant=tenant))
                    self._latencies.append(lat)
                    self._served += 1
                    self._cache_served += 1
                    self._t_last_done = t_done
                    return req_id
                req.warm = np.asarray(hit, np.float32)
        if self.admission_slo_s is not None:
            est = self._est_wait_s()
            if est > self.admission_slo_s:
                self._shed_count += 1
                return Shed(req_id=req_id, est_wait_s=est,
                            slo_s=self.admission_slo_s,
                            queue_depth=len(self._queue), tenant=tenant)
        self._queue.append((req, rows, counts))
        return req_id

    def _est_wait_s(self) -> float:
        """Drain-model wait estimate for a request queued NOW: queue-ahead
        dispatch delay plus one slot tenure, priced at the measured step
        EMA.  Dispatch rate per step is bounded by both the refill lanes
        and the steady-state slot turnover (slots freed per step at mean
        tenure).  Cold engine (no step yet) estimates 0 — always admit."""
        if self._step_ema_s is None:
            return 0.0
        tenure = max(1.0, self.fold_iters / self.sweeps_per_step)
        rate = max(1e-9, min(float(self._refill_cap), self.slots / tenure))
        return self._step_ema_s * (len(self._queue) / rate + tenure)

    # ------------------------------------------------------------ iterate

    def live_slots(self) -> int:
        return self.slots - len(self._free)

    def in_flight(self) -> int:
        """Requests admitted but not yet retired (queued + in a slot)."""
        return len(self._queue) + self.live_slots()

    def step(self) -> int:
        """One slab step: refill free slots from the queue, dispatch the
        jitted advance (``sweeps_per_step`` sweeps over every live slot),
        and harvest whatever earlier steps have finished.  The dispatch
        never blocks — retirement masks are read back lazily through a
        bounded pipeline window, so consecutive steps chain on the device
        while the host runs ahead.  Returns how many documents were
        harvested (possibly from earlier steps)."""
        t0 = time.time()
        n_take = min(len(self._queue), len(self._free), self._refill_cap)
        take = [self._queue.popleft() for _ in range(n_take)]
        slot_ids = [self._free.popleft() for _ in range(n_take)]
        wid, cnt, slot, _ = slab_refill(
            [(rows, counts) for _, rows, counts in take], slot_ids,
            capacity=self._refill_cap, slot_len=self.slot_len,
            pad_slot=self.slots)
        warm = np.zeros((self._refill_cap, self._K), np.float32)
        wmask = np.zeros((self._refill_cap,), bool)
        for i, (req, _, _) in enumerate(take):
            if req.warm is not None:
                warm[i] = req.warm
                wmask[i] = True
        for s, (req, _, _) in zip(slot_ids, take):
            self._slot_req[s] = req
        self._occ_sum += self.live_slots()
        self._key, sub = jax.random.split(self._key)
        self._state, retired, theta_out, iters, r_doc = self._step(
            self._phi, self._state, wid, cnt, slot, warm, wmask, sub)
        self._steps += 1
        self._pending.append(_StepOut(retired, theta_out, iters, r_doc,
                                      self.phi_version))
        n = self._harvest(block=len(self._pending) > self._pipeline)
        dt = time.time() - t0
        self._step_ema_s = (dt if self._step_ema_s is None
                            else 0.8 * self._step_ema_s + 0.2 * dt)
        return n

    def _harvest(self, block: bool = False) -> int:
        """Materialize finished steps off the pipeline head.  ``block``
        forces the oldest step to completion (used when the window fills
        or on drain); otherwise only steps whose retirement mask is
        already on host are consumed."""
        n = 0
        while self._pending:
            head = self._pending[0]
            if not block:
                try:
                    if not head.retired.is_ready():
                        break
                except AttributeError:
                    pass             # no readiness probe: fall through
            self._pending.popleft()
            n += self._materialize(head)
            block = False            # only the first is forced
        return n

    def _materialize(self, out: _StepOut) -> int:
        ret = np.asarray(out.retired)    # the (only) host sync point
        if not ret.any():
            return 0
        th = np.asarray(out.theta)
        itn = np.asarray(out.iters)
        rn = np.asarray(out.r_doc)
        t_done = time.time()
        sweep_b, once_b = self._billing_rates()
        n = 0
        for s in np.nonzero(ret)[0]:
            s = int(s)
            req = self._slot_req[s]
            if req is None:     # retired in an older pipelined step and
                continue        # already harvested from it
            self._slot_req[s] = None
            self._free.append(s)
            doc_iters = int(itn[s])
            bytes_d = sweep_b * doc_iters + once_b
            lat = t_done - req.t_submit
            theta_d = th[s]
            # NaN/Inf quarantine: one poisoned document retires with an
            # error flag (and never enters the cache) instead of crashing
            # the slab or serving garbage to a repeat request (§17)
            finite = bool(np.isfinite(theta_d).all())
            if not finite:
                self._quarantined += 1
            if (self.cache is not None and req.digest is not None
                    and finite):
                self.cache.put(req.tenant, req.digest, out.phi_version,
                               theta_d)
            self._done.append(ServeResult(
                req_id=req.req_id, theta=theta_d, latency_s=lat,
                bucket=s, iters=doc_iters, mean_r=float(rn[s]),
                oov_tokens=req.oov, phi_version=out.phi_version,
                comm_bytes=bytes_d, cached=False, tenant=req.tenant,
                error=None if finite else "nonfinite_theta"))
            self._latencies.append(lat)
            self._iters_sum += doc_iters
            if req.warm is not None:
                self._warm_iters += doc_iters
                self._warm_served += 1
            else:
                self._cold_iters += doc_iters
                self._cold_served += 1
            self._billed_bytes += bytes_d
            self._served += 1
            n += 1
        self._t_last_done = t_done
        return n

    def pump(self, max_steps: Optional[int] = None) -> int:
        """Step until the queue, slab and pipeline are all empty (or
        ``max_steps``).  ``fold_iters`` bounds every slot's tenure, so
        this terminates.  Returns the number of steps run."""
        steps = 0
        while max_steps is None or steps < max_steps:
            if self._queue or self.live_slots():
                self.step()
                steps += 1
            elif self._pending:
                self._harvest(block=True)
            else:
                break
        return steps

    # ------------------------------------------------------------ harvest

    def poll(self) -> List[ServeResult]:
        """Pop every result harvested so far (cache hits and retirements);
        never blocks, never steps."""
        out, self._done = self._done, []
        return out

    def drain(self) -> List[ServeResult]:
        """Pump the slab to empty and return every outstanding result."""
        self.pump()
        return self.poll()

    # ----------------------------------------------------- lifecycle swap

    def swap_phi(self, phi_acc, *, live_words: Optional[int] = None,
                 vocab=None, phi_version: Optional[int] = None) -> None:
        """Install a new (phi statistic, vocab table) generation.  The
        slab is pumped to empty FIRST: everything already admitted was
        row-translated under the old vocabulary, so it retires under the
        old (phi, version) and only post-swap submissions see the new
        generation — torn-phi-proof by construction (DESIGN.md §16)."""
        self.pump()
        phi_norm, live, self.w_cap = _prepare_phi(phi_acc, self.cfg,
                                                  live_words, False)
        recompiled = int(phi_norm.shape[0]) != self._cfg.vocab_size
        self._cfg = dataclasses.replace(self._cfg,
                                        vocab_size=int(phi_norm.shape[0]))
        self.live_words = live
        self._oov_row = live
        if vocab is not None:
            self._vocab = vocab
        self._phi = infer.split_topic_shards(phi_norm, self._topic_shards)
        self.phi_version = (int(phi_version) if phi_version is not None
                            else self.phi_version + 1)
        # phi is a step ARGUMENT: a capacity change re-specializes the jit
        # on the next call — warm the new shape eagerly off the request path
        if recompiled and self._warm_flag:
            self._warmup()

    # ----------------------------------------------------- serve -> train

    def take_retrain_batches(self) -> List[list]:
        """Pop pending hot-OOV admission batches from the trigger (empty
        when no trigger is attached or the rate stayed under threshold)."""
        return self.trigger.take() if self.trigger is not None else []

    # -------------------------------------------------------------- stats

    def _warmup(self) -> None:
        """Compile the (single) step shape before any request arrives: an
        all-empty refill advances an empty slab — semantically a no-op."""
        t0 = time.time()
        R = self._refill_cap
        self._state, retired, *_ = self._step(
            self._phi, self._state,
            np.zeros((R, self.slot_len), np.int32),
            np.zeros((R, self.slot_len), np.float32),
            np.full((R,), self.slots, np.int32),
            np.zeros((R, self._K), np.float32),
            np.zeros((R,), bool), jax.random.PRNGKey(0))
        jax.block_until_ready(retired)
        self.warmup_s = time.time() - t0

    def _billing_rates(self) -> Tuple[float, float]:
        """(bytes per slot-sweep, bytes per document) attribution rates
        from the metered step trace.  Loop-phase bytes split evenly over
        the ``sweeps_per_step`` sweeps and ``slots`` lanes of one step; a
        document's bill is its OWN iteration count times that rate, plus
        its share of the once-per-document phases (init over the refill
        lanes, theta renorm over the slots).  Zero (local reducer) when
        phi is unsharded."""
        if self._rates is None:
            by = self.meter.bytes_by_phase
            loop = (by.get("slab_norm_loop", 0.0)
                    + by.get("slab_rw_loop", 0.0))
            once = (by.get("slab_init_norm", 0.0)
                    / max(self._refill_cap, 1)
                    + by.get("slab_theta_norm", 0.0) / self.slots)
            self._rates = (loop / self.sweeps_per_step / self.slots, once)
        return self._rates

    def _compiles(self) -> int:
        try:
            return int(self._step._cache_size())
        except AttributeError:
            return -1

    def stats(self) -> Dict[str, object]:
        """Serving scorecard (superset of the bucket engine's): goodput,
        latency percentiles, the ONE-compile bound, slab occupancy, warm
        vs cold sweep counts, cache and retraining-trigger state."""
        lats = np.asarray(self._latencies, np.float64)
        span = ((self._t_last_done - self._t_first)
                if self._latencies and self._t_first is not None else 0.0)
        folded = self._cold_served + self._warm_served
        out: Dict[str, object] = {
            "served": self._served,
            "steps": self._steps,
            "docs_per_s": self._served / span if span > 0 else float("nan"),
            "latency_p50_s": float(np.percentile(lats, 50)) if lats.size
            else float("nan"),
            "latency_p99_s": float(np.percentile(lats, 99)) if lats.size
            else float("nan"),
            "mean_fold_iters": (self._iters_sum / folded if folded
                                else 0.0),
            "cold_fold_iters": (self._cold_iters / self._cold_served
                                if self._cold_served else 0.0),
            "warm_fold_iters": (self._warm_iters / self._warm_served
                                if self._warm_served else 0.0),
            "compiles": self._compiles(),
            "slots": self.slots,
            "slot_len": self.slot_len,
            "sweeps_per_step": self.sweeps_per_step,
            # mean fraction of slots doing useful work per step — the
            # slab's analogue of padded-lane efficiency
            "slot_occupancy": (self._occ_sum / self._steps / self.slots
                               if self._steps else 0.0),
            "warmup_s": self.warmup_s,
            "bytes_by_phase": dict(self.meter.bytes_by_phase),
            "per_request_bytes": (self._billed_bytes / folded if folded
                                  else 0.0),
            "live_words": self.live_words,
            "w_cap": self.w_cap,
            "occupancy": self.live_words / max(self.w_cap, 1),
            "phi_version": self.phi_version,
            "oov_rate": (self._oov_tokens / self._total_tokens
                         if self._total_tokens else 0.0),
            "cache_served": self._cache_served,
            "warm_starts": self._warm_served,
            "retrain_batches": (self.trigger.emitted if self.trigger
                                else 0),
            # graceful-degradation counters (§17): sheds are refused at
            # submit and never enter served/latency stats
            "shed": self._shed_count,
            "shed_frac": (self._shed_count
                          / max(1, self._shed_count + self._served
                                + self.in_flight())),
            "quarantined": self._quarantined,
            "admission_slo_s": self.admission_slo_s,
            "step_ema_s": (self._step_ema_s if self._step_ema_s is not None
                           else 0.0),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out
