"""AdamW with mixed precision: bf16 working params, fp32 master + moments.

State pytree mirrors the params (so the sharding policy applies verbatim:
master/m/v inherit each param's PartitionSpec — ZeRO-3 style partitioning
falls out of FSDP specs)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    master: Any      # fp32 copy of params
    m: Any           # fp32 first moment
    v: Any           # fp32 second moment
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(master=f32(params), m=zeros(params), v=zeros(params),
                      step=jnp.zeros((), jnp.int32))


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_bf16_params, new_state).  Grads may be bf16; math is fp32."""
    step = state.step + 1
    warm = jnp.minimum(1.0, step.astype(jnp.float32) / max(cfg.warmup_steps, 1))
    lr = cfg.lr * warm

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = nu2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                       + cfg.weight_decay * p * (p.ndim >= 2))
        return p2, mu2, nu2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_p = jax.tree.leaves(state.master)
    out = [upd(g, mu, nu, p) for g, mu, nu, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), new_master)
    return new_params, AdamWState(new_master, new_m, new_v, step)
