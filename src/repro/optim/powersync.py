"""PowerSync — the paper's communication-efficient sync generalized to
data-parallel *gradient* all-reduce (DESIGN.md §5, the paper's closing
claim: "the proposed communication-efficient MPA scheme can be generalized
to other parallel machine learning algorithms").

Mapping from the paper's LDA quantities:

  phi_hat sync (Eq. 4)        ->  gradient all-reduce
  residual matrix r (Eq. 7-9) ->  error-feedback accumulator (unsent gradient
                                  mass retained locally, re-eligible later —
                                  exactly Fig. 3's dynamic re-selection)
  power words (rows)          ->  top-|lambda_r * rows| rows by synced |acc| row norm
  power topics (cols)         ->  top-|lambda_c * cols| cols by synced |acc| col norm

Deviation from the LDA case (documented): per-row column selection is free
in POBP because the residual matrix itself is synchronized each iteration;
for gradients that sync would cost as much as the payload it saves, so
PowerSync uses *rectangular* (rows x cols) selection from two cheap norm
vectors.  Selection inputs are psum'd, so every shard picks identical
indices — the same property that makes the paper's scheme index-free on
TPU (DESIGN.md §2).

Intended use: inside a `shard_map` (or vmap-simulated) pure-DP training
region where gradients are per-shard; see launch/train.py and
tests/test_powersync.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.sync import CommMeter, Reducer


@dataclasses.dataclass(frozen=True)
class PowerSyncConfig:
    lambda_rows: float = 0.2       # fraction of rows synced per step
    lambda_cols: float = 0.5       # fraction of cols synced per step
    min_dense_size: int = 4096     # tensors smaller than this sync densely
    sync_every_dense: int = 0      # 0=never: periodic full sync (robustness)


def _as_2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """View any >=2-D tensor as [rows, cols] (leading dims merged)."""
    shape = x.shape
    return x.reshape(-1, shape[-1]), shape


def powersync_tree(grads: Any, residual: Any, reducer: Reducer,
                   cfg: PowerSyncConfig, num_shards: int):
    """Compressed all-reduce with error feedback.

    Returns (synced_mean_grads, new_residual).  Invariant: over repeated
    steps, every coordinate's accumulated mass is eventually transmitted
    (residual re-selection — the paper's no-information-loss argument §3.1).
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        if acc.ndim < 2 or acc.size <= cfg.min_dense_size:
            synced = reducer.psum(acc, "powersync_dense")
            return (synced / num_shards).astype(g.dtype), jnp.zeros_like(acc)

        a2, shape = _as_2d(acc)
        rows, cols = a2.shape
        P = max(1, int(round(cfg.lambda_rows * rows)))
        Pc = max(1, int(round(cfg.lambda_cols * cols)))

        # step 1: power rows from the synchronized row-norm vector
        row_norm = reducer.psum(jnp.sum(jnp.abs(a2), axis=1), "powersync_norms",
                                compress=False)
        sel_r = jax.lax.top_k(row_norm, P)[1]
        picked = jnp.take(a2, sel_r, axis=0)                      # [P, cols]

        # step 2: power cols from the synchronized col-norm of picked rows
        col_norm = reducer.psum(jnp.sum(jnp.abs(picked), axis=0),
                                "powersync_norms", compress=False)
        sel_c = jax.lax.top_k(col_norm, Pc)[1]
        packed = jnp.take(picked, sel_c, axis=1)                  # [P, Pc]

        # the only payload-sized collective: the packed power submatrix
        packed_sum = reducer.psum(packed, "powersync_payload")

        synced = jnp.zeros_like(a2).at[sel_r[:, None], sel_c[None, :]].set(
            packed_sum / num_shards)
        # error feedback: what this shard did not transmit stays local
        new_res = a2.at[sel_r[:, None], sel_c[None, :]].set(0.0)
        return synced.reshape(shape).astype(g.dtype), new_res.reshape(shape)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def residual_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def dense_sync_tree(grads: Any, reducer: Reducer, num_shards: int):
    """The baseline (Eq. 4 analogue): full-gradient all-reduce."""
    return jax.tree.map(
        lambda g: (reducer.psum(g.astype(jnp.float32), "dense_grads")
                   / num_shards).astype(g.dtype), grads)
