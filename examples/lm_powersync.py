"""Beyond-paper example: the paper's communication-efficient sync applied to
data-parallel LM training (PowerSync — DESIGN.md §5).

Trains the reduced smollm-360m config twice (dense grad sync vs PowerSync)
and compares loss curves and communicated bytes.

    PYTHONPATH=src python examples/lm_powersync.py [--steps 120]
"""

import argparse

from repro.launch.train import main as train_main


def run(sync: str, steps: int):
    losses, meter = train_main([
        "--arch", "smollm-360m", "--reduced", "--steps", str(steps),
        "--batch", "16", "--seq", "64", "--shards", "4", "--sync", sync,
        "--lambda-rows", "0.2", "--lambda-cols", "0.5",
        "--log-every", str(max(steps // 5, 1))])
    phase = ("powersync_payload" if sync == "power" else "dense_grads")
    return losses, meter.phase_bytes(phase) + meter.phase_bytes(
        "powersync_norms") + meter.phase_bytes("powersync_dense")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    print("=== dense gradient all-reduce (baseline, Eq. 4 analogue) ===")
    dense_losses, dense_bytes = run("dense", args.steps)
    print("\n=== PowerSync (power rows x cols + error feedback, Eq. 6) ===")
    power_losses, power_bytes = run("power", args.steps)

    print(f"\nfinal loss: dense={dense_losses[-1]:.4f} "
          f"power={power_losses[-1]:.4f}")
    print(f"gradient sync bytes/step: dense={dense_bytes:,} "
          f"power={power_bytes:,} "
          f"({dense_bytes / max(power_bytes, 1):.1f}x reduction)")
    print("PowerSync tracks the dense loss curve while communicating a "
          "fraction of the gradient — the paper's power-law selection with "
          "error feedback, generalized exactly as its §5 anticipates.")


if __name__ == "__main__":
    main()
